//! Ablation playground: sweep GPTQT's two knobs — intermediate bits
//! (Fig. 4) and scale re-exploration range (Table VI) — on one model and
//! print the perplexity surface. A quick way to see *why* the paper picks
//! 5-bit step 1 and range 1.
//!
//! ```sh
//! cargo run --release --example ablation -- [model] [--fast]
//! ```

use gptqt::data::Dataset;
use gptqt::eval::ppl::{calib_for, eval_for, eval_ppl, EvalConfig};
use gptqt::model::load_or_init;
use gptqt::model::quantize::quantize_model;
use gptqt::quant::{Method, QuantConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = args.iter().any(|a| a == "--fast");
    let name = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .map(|s| s.as_str())
        .unwrap_or("opt-micro");

    let ecfg = if fast { EvalConfig::fast() } else { EvalConfig::default() };
    let (model, trained) = load_or_init(name, "artifacts", 0)?;
    println!("== GPTQT ablation surface on {name} (trained={trained}) ==");
    let calib = calib_for(&ecfg, Dataset::WikiSyn);
    let windows = eval_for(&ecfg, Dataset::WikiSyn);
    println!("full fp32 ppl: {:.2}\n", eval_ppl(&model, &windows));

    println!("step1 bits × explore range → 3-bit ppl");
    print!("{:>11}", "");
    for range in 0..=2u32 {
        print!("{:>10}", format!("range {range}"));
    }
    println!();
    for step1 in 4..=6u32 {
        print!("{:>11}", format!("step1={step1}"));
        for range in 0..=2u32 {
            let qcfg = QuantConfig {
                bits: 3,
                step1_bits: step1,
                explore_range: range,
                explore_grid: if fast { 3 } else { 6 },
                ..Default::default()
            };
            let qm = quantize_model(&model, &calib, Method::Gptqt, &qcfg, false)?;
            let ppl = eval_ppl(&qm.model, &windows);
            print!("{:>10.2}", ppl);
        }
        println!();
    }
    println!("\n(paper: step1 4–5 bits optimal — Fig. 4; range 1 helps, Table VI)");
    Ok(())
}
