//! Quickstart: quantize one weight matrix twice (the paper's §II-B
//! pipeline on a single layer) and inspect every intermediate object —
//! the 60-second tour of the library.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use gptqt::quant::fuse::FusedRow;
use gptqt::quant::gptq::accumulate_hessian;
use gptqt::quant::gptqt::{search_row, SearchParams};
use gptqt::quant::{quantize_layer, Method, QuantConfig};
use gptqt::tensor::Tensor;
use gptqt::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(0);

    // A layer: 32 output features, 128 inputs; calibration activations.
    let w = Tensor::randn(32, 128, 0.8, &mut rng);
    let acts = Tensor::randn(256, 128, 1.0, &mut rng);
    let hessian = accumulate_hessian(&acts); // H = 2XᵀX  (Eq. 1)

    println!("== GPTQT on one 32x128 layer ==\n");

    // --- step-by-step on one row ---------------------------------------
    let hdiag: Vec<f64> = (0..128).map(|i| hessian.get(i, i)).collect();
    let params = SearchParams {
        step1_bits: 5,     // quantize *first* to 5 bits (Fig. 4 optimum)
        final_bits: 3,     // then re-encode as 3-bit binary coding
        explore_range: 1,  // re-explore Ŝ across 4..6-bit pitches (Eq. 7)
        explore_grid: 8,
    };
    let row = search_row(w.row(0), &hdiag, &params);
    println!("row 0 search: {} candidates evaluated", row.candidates);
    println!("  chosen scale Ŝ = {:.5} (base would be {:.5})", row.scale, {
        let (mn, mx) = {
            let r = w.row(0);
            r.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(a, b), &v| (a.min(v), b.max(v)))
        };
        (mx - mn) / 31.0
    });
    println!("  BCchoice levels (grid units): {:?}", row.codebook.levels);

    // fusion (Eq. 8–11): two steps collapse into Σ α̂ᵢb̂ᵢ + ĉ
    let fused = FusedRow::from_gptqt(&row);
    println!("  fused α̂ = {:?}", fused.alphas);
    println!("  fused bias = {:.5}", fused.bias);
    println!("  representable values: {:?}\n", fused.levels());

    // --- whole layer, all methods ---------------------------------------
    println!("{:<14} {:>12} {:>14} {:>10}", "method", "weight MSE", "output err", "time");
    for method in [Method::Rtn, Method::Bcq, Method::Gptq, Method::Gptqt] {
        let cfg = QuantConfig::with_bits(3);
        let q = quantize_layer(&w, &hessian, method, &cfg)?;
        println!(
            "{:<14} {:>12.3e} {:>14.3e} {:>9.3}s",
            method.name(),
            q.stats.weight_mse,
            q.stats.output_err,
            q.stats.seconds
        );
    }

    println!("\nNote the paper's core observation: BCQ minimizes weight MSE \
              but loses on *output* error — GPTQT optimizes the thing that matters.");

    // --- the packed form the LUT-GEMM hot path consumes ------------------
    let q = quantize_layer(&w, &hessian, Method::Gptqt, &QuantConfig::with_bits(3))?;
    let packed = q.packed.expect("gptqt packs");
    println!(
        "\npacked layer: {} planes, {:.2} bits/weight ({}B vs {}B dense)",
        packed.planes,
        packed.bits_per_weight(),
        packed.packed_bytes(),
        w.len() * 4
    );
    let x: Vec<f32> = (0..128).map(|_| rng.normal_f32()).collect();
    let mut y_lut = vec![0.0; 32];
    gptqt::kernels::gemv_lut::gemv_lut(&packed, &x, &mut y_lut);
    let mut y_dense = vec![0.0; 32];
    gptqt::kernels::gemv_f32(&q.dequant, &x, &mut y_dense);
    let max_diff = y_lut
        .iter()
        .zip(&y_dense)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("LUT-GEMM vs dense on dequantized weights: max diff {max_diff:.2e} (pure fp roundoff)");
    Ok(())
}
