//! End-to-end serving driver — the system-validation example (DESIGN.md):
//! loads the in-repo-trained model, quantizes it with GPTQT, stands up
//! the streaming session server (`Server` front-end over the
//! coordinator's queue → batcher → paged KV → `Backend` stack), serves
//! a batch of real prompts through per-request event streams, and
//! reports latency/throughput — against both the rust CPU hot path
//! (LUT-GEMM) and, when artifacts are present, the AOT-compiled XLA
//! executables over PJRT.
//!
//! ```sh
//! cargo run --release --example serve -- [model] [--requests 16] [--fast] [--adaptive] [--pjrt]
//! ```

use gptqt::coordinator::{
    CpuBackend, EngineConfig, Event, FinishReason, PjrtBackend, Request, SamplingParams,
    SchedulePolicyKind, Server,
};
use gptqt::data::vocab::Vocab;
use gptqt::data::{CorpusGenerator, Dataset};
use gptqt::eval::ppl::{calib_for, EvalConfig};
use gptqt::model::quantize::quantize_model;
use gptqt::model::{fmt_params, load_or_init, BackendModel};
use gptqt::quant::{Method, QuantConfig};
use gptqt::util::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = args.iter().any(|a| a == "--fast");
    let use_pjrt = args.iter().any(|a| a == "--pjrt");
    let policy = if args.iter().any(|a| a == "--adaptive") {
        SchedulePolicyKind::Adaptive
    } else {
        SchedulePolicyKind::Fixed
    };
    let name = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .map(|s| s.as_str())
        .unwrap_or("opt-mini");
    let n_requests: usize = args
        .iter()
        .position(|a| a == "--requests")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast { 6 } else { 16 });

    let (model, trained) = load_or_init(name, "artifacts", 0)?;
    println!(
        "== GPTQT serving demo: {name} ({} params, trained={trained}) ==",
        fmt_params(model.cfg.param_count())
    );

    // ---- quantize with the paper's method -----------------------------
    let ecfg = if fast { EvalConfig::fast() } else { EvalConfig::default() };
    let calib = calib_for(&ecfg, Dataset::WikiSyn);
    let qcfg = QuantConfig::with_bits(3);
    println!("quantizing with GPTQT 3-bit (step1 {} bits) …", qcfg.step1_bits);
    let qm = quantize_model(&model, &calib, Method::Gptqt, &qcfg, false)?;

    let cfg = EngineConfig { max_batch: 4, policy, ..Default::default() };
    let model_cfg = model.cfg.clone();

    // ---- choose the execution backend, spawn the session server ------
    let server = if use_pjrt {
        if !gptqt::runtime::artifacts_present("artifacts", name) {
            anyhow::bail!("--pjrt needs HLO artifacts: run `make artifacts` (AOT_MODELS includes {name}?)");
        }
        let rt = gptqt::runtime::Runtime::cpu()?;
        println!("PJRT platform: {}", rt.platform());
        // the XLA path consumes the dequantized weights — numerically
        // identical to the fused binary coding (fusion property)
        Server::spawn(PjrtBackend(rt.load_model("artifacts", &qm.model)?), cfg)
    } else {
        // the rust hot path consumes the *packed* binary-coded weights
        // through the LUT-GEMM kernel
        let bm = BackendModel::quantized(&model, qm.layers);
        println!(
            "cpu backend [{}]: {:.2} MB streamed per token (vs {:.2} MB dense)",
            bm.backend_label(),
            bm.streamed_bytes_per_token() as f64 / 1e6,
            BackendModel::dense(&model).streamed_bytes_per_token() as f64 / 1e6,
        );
        Server::spawn(CpuBackend(bm), cfg)
    };

    // ---- build requests from corpus prompts ----------------------------
    let (gen, vocab) = CorpusGenerator::with_vocab(Dataset::WikiSyn, model_cfg.vocab, 0);
    let stream = gen.generate(4096, 17);
    let mut rng = Rng::new(7);
    let (prompt_len, gen_len) = if fast { (8, 12) } else { (12, 24) };
    let mut handles = Vec::new();
    for id in 0..n_requests as u64 {
        let start = rng.range(0, stream.len() - prompt_len);
        let prompt = stream[start..start + prompt_len].to_vec();
        handles.push(server.submit(Request::new(id, prompt, gen_len).with_sampling(
            SamplingParams::TopK { k: 16, temperature: 0.9, seed: id },
        )));
    }
    // one extra request, cancelled immediately: the stream still
    // terminates (reason Cancelled) and its KV blocks return to the pool
    let doomed = server.submit(Request::new(
        n_requests as u64,
        stream[..prompt_len].to_vec(),
        gen_len,
    ));
    doomed.cancel();

    // ---- stream request 0 live, then drain the rest --------------------
    let mut live = handles.into_iter();
    let first = live.next().expect("at least one request");
    println!("\n--- streaming req 0 ---");
    let mut responses = Vec::new();
    for ev in first.events() {
        match ev {
            Event::Started { queue_secs, .. } => {
                println!("[started after {:.2} ms queued]", queue_secs * 1e3);
            }
            Event::Token { token, .. } => print_token(&vocab, token),
            Event::Finished(r) => {
                println!("\n[finished: {:?}, ttft {:.1} ms]", r.finish, r.ttft_secs * 1e3);
                responses.push(r);
            }
            Event::Rejected { error, .. } => anyhow::bail!("req 0 rejected: {error:?}"),
        }
    }
    for h in live {
        let id = h.id();
        responses.push(h.wait().map_err(|e| anyhow::anyhow!("request {id}: {e:?}"))?);
    }
    let cancelled = doomed.wait().map_err(|e| anyhow::anyhow!("cancelled stream: {e:?}"))?;
    anyhow::ensure!(cancelled.finish == FinishReason::Cancelled, "cancel must be terminal");

    // ---- shut down, report the engine-thread metrics --------------------
    let metrics = server.shutdown();
    println!("\n--- engine metrics ---");
    println!("{}", metrics.report());
    println!("\n--- sample generations ---");
    for r in responses.iter().take(3) {
        println!(
            "req {:>2} [{:?}, {:.0} tok/s] {}",
            r.id,
            r.finish,
            r.tokens_per_sec(),
            vocab.detokenize(&r.tokens)
        );
    }
    anyhow::ensure!(responses.len() == n_requests);
    anyhow::ensure!(metrics.cancelled_total == 1);
    println!("\nserved {} requests OK (+1 cancelled)", responses.len());
    Ok(())
}

fn print_token(vocab: &Vocab, token: u32) {
    use std::io::Write;
    print!("{} ", vocab.detokenize(&[token]));
    let _ = std::io::stdout().flush();
}
