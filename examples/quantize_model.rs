//! Full model quantization walkthrough: load a trained model, calibrate,
//! quantize with every method, and compare perplexities — a single-model
//! slice of Tables I/V.
//!
//! ```sh
//! cargo run --release --example quantize_model -- [model] [--bits 3] [--fast]
//! ```

use gptqt::data::Dataset;
use gptqt::eval::ppl::{calib_for, eval_for, eval_ppl, EvalConfig};
use gptqt::model::quantize::quantize_model;
use gptqt::model::{fmt_params, load_or_init};
use gptqt::quant::{Method, QuantConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = args.iter().any(|a| a == "--fast");
    let name = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .map(|s| s.as_str())
        .unwrap_or("opt-mini");
    let bits: u32 = args
        .iter()
        .position(|a| a == "--bits")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);

    let ecfg = if fast { EvalConfig::fast() } else { EvalConfig::default() };
    let (model, trained) = load_or_init(name, "artifacts", 0)?;
    println!(
        "model {name}: {} params, trained={trained}",
        fmt_params(model.cfg.param_count())
    );
    if !trained {
        eprintln!("(run `make artifacts` for trained weights — random init demo)");
    }

    let calib = calib_for(&ecfg, Dataset::WikiSyn);
    let windows = eval_for(&ecfg, Dataset::WikiSyn);
    let full_ppl = eval_ppl(&model, &windows);
    println!("\nfull fp32 perplexity: {:.2}\n", full_ppl);

    println!(
        "{:<14} {:>9} {:>12} {:>12} {:>10}",
        "method", "ppl", "Δppl", "mean MSE", "quant time"
    );
    for method in [
        Method::Rtn,
        Method::Bcq,
        Method::Gptq,
        Method::GptqMinMse,
        Method::GptqBcq,
        Method::Gptqt,
    ] {
        let qcfg = QuantConfig::with_bits(bits);
        let qm = quantize_model(&model, &calib, method, &qcfg, false)?;
        let ppl = eval_ppl(&qm.model, &windows);
        let mse: f64 = qm.stats.iter().map(|(_, s)| s.weight_mse).sum::<f64>()
            / qm.stats.len() as f64;
        println!(
            "{:<14} {:>9.2} {:>12.2} {:>12.3e} {:>9.2}s",
            method.name(),
            ppl,
            ppl - full_ppl,
            mse,
            qm.seconds
        );
    }
    println!(
        "\n(paper shape: GPTQT ≤ GPTQ ≪ BCQ/RTN at {bits}-bit; min-MSE variants\n\
         *overfit* — low weight error, worse perplexity — Table V)"
    );
    Ok(())
}
