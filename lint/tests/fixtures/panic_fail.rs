pub fn next_block(free: &mut Vec<u32>) -> u32 {
    free.pop().unwrap()
}
