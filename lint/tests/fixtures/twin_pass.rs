pub fn frobnicate(xs: &mut [f32]) {
    let _ = super::simd::tier();
    frobnicate_scalar(xs);
}

pub fn frobnicate_scalar(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v += 1.0;
    }
}
