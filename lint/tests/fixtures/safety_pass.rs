pub fn read_first(xs: &[f32]) -> f32 {
    // SAFETY: caller guarantees `xs` is non-empty.
    unsafe { *xs.as_ptr() }
}
