pub fn widen_into(xs: &[u8], out: &mut [f32]) {
    for (o, &b) in out.iter_mut().zip(xs) {
        *o = b as f32;
    }
}
