pub fn frobnicate(xs: &mut [f32]) {
    if super::simd::tier() as usize > 0 {
        for v in xs.iter_mut() {
            *v += 1.0;
        }
    }
}
