pub fn widen(xs: &[u8]) -> Vec<f32> {
    xs.iter().map(|&b| b as f32).collect()
}
