pub fn dot_contracted(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        s = x.mul_add(*y, s);
    }
    s
}
