pub fn dot_pinned(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        s += x * y;
    }
    // lint:allow(exact-tier-purity) fixture: documented escape hatch.
    s.mul_add(1.0, 0.0)
}
