pub struct Metrics {
    pub ticks: u64,
    pub dropped: u64,
}

impl Metrics {
    pub fn report(&self) -> String {
        format!("ticks={}", self.ticks)
    }
}
