pub fn next_block(free: &mut Vec<u32>) -> u32 {
    // lint:allow(no-panic-serve) accounting invariant: the pending
    // budget guarantees a free block; an empty list is pool corruption
    free.pop().unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn pops_the_newest_block() {
        // test code may panic freely — the rule only guards shipping code
        let mut free = vec![3, 7];
        assert_eq!(super::next_block(&mut free), 7);
        let n: u32 = "7".parse().unwrap();
        assert_eq!(n, 7);
    }
}
