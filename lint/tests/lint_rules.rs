//! Fixture-driven rule tests.
//!
//! Each rule has a failing snippet that must produce exactly its
//! diagnostic — stable rule ID *and* line number — and a passing twin
//! that must come back clean. The snippets live under `fixtures/` (a
//! subdirectory, so cargo never compiles them as test code) and are fed
//! to [`lint_files`] under synthetic repo-relative paths that put them
//! in the right rule scope (kernel module, metrics file, …).
//!
//! The last test runs the real tree: the linter must report zero
//! violations on the repository it ships in.

use gptqt_lint::{
    lint_files, lint_tree, Diagnostic, FileInput, RULE_ALLOC, RULE_METRICS, RULE_PANIC,
    RULE_PURITY, RULE_SAFETY, RULE_TWIN,
};

/// Lint one in-memory fixture under a synthetic path.
fn lint_one(path: &str, source: &str, tests_text: &str) -> Vec<Diagnostic> {
    let files = [FileInput {
        path: path.to_string(),
        source: source.to_string(),
    }];
    lint_files(&files, tests_text)
}

/// Assert the fixture yields exactly `expect` as `(line, rule)` pairs.
fn expect_diags(diags: &[Diagnostic], expect: &[(usize, &str)]) {
    let got: Vec<(usize, &str)> = diags.iter().map(|d| (d.line, d.rule)).collect();
    assert_eq!(got, expect, "diagnostics: {diags:?}");
}

#[test]
fn safety_comment_rule_flags_unannotated_unsafe() {
    let diags = lint_one(
        "rust/src/util/fixture.rs",
        include_str!("fixtures/safety_fail.rs"),
        "",
    );
    expect_diags(&diags, &[(2, RULE_SAFETY)]);
}

#[test]
fn safety_comment_rule_accepts_safety_comment() {
    let diags = lint_one(
        "rust/src/util/fixture.rs",
        include_str!("fixtures/safety_pass.rs"),
        "",
    );
    expect_diags(&diags, &[]);
}

#[test]
fn exact_tier_purity_rule_flags_mul_add_in_kernels() {
    let diags = lint_one(
        "rust/src/kernels/fixture.rs",
        include_str!("fixtures/purity_fail.rs"),
        "",
    );
    expect_diags(&diags, &[(4, RULE_PURITY)]);
}

#[test]
fn exact_tier_purity_rule_honors_lint_allow() {
    let diags = lint_one(
        "rust/src/kernels/fixture.rs",
        include_str!("fixtures/purity_pass.rs"),
        "",
    );
    expect_diags(&diags, &[]);
}

#[test]
fn exact_tier_purity_rule_exempts_fast_math() {
    // The same contracted dot is legal in the Fast-tier home module.
    let diags = lint_one(
        "rust/src/kernels/fast_math.rs",
        include_str!("fixtures/purity_fail.rs"),
        "",
    );
    expect_diags(&diags, &[]);
}

#[test]
fn hot_path_no_alloc_rule_flags_collect_in_kernels() {
    let diags = lint_one(
        "rust/src/kernels/fixture.rs",
        include_str!("fixtures/alloc_fail.rs"),
        "",
    );
    expect_diags(&diags, &[(2, RULE_ALLOC)]);
}

#[test]
fn hot_path_no_alloc_rule_accepts_in_place_code() {
    let diags = lint_one(
        "rust/src/kernels/fixture.rs",
        include_str!("fixtures/alloc_pass.rs"),
        "",
    );
    expect_diags(&diags, &[]);
}

#[test]
fn hot_path_no_alloc_rule_ignores_cold_modules() {
    // The identical allocating snippet is fine outside the hot set.
    let diags = lint_one(
        "rust/src/util/fixture.rs",
        include_str!("fixtures/alloc_fail.rs"),
        "",
    );
    expect_diags(&diags, &[]);
}

#[test]
fn scalar_twin_rule_flags_dispatched_kernel_without_twin_or_test() {
    let diags = lint_one(
        "rust/src/kernels/fixture.rs",
        include_str!("fixtures/twin_fail.rs"),
        "",
    );
    // Both halves of the contract fail: no `_scalar` twin, no coverage.
    expect_diags(&diags, &[(1, RULE_TWIN), (1, RULE_TWIN)]);
    assert!(diags[0].msg.contains("frobnicate_scalar"), "{}", diags[0]);
    assert!(diags[1].msg.contains("not exercised"), "{}", diags[1]);
}

#[test]
fn scalar_twin_rule_accepts_twinned_and_tested_kernel() {
    let diags = lint_one(
        "rust/src/kernels/fixture.rs",
        include_str!("fixtures/twin_pass.rs"),
        "frobnicate(&mut xs); frobnicate_scalar(&mut ys);",
    );
    expect_diags(&diags, &[]);
}

#[test]
fn scalar_twin_rule_needs_word_boundary_coverage() {
    // `refrobnicate` must not count as coverage of `frobnicate`.
    let diags = lint_one(
        "rust/src/kernels/fixture.rs",
        include_str!("fixtures/twin_pass.rs"),
        "refrobnicate(&mut xs);",
    );
    expect_diags(&diags, &[(1, RULE_TWIN)]);
}

#[test]
fn metrics_report_rule_flags_unreported_counter() {
    let diags = lint_one(
        "rust/src/coordinator/metrics.rs",
        include_str!("fixtures/metrics_fail.rs"),
        "",
    );
    expect_diags(&diags, &[(3, RULE_METRICS)]);
    assert!(diags[0].msg.contains("dropped"), "{}", diags[0]);
}

#[test]
fn metrics_report_rule_accepts_full_report() {
    let diags = lint_one(
        "rust/src/coordinator/metrics.rs",
        include_str!("fixtures/metrics_pass.rs"),
        "",
    );
    expect_diags(&diags, &[]);
}

#[test]
fn no_panic_serve_rule_flags_unwrap_on_serving_path() {
    let diags = lint_one(
        "rust/src/coordinator/server.rs",
        include_str!("fixtures/panic_fail.rs"),
        "",
    );
    expect_diags(&diags, &[(2, RULE_PANIC)]);
    assert!(diags[0].msg.contains("engine thread"), "{}", diags[0]);
}

#[test]
fn no_panic_serve_rule_honors_allow_and_test_mod() {
    // The annotated invariant and the test-module unwrap are both legal.
    let diags = lint_one(
        "rust/src/coordinator/server.rs",
        include_str!("fixtures/panic_pass.rs"),
        "",
    );
    expect_diags(&diags, &[]);
}

#[test]
fn no_panic_serve_rule_ignores_non_serving_modules() {
    // The identical unwrap is fine off the serving path.
    let diags = lint_one(
        "rust/src/util/fixture.rs",
        include_str!("fixtures/panic_fail.rs"),
        "",
    );
    expect_diags(&diags, &[]);
}

#[test]
fn repository_tree_is_lint_clean() {
    // The linter gates CI on the tree it lives in; keep that invariant
    // visible from `cargo test` too.
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("lint/ lives under the repo root")
        .to_path_buf();
    let diags = lint_tree(&root).expect("walk rust/src + rust/tests");
    assert!(
        diags.is_empty(),
        "repo has {} lint violations:\n{}",
        diags.len(),
        diags
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
