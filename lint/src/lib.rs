//! `gptqt-lint` — repo-contract static analysis for the gptqt tree.
//!
//! rustc and clippy cannot see the invariants this codebase's value rests
//! on: the Exact tier's bitwise contract (pinned 8-lane tree reduction,
//! mul-then-add, no FMA outside `fast_math.rs`), the zero-alloc serving hot
//! path, the scalar-twin parity discipline, and the rule that every counter
//! in `Metrics` actually surfaces in its `report()`. This crate enforces
//! them at diff time with a dependency-free line/character scanner — no
//! `syn`, no proc macros, nothing to download.
//!
//! Rules (stable IDs, each with an inline escape hatch
//! `// lint:allow(<rule-id>) <reason>` on the flagged line or in the
//! comment/attribute block immediately above it):
//!
//! | rule id             | contract                                          |
//! |---------------------|---------------------------------------------------|
//! | `safety-comment`    | every `unsafe` is preceded by `// SAFETY:`        |
//! | `exact-tier-purity` | no `mul_add`/`.sum()`/`.fold(`/`_mm256_fmadd` in  |
//! |                     | `kernels/*.rs` outside `fast_math.rs`             |
//! | `hot-path-no-alloc` | no allocation tokens in kernel modules or the     |
//! |                     | `forward_core`/`forward_tick`/`spec_tick`/`step`  |
//! |                     | serving hot path                                  |
//! | `scalar-twin`       | every dispatched `pub fn f(` in `kernels/` has an |
//! |                     | `f_scalar` twin and is named under `rust/tests/`  |
//! | `metrics-report`    | every `pub` counter field of `Metrics` appears in |
//! |                     | `report()`                                        |
//! | `no-panic-serve`    | no `unwrap()`/`expect(`/`panic!` on the serving   |
//! |                     | path (`coordinator/{engine,server,kv_pool,queue,  |
//! |                     | speculative}.rs`) outside `#[cfg(test)]` — a      |
//! |                     | panic there kills the engine thread, not one      |
//! |                     | request                                           |
//!
//! The scanner works on a "code view" of each file: comments and
//! string/char-literal contents are blanked to spaces (newlines kept), so
//! token searches never fire inside prose, and `#[cfg(test)]` modules are
//! masked out for the rules that only constrain shipping code.

use std::collections::BTreeSet;
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub const RULE_SAFETY: &str = "safety-comment";
pub const RULE_PURITY: &str = "exact-tier-purity";
pub const RULE_ALLOC: &str = "hot-path-no-alloc";
pub const RULE_TWIN: &str = "scalar-twin";
pub const RULE_METRICS: &str = "metrics-report";
pub const RULE_PANIC: &str = "no-panic-serve";

pub const ALL_RULES: [&str; 6] = [
    RULE_SAFETY,
    RULE_PURITY,
    RULE_ALLOC,
    RULE_TWIN,
    RULE_METRICS,
    RULE_PANIC,
];

/// Tokens that reassociate or fuse floating-point arithmetic and therefore
/// break the Exact tier's bitwise scalar↔AVX2↔gemm parity.
const PURITY_TOKENS: [&str; 4] = ["mul_add", ".sum()", ".fold(", "_mm256_fmadd"];

/// Tokens that allocate. The serving hot path must stay flat after warmup
/// (pinned dynamically by `tests/alloc_steady.rs`); this catches new
/// allocation sites at diff time instead.
const ALLOC_TOKENS: [&str; 7] = [
    "Vec::new",
    "vec![",
    ".to_vec",
    "format!",
    "Box::new",
    ".collect",
    "with_capacity",
];

/// Panic-capable tokens banned on the serving path: any of these outside
/// `#[cfg(test)]` must carry a `lint:allow(no-panic-serve) <reason>`
/// naming the load-bearing invariant (recoverable conditions belong in
/// `Result`s / `FinishReason::Failed`, not panics).
const PANIC_TOKENS: [&str; 3] = ["unwrap()", "expect(", "panic!"];

/// The serving-path files where a panic terminates the engine worker
/// thread (and with it every in-flight request) instead of one request.
const SERVE_FILES: [&str; 5] = [
    "coordinator/engine.rs",
    "coordinator/server.rs",
    "coordinator/kv_pool.rs",
    "coordinator/queue.rs",
    "coordinator/speculative.rs",
];

/// Hot functions outside `kernels/` whose bodies are allocation-free zones.
/// (`kernels/*.rs` files are hot in their entirety.)
const HOT_FNS: [(&str, &[&str]); 2] = [
    ("rust/src/model/decode.rs", &["forward_core"]),
    (
        "rust/src/coordinator/engine.rs",
        &["forward_tick", "spec_tick", "step"],
    ),
];

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Repo-relative path, forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// One source file handed to the linter (path is repo-relative; rule
/// applicability is decided from it).
pub struct FileInput {
    pub path: String,
    pub source: String,
}

// ---------------------------------------------------------------------------
// Source scanning
// ---------------------------------------------------------------------------

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Blank comments and string/char-literal contents to spaces, preserving
/// the line structure exactly, so token scans only ever see code.
fn code_view(src: &str) -> String {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = chars.clone();
    let mut i = 0usize;

    fn blank(out: &mut [char], from: usize, to: usize) {
        for slot in out[from..to].iter_mut() {
            if *slot != '\n' {
                *slot = ' ';
            }
        }
    }

    while i < n {
        let c = chars[i];
        let prev_ident = i > 0 && is_ident_char(chars[i - 1]);
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            blank(&mut out, start, i);
        } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let start = i;
            i += 2;
            let mut depth = 1;
            while i < n && depth > 0 {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            blank(&mut out, start, i);
        } else if c == '"' {
            let start = i;
            i += 1;
            while i < n {
                if chars[i] == '\\' {
                    i += 2;
                } else if chars[i] == '"' {
                    i += 1;
                    break;
                } else {
                    i += 1;
                }
            }
            blank(&mut out, start, i.min(n));
        } else if !prev_ident
            && (c == 'r' || c == 'b')
            && raw_string_len(&chars, i).is_some()
        {
            let len = raw_string_len(&chars, i).unwrap();
            blank(&mut out, i, (i + len).min(n));
            i += len;
        } else if !prev_ident && c == 'b' && i + 1 < n && chars[i + 1] == '"' {
            // Byte string: reuse the plain-string scan from the quote.
            let start = i;
            i += 2;
            while i < n {
                if chars[i] == '\\' {
                    i += 2;
                } else if chars[i] == '"' {
                    i += 1;
                    break;
                } else {
                    i += 1;
                }
            }
            blank(&mut out, start, i.min(n));
        } else if !prev_ident && c == 'b' && i + 1 < n && chars[i + 1] == '\'' {
            let start = i;
            i += 1;
            i += char_literal_len(&chars, i);
            blank(&mut out, start, i.min(n));
        } else if c == '\'' {
            // Char literal vs lifetime: a literal is `'\...'` or `'x'`.
            let escaped = i + 1 < n && chars[i + 1] == '\\';
            let short = i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'';
            if escaped || short {
                let start = i;
                i += char_literal_len(&chars, i);
                blank(&mut out, start, i.min(n));
            } else {
                i += 1; // lifetime — leave as code
            }
        } else {
            i += 1;
        }
    }
    out.into_iter().collect()
}

/// Length (in chars, from the opening `'`) of a char/byte-char literal.
fn char_literal_len(chars: &[char], start: usize) -> usize {
    let n = chars.len();
    let mut i = start + 1;
    if i < n && chars[i] == '\\' {
        i += 2; // backslash + escaped char (first char of `x41`/`u{..}`)
        while i < n && chars[i] != '\'' {
            i += 1;
        }
        i += 1; // closing quote
    } else {
        i += 2; // payload char + closing quote
    }
    i.saturating_sub(start)
}

/// If `chars[start..]` begins a raw (byte) string `r"…"`, `r#"…"#`,
/// `br"…"`, returns its total length in chars.
fn raw_string_len(chars: &[char], start: usize) -> Option<usize> {
    let n = chars.len();
    let mut i = start;
    if i < n && chars[i] == 'b' {
        i += 1;
    }
    if i >= n || chars[i] != 'r' {
        return None;
    }
    i += 1;
    let mut hashes = 0usize;
    while i < n && chars[i] == '#' {
        hashes += 1;
        i += 1;
    }
    if i >= n || chars[i] != '"' {
        return None;
    }
    i += 1;
    while i < n {
        if chars[i] == '"' {
            let mut j = i + 1;
            let mut seen = 0usize;
            while j < n && seen < hashes && chars[j] == '#' {
                seen += 1;
                j += 1;
            }
            if seen == hashes {
                return Some(j - start);
            }
        }
        i += 1;
    }
    Some(n - start)
}

/// Case-sensitive word search: the match must not touch identifier
/// characters on either side (`dot` matches `simd::dot(`, not `qk_dots`).
fn contains_word(hay: &str, needle: &str) -> bool {
    if needle.is_empty() {
        return false;
    }
    let hb = hay.as_bytes();
    let mut start = 0usize;
    while start <= hay.len() {
        let Some(pos) = hay[start..].find(needle) else {
            return false;
        };
        let at = start + pos;
        let end = at + needle.len();
        let before_ok = at == 0 || !is_ident_byte(hb[at - 1]);
        let after_ok = end >= hb.len() || !is_ident_byte(hb[end]);
        if before_ok && after_ok {
            return true;
        }
        start = at + hay[at..].chars().next().map(char::len_utf8).unwrap_or(1);
    }
    false
}

/// Per-file scan state shared by the rules.
struct Analysis<'a> {
    raw_lines: Vec<&'a str>,
    code_lines: Vec<String>,
    /// Lines inside a `#[cfg(test)]` module (attribute through closing brace).
    in_test: Vec<bool>,
}

fn analyze(src: &str) -> Analysis<'_> {
    let raw_lines: Vec<&str> = src.split('\n').collect();
    let view = code_view(src);
    let code_lines: Vec<String> = view.split('\n').map(|s| s.to_string()).collect();
    debug_assert_eq!(raw_lines.len(), code_lines.len());
    let in_test = test_mask(&code_lines);
    Analysis {
        raw_lines,
        code_lines,
        in_test,
    }
}

/// Mark every line belonging to a `#[cfg(test)]` item (by brace tracking on
/// the code view). Items without a body (`;` before any `{`) end there.
fn test_mask(code_lines: &[String]) -> Vec<bool> {
    let n = code_lines.len();
    let mut mask = vec![false; n];
    let mut i = 0usize;
    while i < n {
        if !code_lines[i].trim_start().starts_with("#[cfg(test)]") {
            i += 1;
            continue;
        }
        mask[i] = true;
        let mut depth: i64 = 0;
        let mut started = false;
        let mut j = i + 1;
        while j < n {
            mask[j] = true;
            let mut done = false;
            for ch in code_lines[j].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        started = true;
                    }
                    '}' => {
                        depth -= 1;
                        if started && depth == 0 {
                            done = true;
                        }
                    }
                    ';' if !started => done = true,
                    _ => {}
                }
            }
            if done {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

/// True when the flagged line, or the contiguous comment/attribute block
/// immediately above it, contains one of `needles`. This is how both
/// `// SAFETY:` discipline and `// lint:allow(<rule>)` escapes resolve.
fn annotated(raw_lines: &[&str], idx: usize, needles: &[&str]) -> bool {
    let hit = |line: &str| needles.iter().any(|n| line.contains(n));
    if hit(raw_lines[idx]) {
        return true;
    }
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let t = raw_lines[i].trim();
        let is_annotation = t.starts_with("//")
            || t.starts_with("#[")
            || t.starts_with("#!")
            || t.starts_with("/*")
            || t.starts_with('*')
            || t.ends_with("*/");
        if !is_annotation {
            return false;
        }
        if hit(raw_lines[i]) {
            return true;
        }
    }
    false
}

fn allow_needle(rule: &str) -> String {
    format!("lint:allow({rule})")
}

/// Find `fn <name>(` declarations and return their body line ranges
/// (inclusive, 0-based; a bodyless trait signature yields `None`).
fn fn_decl_positions(code_lines: &[String], name: &str) -> Vec<(usize, usize)> {
    let needle = format!("fn {name}(");
    let mut out = Vec::new();
    for (idx, line) in code_lines.iter().enumerate() {
        let mut search = 0usize;
        while let Some(pos) = line[search..].find(&needle) {
            let at = search + pos;
            if at == 0 || !is_ident_byte(line.as_bytes()[at - 1]) {
                out.push((idx, at));
                break;
            }
            search = at + 1;
        }
    }
    out
}

/// From a declaration at (line, col), find the body's last line by brace
/// tracking; `None` when a `;` terminates the item before any `{` opens.
fn body_end(code_lines: &[String], decl: (usize, usize)) -> Option<usize> {
    let (start, col) = decl;
    let n = code_lines.len();
    let mut depth: i64 = 0;
    let mut started = false;
    for j in start..n {
        let s: &str = if j == start {
            &code_lines[j][col..]
        } else {
            &code_lines[j]
        };
        for ch in s.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    started = true;
                }
                '}' => {
                    depth -= 1;
                    if started && depth == 0 {
                        return Some(j);
                    }
                }
                ';' if !started => return None,
                _ => {}
            }
        }
    }
    Some(n.saturating_sub(1))
}

/// Identifier immediately following `prefix` on `line`, if any.
fn ident_after<'a>(line: &'a str, prefix: &str, from: usize) -> Option<(&'a str, usize)> {
    let at = from + line[from..].find(prefix)?;
    if at > 0 && is_ident_byte(line.as_bytes()[at - 1]) {
        return None;
    }
    let rest = &line[at + prefix.len()..];
    let end = rest
        .char_indices()
        .find(|(_, c)| !is_ident_char(*c))
        .map(|(i, _)| i)
        .unwrap_or(rest.len());
    if end == 0 {
        None
    } else {
        Some((&rest[..end], at))
    }
}

// ---------------------------------------------------------------------------
// Path classification
// ---------------------------------------------------------------------------

fn is_kernel_path(path: &str) -> bool {
    path.contains("rust/src/kernels/")
}

fn is_fast_math(path: &str) -> bool {
    path.ends_with("kernels/fast_math.rs")
}

fn is_metrics_path(path: &str) -> bool {
    path.ends_with("coordinator/metrics.rs")
}

fn is_serve_path(path: &str) -> bool {
    SERVE_FILES.iter().any(|s| path.ends_with(s))
}

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

fn rule_safety(file: &FileInput, a: &Analysis<'_>, diags: &mut Vec<Diagnostic>) {
    let allow = allow_needle(RULE_SAFETY);
    for (idx, code) in a.code_lines.iter().enumerate() {
        if !contains_word(code, "unsafe") {
            continue;
        }
        if annotated(&a.raw_lines, idx, &[&allow]) {
            continue;
        }
        if annotated(&a.raw_lines, idx, &["SAFETY:", "# Safety"]) {
            continue;
        }
        diags.push(Diagnostic {
            file: file.path.clone(),
            line: idx + 1,
            rule: RULE_SAFETY,
            msg: "`unsafe` without an immediately preceding `// SAFETY:` comment".to_string(),
        });
    }
}

fn rule_purity(file: &FileInput, a: &Analysis<'_>, diags: &mut Vec<Diagnostic>) {
    let allow = allow_needle(RULE_PURITY);
    for (idx, code) in a.code_lines.iter().enumerate() {
        if a.in_test[idx] {
            continue;
        }
        for tok in PURITY_TOKENS {
            if !code.contains(tok) {
                continue;
            }
            if annotated(&a.raw_lines, idx, &[&allow]) {
                continue;
            }
            diags.push(Diagnostic {
                file: file.path.clone(),
                line: idx + 1,
                rule: RULE_PURITY,
                msg: format!(
                    "`{tok}` in an Exact-tier kernel module (reassociation/FMA \
                     breaks the bitwise contract; Fast-tier code lives in fast_math.rs)"
                ),
            });
        }
    }
}

fn rule_alloc_lines<I: Iterator<Item = usize>>(
    file: &FileInput,
    a: &Analysis<'_>,
    lines: I,
    diags: &mut Vec<Diagnostic>,
) {
    let allow = allow_needle(RULE_ALLOC);
    for idx in lines {
        if a.in_test[idx] {
            continue;
        }
        let code = &a.code_lines[idx];
        for tok in ALLOC_TOKENS {
            if !code.contains(tok) {
                continue;
            }
            if annotated(&a.raw_lines, idx, &[&allow]) {
                continue;
            }
            diags.push(Diagnostic {
                file: file.path.clone(),
                line: idx + 1,
                rule: RULE_ALLOC,
                msg: format!(
                    "`{tok}` in a serving hot path (steady state must stay \
                     allocation-free; see tests/alloc_steady.rs)"
                ),
            });
        }
    }
}

fn rule_alloc(file: &FileInput, a: &Analysis<'_>, diags: &mut Vec<Diagnostic>) {
    if is_kernel_path(&file.path) {
        rule_alloc_lines(file, a, 0..a.code_lines.len(), diags);
        return;
    }
    for (suffix, fns) in HOT_FNS {
        if !file.path.ends_with(suffix) {
            continue;
        }
        for name in fns {
            for decl in fn_decl_positions(&a.code_lines, name) {
                if let Some(end) = body_end(&a.code_lines, decl) {
                    rule_alloc_lines(file, a, decl.0..=end, diags);
                }
            }
        }
    }
}

fn collect_fn_names(a: &Analysis<'_>, out: &mut BTreeSet<String>) {
    for (idx, line) in a.code_lines.iter().enumerate() {
        if a.in_test[idx] {
            continue;
        }
        let mut from = 0usize;
        while let Some((name, at)) = ident_after(line, "fn ", from) {
            out.insert(name.to_string());
            from = at + 3;
        }
    }
}

fn rule_twin(
    file: &FileInput,
    a: &Analysis<'_>,
    kernel_fns: &BTreeSet<String>,
    tests_text: &str,
    diags: &mut Vec<Diagnostic>,
) {
    let allow = allow_needle(RULE_TWIN);
    for (idx, line) in a.code_lines.iter().enumerate() {
        if a.in_test[idx] {
            continue;
        }
        let Some((name, at)) = ident_after(line, "pub fn ", 0) else {
            continue;
        };
        let name = name.to_string();
        if name.ends_with("_scalar") {
            continue;
        }
        // "Dispatched" = the body consults the runtime SIMD/numerics tier.
        let Some(end) = body_end(&a.code_lines, (idx, at)) else {
            continue;
        };
        let mut dispatched = false;
        for (j, body_line) in a.code_lines[idx..=end].iter().enumerate() {
            // On the declaration line, skip past the fn's own name so
            // `pub fn tier()` / `pub fn fast_simd()` don't match themselves.
            let text: &str = if j == 0 {
                &body_line[at + "pub fn ".len() + name.len()..]
            } else {
                body_line
            };
            if text.contains("tier()") || text.contains("fast_simd()") {
                dispatched = true;
                break;
            }
        }
        if !dispatched {
            continue;
        }
        if annotated(&a.raw_lines, idx, &[&allow]) {
            continue;
        }
        let twin = format!("{name}_scalar");
        if !kernel_fns.contains(&twin) {
            diags.push(Diagnostic {
                file: file.path.clone(),
                line: idx + 1,
                rule: RULE_TWIN,
                msg: format!(
                    "dispatched kernel `{name}` has no `{twin}` twin \
                     (the parity contract needs a reference implementation)"
                ),
            });
        }
        if !contains_word(tests_text, &name) {
            diags.push(Diagnostic {
                file: file.path.clone(),
                line: idx + 1,
                rule: RULE_TWIN,
                msg: format!(
                    "dispatched kernel `{name}` is not exercised by any test \
                     under rust/tests/"
                ),
            });
        }
    }
}

fn rule_panic(file: &FileInput, a: &Analysis<'_>, diags: &mut Vec<Diagnostic>) {
    let allow = allow_needle(RULE_PANIC);
    for (idx, code) in a.code_lines.iter().enumerate() {
        if a.in_test[idx] {
            continue;
        }
        for tok in PANIC_TOKENS {
            if !code.contains(tok) {
                continue;
            }
            if annotated(&a.raw_lines, idx, &[&allow]) {
                continue;
            }
            diags.push(Diagnostic {
                file: file.path.clone(),
                line: idx + 1,
                rule: RULE_PANIC,
                msg: format!(
                    "`{tok}` on the serving path — a panic here kills the engine \
                     thread, not one request; return a Result (terminating only \
                     the offending request) or annotate the load-bearing invariant"
                ),
            });
        }
    }
}

fn rule_metrics(file: &FileInput, a: &Analysis<'_>, diags: &mut Vec<Diagnostic>) {
    let allow = allow_needle(RULE_METRICS);
    // Locate `pub struct Metrics` and collect its pub fields.
    let mut fields: Vec<(String, usize)> = Vec::new();
    for (idx, line) in a.code_lines.iter().enumerate() {
        if a.in_test[idx] || !contains_word(line, "struct") || !contains_word(line, "Metrics") {
            continue;
        }
        let Some(col) = line.find("struct") else {
            continue;
        };
        let Some(end) = body_end(&a.code_lines, (idx, col)) else {
            continue;
        };
        for (j, body_line) in a.code_lines[idx..=end].iter().enumerate() {
            let t = body_line.trim_start();
            if !t.starts_with("pub ") || !t.contains(':') {
                continue;
            }
            if let Some((name, _)) = ident_after(t, "pub ", 0) {
                fields.push((name.to_string(), idx + j));
            }
        }
        break;
    }
    // The report body every counter must surface in.
    let mut report_body = String::new();
    for decl in fn_decl_positions(&a.code_lines, "report") {
        if let Some(end) = body_end(&a.code_lines, decl) {
            for line in &a.code_lines[decl.0..=end] {
                report_body.push_str(line);
                report_body.push('\n');
            }
            break;
        }
    }
    if report_body.is_empty() {
        return;
    }
    for (name, idx) in fields {
        if contains_word(&report_body, &name) {
            continue;
        }
        if annotated(&a.raw_lines, idx, &[&allow]) {
            continue;
        }
        diags.push(Diagnostic {
            file: file.path.clone(),
            line: idx + 1,
            rule: RULE_METRICS,
            msg: format!("`Metrics` counter `{name}` never surfaces in `report()`"),
        });
    }
}

// ---------------------------------------------------------------------------
// Drivers
// ---------------------------------------------------------------------------

/// Lint a set of in-memory files. `tests_text` is the concatenated source
/// of everything under `rust/tests/` (rule `scalar-twin` checks coverage
/// against it).
pub fn lint_files(files: &[FileInput], tests_text: &str) -> Vec<Diagnostic> {
    let analyses: Vec<Analysis<'_>> = files.iter().map(|f| analyze(&f.source)).collect();

    let mut kernel_fns: BTreeSet<String> = BTreeSet::new();
    for (file, a) in files.iter().zip(&analyses) {
        if is_kernel_path(&file.path) {
            collect_fn_names(a, &mut kernel_fns);
        }
    }

    let mut diags = Vec::new();
    for (file, a) in files.iter().zip(&analyses) {
        rule_safety(file, a, &mut diags);
        if is_kernel_path(&file.path) && !is_fast_math(&file.path) {
            rule_purity(file, a, &mut diags);
        }
        rule_alloc(file, a, &mut diags);
        if is_kernel_path(&file.path) {
            rule_twin(file, a, &kernel_fns, tests_text, &mut diags);
        }
        if is_metrics_path(&file.path) {
            rule_metrics(file, a, &mut diags);
        }
        if is_serve_path(&file.path) {
            rule_panic(file, a, &mut diags);
        }
    }
    diags.sort_by(|x, y| {
        (&x.file, x.line, x.rule, &x.msg).cmp(&(&y.file, y.line, y.rule, &y.msg))
    });
    diags
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint the repository rooted at `root`: every `.rs` under `rust/src`,
/// with `rust/tests` as the coverage corpus.
pub fn lint_tree(root: &Path) -> io::Result<Vec<Diagnostic>> {
    let mut src_paths = Vec::new();
    walk(&root.join("rust").join("src"), &mut src_paths)?;
    src_paths.sort();
    let mut files = Vec::with_capacity(src_paths.len());
    for p in &src_paths {
        let source = fs::read_to_string(p)?;
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push(FileInput { path: rel, source });
    }

    let mut test_paths = Vec::new();
    walk(&root.join("rust").join("tests"), &mut test_paths)?;
    test_paths.sort();
    let mut tests_text = String::new();
    for p in &test_paths {
        tests_text.push_str(&fs::read_to_string(p)?);
        tests_text.push('\n');
    }

    Ok(lint_files(&files, &tests_text))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn code_view_blanks_comments_and_strings() {
        let src = "let a = \"unsafe\"; // unsafe in comment\nlet b = 'x';\n";
        let view = code_view(src);
        assert!(!view.contains("unsafe"));
        assert!(view.contains("let a ="));
        assert_eq!(src.split('\n').count(), view.split('\n').count());
    }

    #[test]
    fn code_view_keeps_lifetimes_handles_raw_strings() {
        let src = "fn f<'a>(x: &'a str) { let s = r#\"vec![\"#; let c = '\\''; }";
        let view = code_view(src);
        assert!(view.contains("fn f<'a>(x: &'a str)"));
        assert!(!view.contains("vec!["));
    }

    #[test]
    fn word_boundaries() {
        assert!(contains_word("simd::dot(a, b)", "dot"));
        assert!(!contains_word("qk_dots(a, b)", "dot"));
        assert!(!contains_word("dot_scalar(a)", "dot"));
    }

    #[test]
    fn test_mask_covers_cfg_test_mod() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}\n";
        let a = analyze(src);
        assert!(!a.in_test[0]);
        assert!(a.in_test[1] && a.in_test[2] && a.in_test[3] && a.in_test[4]);
        assert!(!a.in_test[5]);
    }

    #[test]
    fn annotated_scans_through_attributes() {
        let lines = vec![
            "// SAFETY: callers checked the tier.",
            "#[target_feature(enable = \"avx2\")]",
            "unsafe fn dot_avx2() {}",
        ];
        assert!(annotated(&lines, 2, &["SAFETY:"]));
        assert!(!annotated(&lines, 2, &["lint:allow(safety-comment)"]));
    }
}
