//! CLI driver: `cargo run -p gptqt-lint [repo-root]`.
//!
//! Prints one `file:line: [rule-id] message` diagnostic per violation and a
//! final `lint-violations: N` line (the CI gate greps for it). Exit code 0
//! when clean, 1 on violations, 2 on usage/I/O failure.
//!
//! A second form lints a single file under a synthetic repo-relative path
//! (which decides rule applicability — kernel module, metrics file, …):
//!
//! ```text
//! cargo run -p gptqt-lint -- --file rust/src/kernels/fixture.rs \
//!     lint/tests/fixtures/purity_fail.rs
//! ```
//!
//! That is how the failure fixtures are exercised from the command line;
//! `lint/tests/lint_rules.rs` pins the same behavior in-process.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use gptqt_lint::{lint_files, lint_tree, Diagnostic, FileInput};

fn report(diags: &[Diagnostic]) -> ExitCode {
    for d in diags {
        println!("{d}");
    }
    println!("lint-violations: {}", diags.len());
    if diags.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();

    if args.first().map(String::as_str) == Some("--file") {
        let [_, synthetic_path, real_path] = &args[..] else {
            eprintln!("usage: gptqt-lint --file <repo-relative-path-as> <file>");
            return ExitCode::from(2);
        };
        let source = match std::fs::read_to_string(real_path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("gptqt-lint: failed to read {real_path}: {e}");
                return ExitCode::from(2);
            }
        };
        let files = [FileInput {
            path: synthetic_path.clone(),
            source,
        }];
        return report(&lint_files(&files, ""));
    }

    let root = match args.first() {
        Some(p) => PathBuf::from(p),
        // CARGO_MANIFEST_DIR is lint/; the repo root is its parent, so the
        // binary works from any working directory under `cargo run`.
        None => Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("lint/ sits under the repo root")
            .to_path_buf(),
    };
    let diags = match lint_tree(&root) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("gptqt-lint: failed to read tree under {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    report(&diags)
}
