"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes; every kernel must match its ``ref.py`` oracle to
f32 tolerance across tilings, ragged sizes and degenerate inputs.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import dequant_gemm, lut_gemm, matmul, ref

RTOL, ATOL = 1e-5, 1e-5


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------- matmul

@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 96),
    k=st.integers(1, 64),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_nt_matches_ref(t, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = rand(rng, t, k), rand(rng, n, k)
    got = matmul.matmul_nt(jnp.asarray(x), jnp.asarray(w))
    want = ref.matmul_nt_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL * np.sqrt(k))


@pytest.mark.parametrize("tm,tn", [(1, 1), (8, 8), (64, 128), (1000, 1000)])
def test_matmul_tilings_agree(tm, tn):
    rng = np.random.default_rng(7)
    x, w = rand(rng, 32, 48), rand(rng, 64, 48)
    got = matmul.matmul_nt(jnp.asarray(x), jnp.asarray(w), tm=tm, tn=tn)
    want = ref.matmul_nt_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=1e-4)


def test_matmul_vmem_estimate_positive():
    assert matmul.vmem_bytes(64, 128, 512) > 0


# ----------------------------------------------------------- dequant gemv

@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 80),
    cols=st.integers(1, 96),
    bits=st.integers(2, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_dequant_gemv_matches_ref(rows, cols, bits, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 2**bits, (rows, cols)).astype(np.int32)
    scale = (rng.random(rows).astype(np.float32) + 0.05)
    qz = rand(rng, rows)
    x = rand(rng, cols)
    got = dequant_gemm.dequant_gemv(
        jnp.asarray(codes), jnp.asarray(scale), jnp.asarray(qz), jnp.asarray(x)
    )
    want = ref.dequant_gemv_ref(
        jnp.asarray(codes), jnp.asarray(scale), jnp.asarray(qz), jnp.asarray(x)
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * np.sqrt(cols))


def test_dequant_zero_x():
    codes = np.ones((4, 8), np.int32)
    z = np.zeros(8, np.float32)
    got = dequant_gemm.dequant_gemv(
        jnp.asarray(codes), jnp.ones(4, dtype=jnp.float32), jnp.zeros(4, dtype=jnp.float32), jnp.asarray(z)
    )
    np.testing.assert_allclose(got, np.zeros(4), atol=1e-7)


# -------------------------------------------------------------- lut gemv

def random_bc_layer(rng, rows, planes, cols):
    alphas = (rng.random((rows, planes)).astype(np.float32) + 0.1)
    bias = rand(rng, rows) * 0.1
    signs = rng.choice([-1.0, 1.0], (rows, planes, cols)).astype(np.float32)
    words = ref.pack_signs_np(signs).astype(np.int32)
    return alphas, bias, signs, words


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 64),
    planes=st.integers(1, 4),
    cols=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_lut_gemv_matches_ref(rows, planes, cols, seed):
    rng = np.random.default_rng(seed)
    alphas, bias, _, words = random_bc_layer(rng, rows, planes, cols)
    x = rand(rng, cols)
    got = lut_gemm.lut_gemv(
        jnp.asarray(alphas), jnp.asarray(bias), jnp.asarray(words), jnp.asarray(x)
    )
    want = ref.lut_gemv_ref(
        jnp.asarray(alphas), jnp.asarray(bias), jnp.asarray(words), jnp.asarray(x)
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * np.sqrt(cols))


def test_unpack_signs_roundtrip():
    rng = np.random.default_rng(3)
    signs = rng.choice([-1.0, 1.0], (5, 3, 70)).astype(np.float32)
    words = ref.pack_signs_np(signs)
    back = np.asarray(ref.unpack_signs_ref(jnp.asarray(words.astype(np.int32)), 70))
    np.testing.assert_array_equal(back, signs)


def test_lut_gemv_equals_dense_dequant():
    # the fused binary coding evaluated via LUT must equal the dense
    # expansion W = Σ α·sign + bias multiplied the ordinary way
    rng = np.random.default_rng(9)
    rows, planes, cols = 16, 3, 40
    alphas, bias, signs, words = random_bc_layer(rng, rows, planes, cols)
    x = rand(rng, cols)
    dense = (alphas[:, :, None] * signs).sum(axis=1) + bias[:, None]
    want = dense @ x
    got = lut_gemm.lut_gemv(
        jnp.asarray(alphas), jnp.asarray(bias), jnp.asarray(words.astype(np.int32)), jnp.asarray(x)
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("tr", [1, 3, 16, 64])
def test_lut_gemv_tilings_agree(tr):
    rng = np.random.default_rng(11)
    alphas, bias, _, words = random_bc_layer(rng, 48, 2, 33)
    x = rand(rng, 33)
    got = lut_gemm.lut_gemv(
        jnp.asarray(alphas), jnp.asarray(bias), jnp.asarray(words.astype(np.int32)), jnp.asarray(x), tr=tr
    )
    want = ref.lut_gemv_ref(
        jnp.asarray(alphas), jnp.asarray(bias), jnp.asarray(words.astype(np.int32)), jnp.asarray(x)
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_lut_vmem_estimate_reflects_tradeoff():
    small = lut_gemm.vmem_bytes(16, 3, 256)
    big = lut_gemm.vmem_bytes(64, 3, 256)
    assert big > small > 0
