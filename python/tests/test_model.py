"""L2 model tests: shapes, causality, decode-vs-prefill parity, GQTW
round-trips, and the weight-order ABI."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import gqtw
from compile.configs import PRESETS, by_name, ModelConfig, OPT, LLAMA, BLOOM
from compile.model import (
    batched_nll,
    decode_step,
    init_weights,
    ordered_weights,
    prefill_logits,
    weights_from_ordered,
)


def tiny(family):
    return ModelConfig(f"tiny-{family}", family, 32, 2, 2, 64, vocab=64, max_seq=32)


@pytest.mark.parametrize("family", [OPT, LLAMA, BLOOM])
def test_prefill_shapes_and_finite(family):
    cfg = tiny(family)
    w = init_weights(cfg, 0)
    tokens = jnp.arange(10, dtype=jnp.int32) % cfg.vocab
    logits = prefill_logits(cfg, w, tokens)
    assert logits.shape == (10, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("family", [OPT, LLAMA, BLOOM])
def test_causality(family):
    cfg = tiny(family)
    w = init_weights(cfg, 1)
    a = jnp.asarray([1, 2, 3, 4, 5, 6], dtype=jnp.int32)
    b = a.at[-1].set(63)
    la = prefill_logits(cfg, w, a)
    lb = prefill_logits(cfg, w, b)
    np.testing.assert_allclose(la[:-1], lb[:-1], atol=1e-5)


@pytest.mark.parametrize("family", [OPT, LLAMA, BLOOM])
def test_decode_matches_prefill(family):
    cfg = tiny(family)
    w = init_weights(cfg, 2)
    tokens = np.array([3, 9, 27, 44, 5, 13], dtype=np.int32)
    full = prefill_logits(cfg, w, jnp.asarray(tokens))
    k = jnp.zeros((cfg.layers, cfg.max_seq, cfg.d_model))
    v = jnp.zeros_like(k)
    last = None
    for pos, tok in enumerate(tokens):
        last, k, v = decode_step(
            cfg, w, k, v, jnp.int32(tok), jnp.int32(pos)
        )
    np.testing.assert_allclose(last, full[-1], rtol=1e-4, atol=1e-4)


def test_pallas_prefill_matches_plain():
    cfg = tiny(OPT)
    w = init_weights(cfg, 3)
    tokens = jnp.arange(8, dtype=jnp.int32)
    plain = prefill_logits(cfg, w, tokens, use_pallas=False)
    pallas = prefill_logits(cfg, w, tokens, use_pallas=True)
    np.testing.assert_allclose(plain, pallas, rtol=1e-5, atol=1e-5)


def test_nll_reasonable_at_init():
    cfg = tiny(OPT)
    w = init_weights(cfg, 4)
    batch = jnp.asarray(np.random.default_rng(0).integers(0, 64, (2, 17)), dtype=jnp.int32)
    nll = float(batched_nll(cfg, w, batch))
    assert 0 < nll < np.log(cfg.vocab) * 2


def test_weight_order_total_and_unique():
    for cfg in PRESETS:
        order = cfg.weight_order()
        assert len(order) == len(set(order)), cfg.name
        w = init_weights(cfg, 0) if cfg.d_model <= 128 else None
        if w is not None:
            assert set(order) == set(w.keys()), cfg.name


def test_ordered_weights_roundtrip():
    cfg = tiny(LLAMA)
    w = init_weights(cfg, 5)
    arrays = ordered_weights(cfg, w)
    back = weights_from_ordered(cfg, arrays)
    for k in w:
        np.testing.assert_array_equal(np.asarray(w[k]), np.asarray(back[k]))


def test_gqtw_roundtrip(tmp_path):
    cfg = tiny(OPT)
    w = init_weights(cfg, 6)
    path = tmp_path / "w.gqtw"
    gqtw.save(path, {k: np.asarray(w[k]) for k in cfg.weight_order()})
    back = gqtw.load(path)
    assert list(back.keys()) == cfg.weight_order()
    for k in back:
        arr = np.asarray(w[k])
        if arr.ndim == 1:
            arr = arr.reshape(1, -1)
        np.testing.assert_array_equal(back[k], arr)


def test_gqtw_rejects_garbage(tmp_path):
    p = tmp_path / "bad.bin"
    p.write_bytes(b"whatever this is")
    with pytest.raises(ValueError):
        gqtw.load(p)


def test_presets_have_schedules_or_are_timing_only():
    from compile.configs import TRAIN_SCHEDULE

    trained = set(TRAIN_SCHEDULE)
    for cfg in PRESETS:
        if cfg.name not in trained:
            # timing-only ladder entries (Table IV) — documented
            assert cfg.name in {"opt-lg", "opt-xl"}, cfg.name
