"""AOT lowering smoke tests: the HLO text must be produced, parse-able in
spirit (non-empty ENTRY, right arg count) and stable in ABI order."""

import re

import jax.numpy as jnp
import numpy as np

from compile.aot import lower_decode, lower_logits, shapes_for, to_hlo_text
from compile.configs import ModelConfig, OPT


def micro_cfg():
    return ModelConfig("aot-test", OPT, 32, 2, 2, 64, vocab=64, max_seq=32)


def test_shapes_for_matches_weight_order():
    cfg = micro_cfg()
    shapes = shapes_for(cfg)
    order = cfg.weight_order()
    assert len(shapes) == len(order)
    # spot checks
    assert shapes[order.index("tok_emb")] == (64, 32)
    assert shapes[order.index("L0.attn.q")] == (32, 32)
    assert shapes[order.index("L1.ff.up")] == (64, 32)


def entry_param_count(text):
    """Number of parameters of the ENTRY computation (fusion bodies also
    declare parameters, so a global regex over-counts)."""
    entry = text[text.index("ENTRY") :]
    ids = set()
    for line in entry.splitlines():
        m = re.search(r"parameter\((\d+)\)", line)
        if m:
            ids.add(int(m.group(1)))
    return len(ids)


def test_logits_lowering_produces_hlo_text():
    cfg = micro_cfg()
    text = to_hlo_text(lower_logits(cfg, seq=16, use_pallas=False))
    assert "ENTRY" in text
    assert "f32[16,64]" in text  # logits shape appears
    # one parameter per weight + tokens
    assert entry_param_count(text) == len(cfg.weight_order()) + 1


def test_decode_lowering_produces_hlo_text():
    cfg = micro_cfg()
    text = to_hlo_text(lower_decode(cfg, kv_len=8))
    assert "ENTRY" in text
    assert entry_param_count(text) == len(cfg.weight_order()) + 4  # + k, v, token, pos


def test_pallas_lowering_also_produces_hlo_text():
    cfg = micro_cfg()
    text = to_hlo_text(lower_logits(cfg, seq=16, use_pallas=True))
    assert "ENTRY" in text
    # interpret=True must NOT leave TPU custom-calls behind
    assert "tpu_custom_call" not in text


def test_lowering_is_deterministic():
    cfg = micro_cfg()
    a = to_hlo_text(lower_logits(cfg, seq=8, use_pallas=False))
    b = to_hlo_text(lower_logits(cfg, seq=8, use_pallas=False))
    assert a == b
