"""Model configurations — the Python mirror of ``rust/src/model/config.rs``.

The two sides MUST stay in lockstep: preset dimensions, weight names, and
``weight_order`` (the positional argument order of every AOT artifact).
A divergence here shows up as shape errors (best case) or silent numeric
garbage (worst case) when rust feeds the HLO executables.
"""

from dataclasses import dataclass

VOCAB = 2048
MAX_SEQ = 256

OPT, LLAMA, BLOOM = "opt", "llama", "bloom"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    d_model: int
    layers: int
    heads: int
    d_ff: int
    vocab: int = VOCAB
    max_seq: int = MAX_SEQ

    @property
    def head_dim(self) -> int:
        return self.d_model // self.heads

    def block_linears(self, i: int):
        d, ff = self.d_model, self.d_ff
        out = [
            (f"L{i}.attn.q", d, d),
            (f"L{i}.attn.k", d, d),
            (f"L{i}.attn.v", d, d),
            (f"L{i}.attn.o", d, d),
        ]
        if self.family == LLAMA:
            out += [
                (f"L{i}.ff.gate", ff, d),
                (f"L{i}.ff.up", ff, d),
                (f"L{i}.ff.down", d, ff),
            ]
        else:
            out += [
                (f"L{i}.ff.up", ff, d),
                (f"L{i}.ff.down", d, ff),
            ]
        return out

    def weight_order(self):
        """Canonical weight argument order (== rust weight_order())."""
        order = ["tok_emb"]
        if self.family == OPT:
            order.append("pos_emb")
        for i in range(self.layers):
            order.append(f"L{i}.ln1.w")
            if self.family != LLAMA:
                order.append(f"L{i}.ln1.b")
            order += [name for name, _, _ in self.block_linears(i)[:4]]
            order.append(f"L{i}.ln2.w")
            if self.family != LLAMA:
                order.append(f"L{i}.ln2.b")
            order += [name for name, _, _ in self.block_linears(i)[4:]]
        order.append("final_ln.w")
        if self.family != LLAMA:
            order.append("final_ln.b")
        return order

    def param_count(self) -> int:
        d, ff = self.d_model, self.d_ff
        emb = self.vocab * d + (self.max_seq * d if self.family == OPT else 0)
        attn = 4 * d * d
        ffn = 3 * d * ff if self.family == LLAMA else 2 * d * ff
        norms = (2 if self.family == LLAMA else 4) * d * self.layers + 2 * d
        return emb + self.layers * (attn + ffn) + norms


PRESETS = [
    ModelConfig("opt-nano", OPT, 64, 2, 2, 256),
    ModelConfig("opt-micro", OPT, 96, 3, 3, 384),
    ModelConfig("opt-mini", OPT, 128, 4, 4, 512),
    ModelConfig("opt-sm", OPT, 192, 6, 6, 768),
    ModelConfig("opt-md", OPT, 256, 8, 8, 1024),
    ModelConfig("opt-lg", OPT, 384, 10, 8, 1536),
    ModelConfig("opt-xl", OPT, 512, 12, 8, 2048),
    ModelConfig("llama-sm", LLAMA, 192, 6, 6, 512),
    ModelConfig("llama-md", LLAMA, 256, 8, 8, 688),
    ModelConfig("bloom-nano", BLOOM, 64, 2, 2, 256),
    ModelConfig("bloom-mini", BLOOM, 128, 4, 4, 512),
    ModelConfig("bloom-sm", BLOOM, 192, 6, 6, 768),
    ModelConfig("bloom-md", BLOOM, 256, 8, 8, 1024),
]


def by_name(name: str) -> ModelConfig:
    for cfg in PRESETS:
        if cfg.name == name:
            return cfg
    raise KeyError(f"unknown preset {name!r}")


# Default training schedule for `make artifacts` on a single CPU core:
# (steps, batch, seq). Larger ladder entries are timing-only (Table IV)
# and keep random init — documented in DESIGN.md §2.
TRAIN_SCHEDULE = {
    "opt-nano": (400, 8, 96),
    "opt-micro": (300, 8, 96),
    "opt-mini": (250, 8, 96),
    "opt-sm": (160, 8, 96),
    "opt-md": (100, 8, 96),
    "llama-sm": (160, 8, 96),
    "llama-md": (100, 8, 96),
    "bloom-nano": (350, 8, 96),
    "bloom-mini": (250, 8, 96),
    "bloom-sm": (140, 8, 96),
    "bloom-md": (100, 8, 96),
}
