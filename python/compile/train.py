"""Build-time training: fit each model preset on the rust-generated
synthetic corpus (`gptqt gen-corpus` → ``artifacts/corpus-wiki-syn-
train.bin``), log the loss curve, and save GQTW weights for the rust
runtime.

This replaces the paper's HuggingFace checkpoints (unavailable offline,
DESIGN.md §2): the quantization experiments need *trained* weights with
real activation statistics, not random init.

Usage::

    python -m compile.train [--models opt-nano,opt-mini] [--steps-scale 1.0]
"""

import argparse
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import gqtw
from .configs import PRESETS, TRAIN_SCHEDULE, by_name
from .model import batched_nll, init_weights


def load_corpus(path):
    toks = np.fromfile(path, dtype="<u4")
    if len(toks) < 10_000:
        raise SystemExit(f"corpus {path} too small ({len(toks)} tokens) — run `gptqt gen-corpus`")
    return toks.astype(np.int32)


def sample_batch(rng, corpus, batch, seq):
    starts = rng.integers(0, len(corpus) - seq - 1, size=batch)
    return np.stack([corpus[s : s + seq + 1] for s in starts])


def adam_init(weights):
    zeros = {k: jnp.zeros_like(v) for k, v in weights.items()}
    return zeros, {k: jnp.zeros_like(v) for k, v in zeros.items()}


def train_one(cfg, corpus, steps, batch, seq, lr=3e-3, seed=0, log=print):
    weights = init_weights(cfg, seed)
    m, v = adam_init(weights)
    b1, b2, eps = 0.9, 0.95, 1e-8
    warmup = max(1, steps // 10)

    loss_grad = jax.jit(jax.value_and_grad(lambda w, b: batched_nll(cfg, w, b)))

    @jax.jit
    def update(weights, m, v, grads, lr_t, t):
        new_w, new_m, new_v = {}, {}, {}
        for k in weights:
            g = grads[k]
            mk = b1 * m[k] + (1 - b1) * g
            vk = b2 * v[k] + (1 - b2) * g * g
            mhat = mk / (1 - b1**t)
            vhat = vk / (1 - b2**t)
            new_w[k] = weights[k] - lr_t * mhat / (jnp.sqrt(vhat) + eps)
            new_m[k], new_v[k] = mk, vk
        return new_w, new_m, new_v

    rng = np.random.default_rng(seed + 1)
    curve = []
    t0 = time.time()
    for step in range(1, steps + 1):
        if step <= warmup:
            lr_t = lr * step / warmup
        else:
            p = (step - warmup) / max(1, steps - warmup)
            lr_t = lr * (0.1 + 0.9 * 0.5 * (1 + np.cos(np.pi * p)))
        batch_tokens = jnp.asarray(sample_batch(rng, corpus, batch, seq))
        loss, grads = loss_grad(weights, batch_tokens)
        weights, m, v = update(weights, m, v, grads, jnp.float32(lr_t), jnp.float32(step))
        curve.append(float(loss))
        if step % 10 == 0 or step == 1:
            log(
                f"  {cfg.name} step {step:4d}/{steps} loss {float(loss):.4f} "
                f"lr {lr_t:.2e} ({time.time() - t0:.0f}s)"
            )
    return weights, curve


def heldout_ppl(cfg, weights, corpus, windows=6, seq=96, seed=123):
    rng = np.random.default_rng(seed)
    nll = 0.0
    for _ in range(windows):
        batch_tokens = jnp.asarray(sample_batch(rng, corpus, 1, seq))
        nll += float(batched_nll(cfg, weights, batch_tokens))
    return float(np.exp(nll / windows))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default=",".join(TRAIN_SCHEDULE.keys()))
    ap.add_argument("--steps-scale", type=float, default=1.0)
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    art = Path(args.artifacts)
    art.mkdir(parents=True, exist_ok=True)
    corpus = load_corpus(art / "corpus-wiki-syn-train.bin")
    # hold out the tail for ppl sanity (rust evaluates on its own stream)
    split = int(len(corpus) * 0.95)
    train_c, held = corpus[:split], corpus[split:]

    names = [n.strip() for n in args.models.split(",") if n.strip()]
    for name in names:
        cfg = by_name(name)
        steps, batch, seq = TRAIN_SCHEDULE.get(name, (150, 8, 96))
        steps = max(20, int(steps * args.steps_scale))
        out = art / f"{name}.gqtw"
        if out.exists():
            print(f"[train] {name}: {out} exists, skipping")
            continue
        print(f"[train] {name}: {steps} steps batch {batch} seq {seq}")
        weights, curve = train_one(cfg, train_c, steps, batch, seq, seed=args.seed)
        ppl = heldout_ppl(cfg, weights, held)
        print(f"[train] {name}: final loss {curve[-1]:.4f}, held-out ppl {ppl:.2f}")
        gqtw.save(out, {k: np.asarray(weights[k]) for k in cfg.weight_order()})
        with open(art / f"train-log-{name}.txt", "w") as f:
            f.write(f"# {name} steps={steps} batch={batch} seq={seq}\n")
            f.write(f"# final_loss={curve[-1]:.5f} heldout_ppl={ppl:.3f}\n")
            for i, loss_v in enumerate(curve, 1):
                f.write(f"{i}\t{loss_v:.5f}\n")
        print(f"[train] {name}: saved {out}")


if __name__ == "__main__":
    sys.exit(main())
