"""GQTW weight container — Python writer/reader matching
``rust/src/model/weights.rs`` byte-for-byte.

Layout (little-endian)::

    magic   [8]  b"GQTW0001"
    count   u32
    repeat count times:
      name_len u32, name [name_len] utf-8
      rows u32, cols u32
      data rows*cols f32
"""

import struct

import numpy as np

MAGIC = b"GQTW0001"


def save(path, tensors):
    """Write an ordered ``{name: 2-D float32 array}`` mapping."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.asarray(arr, dtype=np.float32)
            if arr.ndim == 1:
                arr = arr.reshape(1, -1)
            if arr.ndim != 2:
                raise ValueError(f"{name}: GQTW stores 2-D tensors, got {arr.shape}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<II", arr.shape[0], arr.shape[1]))
            f.write(arr.astype("<f4").tobytes())


def load(path):
    """Read a GQTW file into an ordered ``{name: float32 array}`` dict."""
    with open(path, "rb") as f:
        data = f.read()
    if data[:8] != MAGIC:
        raise ValueError(f"bad GQTW magic in {path}")
    off = 8
    (count,) = struct.unpack_from("<I", data, off)
    off += 4
    out = {}
    for _ in range(count):
        (name_len,) = struct.unpack_from("<I", data, off)
        off += 4
        name = data[off : off + name_len].decode("utf-8")
        off += name_len
        rows, cols = struct.unpack_from("<II", data, off)
        off += 8
        n = rows * cols
        arr = np.frombuffer(data, dtype="<f4", count=n, offset=off).reshape(rows, cols)
        off += n * 4
        out[name] = arr.copy()
    return out
