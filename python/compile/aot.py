"""AOT lowering: JAX model functions → HLO *text* artifacts for the rust
PJRT runtime.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts per model:

* ``<name>.logits.hlo.txt``  — ``(weights…, tokens i32[T]) → (logits f32[T,V],)``
* ``<name>.decode.hlo.txt``  — ``(weights…, k f32[L,S,D], v f32[L,S,D],
  token i32[], pos i32[]) → (logits f32[V], k', v')``

Weight arguments are positional in ``ModelConfig.weight_order`` — the ABI
shared with ``rust/src/model/config.rs``.

Usage::

    python -m compile.aot [--models opt-nano,opt-mini] [--seq 128]
                          [--kv-len 64] [--pallas] [--out-dir ../artifacts]
"""

import argparse
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import by_name
from .model import decode_step, prefill_logits


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def weight_specs(cfg, weights_shapes):
    return [jax.ShapeDtypeStruct(s, jnp.float32) for s in weights_shapes]


def shapes_for(cfg):
    """Shapes of every weight in ABI order."""
    d = cfg.d_model
    shapes = []
    for name in cfg.weight_order():
        if name == "tok_emb":
            shapes.append((cfg.vocab, d))
        elif name == "pos_emb":
            shapes.append((cfg.max_seq, d))
        elif ".ln" in name or name.startswith("final_ln"):
            shapes.append((1, d))
        else:
            i, rest = name.split(".", 1)
            for lname, rows, cols in cfg.block_linears(int(i[1:])):
                if lname == name:
                    shapes.append((rows, cols))
                    break
            else:
                raise KeyError(name)
    return shapes


def lower_logits(cfg, seq, use_pallas):
    wshapes = shapes_for(cfg)

    def fn(*args):
        weights = dict(zip(cfg.weight_order(), args[:-1]))
        tokens = args[-1]
        return (prefill_logits(cfg, weights, tokens, use_pallas=use_pallas),)

    specs = weight_specs(cfg, wshapes) + [jax.ShapeDtypeStruct((seq,), jnp.int32)]
    return jax.jit(fn).lower(*specs)


def lower_decode(cfg, kv_len):
    wshapes = shapes_for(cfg)
    d = cfg.d_model

    def fn(*args):
        nw = len(wshapes)
        weights = dict(zip(cfg.weight_order(), args[:nw]))
        k, v, token, pos = args[nw : nw + 4]
        return decode_step(cfg, weights, k, v, token, pos)

    specs = (
        weight_specs(cfg, wshapes)
        + [
            jax.ShapeDtypeStruct((cfg.layers, kv_len, d), jnp.float32),
            jax.ShapeDtypeStruct((cfg.layers, kv_len, d), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32),
        ]
    )
    return jax.jit(fn).lower(*specs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="opt-nano,opt-mini")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--kv-len", type=int, default=64)
    ap.add_argument(
        "--pallas",
        action="store_true",
        help="route logits-artifact linears through the Pallas tiled matmul",
    )
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    for name in [n.strip() for n in args.models.split(",") if n.strip()]:
        cfg = by_name(name)
        for kind, lowered in [
            ("logits", lower_logits(cfg, args.seq, args.pallas)),
            ("decode", lower_decode(cfg, args.kv_len)),
        ]:
            text = to_hlo_text(lowered)
            path = out_dir / f"{name}.{kind}.hlo.txt"
            path.write_text(text)
            print(f"[aot] wrote {path} ({len(text)} chars)")
        # metadata the rust runtime reads to know artifact shapes
        meta = out_dir / f"{name}.meta.txt"
        meta.write_text(
            f"model={name}\nseq={args.seq}\nkv_len={args.kv_len}\n"
            f"pallas={int(args.pallas)}\nweights={len(cfg.weight_order())}\n"
        )
        print(f"[aot] wrote {meta}")


if __name__ == "__main__":
    sys.exit(main())
