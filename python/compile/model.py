"""L2 — the JAX model: decoder-only transformer in three families
(OPT/Llama/Bloom-like), numerically identical to the rust reference
forward (``rust/src/model/forward.rs``): same GELU tanh approximation,
same RoPE pairing, same ALiBi slopes, same ε = 1e-5.

Weights travel as a ``{name: array}`` dict ordered by
``configs.ModelConfig.weight_order`` — the positional ABI of the AOT
artifacts. ``use_pallas=True`` routes the linear-layer contractions
through the Pallas tiled matmul (L1 lowering into the same HLO).
"""

import jax
import jax.numpy as jnp
import numpy as np

from .configs import BLOOM, LLAMA, OPT, ModelConfig
from .kernels import matmul as pallas_matmul

LN_EPS = 1e-5


def linear(x, w, use_pallas=False):
    """``x (… × in) @ w (out × in)ᵀ``."""
    if use_pallas and x.ndim == 2:
        return pallas_matmul.matmul_nt(x, w)
    return jnp.dot(x, w.T)


def layernorm(x, w, b):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + LN_EPS) * w + b


def rmsnorm(x, w):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + LN_EPS) * w


def norm(cfg, weights, prefix, x):
    if cfg.family == LLAMA:
        return rmsnorm(x, weights[f"{prefix}.w"][0])
    return layernorm(x, weights[f"{prefix}.w"][0], weights[f"{prefix}.b"][0])


def rope(x, positions):
    """Rotary embedding. x: (T × H × dh), positions: (T,) int32.
    Pairing convention (x[2i], x[2i+1]) — matches rust `rope`."""
    t, h, dh = x.shape
    half = dh // 2
    inv_freq = 10000.0 ** (-2.0 * jnp.arange(half, dtype=jnp.float32) / dh)
    angles = positions.astype(jnp.float32)[:, None] * inv_freq[None, :]  # T × half
    sin = jnp.sin(angles)[:, None, :]
    cos = jnp.cos(angles)[:, None, :]
    even = x[..., 0::2]
    odd = x[..., 1::2]
    r_even = even * cos - odd * sin
    r_odd = even * sin + odd * cos
    return jnp.stack([r_even, r_odd], axis=-1).reshape(t, h, dh)


def alibi_slopes(heads):
    return 2.0 ** (-8.0 * (jnp.arange(heads, dtype=jnp.float32) + 1.0) / heads)


def block(cfg: ModelConfig, weights, i, x, positions, use_pallas=False):
    """One transformer block over a (T × d) window."""
    t = x.shape[0]
    heads, dh = cfg.heads, cfg.head_dim
    h = norm(cfg, weights, f"L{i}.ln1", x)
    q = linear(h, weights[f"L{i}.attn.q"], use_pallas).reshape(t, heads, dh)
    k = linear(h, weights[f"L{i}.attn.k"], use_pallas).reshape(t, heads, dh)
    v = linear(h, weights[f"L{i}.attn.v"], use_pallas).reshape(t, heads, dh)
    if cfg.family == LLAMA:
        q = rope(q, positions)
        k = rope(k, positions)
    scores = jnp.einsum("thd,shd->hts", q, k) / np.sqrt(dh)
    if cfg.family == BLOOM:
        rel = (positions[None, :] - positions[:, None]).astype(jnp.float32)  # j − i
        scores = scores + alibi_slopes(heads)[:, None, None] * rel[None, :, :]
    causal = positions[None, :] <= positions[:, None]  # (i, j): j ≤ i
    scores = jnp.where(causal[None, :, :], scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hts,shd->thd", probs, v).reshape(t, heads * dh)
    x = x + linear(ctx, weights[f"L{i}.attn.o"], use_pallas)

    h2 = norm(cfg, weights, f"L{i}.ln2", x)
    if cfg.family == LLAMA:
        gate = linear(h2, weights[f"L{i}.ff.gate"], use_pallas)
        up = linear(h2, weights[f"L{i}.ff.up"], use_pallas)
        act = jax.nn.silu(gate) * up
    else:
        up = linear(h2, weights[f"L{i}.ff.up"], use_pallas)
        act = jax.nn.gelu(up)  # approximate=True (tanh) — matches rust
    return x + linear(act, weights[f"L{i}.ff.down"], use_pallas)


def embed(cfg: ModelConfig, weights, tokens, positions):
    x = weights["tok_emb"][tokens]
    if cfg.family == OPT:
        x = x + weights["pos_emb"][positions]
    return x


def prefill_logits(cfg: ModelConfig, weights, tokens, use_pallas=False):
    """Full-window logits (T × vocab) — the perplexity/prefill artifact."""
    t = tokens.shape[0]
    positions = jnp.arange(t, dtype=jnp.int32)
    x = embed(cfg, weights, tokens, positions)
    for i in range(cfg.layers):
        x = block(cfg, weights, i, x, positions, use_pallas)
    xf = norm(cfg, weights, "final_ln", x)
    return linear(xf, weights["tok_emb"], use_pallas)


def decode_step(cfg: ModelConfig, weights, k_cache, v_cache, token, pos):
    """Single-token decode with stacked KV caches.

    k_cache/v_cache: (L × S × d) f32; token: () int32; pos: () int32.
    Returns (logits (vocab,), k_cache', v_cache').
    """
    heads, dh, d = cfg.heads, cfg.head_dim, cfg.d_model
    s = k_cache.shape[1]
    x = weights["tok_emb"][token]
    if cfg.family == OPT:
        x = x + weights["pos_emb"][pos]
    span = jnp.arange(s, dtype=jnp.int32)
    mask = span <= pos
    for i in range(cfg.layers):
        h = norm(cfg, weights, f"L{i}.ln1", x)
        q = jnp.dot(h, weights[f"L{i}.attn.q"].T).reshape(heads, dh)
        k = jnp.dot(h, weights[f"L{i}.attn.k"].T).reshape(heads, dh)
        v = jnp.dot(h, weights[f"L{i}.attn.v"].T).reshape(heads, dh)
        if cfg.family == LLAMA:
            q = rope(q[None], pos[None])[0]
            k = rope(k[None], pos[None])[0]
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.reshape(1, 1, d), (i, pos, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.reshape(1, 1, d), (i, pos, 0)
        )
        kc = k_cache[i].reshape(s, heads, dh)
        vc = v_cache[i].reshape(s, heads, dh)
        scores = jnp.einsum("hd,shd->hs", q, kc) / np.sqrt(dh)
        if cfg.family == BLOOM:
            rel = (span - pos).astype(jnp.float32)
            scores = scores + alibi_slopes(heads)[:, None] * rel[None, :]
        scores = jnp.where(mask[None, :], scores, -1e9)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("hs,shd->hd", probs, vc).reshape(d)
        x = x + jnp.dot(ctx, weights[f"L{i}.attn.o"].T)

        h2 = norm(cfg, weights, f"L{i}.ln2", x)
        if cfg.family == LLAMA:
            act = jax.nn.silu(jnp.dot(h2, weights[f"L{i}.ff.gate"].T)) * jnp.dot(
                h2, weights[f"L{i}.ff.up"].T
            )
        else:
            act = jax.nn.gelu(jnp.dot(h2, weights[f"L{i}.ff.up"].T))
        x = x + jnp.dot(act, weights[f"L{i}.ff.down"].T)
    xf = norm(cfg, weights, "final_ln", x)
    logits = jnp.dot(xf, weights["tok_emb"].T)
    return logits, k_cache, v_cache


def batched_nll(cfg: ModelConfig, weights, batch):
    """Mean next-token cross-entropy over a (B × T+1) token batch."""

    def one(tokens):
        logits = prefill_logits(cfg, weights, tokens[:-1])
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, tokens[1:, None], axis=-1))

    return jnp.mean(jax.vmap(one)(batch))


def init_weights(cfg: ModelConfig, seed=0):
    """GPT-2-style init, mirroring rust `init::random_weights` semantics
    (not bitwise — training overwrites everything anyway)."""
    rng = np.random.default_rng(seed)
    sigma = 0.02
    resid = sigma / np.sqrt(2 * cfg.layers)
    w = {}
    d = cfg.d_model
    w["tok_emb"] = rng.normal(0, sigma, (cfg.vocab, d)).astype(np.float32)
    if cfg.family == OPT:
        w["pos_emb"] = rng.normal(0, sigma, (cfg.max_seq, d)).astype(np.float32)
    for i in range(cfg.layers):
        w[f"L{i}.ln1.w"] = np.ones((1, d), np.float32)
        if cfg.family != LLAMA:
            w[f"L{i}.ln1.b"] = np.zeros((1, d), np.float32)
        w[f"L{i}.ln2.w"] = np.ones((1, d), np.float32)
        if cfg.family != LLAMA:
            w[f"L{i}.ln2.b"] = np.zeros((1, d), np.float32)
        for name, rows, cols in cfg.block_linears(i):
            s = resid if name.endswith((".o", ".down")) else sigma
            w[name] = rng.normal(0, s, (rows, cols)).astype(np.float32)
    w["final_ln.w"] = np.ones((1, d), np.float32)
    if cfg.family != LLAMA:
        w["final_ln.b"] = np.zeros((1, d), np.float32)
    return {k: jnp.asarray(v) for k, v in w.items()}


def ordered_weights(cfg: ModelConfig, weights):
    """Weights as a positional list in artifact ABI order."""
    return [weights[name] for name in cfg.weight_order()]


def weights_from_ordered(cfg: ModelConfig, arrays):
    return dict(zip(cfg.weight_order(), arrays))
