"""Pallas LUT-GEMM kernel — the GPTQT binary-coding matvec (paper §II-D,
LUT-GEMM [13]) re-thought for TPU.

GPU original: one warp per output tile, a 2^g-entry table of activation
partial sums in shared memory, gathers indexed by packed sign bytes.

TPU re-think (DESIGN.md §8): there is no per-thread gather loop to win
with — the VPU wants wide regular ops and the MXU wants contractions. So
the kernel:

* streams the packed sign *words* (int32, 3 bits/weight ⇒ ~10.7× less
  HBM traffic than f32 weights — the same bandwidth win LUT-GEMM gets),
* unpacks a (row-tile × planes × cols) ±1 tensor in VMEM with vectorized
  shift/mask ops (the "table" becomes implicit — on TPU materializing
  per-group LUTs is slower than the VPU's bulk unpack),
* contracts signs × activations on the MXU (`einsum rpc,c->rp`), then
  folds the per-plane α̂ scales and the fused bias — Eq. 11's pure binary
  coding, no intermediate integer state.

Grid: one step per row tile; BlockSpec stages that tile's α̂/bias/sign
words into VMEM while x stays resident across steps.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lut_gemv_kernel(x_ref, alphas_ref, bias_ref, words_ref, o_ref):
    x = x_ref[...]  # (cols,)
    words = words_ref[...]  # (TR, planes, W) int32
    tr, planes, nwords = words.shape
    cols = x.shape[0]
    shifts = jnp.arange(32, dtype=words.dtype)
    bits = (words[..., None] >> shifts[None, None, None, :]) & 1
    signs = bits.reshape(tr, planes, nwords * 32)[..., :cols].astype(jnp.float32) * 2.0 - 1.0
    partial = jnp.einsum("rpc,c->rp", signs, x)  # MXU contraction
    o_ref[...] = jnp.sum(alphas_ref[...] * partial, axis=1) + bias_ref[...] * jnp.sum(x)


@functools.partial(jax.jit, static_argnames=("tr",))
def lut_gemv(alphas, bias, words, x, tr=64):
    """``y = Ŵ·x`` over the fused binary-coded layer.

    alphas (rows × planes) f32, bias (rows,) f32,
    words (rows × planes × W) int32 packed signs, x (cols,) f32.
    """
    rows, planes = alphas.shape
    nwords = words.shape[2]
    cols = x.shape[0]
    assert words.shape[0] == rows and bias.shape == (rows,)
    while rows % tr != 0:
        tr -= 1
    tr = max(tr, 1)
    grid = (rows // tr,)
    return pl.pallas_call(
        _lut_gemv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cols,), lambda i: (0,)),
            pl.BlockSpec((tr, planes), lambda i: (i, 0)),
            pl.BlockSpec((tr,), lambda i: (i,)),
            pl.BlockSpec((tr, planes, nwords), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tr,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,
    )(x, alphas, bias, words)


def vmem_bytes(tr, planes, cols):
    """Per-grid-step VMEM estimate: x + unpacked signs + α̂/bias/out.
    The unpacked sign tensor dominates — it is the deliberate trade:
    4·TR·planes·cols bytes of VMEM scratch buys a 32/planes× cut in HBM
    traffic for the weights."""
    nwords = (cols + 31) // 32
    return 4 * (cols + tr * planes * nwords + tr * planes * cols + tr * planes + 2 * tr)
