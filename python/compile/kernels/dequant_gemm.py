"""Pallas dequant-GEMM kernel — the GPTQ inference matvec: linearly
quantized integer codes are dequantized tile-by-tile in VMEM and
contracted on the MXU (`w = scale·(q + qz)`, then `w @ x`).

This is the baseline GPTQT races against in Table IV: same HBM traffic
class (int codes), but it must materialize fp weights before the
contraction, where the binary-coding kernel goes straight from sign bits
to partial sums.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dequant_gemv_kernel(x_ref, codes_ref, scale_ref, qz_ref, o_ref):
    x = x_ref[...]
    w = scale_ref[...][:, None] * (codes_ref[...].astype(jnp.float32) + qz_ref[...][:, None])
    o_ref[...] = w @ x


@functools.partial(jax.jit, static_argnames=("tr",))
def dequant_gemv(codes, scale, qz, x, tr=64):
    """``y = Ŵ·x`` with on-the-fly dequantization.

    codes (rows × cols) int32, scale/qz (rows,) f32, x (cols,) f32.
    """
    rows, cols = codes.shape
    while rows % tr != 0:
        tr -= 1
    tr = max(tr, 1)
    grid = (rows // tr,)
    return pl.pallas_call(
        _dequant_gemv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cols,), lambda i: (0,)),
            pl.BlockSpec((tr, cols), lambda i: (i, 0)),
            pl.BlockSpec((tr,), lambda i: (i,)),
            pl.BlockSpec((tr,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tr,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,
    )(x, codes, scale, qz)
