"""Tiled Pallas matmul kernel — the linear-layer contraction used by the
AOT model variants (L1 called from L2).

TPU mapping (DESIGN.md §8): the grid walks (row-tile × out-tile) blocks;
BlockSpec stages an (TM × K) activation panel and an (TN × K) weight panel
into VMEM per step and the contraction feeds the MXU as a
``jnp.dot(a, b.T)``. ``interpret=True`` everywhere in this repo — the CPU
PJRT plugin cannot execute Mosaic custom-calls; the lowering is the same
HLO the rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_nt_kernel(x_ref, w_ref, o_ref):
    # One (TM × TN) output tile: full-K panels are VMEM-resident.
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...].T)


def _pick_tile(n, target):
    """Largest divisor of n that is ≤ target (keeps tiles even, avoids
    padding logic; model dims here are powers of two)."""
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return max(t, 1)


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def matmul_nt(x, w, tm=64, tn=128):
    """``x (T×K) @ w (N×K)ᵀ`` via a grid of Pallas tiles."""
    t, k = x.shape
    n, k2 = w.shape
    assert k == k2, f"contraction mismatch {x.shape} vs {w.shape}"
    tm = _pick_tile(t, tm)
    tn = _pick_tile(n, tn)
    grid = (t // tm, n // tn)
    return pl.pallas_call(
        _matmul_nt_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), x.dtype),
        interpret=True,
    )(x, w)


def vmem_bytes(tm, tn, k, dtype_bytes=4):
    """VMEM footprint estimate of one grid step (for DESIGN.md §Perf):
    activation panel + weight panel + output tile."""
    return dtype_bytes * (tm * k + tn * k + tm * tn)
