"""L1 — Pallas kernels (build-time; interpret=True for CPU PJRT).

* ``matmul``       — tiled matmul (model linears)
* ``lut_gemm``     — fused binary-coding matvec (GPTQT inference)
* ``dequant_gemm`` — int-dequant matvec (GPTQ inference)
* ``ref``          — pure-jnp oracles for all of the above
"""
