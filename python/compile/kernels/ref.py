"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package is asserted allclose against these functions
by ``python/tests/test_kernels.py`` (including hypothesis shape sweeps).
The rust CPU kernels implement the same contracts (see
``rust/src/kernels``), so these oracles pin down the semantics for the
whole three-layer stack.
"""

import jax.numpy as jnp
import numpy as np


def matmul_nt_ref(x, w):
    """``x (T×k) @ w (r×k)ᵀ`` — the linear-layer contraction (weights
    stored (out × in), matching the rust/Tensor layout)."""
    return jnp.dot(x, w.T)


def dequant_gemv_ref(codes, scale, qz, x):
    """GPTQ dequant matvec.

    codes: int32 (rows × cols) quantized weights,
    scale/qz: f32 (rows,) per-row dequant params (``w = scale·(q + qz)``),
    x: f32 (cols,).
    """
    w = scale[:, None] * (codes.astype(jnp.float32) + qz[:, None])
    return w @ x


def unpack_signs_ref(words, cols):
    """Unpack bit-packed sign planes to ±1.

    words: int32 (rows × planes × W) with bit k of word j covering column
    ``32·j + k``; returns f32 (rows × planes × cols) in {−1, +1}.
    """
    rows, planes, nwords = words.shape
    shifts = jnp.arange(32, dtype=words.dtype)
    bits = (words[..., None] >> shifts[None, None, None, :]) & 1
    bits = bits.reshape(rows, planes, nwords * 32)[..., :cols]
    return bits.astype(jnp.float32) * 2.0 - 1.0


def lut_gemv_ref(alphas, bias, words, x):
    """Fused binary-coding (LUT-GEMM) matvec — the GPTQT inference op.

    ``y[r] = Σ_p alphas[r,p]·(Σ_c sign[r,p,c]·x[c]) + bias[r]·Σ_c x[c]``

    alphas: f32 (rows × planes), bias: f32 (rows,),
    words: int32 (rows × planes × W) packed signs, x: f32 (cols,).
    """
    cols = x.shape[0]
    signs = unpack_signs_ref(words, cols)  # rows × planes × cols
    partial = jnp.einsum("rpc,c->rp", signs, x)
    return jnp.sum(alphas * partial, axis=1) + bias * jnp.sum(x)


def pack_signs_np(signs):
    """numpy helper: pack a ±1 (rows × planes × cols) array into int32
    words (rows × planes × ceil(cols/32)). Inverse of unpack_signs_ref."""
    signs = np.asarray(signs)
    rows, planes, cols = signs.shape
    nwords = (cols + 31) // 32
    bits = (signs > 0).astype(np.uint64)
    padded = np.zeros((rows, planes, nwords * 32), dtype=np.uint64)
    padded[..., :cols] = bits
    padded = padded.reshape(rows, planes, nwords, 32)
    shifts = np.arange(32, dtype=np.uint64)
    words = (padded << shifts).sum(axis=-1).astype(np.uint32)
    return words.view(np.int32) if words.dtype != np.int32 else words
