//! Run-configuration loading: a tiny `key = value` config format (no
//! serde/toml offline) used by the launcher for experiment presets.
//!
//! Format: one `key = value` per line, `#` comments, sections as
//! `key.subkey`. Values: strings, integers, floats, booleans.

use anyhow::{bail, Result};
use std::collections::HashMap;
use std::path::Path;

/// Parsed config: flat dotted-key map.
#[derive(Debug, Default, Clone)]
pub struct Config {
    values: HashMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = HashMap::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("config line {} has no `=`: {raw:?}", ln + 1);
            };
            let key = k.trim();
            if key.is_empty() {
                bail!("config line {} has empty key", ln + 1);
            }
            values.insert(key.to_string(), v.trim().to_string());
        }
        Ok(Config { values })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            _ => default,
        }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_config() {
        let c = Config::parse(
            "# experiment preset\n\
             model = opt-md\n\
             quant.bits = 3     # final bits\n\
             quant.step1_bits=5\n\
             serve.batch = 8\n\
             fast = true\n",
        )
        .unwrap();
        assert_eq!(c.get("model"), Some("opt-md"));
        assert_eq!(c.get_usize("quant.bits", 0), 3);
        assert_eq!(c.get_usize("quant.step1_bits", 0), 5);
        assert!(c.get_bool("fast", false));
        assert_eq!(c.get_usize("missing", 7), 7);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("just a line").is_err());
        assert!(Config::parse("= novalue").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let c = Config::parse("\n# only comments\n\n").unwrap();
        assert!(c.is_empty());
    }
}
