//! Head-major attention kernels — the serving hot loop downstream of
//! the GEMMs.
//!
//! With the three weight formats on SIMD-dispatched, pool-threaded
//! [`super::Gemv`] kernels, attention is the Amdahl term that caps
//! long-context prefill and high-occupancy decode. These primitives fix
//! that, fed by the **head-major** KV layout
//! (`layers × heads × max_seq × head_dim`,
//! [`crate::model::KvCache`]): a head's cache positions are one
//! contiguous strip, so the inner loop over the KV prefix streams
//! memory instead of striding `d_model` floats per position.
//!
//! Two primitives cover one (row, head) attention work item:
//!
//! * [`qk_dots`] — one query head against a contiguous K strip:
//!   `scores[j] = (Σ_d q[d]·k[j·dh+d])·scale + slope·(j − pos)`
//!   (the `slope` term is ALiBi; 0 elsewhere).
//! * [`av_accumulate`] — softmax-weighted V strip accumulation:
//!   `out[d] += Σ_j w[j]·v[j·dh+d]`, `j` ascending.
//!
//! Both carry the same **bitwise** scalar↔AVX2 contract as the GEMM
//! kernels ([`super::simd`]): the per-position dot uses the pinned
//! 8-accumulator lane mapping, mul-then-add (no FMA), and the pinned
//! tree reduction, so runtime dispatch can never change a served token;
//! `av_accumulate` keeps the per-element `j` order of the scalar loop
//! (lanes are independent across `d`), which also makes it
//! order-identical to the pre-head-major implementation. Each entry
//! point has a `*_scalar` twin; `tests/attn_parity.rs` pins the twins
//! `assert_eq!`-equal across ragged head dims and context lengths.
//!
//! The (row, head) fan-out across [`crate::util::pool`] lives with the
//! forward core (`model::decode`), which owns the caches; work items
//! are independent and internally sequential, so threaded and
//! single-threaded attention are bitwise identical too.
//!
//! These two primitives (plus the materialized score buffer and libm
//! softmax between them) are the **`Exact` numerics mode** of the
//! attention row. The opt-in `Fast` mode replaces the whole pipeline
//! with one fused flash-style kernel,
//! [`super::fast_math::attn_row_fast`], which never materializes
//! per-position scores — same work item, relaxed contract.

use super::simd::{self, SimdTier};

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Scores of one query head against a contiguous K strip at the
/// detected tier. `kstrip` holds `scores.len()` rows of `q.len()`
/// contiguous floats (positions `0..scores.len()` of one head);
/// `scores[j] = dot(q, k_j)·scale + slope·(j − pos)` where `pos` is the
/// query's absolute position (the ALiBi bias is `≤ 0` over the past).
#[inline]
pub fn qk_dots(q: &[f32], kstrip: &[f32], scale: f32, slope: f32, pos: usize, scores: &mut [f32]) {
    qk_dots_t(q, kstrip, scale, slope, pos, scores, simd::tier())
}

/// [`qk_dots`] forced onto the scalar tier — the parity reference the
/// AVX2 tier must match bitwise (`tests/attn_parity.rs`).
pub fn qk_dots_scalar(
    q: &[f32],
    kstrip: &[f32],
    scale: f32,
    slope: f32,
    pos: usize,
    scores: &mut [f32],
) {
    let dh = q.len();
    debug_assert_eq!(kstrip.len(), scores.len() * dh);
    let posf = pos as f32;
    for (j, s) in scores.iter_mut().enumerate() {
        let krow = &kstrip[j * dh..(j + 1) * dh];
        *s = simd::dot_scalar(q, krow) * scale + slope * (j as f32 - posf);
    }
}

/// [`qk_dots`] pinned to an explicit tier. `t` must not exceed the
/// detected tier (the public wrapper guarantees this; the forward core
/// hoists one `tier()` call per layer).
#[inline]
pub(crate) fn qk_dots_t(
    q: &[f32],
    kstrip: &[f32],
    scale: f32,
    slope: f32,
    pos: usize,
    scores: &mut [f32],
    t: SimdTier,
) {
    match t {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: callers only pass Avx2 when tier() reported it.
        SimdTier::Avx2 => unsafe { qk_dots_avx2(q, kstrip, scale, slope, pos, scores) },
        _ => qk_dots_scalar(q, kstrip, scale, slope, pos, scores),
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: caller must ensure AVX2 and `kstrip.len() == n_keys * dh` (the
// dispatcher asserts it); every `get_unchecked` row below stays inside
// that bound, and the per-row dot goes through `dot_avx2`'s chunk bound.
unsafe fn qk_dots_avx2(
    q: &[f32],
    kstrip: &[f32],
    scale: f32,
    slope: f32,
    pos: usize,
    scores: &mut [f32],
) {
    let dh = q.len();
    debug_assert_eq!(kstrip.len(), scores.len() * dh);
    let posf = pos as f32;
    for (j, s) in scores.iter_mut().enumerate() {
        // same pinned lane mapping + tree reduction as the scalar twin
        let krow = kstrip.get_unchecked(j * dh..(j + 1) * dh);
        *s = simd::dot_avx2(q, krow) * scale + slope * (j as f32 - posf);
    }
}

/// Softmax-weighted V accumulation at the detected tier:
/// `out[d] += Σ_j weights[j]·vstrip[j·dh+d]` with `j` ascending.
/// `vstrip` holds `weights.len()` rows of `out.len()` contiguous floats.
/// Accumulates **onto** `out` (callers zero it once per row).
#[inline]
pub fn av_accumulate(weights: &[f32], vstrip: &[f32], out: &mut [f32]) {
    av_accumulate_t(weights, vstrip, out, simd::tier())
}

/// [`av_accumulate`] forced onto the scalar tier (parity reference).
pub fn av_accumulate_scalar(weights: &[f32], vstrip: &[f32], out: &mut [f32]) {
    let dh = out.len();
    debug_assert_eq!(vstrip.len(), weights.len() * dh);
    for (j, &w) in weights.iter().enumerate() {
        simd::axpy_scalar(out, w, &vstrip[j * dh..(j + 1) * dh]);
    }
}

/// [`av_accumulate`] pinned to an explicit tier.
#[inline]
pub(crate) fn av_accumulate_t(weights: &[f32], vstrip: &[f32], out: &mut [f32], t: SimdTier) {
    match t {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: callers only pass Avx2 when tier() reported it.
        SimdTier::Avx2 => unsafe { av_accumulate_avx2(weights, vstrip, out) },
        _ => av_accumulate_scalar(weights, vstrip, out),
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: caller must ensure AVX2 and `vstrip.len() == weights.len() * dh`
// (the dispatcher asserts it); row slices and 8-lane loads/stores below
// stay inside that bound, tail handled element-wise.
unsafe fn av_accumulate_avx2(weights: &[f32], vstrip: &[f32], out: &mut [f32]) {
    let dh = out.len();
    debug_assert_eq!(vstrip.len(), weights.len() * dh);
    let n = weights.len();
    let chunks = dh / 8;
    let op = out.as_mut_ptr();
    let vp = vstrip.as_ptr();
    for j in 0..n {
        // identical per-element j order to the scalar twin: lanes span
        // the independent d axis, each element sees mul-then-add per j
        let w = _mm256_set1_ps(*weights.get_unchecked(j));
        let row = vp.add(j * dh);
        for i in 0..chunks {
            let o = i * 8;
            let prod = _mm256_mul_ps(w, _mm256_loadu_ps(row.add(o)));
            _mm256_storeu_ps(op.add(o), _mm256_add_ps(_mm256_loadu_ps(op.add(o)), prod));
        }
        let wj = *weights.get_unchecked(j);
        for d in chunks * 8..dh {
            *op.add(d) += wj * *row.add(d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn qk_dots_tiers_match_bitwise_on_ragged_shapes() {
        let mut rng = Rng::new(61);
        for dh in [1usize, 4, 7, 8, 16, 31, 64] {
            for ctx in [1usize, 2, 7, 64, 129] {
                let q: Vec<f32> = (0..dh).map(|_| rng.normal_f32()).collect();
                let kstrip: Vec<f32> = (0..ctx * dh).map(|_| rng.normal_f32()).collect();
                let scale = 1.0 / (dh as f32).sqrt();
                for slope in [0.0f32, -0.125] {
                    let mut s_s = vec![0.0f32; ctx];
                    let mut s_d = vec![0.0f32; ctx];
                    qk_dots_scalar(&q, &kstrip, scale, slope, ctx - 1, &mut s_s);
                    qk_dots(&q, &kstrip, scale, slope, ctx - 1, &mut s_d);
                    for (j, (a, b)) in s_s.iter().zip(&s_d).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "dh={dh} ctx={ctx} slope={slope} j={j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn qk_dots_matches_per_position_pinned_dot() {
        // the kernel is definitionally a strip of pinned dots plus the
        // scale/ALiBi epilogue — pin that decomposition bitwise
        let mut rng = Rng::new(62);
        let (dh, ctx, pos) = (24usize, 17usize, 16usize);
        let q: Vec<f32> = (0..dh).map(|_| rng.normal_f32()).collect();
        let kstrip: Vec<f32> = (0..ctx * dh).map(|_| rng.normal_f32()).collect();
        let (scale, slope) = (0.25f32, -0.5f32);
        let mut scores = vec![0.0f32; ctx];
        qk_dots(&q, &kstrip, scale, slope, pos, &mut scores);
        for j in 0..ctx {
            let expect = simd::dot_scalar(&q, &kstrip[j * dh..(j + 1) * dh]) * scale
                + slope * (j as f32 - pos as f32);
            assert_eq!(scores[j].to_bits(), expect.to_bits(), "j={j}");
        }
    }

    #[test]
    fn av_accumulate_tiers_match_bitwise_and_accumulate() {
        let mut rng = Rng::new(63);
        for dh in [1usize, 5, 8, 13, 32, 64] {
            for ctx in [1usize, 3, 9, 65] {
                let w: Vec<f32> = (0..ctx).map(|_| rng.normal_f32()).collect();
                let vstrip: Vec<f32> = (0..ctx * dh).map(|_| rng.normal_f32()).collect();
                let base: Vec<f32> = (0..dh).map(|_| rng.normal_f32()).collect();
                let mut out_s = base.clone();
                let mut out_d = base.clone();
                av_accumulate_scalar(&w, &vstrip, &mut out_s);
                av_accumulate(&w, &vstrip, &mut out_d);
                for (d, (a, b)) in out_s.iter().zip(&out_d).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "dh={dh} ctx={ctx} d={d}");
                }
                // definitional check: j-ascending axpy onto the base
                let mut expect = base.clone();
                for (j, &wj) in w.iter().enumerate() {
                    for d in 0..dh {
                        expect[d] += wj * vstrip[j * dh + d];
                    }
                }
                assert_eq!(out_s, expect, "dh={dh} ctx={ctx}");
            }
        }
    }

    #[test]
    fn empty_context_is_a_noop() {
        let q = [1.0f32; 8];
        let mut scores: [f32; 0] = [];
        qk_dots(&q, &[], 1.0, 0.0, 0, &mut scores);
        let mut out = [2.5f32; 8];
        av_accumulate(&[], &[], &mut out);
        assert!(out.iter().all(|&v| v == 2.5));
    }
}
