//! The **`Fast` numerics tier** — FMA kernels, a polynomial `exp`, and a
//! flash-style online-softmax attention row.
//!
//! [`super::simd`] pins every kernel to a bitwise scalar↔AVX2 contract:
//! no FMA, no reassociation, transcendentals on libm. That contract is
//! the right default (it is what lets runtime dispatch never change a
//! served token), but it caps the hot path. This module is the escape
//! hatch: an explicitly *relaxed* tier selected per call by
//! [`NumericsMode`], never silently.
//!
//! ## The relaxed contract
//!
//! `Fast` kernels do **not** promise bit-equality with their `Exact`
//! twins. They promise, and `tests/numerics_tolerance.rs` enforces:
//!
//! 1. **Bounded drift.** Every `Fast` kernel stays within a small
//!    relative tolerance of its `Exact` twin (FMA removes intermediate
//!    roundings; [`exp_fast`] carries ~2 ULP vs libm).
//! 2. **Determinism within the tier.** The scalar fallback uses
//!    [`f32::mul_add`] — the same correctly-rounded fused operation
//!    `_mm256_fmadd_ps` executes — with the identical pinned
//!    8-accumulator shape and tree reduction as the vector path, so
//!    scalar and AVX2+FMA `Fast` results are **bitwise identical to
//!    each other**. Greedy decode under `Fast` is therefore still
//!    machine-independent, and `tests/numerics_divergence.rs` can
//!    assert token divergence vs `Exact` is exactly zero.
//!
//! The payoff: fused multiply-adds in every dot/axpy, a vectorized
//! polynomial [`exp_fast`] (Cephes coefficients, Cody–Waite reduction)
//! replacing per-element libm calls in silu/gelu/softmax, and
//! [`attn_row_fast`] — one fused attention work item that blocks over
//! the K/V strips with a running max/denominator so scores never
//! materialize beyond a stack-resident [`ATTN_BLOCK`] buffer.
//!
//! Dispatch is probed once per process ([`fast_simd`]): AVX2 **and**
//! FMA must both be present for the vector path (every AVX2 server CPU
//! has FMA, but the probe keeps the fallback honest).

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Which numerics contract a forward pass runs under. Parallel to
/// [`super::SimdTier`] (instruction selection), but orthogonal to it:
/// the tier answers *how fast can this CPU go*, the mode answers *how
/// much numeric drift did the caller opt into*.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum NumericsMode {
    /// The bitwise contract of [`super::simd`]: scalar ≡ AVX2 on every
    /// input, parity suites assert `to_bits()` equality. Default
    /// everywhere.
    #[default]
    Exact,
    /// This module's relaxed contract: FMA + polynomial exp + online
    /// softmax, bounded drift vs `Exact`, deterministic within the
    /// tier. Opt-in via `--numerics fast`.
    Fast,
}

impl NumericsMode {
    /// Parse a CLI value ("exact" / "fast").
    pub fn parse(s: &str) -> Option<NumericsMode> {
        match s {
            "exact" => Some(NumericsMode::Exact),
            "fast" => Some(NumericsMode::Fast),
            _ => None,
        }
    }

    /// Human label for bench/metrics output ("exact" / "fast").
    pub fn label(self) -> &'static str {
        match self {
            NumericsMode::Exact => "exact",
            NumericsMode::Fast => "fast",
        }
    }
}

/// Whether the vector `Fast` path (AVX2 + FMA) is available, probed
/// once per process. When false the scalar [`f32::mul_add`] fallback
/// runs — bitwise identical to the vector path by construction.
pub fn fast_simd() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static FMA: once_cell::sync::Lazy<bool> = once_cell::sync::Lazy::new(|| {
            is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
        });
        if *FMA {
            return true;
        }
    }
    false
}

// -------------------------------------------------------------- dot/axpy

/// `Σ a[i]·b[i]` with fused multiply-adds. Same pinned 8-accumulator
/// lane mapping and tree reduction as [`super::simd::dot`], so the only
/// difference from `Exact` is the single rounding per FMA.
#[inline]
pub fn dot_fast(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if fast_simd() {
            // SAFETY: fast_simd() verified avx2+fma.
            return unsafe { dot_fma(a, b) };
        }
    }
    dot_fast_scalar(a, b)
}

/// Scalar twin of [`dot_fast`] — [`f32::mul_add`] per element, so it is
/// bitwise identical to the AVX2+FMA path (the `Fast`-tier determinism
/// reference, pinned by this module's tests).
#[inline]
pub fn dot_fast_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let (mut s4, mut s5, mut s6, mut s7) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let o = i * 8;
        s0 = a[o].mul_add(b[o], s0);
        s1 = a[o + 1].mul_add(b[o + 1], s1);
        s2 = a[o + 2].mul_add(b[o + 2], s2);
        s3 = a[o + 3].mul_add(b[o + 3], s3);
        s4 = a[o + 4].mul_add(b[o + 4], s4);
        s5 = a[o + 5].mul_add(b[o + 5], s5);
        s6 = a[o + 6].mul_add(b[o + 6], s6);
        s7 = a[o + 7].mul_add(b[o + 7], s7);
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..n {
        tail = a[i].mul_add(b[i], tail);
    }
    (s0 + s1) + (s2 + s3) + ((s4 + s5) + (s6 + s7)) + tail
}

/// Pinned-order horizontal sum — the same tree as
/// `simd::hsum_pinned` / the scalar reduction above.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
// SAFETY: `unsafe` only for #[target_feature]; pure register math plus an
// 8-lane stack spill. Caller ensures AVX2+FMA (`fast_simd()`).
unsafe fn hsum_pinned(v: __m256) -> f32 {
    let mut l = [0.0f32; 8];
    _mm256_storeu_ps(l.as_mut_ptr(), v);
    (l[0] + l[1]) + (l[2] + l[3]) + ((l[4] + l[5]) + (l[6] + l[7]))
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
// SAFETY: caller must ensure AVX2+FMA. All loads go through
// `as_ptr().add(o)` with `o + 8 <= len` by the chunk bound.
unsafe fn dot_fma(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc = _mm256_setzero_ps();
    for i in 0..chunks {
        let o = i * 8;
        acc = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(o)), _mm256_loadu_ps(bp.add(o)), acc);
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..n {
        tail = a[i].mul_add(b[i], tail);
    }
    hsum_pinned(acc) + tail
}

/// `acc[i] += s·v[i]` with one fused rounding per element. Lanes are
/// independent, so scalar mul_add and AVX2 fmadd agree bitwise.
#[inline]
pub fn axpy_fast(acc: &mut [f32], s: f32, v: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        if fast_simd() {
            // SAFETY: fast_simd() verified avx2+fma.
            unsafe { axpy_fma(acc, s, v) };
            return;
        }
    }
    axpy_fast_scalar(acc, s, v)
}

/// Scalar twin of [`axpy_fast`] (bitwise identical to the vector path).
#[inline]
pub fn axpy_fast_scalar(acc: &mut [f32], s: f32, v: &[f32]) {
    debug_assert_eq!(acc.len(), v.len());
    for (o, &vv) in acc.iter_mut().zip(v) {
        *o = s.mul_add(vv, *o);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
// SAFETY: caller must ensure AVX2+FMA. Loads/stores stay inside
// `acc`/`v`: `o + 8 <= len` per chunk, tail handled element-wise.
unsafe fn axpy_fma(acc: &mut [f32], s: f32, v: &[f32]) {
    debug_assert_eq!(acc.len(), v.len());
    let n = acc.len();
    let chunks = n / 8;
    let op = acc.as_mut_ptr();
    let vp = v.as_ptr();
    let sv = _mm256_set1_ps(s);
    for i in 0..chunks {
        let o = i * 8;
        let r = _mm256_fmadd_ps(sv, _mm256_loadu_ps(vp.add(o)), _mm256_loadu_ps(op.add(o)));
        _mm256_storeu_ps(op.add(o), r);
    }
    for i in chunks * 8..n {
        *op.add(i) = s.mul_add(*vp.add(i), *op.add(i));
    }
}

/// `Σ codes[i]·x[i]` (codes widened `u8 → f32` exactly) with FMA — the
/// `Fast` twin of `simd::code_dot_t`, same pinned shape.
#[inline]
pub(crate) fn code_dot_fast(codes: &[u8], x: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if fast_simd() {
            // SAFETY: fast_simd() verified avx2+fma.
            return unsafe { code_dot_fma(codes, x) };
        }
    }
    code_dot_fast_scalar(codes, x)
}

#[inline]
fn code_dot_fast_scalar(codes: &[u8], x: &[f32]) -> f32 {
    debug_assert_eq!(codes.len(), x.len());
    let n = x.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let (mut s4, mut s5, mut s6, mut s7) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let o = i * 8;
        s0 = (codes[o] as f32).mul_add(x[o], s0);
        s1 = (codes[o + 1] as f32).mul_add(x[o + 1], s1);
        s2 = (codes[o + 2] as f32).mul_add(x[o + 2], s2);
        s3 = (codes[o + 3] as f32).mul_add(x[o + 3], s3);
        s4 = (codes[o + 4] as f32).mul_add(x[o + 4], s4);
        s5 = (codes[o + 5] as f32).mul_add(x[o + 5], s5);
        s6 = (codes[o + 6] as f32).mul_add(x[o + 6], s6);
        s7 = (codes[o + 7] as f32).mul_add(x[o + 7], s7);
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..n {
        tail = (codes[i] as f32).mul_add(x[i], tail);
    }
    (s0 + s1) + (s2 + s3) + ((s4 + s5) + (s6 + s7)) + tail
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
// SAFETY: caller must ensure AVX2+FMA. 8-byte code loads and 8-lane
// f32 loads both satisfy `o + 8 <= len` by the chunk bound.
unsafe fn code_dot_fma(codes: &[u8], x: &[f32]) -> f32 {
    debug_assert_eq!(codes.len(), x.len());
    let n = x.len();
    let chunks = n / 8;
    let cp = codes.as_ptr();
    let xp = x.as_ptr();
    let mut acc = _mm256_setzero_ps();
    for i in 0..chunks {
        let o = i * 8;
        let cw = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_loadl_epi64(
            cp.add(o) as *const __m128i
        )));
        acc = _mm256_fmadd_ps(cw, _mm256_loadu_ps(xp.add(o)), acc);
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..n {
        tail = (codes[i] as f32).mul_add(x[i], tail);
    }
    hsum_pinned(acc) + tail
}

/// Pinned 8-accumulator sum (adds only). Deterministic everywhere —
/// used where the `Fast` tier needs a reassociation-friendly shape that
/// still reduces in one fixed order (softmax denominators).
#[inline]
pub(crate) fn sum_fast(xs: &[f32]) -> f32 {
    let n = xs.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let (mut s4, mut s5, mut s6, mut s7) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let o = i * 8;
        s0 += xs[o];
        s1 += xs[o + 1];
        s2 += xs[o + 2];
        s3 += xs[o + 3];
        s4 += xs[o + 4];
        s5 += xs[o + 5];
        s6 += xs[o + 6];
        s7 += xs[o + 7];
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..n {
        tail += xs[i];
    }
    (s0 + s1) + (s2 + s3) + ((s4 + s5) + (s6 + s7)) + tail
}

// ---------------------------------------------------------- fast exp

// Cephes expf: exp(x) = 2^k · exp(r), |r| ≤ ½ln2, with a degree-5
// minimax polynomial for exp(r) − 1 − r over the reduced range. The
// decimal forms below are the published Cephes coefficients; rustc
// rounds them to the nearest f32 (clippy's shortest-repr lint disagrees
// with the citation, hence the allow).
#[allow(clippy::excessive_precision)]
mod exp_consts {
    pub const P0: f32 = 1.9875691500e-4;
    pub const P1: f32 = 1.3981999507e-3;
    pub const P2: f32 = 8.3334519073e-3;
    pub const P3: f32 = 4.1665795894e-2;
    pub const P4: f32 = 1.6666665459e-1;
    pub const P5: f32 = 5.0000001201e-1;
    /// ln2 split hi+lo (Cody–Waite): `k·LN2_HI` is exact for |k| ≤ 127.
    pub const LN2_HI: f32 = 0.693_359_375;
    pub const LN2_LO: f32 = -2.121_944_4e-4;
    /// Clamp bounds: exp(−87) sits just above the smallest normal,
    /// and 88 keeps `k ≤ 127` so the exponent-bits scale stays finite.
    pub const LO: f32 = -87.0;
    pub const HI: f32 = 88.0;
}

/// Polynomial `exp` — ~2 ULP relative error vs libm, fully inlineable,
/// and lane-matched to the AVX2 path: `round_ties_even` mirrors
/// `_mm256_round_ps` (nearest), every fused step mirrors one `fmadd`,
/// so scalar and vector evaluations are bitwise identical per element.
#[inline]
pub fn exp_fast(x: f32) -> f32 {
    use exp_consts::*;
    let x = x.max(LO).min(HI);
    let k = (x * std::f32::consts::LOG2_E).round_ties_even();
    let nk = -k;
    let r = nk.mul_add(LN2_HI, x);
    let r = nk.mul_add(LN2_LO, r);
    let mut p = P0;
    p = p.mul_add(r, P1);
    p = p.mul_add(r, P2);
    p = p.mul_add(r, P3);
    p = p.mul_add(r, P4);
    p = p.mul_add(r, P5);
    let y = p.mul_add(r * r, r) + 1.0;
    // 2^k via exponent bits; k ∈ [−126, 127] after the clamp.
    let scale = f32::from_bits((((k as i32) + 127) << 23) as u32);
    y * scale
}

/// Eight [`exp_fast`] evaluations — identical operation sequence per
/// lane, so results match the scalar form bitwise.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
// SAFETY: `unsafe` only for #[target_feature]; register-only polynomial
// evaluation, no memory access. Caller ensures AVX2+FMA.
unsafe fn exp_fast8(x: __m256) -> __m256 {
    use exp_consts::*;
    let x = _mm256_min_ps(_mm256_max_ps(x, _mm256_set1_ps(LO)), _mm256_set1_ps(HI));
    let k = _mm256_round_ps::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(_mm256_mul_ps(
        x,
        _mm256_set1_ps(std::f32::consts::LOG2_E),
    ));
    let nk = _mm256_xor_ps(k, _mm256_set1_ps(-0.0)); // IEEE negate, like scalar `-k`
    let r = _mm256_fmadd_ps(nk, _mm256_set1_ps(LN2_HI), x);
    let r = _mm256_fmadd_ps(nk, _mm256_set1_ps(LN2_LO), r);
    let mut p = _mm256_set1_ps(P0);
    p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(P1));
    p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(P2));
    p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(P3));
    p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(P4));
    p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(P5));
    let y = _mm256_add_ps(
        _mm256_fmadd_ps(p, _mm256_mul_ps(r, r), r),
        _mm256_set1_ps(1.0),
    );
    let ki = _mm256_cvtps_epi32(k); // exact: k is integral after round
    let scale = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(
        ki,
        _mm256_set1_epi32(127),
    )));
    _mm256_mul_ps(y, scale)
}

/// `xs[i] = exp_fast(xs[i])` in place, 8 lanes at a time where the
/// vector path is up.
#[inline]
pub fn exp_map_fast(xs: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        if fast_simd() {
            // SAFETY: fast_simd() verified avx2+fma.
            unsafe { exp_map_fma(xs) };
            return;
        }
    }
    exp_map_fast_scalar(xs)
}

/// Scalar twin of [`exp_map_fast`]: the same polynomial per element, in
/// index order (the vector path evaluates identical lane math).
#[inline]
pub fn exp_map_fast_scalar(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = exp_fast(*v);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
// SAFETY: caller must ensure AVX2+FMA. In-place 8-lane loads/stores with
// `o + 8 <= len` per chunk; tail handled by the scalar polynomial.
unsafe fn exp_map_fma(xs: &mut [f32]) {
    let n = xs.len();
    let chunks = n / 8;
    let p = xs.as_mut_ptr();
    for i in 0..chunks {
        let o = i * 8;
        _mm256_storeu_ps(p.add(o), exp_fast8(_mm256_loadu_ps(p.add(o))));
    }
    for i in chunks * 8..n {
        *p.add(i) = exp_fast(*p.add(i));
    }
}

// ------------------------------------------------------- activations

/// `gate[i] = silu(gate[i])·up[i]` on the polynomial exp — the `Fast`
/// twin of `simd::silu_mul` (which pins both tiers to libm).
#[inline]
pub fn silu_mul_fast(gate: &mut [f32], up: &[f32]) {
    debug_assert_eq!(gate.len(), up.len());
    #[cfg(target_arch = "x86_64")]
    {
        if fast_simd() {
            // SAFETY: fast_simd() verified avx2+fma.
            unsafe { silu_mul_fma(gate, up) };
            return;
        }
    }
    silu_mul_fast_scalar(gate, up)
}

/// Scalar twin of [`silu_mul_fast`] (bitwise identical to the vector
/// path — each step below mirrors one intrinsic).
#[inline]
pub fn silu_mul_fast_scalar(gate: &mut [f32], up: &[f32]) {
    debug_assert_eq!(gate.len(), up.len());
    for (g, &u) in gate.iter_mut().zip(up) {
        let x = *g;
        let e = exp_fast(-x);
        *g = x / (1.0 + e) * u;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
// SAFETY: caller must ensure AVX2+FMA and equal lengths (the dispatcher
// asserts); `o + 8 <= len` bounds every load/store.
unsafe fn silu_mul_fma(gate: &mut [f32], up: &[f32]) {
    let n = gate.len();
    let chunks = n / 8;
    let gp = gate.as_mut_ptr();
    let up_ = up.as_ptr();
    let one = _mm256_set1_ps(1.0);
    let sign = _mm256_set1_ps(-0.0);
    for i in 0..chunks {
        let o = i * 8;
        let x = _mm256_loadu_ps(gp.add(o));
        let e = exp_fast8(_mm256_xor_ps(x, sign));
        let v = _mm256_mul_ps(
            _mm256_div_ps(x, _mm256_add_ps(one, e)),
            _mm256_loadu_ps(up_.add(o)),
        );
        _mm256_storeu_ps(gp.add(o), v);
    }
    for i in chunks * 8..n {
        let x = *gp.add(i);
        let e = exp_fast(-x);
        *gp.add(i) = x / (1.0 + e) * *up_.add(i);
    }
}

const GELU_C: f32 = 0.797_884_56; // sqrt(2/π), as in simd::gelu
const GELU_A: f32 = 0.044715;

/// tanh-GELU on the polynomial exp, one element:
/// `tanh(t) = 1 − 2/(exp(2t)+1)`. Operation order mirrors the vector
/// path exactly.
#[inline]
pub fn gelu_fast(x: f32) -> f32 {
    let x3 = x * x * x;
    let t = GELU_A.mul_add(x3, x) * GELU_C;
    let e = exp_fast(t + t);
    let th = 1.0 - 2.0 / (e + 1.0);
    0.5 * (x * (1.0 + th))
}

/// `x[i] = gelu(x[i])` in place on the polynomial exp — the `Fast`
/// twin of `simd::gelu_map`.
#[inline]
pub fn gelu_map_fast(x: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        if fast_simd() {
            // SAFETY: fast_simd() verified avx2+fma.
            unsafe { gelu_map_fma(x) };
            return;
        }
    }
    gelu_map_fast_scalar(x)
}

/// Scalar twin of [`gelu_map_fast`]: [`gelu_fast`] per element, in index
/// order (the vector path evaluates identical lane math).
#[inline]
pub fn gelu_map_fast_scalar(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = gelu_fast(*v);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
// SAFETY: caller must ensure AVX2+FMA. In-place 8-lane loads/stores with
// `o + 8 <= len` per chunk; tail handled by the scalar polynomial.
unsafe fn gelu_map_fma(xs: &mut [f32]) {
    let n = xs.len();
    let chunks = n / 8;
    let p = xs.as_mut_ptr();
    let one = _mm256_set1_ps(1.0);
    let two = _mm256_set1_ps(2.0);
    let half = _mm256_set1_ps(0.5);
    let a = _mm256_set1_ps(GELU_A);
    let c = _mm256_set1_ps(GELU_C);
    for i in 0..chunks {
        let o = i * 8;
        let x = _mm256_loadu_ps(p.add(o));
        let x3 = _mm256_mul_ps(_mm256_mul_ps(x, x), x);
        let t = _mm256_mul_ps(_mm256_fmadd_ps(a, x3, x), c);
        let e = exp_fast8(_mm256_add_ps(t, t));
        let th = _mm256_sub_ps(one, _mm256_div_ps(two, _mm256_add_ps(e, one)));
        let v = _mm256_mul_ps(half, _mm256_mul_ps(x, _mm256_add_ps(one, th)));
        _mm256_storeu_ps(p.add(o), v);
    }
    for i in chunks * 8..n {
        *p.add(i) = gelu_fast(*p.add(i));
    }
}

/// In-place softmax on the polynomial exp: max-subtract, [`exp_map_fast`],
/// pinned-order sum, scale. The `Fast` twin of
/// `model::forward::softmax` (which stays the `Exact` reference).
#[inline]
pub fn softmax_fast(row: &mut [f32]) {
    if row.is_empty() {
        return;
    }
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    for v in row.iter_mut() {
        *v -= max;
    }
    exp_map_fast(row);
    let inv = 1.0 / sum_fast(row);
    for v in row.iter_mut() {
        *v *= inv;
    }
}

// ------------------------------------- fused online-softmax attention

/// Positions per online-softmax block: the score buffer lives on the
/// stack and one block of K rows (`128 × head_dim` floats) stays
/// L1/L2-resident while both passes (max + exp/accumulate) run over it.
pub const ATTN_BLOCK: usize = 128;

/// One fused attention work item — the `Fast` tier's replacement for
/// the `qk_dots → softmax → av_accumulate` pipeline of
/// [`super::attn`].
///
/// Flash-attention style over the head-major strips: K/V are walked in
/// [`ATTN_BLOCK`]-position blocks with a running max `m` and
/// denominator `l`; scores for a block live in a stack buffer and are
/// folded into `out` before the next block streams in, so per-position
/// scores never materialize. Per block:
///
/// 1. `s[j] = fma(dot_fast(q, k_j), scale, slope·(j − pos))`,
/// 2. rescale the running state by `exp(m − m_new)` (0 when `m` is
///    still −∞ — [`exp_fast`] clamps and would return a denormal-range
///    value, not 0, so the first block is special-cased),
/// 3. `p_j = exp_fast(s_j − m_new)`; `l += Σ p_j`;
///    `out += p_j · v_j` via [`axpy_fast`].
///
/// Finally `out *= 1/l`. `out` is overwritten (no caller zeroing).
/// Every primitive underneath is deterministic across the `Fast`
/// scalar/vector paths, so the whole row is too.
pub fn attn_row_fast(
    q: &[f32],
    kstrip: &[f32],
    vstrip: &[f32],
    scale: f32,
    slope: f32,
    pos: usize,
    out: &mut [f32],
) {
    let dh = q.len();
    debug_assert_eq!(out.len(), dh);
    debug_assert_eq!(kstrip.len(), vstrip.len());
    debug_assert_eq!(kstrip.len() % dh.max(1), 0);
    let ctx = kstrip.len() / dh.max(1);
    out.fill(0.0);
    if ctx == 0 {
        return;
    }
    let posf = pos as f32;
    let mut m = f32::NEG_INFINITY;
    let mut l = 0.0f32;
    let mut sbuf = [0.0f32; ATTN_BLOCK];
    let mut b0 = 0;
    while b0 < ctx {
        let bn = (ctx - b0).min(ATTN_BLOCK);
        let s = &mut sbuf[..bn];
        let mut bmax = f32::NEG_INFINITY;
        for (j, sj) in s.iter_mut().enumerate() {
            let at = b0 + j;
            let krow = &kstrip[at * dh..(at + 1) * dh];
            let v = dot_fast(q, krow).mul_add(scale, slope * (at as f32 - posf));
            *sj = v;
            bmax = bmax.max(v);
        }
        let m_new = m.max(bmax);
        // rescale previous blocks' contribution into the new frame
        let c = if m > f32::NEG_INFINITY {
            exp_fast(m - m_new)
        } else {
            0.0
        };
        if c != 1.0 {
            l *= c;
            for o in out.iter_mut() {
                *o *= c;
            }
        }
        for sj in s.iter_mut() {
            *sj -= m_new;
        }
        exp_map_fast(s);
        l += sum_fast(s);
        for (j, &p) in s.iter().enumerate() {
            let at = b0 + j;
            axpy_fast(out, p, &vstrip[at * dh..(at + 1) * dh]);
        }
        m = m_new;
        b0 += bn;
    }
    let inv = 1.0 / l;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{attn, simd};
    use crate::util::Rng;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn mode_parses_and_labels() {
        assert_eq!(NumericsMode::parse("exact"), Some(NumericsMode::Exact));
        assert_eq!(NumericsMode::parse("fast"), Some(NumericsMode::Fast));
        assert_eq!(NumericsMode::parse("warp"), None);
        assert_eq!(NumericsMode::default(), NumericsMode::Exact);
        assert_eq!(NumericsMode::Fast.label(), "fast");
    }

    #[test]
    fn exp_fast_tracks_libm_closely() {
        let mut rng = Rng::new(71);
        for _ in 0..2000 {
            let x = rng.normal_f32() * 8.0;
            let want = (x as f64).exp();
            let got = exp_fast(x) as f64;
            assert!(
                ((got - want) / want).abs() < 1e-5,
                "x={x} got={got} want={want}"
            );
        }
        // edges: clamps stay finite and positive
        assert!(exp_fast(-1e30) > 0.0);
        assert!(exp_fast(1e30).is_finite());
        assert_eq!(exp_fast(0.0), 1.0);
    }

    #[test]
    fn exp_map_matches_scalar_exp_bitwise() {
        // vector lanes must reproduce the scalar evaluation exactly —
        // the determinism half of the Fast contract
        let mut rng = Rng::new(72);
        for n in [1usize, 7, 8, 9, 64, 131] {
            let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 10.0).collect();
            let mut mapped = xs.clone();
            exp_map_fast(&mut mapped);
            for (i, (&x, &y)) in xs.iter().zip(&mapped).enumerate() {
                assert_eq!(exp_fast(x).to_bits(), y.to_bits(), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn fast_dot_and_axpy_match_scalar_twins_bitwise() {
        let mut rng = Rng::new(73);
        for n in [0usize, 1, 7, 8, 9, 33, 257, 1031] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            assert_eq!(
                dot_fast(&a, &b).to_bits(),
                dot_fast_scalar(&a, &b).to_bits(),
                "dot n={n}"
            );
            let s = rng.normal_f32();
            let base: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let mut y_v = base.clone();
            let mut y_s = base.clone();
            axpy_fast(&mut y_v, s, &a);
            axpy_fast_scalar(&mut y_s, s, &a);
            for (u, v) in y_s.iter().zip(&y_v) {
                assert_eq!(u.to_bits(), v.to_bits(), "axpy n={n}");
            }
        }
    }

    #[test]
    fn fast_dot_stays_close_to_exact_dot() {
        let mut rng = Rng::new(74);
        for n in [1usize, 9, 128, 1031] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let exact = simd::dot_scalar(&a, &b);
            let fast = dot_fast(&a, &b);
            assert!(close(exact, fast, 1e-5), "n={n} exact={exact} fast={fast}");
        }
    }

    #[test]
    fn code_dot_fast_stays_close_to_exact() {
        let mut rng = Rng::new(75);
        for n in [1usize, 8, 77, 1031] {
            let codes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let exact = simd::code_dot_t(&codes, &x, simd::SimdTier::Scalar);
            let fast = code_dot_fast(&codes, &x);
            // code magnitudes reach 255, so compare relative to the
            // accumulated magnitude rather than 1.0
            let mag = codes
                .iter()
                .zip(&x)
                .map(|(&c, &v)| (c as f32 * v).abs())
                .sum::<f32>();
            assert!(
                (exact - fast).abs() <= 1e-5 * (1.0 + mag),
                "n={n} exact={exact} fast={fast}"
            );
        }
    }

    #[test]
    fn activations_track_exact_forms() {
        let mut rng = Rng::new(76);
        for n in [1usize, 8, 13, 131] {
            let base: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 3.0).collect();
            let up: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let mut g_fast = base.clone();
            silu_mul_fast(&mut g_fast, &up);
            for i in 0..n {
                let want = simd::silu(base[i]) * up[i];
                assert!(close(want, g_fast[i], 1e-5), "silu n={n} i={i}");
            }
            let mut x_fast = base.clone();
            gelu_map_fast(&mut x_fast);
            for i in 0..n {
                let want = simd::gelu(base[i]);
                assert!(close(want, x_fast[i], 1e-4), "gelu n={n} i={i}");
            }
        }
    }

    #[test]
    fn softmax_fast_normalizes_and_tracks_exact() {
        let mut rng = Rng::new(77);
        for n in [1usize, 2, 9, 64, 300] {
            let base: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 5.0).collect();
            let mut fast = base.clone();
            softmax_fast(&mut fast);
            let sum: f32 = fast.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "n={n} sum={sum}");
            // exact reference: libm exp, sequential normalize
            let max = base.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = base.iter().map(|&v| (v - max).exp()).collect();
            let denom: f32 = exps.iter().sum();
            for i in 0..n {
                assert!(close(exps[i] / denom, fast[i], 1e-4), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn attn_row_fast_matches_exact_pipeline_within_tolerance() {
        let mut rng = Rng::new(78);
        for dh in [1usize, 8, 24, 64] {
            // 300 crosses two ATTN_BLOCK boundaries → exercises rescale
            for ctx in [1usize, 2, 17, 128, 129, 300] {
                let q: Vec<f32> = (0..dh).map(|_| rng.normal_f32()).collect();
                let kstrip: Vec<f32> = (0..ctx * dh).map(|_| rng.normal_f32()).collect();
                let vstrip: Vec<f32> = (0..ctx * dh).map(|_| rng.normal_f32()).collect();
                let scale = 1.0 / (dh as f32).sqrt();
                for slope in [0.0f32, -0.125] {
                    // exact pipeline: scores → libm softmax → weighted V
                    let mut scores = vec![0.0f32; ctx];
                    attn::qk_dots_scalar(&q, &kstrip, scale, slope, ctx - 1, &mut scores);
                    let max = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    let mut sum = 0.0f32;
                    for s in scores.iter_mut() {
                        *s = (*s - max).exp();
                        sum += *s;
                    }
                    for s in scores.iter_mut() {
                        *s /= sum;
                    }
                    let mut want = vec![0.0f32; dh];
                    attn::av_accumulate_scalar(&scores, &vstrip, &mut want);

                    let mut got = vec![0.0f32; dh];
                    attn_row_fast(&q, &kstrip, &vstrip, scale, slope, ctx - 1, &mut got);
                    for d in 0..dh {
                        assert!(
                            close(want[d], got[d], 2e-4),
                            "dh={dh} ctx={ctx} slope={slope} d={d}: {} vs {}",
                            want[d],
                            got[d]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn attn_row_fast_empty_context_zeroes_out() {
        let q = [1.0f32; 8];
        let mut out = [2.5f32; 8];
        attn_row_fast(&q, &[], &[], 1.0, 0.0, 0, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }
}
