//! LUT-GEMM binary-coding matvec — the GPTQT inference path (paper §II-D
//! and [13], Park et al.).
//!
//! For a fused binary-coded row `W[r,c] = Σ_p α[r,p]·b[r,p,c] + β[r]`
//! (`b ∈ {±1}`):
//!
//! ```text
//! y[r] = Σ_p α[r,p]·(Σ_c b[r,p,c]·x_c) + β[r]·Σ_c x_c
//! ```
//!
//! The inner signed sums share massive structure across rows and planes:
//! within a group of 8 columns only 256 sign patterns exist, so one
//! 256-entry table of partial sums (`lut[pattern] = Σ_k ±x[8g+k]`) built
//! per group in 256 adds serves every (row, plane) via a single byte
//! lookup. That is LUT-GEMM's shared-memory table, landed in L1 cache:
//!
//! * ops: `cols/8 · (256 + rows·planes)` adds  vs  `rows·cols` mul-adds,
//! * bytes: `rows·cols·planes/8`  vs  `4·rows·cols` — the ~10× traffic
//!   cut that wins the bandwidth-bound decode regime.
//!
//! The LUT is built by gray-code-free DP: `lut[p] = lut[p \ lowbit] +
//! 2·x[lowbit]`, starting from `lut[0] = −Σ_k x_k`.
//!
//! The per-slot accumulation runs at the dispatched SIMD tier
//! ([`crate::kernels::simd::lut_accumulate`]): AVX2 processes 8
//! `(row, plane)` slots per step, gathering 8 byte-codes per L1-resident
//! table (`vpgatherdps`) and adding tables in ascending group order —
//! the same per-slot add order as the scalar tier, so scalar and SIMD
//! results are bitwise identical (gathers are exact loads).

use super::simd::{self, SimdTier};
use super::NumericsMode;
use crate::quant::pack::{PackedBcLayer, GROUP};

/// Groups processed per accumulator pass. The `(rows × planes)` f32
/// accumulator array is the dominant memory stream (it is re-walked per
/// group); blocking GBLOCK groups per pass cuts that traffic GBLOCK× at
/// the cost of GBLOCK L1-resident LUTs (8 KiB) — see EXPERIMENTS.md §Perf.
const GBLOCK: usize = 8;

/// `y = Ŵ·x` over the packed binary-coded layer.
pub fn gemv_lut(layer: &PackedBcLayer, x: &[f32], y: &mut [f32]) {
    gemv_lut_t(layer, x, y, simd::tier());
}

/// [`gemv_lut`] forced onto the scalar tier — the reference the SIMD
/// path must match bitwise (`tests/simd_parity.rs`).
pub fn gemv_lut_scalar(layer: &PackedBcLayer, x: &[f32], y: &mut [f32]) {
    gemv_lut_t(layer, x, y, SimdTier::Scalar);
}

fn gemv_lut_t(layer: &PackedBcLayer, x: &[f32], y: &mut [f32], t: SimdTier) {
    assert_eq!(x.len(), layer.cols);
    assert_eq!(y.len(), layer.rows);
    let rows = layer.rows;
    let planes = layer.planes;
    let sum_x = super::sum_seq(x);

    // signed-sum accumulators per (row, plane)
    // lint:allow(hot-path-no-alloc) one plane-accumulator strip per gemv
    // call; steady-state pinned by tests/alloc_steady.rs.
    let mut acc = vec![0.0f32; rows * planes];
    let mut luts = [[0.0f32; 1 << GROUP]; GBLOCK];
    let slots = rows * planes;

    for gb in (0..layer.groups).step_by(GBLOCK) {
        let gn = GBLOCK.min(layer.groups - gb);
        for (g, lut) in luts.iter_mut().enumerate().take(gn) {
            let base = (gb + g) * GROUP;
            // group activations (zero-padded tail)
            let mut xg = [0.0f32; GROUP];
            for k in 0..GROUP.min(layer.cols - base) {
                xg[k] = x[base + k];
            }
            build_lut(&xg, lut);
        }
        let codes = &layer.codes[gb * slots..(gb + gn) * slots];
        let mut slices: [&[u8]; GBLOCK] = [&[]; GBLOCK];
        for (g, sl) in slices.iter_mut().enumerate().take(gn) {
            *sl = &codes[g * slots..(g + 1) * slots];
        }
        simd::lut_accumulate(&mut acc, &slices[..gn], &luts[..gn], t);
    }

    for r in 0..rows {
        let mut v = layer.bias[r] * sum_x;
        let arow = &layer.alphas[r * planes..(r + 1) * planes];
        let crow = &acc[r * planes..(r + 1) * planes];
        for (a, s) in arow.iter().zip(crow) {
            v += a * s;
        }
        y[r] = v;
    }
}

/// `y = Ŵ·x` on the `Fast` numerics tier. The LUT build and per-slot
/// gather-adds are *shared* with [`gemv_lut`] — they are add-only, so
/// FMA has nothing to fuse and the bitwise cross-tier accumulation is
/// already optimal — only the α-epilogue fuses its multiply-adds
/// (`v = fma(α_p, acc_p, v)`). Deterministic across instruction tiers
/// for the same reason the `Exact` kernel is.
// lint:allow(scalar-twin) tier() only steers the add-only shared LUT
// accumulate (bitwise across tiers); the Fast-vs-Exact budget is pinned
// by tests/numerics_tolerance.rs through Gemv::gemv_mode.
pub fn gemv_lut_fast(layer: &PackedBcLayer, x: &[f32], y: &mut [f32]) {
    let t = simd::tier();
    assert_eq!(x.len(), layer.cols);
    assert_eq!(y.len(), layer.rows);
    let rows = layer.rows;
    let planes = layer.planes;
    let sum_x = super::sum_seq(x);

    // lint:allow(hot-path-no-alloc) one plane-accumulator strip per gemv
    // call; steady-state pinned by tests/alloc_steady.rs.
    let mut acc = vec![0.0f32; rows * planes];
    let mut luts = [[0.0f32; 1 << GROUP]; GBLOCK];
    let slots = rows * planes;

    for gb in (0..layer.groups).step_by(GBLOCK) {
        let gn = GBLOCK.min(layer.groups - gb);
        for (g, lut) in luts.iter_mut().enumerate().take(gn) {
            let base = (gb + g) * GROUP;
            let mut xg = [0.0f32; GROUP];
            for k in 0..GROUP.min(layer.cols - base) {
                xg[k] = x[base + k];
            }
            build_lut(&xg, lut);
        }
        let codes = &layer.codes[gb * slots..(gb + gn) * slots];
        let mut slices: [&[u8]; GBLOCK] = [&[]; GBLOCK];
        for (g, sl) in slices.iter_mut().enumerate().take(gn) {
            *sl = &codes[g * slots..(g + 1) * slots];
        }
        simd::lut_accumulate(&mut acc, &slices[..gn], &luts[..gn], t);
    }

    for r in 0..rows {
        let mut v = layer.bias[r] * sum_x;
        let arow = &layer.alphas[r * planes..(r + 1) * planes];
        let crow = &acc[r * planes..(r + 1) * planes];
        for (a, s) in arow.iter().zip(crow) {
            // lint:allow(exact-tier-purity) Fast-tier α-epilogue FMA.
            v = a.mul_add(*s, v);
        }
        y[r] = v;
    }
}

/// Batched `ys[b] = Ŵ·xs[b]` — the LUT-GEMM path with weight reuse.
///
/// The per-group 256-entry LUTs are built once per batch item (that cost
/// scales with B, as in B gemvs), but the packed sign bytes — the
/// dominant memory stream, `rows·planes` bytes per group — are walked
/// **once per group block for the whole batch**: every code byte is
/// looked up in all B tables while it is register/L1-hot, 8 slots per
/// SIMD step on the AVX2 tier. Per-token weight traffic is
/// `packed_bytes() / B`.
///
/// Per batch item the accumulation order is identical to [`gemv_lut`]
/// (groups added in ascending order onto the same `(row, plane)`
/// accumulator, same epilogue), so batched results are bit-identical to
/// sequential ones. Calls with enough total work split rows across the
/// pool: each worker re-runs the group loop over its own row range with
/// private LUTs and accumulators, so the per-element order — and with it
/// the bitwise contract — is untouched (LUT builds are duplicated per
/// worker; they are a small, row-count-independent cost). The partition
/// is aligned to [`simd::BLOCK`] rows so every worker's slot range is a
/// whole number of SIMD blocks (scalar tails only in the last chunk).
pub fn gemm_lut(layer: &PackedBcLayer, xs: &[&[f32]], ys: &mut [Vec<f32>]) {
    gemm_lut_m(layer, xs, ys, simd::tier(), NumericsMode::Exact);
}

/// [`gemm_lut`] forced onto the scalar tier (bench/test reference).
pub fn gemm_lut_scalar(layer: &PackedBcLayer, xs: &[&[f32]], ys: &mut [Vec<f32>]) {
    gemm_lut_m(layer, xs, ys, SimdTier::Scalar, NumericsMode::Exact);
}

/// Batched LUT matvec on the `Fast` numerics tier — identical
/// accumulation to [`gemm_lut`] (see [`gemv_lut_fast`] for why the
/// gather-adds are shared), fused α-epilogue per output element, so
/// `gemm_lut_fast(B=1) == gemv_lut_fast` per element.
// lint:allow(scalar-twin) Fast gemm wrapper: its reference is the Exact
// gemm (bitwise), and Fast-vs-Exact closeness is pinned per kernel by
// tests/numerics_tolerance.rs through Gemv::gemm_mode.
pub fn gemm_lut_fast(layer: &PackedBcLayer, xs: &[&[f32]], ys: &mut [Vec<f32>]) {
    gemm_lut_m(layer, xs, ys, simd::tier(), NumericsMode::Fast);
}

fn gemm_lut_m(
    layer: &PackedBcLayer,
    xs: &[&[f32]],
    ys: &mut [Vec<f32>],
    t: SimdTier,
    mode: NumericsMode,
) {
    let nb = xs.len();
    assert_eq!(nb, ys.len(), "gemm_lut batch size mismatch");
    for x in xs {
        assert_eq!(x.len(), layer.cols);
    }
    for y in ys.iter() {
        assert_eq!(y.len(), layer.rows);
    }
    if nb == 0 {
        return;
    }
    // lint:allow(hot-path-no-alloc) one O(batch) epilogue table per gemm
    // call; steady-state flatness is pinned by tests/alloc_steady.rs.
    let sum_x: Vec<f32> = xs.iter().map(|x| super::sum_seq(x)).collect();
    let writer = super::RowWriter::new(ys);
    if super::par_rows(layer.rows, layer.cols, nb) {
        crate::util::pool::global().scope_chunks_aligned(layer.rows, simd::BLOCK, |range| {
            gemm_lut_rows(layer, xs, &sum_x, range.start, range.end, &writer, t, mode);
        });
    } else {
        gemm_lut_rows(layer, xs, &sum_x, 0, layer.rows, &writer, t, mode);
    }
}

/// The gemm body restricted to output rows `[rows_lo, rows_hi)` — the
/// unit one pool worker executes. Accumulation per (row, plane) slot
/// still walks groups in ascending order, matching [`gemv_lut`] exactly.
#[allow(clippy::too_many_arguments)]
fn gemm_lut_rows(
    layer: &PackedBcLayer,
    xs: &[&[f32]],
    sum_x: &[f32],
    rows_lo: usize,
    rows_hi: usize,
    writer: &super::RowWriter,
    t: SimdTier,
    mode: NumericsMode,
) {
    let nb = xs.len();
    let rows = layer.rows;
    let planes = layer.planes;
    let nrows = rows_hi - rows_lo;
    // per-item (row, plane) accumulators for this row range, batch-major
    let lslots = nrows * planes;
    // lint:allow(hot-path-no-alloc) per-worker accumulator + LUT scratch,
    // one allocation per gemm call (tests/alloc_steady.rs pins flatness).
    let mut acc = vec![0.0f32; nb * lslots];
    // per-item LUTs for the current group block, index `bi·GBLOCK + g`
    // lint:allow(hot-path-no-alloc) see `acc` above.
    let mut luts = vec![[0.0f32; 1 << GROUP]; nb * GBLOCK];

    for gb in (0..layer.groups).step_by(GBLOCK) {
        let gn = GBLOCK.min(layer.groups - gb);
        for (bi, x) in xs.iter().enumerate() {
            for g in 0..gn {
                let base = (gb + g) * GROUP;
                let take = GROUP.min(layer.cols - base);
                let mut xg = [0.0f32; GROUP];
                xg[..take].copy_from_slice(&x[base..base + take]);
                build_lut(&xg, &mut luts[bi * GBLOCK + g]);
            }
        }
        // this group block's code bytes restricted to our row range
        let mut slices: [&[u8]; GBLOCK] = [&[]; GBLOCK];
        for (g, sl) in slices.iter_mut().enumerate().take(gn) {
            *sl = &layer.codes
                [((gb + g) * rows + rows_lo) * planes..((gb + g) * rows + rows_hi) * planes];
        }
        for bi in 0..nb {
            let lut_b = &luts[bi * GBLOCK..bi * GBLOCK + gn];
            let arow = &mut acc[bi * lslots..(bi + 1) * lslots];
            simd::lut_accumulate(arow, &slices[..gn], lut_b, t);
        }
    }

    for bi in 0..nb {
        let acc_b = &acc[bi * lslots..(bi + 1) * lslots];
        for r in rows_lo..rows_hi {
            let mut v = layer.bias[r] * sum_x[bi];
            let arow = &layer.alphas[r * planes..(r + 1) * planes];
            let crow = &acc_b[(r - rows_lo) * planes..(r - rows_lo + 1) * planes];
            match mode {
                NumericsMode::Exact => {
                    for (a, s) in arow.iter().zip(crow) {
                        v += a * s;
                    }
                }
                NumericsMode::Fast => {
                    for (a, s) in arow.iter().zip(crow) {
                        // lint:allow(exact-tier-purity) Fast-tier FMA arm.
                        v = a.mul_add(*s, v);
                    }
                }
            }
            // SAFETY: each row lands in exactly one worker's range.
            unsafe { writer.set(bi, r, v) };
        }
    }
}

/// Fill `lut[pattern] = Σ_k sign_k(pattern)·xg[k]` for all 256 patterns
/// in 256 adds (DP over the lowest set bit).
#[inline]
pub fn build_lut(xg: &[f32; GROUP], lut: &mut [f32; 1 << GROUP]) {
    let mut neg = 0.0f32;
    for &v in xg.iter() {
        neg -= v;
    }
    lut[0] = neg;
    for p in 1usize..(1 << GROUP) {
        let low = p.trailing_zeros() as usize;
        lut[p] = lut[p & (p - 1)] + 2.0 * xg[low];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemv_f32;
    use crate::quant::pack::PackedBcLayer;
    use crate::util::Rng;

    fn random_packed(rows: usize, cols: usize, planes: usize, seed: u64) -> PackedBcLayer {
        PackedBcLayer::random(rows, cols, planes, seed)
    }

    #[test]
    fn lut_dp_matches_bruteforce() {
        let mut rng = Rng::new(321);
        let mut xg = [0.0f32; GROUP];
        for v in xg.iter_mut() {
            *v = rng.normal_f32();
        }
        let mut lut = [0.0f32; 256];
        build_lut(&xg, &mut lut);
        for p in 0..256usize {
            let mut expect = 0.0f32;
            for (k, &v) in xg.iter().enumerate() {
                expect += if p >> k & 1 == 1 { v } else { -v };
            }
            assert!((lut[p] - expect).abs() < 1e-4, "pattern {p}: {} vs {expect}", lut[p]);
        }
    }

    #[test]
    fn matches_dense_on_dequantized_weights() {
        let mut rng = Rng::new(322);
        for (rows, cols, planes) in [(4, 8, 2), (16, 40, 3), (64, 130, 3), (32, 256, 2)] {
            let layer = random_packed(rows, cols, planes, rows as u64 * 1000 + cols as u64);
            let dense = layer.dequant();
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
            let mut y = vec![0.0; rows];
            gemv_lut(&layer, &x, &mut y);
            let mut y_ref = vec![0.0; rows];
            gemv_f32(&dense, &x, &mut y_ref);
            for (r, (a, b)) in y.iter().zip(&y_ref).enumerate() {
                let tol = 2e-4 * (cols as f32).sqrt() * (1.0 + b.abs());
                assert!(
                    (a - b).abs() < tol,
                    "({rows}x{cols}x{planes}) row {r}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn gemm_is_bitwise_identical_to_gemv() {
        let mut rng = Rng::new(325);
        // 130 cols exercises both a ragged final group and a partial
        // GBLOCK tail (17 groups = 2 blocks of 8 + 1)
        for (rows, cols, planes) in [(16, 40, 3), (8, 130, 2)] {
            let layer = random_packed(rows, cols, planes, 77 + rows as u64);
            let xs: Vec<Vec<f32>> = (0..5)
                .map(|_| (0..cols).map(|_| rng.normal_f32()).collect())
                .collect();
            let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let mut ys: Vec<Vec<f32>> = (0..5).map(|_| vec![0.0; rows]).collect();
            gemm_lut(&layer, &refs, &mut ys);
            for (x, y) in xs.iter().zip(&ys) {
                let mut y_ref = vec![0.0; rows];
                gemv_lut(&layer, x, &mut y_ref);
                assert_eq!(y, &y_ref);
            }
        }
    }

    #[test]
    fn scalar_tier_is_bitwise_identical_to_dispatch() {
        let mut rng = Rng::new(326);
        // rows·planes not a multiple of the SIMD block, ragged cols
        for (rows, cols, planes) in [(5, 13, 3), (33, 130, 2)] {
            let layer = random_packed(rows, cols, planes, 400 + cols as u64);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
            let mut y_s = vec![0.0; rows];
            let mut y_d = vec![0.0; rows];
            gemv_lut_scalar(&layer, &x, &mut y_s);
            gemv_lut(&layer, &x, &mut y_d);
            assert_eq!(y_s, y_d, "gemv scalar vs dispatched ({rows}x{cols})");
            let xs: Vec<Vec<f32>> = (0..3)
                .map(|_| (0..cols).map(|_| rng.normal_f32()).collect())
                .collect();
            let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let mut ys_s: Vec<Vec<f32>> = (0..3).map(|_| vec![0.0; rows]).collect();
            let mut ys_d = ys_s.clone();
            gemm_lut_scalar(&layer, &refs, &mut ys_s);
            gemm_lut(&layer, &refs, &mut ys_d);
            assert_eq!(ys_s, ys_d, "gemm scalar vs dispatched ({rows}x{cols})");
        }
    }

    #[test]
    fn ragged_tail_columns_are_correct() {
        // cols not a multiple of 8 exercises the zero-padded group
        let layer = random_packed(8, 13, 2, 99);
        let dense = layer.dequant();
        let mut rng = Rng::new(323);
        let x: Vec<f32> = (0..13).map(|_| rng.normal_f32()).collect();
        let mut y = vec![0.0; 8];
        gemv_lut(&layer, &x, &mut y);
        let y_ref = {
            let mut t = vec![0.0; 8];
            gemv_f32(&dense, &x, &mut t);
            t
        };
        for (a, b) in y.iter().zip(&y_ref) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn gptqt_pipeline_layer_runs_through_lut() {
        // full integration: quantize a layer with GPTQT, gemv via LUT,
        // compare against dense gemv on the dequantized weights
        use crate::quant::{quantize_layer, Method, QuantConfig};
        use crate::tensor::Tensor;
        let mut rng = Rng::new(324);
        let d = 64;
        let w = Tensor::randn(16, d, 1.0, &mut rng);
        let acts = Tensor::randn(128, d, 1.0, &mut rng);
        let h = crate::quant::gptq::accumulate_hessian(&acts);
        let cfg = QuantConfig { explore_grid: 4, ..QuantConfig::with_bits(3) };
        let q = quantize_layer(&w, &h, Method::Gptqt, &cfg).unwrap();
        let packed = q.packed.unwrap();
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut y = vec![0.0; 16];
        gemv_lut(&packed, &x, &mut y);
        let mut y_ref = vec![0.0; 16];
        gemv_f32(&q.dequant, &x, &mut y_ref);
        for (a, b) in y.iter().zip(&y_ref) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }
}
