//! Runtime-dispatched SIMD inner loops for the serving kernels.
//!
//! Every hot accumulation in [`crate::kernels`] funnels through one of
//! the primitives here: the f32 dot ([`dot`]), the integer-code dot of
//! the dequant path (`code_dot_t`), the byte-code widening used by the
//! batched dequant gemm (`widen_codes`), and the LUT gather-accumulate
//! of the binary-coding path (`lut_accumulate`). Each primitive has a
//! portable scalar tier and an explicit AVX2 tier selected once per
//! process via `is_x86_feature_detected!` (no compile-time feature
//! flags needed — `RUSTFLAGS=-C target-feature=+avx2` merely lets the
//! compiler assume what the dispatcher would have detected anyway).
//!
//! ## The bitwise parity contract
//!
//! The engine's batched == sequential token guarantee rests on `gemv ==
//! gemm(B=1)` being *bitwise*. The SIMD tiers extend that contract one
//! axis further: **scalar and AVX2 produce bit-identical results on
//! every input**, so runtime dispatch can never change a served token.
//! Three rules make this possible:
//!
//! 1. **Pinned lane → accumulator mapping.** The scalar tiers keep 8
//!    independent accumulators where accumulator `k` owns indices
//!    `8·i + k`; the AVX2 tiers put accumulator `k` in vector lane `k`.
//!    Identical operand sequence per accumulator ⇒ identical rounding.
//! 2. **Pinned tree reduction.** Horizontal sums always reduce as
//!    `(l0+l1) + (l2+l3) + ((l4+l5) + (l6+l7)) + tail` — the same
//!    expression in both tiers.
//! 3. **No FMA.** `_mm256_fmadd_ps` rounds once where `mul` + `add`
//!    round twice, which would break rule 1. In this bandwidth-bound
//!    regime the fused multiply buys nothing the wider registers did
//!    not already, so every tier multiplies then adds. (Decision pinned
//!    per kernel by `tests/simd_parity.rs`.)
//!
//! Conversions (`u8 → f32`) and LUT gathers are exact, so they cannot
//! perturb parity. The upshot: `kernel_parity.rs` / `engine_batched.rs`
//! keep their `assert_eq!` checks — no ULP tolerance anywhere.
//!
//! These rules define the **`Exact` numerics mode** — the default
//! everywhere. Rule 3's FMA (and a vectorized `exp` for the
//! transcendentals below) is exactly what the opt-in `Fast` mode buys
//! back, under a relaxed tolerance contract of its own: see
//! [`super::fast_math`].

use crate::quant::pack::GROUP;

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Width (f32 lanes / code bytes) of one SIMD block; row partitions that
/// want tail-free workers align on this (see
/// [`crate::util::pool::ThreadPool::scope_chunks_aligned`]).
pub const BLOCK: usize = 8;

/// Instruction tier a kernel executes at.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SimdTier {
    /// Portable unrolled loops (the reference semantics).
    Scalar,
    /// Explicit AVX2 intrinsics, bitwise-equal to `Scalar`.
    Avx2,
}

impl SimdTier {
    /// Probe the running CPU (uncached; prefer [`tier`]).
    pub fn detect() -> SimdTier {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") {
                return SimdTier::Avx2;
            }
        }
        SimdTier::Scalar
    }

    /// Human label for bench output ("scalar" / "avx2").
    pub fn label(self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Avx2 => "avx2",
        }
    }
}

/// The best tier the running CPU supports, detected once per process.
pub fn tier() -> SimdTier {
    use once_cell::sync::Lazy;
    static TIER: Lazy<SimdTier> = Lazy::new(SimdTier::detect);
    *TIER
}

// ---------------------------------------------------------------- dot

/// `Σ a[i]·b[i]` at the detected tier.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_t(a, b, tier())
}

/// [`dot`] pinned to an explicit tier. `t` must not exceed the detected
/// tier (the public wrappers guarantee this).
#[inline]
pub(crate) fn dot_t(a: &[f32], b: &[f32], t: SimdTier) -> f32 {
    match t {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: callers only pass Avx2 when tier() reported it.
        SimdTier::Avx2 => unsafe { dot_avx2(a, b) },
        _ => dot_scalar(a, b),
    }
}

/// Scalar-tier dot: 8 accumulators, lane `k` owns indices `8·i + k`,
/// pinned tree reduction. This exact shape is the parity reference for
/// the AVX2 tier *and* auto-vectorizes acceptably where AVX2 is absent.
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let (mut s4, mut s5, mut s6, mut s7) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let o = i * 8;
        s0 += a[o] * b[o];
        s1 += a[o + 1] * b[o + 1];
        s2 += a[o + 2] * b[o + 2];
        s3 += a[o + 3] * b[o + 3];
        s4 += a[o + 4] * b[o + 4];
        s5 += a[o + 5] * b[o + 5];
        s6 += a[o + 6] * b[o + 6];
        s7 += a[o + 7] * b[o + 7];
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    (s0 + s1) + (s2 + s3) + ((s4 + s5) + (s6 + s7)) + tail
}

/// Pinned-order horizontal sum of one vector of 8 lane accumulators —
/// the same tree the scalar tier spells out.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: `unsafe` only for #[target_feature]; pure register math, no
// memory access beyond the 8-lane stack spill. Caller ensures AVX2.
unsafe fn hsum_pinned(v: __m256) -> f32 {
    let mut l = [0.0f32; 8];
    _mm256_storeu_ps(l.as_mut_ptr(), v);
    (l[0] + l[1]) + (l[2] + l[3]) + ((l[4] + l[5]) + (l[6] + l[7]))
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: caller must ensure AVX2 (dispatchers check `tier()`). All loads
// go through `as_ptr().add(o)` with `o + 8 <= len` by the chunk bound.
pub(crate) unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc = _mm256_setzero_ps();
    for i in 0..chunks {
        let o = i * 8;
        let prod = _mm256_mul_ps(_mm256_loadu_ps(ap.add(o)), _mm256_loadu_ps(bp.add(o)));
        acc = _mm256_add_ps(acc, prod);
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    hsum_pinned(acc) + tail
}

// ------------------------------------------------- elementwise helpers

/// `x[i] += a[i]` at the detected tier — the residual-add of the
/// forward core. Lanes are independent (one add per element, in index
/// order, on every tier), so dispatch is bitwise-invisible by
/// construction; `tests/attn_parity.rs` pins it anyway.
#[inline]
pub fn add_assign(x: &mut [f32], a: &[f32]) {
    add_assign_t(x, a, tier())
}

/// [`add_assign`] forced onto the scalar tier (parity reference).
#[inline]
pub fn add_assign_scalar(x: &mut [f32], a: &[f32]) {
    debug_assert_eq!(x.len(), a.len());
    for (xv, &av) in x.iter_mut().zip(a) {
        *xv += av;
    }
}

/// [`add_assign`] pinned to an explicit tier.
#[inline]
pub(crate) fn add_assign_t(x: &mut [f32], a: &[f32], t: SimdTier) {
    match t {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: callers only pass Avx2 when tier() reported it.
        SimdTier::Avx2 => unsafe { add_assign_avx2(x, a) },
        _ => add_assign_scalar(x, a),
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: caller must ensure AVX2. Loads/stores stay inside `x`/`a`:
// `o + 8 <= len` per chunk, tail handled element-wise.
unsafe fn add_assign_avx2(x: &mut [f32], a: &[f32]) {
    debug_assert_eq!(x.len(), a.len());
    let n = x.len();
    let chunks = n / 8;
    let xp = x.as_mut_ptr();
    let ap = a.as_ptr();
    for i in 0..chunks {
        let o = i * 8;
        let v = _mm256_add_ps(_mm256_loadu_ps(xp.add(o)), _mm256_loadu_ps(ap.add(o)));
        _mm256_storeu_ps(xp.add(o), v);
    }
    for i in chunks * 8..n {
        *xp.add(i) += *ap.add(i);
    }
}

/// `acc[i] += s·v[i]` at the detected tier — the weighted-accumulate
/// under [`crate::kernels::attn::av_accumulate`]. Mul-then-add per
/// element (no FMA), lanes independent, so scalar and AVX2 are bitwise
/// identical.
#[inline]
pub fn axpy(acc: &mut [f32], s: f32, v: &[f32]) {
    axpy_t(acc, s, v, tier())
}

/// [`axpy`] forced onto the scalar tier (parity reference).
#[inline]
pub fn axpy_scalar(acc: &mut [f32], s: f32, v: &[f32]) {
    debug_assert_eq!(acc.len(), v.len());
    for (o, &vv) in acc.iter_mut().zip(v) {
        *o += s * vv;
    }
}

/// [`axpy`] pinned to an explicit tier.
#[inline]
pub(crate) fn axpy_t(acc: &mut [f32], s: f32, v: &[f32], t: SimdTier) {
    match t {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: callers only pass Avx2 when tier() reported it.
        SimdTier::Avx2 => unsafe { axpy_avx2(acc, s, v) },
        _ => axpy_scalar(acc, s, v),
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: caller must ensure AVX2. Loads/stores stay inside `acc`/`v`:
// `o + 8 <= len` per chunk, tail handled element-wise.
unsafe fn axpy_avx2(acc: &mut [f32], s: f32, v: &[f32]) {
    debug_assert_eq!(acc.len(), v.len());
    let n = acc.len();
    let chunks = n / 8;
    let op = acc.as_mut_ptr();
    let vp = v.as_ptr();
    let sv = _mm256_set1_ps(s);
    for i in 0..chunks {
        let o = i * 8;
        let prod = _mm256_mul_ps(sv, _mm256_loadu_ps(vp.add(o)));
        _mm256_storeu_ps(op.add(o), _mm256_add_ps(_mm256_loadu_ps(op.add(o)), prod));
    }
    for i in chunks * 8..n {
        *op.add(i) += s * *vp.add(i);
    }
}

// ---------------------------------------------------------- activations

/// tanh-approximated GELU (jax.nn.gelu's default) — the canonical
/// scalar form every tier evaluates.
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/π)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// SiLU (swish) — Llama's gate activation, canonical scalar form.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// `gate[i] = silu(gate[i])·up[i]` — the Llama FFN gate fused with its
/// up-projection multiply. Both tiers share the scalar loop: the
/// transcendental (`exp`) has no bitwise-stable AVX2 formulation — any
/// vector polynomial rounds differently from libm, which would break
/// the parity contract the served-token guarantee rests on. The
/// dispatch surface exists so a relaxed-contract vector tier can slot
/// in later without touching the model code.
#[inline]
pub fn silu_mul(gate: &mut [f32], up: &[f32]) {
    silu_mul_t(gate, up, tier())
}

/// [`silu_mul`] forced onto the scalar tier (parity reference).
#[inline]
pub fn silu_mul_scalar(gate: &mut [f32], up: &[f32]) {
    debug_assert_eq!(gate.len(), up.len());
    for (g, &u) in gate.iter_mut().zip(up) {
        *g = silu(*g) * u;
    }
}

/// [`silu_mul`] pinned to an explicit tier (both evaluate identically;
/// see [`silu_mul`] for why).
#[inline]
pub(crate) fn silu_mul_t(gate: &mut [f32], up: &[f32], _t: SimdTier) {
    silu_mul_scalar(gate, up);
}

/// `x[i] = gelu(x[i])` in place — same tier story as [`silu_mul`]
/// (`tanh` pins both tiers to the shared scalar loop).
#[inline]
pub fn gelu_map(x: &mut [f32]) {
    gelu_map_t(x, tier())
}

/// [`gelu_map`] forced onto the scalar tier (parity reference).
#[inline]
pub fn gelu_map_scalar(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = gelu(*v);
    }
}

/// [`gelu_map`] pinned to an explicit tier.
#[inline]
pub(crate) fn gelu_map_t(x: &mut [f32], _t: SimdTier) {
    gelu_map_scalar(x);
}

// ----------------------------------------------------------- code dot

/// `Σ codes[i]·x[i]` with the codes widened `u8 → f32` on the fly —
/// the dequant path's inner product, same pinned shape as [`dot`]
/// (widening is exact, so `code_dot(c, x) == dot(widen(c), x)` bitwise).
#[inline]
pub(crate) fn code_dot_t(codes: &[u8], x: &[f32], t: SimdTier) -> f32 {
    match t {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: callers only pass Avx2 when tier() reported it.
        SimdTier::Avx2 => unsafe { code_dot_avx2(codes, x) },
        _ => code_dot_scalar(codes, x),
    }
}

#[inline]
fn code_dot_scalar(codes: &[u8], x: &[f32]) -> f32 {
    debug_assert_eq!(codes.len(), x.len());
    let n = x.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let (mut s4, mut s5, mut s6, mut s7) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let o = i * 8;
        s0 += codes[o] as f32 * x[o];
        s1 += codes[o + 1] as f32 * x[o + 1];
        s2 += codes[o + 2] as f32 * x[o + 2];
        s3 += codes[o + 3] as f32 * x[o + 3];
        s4 += codes[o + 4] as f32 * x[o + 4];
        s5 += codes[o + 5] as f32 * x[o + 5];
        s6 += codes[o + 6] as f32 * x[o + 6];
        s7 += codes[o + 7] as f32 * x[o + 7];
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..n {
        tail += codes[i] as f32 * x[i];
    }
    (s0 + s1) + (s2 + s3) + ((s4 + s5) + (s6 + s7)) + tail
}

/// Load 8 code bytes and widen them to 8 exact f32 lanes.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: caller must ensure AVX2 and that `p..p+8` is readable (every
// call site passes `base.add(o)` with `o + 8 <= len`).
unsafe fn load8_u8_as_f32(p: *const u8) -> __m256 {
    _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_loadl_epi64(p as *const __m128i)))
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: caller must ensure AVX2. 8-byte code loads and 8-lane f32
// loads both satisfy `o + 8 <= len` by the chunk bound.
unsafe fn code_dot_avx2(codes: &[u8], x: &[f32]) -> f32 {
    debug_assert_eq!(codes.len(), x.len());
    let n = x.len();
    let chunks = n / 8;
    let cp = codes.as_ptr();
    let xp = x.as_ptr();
    let mut acc = _mm256_setzero_ps();
    for i in 0..chunks {
        let o = i * 8;
        let prod = _mm256_mul_ps(load8_u8_as_f32(cp.add(o)), _mm256_loadu_ps(xp.add(o)));
        acc = _mm256_add_ps(acc, prod);
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..n {
        tail += codes[i] as f32 * x[i];
    }
    hsum_pinned(acc) + tail
}

/// Widen a row of code bytes to f32 (`out[i] = codes[i] as f32`) — the
/// batched dequant gemm converts each streamed weight row once and then
/// feeds every batch item the f32 tile at SIMD width. Exact, so the
/// tier cannot matter for the value; the AVX2 tier only converts faster.
#[inline]
pub(crate) fn widen_codes(codes: &[u8], out: &mut [f32], t: SimdTier) {
    debug_assert_eq!(codes.len(), out.len());
    match t {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: callers only pass Avx2 when tier() reported it.
        SimdTier::Avx2 => unsafe { widen_codes_avx2(codes, out) },
        _ => {
            for (o, &c) in out.iter_mut().zip(codes) {
                *o = c as f32;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: caller must ensure AVX2 and `codes.len() == out.len()` (the
// dispatcher asserts it); `o + 8 <= len` bounds every load/store.
unsafe fn widen_codes_avx2(codes: &[u8], out: &mut [f32]) {
    let n = out.len();
    let chunks = n / 8;
    let cp = codes.as_ptr();
    let op = out.as_mut_ptr();
    for i in 0..chunks {
        let o = i * 8;
        _mm256_storeu_ps(op.add(o), load8_u8_as_f32(cp.add(o)));
    }
    for i in chunks * 8..n {
        *op.add(i) = *cp.add(i) as f32;
    }
}

// ------------------------------------------------------ LUT accumulate

/// `acc[i] += Σ_g luts[g][codes[g][i]]` with `g` ascending per slot —
/// the LUT-GEMM inner accumulation shared by `gemv_lut` and `gemm_lut`.
/// Each `codes[g]` slice must be exactly `acc.len()` bytes. The AVX2
/// tier gathers 8 byte-codes per table per step (`vpgatherdps` over the
/// L1-resident 256-entry LUT); per slot the add order is identical to
/// the scalar tier, so the result is bitwise equal.
#[inline]
pub(crate) fn lut_accumulate(
    acc: &mut [f32],
    codes: &[&[u8]],
    luts: &[[f32; 1 << GROUP]],
    t: SimdTier,
) {
    debug_assert_eq!(codes.len(), luts.len());
    for cs in codes {
        debug_assert_eq!(cs.len(), acc.len());
    }
    match t {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: callers only pass Avx2 when tier() reported it; every
        // gather index is a u8, in bounds of the 256-entry tables.
        SimdTier::Avx2 => unsafe { lut_accumulate_avx2(acc, codes, luts) },
        _ => lut_accumulate_scalar(acc, codes, luts),
    }
}

fn lut_accumulate_scalar(acc: &mut [f32], codes: &[&[u8]], luts: &[[f32; 1 << GROUP]]) {
    for (i, slot) in acc.iter_mut().enumerate() {
        let mut s = *slot;
        for (cs, lut) in codes.iter().zip(luts) {
            s += lut[cs[i] as usize];
        }
        *slot = s;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: caller must ensure AVX2, every `codes[g].len() == acc.len()`
// (the dispatcher asserts it), and code values < 2^GROUP index the
// 256-entry tables — u8 codes can't exceed that by construction.
unsafe fn lut_accumulate_avx2(acc: &mut [f32], codes: &[&[u8]], luts: &[[f32; 1 << GROUP]]) {
    // re-assert the dispatcher's bounds at the deref site: every raw
    // load below (`cs.as_ptr().add(o)`, `ap.add(i)`) is justified by
    // exactly these two shape facts
    debug_assert_eq!(codes.len(), luts.len());
    for cs in codes.iter() {
        debug_assert_eq!(cs.len(), acc.len());
    }
    let n = acc.len();
    let chunks = n / 8;
    let ap = acc.as_mut_ptr();
    for i in 0..chunks {
        let o = i * 8;
        let mut v = _mm256_loadu_ps(ap.add(o));
        for (cs, lut) in codes.iter().zip(luts) {
            let idx = _mm256_cvtepu8_epi32(_mm_loadl_epi64(cs.as_ptr().add(o) as *const __m128i));
            v = _mm256_add_ps(v, _mm256_i32gather_ps::<4>(lut.as_ptr(), idx));
        }
        _mm256_storeu_ps(ap.add(o), v);
    }
    for i in chunks * 8..n {
        let mut s = *ap.add(i);
        for (cs, lut) in codes.iter().zip(luts) {
            s += lut[cs[i] as usize];
        }
        *ap.add(i) = s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn detection_is_stable_and_labeled() {
        let t = tier();
        assert_eq!(t, tier(), "cached tier must not change");
        assert!(t.label() == "scalar" || t.label() == "avx2");
    }

    #[test]
    fn dot_tiers_match_bitwise_on_ragged_lengths() {
        let mut rng = Rng::new(41);
        for n in [0usize, 1, 7, 8, 9, 31, 64, 1031] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let scalar = dot_scalar(&a, &b);
            let dispatched = dot(&a, &b);
            assert_eq!(scalar.to_bits(), dispatched.to_bits(), "n={n}");
        }
    }

    #[test]
    fn code_dot_tiers_match_bitwise_and_equal_widened_dot() {
        let mut rng = Rng::new(42);
        for n in [1usize, 8, 13, 77, 256, 1031] {
            let codes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let scalar = code_dot_t(&codes, &x, SimdTier::Scalar);
            let dispatched = code_dot_t(&codes, &x, tier());
            assert_eq!(scalar.to_bits(), dispatched.to_bits(), "n={n}");
            // widening is exact, so the widened dot is the same bits too
            let mut wide = vec![0.0f32; n];
            widen_codes(&codes, &mut wide, tier());
            assert_eq!(dot(&wide, &x).to_bits(), scalar.to_bits(), "widen n={n}");
        }
    }

    #[test]
    fn widen_tiers_agree_exactly() {
        let mut rng = Rng::new(43);
        for n in [1usize, 9, 64, 257] {
            let codes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            widen_codes(&codes, &mut a, SimdTier::Scalar);
            widen_codes(&codes, &mut b, tier());
            assert_eq!(a, b);
            for (v, &c) in a.iter().zip(&codes) {
                assert_eq!(*v, c as f32);
            }
        }
    }

    #[test]
    fn add_assign_and_axpy_tiers_match_bitwise() {
        let mut rng = Rng::new(45);
        for n in [0usize, 1, 7, 8, 9, 33, 257] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let base: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let s = rng.normal_f32();
            let mut x_s = base.clone();
            let mut x_d = base.clone();
            add_assign_scalar(&mut x_s, &a);
            add_assign(&mut x_d, &a);
            for (u, v) in x_s.iter().zip(&x_d) {
                assert_eq!(u.to_bits(), v.to_bits(), "add_assign n={n}");
            }
            let mut y_s = base.clone();
            let mut y_d = base.clone();
            axpy_scalar(&mut y_s, s, &a);
            axpy(&mut y_d, s, &a);
            for (u, v) in y_s.iter().zip(&y_d) {
                assert_eq!(u.to_bits(), v.to_bits(), "axpy n={n}");
            }
        }
    }

    #[test]
    fn activation_helpers_match_scalar_twins_bitwise() {
        let mut rng = Rng::new(46);
        for n in [1usize, 9, 64, 131] {
            let up: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let base: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let mut g_s = base.clone();
            let mut g_d = base.clone();
            silu_mul_scalar(&mut g_s, &up);
            silu_mul(&mut g_d, &up);
            assert_eq!(g_s, g_d, "silu_mul n={n}");
            // and against the per-element definition
            for (g, (&b, &u)) in g_s.iter().zip(base.iter().zip(&up)) {
                assert_eq!(*g, silu(b) * u);
            }
            let mut x_s = base.clone();
            let mut x_d = base.clone();
            gelu_map_scalar(&mut x_s);
            gelu_map(&mut x_d);
            assert_eq!(x_s, x_d, "gelu_map n={n}");
        }
    }

    #[test]
    fn lut_accumulate_tiers_match_bitwise() {
        let mut rng = Rng::new(44);
        for slots in [1usize, 7, 8, 16, 33, 1031] {
            for groups in [1usize, 3, 8] {
                let mut luts = vec![[0.0f32; 1 << GROUP]; groups];
                for lut in luts.iter_mut() {
                    for v in lut.iter_mut() {
                        *v = rng.normal_f32();
                    }
                }
                let codes: Vec<Vec<u8>> = (0..groups)
                    .map(|_| (0..slots).map(|_| rng.below(256) as u8).collect())
                    .collect();
                let slices: Vec<&[u8]> = codes.iter().map(|c| c.as_slice()).collect();
                let base: Vec<f32> = (0..slots).map(|_| rng.normal_f32()).collect();
                let mut acc_s = base.clone();
                let mut acc_d = base.clone();
                lut_accumulate(&mut acc_s, &slices, &luts, SimdTier::Scalar);
                lut_accumulate(&mut acc_d, &slices, &luts, tier());
                for (i, (a, b)) in acc_s.iter().zip(&acc_d).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "slots={slots} groups={groups} slot {i}"
                    );
                }
            }
        }
    }
}
