//! Serving hot-path kernels — the CPU realization of the three weight
//! formats the paper races in Table IV:
//!
//! | format                | gemv kernel     | batched gemm       | dispatch tiers | numerics modes | parity contract per mode | paper row      |
//! |-----------------------|-----------------|--------------------|----------------|----------------|--------------------------|----------------|
//! | dense f32             | [`gemv_f32`]    | [`gemm_f32`]       | scalar / AVX2  | Exact / Fast (FMA dot) | bitwise / rel-tol + tier-deterministic | `full` (fp16)  |
//! | packed int + dequant  | [`gemv_dequant`]| [`gemm_dequant`]   | scalar / AVX2  | Exact / Fast (FMA code-dot + epilogue) | bitwise / rel-tol + tier-deterministic | `GPTQ`         |
//! | fused binary coding   | [`gemv_lut`]    | [`gemm_lut`]       | scalar / AVX2  | Exact / Fast (FMA α-epilogue; LUT adds shared) | bitwise / rel-tol + tier-deterministic | `GPTQT` (LUT-GEMM) |
//! | attention (head-major KV) | [`attn::qk_dots`] | [`attn::av_accumulate`] | scalar / AVX2 | Exact / Fast ([`fast_math::attn_row_fast`] online softmax) | bitwise / rel-tol + tier-deterministic | serving context (all rows) |
//!
//! The attention row is not a weight format: it is the per-(row, head)
//! score/context pair the forward core runs between the QKV and output
//! GEMMs, fed by the head-major `KvCache` strips and fanned across the
//! pool per (row, head) work item above [`PAR_MIN_WORK`]
//! (see [`attn`] and `model::decode`).
//!
//! The table's contracts are *statically enforced* by `gptqt-lint`
//! (CONTRIBUTING.md has the full rule list): the bitwise column is rule
//! `exact-tier-purity` (no FMA/reassociation outside `fast_math`), the
//! `*_scalar` twins and their test coverage are rule `scalar-twin`, the
//! allocation-free hot path is rule `hot-path-no-alloc`, and every
//! `unsafe` SIMD site carries a `// SAFETY:` comment (rule
//! `safety-comment`).
//!
//! All three implement [`Gemv`], so the decode loop and the speed
//! benchmarks swap formats without touching the model code. In the
//! bandwidth-bound single-token decode regime the ranking is decided by
//! bytes streamed per output element: 4 B (f32) vs ~`bits/8` B (packed)
//! — the same asymmetry that gives the paper its 30B-scale speedups.
//!
//! **SIMD dispatch.** Every inner accumulation runs through
//! [`simd`]: an explicit AVX2 tier selected once per process via
//! `is_x86_feature_detected!("avx2")`, with a portable scalar fallback
//! everywhere else. All three kernels pin the *bitwise* variant of the
//! parity contract — AVX2 uses the same lane → accumulator mapping, the
//! same mul-then-add rounding (no FMA), and the same tree reduction as
//! the scalar tier, so dispatch can never change a served token. Each
//! kernel has a `*_scalar` twin (e.g. [`gemm_lut_scalar`]) that forces
//! the scalar tier; `tests/simd_parity.rs` asserts `assert_eq!` between
//! the twins across ragged shapes and batch sizes.
//!
//! **Numerics modes.** Orthogonal to the instruction tier, every kernel
//! carries a [`NumericsMode`] axis: `Exact` (the bitwise contract
//! above, the default everywhere) and `Fast` — FMA dots, a polynomial
//! `exp`, and fused online-softmax attention, all in [`fast_math`].
//! `Fast` trades bit-equality with `Exact` for throughput under an
//! explicit relaxed contract: bounded relative drift
//! (`tests/numerics_tolerance.rs`) and bitwise determinism *within* the
//! tier (the scalar `mul_add` fallback matches the AVX2+FMA path), so
//! greedy decode stays machine-independent and token divergence vs
//! `Exact` is asserted ≈0 end-to-end (`tests/numerics_divergence.rs`).
//! The mode threads from the CLI (`--numerics`) through
//! [`crate::model::BackendModel`] into [`Gemv::gemm_mode`] — never
//! probed implicitly. Compare the tiers locally with the smoke benches:
//!
//! ```text
//! cargo bench --bench kernels -- --smoke   # writes BENCH_kernels.json
//! cargo bench --bench speed   -- --smoke   # writes BENCH_speed.json
//! ```
//!
//! The speed bench's `serve spec` records time the self-speculative
//! serving protocol built on these kernels (2-bit binary-coding draft,
//! 3-bit LUT or dense verify — see
//! [`crate::coordinator::SpeculativeBackend`]); each record carries
//! effective tokens/sec plus an `acceptance_rate` key, both diffed by
//! the CI bench-trend job.
//!
//! **Batched weight reuse.** A server decoding B concurrent sequences
//! would stream the weights B times through the gemv path; the batched
//! [`Gemv::gemm`] entry point streams each weight row/byte **once per
//! batch** and applies it to all B activation vectors (per-row dequant
//! params and per-group LUT tables are likewise built once per batch
//! item but the dominant packed-code traffic is amortized B×). This is
//! the same weight-reuse win LUT-GEMM and FineQuant report for batched
//! serving. Every `gemm` is element-for-element identical in fp
//! arithmetic order to B independent `gemv` calls, so batched decode is
//! token-identical to sequential decode (tested in
//! `tests/kernel_parity.rs`).
//!
//! The batch dimension of `gemm` carries *anything that shares a weight
//! stream*: concurrent decode sequences, the T tokens of one prefill
//! chunk, or both mixed in a single engine tick — the chunk-major
//! forward core ([`crate::model::BackendModel`]) flattens all of them
//! into one activation list per linear.
//!
//! **Thread-level parallelism.** When a `gemm` call carries enough total
//! work (`rows × cols × batch ≥ 2²¹`, see [`PAR_MIN_WORK`]), its output
//! rows are partitioned across the global [`crate::util::pool`] workers.
//! The partition is by *row*, so every output element keeps the exact
//! reduction order of the single-threaded kernel — the bitwise
//! `gemm == per-item gemv` contract survives threading. The gate is
//! total work, not batch size: a `gemm(B=1)` decode step on a layer
//! big enough to clear the threshold also threads (that *helps* batch-1
//! latency), while small calls stay single-threaded because pool
//! dispatch would cost more than it saves. The `gemv` entry points are
//! always single-threaded.
//!
//! [`gemm_dequant`]: gemv_dequant::gemm_dequant
//! [`gemm_lut`]: gemv_lut::gemm_lut
//! [`gemm_lut_scalar`]: gemv_lut::gemm_lut_scalar

pub mod attn;
pub mod fast_math;
pub mod gemv_dequant;
pub mod gemv_lut;
pub mod simd;

pub use fast_math::NumericsMode;
pub use simd::SimdTier;

use crate::quant::linear::IntLayer;
use crate::quant::pack::PackedBcLayer;
use crate::tensor::Tensor;
use crate::util::pool;

/// Sequential left-to-right `Σ xs[i]` — the pinned-order input sum of the
/// dequant epilogues, spelled as an explicit loop so Exact-tier kernels
/// carry no `.sum()`/`.fold(` reassociation hazard (rule
/// `exact-tier-purity`). Bitwise identical to the iterator sum it
/// replaces: both are an in-order binary fold from 0.0.
#[inline]
pub(crate) fn sum_seq(xs: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for &v in xs {
        s += v;
    }
    s
}

/// Minimum total work (`rows × cols × batch` weight-element applications)
/// before a batched kernel fans its output rows across the pool.
pub const PAR_MIN_WORK: usize = 1 << 21;

/// Whether a `rows × cols` layer applied to `batch` activations should
/// run row-parallel on the global pool.
pub(crate) fn par_rows(rows: usize, cols: usize, batch: usize) -> bool {
    rows.saturating_mul(cols).saturating_mul(batch) >= PAR_MIN_WORK
        && pool::global().threads() > 1
}

/// Pointer bundle giving pool workers disjoint-row write access to the
/// per-batch-item output vectors of a `gemm` call.
pub(crate) struct RowWriter(Vec<*mut f32>);
// SAFETY: workers only dereference through `set`, whose contract (below)
// makes every (bi, r) write target disjoint; the pool joins before the
// borrowed output vectors can move.
unsafe impl Sync for RowWriter {}
// SAFETY: the raw pointers stay valid for the whole gemm call — see `Sync`.
unsafe impl Send for RowWriter {}

impl RowWriter {
    pub(crate) fn new(ys: &mut [Vec<f32>]) -> RowWriter {
        // lint:allow(hot-path-no-alloc) O(batch) pointer bundle per gemm
        // call; steady-state pinned by tests/alloc_steady.rs.
        RowWriter(ys.iter_mut().map(|y| y.as_mut_ptr()).collect())
    }

    /// Write output row `r` of batch item `bi`.
    ///
    /// # Safety
    /// Each row index must be written by exactly one thread (the pool
    /// partitions `0..rows` into disjoint ranges), and the `ys` the
    /// writer was built from must outlive all writes — guaranteed by
    /// `scope_chunks` joining before return.
    #[inline]
    pub(crate) unsafe fn set(&self, bi: usize, r: usize, v: f32) {
        *self.0[bi].add(r) = v;
    }
}

/// A matrix–vector product backend: `y = W·x` for one weight format,
/// plus the batched `Y = W·X` form that amortizes weight streaming
/// across concurrent sequences.
pub trait Gemv: Send + Sync {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    /// `y` must have length `rows()`, `x` length `cols()`.
    fn gemv(&self, x: &[f32], y: &mut [f32]);
    /// Batched matvec: `ys[b] = W·xs[b]` for every batch item `b`.
    ///
    /// Implementations stream the weights once for the whole batch.
    /// Contract: the result must be *identical* (same fp operation
    /// order per item) to calling [`Gemv::gemv`] on each item — the
    /// engine relies on this for batched == sequential token parity.
    /// The default falls back to that per-item loop.
    fn gemm(&self, xs: &[&[f32]], ys: &mut [Vec<f32>]) {
        assert_eq!(xs.len(), ys.len(), "gemm batch size mismatch");
        for (x, y) in xs.iter().zip(ys.iter_mut()) {
            self.gemv(x, y);
        }
    }
    /// Mode-dispatched matvec: `Exact` routes to [`Gemv::gemv`].
    /// Backends with a `Fast` tier override this with their FMA kernels;
    /// the default ignores the mode (running `Exact` under `Fast` is
    /// always within the relaxed contract).
    fn gemv_mode(&self, x: &[f32], y: &mut [f32], mode: NumericsMode) {
        let _ = mode;
        self.gemv(x, y);
    }
    /// Mode-dispatched batched matvec; same override story as
    /// [`Gemv::gemv_mode`]. `Fast` implementations must keep the
    /// weight-streaming shape of [`Gemv::gemm`] (one stream per batch)
    /// and the per-item `gemm_mode(B=1) == gemv_mode` identity — the
    /// engine's batched == sequential token guarantee holds per mode.
    fn gemm_mode(&self, xs: &[&[f32]], ys: &mut [Vec<f32>], mode: NumericsMode) {
        let _ = mode;
        self.gemm(xs, ys);
    }
    /// Bytes this layer streams from memory per matvec — the quantity
    /// that dominates decode latency (Table IV's bandwidth story). A
    /// batched gemm streams this once per batch, i.e. `streamed_bytes /
    /// B` per generated token.
    fn streamed_bytes(&self) -> usize;
    /// Human label for benches.
    fn label(&self) -> &'static str;
}

/// Dense f32 weights (the `full` baseline).
pub struct DenseGemv {
    pub w: Tensor,
}

impl DenseGemv {
    pub fn new(w: Tensor) -> Self {
        DenseGemv { w }
    }
}

impl Gemv for DenseGemv {
    fn rows(&self) -> usize {
        self.w.rows()
    }

    fn cols(&self) -> usize {
        self.w.cols()
    }

    fn gemv(&self, x: &[f32], y: &mut [f32]) {
        gemv_f32(&self.w, x, y);
    }

    fn gemm(&self, xs: &[&[f32]], ys: &mut [Vec<f32>]) {
        gemm_f32(&self.w, xs, ys);
    }

    fn gemv_mode(&self, x: &[f32], y: &mut [f32], mode: NumericsMode) {
        match mode {
            NumericsMode::Exact => gemv_f32(&self.w, x, y),
            NumericsMode::Fast => gemv_f32_fast(&self.w, x, y),
        }
    }

    fn gemm_mode(&self, xs: &[&[f32]], ys: &mut [Vec<f32>], mode: NumericsMode) {
        match mode {
            NumericsMode::Exact => gemm_f32(&self.w, xs, ys),
            NumericsMode::Fast => gemm_f32_fast(&self.w, xs, ys),
        }
    }

    fn streamed_bytes(&self) -> usize {
        self.w.len() * 4
    }

    fn label(&self) -> &'static str {
        "full"
    }
}

/// Dense f32 matvec (SIMD-dispatched dot per row).
pub fn gemv_f32(w: &Tensor, x: &[f32], y: &mut [f32]) {
    gemv_f32_t(w, x, y, simd::tier());
}

/// [`gemv_f32`] forced onto the scalar tier — the reference the SIMD
/// path must match bitwise (`tests/simd_parity.rs`).
pub fn gemv_f32_scalar(w: &Tensor, x: &[f32], y: &mut [f32]) {
    gemv_f32_t(w, x, y, SimdTier::Scalar);
}

fn gemv_f32_t(w: &Tensor, x: &[f32], y: &mut [f32], t: SimdTier) {
    assert_eq!(x.len(), w.cols());
    assert_eq!(y.len(), w.rows());
    for (r, yr) in y.iter_mut().enumerate() {
        *yr = simd::dot_t(w.row(r), x, t);
    }
}

/// Dense f32 batched matvec: each weight row is streamed once and dotted
/// against every batch activation while it is cache-hot — `rows·cols`
/// weight traffic for the whole batch instead of per sequence. Per item
/// the arithmetic is exactly [`gemv_f32`]'s; large calls split rows
/// across the pool (same per-row reduction order, so still bitwise).
pub fn gemm_f32(w: &Tensor, xs: &[&[f32]], ys: &mut [Vec<f32>]) {
    gemm_f32_t(w, xs, ys, simd::tier());
}

/// [`gemm_f32`] forced onto the scalar tier (bench/test reference).
pub fn gemm_f32_scalar(w: &Tensor, xs: &[&[f32]], ys: &mut [Vec<f32>]) {
    gemm_f32_t(w, xs, ys, SimdTier::Scalar);
}

fn gemm_f32_t(w: &Tensor, xs: &[&[f32]], ys: &mut [Vec<f32>], t: SimdTier) {
    assert_eq!(xs.len(), ys.len(), "gemm_f32 batch size mismatch");
    for x in xs {
        assert_eq!(x.len(), w.cols());
    }
    for y in ys.iter() {
        assert_eq!(y.len(), w.rows());
    }
    let rows = w.rows();
    if par_rows(rows, w.cols(), xs.len()) {
        let writer = RowWriter::new(ys);
        pool::global().scope_chunks(rows, |range| {
            for r in range {
                let row = w.row(r);
                for (bi, x) in xs.iter().enumerate() {
                    // SAFETY: each row lands in exactly one chunk.
                    unsafe { writer.set(bi, r, simd::dot_t(row, x, t)) };
                }
            }
        });
    } else {
        for r in 0..rows {
            let row = w.row(r);
            for (x, y) in xs.iter().zip(ys.iter_mut()) {
                y[r] = simd::dot_t(row, x, t);
            }
        }
    }
}

/// Dense f32 matvec on the `Fast` numerics tier —
/// [`fast_math::dot_fast`] (FMA) per row, otherwise [`gemv_f32`]'s
/// shape. Row partition and per-row reduction order are unchanged, so
/// the result is deterministic across the `Fast` scalar/vector paths.
pub fn gemv_f32_fast(w: &Tensor, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), w.cols());
    assert_eq!(y.len(), w.rows());
    for (r, yr) in y.iter_mut().enumerate() {
        *yr = fast_math::dot_fast(w.row(r), x);
    }
}

/// Dense f32 batched matvec on the `Fast` numerics tier — the same
/// weight-streaming and pool row-partition as [`gemm_f32`] with the FMA
/// dot inside, so `gemm_f32_fast(B=1) == gemv_f32_fast` per element.
pub fn gemm_f32_fast(w: &Tensor, xs: &[&[f32]], ys: &mut [Vec<f32>]) {
    assert_eq!(xs.len(), ys.len(), "gemm_f32 batch size mismatch");
    for x in xs {
        assert_eq!(x.len(), w.cols());
    }
    for y in ys.iter() {
        assert_eq!(y.len(), w.rows());
    }
    let rows = w.rows();
    if par_rows(rows, w.cols(), xs.len()) {
        let writer = RowWriter::new(ys);
        pool::global().scope_chunks(rows, |range| {
            for r in range {
                let row = w.row(r);
                for (bi, x) in xs.iter().enumerate() {
                    // SAFETY: each row lands in exactly one chunk.
                    unsafe { writer.set(bi, r, fast_math::dot_fast(row, x)) };
                }
            }
        });
    } else {
        for r in 0..rows {
            let row = w.row(r);
            for (x, y) in xs.iter().zip(ys.iter_mut()) {
                y[r] = fast_math::dot_fast(row, x);
            }
        }
    }
}

impl Gemv for IntLayer {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn gemv(&self, x: &[f32], y: &mut [f32]) {
        gemv_dequant::gemv_dequant(self, x, y);
    }

    fn gemm(&self, xs: &[&[f32]], ys: &mut [Vec<f32>]) {
        gemv_dequant::gemm_dequant(self, xs, ys);
    }

    fn gemv_mode(&self, x: &[f32], y: &mut [f32], mode: NumericsMode) {
        match mode {
            NumericsMode::Exact => gemv_dequant::gemv_dequant(self, x, y),
            NumericsMode::Fast => gemv_dequant::gemv_dequant_fast(self, x, y),
        }
    }

    fn gemm_mode(&self, xs: &[&[f32]], ys: &mut [Vec<f32>], mode: NumericsMode) {
        match mode {
            NumericsMode::Exact => gemv_dequant::gemm_dequant(self, xs, ys),
            NumericsMode::Fast => gemv_dequant::gemm_dequant_fast(self, xs, ys),
        }
    }

    fn streamed_bytes(&self) -> usize {
        self.packed_bytes()
    }

    fn label(&self) -> &'static str {
        "gptq-dequant"
    }
}

impl Gemv for PackedBcLayer {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn gemv(&self, x: &[f32], y: &mut [f32]) {
        gemv_lut::gemv_lut(self, x, y);
    }

    fn gemm(&self, xs: &[&[f32]], ys: &mut [Vec<f32>]) {
        gemv_lut::gemm_lut(self, xs, ys);
    }

    fn gemv_mode(&self, x: &[f32], y: &mut [f32], mode: NumericsMode) {
        match mode {
            NumericsMode::Exact => gemv_lut::gemv_lut(self, x, y),
            NumericsMode::Fast => gemv_lut::gemv_lut_fast(self, x, y),
        }
    }

    fn gemm_mode(&self, xs: &[&[f32]], ys: &mut [Vec<f32>], mode: NumericsMode) {
        match mode {
            NumericsMode::Exact => gemv_lut::gemm_lut(self, xs, ys),
            NumericsMode::Fast => gemv_lut::gemm_lut_fast(self, xs, ys),
        }
    }

    fn streamed_bytes(&self) -> usize {
        self.packed_bytes()
    }

    fn label(&self) -> &'static str {
        "gptqt-lut"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn dense_gemv_matches_tensor_gemv() {
        let mut rng = Rng::new(301);
        let w = Tensor::randn(37, 53, 1.0, &mut rng);
        let x: Vec<f32> = (0..53).map(|_| rng.normal_f32()).collect();
        let mut y = vec![0.0; 37];
        gemv_f32(&w, &x, &mut y);
        let y_ref = w.gemv(&x);
        for (a, b) in y.iter().zip(&y_ref) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn dense_gemm_equals_per_item_gemv() {
        let mut rng = Rng::new(303);
        let w = Tensor::randn(19, 45, 1.0, &mut rng);
        let dense = DenseGemv::new(w.clone());
        let xs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..45).map(|_| rng.normal_f32()).collect())
            .collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        let mut ys: Vec<Vec<f32>> = (0..5).map(|_| vec![0.0; 19]).collect();
        dense.gemm(&refs, &mut ys);
        for (x, y) in xs.iter().zip(&ys) {
            let mut y_ref = vec![0.0; 19];
            dense.gemv(x, &mut y_ref);
            assert_eq!(y, &y_ref, "gemm must be bitwise identical to gemv");
        }
    }

    #[test]
    fn parallel_gemm_stays_bitwise_identical_to_gemv() {
        // 2048×1024 ≥ PAR_MIN_WORK even at batch 1, so this exercises the
        // row-partitioned pool path on multicore machines (and the
        // sequential path on single-core ones — same contract either way)
        let mut rng = Rng::new(307);
        let (rows, cols) = (2048usize, 1024usize);
        let w = Tensor::randn(rows, cols, 0.05, &mut rng);
        let dense = DenseGemv::new(w.clone());
        let (q, grids) = crate::quant::linear::rtn_quantize(&w, 3);
        let il = IntLayer::encode(&q, &grids, 3);
        let packed = PackedBcLayer::random(rows, cols, 3, 11);
        let xs: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..cols).map(|_| rng.normal_f32()).collect())
            .collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        let backends: [&dyn Gemv; 3] = [&dense, &il, &packed];
        for backend in backends {
            assert!(par_rows(rows, cols, 1) || pool::global().threads() == 1);
            let mut ys: Vec<Vec<f32>> = (0..3).map(|_| vec![0.0; rows]).collect();
            backend.gemm(&refs, &mut ys);
            for (x, y) in xs.iter().zip(&ys) {
                let mut y_ref = vec![0.0; rows];
                backend.gemv(x, &mut y_ref);
                assert_eq!(
                    y,
                    &y_ref,
                    "{}: threaded gemm must stay bitwise identical to gemv",
                    backend.label()
                );
            }
        }
    }

    #[test]
    fn mode_dispatch_exact_matches_default_and_fast_is_consistent() {
        let mut rng = Rng::new(308);
        let (rows, cols) = (24usize, 77usize);
        let w = Tensor::randn(rows, cols, 0.5, &mut rng);
        let dense = DenseGemv::new(w.clone());
        let (q, grids) = crate::quant::linear::rtn_quantize(&w, 3);
        let il = IntLayer::encode(&q, &grids, 3);
        let packed = PackedBcLayer::random(rows, cols, 3, 17);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
        let backends: [&dyn Gemv; 3] = [&dense, &il, &packed];
        for backend in backends {
            // Exact mode is exactly the unmoded entry point
            let mut y_plain = vec![0.0f32; rows];
            let mut y_exact = vec![0.0f32; rows];
            backend.gemv(&x, &mut y_plain);
            backend.gemv_mode(&x, &mut y_exact, NumericsMode::Exact);
            assert_eq!(y_plain, y_exact, "{}", backend.label());
            // Fast gemm(B=1) equals Fast gemv bitwise (per-mode identity)
            let mut y_fast = vec![0.0f32; rows];
            backend.gemv_mode(&x, &mut y_fast, NumericsMode::Fast);
            let mut ys: Vec<Vec<f32>> = vec![vec![0.0f32; rows]];
            backend.gemm_mode(&[&x], &mut ys, NumericsMode::Fast);
            assert_eq!(ys[0], y_fast, "{}", backend.label());
            // and Fast stays within the relaxed tolerance of Exact
            for (r, (a, b)) in y_exact.iter().zip(&y_fast).enumerate() {
                let tol = 1e-4 * (cols as f32).sqrt() * (1.0 + a.abs());
                assert!(
                    (a - b).abs() < tol,
                    "{} row {r}: exact={a} fast={b}",
                    backend.label()
                );
            }
        }
    }

    #[test]
    fn streamed_bytes_ordering() {
        // packed 3-bit must stream ~10× less than f32
        let mut rng = Rng::new(302);
        let w = Tensor::randn(64, 256, 1.0, &mut rng);
        let dense = DenseGemv::new(w.clone());
        let (q, grids) = crate::quant::linear::rtn_quantize(&w, 3);
        let il = IntLayer::encode(&q, &grids, 3);
        assert!(il.streamed_bytes() * 2 < dense.streamed_bytes());
    }
}
