//! Serving hot-path kernels — the CPU realization of the three weight
//! formats the paper races in Table IV:
//!
//! | format                | kernel         | paper row      |
//! |-----------------------|----------------|----------------|
//! | dense f32             | [`gemv_f32`]   | `full` (fp16)  |
//! | packed int + dequant  | [`gemv_dequant`]| `GPTQ`        |
//! | fused binary coding   | [`gemv_lut`]   | `GPTQT` (LUT-GEMM) |
//!
//! All three implement [`Gemv`], so the decode loop and the speed
//! benchmarks swap formats without touching the model code. In the
//! bandwidth-bound single-token decode regime the ranking is decided by
//! bytes streamed per output element: 4 B (f32) vs ~`bits/8` B (packed)
//! — the same asymmetry that gives the paper its 30B-scale speedups.

pub mod gemv_dequant;
pub mod gemv_lut;

use crate::quant::linear::IntLayer;
use crate::quant::pack::PackedBcLayer;
use crate::tensor::Tensor;

/// A matrix–vector product backend: `y = W·x` for one weight format.
pub trait Gemv: Send + Sync {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    /// `y` must have length `rows()`, `x` length `cols()`.
    fn gemv(&self, x: &[f32], y: &mut [f32]);
    /// Bytes this layer streams from memory per matvec — the quantity
    /// that dominates decode latency (Table IV's bandwidth story).
    fn streamed_bytes(&self) -> usize;
    /// Human label for benches.
    fn label(&self) -> &'static str;
}

/// Dense f32 weights (the `full` baseline).
pub struct DenseGemv {
    pub w: Tensor,
}

impl DenseGemv {
    pub fn new(w: Tensor) -> Self {
        DenseGemv { w }
    }
}

impl Gemv for DenseGemv {
    fn rows(&self) -> usize {
        self.w.rows()
    }

    fn cols(&self) -> usize {
        self.w.cols()
    }

    fn gemv(&self, x: &[f32], y: &mut [f32]) {
        gemv_f32(&self.w, x, y);
    }

    fn streamed_bytes(&self) -> usize {
        self.w.len() * 4
    }

    fn label(&self) -> &'static str {
        "full"
    }
}

/// Dense f32 matvec (unrolled dot per row).
pub fn gemv_f32(w: &Tensor, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), w.cols());
    assert_eq!(y.len(), w.rows());
    for (r, yr) in y.iter_mut().enumerate() {
        *yr = crate::tensor::ops::dot(w.row(r), x);
    }
}

impl Gemv for IntLayer {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn gemv(&self, x: &[f32], y: &mut [f32]) {
        gemv_dequant::gemv_dequant(self, x, y);
    }

    fn streamed_bytes(&self) -> usize {
        self.packed_bytes()
    }

    fn label(&self) -> &'static str {
        "gptq-dequant"
    }
}

impl Gemv for PackedBcLayer {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn gemv(&self, x: &[f32], y: &mut [f32]) {
        gemv_lut::gemv_lut(self, x, y);
    }

    fn streamed_bytes(&self) -> usize {
        self.packed_bytes()
    }

    fn label(&self) -> &'static str {
        "gptqt-lut"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn dense_gemv_matches_tensor_gemv() {
        let mut rng = Rng::new(301);
        let w = Tensor::randn(37, 53, 1.0, &mut rng);
        let x: Vec<f32> = (0..53).map(|_| rng.normal_f32()).collect();
        let mut y = vec![0.0; 37];
        gemv_f32(&w, &x, &mut y);
        let y_ref = w.gemv(&x);
        for (a, b) in y.iter().zip(&y_ref) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn streamed_bytes_ordering() {
        // packed 3-bit must stream ~10× less than f32
        let mut rng = Rng::new(302);
        let w = Tensor::randn(64, 256, 1.0, &mut rng);
        let dense = DenseGemv::new(w.clone());
        let (q, grids) = crate::quant::linear::rtn_quantize(&w, 3);
        let il = IntLayer::encode(&q, &grids, 3);
        assert!(il.streamed_bytes() * 2 < dense.streamed_bytes());
    }
}
