//! On-the-fly dequantize matvec — the GPTQ inference path.
//!
//! GPTQ stores linearly quantized integers and dequantizes to fp at
//! compute time (`Ŵ = S·(q + qz)`), paying a small arithmetic overhead
//! for the bandwidth saving (paper §III-E: "GPTQ dequantizes weights to
//! fp16 in real-time during computations, introducing a minor
//! computational overhead").
//!
//! The inner loop is restructured to avoid per-element dequantization:
//! `Σ_c S(q_c + qz)·x_c = S·(Σ_c q_c·x_c) + S·qz·(Σ_c x_c)` — one integer
//! ·f32 accumulation plus two scalars, which is both faster and exactly
//! equal (fp-associativity aside) to the naive form.
//!
//! The integer·f32 dot runs at the dispatched SIMD tier
//! ([`crate::kernels::simd::code_dot_t`]): AVX2 widens 8 code bytes per
//! step and multiplies-then-adds with the same lane → accumulator
//! mapping as the scalar tier, so scalar and SIMD results are bitwise
//! identical. The batched [`gemm_dequant`] additionally widens each
//! streamed code row to f32 **once per batch** and feeds all batch
//! items the widened tile at SIMD width — exact conversion, so still
//! the same bits as per-item [`gemv_dequant`].

use super::fast_math;
use super::simd::{self, SimdTier};
use crate::quant::linear::IntLayer;

/// `y = Ŵ·x` over the integer layer.
pub fn gemv_dequant(layer: &IntLayer, x: &[f32], y: &mut [f32]) {
    gemv_dequant_t(layer, x, y, simd::tier());
}

/// [`gemv_dequant`] forced onto the scalar tier — the reference the
/// SIMD path must match bitwise (`tests/simd_parity.rs`).
pub fn gemv_dequant_scalar(layer: &IntLayer, x: &[f32], y: &mut [f32]) {
    gemv_dequant_t(layer, x, y, SimdTier::Scalar);
}

fn gemv_dequant_t(layer: &IntLayer, x: &[f32], y: &mut [f32], t: SimdTier) {
    assert_eq!(x.len(), layer.cols);
    assert_eq!(y.len(), layer.rows);
    let sum_x = super::sum_seq(x);
    let cols = layer.cols;
    for r in 0..layer.rows {
        let (s, qz) = layer.row_params[r];
        let codes = &layer.codes[r * cols..(r + 1) * cols];
        let acc = simd::code_dot_t(codes, x, t);
        y[r] = s * acc + s * qz * sum_x;
    }
}

/// `y = Ŵ·x` on the `Fast` numerics tier: FMA code-dot
/// ([`fast_math::code_dot_fast`]) plus a fused dequant epilogue
/// (`fma(s·qz, Σx, s·acc)`). Same row order and accumulator shape, so
/// the result is deterministic across the `Fast` scalar/vector paths.
pub fn gemv_dequant_fast(layer: &IntLayer, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), layer.cols);
    assert_eq!(y.len(), layer.rows);
    let sum_x = super::sum_seq(x);
    let cols = layer.cols;
    for r in 0..layer.rows {
        let (s, qz) = layer.row_params[r];
        let codes = &layer.codes[r * cols..(r + 1) * cols];
        let acc = fast_math::code_dot_fast(codes, x);
        // lint:allow(exact-tier-purity) Fast-tier epilogue: fused
        // multiply-add is this tier's contract, the file is just shared.
        y[r] = (s * qz).mul_add(sum_x, s * acc);
    }
}

/// Batched `ys[b] = Ŵ·xs[b]`: each row's packed codes are streamed from
/// memory once, widened to an f32 tile once, and that tile is dotted
/// against every activation in the batch while it sits in cache — the
/// per-token weight traffic drops from `packed_bytes()` to
/// `packed_bytes() / B`, and the `u8 → f32` conversion cost is paid
/// once per row instead of once per (row, item). Per batch item the
/// arithmetic is exactly [`gemv_dequant`]'s (widening is exact; the dot
/// keeps the same pinned lanes and reduction), so batched and
/// sequential decode agree bit-for-bit. Calls with enough total work
/// split rows across the pool; the row partition keeps every output
/// element's reduction order unchanged.
pub fn gemm_dequant(layer: &IntLayer, xs: &[&[f32]], ys: &mut [Vec<f32>]) {
    gemm_dequant_t(layer, xs, ys, simd::tier());
}

/// [`gemm_dequant`] forced onto the scalar tier (bench/test reference).
pub fn gemm_dequant_scalar(layer: &IntLayer, xs: &[&[f32]], ys: &mut [Vec<f32>]) {
    gemm_dequant_t(layer, xs, ys, SimdTier::Scalar);
}

fn gemm_dequant_t(layer: &IntLayer, xs: &[&[f32]], ys: &mut [Vec<f32>], t: SimdTier) {
    assert_eq!(xs.len(), ys.len(), "gemm_dequant batch size mismatch");
    for x in xs {
        assert_eq!(x.len(), layer.cols);
    }
    for y in ys.iter() {
        assert_eq!(y.len(), layer.rows);
    }
    // lint:allow(hot-path-no-alloc) one O(batch) epilogue table per gemm
    // call; steady-state flatness is pinned by tests/alloc_steady.rs.
    let sum_x: Vec<f32> = xs.iter().map(|x| super::sum_seq(x)).collect();
    let cols = layer.cols;
    if super::par_rows(layer.rows, cols, xs.len()) {
        let writer = super::RowWriter::new(ys);
        crate::util::pool::global().scope_chunks(layer.rows, |range| {
            // per-worker scratch for the widened row tile
            // lint:allow(hot-path-no-alloc) one O(cols) tile per worker per
            // gemm call; steady-state pinned by tests/alloc_steady.rs.
            let mut wide = vec![0.0f32; cols];
            for r in range {
                let (s, qz) = layer.row_params[r];
                let codes = &layer.codes[r * cols..(r + 1) * cols];
                simd::widen_codes(codes, &mut wide, t);
                for (bi, x) in xs.iter().enumerate() {
                    let acc = simd::dot_t(&wide, x, t);
                    // SAFETY: each row lands in exactly one chunk.
                    unsafe { writer.set(bi, r, s * acc + s * qz * sum_x[bi]) };
                }
            }
        });
    } else {
        // lint:allow(hot-path-no-alloc) one O(cols) tile per gemm call.
        let mut wide = vec![0.0f32; cols];
        for r in 0..layer.rows {
            let (s, qz) = layer.row_params[r];
            let codes = &layer.codes[r * cols..(r + 1) * cols];
            simd::widen_codes(codes, &mut wide, t);
            for (bi, x) in xs.iter().enumerate() {
                let acc = simd::dot_t(&wide, x, t);
                ys[bi][r] = s * acc + s * qz * sum_x[bi];
            }
        }
    }
}

/// Batched `ys[b] = Ŵ·xs[b]` on the `Fast` numerics tier — the same
/// widen-once weight streaming and pool row-partition as
/// [`gemm_dequant`], with [`fast_math::dot_fast`] against the widened
/// tile and the fused epilogue of [`gemv_dequant_fast`]. Widening is
/// exact and the FMA dot keeps the pinned shape, so
/// `gemm_dequant_fast(B=1) == gemv_dequant_fast` per element.
// lint:allow(scalar-twin) Fast gemm wrapper: its reference is the Exact
// gemm (bitwise), and Fast-vs-Exact closeness is pinned per kernel by
// tests/numerics_tolerance.rs through Gemv::gemm_mode.
pub fn gemm_dequant_fast(layer: &IntLayer, xs: &[&[f32]], ys: &mut [Vec<f32>]) {
    assert_eq!(xs.len(), ys.len(), "gemm_dequant batch size mismatch");
    for x in xs {
        assert_eq!(x.len(), layer.cols);
    }
    for y in ys.iter() {
        assert_eq!(y.len(), layer.rows);
    }
    let t = simd::tier();
    // lint:allow(hot-path-no-alloc) one O(batch) epilogue table per gemm
    // call; steady-state flatness is pinned by tests/alloc_steady.rs.
    let sum_x: Vec<f32> = xs.iter().map(|x| super::sum_seq(x)).collect();
    let cols = layer.cols;
    if super::par_rows(layer.rows, cols, xs.len()) {
        let writer = super::RowWriter::new(ys);
        crate::util::pool::global().scope_chunks(layer.rows, |range| {
            // lint:allow(hot-path-no-alloc) one O(cols) widened tile per
            // worker per gemm call (tests/alloc_steady.rs pins flatness).
            let mut wide = vec![0.0f32; cols];
            for r in range {
                let (s, qz) = layer.row_params[r];
                let codes = &layer.codes[r * cols..(r + 1) * cols];
                simd::widen_codes(codes, &mut wide, t);
                for (bi, x) in xs.iter().enumerate() {
                    let acc = fast_math::dot_fast(&wide, x);
                    // SAFETY: each row lands in exactly one chunk.
                    // lint:allow(exact-tier-purity) Fast-tier epilogue FMA.
                    unsafe { writer.set(bi, r, (s * qz).mul_add(sum_x[bi], s * acc)) };
                }
            }
        });
    } else {
        // lint:allow(hot-path-no-alloc) one O(cols) tile per gemm call.
        let mut wide = vec![0.0f32; cols];
        for r in 0..layer.rows {
            let (s, qz) = layer.row_params[r];
            let codes = &layer.codes[r * cols..(r + 1) * cols];
            simd::widen_codes(codes, &mut wide, t);
            for (bi, x) in xs.iter().enumerate() {
                let acc = fast_math::dot_fast(&wide, x);
                // lint:allow(exact-tier-purity) Fast-tier epilogue FMA.
                ys[bi][r] = (s * qz).mul_add(sum_x[bi], s * acc);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemv_f32;
    use crate::quant::linear::rtn_quantize;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    #[test]
    fn matches_dense_on_dequantized_weights() {
        let mut rng = Rng::new(311);
        for (rows, cols) in [(8, 16), (33, 77), (128, 256)] {
            let w = Tensor::randn(rows, cols, 1.0, &mut rng);
            let (q, grids) = rtn_quantize(&w, 3);
            let il = IntLayer::encode(&q, &grids, 3);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
            let mut y = vec![0.0; rows];
            gemv_dequant(&il, &x, &mut y);
            let mut y_ref = vec![0.0; rows];
            gemv_f32(&q, &x, &mut y_ref);
            for (r, (a, b)) in y.iter().zip(&y_ref).enumerate() {
                let tol = 1e-4 * (cols as f32).sqrt() * (1.0 + b.abs());
                assert!((a - b).abs() < tol, "({rows}x{cols}) row {r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn gemm_is_bitwise_identical_to_gemv() {
        let mut rng = Rng::new(313);
        for (rows, cols) in [(8, 16), (33, 77)] {
            let w = Tensor::randn(rows, cols, 1.0, &mut rng);
            let (q, grids) = rtn_quantize(&w, 3);
            let il = IntLayer::encode(&q, &grids, 3);
            let xs: Vec<Vec<f32>> = (0..4)
                .map(|_| (0..cols).map(|_| rng.normal_f32()).collect())
                .collect();
            let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let mut ys: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; rows]).collect();
            gemm_dequant(&il, &refs, &mut ys);
            for (x, y) in xs.iter().zip(&ys) {
                let mut y_ref = vec![0.0; rows];
                gemv_dequant(&il, x, &mut y_ref);
                assert_eq!(y, &y_ref);
            }
        }
    }

    #[test]
    fn scalar_tier_is_bitwise_identical_to_dispatch() {
        let mut rng = Rng::new(314);
        let (rows, cols) = (17, 131);
        let w = Tensor::randn(rows, cols, 1.0, &mut rng);
        let (q, grids) = rtn_quantize(&w, 4);
        let il = IntLayer::encode(&q, &grids, 4);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
        let mut y_s = vec![0.0; rows];
        let mut y_d = vec![0.0; rows];
        gemv_dequant_scalar(&il, &x, &mut y_s);
        gemv_dequant(&il, &x, &mut y_d);
        assert_eq!(y_s, y_d, "gemv scalar vs dispatched");
        let xs: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..cols).map(|_| rng.normal_f32()).collect())
            .collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        let mut ys_s: Vec<Vec<f32>> = (0..3).map(|_| vec![0.0; rows]).collect();
        let mut ys_d = ys_s.clone();
        gemm_dequant_scalar(&il, &refs, &mut ys_s);
        gemm_dequant(&il, &refs, &mut ys_d);
        assert_eq!(ys_s, ys_d, "gemm scalar vs dispatched");
    }

    #[test]
    fn zero_activation_gives_zero_output() {
        let mut rng = Rng::new(312);
        let w = Tensor::randn(5, 12, 1.0, &mut rng);
        let (q, grids) = rtn_quantize(&w, 2);
        let il = IntLayer::encode(&q, &grids, 2);
        let x = vec![0.0f32; 12];
        let mut y = vec![1.0; 5];
        gemv_dequant(&il, &x, &mut y);
        assert!(y.iter().all(|&v| v.abs() < 1e-7));
    }
}
