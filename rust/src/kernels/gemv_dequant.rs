//! On-the-fly dequantize matvec — the GPTQ inference path.
//!
//! GPTQ stores linearly quantized integers and dequantizes to fp at
//! compute time (`Ŵ = S·(q + qz)`), paying a small arithmetic overhead
//! for the bandwidth saving (paper §III-E: "GPTQ dequantizes weights to
//! fp16 in real-time during computations, introducing a minor
//! computational overhead").
//!
//! The inner loop is restructured to avoid per-element dequantization:
//! `Σ_c S(q_c + qz)·x_c = S·(Σ_c q_c·x_c) + S·qz·(Σ_c x_c)` — one integer
//! ·f32 accumulation plus two scalars, which is both faster and exactly
//! equal (fp-associativity aside) to the naive form.

use crate::quant::linear::IntLayer;

/// Integer-code dot product for one row (4-way unrolled). Shared by the
/// single-sequence and batched paths so both produce bit-identical
/// results — the invariant the batched engine's token parity rests on.
#[inline]
fn row_code_dot(codes: &[u8], x: &[f32]) -> f32 {
    let cols = x.len();
    debug_assert_eq!(codes.len(), cols);
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let chunks = cols / 4;
    for i in 0..chunks {
        let o = i * 4;
        acc0 += codes[o] as f32 * x[o];
        acc1 += codes[o + 1] as f32 * x[o + 1];
        acc2 += codes[o + 2] as f32 * x[o + 2];
        acc3 += codes[o + 3] as f32 * x[o + 3];
    }
    let mut acc = (acc0 + acc1) + (acc2 + acc3);
    for c in chunks * 4..cols {
        acc += codes[c] as f32 * x[c];
    }
    acc
}

/// `y = Ŵ·x` over the integer layer.
pub fn gemv_dequant(layer: &IntLayer, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), layer.cols);
    assert_eq!(y.len(), layer.rows);
    let sum_x: f32 = x.iter().sum();
    let cols = layer.cols;
    for r in 0..layer.rows {
        let (s, qz) = layer.row_params[r];
        let codes = &layer.codes[r * cols..(r + 1) * cols];
        let acc = row_code_dot(codes, x);
        y[r] = s * acc + s * qz * sum_x;
    }
}

/// Batched `ys[b] = Ŵ·xs[b]`: each row's packed codes are streamed from
/// memory once and applied to every activation in the batch while they
/// sit in cache — the per-token weight traffic drops from
/// `packed_bytes()` to `packed_bytes() / B`. Per batch item the
/// arithmetic is exactly [`gemv_dequant`]'s (same unrolled accumulators,
/// same order), so batched and sequential decode agree bit-for-bit.
/// Calls with enough total work split rows across the pool; the row
/// partition keeps every output element's reduction order unchanged.
pub fn gemm_dequant(layer: &IntLayer, xs: &[&[f32]], ys: &mut [Vec<f32>]) {
    assert_eq!(xs.len(), ys.len(), "gemm_dequant batch size mismatch");
    for x in xs {
        assert_eq!(x.len(), layer.cols);
    }
    for y in ys.iter() {
        assert_eq!(y.len(), layer.rows);
    }
    let sum_x: Vec<f32> = xs.iter().map(|x| x.iter().sum()).collect();
    let cols = layer.cols;
    if super::par_rows(layer.rows, cols, xs.len()) {
        let writer = super::RowWriter::new(ys);
        crate::util::pool::global().scope_chunks(layer.rows, |range| {
            for r in range {
                let (s, qz) = layer.row_params[r];
                let codes = &layer.codes[r * cols..(r + 1) * cols];
                for (bi, x) in xs.iter().enumerate() {
                    let acc = row_code_dot(codes, x);
                    // Safety: each row lands in exactly one chunk.
                    unsafe { writer.set(bi, r, s * acc + s * qz * sum_x[bi]) };
                }
            }
        });
    } else {
        for r in 0..layer.rows {
            let (s, qz) = layer.row_params[r];
            let codes = &layer.codes[r * cols..(r + 1) * cols];
            for (bi, x) in xs.iter().enumerate() {
                let acc = row_code_dot(codes, x);
                ys[bi][r] = s * acc + s * qz * sum_x[bi];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemv_f32;
    use crate::quant::linear::rtn_quantize;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    #[test]
    fn matches_dense_on_dequantized_weights() {
        let mut rng = Rng::new(311);
        for (rows, cols) in [(8, 16), (33, 77), (128, 256)] {
            let w = Tensor::randn(rows, cols, 1.0, &mut rng);
            let (q, grids) = rtn_quantize(&w, 3);
            let il = IntLayer::encode(&q, &grids, 3);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
            let mut y = vec![0.0; rows];
            gemv_dequant(&il, &x, &mut y);
            let mut y_ref = vec![0.0; rows];
            gemv_f32(&q, &x, &mut y_ref);
            for (r, (a, b)) in y.iter().zip(&y_ref).enumerate() {
                let tol = 1e-4 * (cols as f32).sqrt() * (1.0 + b.abs());
                assert!((a - b).abs() < tol, "({rows}x{cols}) row {r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn gemm_is_bitwise_identical_to_gemv() {
        let mut rng = Rng::new(313);
        for (rows, cols) in [(8, 16), (33, 77)] {
            let w = Tensor::randn(rows, cols, 1.0, &mut rng);
            let (q, grids) = rtn_quantize(&w, 3);
            let il = IntLayer::encode(&q, &grids, 3);
            let xs: Vec<Vec<f32>> = (0..4)
                .map(|_| (0..cols).map(|_| rng.normal_f32()).collect())
                .collect();
            let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let mut ys: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; rows]).collect();
            gemm_dequant(&il, &refs, &mut ys);
            for (x, y) in xs.iter().zip(&ys) {
                let mut y_ref = vec![0.0; rows];
                gemv_dequant(&il, x, &mut y_ref);
                assert_eq!(y, &y_ref);
            }
        }
    }

    #[test]
    fn zero_activation_gives_zero_output() {
        let mut rng = Rng::new(312);
        let w = Tensor::randn(5, 12, 1.0, &mut rng);
        let (q, grids) = rtn_quantize(&w, 2);
        let il = IntLayer::encode(&q, &grids, 2);
        let x = vec![0.0f32; 12];
        let mut y = vec![1.0; 5];
        gemv_dequant(&il, &x, &mut y);
        assert!(y.iter().all(|&v| v.abs() < 1e-7));
    }
}
