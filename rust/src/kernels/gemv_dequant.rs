//! On-the-fly dequantize matvec — the GPTQ inference path.
//!
//! GPTQ stores linearly quantized integers and dequantizes to fp at
//! compute time (`Ŵ = S·(q + qz)`), paying a small arithmetic overhead
//! for the bandwidth saving (paper §III-E: "GPTQ dequantizes weights to
//! fp16 in real-time during computations, introducing a minor
//! computational overhead").
//!
//! The inner loop is restructured to avoid per-element dequantization:
//! `Σ_c S(q_c + qz)·x_c = S·(Σ_c q_c·x_c) + S·qz·(Σ_c x_c)` — one integer
//! ·f32 accumulation plus two scalars, which is both faster and exactly
//! equal (fp-associativity aside) to the naive form.

use crate::quant::linear::IntLayer;

/// `y = Ŵ·x` over the integer layer.
pub fn gemv_dequant(layer: &IntLayer, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), layer.cols);
    assert_eq!(y.len(), layer.rows);
    let sum_x: f32 = x.iter().sum();
    let cols = layer.cols;
    for r in 0..layer.rows {
        let (s, qz) = layer.row_params[r];
        let codes = &layer.codes[r * cols..(r + 1) * cols];
        let mut acc0 = 0.0f32;
        let mut acc1 = 0.0f32;
        let mut acc2 = 0.0f32;
        let mut acc3 = 0.0f32;
        let chunks = cols / 4;
        for i in 0..chunks {
            let o = i * 4;
            acc0 += codes[o] as f32 * x[o];
            acc1 += codes[o + 1] as f32 * x[o + 1];
            acc2 += codes[o + 2] as f32 * x[o + 2];
            acc3 += codes[o + 3] as f32 * x[o + 3];
        }
        let mut acc = (acc0 + acc1) + (acc2 + acc3);
        for c in chunks * 4..cols {
            acc += codes[c] as f32 * x[c];
        }
        y[r] = s * acc + s * qz * sum_x;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemv_f32;
    use crate::quant::linear::rtn_quantize;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    #[test]
    fn matches_dense_on_dequantized_weights() {
        let mut rng = Rng::new(311);
        for (rows, cols) in [(8, 16), (33, 77), (128, 256)] {
            let w = Tensor::randn(rows, cols, 1.0, &mut rng);
            let (q, grids) = rtn_quantize(&w, 3);
            let il = IntLayer::encode(&q, &grids, 3);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
            let mut y = vec![0.0; rows];
            gemv_dequant(&il, &x, &mut y);
            let mut y_ref = vec![0.0; rows];
            gemv_f32(&q, &x, &mut y_ref);
            for (r, (a, b)) in y.iter().zip(&y_ref).enumerate() {
                let tol = 1e-4 * (cols as f32).sqrt() * (1.0 + b.abs());
                assert!((a - b).abs() < tol, "({rows}x{cols}) row {r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn zero_activation_gives_zero_output() {
        let mut rng = Rng::new(312);
        let w = Tensor::randn(5, 12, 1.0, &mut rng);
        let (q, grids) = rtn_quantize(&w, 2);
        let il = IntLayer::encode(&q, &grids, 2);
        let x = vec![0.0f32; 12];
        let mut y = vec![1.0; 5];
        gemv_dequant(&il, &x, &mut y);
        assert!(y.iter().all(|&v| v.abs() < 1e-7));
    }
}
