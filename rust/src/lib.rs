//! # gptqt — Quantize Large Language Models Twice
//!
//! A full-stack reproduction of **“GPTQT: Quantize Large Language Models
//! Twice to Push the Efficiency”** (Guo, Lang, Ren — IEEE ICCIS 2024):
//! a post-training quantization method that (1) linearly quantizes LLM
//! weights to an intermediate high bit-width inside the GPTQ
//! error-compensation loop, (2) re-encodes the integer grid into a
//! lower-bit **binary coding** (`Σ αᵢ bᵢ + c`, `bᵢ ∈ {±1}`) chosen by
//! output-error grid search with a re-explored scale factor, and (3) fuses
//! both steps into a single pure binary coding at inference, enabling
//! LUT-GEMM-style matmuls.
//!
//! ## Architecture (three layers)
//!
//! * **L1 — Pallas kernels** (`python/compile/kernels/`): the binary-coded
//!   matmul and the dequant matmul, authored at build time, validated
//!   against pure-jnp oracles, lowered into the model HLO.
//! * **L2 — JAX model** (`python/compile/model.py`): decoder-only
//!   transformer variants (OPT-like, Llama-like, Bloom-like) AOT-lowered
//!   to HLO *text* artifacts.
//! * **L3 — this crate**: the runtime system. Quantization library
//!   ([`quant`]), CPU hot-path kernels ([`kernels`]), PJRT runtime
//!   ([`runtime`], behind the `pjrt` feature), streaming serving
//!   coordinator ([`coordinator`]), synthetic data ([`data`]),
//!   model/weight substrate ([`model`]), evaluation and experiment
//!   drivers ([`eval`]), and a micro-bench harness ([`bench`]).
//!
//! ## Serving surface: `Server` / `Backend` / `SchedulePolicy`
//!
//! The public serving API is the streaming session front-end
//! [`coordinator::Server`]: it owns the engine on a dedicated thread,
//! [`coordinator::Server::submit`] returns a channel-backed
//! [`coordinator::RequestHandle`] that yields every generated token as
//! an event the moment it is sampled, and handles support mid-flight
//! cancellation (paged-KV blocks return to the pool immediately) and
//! per-request deadlines. The engine itself is generic over the
//! [`coordinator::Backend`] trait — [`coordinator::CpuBackend`] for
//! the rust kernels below, [`coordinator::PjrtBackend`] for the XLA
//! executables — and its per-tick prefill-chunk decision is a
//! [`coordinator::SchedulePolicy`] object (fixed, or adaptive to
//! decode occupancy to bound inter-token latency). Streamed tokens are
//! bit-identical to offline `run_to_completion` serving under every
//! backend and policy; `tests/engine_server.rs` pins it.
//!
//! ## Serving hot path: one chunk-major forward core
//!
//! Every linear layer is a [`kernels::Gemv`] backend with two entry
//! points: single-sequence `gemv` (the paper's §III-E batch-1 latency
//! protocol) and batched `gemm`, which streams each weight row / packed
//! code byte **once per batch of activation vectors** instead of once
//! per vector — and, above a total-work threshold, fans its output rows
//! across the global thread pool. Single-token decode is
//! bandwidth-bound, so at batch B the per-token weight traffic drops to
//! `streamed_bytes / B` — the LUT-GEMM/FineQuant-style weight-reuse win
//! a multi-tenant server needs.
//!
//! The batch dimension carries more than concurrent decodes: the
//! private chunk-major core in `model::decode` flattens **per-sequence
//! token chunks** into the same gemm calls, so prefill processes T
//! prompt tokens per weight stream, the coordinator's `Engine::step`
//! advances prefilling *and* decoding sequences in one
//! `Backend::forward_tick` per tick (chunk length chosen by the
//! schedule policy), and full-sequence evaluation ([`model::Model::forward`],
//! `eval ppl` — including through the quantized backends) is the
//! degenerate one-chunk case. [`model::BackendModel::decode_step`],
//! [`model::BackendModel::decode_batch`],
//! [`model::BackendModel::prefill`], and
//! [`model::BackendModel::forward_chunk`] are all thin views of that
//! core. Per token the fp operation order is identical everywhere, so
//! chunked, batched, and sequential execution produce bit-identical
//! logits — `tests/kernel_parity.rs`, `tests/chunked_prefill.rs`, and
//! `tests/engine_batched.rs` pin it.
//!
//! Between the QKV and output gemms the core runs the **vectorized
//! attention subsystem** ([`kernels::attn`]): the per-sequence KV
//! caches are stored head-major (`layers × heads × max_seq × head_dim`,
//! [`model::KvCache`]), so each (row, head) work item streams one
//! contiguous K strip through `qk_dots` and one contiguous V strip
//! through `av_accumulate`, and ticks with enough attention work fan
//! the items across the same thread pool the gemms use. Activations
//! live in a per-engine [`model::ForwardScratch`] workspace threaded
//! through every `Backend::forward_tick`, and linear/norm handles are
//! resolved to indexed slots at `BackendModel` construction — a
//! steady-state decode tick does no per-row-per-layer heap allocation
//! and never hashes a layer name.
//!
//! Below the gemm and attention calls, every inner accumulation runs at
//! a runtime-dispatched SIMD tier ([`kernels::simd`]): explicit AVX2
//! (detected once via `is_x86_feature_detected!`) with a portable
//! scalar fallback. The AVX2 tier keeps the scalar tier's lane →
//! accumulator mapping, mul-then-add rounding (no FMA), and pinned
//! tree reduction, so **scalar and SIMD are bitwise identical** for
//! all three weight formats and the attention kernels — dispatch can
//! never change a served token; `tests/simd_parity.rs` and
//! `tests/attn_parity.rs` pin the decision per kernel.
//!
//! That bitwise discipline is one half of a **two-tier numerics
//! contract** ([`kernels::NumericsMode`]). `Exact` — the default
//! everywhere — is the tier above: identity is the spec, so results
//! are reproducible across machines and dispatch tiers. `Fast`
//! ([`kernels::fast_math`]) trades identity for throughput: FMA
//! contraction in the dot/axpy/gemm epilogues, a vectorized polynomial
//! `exp` behind silu/gelu/softmax, and a fused flash-style
//! online-softmax attention row that never materializes per-position
//! scores. Its spec is *tolerance* — per-kernel ULP/relative budgets
//! pinned by `tests/numerics_tolerance.rs` — plus one serving-level
//! guarantee: greedy decode emits the same tokens as `Exact`
//! (`tests/numerics_divergence.rs` counts divergences through
//! [`coordinator::Metrics`] and asserts zero). Within `Fast`, the
//! scalar fallback mirrors the AVX2+FMA path `mul_add`-for-`fmadd`
//! with the same pinned reduction tree, so the *relaxed* tier is still
//! deterministic per machine. The mode is threaded from
//! `EngineConfig::numerics` (CLI: `--numerics exact|fast`) through
//! `Backend::set_numerics` into every kernel dispatch. The
//! smoke benches (`cargo bench --bench kernels -- --smoke`, same for
//! `speed`) emit `BENCH_*.json` perf records — tagged with SIMD tier
//! and numerics mode — that CI archives on every PR.
//!
//! The two quantization steps also buy a serving-level speedup beyond
//! cheap weights: **self-speculative decoding**
//! ([`coordinator::SpeculativeBackend`]). The 2-bit binary-coding
//! encode of a model is a natural draft for its 3-bit (or dense)
//! target — same vocabulary, same calibration, no second training run.
//! Per engine tick the draft decodes `k` tokens autoregressively, the
//! target verifies all of them in **one** chunk-major batched forward
//! (k+1 positions of logits per weight stream — exactly the
//! amortization the forward core above exists for), and the engine
//! accepts the longest agreeing prefix plus the target's correction
//! token, rolling the paged KV back past the accept point
//! ([`model::KvCache::truncate_to`] +
//! [`coordinator::PagedKvManager::truncate_to`]). The acceptance rule
//! is argmax-based, so greedy output is **token-identical** to
//! target-only decoding — `tests/speculative.rs` pins it across
//! draft/target pairs and both numerics tiers, and the CI spec-parity
//! lane gates on its `spec-divergences-total: 0` line. Configured via
//! `EngineConfig::spec` (CLI: `gptqt serve --speculative`); acceptance
//! counters surface in [`coordinator::Metrics`] and the `serve spec`
//! bench records.
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` + trained weights once; the `gptqt` binary is
//! self-contained afterwards.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

// Explicit index loops are the idiom in the kernel/numeric code: the
// reduction order they spell out is load-bearing for the bitwise
// gemv == gemm parity contract, so don't let style lints rewrite them.
#![allow(clippy::needless_range_loop)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod kernels;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use tensor::Tensor;
