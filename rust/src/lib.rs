//! # gptqt — Quantize Large Language Models Twice
//!
//! A full-stack reproduction of **“GPTQT: Quantize Large Language Models
//! Twice to Push the Efficiency”** (Guo, Lang, Ren — IEEE ICCIS 2024):
//! a post-training quantization method that (1) linearly quantizes LLM
//! weights to an intermediate high bit-width inside the GPTQ
//! error-compensation loop, (2) re-encodes the integer grid into a
//! lower-bit **binary coding** (`Σ αᵢ bᵢ + c`, `bᵢ ∈ {±1}`) chosen by
//! output-error grid search with a re-explored scale factor, and (3) fuses
//! both steps into a single pure binary coding at inference, enabling
//! LUT-GEMM-style matmuls.
//!
//! ## Architecture (three layers)
//!
//! * **L1 — Pallas kernels** (`python/compile/kernels/`): the binary-coded
//!   matmul and the dequant matmul, authored at build time, validated
//!   against pure-jnp oracles, lowered into the model HLO.
//! * **L2 — JAX model** (`python/compile/model.py`): decoder-only
//!   transformer variants (OPT-like, Llama-like, Bloom-like) AOT-lowered
//!   to HLO *text* artifacts.
//! * **L3 — this crate**: the runtime system. Quantization library
//!   ([`quant`]), CPU hot-path kernels ([`kernels`]), PJRT runtime
//!   ([`runtime`], behind the `pjrt` feature), serving coordinator
//!   ([`coordinator`]), synthetic data ([`data`]), model/weight substrate
//!   ([`model`]), evaluation and experiment drivers ([`eval`]), and a
//!   micro-bench harness ([`bench`]).
//!
//! ## Serving hot path: gemv *and* batched gemm
//!
//! Every linear layer is a [`kernels::Gemv`] backend with two entry
//! points: single-sequence `gemv` (the paper's §III-E batch-1 latency
//! protocol) and batched `gemm`, which streams each weight row / packed
//! code byte **once per batch of concurrent sequences** instead of once
//! per sequence. Single-token decode is bandwidth-bound, so at batch B
//! the per-token weight traffic drops to `streamed_bytes / B` — the
//! LUT-GEMM/FineQuant-style weight-reuse win a multi-tenant server
//! needs. [`model::BackendModel::decode_batch`] threads the batched
//! kernels through the whole transformer step, and the coordinator's
//! `Engine::step` collects all runnable sequences into one batched
//! decode call per tick. Batched arithmetic is per-item identical to the
//! sequential path (same fp operation order), so generations are
//! token-identical either way — `tests/kernel_parity.rs` and
//! `tests/engine_batched.rs` pin both properties.
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` + trained weights once; the `gptqt` binary is
//! self-contained afterwards.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod kernels;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use tensor::Tensor;
