//! Scheduling policy — the per-tick chunk decision behind the engine.
//!
//! Each tick the engine advances every running sequence through one
//! shared forward: prefilling sequences contribute their next prompt
//! chunk, decoding sequences one token. The chunk length is the
//! prefill/decode interference knob: long chunks amortize weight
//! streaming harder but lengthen the tick, inflating the inter-token
//! latency of every co-scheduled decoding sequence — the very quantity
//! the paper's §III-E speed claims are about.
//!
//! [`SchedulePolicy`] makes that decision a first-class object:
//! [`FixedChunk`] feeds a constant chunk (the historical behavior),
//! [`AdaptiveChunk`] shrinks the chunk as decode occupancy rises to
//! bound inter-token latency and grows it back to the configured
//! maximum when the tick is prefill-only. Policies are selected via
//! [`super::EngineConfig::policy`]; custom implementations plug in
//! through [`super::Engine::with_policy`].
//!
//! Chunking never changes generated tokens: the chunk-major forward
//! core is bit-identical under any chunk split (pinned by
//! `tests/chunked_prefill.rs`), so a policy can only trade latency
//! against throughput — never correctness.

/// Occupancy snapshot a policy sees each tick, taken after admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TickState {
    /// Running sequences still consuming their prompt.
    pub prefilling: usize,
    /// Running sequences in the decode phase (one token per tick each).
    pub decoding: usize,
    /// Requests waiting in the admission queue.
    pub queued: usize,
}

/// Per-tick chunk/batch decision. `&mut self` so policies may carry
/// state (EWMA latency trackers, hysteresis, ...).
pub trait SchedulePolicy: Send {
    /// Prompt tokens each prefilling sequence feeds into this tick's
    /// shared forward. The engine clamps the result to
    /// `1..=EngineConfig::prefill_chunk`.
    fn chunk_for_tick(&mut self, tick: TickState) -> usize;

    /// Human label for reports.
    fn label(&self) -> &'static str;
}

/// Constant chunk length — the pre-policy engine behavior.
#[derive(Debug, Clone, Copy)]
pub struct FixedChunk(pub usize);

impl SchedulePolicy for FixedChunk {
    fn chunk_for_tick(&mut self, _tick: TickState) -> usize {
        self.0.max(1)
    }

    fn label(&self) -> &'static str {
        "fixed-chunk"
    }
}

/// Occupancy-adaptive chunking (the ROADMAP "adaptive chunk
/// scheduling" item): a prefill-only tick takes the full `max_chunk`
/// (nobody is waiting on a next token, so amortize the weight stream
/// as hard as possible); once sequences are decoding, the chunk
/// shrinks as `max_chunk / (decoding + 1)` so the tick length — and
/// with it every decoding sequence's inter-token latency — stays
/// roughly constant as occupancy rises.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveChunk {
    /// Upper bound (a prefill-only tick uses exactly this).
    pub max_chunk: usize,
    /// Lower bound under heavy decode pressure.
    pub min_chunk: usize,
}

impl AdaptiveChunk {
    pub fn new(max_chunk: usize) -> AdaptiveChunk {
        AdaptiveChunk { max_chunk: max_chunk.max(1), min_chunk: 1 }
    }
}

impl SchedulePolicy for AdaptiveChunk {
    fn chunk_for_tick(&mut self, tick: TickState) -> usize {
        if tick.decoding == 0 {
            self.max_chunk
        } else {
            (self.max_chunk / (tick.decoding + 1))
                .max(self.min_chunk.max(1))
                .min(self.max_chunk)
        }
    }

    fn label(&self) -> &'static str {
        "adaptive-chunk"
    }
}

/// Config-level policy selector ([`super::EngineConfig::policy`]).
/// The engine instantiates the policy with
/// `EngineConfig::prefill_chunk` as its chunk bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulePolicyKind {
    /// [`FixedChunk`] at `prefill_chunk` — the historical behavior.
    #[default]
    Fixed,
    /// [`AdaptiveChunk`] bounded by `prefill_chunk`.
    Adaptive,
}

impl SchedulePolicyKind {
    /// Parse a CLI-style name.
    pub fn parse(s: &str) -> Option<SchedulePolicyKind> {
        match s {
            "fixed" => Some(SchedulePolicyKind::Fixed),
            "adaptive" => Some(SchedulePolicyKind::Adaptive),
            _ => None,
        }
    }

    /// Build the policy object with `chunk` as its bound.
    pub fn build(self, chunk: usize) -> Box<dyn SchedulePolicy> {
        match self {
            SchedulePolicyKind::Fixed => Box::new(FixedChunk(chunk)),
            SchedulePolicyKind::Adaptive => Box::new(AdaptiveChunk::new(chunk)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tick(prefilling: usize, decoding: usize) -> TickState {
        TickState { prefilling, decoding, queued: 0 }
    }

    #[test]
    fn fixed_is_constant() {
        let mut p = FixedChunk(16);
        assert_eq!(p.chunk_for_tick(tick(1, 0)), 16);
        assert_eq!(p.chunk_for_tick(tick(4, 7)), 16);
        // degenerate zero config still feeds one token per tick
        assert_eq!(FixedChunk(0).chunk_for_tick(tick(1, 1)), 1);
    }

    #[test]
    fn adaptive_full_chunk_when_prefill_only() {
        let mut p = AdaptiveChunk::new(32);
        assert_eq!(p.chunk_for_tick(tick(3, 0)), 32);
    }

    #[test]
    fn adaptive_shrinks_with_decode_occupancy() {
        let mut p = AdaptiveChunk::new(32);
        let mut prev = usize::MAX;
        for decoding in 1..=16 {
            let c = p.chunk_for_tick(tick(2, decoding));
            assert!(c <= prev, "chunk grew as occupancy rose: {c} > {prev}");
            assert!((1..=32).contains(&c), "chunk {c} escaped the bound");
            prev = c;
        }
        // heavy decode pressure bottoms out at min_chunk
        assert_eq!(p.chunk_for_tick(tick(1, 100)), 1);
    }

    #[test]
    fn adaptive_never_exceeds_configured_bound() {
        for max in [1usize, 2, 7, 16, 64] {
            let mut p = AdaptiveChunk::new(max);
            for prefilling in 0..4 {
                for decoding in 0..20 {
                    let c = p.chunk_for_tick(tick(prefilling, decoding));
                    assert!(c >= 1 && c <= max, "chunk {c} outside 1..={max}");
                }
            }
        }
    }

    #[test]
    fn kind_builds_and_parses() {
        assert_eq!(SchedulePolicyKind::parse("fixed"), Some(SchedulePolicyKind::Fixed));
        assert_eq!(SchedulePolicyKind::parse("adaptive"), Some(SchedulePolicyKind::Adaptive));
        assert_eq!(SchedulePolicyKind::parse("nope"), None);
        assert_eq!(SchedulePolicyKind::Fixed.build(8).chunk_for_tick(tick(0, 3)), 8);
        assert!(SchedulePolicyKind::Adaptive.build(8).chunk_for_tick(tick(0, 3)) <= 8);
        assert_eq!(SchedulePolicyKind::default(), SchedulePolicyKind::Fixed);
    }
}
