//! Serving metrics: latency histograms + throughput counters.

use crate::util::{Histogram, Stopwatch};
use std::time::Duration;

/// Aggregated engine metrics (single-writer: the engine loop).
#[derive(Default)]
pub struct Metrics {
    pub queue_time: Histogram,
    pub ttft: Histogram,
    pub per_token: Histogram,
    pub e2e: Histogram,
    pub prompt_tokens: u64,
    pub generated_tokens: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Number of batched decode calls issued by the engine.
    pub decode_batches: u64,
    /// Sequences advanced across all batched decode calls (tokens
    /// decoded on the batched path).
    pub decode_batch_tokens: u64,
    /// Largest batch a single decode call carried — >1 means the engine
    /// actually amortized weight streaming across sequences.
    pub max_batch_occupancy: u64,
    /// Requests cancelled by the client (queued or mid-flight).
    pub cancelled_total: u64,
    /// Requests retired because their deadline passed.
    pub expired_total: u64,
    /// Largest per-tick prefill chunk the schedule policy chose —
    /// bounded by `EngineConfig::prefill_chunk` (tests pin this).
    pub max_tick_chunk: u64,
    /// Admissions that shared a cached prompt prefix.
    pub prefix_hits: u64,
    /// Admissions that found no cached prefix (cache enabled only).
    pub prefix_misses: u64,
    /// Prefixes published into the cache after prefill completed.
    pub prefix_insertions: u64,
    /// Cache entries evicted (LRU capacity or pool pressure).
    pub prefix_evictions: u64,
    /// Prompt tokens admitted without re-prefilling (Σ matched lengths).
    pub prefix_tokens_reused: u64,
    /// Prompt tokens actually pushed through the forward pass — with the
    /// cache on, `prefix_tokens_reused + prefill_tokens_computed` equals
    /// total admitted prompt tokens, which is how tests assert a hit
    /// skipped the matched fraction of prefill work.
    pub prefill_tokens_computed: u64,
    /// Gauge: blocks currently pinned by the prefix cache.
    pub prefix_blocks_pinned: u64,
    /// Gauge: most event sinks the server held at once.
    pub sinks_peak: u64,
    /// Gauge: sinks still registered when the server drained — any value
    /// above zero is a leak (tests pin zero).
    pub sinks_open_final: u64,
    /// TTFT of requests admitted via a prefix-cache hit.
    pub ttft_hit: Histogram,
    /// TTFT of requests prefilled from scratch.
    pub ttft_cold: Histogram,
    /// Numerics tier the backend served under
    /// ([`crate::kernels::NumericsMode::label`]); set from
    /// `EngineConfig::numerics` at engine construction.
    pub numerics_label: &'static str,
    /// Detected SIMD tier ([`crate::kernels::simd::SimdTier::label`]).
    pub simd_tier_label: &'static str,
    /// Greedy-decode token divergences observed between the `Fast` and
    /// `Exact` numerics tiers — recorded by the divergence harness
    /// ([`Metrics::record_greedy_divergences`]); the acceptance tests
    /// assert this stays 0.
    pub greedy_divergences: u64,
    /// Speculative draft/verify rounds executed (one per sequence per
    /// speculating tick).
    pub spec_ticks: u64,
    /// Tokens proposed by the draft model across all rounds.
    pub spec_drafted_total: u64,
    /// Drafted tokens the target model accepted (agreed with by argmax).
    pub spec_accepted_total: u64,
    /// KV positions written during drafting and then rolled back
    /// (rejected draft tokens plus any unused bonus position).
    pub spec_rolled_back_total: u64,
    /// Tokens emitted by speculative rounds — accepted draft tokens plus
    /// the target's correction/bonus token each round; `emitted / ticks`
    /// is the effective tokens-per-verify-pass multiplier.
    pub spec_emitted_total: u64,
    /// Requests terminated by a contained serving fault
    /// (`FinishReason::Failed(_)`): backend errors, pool exhaustion
    /// beyond admission, cache-import mismatch, spec-rollback
    /// violations, contained panics, and drain-deadline shutdowns.
    pub requests_failed: u64,
    /// Submissions shed by queue-depth admission control
    /// (`SubmitError::Full` → `Event::Rejected { retry_after }`).
    pub shed_total: u64,
    /// Ticks served in degraded mode — pool pressure past
    /// `EngineConfig::pressure_threshold` or the post-panic latch —
    /// with speculation and prefix insertion disabled.
    pub degraded_ticks: u64,
    /// Faults fired by `util::fault` injection points (`chaos` builds;
    /// always 0 in production builds).
    pub faults_injected: u64,
    /// Non-terminal events dropped by the `DropOldest` backpressure
    /// policy on slow consumers (terminal events are never dropped).
    pub events_dropped: u64,
    /// Gauge: free paged-KV blocks when the server drained — equal to
    /// `kv_blocks_total` unless blocks leaked (tests pin equality).
    pub kv_blocks_free_final: u64,
    /// Gauge: total paged-KV blocks in the pool.
    pub kv_blocks_total: u64,
    wall: Option<Stopwatch>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            wall: Some(Stopwatch::start()),
            numerics_label: crate::kernels::NumericsMode::Exact.label(),
            simd_tier_label: crate::kernels::simd::tier().label(),
            ..Default::default()
        }
    }

    pub fn record_queue(&mut self, d: Duration) {
        self.queue_time.record(d);
    }

    pub fn record_ttft(&mut self, d: Duration) {
        self.ttft.record(d);
    }

    /// Record TTFT split by how the request was admitted: `hit` requests
    /// skipped their matched prefix, cold requests prefilled everything.
    pub fn record_ttft_admission(&mut self, d: Duration, hit: bool) {
        if hit {
            self.ttft_hit.record(d);
        } else {
            self.ttft_cold.record(d);
        }
    }

    pub fn record_token(&mut self, d: Duration) {
        self.per_token.record(d);
        self.generated_tokens += 1;
    }

    pub fn record_done(&mut self, e2e: Duration, prompt_tokens: usize) {
        self.e2e.record(e2e);
        self.prompt_tokens += prompt_tokens as u64;
        self.completed += 1;
    }

    /// Record a client cancellation (queued or mid-flight).
    pub fn record_cancelled(&mut self) {
        self.cancelled_total += 1;
    }

    /// Record a deadline expiry.
    pub fn record_expired(&mut self) {
        self.expired_total += 1;
    }

    /// Record `n` greedy-decode token divergences between the `Fast`
    /// and `Exact` numerics tiers (the eval harness's end-to-end
    /// correctness check for [`crate::kernels::NumericsMode::Fast`]).
    pub fn record_greedy_divergences(&mut self, n: u64) {
        self.greedy_divergences += n;
    }

    /// Record one speculative draft/verify round for one sequence:
    /// `drafted` tokens proposed, `accepted` of them agreed with the
    /// target, `rolled_back` KV positions were truncated away, and
    /// `emitted` tokens actually streamed (accepted + correction/bonus,
    /// possibly cut short by EOS).
    pub fn record_spec(
        &mut self,
        drafted: usize,
        accepted: usize,
        rolled_back: usize,
        emitted: usize,
    ) {
        self.spec_ticks += 1;
        self.spec_drafted_total += drafted as u64;
        self.spec_accepted_total += accepted as u64;
        self.spec_rolled_back_total += rolled_back as u64;
        self.spec_emitted_total += emitted as u64;
    }

    /// Fraction of drafted tokens the target accepted (0 when nothing
    /// was drafted) — the headline speculative-decoding quality number.
    pub fn spec_acceptance_rate(&self) -> f64 {
        if self.spec_drafted_total == 0 {
            0.0
        } else {
            self.spec_accepted_total as f64 / self.spec_drafted_total as f64
        }
    }

    /// Record the chunk length the schedule policy chose for one tick.
    pub fn record_tick_chunk(&mut self, chunk: usize) {
        self.max_tick_chunk = self.max_tick_chunk.max(chunk as u64);
    }

    /// Record one batched decode call advancing `occupancy` sequences.
    pub fn record_batch(&mut self, occupancy: usize) {
        self.decode_batches += 1;
        self.decode_batch_tokens += occupancy as u64;
        self.max_batch_occupancy = self.max_batch_occupancy.max(occupancy as u64);
    }

    /// Record one batched forward step — the accounting both the CPU and
    /// PJRT engine paths share. `seqs` sequences shared the step's
    /// weight stream (the occupancy) and `emitted` sampled tokens came
    /// out of it. Each emitted token is attributed the **full** step
    /// latency: that is the inter-token gap a streaming client observes
    /// (every sequence advances once per tick), so co-scheduled prefill
    /// chunks visibly inflate it — the interference the
    /// `prefill_chunk` knob is tuned against. No-op when nothing was
    /// emitted (a tick that only advanced mid-prompt prefill chunks).
    pub fn record_batch_step(&mut self, elapsed: Duration, seqs: usize, emitted: usize) {
        if emitted == 0 {
            return;
        }
        self.record_batch(seqs);
        for _ in 0..emitted {
            self.record_token(elapsed);
        }
    }

    /// Mean sequences per batched decode call (0 when none ran).
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.decode_batches == 0 {
            0.0
        } else {
            self.decode_batch_tokens as f64 / self.decode_batches as f64
        }
    }

    /// Generated tokens per wall-clock second since engine start.
    pub fn throughput(&self) -> f64 {
        match &self.wall {
            Some(sw) if sw.elapsed_secs() > 0.0 => {
                self.generated_tokens as f64 / sw.elapsed_secs()
            }
            _ => 0.0,
        }
    }

    /// Multi-line human report.
    pub fn report(&self) -> String {
        format!(
            "completed={} cancelled={} expired={} rejected={} prompt_toks={} gen_toks={} \
             throughput={:.1} tok/s\n\
             numerics: mode={} simd={} greedy_divergences={}\n\
             spec    : ticks={} drafted={} accepted={} rolled_back={} emitted={} \
             accept_rate={:.3}\n\
             batch   : calls={} batch_toks={} mean_occupancy={:.2} max_occupancy={} \
             max_tick_chunk={}\n\
             prefix  : hits={} misses={} inserts={} evicts={} reused_toks={} \
             prefill_toks={} pinned_blocks={}\n\
             server  : sinks_peak={} sinks_open_final={} events_dropped={}\n\
             faults  : failed={} shed={} degraded_ticks={} injected={} \
             kv_free_final={} kv_total={}\n\
             queue   : {}\n\
             ttft    : {}\n\
             ttft-hit: {}\n\
             ttft-cold: {}\n\
             per-tok : {}\n\
             e2e     : {}",
            self.completed,
            self.cancelled_total,
            self.expired_total,
            self.rejected,
            self.prompt_tokens,
            self.generated_tokens,
            self.throughput(),
            self.numerics_label,
            self.simd_tier_label,
            self.greedy_divergences,
            self.spec_ticks,
            self.spec_drafted_total,
            self.spec_accepted_total,
            self.spec_rolled_back_total,
            self.spec_emitted_total,
            self.spec_acceptance_rate(),
            self.decode_batches,
            self.decode_batch_tokens,
            self.mean_batch_occupancy(),
            self.max_batch_occupancy,
            self.max_tick_chunk,
            self.prefix_hits,
            self.prefix_misses,
            self.prefix_insertions,
            self.prefix_evictions,
            self.prefix_tokens_reused,
            self.prefill_tokens_computed,
            self.prefix_blocks_pinned,
            self.sinks_peak,
            self.sinks_open_final,
            self.events_dropped,
            self.requests_failed,
            self.shed_total,
            self.degraded_ticks,
            self.faults_injected,
            self.kv_blocks_free_final,
            self.kv_blocks_total,
            self.queue_time.summary(),
            self.ttft.summary(),
            self.ttft_hit.summary(),
            self.ttft_cold.summary(),
            self.per_token.summary(),
            self.e2e.summary(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let mut m = Metrics::new();
        m.record_queue(Duration::from_millis(1));
        m.record_ttft(Duration::from_millis(10));
        for _ in 0..5 {
            m.record_token(Duration::from_millis(2));
        }
        m.record_done(Duration::from_millis(20), 7);
        assert_eq!(m.generated_tokens, 5);
        assert_eq!(m.prompt_tokens, 7);
        assert_eq!(m.completed, 1);
        let r = m.report();
        assert!(r.contains("completed=1"));
        assert!(r.contains("per-tok"));
        // the active numerics mode + SIMD tier surface in the summary
        assert!(r.contains("mode=exact"), "{r}");
        assert!(r.contains("greedy_divergences=0"), "{r}");
    }

    #[test]
    fn greedy_divergences_accumulate_and_surface() {
        let mut m = Metrics::new();
        assert_eq!(m.numerics_label, "exact");
        m.numerics_label = crate::kernels::NumericsMode::Fast.label();
        m.record_greedy_divergences(0);
        m.record_greedy_divergences(2);
        assert_eq!(m.greedy_divergences, 2);
        let r = m.report();
        assert!(r.contains("mode=fast"), "{r}");
        assert!(r.contains("greedy_divergences=2"), "{r}");
    }

    #[test]
    fn batch_step_attributes_full_tick_latency() {
        let mut m = Metrics::new();
        // 4 sequences advanced, 4 sampled tokens: each token sees the
        // whole tick as its inter-token latency
        m.record_batch_step(Duration::from_millis(20), 4, 4);
        assert_eq!(m.generated_tokens, 4);
        assert_eq!(m.decode_batches, 1);
        assert_eq!(m.max_batch_occupancy, 4);
        // an all-mid-prompt tick records nothing
        m.record_batch_step(Duration::from_millis(5), 4, 0);
        assert_eq!(m.generated_tokens, 4);
        assert_eq!(m.decode_batches, 1);
    }

    #[test]
    fn cancellation_and_expiry_surface_in_report() {
        let mut m = Metrics::new();
        m.record_cancelled();
        m.record_cancelled();
        m.record_expired();
        m.record_tick_chunk(4);
        m.record_tick_chunk(16);
        m.record_tick_chunk(8);
        assert_eq!(m.cancelled_total, 2);
        assert_eq!(m.expired_total, 1);
        assert_eq!(m.max_tick_chunk, 16);
        let r = m.report();
        assert!(r.contains("cancelled=2"), "{r}");
        assert!(r.contains("expired=1"), "{r}");
        assert!(r.contains("max_tick_chunk=16"), "{r}");
    }

    #[test]
    fn prefix_counters_surface_in_report() {
        let mut m = Metrics::new();
        m.prefix_hits = 3;
        m.prefix_misses = 2;
        m.prefix_insertions = 2;
        m.prefix_evictions = 1;
        m.prefix_tokens_reused = 40;
        m.prefill_tokens_computed = 17;
        m.record_ttft_admission(Duration::from_millis(2), true);
        m.record_ttft_admission(Duration::from_millis(9), false);
        assert_eq!(m.ttft_hit.count(), 1);
        assert_eq!(m.ttft_cold.count(), 1);
        let r = m.report();
        assert!(r.contains("hits=3"), "{r}");
        assert!(r.contains("reused_toks=40"), "{r}");
        assert!(r.contains("prefill_toks=17"), "{r}");
        assert!(r.contains("ttft-hit"), "{r}");
    }

    #[test]
    fn spec_counters_accumulate_and_surface() {
        let mut m = Metrics::new();
        assert_eq!(m.spec_acceptance_rate(), 0.0, "no drafts yet");
        // round 1: k=4 drafted, 2 accepted → correction token, rollback 2
        m.record_spec(4, 2, 2, 3);
        // round 2: full accept → bonus token, nothing rolled back
        m.record_spec(4, 4, 0, 5);
        assert_eq!(m.spec_ticks, 2);
        assert_eq!(m.spec_drafted_total, 8);
        assert_eq!(m.spec_accepted_total, 6);
        assert_eq!(m.spec_rolled_back_total, 2);
        assert_eq!(m.spec_emitted_total, 8);
        assert!((m.spec_acceptance_rate() - 0.75).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("spec    : ticks=2 drafted=8 accepted=6"), "{r}");
        assert!(r.contains("accept_rate=0.750"), "{r}");
    }

    #[test]
    fn fault_counters_surface_in_report() {
        let mut m = Metrics::new();
        m.requests_failed = 3;
        m.shed_total = 7;
        m.degraded_ticks = 11;
        m.faults_injected = 5;
        m.events_dropped = 2;
        m.kv_blocks_free_final = 64;
        m.kv_blocks_total = 64;
        let r = m.report();
        assert!(r.contains("failed=3"), "{r}");
        assert!(r.contains("shed=7"), "{r}");
        assert!(r.contains("degraded_ticks=11"), "{r}");
        assert!(r.contains("injected=5"), "{r}");
        assert!(r.contains("events_dropped=2"), "{r}");
        assert!(r.contains("kv_free_final=64 kv_total=64"), "{r}");
    }

    #[test]
    fn batch_occupancy_tracks_mean_and_max() {
        let mut m = Metrics::new();
        assert_eq!(m.mean_batch_occupancy(), 0.0);
        m.record_batch(1);
        m.record_batch(3);
        m.record_batch(8);
        assert_eq!(m.decode_batches, 3);
        assert_eq!(m.max_batch_occupancy, 8);
        assert!((m.mean_batch_occupancy() - 4.0).abs() < 1e-9);
        assert!(m.report().contains("max_occupancy=8"));
    }
}
