//! Continuous batcher: admission policy from queue → running set.
//!
//! Each scheduling tick admits requests while (a) the running set is
//! below `max_batch`, (b) the paged KV manager can commit the request's
//! worst case, and (c) the per-tick prefill token budget is not blown
//! (long prompts otherwise starve decoding sequences — the classic
//! prefill/decode interference continuous batching exists to manage).

use super::kv_pool::PagedKvManager;
use super::queue::RequestQueue;
use super::request::Request;

/// Admission policy knobs. (The per-tick prefill *chunk* decision lives
/// in [`super::policy::SchedulePolicy`] — the batcher only decides what
/// enters the running set.)
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    /// Max prompt tokens admitted per tick.
    pub prefill_token_budget: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, prefill_token_budget: 512 }
    }
}

/// Stateless admission policy (state lives in queue + kv manager).
pub struct Batcher {
    pub cfg: BatcherConfig,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher { cfg }
    }

    /// Pull admissible requests from the queue. `running` is the current
    /// decoding-set size. Requests that don't fit the KV commitment are
    /// pushed back (they retry next tick — FIFO order is preserved by
    /// the queue's sequence numbers only for *newly* arrived requests;
    /// a pushed-back head blocks lower-priority work, which is the
    /// head-of-line behaviour we want for fairness).
    pub fn admit(
        &self,
        queue: &RequestQueue,
        running: usize,
        kv: &mut PagedKvManager,
    ) -> Vec<Request> {
        self.admit_with(queue, running, kv, &mut |req, kv| {
            kv.admit(req.id, req.prompt.len(), req.max_tokens())
        })
    }

    /// [`Batcher::admit`] with a pluggable per-request KV admission
    /// attempt — the engine passes a closure that consults the prefix
    /// cache first (shared admission, pressure eviction) and falls back
    /// to a cold [`PagedKvManager::admit`]. The closure must either
    /// admit `req.id` into `kv` and return true, or leave `kv` untouched
    /// for that sequence and return false.
    pub fn admit_with(
        &self,
        queue: &RequestQueue,
        running: usize,
        kv: &mut PagedKvManager,
        try_admit: &mut dyn FnMut(&Request, &mut PagedKvManager) -> bool,
    ) -> Vec<Request> {
        let mut admitted = Vec::new();
        let mut prefill_budget = self.cfg.prefill_token_budget;
        while running + admitted.len() < self.cfg.max_batch {
            let Some(req) = queue.try_pop() else { break };
            if req.prompt.len() > prefill_budget && !admitted.is_empty() {
                // would blow the tick budget — retry next tick
                let _ = queue.push(req);
                break;
            }
            if !try_admit(&req, kv) {
                // no KV headroom: park it and stop admitting (anything
                // later is same or lower priority)
                let _ = queue.push(req);
                break;
            }
            prefill_budget = prefill_budget.saturating_sub(req.prompt.len());
            admitted.push(req);
        }
        admitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize, gen: usize) -> Request {
        Request::new(id, vec![7; prompt], gen)
    }

    #[test]
    fn admits_up_to_max_batch() {
        let q = RequestQueue::new(64);
        for id in 0..10 {
            q.push(req(id, 4, 4)).unwrap();
        }
        let mut kv = PagedKvManager::new(1024, 16);
        let b = Batcher::new(BatcherConfig { max_batch: 4, prefill_token_budget: 1000 });
        let admitted = b.admit(&q, 0, &mut kv);
        assert_eq!(admitted.len(), 4);
        assert_eq!(q.len(), 6);
        // with 2 already running only 2 more fit
        let admitted2 = b.admit(&q, 2, &mut kv);
        assert_eq!(admitted2.len(), 2);
    }

    #[test]
    fn respects_kv_headroom() {
        let q = RequestQueue::new(64);
        q.push(req(1, 16, 16)).unwrap(); // 2 blocks worst case
        q.push(req(2, 64, 64)).unwrap(); // 8 blocks worst case
        q.push(req(3, 4, 4)).unwrap();
        let mut kv = PagedKvManager::new(4, 16);
        let b = Batcher::new(BatcherConfig::default());
        let admitted = b.admit(&q, 0, &mut kv);
        // req 1 admits (2 blocks), req 2 doesn't fit → stop (head of line)
        assert_eq!(admitted.len(), 1);
        assert_eq!(admitted[0].id, 1);
        assert_eq!(q.len(), 2);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn prefill_budget_defers_long_prompts() {
        let q = RequestQueue::new(64);
        q.push(req(1, 100, 4)).unwrap();
        q.push(req(2, 100, 4)).unwrap();
        let mut kv = PagedKvManager::new(1024, 16);
        let b = Batcher::new(BatcherConfig { max_batch: 8, prefill_token_budget: 128 });
        let admitted = b.admit(&q, 0, &mut kv);
        // first long prompt admits (budget applies after the first),
        // second is deferred to the next tick
        assert_eq!(admitted.len(), 1);
        assert_eq!(q.len(), 1);
        let admitted2 = b.admit(&q, 1, &mut kv);
        assert_eq!(admitted2.len(), 1);
    }

    #[test]
    fn empty_queue_admits_nothing() {
        let q = RequestQueue::new(4);
        let mut kv = PagedKvManager::new(16, 16);
        let b = Batcher::new(BatcherConfig::default());
        assert!(b.admit(&q, 0, &mut kv).is_empty());
    }
}
