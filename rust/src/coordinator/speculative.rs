//! Self-speculative decoding — GPTQT's two quantization steps as a
//! draft/target pair.
//!
//! GPTQT quantizes twice: a higher-bit linear stage, then a low-bit
//! binary re-encoding. Every served model therefore ships with a cheap
//! sibling for free — the 2-bit binary-coding backend drafts, the 3-bit
//! (or dense) target verifies. [`SpeculativeBackend`] packages the pair
//! as one [`Backend`], so the engine, server, prefix cache, and metrics
//! all work unchanged.
//!
//! # Draft → verify → accept/rollback (one round per tick)
//!
//! For a decoding sequence whose last sampled token is `x₀`:
//!
//! 1. **Draft.** The draft model decodes `k` tokens `d₁..d_k`
//!    autoregressively by greedy argmax, starting from `x₀` (batched
//!    across sequences — one cheap weight stream per round).
//! 2. **Verify.** The target model consumes the chunk `[x₀, d₁..d_k]`
//!    in **one** chunk-major forward
//!    ([`crate::model::BackendModel::forward_chunks_all_with`]) and
//!    returns every position's logits — `k+1` target distributions for
//!    the cost of one weight stream, which is exactly what the batched
//!    forward core of PRs 1–2 was built to amortize.
//! 3. **Accept.** Position `i`'s target argmax `t_{i+1}` is compared to
//!    the drafted `d_{i+1}`: agreeing tokens are accepted left to
//!    right; the first disagreement emits `t` as the **correction**
//!    token and stops; if all `k` agree, position `k`'s argmax is a
//!    free **bonus** token. Every round therefore emits
//!    `accepted + 1 ∈ 1..=k+1` tokens, all of them exactly the tokens
//!    target-only greedy decoding would have produced — speculation
//!    changes latency, never output (pinned by `tests/speculative.rs`).
//! 4. **Rollback.** Both KV caches are truncated back to the accepted
//!    history ([`SpecCapable::truncate_kv`]); the engine mirrors the
//!    rollback into the paged pool
//!    ([`super::kv_pool::PagedKvManager::truncate_to`]), re-crediting
//!    the freed blocks. On a full accept the draft cache instead
//!    catches up by one position (the bonus token's predecessor was
//!    never fed to it).
//!
//! The wrapper keeps the two caches in lockstep everywhere else:
//! [`Backend::forward_tick`] (prefill and non-greedy decode) advances
//! both, and prefix-cache snapshot/import carry both or neither.

use super::engine::Backend;
use crate::tensor::Tensor;
use anyhow::Result;

/// Which weight format the draft model is quantized to — GPTQT's cheap
/// second-step encodings, or dense for ablations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DraftFormat {
    /// 2-bit binary coding (LUT-GEMM) — the paper-native draft.
    Lut2,
    /// 3-bit binary coding.
    Lut3,
    /// Unquantized f32 (ablation baseline; drafts are free of
    /// quantization error but stream full-width weights).
    Dense,
}

impl DraftFormat {
    /// Parse a CLI spelling (`lut2` / `lut3` / `dense`).
    pub fn parse(s: &str) -> Result<DraftFormat, String> {
        match s {
            "lut2" => Ok(DraftFormat::Lut2),
            "lut3" => Ok(DraftFormat::Lut3),
            "dense" => Ok(DraftFormat::Dense),
            other => Err(format!("unknown draft format '{other}' (expected lut2|lut3|dense)")),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            DraftFormat::Lut2 => "lut2",
            DraftFormat::Lut3 => "lut3",
            DraftFormat::Dense => "dense",
        }
    }
}

/// Speculative-decoding knobs, threaded through
/// [`super::EngineConfig::spec`] and `gptqt serve --speculative`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecConfig {
    /// Whether the serving stack should build/drive a draft model at
    /// all. Off by default — speculation is an opt-in speed multiplier.
    pub enabled: bool,
    /// Draft tokens proposed per round (clamped per sequence so the
    /// round never overruns the request's generation budget).
    pub k: usize,
    /// Weight format the draft model is built in.
    pub draft_format: DraftFormat,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig { enabled: false, k: 4, draft_format: DraftFormat::Lut2 }
    }
}

/// Result of one draft/verify round for one sequence. `tokens` is what
/// the sequence emits this round (accepted drafts + correction/bonus,
/// `accepted + 1` of them); `drafted`/`accepted` feed the metrics.
#[derive(Debug, Clone)]
pub struct SpecOutcome {
    pub tokens: Vec<u32>,
    pub drafted: usize,
    pub accepted: usize,
}

/// Extra surface a backend must expose beyond [`Backend`] to take part
/// in draft/verify: all-position logits for a chunk (the verify
/// kernel), KV truncation (the rollback), and the current KV length
/// (the rollback anchor).
pub trait SpecCapable: Backend {
    /// Advance each chunk against its cache and return **every**
    /// position's logits (`Tᵦ × vocab` per chunk) — must be per-token
    /// bitwise identical to feeding the tokens one at a time.
    fn forward_chunk_all(
        &self,
        chunks: &[&[u32]],
        caches: &mut [&mut Self::Kv],
        scratch: &mut Self::Scratch,
    ) -> Result<Vec<Tensor>>;

    /// Forget every cached position at index `len` and beyond.
    fn truncate_kv(&self, cache: &mut Self::Kv, len: usize);

    /// Number of positions currently stored in `cache`.
    fn kv_len(&self, cache: &Self::Kv) -> usize;
}

impl SpecCapable for super::engine::CpuBackend {
    fn forward_chunk_all(
        &self,
        chunks: &[&[u32]],
        caches: &mut [&mut crate::model::KvCache],
        scratch: &mut crate::model::ForwardScratch,
    ) -> Result<Vec<Tensor>> {
        Ok(self.0.forward_chunks_all_with(chunks, caches, scratch))
    }

    fn truncate_kv(&self, cache: &mut crate::model::KvCache, len: usize) {
        cache.truncate_to(len);
    }

    fn kv_len(&self, cache: &crate::model::KvCache) -> usize {
        cache.len
    }
}

/// Paired draft/target KV state for one sequence. The two caches cover
/// the same token history at all times outside a `spec_tick` round.
pub struct SpecKv<DK, TK> {
    pub draft: DK,
    pub target: TK,
}

/// Paired forward workspaces (contents carry nothing between ticks).
#[derive(Default)]
pub struct SpecScratch<DS, TS> {
    draft: DS,
    target: TS,
}

/// Two models, one [`Backend`]: the draft decodes cheap candidate
/// tokens, the target verifies them in one chunk-major pass. Greedy
/// output is token-identical to serving the target alone; the draft
/// only decides how many target weight streams that output costs.
pub struct SpeculativeBackend<D: SpecCapable, T: SpecCapable> {
    draft: D,
    target: T,
    k: usize,
}

impl<D: SpecCapable, T: SpecCapable> SpeculativeBackend<D, T> {
    /// Pair a draft with a target. Both must share one tokenizer/vocab
    /// (the acceptance rule compares token ids) — the construction sites
    /// (`eval::cmd::serve`, `eval::speed`) build both from the same
    /// [`crate::model::Model`], which guarantees it.
    pub fn new(draft: D, target: T, k: usize) -> SpeculativeBackend<D, T> {
        assert!(k >= 1, "speculative k must be at least 1");
        SpeculativeBackend { draft, target, k }
    }

    /// Draft tokens proposed per round.
    pub fn k(&self) -> usize {
        self.k
    }

    /// The verifying (served) model.
    pub fn target(&self) -> &T {
        &self.target
    }

    /// The drafting model.
    pub fn draft(&self) -> &D {
        &self.draft
    }

    /// One draft/verify/accept/rollback round for every sequence.
    /// `last[b]` is sequence `b`'s newest sampled (not yet fed) token
    /// and `budgets[b]` its remaining generation budget (≥ 1). See the
    /// module docs for the protocol; the length bookkeeping invariant
    /// is: both caches enter at `len = L` (token `last` unfed) and
    /// leave at `len = L + outcome.tokens.len()` (newest emitted token
    /// unfed), exactly as if the emitted tokens had been served one
    /// normal tick at a time.
    fn run_round(
        &self,
        last: &[u32],
        caches: &mut [&mut SpecKv<D::Kv, T::Kv>],
        budgets: &[usize],
        scratch: &mut SpecScratch<D::Scratch, T::Scratch>,
    ) -> Result<Vec<SpecOutcome>> {
        let nb = last.len();
        debug_assert_eq!(caches.len(), nb);
        debug_assert_eq!(budgets.len(), nb);
        let base: Vec<usize> = caches.iter().map(|c| self.target.kv_len(&c.target)).collect();
        // per-sequence draft allotment: a round emits accepted + 1
        // tokens, so drafting more than budget − 1 could overrun the
        // request's max_new_tokens on a full accept
        let ks: Vec<usize> = budgets.iter().map(|&b| self.k.min(b.saturating_sub(1))).collect();

        // ---- draft phase: batched greedy decode on the cheap model ----
        let mut drafts: Vec<Vec<u32>> = vec![Vec::new(); nb];
        let mut cur: Vec<u32> = last.to_vec();
        let kmax = ks.iter().copied().max().unwrap_or(0);
        let mut sel: Vec<usize> = Vec::new();
        let mut toks: Vec<u32> = Vec::new();
        for round in 0..kmax {
            sel.clear();
            toks.clear();
            for b in 0..nb {
                if round < ks[b] {
                    sel.push(b);
                    toks.push(cur[b]);
                }
            }
            if sel.is_empty() {
                break;
            }
            let chunks: Vec<&[u32]> = toks.iter().map(std::slice::from_ref).collect();
            let need = vec![true; sel.len()];
            let mut dcaches: Vec<&mut D::Kv> = Vec::with_capacity(sel.len());
            let mut want = sel.iter().peekable();
            for (b, c) in caches.iter_mut().enumerate() {
                if want.peek() == Some(&&b) {
                    want.next();
                    dcaches.push(&mut c.draft);
                }
            }
            let logits =
                self.draft.forward_tick(&chunks, &mut dcaches, &need, &mut scratch.draft)?;
            for (si, &b) in sel.iter().enumerate() {
                // lint:allow(no-panic-serve) `need` was all-true for this
                // forward: a missing row is a backend contract violation
                let l = logits[si].as_ref().expect("draft round requested logits");
                let t = super::sampler::argmax(l);
                drafts[b].push(t);
                cur[b] = t;
            }
        }

        // ---- verify phase: one chunk-major target forward -------------
        // chunk b = [last, d₁..d_k]: position i's logits are the target
        // distribution after i accepted tokens — k+1 verdicts per weight
        // stream (k = 0 degenerates to plain single-token decode)
        let vstore: Vec<Vec<u32>> = (0..nb)
            .map(|b| {
                let mut v = Vec::with_capacity(1 + drafts[b].len());
                v.push(last[b]);
                v.extend_from_slice(&drafts[b]);
                v
            })
            .collect();
        let vchunks: Vec<&[u32]> = vstore.iter().map(|v| v.as_slice()).collect();
        let mut tcaches: Vec<&mut T::Kv> = caches.iter_mut().map(|c| &mut c.target).collect();
        let all = self.target.forward_chunk_all(&vchunks, &mut tcaches, &mut scratch.target)?;
        drop(tcaches);

        // ---- accept + rollback ----------------------------------------
        let mut out: Vec<SpecOutcome> = Vec::with_capacity(nb);
        let mut full_accept = vec![false; nb];
        for b in 0..nb {
            let k_b = drafts[b].len();
            let logits = &all[b];
            let mut tokens = Vec::with_capacity(k_b + 1);
            let mut accepted = 0usize;
            for i in 0..k_b {
                let t = super::sampler::argmax(logits.row(i));
                tokens.push(t);
                if t != drafts[b][i] {
                    break; // correction token: target overrules the draft
                }
                accepted += 1;
            }
            if accepted == k_b {
                // every draft agreed (or k = 0): the last position's
                // argmax is the bonus / plain-decode token
                tokens.push(super::sampler::argmax(logits.row(k_b)));
                full_accept[b] = true;
            }
            debug_assert_eq!(tokens.len(), accepted + 1);
            // roll the target back past the rejected tail: it consumed
            // k_b + 1 positions but only `last` + accepted drafts are
            // real history
            self.target.truncate_kv(&mut caches[b].target, base[b] + 1 + accepted);
            if !full_accept[b] {
                // the draft consumed k_b positions (last, d₁..d_{k-1});
                // keep the same accepted history
                self.draft.truncate_kv(&mut caches[b].draft, base[b] + 1 + accepted);
            }
            out.push(SpecOutcome { tokens, drafted: k_b, accepted });
        }

        // ---- draft catch-up for full accepts --------------------------
        // the draft never fed its own final token d_k (or, at k = 0,
        // `last`): feed it now, logits unneeded, so both caches leave at
        // base + accepted + 1 with the newest emitted token unfed
        sel.clear();
        toks.clear();
        for b in 0..nb {
            if full_accept[b] {
                sel.push(b);
                // lint:allow(no-panic-serve) vstore[b] always holds `last`
                // plus the drafts — built non-empty a screen above
                toks.push(*vstore[b].last().expect("verify chunk is never empty"));
            }
        }
        if !sel.is_empty() {
            let chunks: Vec<&[u32]> = toks.iter().map(std::slice::from_ref).collect();
            let need = vec![false; sel.len()];
            let mut dcaches: Vec<&mut D::Kv> = Vec::with_capacity(sel.len());
            let mut want = sel.iter().peekable();
            for (b, c) in caches.iter_mut().enumerate() {
                if want.peek() == Some(&&b) {
                    want.next();
                    dcaches.push(&mut c.draft);
                }
            }
            self.draft.forward_tick(&chunks, &mut dcaches, &need, &mut scratch.draft)?;
        }

        if cfg!(debug_assertions) {
            for (b, c) in caches.iter().enumerate() {
                debug_assert_eq!(
                    self.target.kv_len(&c.target),
                    base[b] + out[b].tokens.len(),
                    "target cache out of lockstep after round"
                );
                debug_assert_eq!(
                    self.draft.kv_len(&c.draft),
                    base[b] + out[b].tokens.len(),
                    "draft cache out of lockstep after round"
                );
            }
        }
        Ok(out)
    }
}

impl<D: SpecCapable, T: SpecCapable> Backend for SpeculativeBackend<D, T> {
    type Kv = SpecKv<D::Kv, T::Kv>;
    type Scratch = SpecScratch<D::Scratch, T::Scratch>;

    fn capacity(&self) -> usize {
        self.draft.capacity().min(self.target.capacity())
    }

    fn new_cache(&self) -> Result<Self::Kv> {
        Ok(SpecKv { draft: self.draft.new_cache()?, target: self.target.new_cache()? })
    }

    /// The non-speculative path (prefill chunks, non-greedy decode):
    /// advance **both** caches with the same tokens so they stay in
    /// lockstep, and serve the **target's** logits — sampling always
    /// follows the verifying model, so non-greedy requests too are
    /// distributed exactly as target-only serving.
    fn forward_tick(
        &self,
        chunks: &[&[u32]],
        caches: &mut [&mut Self::Kv],
        need: &[bool],
        scratch: &mut Self::Scratch,
    ) -> Result<Vec<Option<Vec<f32>>>> {
        let no_need = vec![false; chunks.len()];
        {
            let mut dcaches: Vec<&mut D::Kv> = caches.iter_mut().map(|c| &mut c.draft).collect();
            self.draft.forward_tick(chunks, &mut dcaches, &no_need, &mut scratch.draft)?;
        }
        let mut tcaches: Vec<&mut T::Kv> = caches.iter_mut().map(|c| &mut c.target).collect();
        self.target.forward_tick(chunks, &mut tcaches, need, &mut scratch.target)
    }

    fn batch_amortized(&self) -> bool {
        self.target.batch_amortized()
    }

    fn snapshot_kv_prefix(&self, cache: &Self::Kv, tokens: usize) -> Option<Self::Kv> {
        Some(SpecKv {
            draft: self.draft.snapshot_kv_prefix(&cache.draft, tokens)?,
            target: self.target.snapshot_kv_prefix(&cache.target, tokens)?,
        })
    }

    fn import_kv_prefix(&self, dst: &mut Self::Kv, src: &Self::Kv, tokens: usize) -> bool {
        if !self.draft.import_kv_prefix(&mut dst.draft, &src.draft, tokens) {
            return false;
        }
        if !self.target.import_kv_prefix(&mut dst.target, &src.target, tokens) {
            // keep the pair consistent: forget the draft-side import so
            // the engine's cold-prefill fallback refills both from zero
            self.draft.truncate_kv(&mut dst.draft, 0);
            return false;
        }
        true
    }

    fn set_numerics(&mut self, mode: crate::kernels::NumericsMode) {
        self.draft.set_numerics(mode);
        self.target.set_numerics(mode);
    }

    fn speculates(&self) -> bool {
        true
    }

    fn set_spec(&mut self, cfg: &SpecConfig) {
        if cfg.enabled {
            self.k = cfg.k.max(1);
        }
    }

    fn spec_tick(
        &self,
        last: &[u32],
        caches: &mut [&mut Self::Kv],
        budgets: &[usize],
        scratch: &mut Self::Scratch,
    ) -> Option<Result<Vec<SpecOutcome>>> {
        Some(self.run_round(last, caches, budgets, scratch))
    }

    fn label(&self) -> &'static str {
        "speculative"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{CpuBackend, Engine};
    use crate::coordinator::request::SamplingParams;
    use crate::coordinator::{EngineConfig, Request};
    use crate::model::init::random_weights;
    use crate::model::{presets, BackendModel, Model};

    fn tiny_model(seed: u64) -> Model {
        let mut cfg = presets::by_name("opt-nano").unwrap();
        cfg.vocab = 64;
        cfg.max_seq = 64;
        Model::new(cfg.clone(), random_weights(&cfg, seed))
    }

    fn cfg_no_eos(max_batch: usize) -> EngineConfig {
        EngineConfig {
            max_batch,
            total_blocks: 128,
            block_size: 8,
            eos_token: u32::MAX,
            ..Default::default()
        }
    }

    fn req(id: u64, prompt_len: usize, gen: usize) -> Request {
        Request::new(id, (0..prompt_len as u32).map(|i| 3 + i % 60).collect(), gen)
    }

    type SpecCpu = SpeculativeBackend<CpuBackend, CpuBackend>;

    /// Draft and target are *different* models (different random
    /// weights), so drafts get rejected — and greedy output must still
    /// be token-identical to serving the target alone.
    #[test]
    fn speculative_greedy_matches_target_only() {
        let target = tiny_model(42);
        let draft = tiny_model(1042);
        let serve = |spec: bool| {
            let mut out = if spec {
                let be: SpecCpu = SpeculativeBackend::new(
                    CpuBackend(BackendModel::dense(&draft)),
                    CpuBackend(BackendModel::dense(&target)),
                    4,
                );
                let mut e = Engine::new(be, cfg_no_eos(4));
                for id in 0..4 {
                    e.submit(req(id, 4 + id as usize, 12)).unwrap();
                }
                let out = e.run_to_completion().unwrap();
                assert!(e.metrics.spec_ticks > 0, "speculative path never ran");
                assert!(e.metrics.spec_drafted_total > 0);
                e.check_invariants().unwrap();
                assert_eq!(e.kv().used_blocks(), 0, "rollback leaked pool blocks");
                out
            } else {
                let mut e = Engine::new(CpuBackend(BackendModel::dense(&target)), cfg_no_eos(4));
                for id in 0..4 {
                    e.submit(req(id, 4 + id as usize, 12)).unwrap();
                }
                e.run_to_completion().unwrap()
            };
            out.sort_by_key(|r| r.id);
            out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(serve(true), serve(false), "speculation changed greedy output");
    }

    /// An identical draft/target pair agrees everywhere: every round
    /// accepts all k drafts and emits k + 1 tokens.
    #[test]
    fn identical_pair_accepts_every_draft() {
        let m = tiny_model(7);
        let be: SpecCpu = SpeculativeBackend::new(
            CpuBackend(BackendModel::dense(&m)),
            CpuBackend(BackendModel::dense(&m)),
            3,
        );
        let mut e = Engine::new(be, cfg_no_eos(2));
        e.submit(req(1, 5, 13)).unwrap();
        let out = e.run_to_completion().unwrap();
        assert_eq!(out[0].tokens.len(), 13);
        assert_eq!(e.metrics.spec_acceptance_rate(), 1.0);
        assert_eq!(e.metrics.spec_rolled_back_total, 0);
        assert_eq!(
            e.metrics.spec_emitted_total,
            e.metrics.spec_drafted_total + e.metrics.spec_ticks,
            "full accepts emit drafted + bonus every round"
        );
        e.check_invariants().unwrap();
    }

    /// The per-round draft allotment is clamped so a full accept never
    /// overruns `max_new_tokens`, including max_new = 1 (k = 0: plain
    /// decode through the verify path).
    #[test]
    fn respects_generation_budget() {
        let m = tiny_model(9);
        for gen in [1usize, 2, 3, 5] {
            let be: SpecCpu = SpeculativeBackend::new(
                CpuBackend(BackendModel::dense(&m)),
                CpuBackend(BackendModel::dense(&m)),
                4,
            );
            let mut e = Engine::new(be, cfg_no_eos(2));
            e.submit(req(1, 4, gen)).unwrap();
            let out = e.run_to_completion().unwrap();
            assert_eq!(out[0].tokens.len(), gen, "budget {gen} overrun");
            e.check_invariants().unwrap();
        }
    }

    /// Non-greedy requests bypass speculation (the acceptance rule is
    /// argmax-based) but share the engine with speculating ones; their
    /// seeded sampling must match target-only serving exactly.
    #[test]
    fn mixed_greedy_and_topk_batch_matches_target_only() {
        let target = tiny_model(52);
        let draft = tiny_model(1052);
        let topk = SamplingParams::TopK { k: 8, temperature: 1.0, seed: 99 };
        let submit_all = |e: &mut dyn FnMut(Request)| {
            e(req(1, 5, 10));
            e(req(2, 6, 10).with_sampling(topk));
            e(req(3, 4, 10));
        };
        let spec = {
            let be: SpecCpu = SpeculativeBackend::new(
                CpuBackend(BackendModel::dense(&draft)),
                CpuBackend(BackendModel::dense(&target)),
                4,
            );
            let mut e = Engine::new(be, cfg_no_eos(4));
            submit_all(&mut |r| e.submit(r).unwrap());
            let mut out = e.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            assert!(e.metrics.spec_ticks > 0);
            e.check_invariants().unwrap();
            out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        let plain = {
            let mut e = Engine::new(CpuBackend(BackendModel::dense(&target)), cfg_no_eos(4));
            submit_all(&mut |r| e.submit(r).unwrap());
            let mut out = e.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(spec, plain, "mixed batch diverged from target-only serving");
    }

    #[test]
    fn engine_config_spec_k_overrides_constructor() {
        let m = tiny_model(3);
        let be: SpecCpu = SpeculativeBackend::new(
            CpuBackend(BackendModel::dense(&m)),
            CpuBackend(BackendModel::dense(&m)),
            4,
        );
        let cfg = EngineConfig {
            spec: SpecConfig { enabled: true, k: 2, draft_format: DraftFormat::Dense },
            ..cfg_no_eos(2)
        };
        let e = Engine::new(be, cfg);
        assert_eq!(e.backend().k(), 2, "EngineConfig::spec.k must reach the backend");
    }

    #[test]
    fn draft_format_parses_and_labels() {
        assert_eq!(DraftFormat::parse("lut2"), Ok(DraftFormat::Lut2));
        assert_eq!(DraftFormat::parse("lut3"), Ok(DraftFormat::Lut3));
        assert_eq!(DraftFormat::parse("dense"), Ok(DraftFormat::Dense));
        assert!(DraftFormat::parse("int8").is_err());
        assert_eq!(DraftFormat::Lut2.label(), "lut2");
        assert_eq!(SpecConfig::default().k, 4);
        assert!(!SpecConfig::default().enabled);
    }
}
