//! Paged KV-cache manager — vLLM-style block accounting.
//!
//! The pool owns `total_blocks` fixed-size blocks; a sequence holds a
//! block table and grows it one block at a time as it decodes. Admission
//! control asks [`PagedKvManager::can_admit`] with the request's worst-
//! case token need so a decoding batch can never deadlock on blocks.
//!
//! Invariants (property-tested below):
//! * a block is owned by at most one sequence at a time,
//! * `free + Σ allocated == total`,
//! * freeing a sequence returns exactly its blocks.

use std::collections::HashMap;

/// Handle of an admitted sequence.
pub type SeqId = u64;

/// Block-granular KV accounting.
pub struct PagedKvManager {
    block_size: usize,
    free: Vec<u32>,
    tables: HashMap<SeqId, Vec<u32>>,
    /// tokens currently stored per sequence
    lens: HashMap<SeqId, usize>,
    /// worst-case block commitment per sequence (admission guarantee)
    commits: HashMap<SeqId, usize>,
    committed: usize,
    total: usize,
}

impl PagedKvManager {
    pub fn new(total_blocks: usize, block_size: usize) -> PagedKvManager {
        assert!(block_size > 0);
        PagedKvManager {
            block_size,
            free: (0..total_blocks as u32).rev().collect(),
            tables: HashMap::new(),
            lens: HashMap::new(),
            commits: HashMap::new(),
            committed: 0,
            total: total_blocks,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.total - self.free.len()
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Worst-case admission check for a request needing `max_tokens` —
    /// against *committed* blocks (every running sequence's worst case),
    /// so an admitted batch can always decode to completion.
    pub fn can_admit(&self, max_tokens: usize) -> bool {
        self.committed + self.blocks_for(max_tokens.max(1)) <= self.total
    }

    /// Admit a sequence, committing its worst case and reserving blocks
    /// for its prompt immediately. Returns false (no side effects) if the
    /// worst case doesn't fit.
    pub fn admit(&mut self, seq: SeqId, prompt_tokens: usize, max_tokens: usize) -> bool {
        assert!(!self.tables.contains_key(&seq), "seq {seq} already admitted");
        if !self.can_admit(max_tokens) {
            return false;
        }
        let worst = self.blocks_for(max_tokens.max(1));
        let need = self.blocks_for(prompt_tokens.max(1)).min(worst);
        let blocks: Vec<u32> = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.committed += worst;
        self.commits.insert(seq, worst);
        self.tables.insert(seq, blocks);
        self.lens.insert(seq, prompt_tokens);
        true
    }

    /// Account one generated token; allocates a new block on boundary.
    /// Returns false when the sequence would exceed its admission-time
    /// commitment (the engine's length guard failed) — never on pool
    /// exhaustion, which commitment accounting makes impossible.
    pub fn append_token(&mut self, seq: SeqId) -> bool {
        let len = self.lens.get_mut(&seq).expect("unknown seq");
        let need = (*len + 1).div_ceil(self.block_size);
        if need > self.commits[&seq] {
            return false;
        }
        let table = self.tables.get_mut(&seq).unwrap();
        while table.len() < need {
            let b = self.free.pop().expect("commitment guarantees a free block");
            table.push(b);
        }
        *len += 1;
        true
    }

    /// Release all blocks (and the worst-case commitment) of a sequence.
    pub fn release(&mut self, seq: SeqId) {
        if let Some(blocks) = self.tables.remove(&seq) {
            self.free.extend(blocks);
        }
        if let Some(worst) = self.commits.remove(&seq) {
            self.committed -= worst;
        }
        self.lens.remove(&seq);
    }

    /// Current block table of a sequence (for debugging / metrics).
    pub fn table(&self, seq: SeqId) -> Option<&[u32]> {
        self.tables.get(&seq).map(|v| v.as_slice())
    }

    pub fn active_seqs(&self) -> usize {
        self.tables.len()
    }

    /// Consistency check: every block owned exactly once.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = std::collections::HashSet::new();
        for &b in &self.free {
            if !seen.insert(b) {
                return Err(format!("block {b} duplicated in free list"));
            }
        }
        for (seq, table) in &self.tables {
            for &b in table {
                if !seen.insert(b) {
                    return Err(format!("block {b} double-owned (seq {seq})"));
                }
            }
        }
        if seen.len() != self.total {
            return Err(format!("{} blocks tracked, expected {}", seen.len(), self.total));
        }
        let committed: usize = self.commits.values().sum();
        if committed != self.committed {
            return Err(format!(
                "commitment drift: {} recorded vs {} summed",
                self.committed, committed
            ));
        }
        if self.used_blocks() > self.committed {
            return Err(format!(
                "allocated {} blocks beyond commitment {}",
                self.used_blocks(),
                self.committed
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn admit_reserves_prompt_blocks() {
        let mut m = PagedKvManager::new(10, 16);
        assert!(m.admit(1, 33, 64)); // 33 tokens → 3 blocks
        assert_eq!(m.used_blocks(), 3);
        assert_eq!(m.table(1).unwrap().len(), 3);
        m.check_invariants().unwrap();
    }

    #[test]
    fn admission_respects_worst_case_commitment() {
        let mut m = PagedKvManager::new(4, 16);
        assert!(m.admit(1, 16, 48)); // commits 3 blocks, holds 1
        // commitment 3 + worst 4 > 4 → reject even though blocks are free
        assert!(!m.admit(2, 8, 64));
        // 3 + 2 > 4 → still rejected (worst case must be guaranteed)
        assert!(!m.admit(3, 8, 32));
        // 3 + 1 = 4 fits
        assert!(m.admit(4, 8, 16));
        m.check_invariants().unwrap();
        // seq 1 can decode to its full worst case even with 4 admitted
        for _ in 0..32 {
            assert!(m.append_token(1));
        }
        assert!(!m.append_token(1)); // beyond commitment → rejected
    }

    #[test]
    fn append_allocates_on_boundary() {
        let mut m = PagedKvManager::new(8, 4);
        assert!(m.admit(1, 4, 12));
        assert_eq!(m.table(1).unwrap().len(), 1);
        assert!(m.append_token(1)); // token 5 → second block
        assert_eq!(m.table(1).unwrap().len(), 2);
        for _ in 0..3 {
            assert!(m.append_token(1));
        }
        assert_eq!(m.table(1).unwrap().len(), 2); // tokens 6..8 fit
        assert!(m.append_token(1)); // token 9 → third block
        assert_eq!(m.table(1).unwrap().len(), 3);
        m.check_invariants().unwrap();
    }

    #[test]
    fn release_returns_blocks() {
        let mut m = PagedKvManager::new(6, 8);
        assert!(m.admit(1, 24, 24));
        assert!(m.admit(2, 16, 16));
        assert_eq!(m.free_blocks(), 1);
        m.release(1);
        assert_eq!(m.free_blocks(), 4);
        m.release(2);
        assert_eq!(m.free_blocks(), 6);
        m.check_invariants().unwrap();
    }

    #[test]
    fn property_random_workload_never_double_owns() {
        let mut rng = Rng::new(808);
        let mut m = PagedKvManager::new(32, 4);
        let mut live: Vec<SeqId> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..2000 {
            match rng.below(10) {
                0..=3 => {
                    let prompt = rng.range(1, 20);
                    let max = prompt + rng.range(0, 20);
                    if m.admit(next_id, prompt, max) {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                4..=7 if !live.is_empty() => {
                    let idx = rng.range(0, live.len());
                    let _ = m.append_token(live[idx]);
                }
                _ if !live.is_empty() => {
                    let idx = rng.range(0, live.len());
                    let seq = live.swap_remove(idx);
                    m.release(seq);
                }
                _ => {}
            }
            m.check_invariants().unwrap();
        }
        for seq in live {
            m.release(seq);
        }
        assert_eq!(m.free_blocks(), 32);
        m.check_invariants().unwrap();
    }
}
