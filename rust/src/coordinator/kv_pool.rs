//! Paged KV-cache manager — vLLM-style block accounting with refcounted
//! copy-on-write sharing.
//!
//! The pool owns `total_blocks` fixed-size blocks; a sequence holds a
//! block table and grows it one block at a time as it decodes. Blocks are
//! refcounted so the prefix cache can share an already-prefilled prefix
//! across sequences: [`PagedKvManager::admit_shared`] adopts cached
//! blocks by reference, and a sequence that appends into a block whose
//! refcount is above one copies it first (copy-on-write) so writers never
//! alias. The prefix cache itself holds blocks alive through
//! [`PagedKvManager::pin_prefix`] / [`PagedKvManager::unpin_prefix`].
//!
//! Admission control asks [`PagedKvManager::can_admit`] (or
//! [`PagedKvManager::can_admit_shared`]) with the request's worst-case
//! token need so a decoding batch can never deadlock on blocks. With
//! sharing, "committed blocks" is no longer meaningful (a shared block is
//! one allocation serving many tables), so the guarantee is kept in terms
//! of *future allocations*: each sequence carries a `pending` budget — the
//! number of free-list pops it may still perform (boundary growth plus at
//! most one copy-on-write of a partially-filled shared tail block) — and
//! the pool maintains `Σ pending ≤ free`. Every allocation decrements both
//! sides, frees only grow the right side, and admission/pinning refuse
//! whenever they would break the inequality, so a pending allocation can
//! always be satisfied.
//!
//! Invariants (property-tested below, see [`PagedKvManager::check_invariants`]):
//! * `refs[b] == (occurrences of b across tables) + pins[b]` for every block,
//! * the free list holds exactly the blocks with `refs == 0`, each once,
//! * `pending_total == Σ pending` and `pending_total ≤ free`,
//! * releasing every sequence and unpinning every prefix frees the pool.

use std::collections::HashMap;

/// Handle of an admitted sequence.
pub type SeqId = u64;

/// Block-granular KV accounting.
pub struct PagedKvManager {
    block_size: usize,
    free: Vec<u32>,
    /// per-block reference count: table occurrences + pins
    refs: Vec<u32>,
    /// per-block prefix-cache pin count (subset of `refs`)
    pins: Vec<u32>,
    tables: HashMap<SeqId, Vec<u32>>,
    /// tokens currently stored per sequence
    lens: HashMap<SeqId, usize>,
    /// worst-case table length (blocks) per sequence (admission guarantee)
    commits: HashMap<SeqId, usize>,
    /// free-list allocations each sequence may still perform
    pending: HashMap<SeqId, usize>,
    pending_total: usize,
    cow_copies: u64,
    total: usize,
}

impl PagedKvManager {
    pub fn new(total_blocks: usize, block_size: usize) -> PagedKvManager {
        assert!(block_size > 0);
        PagedKvManager {
            block_size,
            free: (0..total_blocks as u32).rev().collect(),
            refs: vec![0; total_blocks],
            pins: vec![0; total_blocks],
            tables: HashMap::new(),
            lens: HashMap::new(),
            commits: HashMap::new(),
            pending: HashMap::new(),
            pending_total: 0,
            cow_copies: 0,
            total: total_blocks,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.total - self.free.len()
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Number of blocks covering `tokens` tokens (public for the prefix
    /// cache, which pins exactly the blocks covering a cached prompt).
    pub fn blocks_covering(&self, tokens: usize) -> usize {
        self.blocks_for(tokens)
    }

    /// Tokens currently accounted for a sequence.
    pub fn seq_tokens(&self, seq: SeqId) -> Option<usize> {
        self.lens.get(&seq).copied()
    }

    /// Total copy-on-write block copies performed so far.
    pub fn cow_copies(&self) -> u64 {
        self.cow_copies
    }

    /// Reference count of a block (tables + pins). Test/debug aid.
    pub fn block_refs(&self, block: u32) -> u32 {
        self.refs[block as usize]
    }

    /// Number of distinct blocks currently pinned by the prefix cache.
    pub fn pinned_blocks(&self) -> usize {
        self.pins.iter().filter(|&&p| p > 0).count()
    }

    /// Worst-case admission check for a request needing `max_tokens`.
    /// The request would add `blocks_for(max_tokens)` future allocations;
    /// it fits iff the pool can still promise every pending allocation.
    pub fn can_admit(&self, max_tokens: usize) -> bool {
        self.blocks_for(max_tokens.max(1)) + self.pending_total <= self.free.len()
    }

    /// Like [`Self::can_admit`] but for a request that will adopt a cached
    /// prefix of `shared_tokens` tokens. Fully-shared blocks are never
    /// written by the new sequence, so they cost it no allocations; a
    /// partially-filled shared tail block still counts (it is copied on
    /// write).
    pub fn can_admit_shared(&self, max_tokens: usize, shared_tokens: usize) -> bool {
        let worst = self.blocks_for(max_tokens.max(1));
        let shared_full = shared_tokens / self.block_size;
        worst.saturating_sub(shared_full) + self.pending_total <= self.free.len()
    }

    /// Pop a free block on behalf of `seq`, consuming one unit of its
    /// pending-allocation budget. The `Σ pending ≤ free` invariant
    /// guarantees the pop succeeds whenever the budget is positive.
    fn take_free_for(&mut self, seq: SeqId) -> u32 {
        // lint:allow(no-panic-serve) accounting invariant: allocating for
        // a seq with no budget entry is pool corruption, not a load fault
        let p = self.pending.get_mut(&seq).expect("seq has no allocation budget");
        assert!(*p > 0, "seq {seq} exceeded its pending-allocation budget");
        *p -= 1;
        self.pending_total -= 1;
        // lint:allow(no-panic-serve) accounting invariant: Σ pending ≤ free
        // makes an empty free list here impossible without corruption
        let b = self.free.pop().expect("pending accounting guarantees a free block");
        debug_assert_eq!(self.refs[b as usize], 0);
        self.refs[b as usize] = 1;
        b
    }

    fn deref_block(&mut self, b: u32) {
        let r = &mut self.refs[b as usize];
        assert!(*r > 0, "block {b} refcount underflow");
        *r -= 1;
        if *r == 0 {
            self.free.push(b);
        }
    }

    /// Admit a sequence, committing its worst case and reserving blocks
    /// for its prompt immediately. Returns false (no side effects) if the
    /// worst case doesn't fit.
    pub fn admit(&mut self, seq: SeqId, prompt_tokens: usize, max_tokens: usize) -> bool {
        assert!(!self.tables.contains_key(&seq), "seq {seq} already admitted");
        if !self.can_admit(max_tokens) {
            return false;
        }
        let worst = self.blocks_for(max_tokens.max(1));
        let need = self.blocks_for(prompt_tokens.max(1)).min(worst);
        self.commits.insert(seq, worst);
        self.pending.insert(seq, worst);
        self.pending_total += worst;
        let mut table = Vec::with_capacity(need);
        for _ in 0..need {
            let b = self.take_free_for(seq);
            table.push(b);
        }
        self.tables.insert(seq, table);
        self.lens.insert(seq, prompt_tokens);
        true
    }

    /// Admit a sequence that adopts `shared` — the cached blocks covering
    /// the first `shared_tokens` tokens of its prompt — by reference.
    /// Fully-covered shared blocks are read-only forever (prefill resumes
    /// at `shared_tokens`); if the prompt extends into a partially-filled
    /// shared tail block, that block is copied-on-write immediately so the
    /// new sequence prefills into its own copy. Remaining prompt blocks
    /// are reserved upfront as in [`Self::admit`]. Returns false (no side
    /// effects) if the private worst case doesn't fit.
    pub fn admit_shared(
        &mut self,
        seq: SeqId,
        prompt_tokens: usize,
        max_tokens: usize,
        shared: &[u32],
        shared_tokens: usize,
    ) -> bool {
        assert!(!self.tables.contains_key(&seq), "seq {seq} already admitted");
        assert!(shared_tokens > 0 && shared_tokens <= prompt_tokens);
        assert!(prompt_tokens <= max_tokens);
        assert_eq!(shared.len(), self.blocks_for(shared_tokens));
        if !self.can_admit_shared(max_tokens, shared_tokens) {
            return false;
        }
        let worst = self.blocks_for(max_tokens.max(1));
        let shared_full = shared_tokens / self.block_size;
        let mut table: Vec<u32> = shared.to_vec();
        for &b in shared {
            debug_assert!(self.refs[b as usize] > 0, "shared block {b} is free");
            self.refs[b as usize] += 1;
        }
        self.commits.insert(seq, worst);
        self.pending.insert(seq, worst - shared_full);
        self.pending_total += worst - shared_full;
        self.lens.insert(seq, prompt_tokens);
        if prompt_tokens > shared_tokens && shared_tokens % self.block_size != 0 {
            // lint:allow(no-panic-serve) shared_tokens > 0 is asserted
            // above, so the adopted table is non-empty by construction
            let old = *table.last().unwrap();
            let nb = self.take_free_for(seq);
            // lint:allow(no-panic-serve) same non-empty table as two lines up
            *table.last_mut().unwrap() = nb;
            self.deref_block(old);
            self.cow_copies += 1;
        }
        let need = self.blocks_for(prompt_tokens.max(1)).min(worst);
        while table.len() < need {
            let b = self.take_free_for(seq);
            table.push(b);
        }
        self.tables.insert(seq, table);
        true
    }

    /// Account one generated token; allocates a new block on boundary and
    /// copies the target block first when it is shared (refcount > 1).
    /// Returns false when the sequence would exceed its admission-time
    /// commitment (the engine's length guard failed) — never on pool
    /// exhaustion, which the pending-allocation accounting makes
    /// impossible.
    pub fn append_token(&mut self, seq: SeqId) -> bool {
        // lint:allow(no-panic-serve) accounting invariant: appending to a
        // seq the pool never admitted is an engine bug, not a load fault
        let len = *self.lens.get(&seq).expect("unknown seq");
        let need = (len + 1).div_ceil(self.block_size);
        if need > self.commits[&seq] {
            return false;
        }
        if self.tables[&seq].len() < need {
            let b = self.take_free_for(seq);
            // lint:allow(no-panic-serve) `lens` and `tables` share admission
            self.tables.get_mut(&seq).unwrap().push(b);
        }
        let write_idx = len / self.block_size;
        let cur = self.tables[&seq][write_idx];
        if self.refs[cur as usize] > 1 {
            let nb = self.take_free_for(seq);
            // lint:allow(no-panic-serve) `lens` and `tables` share admission
            self.tables.get_mut(&seq).unwrap()[write_idx] = nb;
            self.deref_block(cur);
            self.cow_copies += 1;
        }
        // lint:allow(no-panic-serve) `lens` entry was read at function entry
        *self.lens.get_mut(&seq).unwrap() = len + 1;
        true
    }

    /// Roll a sequence back to `tokens` stored tokens, returning the
    /// blocks past the new boundary to the free list and re-crediting
    /// them to the sequence's pending-allocation budget (the commitment
    /// is unchanged — the sequence may still grow back to its worst
    /// case, so `Σ pending ≤ free` is preserved by construction: every
    /// freed block grows both sides by one).
    ///
    /// This is the speculative-decode reject path, and it only ever cuts
    /// into the sequence's **privately-owned decode tail** — the drafted
    /// positions lie past the prompt, and any block covering drafted
    /// tokens was either freshly allocated or already copied-on-write
    /// (`append_token` CoWs before writing a shared block). Popping a
    /// block that is still shared or pinned would corrupt another
    /// sequence's table, so that is asserted, not handled.
    pub fn truncate_to(&mut self, seq: SeqId, tokens: usize) {
        // lint:allow(no-panic-serve) accounting invariant: rolling back a
        // seq the pool never admitted is an engine bug, not a load fault
        let len = *self.lens.get(&seq).expect("unknown seq");
        assert!(tokens <= len, "truncate_to({tokens}) beyond stored {len}");
        if tokens == len {
            return;
        }
        // same floor as admit(): even an empty sequence keeps one block
        let need = self.blocks_for(tokens.max(1));
        // lint:allow(no-panic-serve) `lens` and `tables` share admission
        let table = self.tables.get_mut(&seq).expect("unknown seq");
        let mut freed = 0usize;
        while table.len() > need {
            // lint:allow(no-panic-serve) accounting invariant: the loop
            // bound keeps pops within the table's own recorded length
            let b = table.pop().expect("table shorter than its own accounting");
            assert_eq!(
                self.pins[b as usize], 0,
                "rollback popped pinned block {b} — truncation cut into a published prefix"
            );
            assert_eq!(
                self.refs[b as usize], 1,
                "rollback popped shared block {b} — truncation cut into a shared prefix"
            );
            self.refs[b as usize] = 0;
            self.free.push(b);
            freed += 1;
        }
        if freed > 0 {
            // lint:allow(no-panic-serve) `pending` entries live as long as
            // the seq's table, checked admitted at function entry
            *self.pending.get_mut(&seq).expect("unknown seq") += freed;
            self.pending_total += freed;
        }
        // lint:allow(no-panic-serve) `lens` entry was read at function entry
        *self.lens.get_mut(&seq).unwrap() = tokens;
    }

    /// Pin a cached prefix's blocks so they survive the donor sequence's
    /// release. `tail_grant` names the donor when it may later write into
    /// the last pinned block (its prompt ends mid-block): pinning then
    /// adds one copy-on-write allocation to the donor's budget, which is
    /// only sound if the pool can still promise every pending allocation —
    /// otherwise the pin is refused (no side effects) and the caller skips
    /// caching. A grant for an already-released donor is ignored.
    pub fn pin_prefix(&mut self, blocks: &[u32], tail_grant: Option<SeqId>) -> bool {
        let grant = tail_grant.filter(|s| self.pending.contains_key(s));
        if grant.is_some() && self.pending_total + 1 > self.free.len() {
            return false;
        }
        for &b in blocks {
            assert!(self.refs[b as usize] > 0, "cannot pin free block {b}");
            self.pins[b as usize] += 1;
            self.refs[b as usize] += 1;
        }
        if let Some(donor) = grant {
            // lint:allow(no-panic-serve) `grant` was filtered on the
            // donor's `pending` entry existing a few lines above
            *self.pending.get_mut(&donor).unwrap() += 1;
            self.pending_total += 1;
        }
        true
    }

    /// Drop the prefix cache's pins on `blocks` (eviction). Blocks whose
    /// refcount reaches zero return to the free list.
    pub fn unpin_prefix(&mut self, blocks: &[u32]) {
        for &b in blocks {
            assert!(self.pins[b as usize] > 0, "block {b} pin underflow");
            self.pins[b as usize] -= 1;
            self.deref_block(b);
        }
    }

    /// Release all blocks (and the remaining allocation budget) of a
    /// sequence. Shared blocks stay alive while other tables or pins
    /// reference them.
    pub fn release(&mut self, seq: SeqId) {
        if let Some(blocks) = self.tables.remove(&seq) {
            for b in blocks {
                self.deref_block(b);
            }
        }
        self.commits.remove(&seq);
        if let Some(p) = self.pending.remove(&seq) {
            self.pending_total -= p;
        }
        self.lens.remove(&seq);
    }

    /// Current block table of a sequence (for debugging / metrics).
    pub fn table(&self, seq: SeqId) -> Option<&[u32]> {
        self.tables.get(&seq).map(|v| v.as_slice())
    }

    pub fn active_seqs(&self) -> usize {
        self.tables.len()
    }

    /// Consistency check: refcounts match table occurrences plus pins, the
    /// free list is exactly the zero-ref blocks, and the pending-allocation
    /// promise holds.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut occ = vec![0u32; self.total];
        for (seq, table) in &self.tables {
            let commit = *self
                .commits
                .get(seq)
                .ok_or_else(|| format!("seq {seq} has a table but no commitment"))?;
            if table.len() > commit {
                return Err(format!(
                    "seq {seq} table {} blocks beyond commitment {commit}",
                    table.len()
                ));
            }
            let len = *self
                .lens
                .get(seq)
                .ok_or_else(|| format!("seq {seq} has a table but no length"))?;
            if self.blocks_for(len).min(commit) > table.len() {
                return Err(format!(
                    "seq {seq} stores {len} tokens in {} blocks",
                    table.len()
                ));
            }
            for &b in table {
                let slot = occ
                    .get_mut(b as usize)
                    .ok_or_else(|| format!("seq {seq} references unknown block {b}"))?;
                *slot += 1;
            }
        }
        for b in 0..self.total {
            let expect = occ[b] + self.pins[b];
            if self.refs[b] != expect {
                return Err(format!(
                    "block {b} refcount {} but {} table occurrences + {} pins",
                    self.refs[b], occ[b], self.pins[b]
                ));
            }
        }
        let mut seen = std::collections::HashSet::new();
        for &b in &self.free {
            if !seen.insert(b) {
                return Err(format!("block {b} duplicated in free list"));
            }
            if self.refs[b as usize] != 0 {
                return Err(format!("block {b} on free list with refcount > 0"));
            }
        }
        let zero_refs = self.refs.iter().filter(|&&r| r == 0).count();
        if seen.len() != zero_refs {
            return Err(format!(
                "free list holds {} blocks but {} have zero refs",
                seen.len(),
                zero_refs
            ));
        }
        for seq in self.tables.keys() {
            if !self.pending.contains_key(seq) {
                return Err(format!("seq {seq} has a table but no pending budget"));
            }
        }
        if self.pending.len() != self.tables.len() {
            return Err(format!(
                "{} pending budgets vs {} tables",
                self.pending.len(),
                self.tables.len()
            ));
        }
        let pending: usize = self.pending.values().sum();
        if pending != self.pending_total {
            return Err(format!(
                "pending drift: {} recorded vs {} summed",
                self.pending_total, pending
            ));
        }
        if self.pending_total > self.free.len() {
            return Err(format!(
                "{} pending allocations promised but only {} free blocks",
                self.pending_total,
                self.free.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn admit_reserves_prompt_blocks() {
        let mut m = PagedKvManager::new(10, 16);
        assert!(m.admit(1, 33, 64)); // 33 tokens → 3 blocks
        assert_eq!(m.used_blocks(), 3);
        assert_eq!(m.table(1).unwrap().len(), 3);
        m.check_invariants().unwrap();
    }

    #[test]
    fn admission_respects_worst_case_commitment() {
        let mut m = PagedKvManager::new(4, 16);
        assert!(m.admit(1, 16, 48)); // commits 3 blocks, holds 1
        // commitment 3 + worst 4 > 4 → reject even though blocks are free
        assert!(!m.admit(2, 8, 64));
        // 3 + 2 > 4 → still rejected (worst case must be guaranteed)
        assert!(!m.admit(3, 8, 32));
        // 3 + 1 = 4 fits
        assert!(m.admit(4, 8, 16));
        m.check_invariants().unwrap();
        // seq 1 can decode to its full worst case even with 4 admitted
        for _ in 0..32 {
            assert!(m.append_token(1));
        }
        assert!(!m.append_token(1)); // beyond commitment → rejected
    }

    #[test]
    fn append_allocates_on_boundary() {
        let mut m = PagedKvManager::new(8, 4);
        assert!(m.admit(1, 4, 12));
        assert_eq!(m.table(1).unwrap().len(), 1);
        assert!(m.append_token(1)); // token 5 → second block
        assert_eq!(m.table(1).unwrap().len(), 2);
        for _ in 0..3 {
            assert!(m.append_token(1));
        }
        assert_eq!(m.table(1).unwrap().len(), 2); // tokens 6..8 fit
        assert!(m.append_token(1)); // token 9 → third block
        assert_eq!(m.table(1).unwrap().len(), 3);
        m.check_invariants().unwrap();
    }

    #[test]
    fn release_returns_blocks() {
        let mut m = PagedKvManager::new(6, 8);
        assert!(m.admit(1, 24, 24));
        assert!(m.admit(2, 16, 16));
        assert_eq!(m.free_blocks(), 1);
        m.release(1);
        assert_eq!(m.free_blocks(), 4);
        m.release(2);
        assert_eq!(m.free_blocks(), 6);
        m.check_invariants().unwrap();
    }

    #[test]
    fn boundary_prompt_plus_max_on_block_edge() {
        let mut m = PagedKvManager::new(4, 8);
        // prompt exactly one block, worst case exactly two blocks
        assert!(m.admit(1, 8, 16));
        assert_eq!(m.table(1).unwrap().len(), 1);
        for i in 0..8 {
            assert!(m.append_token(1), "append {i}");
        }
        assert_eq!(m.table(1).unwrap().len(), 2);
        assert_eq!(m.seq_tokens(1), Some(16));
        // token 17 would need a third block past the commitment
        assert!(!m.append_token(1));
        m.check_invariants().unwrap();
        m.release(1);
        assert_eq!(m.free_blocks(), 4);
    }

    #[test]
    fn boundary_zero_length_prompt() {
        let mut m = PagedKvManager::new(4, 4);
        assert!(m.admit(1, 0, 4)); // still reserves one block
        assert_eq!(m.table(1).unwrap().len(), 1);
        assert_eq!(m.seq_tokens(1), Some(0));
        for _ in 0..4 {
            assert!(m.append_token(1));
        }
        assert_eq!(m.table(1).unwrap().len(), 1);
        assert!(!m.append_token(1));
        m.check_invariants().unwrap();
    }

    #[test]
    fn boundary_block_size_one() {
        let mut m = PagedKvManager::new(8, 1);
        assert!(m.admit(1, 3, 5));
        assert_eq!(m.table(1).unwrap().len(), 3);
        assert!(m.append_token(1));
        assert!(m.append_token(1));
        assert_eq!(m.table(1).unwrap().len(), 5);
        assert!(!m.append_token(1));
        m.check_invariants().unwrap();
        // remaining capacity: 3 free, 0 pending
        assert!(m.admit(2, 1, 3));
        assert!(!m.admit(3, 1, 1));
        m.check_invariants().unwrap();
    }

    #[test]
    fn shared_admission_adopts_blocks_and_cows_tail() {
        let mut m = PagedKvManager::new(16, 4);
        // donor: 10-token prompt in 3 blocks, worst case 4
        assert!(m.admit(1, 10, 14));
        let donor_blocks: Vec<u32> = m.table(1).unwrap().to_vec();
        assert_eq!(donor_blocks.len(), 3);
        // cache pins the blocks covering the prompt; the donor ends
        // mid-block (10 % 4 != 0) so it gets a CoW grant
        assert!(m.pin_prefix(&donor_blocks, Some(1)));
        m.check_invariants().unwrap();
        assert_eq!(m.pinned_blocks(), 3);

        // a new request sharing the full 10-token prefix
        assert!(m.admit_shared(2, 12, 16, &donor_blocks, 10));
        let t2: Vec<u32> = m.table(2).unwrap().to_vec();
        assert_eq!(t2.len(), 3);
        // full blocks adopted by reference, partial tail copied-on-write
        assert_eq!(&t2[..2], &donor_blocks[..2]);
        assert_ne!(t2[2], donor_blocks[2]);
        assert_eq!(m.cow_copies(), 1);
        m.check_invariants().unwrap();

        // the donor's next append writes into its pinned tail → CoW
        assert!(m.append_token(1));
        let t1: Vec<u32> = m.table(1).unwrap().to_vec();
        assert_ne!(t1[2], donor_blocks[2]);
        assert_eq!(m.cow_copies(), 2);
        assert_eq!(m.block_refs(donor_blocks[2]), 1); // pin only
        m.check_invariants().unwrap();

        // teardown: everything comes back
        m.unpin_prefix(&donor_blocks);
        m.release(1);
        m.release(2);
        assert_eq!(m.free_blocks(), 16);
        m.check_invariants().unwrap();
    }

    #[test]
    fn pin_grant_refused_under_pressure() {
        let mut m = PagedKvManager::new(4, 4);
        assert!(m.admit(1, 2, 16)); // worst 4: 1 block held, 3 pending
        let blocks: Vec<u32> = m.table(1).unwrap().to_vec();
        // granting one more pending allocation would outrun the free list
        assert!(!m.pin_prefix(&blocks, Some(1)));
        assert_eq!(m.pinned_blocks(), 0);
        m.check_invariants().unwrap();
        // without a grant the pin is free of allocation promises
        assert!(m.pin_prefix(&blocks, None));
        assert_eq!(m.pinned_blocks(), 1);
        // a grant for an unknown (already released) donor is ignored
        assert!(m.pin_prefix(&blocks, Some(99)));
        m.unpin_prefix(&blocks);
        m.unpin_prefix(&blocks);
        m.check_invariants().unwrap();
        m.release(1);
        assert_eq!(m.free_blocks(), 4);
    }

    #[test]
    fn truncate_frees_blocks_and_recredits_pending() {
        let mut m = PagedKvManager::new(8, 4);
        assert!(m.admit(1, 4, 24)); // 1 block held, commitment 6
        for _ in 0..12 {
            assert!(m.append_token(1)); // 16 tokens → 4 blocks
        }
        assert_eq!(m.table(1).unwrap().len(), 4);
        let free_before = m.free_blocks();
        // reject a 7-token draft: roll back to 9 tokens (3 blocks)
        m.truncate_to(1, 9);
        assert_eq!(m.seq_tokens(1), Some(9));
        assert_eq!(m.table(1).unwrap().len(), 3);
        assert_eq!(m.free_blocks(), free_before + 1);
        m.check_invariants().unwrap();
        // the freed block was re-credited: the sequence can still grow
        // back to its full commitment (24 tokens)
        for _ in 0..15 {
            assert!(m.append_token(1));
        }
        assert!(!m.append_token(1), "commitment unchanged by rollback");
        m.check_invariants().unwrap();
        m.release(1);
        assert_eq!(m.free_blocks(), 8);
    }

    #[test]
    fn truncate_within_block_moves_no_blocks() {
        let mut m = PagedKvManager::new(4, 8);
        assert!(m.admit(1, 3, 16));
        for _ in 0..4 {
            assert!(m.append_token(1)); // 7 tokens, still 1 block
        }
        let table = m.table(1).unwrap().to_vec();
        m.truncate_to(1, 4);
        assert_eq!(m.table(1).unwrap(), table.as_slice(), "same single block");
        assert_eq!(m.seq_tokens(1), Some(4));
        // no-op truncation is allowed
        m.truncate_to(1, 4);
        m.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "pinned block")]
    fn truncate_into_pinned_prefix_is_a_bug() {
        let mut m = PagedKvManager::new(8, 4);
        assert!(m.admit(1, 8, 12));
        let blocks = m.table(1).unwrap().to_vec();
        assert!(m.pin_prefix(&blocks, None));
        // cutting into the published prefix violates the engine's
        // floor contract — the pool refuses loudly
        m.truncate_to(1, 2);
    }

    #[test]
    fn property_random_workload_never_double_owns() {
        let mut rng = Rng::new(808);
        let mut m = PagedKvManager::new(32, 4);
        let mut live: Vec<SeqId> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..2000 {
            match rng.below(10) {
                0..=3 => {
                    let prompt = rng.range(1, 20);
                    let max = prompt + rng.range(0, 20);
                    if m.admit(next_id, prompt, max) {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                4..=7 if !live.is_empty() => {
                    let idx = rng.range(0, live.len());
                    let _ = m.append_token(live[idx]);
                }
                _ if !live.is_empty() => {
                    let idx = rng.range(0, live.len());
                    let seq = live.swap_remove(idx);
                    m.release(seq);
                }
                _ => {}
            }
            m.check_invariants().unwrap();
        }
        for seq in live {
            m.release(seq);
        }
        assert_eq!(m.free_blocks(), 32);
        m.check_invariants().unwrap();
    }

    /// Speculative draft/verify churn: sequences repeatedly append a
    /// drafted burst and roll back to a random accept point, interleaved
    /// with prefix-cache pins, shared admissions, and mid-draft cancels.
    /// Each sequence carries a rollback floor (its prompt — which also
    /// bounds every pin and shared adoption, exactly the engine's
    /// contract), so `truncate_to` only ever cuts the private decode
    /// tail. Invariants hold at every step and the pool drains to full.
    #[test]
    fn property_speculative_rollback_churn_preserves_invariants() {
        let mut rng = Rng::new(9109);
        let mut m = PagedKvManager::new(48, 4);
        // (seq, floor): floor = prompt tokens — never truncated past
        let mut live: Vec<(SeqId, usize)> = Vec::new();
        let mut pinned: Vec<(Vec<u32>, usize)> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..3000 {
            match rng.below(12) {
                0..=1 => {
                    let prompt = rng.range(1, 14);
                    let max = prompt + rng.range(2, 14);
                    if m.admit(next_id, prompt, max) {
                        live.push((next_id, prompt));
                    }
                    next_id += 1;
                }
                2 if !pinned.is_empty() => {
                    // prefix-cache hit: adopt a pinned prefix by reference
                    let (blocks, tokens) = pinned[rng.range(0, pinned.len())].clone();
                    let prompt = tokens + rng.range(1, 6);
                    let max = prompt + rng.range(2, 10);
                    if m.admit_shared(next_id, prompt, max, &blocks, tokens) {
                        live.push((next_id, prompt));
                    }
                    next_id += 1;
                }
                3 if !live.is_empty() => {
                    // publish a prompt prefix (pin only up to the floor,
                    // as the engine does at prompt completion)
                    let (seq, floor) = live[rng.range(0, live.len())];
                    let covering = m.blocks_covering(floor);
                    let blocks = m.table(seq).unwrap();
                    if blocks.len() >= covering {
                        let blocks = blocks[..covering].to_vec();
                        let grant = (floor % m.block_size() != 0).then_some(seq);
                        if m.pin_prefix(&blocks, grant) {
                            pinned.push((blocks, floor));
                        }
                    }
                }
                4 if !pinned.is_empty() => {
                    let (blocks, _) = pinned.swap_remove(rng.range(0, pinned.len()));
                    m.unpin_prefix(&blocks);
                }
                5..=8 if !live.is_empty() => {
                    // one speculative tick: draft a burst, then accept a
                    // prefix of it (roll the rest back) — or cancel
                    // mid-draft with the rejected tokens still in place
                    let idx = rng.range(0, live.len());
                    let (seq, _) = live[idx];
                    let before = m.seq_tokens(seq).unwrap();
                    let mut appended = 0usize;
                    for _ in 0..rng.range(1, 6) {
                        if m.append_token(seq) {
                            appended += 1;
                        } else {
                            break;
                        }
                    }
                    if rng.below(8) == 0 {
                        // mid-draft cancel: release before any rollback
                        m.release(seq);
                        live.swap_remove(idx);
                    } else {
                        let accepted = rng.range(0, appended + 1);
                        m.truncate_to(seq, before + accepted);
                    }
                }
                9 if !live.is_empty() => {
                    // full reject all the way down to the floor
                    let (seq, floor) = live[rng.range(0, live.len())];
                    let len = m.seq_tokens(seq).unwrap();
                    if floor <= len {
                        m.truncate_to(seq, floor);
                    }
                }
                _ if !live.is_empty() => {
                    let idx = rng.range(0, live.len());
                    let (seq, _) = live.swap_remove(idx);
                    m.release(seq);
                }
                _ => {}
            }
            m.check_invariants().unwrap();
        }
        for (blocks, _) in pinned {
            m.unpin_prefix(&blocks);
        }
        for (seq, _) in live {
            m.release(seq);
        }
        assert_eq!(m.free_blocks(), 48, "rollback churn leaked blocks");
        m.check_invariants().unwrap();
    }

    #[test]
    fn property_shared_churn_preserves_invariants() {
        let mut rng = Rng::new(4242);
        let mut m = PagedKvManager::new(48, 4);
        let mut live: Vec<SeqId> = Vec::new();
        // pinned prefixes: (blocks, tokens covered)
        let mut pinned: Vec<(Vec<u32>, usize)> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..3000 {
            match rng.below(14) {
                0..=2 => {
                    let prompt = rng.range(1, 16);
                    let max = prompt + rng.range(0, 12);
                    if m.admit(next_id, prompt, max) {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                3..=4 if !pinned.is_empty() => {
                    // admit a request sharing a pinned prefix
                    let (blocks, tokens) = pinned[rng.range(0, pinned.len())].clone();
                    let prompt = tokens + rng.range(1, 8);
                    let max = prompt + rng.range(0, 8);
                    if m.admit_shared(next_id, prompt, max, &blocks, tokens) {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                5..=6 if !live.is_empty() => {
                    // pin a live sequence's leading tokens (cache insert)
                    let seq = live[rng.range(0, live.len())];
                    let len = m.seq_tokens(seq).unwrap();
                    if len > 0 {
                        let tokens = rng.range(1, len + 1);
                        let covering = m.blocks_covering(tokens);
                        let blocks = m.table(seq).unwrap()[..covering].to_vec();
                        let grant = (len / m.block_size() < covering).then_some(seq);
                        if m.pin_prefix(&blocks, grant) {
                            pinned.push((blocks, tokens));
                        }
                    }
                }
                7 if !pinned.is_empty() => {
                    // evict a cached prefix
                    let idx = rng.range(0, pinned.len());
                    let (blocks, _) = pinned.swap_remove(idx);
                    m.unpin_prefix(&blocks);
                }
                8..=11 if !live.is_empty() => {
                    let idx = rng.range(0, live.len());
                    let _ = m.append_token(live[idx]);
                }
                _ if !live.is_empty() => {
                    let idx = rng.range(0, live.len());
                    let seq = live.swap_remove(idx);
                    m.release(seq);
                }
                _ => {}
            }
            m.check_invariants().unwrap();
        }
        for (blocks, _) in pinned {
            m.unpin_prefix(&blocks);
        }
        for seq in live {
            m.release(seq);
        }
        assert_eq!(m.free_blocks(), 48);
        m.check_invariants().unwrap();
    }
}
