//! Next-token sampling.

use super::request::SamplingParams;
use crate::util::Rng;

/// Sampler state per sequence (owns the RNG stream for reproducibility).
pub struct Sampler {
    params: SamplingParams,
    rng: Rng,
}

impl Sampler {
    pub fn new(params: SamplingParams) -> Sampler {
        let seed = match params {
            SamplingParams::Greedy => 0,
            SamplingParams::TopK { seed, .. } => seed,
        };
        Sampler { params, rng: Rng::new(seed) }
    }

    /// Pick the next token id from raw logits.
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        match self.params {
            SamplingParams::Greedy => argmax(logits),
            SamplingParams::TopK { k, temperature, .. } => {
                self.top_k(logits, k.max(1), temperature.max(1e-4))
            }
        }
    }

    fn top_k(&mut self, logits: &[f32], k: usize, temperature: f32) -> u32 {
        let k = k.min(logits.len());
        // indices of the k largest logits
        let mut idx: Vec<u32> = (0..logits.len() as u32).collect();
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            logits[b as usize].partial_cmp(&logits[a as usize]).unwrap()
        });
        let top = &idx[..k];
        let max = top
            .iter()
            .map(|&i| logits[i as usize])
            .fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f64> = top
            .iter()
            .map(|&i| (((logits[i as usize] - max) / temperature) as f64).exp())
            .collect();
        top[self.rng.weighted(&weights)]
    }
}

/// Argmax with deterministic tie-breaking (lowest index).
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut s = Sampler::new(SamplingParams::Greedy);
        assert_eq!(s.sample(&[0.1, 3.0, -2.0, 3.0]), 1); // tie → lowest index
    }

    #[test]
    fn top_k_only_samples_top_k() {
        let mut s = Sampler::new(SamplingParams::TopK { k: 2, temperature: 1.0, seed: 1 });
        let logits = [10.0, 9.0, -50.0, -50.0];
        for _ in 0..200 {
            let t = s.sample(&logits);
            assert!(t == 0 || t == 1, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn top_k_is_seed_deterministic() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let run = |seed| {
            let mut s = Sampler::new(SamplingParams::TopK { k: 8, temperature: 0.9, seed });
            (0..50).map(|_| s.sample(&logits)).collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let mut s = Sampler::new(SamplingParams::TopK { k: 4, temperature: 1e-4, seed: 3 });
        let logits = [1.0, 5.0, 4.9, 2.0];
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 1);
        }
    }
}
