//! Request/response types for the serving path.

use std::time::{Duration, Instant};

use super::error::FailReason;
use crate::util::time::now;

/// How to pick the next token from the logits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplingParams {
    /// Argmax.
    Greedy,
    /// Top-k sampling at a temperature, seeded for reproducibility.
    TopK { k: usize, temperature: f32, seed: u64 },
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams::Greedy
    }
}

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
    /// Lower value = served earlier within the same admission wave.
    pub priority: u8,
    /// Serving budget measured from `arrived`; once exceeded the engine
    /// retires the request (queued or mid-flight) with
    /// [`FinishReason::DeadlineExpired`].
    pub deadline: Option<Duration>,
    pub arrived: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens,
            sampling: SamplingParams::Greedy,
            priority: 0,
            deadline: None,
            arrived: now(),
        }
    }

    pub fn with_sampling(mut self, s: SamplingParams) -> Request {
        self.sampling = s;
        self
    }

    pub fn with_priority(mut self, p: u8) -> Request {
        self.priority = p;
        self
    }

    pub fn with_deadline(mut self, d: Duration) -> Request {
        self.deadline = Some(d);
        self
    }

    /// Total tokens this request may occupy in the KV cache.
    pub fn max_tokens(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }
}

/// Why a sequence stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit the EOS token.
    Eos,
    /// Exhausted `max_new_tokens`.
    Length,
    /// Client cancelled (queued or mid-flight); `Response::tokens`
    /// holds whatever streamed before the cancel landed.
    Cancelled,
    /// The request's deadline passed before it finished.
    DeadlineExpired,
    /// A contained serving fault terminated this request; the reason
    /// says which containment path fired. Its KV blocks were returned
    /// and the engine kept serving the rest of the batch.
    Failed(FailReason),
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Seconds spent queued before prefill started.
    pub queue_secs: f64,
    /// Time to first generated token (from arrival).
    pub ttft_secs: f64,
    /// Total end-to-end seconds.
    pub e2e_secs: f64,
}

impl Response {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.e2e_secs > 0.0 {
            self.tokens.len() as f64 / self.e2e_secs
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_budget() {
        let r = Request::new(1, vec![1, 2, 3], 10);
        assert_eq!(r.max_tokens(), 13);
        assert_eq!(r.sampling, SamplingParams::Greedy);
        assert_eq!(r.deadline, None);
        let r = r.with_deadline(Duration::from_millis(250));
        assert_eq!(r.deadline, Some(Duration::from_millis(250)));
    }

    #[test]
    fn response_throughput() {
        let r = Response {
            id: 1,
            tokens: vec![1; 20],
            finish: FinishReason::Length,
            queue_secs: 0.0,
            ttft_secs: 0.1,
            e2e_secs: 2.0,
        };
        assert!((r.tokens_per_sec() - 10.0).abs() < 1e-9);
    }
}
