//! Error taxonomy for the serving path.
//!
//! The coordinator distinguishes three failure tiers:
//!
//! 1. **Recoverable, per-request** — [`FailReason`]. The offending
//!    request terminates with `FinishReason::Failed(reason)`, every one
//!    of its paged-KV blocks returns to the free list, and the engine
//!    keeps serving the rest of the batch. Backend forward errors,
//!    pool exhaustion beyond the admission commitment, prefix-cache
//!    import mismatches, and speculative-rollback protocol violations
//!    all land here.
//! 2. **Contained engine faults** — a panic that unwinds out of
//!    `Backend::forward_tick` / `spec_tick` is caught at the tick
//!    boundary, the participating requests fail with
//!    [`FailReason::Panic`], and the engine is marked *degraded*
//!    (speculation and prefix-cache insertion stay off) but alive.
//! 3. **Fatal** — [`EngineError`]. Returned from `Engine::step` only
//!    when the paged-KV pool's own invariants no longer hold after a
//!    containment attempt; serving cannot continue safely.
//!
//! Load-bearing `assert!`s (pool accounting, block-table consistency)
//! stay as asserts on purpose: they fire only on coordinator bugs, not
//! on workload- or backend-induced conditions, and masking them would
//! serve corrupt state. See CONTRIBUTING.md "Failure containment
//! invariants" for the full table.

use std::fmt;

/// Why a single request was terminated with
/// `FinishReason::Failed(reason)`. `Copy` so `FinishReason` (and every
/// type embedding it: `Response`, `Event`) stays `Copy`-friendly and
/// pattern-matchable by value.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FailReason {
    /// `Backend::forward_tick` / `new_cache` / `spec_tick` returned an
    /// error; the whole tick's batch shares this failure domain
    /// (per-sequence attribution is impossible once a fused forward
    /// fails).
    Backend,
    /// `PagedKvManager::append_token` refused a token beyond the
    /// sequence's admission commitment — the request asked for more KV
    /// than it reserved.
    PoolExhausted,
    /// An imported prefix-cache snapshot failed post-import validation
    /// against the backend cache.
    CacheImport,
    /// A speculative round broke the rollback protocol (emitted zero
    /// tokens, overran its budget, or accept/draft accounting went
    /// inconsistent).
    SpecRollback,
    /// A panic unwound out of the backend and was contained at the
    /// tick boundary; the engine continues degraded.
    Panic,
    /// The server's drain deadline expired during shutdown before the
    /// request finished.
    Shutdown,
}

impl FailReason {
    /// Stable lowercase label for logs, metrics, and bench records.
    pub fn label(&self) -> &'static str {
        match self {
            FailReason::Backend => "backend",
            FailReason::PoolExhausted => "pool_exhausted",
            FailReason::CacheImport => "cache_import",
            FailReason::SpecRollback => "spec_rollback",
            FailReason::Panic => "panic",
            FailReason::Shutdown => "shutdown",
        }
    }
}

impl fmt::Display for FailReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// Fatal engine failure: `Engine::step` returns this only when serving
/// cannot continue safely. Everything recoverable is a [`FailReason`]
/// on the individual request instead.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// The paged-KV pool failed `check_invariants` after a fault was
    /// contained: block accounting is no longer trustworthy, so every
    /// subsequent admission or append could corrupt live sequences.
    PoolCorrupted(String),
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::PoolCorrupted(detail) => {
                write!(f, "paged-KV pool invariants violated after fault containment: {detail}")
            }
        }
    }
}

impl std::error::Error for EngineError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fail_reason_labels_are_stable() {
        let all = [
            FailReason::Backend,
            FailReason::PoolExhausted,
            FailReason::CacheImport,
            FailReason::SpecRollback,
            FailReason::Panic,
            FailReason::Shutdown,
        ];
        let labels: Vec<&str> = all.iter().map(|r| r.label()).collect();
        assert_eq!(
            labels,
            ["backend", "pool_exhausted", "cache_import", "spec_rollback", "panic", "shutdown"]
        );
        // labels are unique (they key failure counters downstream)
        let set: std::collections::HashSet<&str> = labels.iter().copied().collect();
        assert_eq!(set.len(), all.len());
    }

    #[test]
    fn engine_error_displays_detail() {
        let e = EngineError::PoolCorrupted("seq 3 holds freed block".into());
        let msg = format!("{e}");
        assert!(msg.contains("invariants"), "{msg}");
        assert!(msg.contains("seq 3"), "{msg}");
        // it satisfies std::error::Error so `?` into anyhow works
        let _: &dyn std::error::Error = &e;
    }
}
