//! Streaming session front-end — the public serving API.
//!
//! [`Server::spawn`] moves an [`Engine`] onto a dedicated worker
//! thread; any number of producer threads then [`Server::submit`]
//! requests and consume per-request [`Event`] streams through the
//! returned [`RequestHandle`]s. Tokens surface the moment the engine
//! samples them ([`Event::Token`]), so callers observe true
//! inter-token latency instead of a fully-buffered response — the
//! quantity the paper's §III-E speed claims are about — and can
//! [`RequestHandle::cancel`] mid-flight (paged-KV blocks return to the
//! pool immediately) or bound a request with a deadline
//! ([`super::Request::with_deadline`]).
//!
//! # Bounded channels and backpressure
//!
//! Every channel in the serving path has a fixed capacity, so a slow
//! consumer (or a submit storm) costs bounded memory instead of
//! unbounded growth:
//!
//! * **Control channel** (submit / cancel / shutdown): bounded at
//!   `max(max_queue, 16)` messages. Overflow behavior: producers
//!   **block** in [`Server::submit`] / [`RequestHandle::cancel`] until
//!   the engine drains the backlog — natural backpressure; the engine
//!   thread never sends to this channel, so it cannot deadlock against
//!   itself.
//! * **Per-handle event channels**: bounded at
//!   [`super::EngineConfig::event_buffer`] events. When a consumer
//!   lags, [`super::EngineConfig::backpressure`] picks the policy
//!   ([`BackpressurePolicy`]): `Block` the engine (lossless, default),
//!   `DropOldest` undelivered non-terminal events
//!   (`Metrics::events_dropped` counts them), or `Cancel` the lagging
//!   request. Terminal events are **always** delivered — a full buffer
//!   drops its oldest entries to make room — so a stream never ends
//!   without its `Finished`/`Rejected`.
//!
//! The engine thread multiplexes control messages with scheduling
//! ticks: it drains the control channel without blocking while work is
//! running and parks on it when idle, so an idle server burns no CPU.
//! Dropping a [`RequestHandle`] auto-cancels its request on the next
//! event, and dropping the [`Server`] (or calling [`Server::shutdown`]
//! / [`Server::shutdown_within`]) drains in-flight work — bounded by
//! the drain deadline, past which unfinished requests terminate with
//! `Failed(Shutdown)` so no handle ever hangs — and returns the final
//! [`Metrics`].

use super::engine::{Backend, Engine};
use super::error::FailReason;
use super::metrics::Metrics;
use super::queue::SubmitError;
use super::request::{Request, Response};
use super::EngineConfig;
use crate::util::time::now;
use std::collections::{HashMap, VecDeque};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

/// What the engine does when a request's bounded event channel is full
/// because the consumer reads slower than tokens are generated.
/// Terminal events are exempt: they always land, dropping buffered
/// non-terminal events if that is what it takes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackpressurePolicy {
    /// Park the engine thread until the consumer catches up. Lossless,
    /// and the default — one slow stream throttles the whole engine,
    /// which is the honest behavior for a correctness-first default.
    Block,
    /// Drop the oldest undelivered event to admit the new one. The
    /// stream stays live and bounded but may skip tokens;
    /// `Metrics::events_dropped` counts every loss. The terminal
    /// `Response` still carries the complete token list.
    DropOldest,
    /// Terminate the lagging request with `FinishReason::Cancelled` —
    /// the slow consumer pays, nobody else. The overflowing event is
    /// dropped; the terminal event still arrives.
    Cancel,
}

/// What a [`RequestHandle`] yields. `Finished` and `Rejected` are
/// terminal: the stream closes after them.
#[derive(Debug, Clone)]
pub enum Event {
    /// The request left the queue and began prefill (queue-wait
    /// visibility; also recorded in `Metrics::queue_time`).
    Started { id: u64, queue_secs: f64 },
    /// One generated token, emitted as soon as it was sampled.
    Token { id: u64, token: u32, t_emit: Instant },
    /// Terminal: the full response — any [`super::request::FinishReason`],
    /// including `Cancelled`, `DeadlineExpired`, and `Failed(_)`. Its
    /// `tokens` are exactly the tokens generated for this request, even
    /// when a lossy backpressure policy dropped some `Token` events.
    Finished(Response),
    /// Terminal: the request never entered the queue. When admission
    /// control shed it for queue depth (`SubmitError::Full` on a full
    /// queue), `retry_after` suggests a client back-off in seconds
    /// (estimated backlog drain time); `None` means retrying cannot
    /// help (unservable request, closed server).
    Rejected { id: u64, error: SubmitError, retry_after: Option<f64> },
}

impl Event {
    /// The request this event belongs to.
    pub fn id(&self) -> u64 {
        match self {
            Event::Started { id, .. } => *id,
            Event::Token { id, .. } => *id,
            Event::Rejected { id, .. } => *id,
            Event::Finished(r) => r.id,
        }
    }

    /// True for `Finished` / `Rejected` — the stream ends here.
    pub fn is_terminal(&self) -> bool {
        matches!(self, Event::Finished(_) | Event::Rejected { .. })
    }
}

// ---- bounded per-handle event channel ---------------------------------
//
// std::sync::mpsc offers bounded-blocking (`sync_channel`) but not
// drop-oldest, so the event path uses a small purpose-built channel:
// a VecDeque under a mutex with two condvars. Single producer (the
// engine thread), single consumer (the handle owner); `clone` exists
// only for the submit-time local-rejection path.

struct ChanState {
    buf: VecDeque<Event>,
    /// Receiver still attached; senders see `Disconnected` once false.
    rx_alive: bool,
    /// Live sender count; the receiver sees end-of-stream at zero.
    senders: usize,
    /// Non-terminal events dropped to make room (DropOldest / terminal
    /// force-delivery); drained into `Metrics::events_dropped`.
    dropped: u64,
}

struct Chan {
    state: Mutex<ChanState>,
    cap: usize,
    not_full: Condvar,
    not_empty: Condvar,
}

impl Chan {
    fn locked(&self) -> MutexGuard<'_, ChanState> {
        // A poisoned mutex means a peer thread panicked mid-push/pop;
        // the deque of plain events is still structurally sound, so
        // recover the guard instead of cascading the panic.
        match self.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }
}

/// How one bounded send went.
enum SendOutcome {
    Sent,
    /// Receiver hung up (handle dropped): nothing was delivered.
    Disconnected,
    /// `Cancel` policy and the buffer is full: the event was discarded
    /// and the caller should cancel the request.
    Overflow,
}

struct EventTx(Arc<Chan>);

impl Clone for EventTx {
    fn clone(&self) -> EventTx {
        self.0.locked().senders += 1;
        EventTx(Arc::clone(&self.0))
    }
}

impl Drop for EventTx {
    fn drop(&mut self) {
        let mut s = self.0.locked();
        s.senders -= 1;
        let last = s.senders == 0;
        drop(s);
        if last {
            // end-of-stream: wake a receiver parked in recv()
            self.0.not_empty.notify_all();
        }
    }
}

impl EventTx {
    /// Send one event under the given slow-consumer policy. Returns the
    /// outcome plus how many buffered non-terminal events were dropped
    /// to make room (terminal events always land).
    fn send(&self, ev: Event, policy: BackpressurePolicy) -> (SendOutcome, u64) {
        let mut s = self.0.locked();
        if !s.rx_alive {
            return (SendOutcome::Disconnected, 0);
        }
        let mut dropped = 0u64;
        if ev.is_terminal() {
            // a stream must always end with its terminal event: evict
            // the oldest buffered events if the consumer let them pile up
            while s.buf.len() >= self.0.cap {
                s.buf.pop_front();
                dropped += 1;
            }
        } else {
            match policy {
                BackpressurePolicy::Block => {
                    while s.buf.len() >= self.0.cap && s.rx_alive {
                        s = match self.0.not_full.wait(s) {
                            Ok(g) => g,
                            Err(poisoned) => poisoned.into_inner(),
                        };
                    }
                    if !s.rx_alive {
                        return (SendOutcome::Disconnected, 0);
                    }
                }
                BackpressurePolicy::DropOldest => {
                    while s.buf.len() >= self.0.cap {
                        s.buf.pop_front();
                        dropped += 1;
                    }
                }
                BackpressurePolicy::Cancel => {
                    if s.buf.len() >= self.0.cap {
                        s.dropped += 1;
                        return (SendOutcome::Overflow, 1);
                    }
                }
            }
        }
        s.dropped += dropped;
        s.buf.push_back(ev);
        drop(s);
        self.0.not_empty.notify_one();
        (SendOutcome::Sent, dropped)
    }
}

struct EventRx(Arc<Chan>);

impl Drop for EventRx {
    fn drop(&mut self) {
        self.0.locked().rx_alive = false;
        // unpark an engine thread blocked on a full buffer: its send
        // returns Disconnected, which triggers auto-cancel
        self.0.not_full.notify_all();
    }
}

impl EventRx {
    /// Blocking receive; `None` once all senders are gone and the
    /// buffer is drained.
    fn recv(&self) -> Option<Event> {
        let mut s = self.0.locked();
        loop {
            if let Some(ev) = s.buf.pop_front() {
                drop(s);
                self.0.not_full.notify_one();
                return Some(ev);
            }
            if s.senders == 0 {
                return None;
            }
            s = match self.0.not_empty.wait(s) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }

    /// Non-blocking receive; `None` when nothing is buffered.
    fn try_recv(&self) -> Option<Event> {
        let ev = self.0.locked().buf.pop_front();
        if ev.is_some() {
            self.0.not_full.notify_one();
        }
        ev
    }
}

fn event_channel(cap: usize) -> (EventTx, EventRx) {
    let chan = Arc::new(Chan {
        state: Mutex::new(ChanState {
            buf: VecDeque::with_capacity(cap.clamp(1, 64)),
            rx_alive: true,
            senders: 1,
            dropped: 0,
        }),
        cap: cap.max(1),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
    });
    (EventTx(Arc::clone(&chan)), EventRx(chan))
}

enum Ctl {
    Submit(Box<Request>, EventTx),
    Cancel(u64),
    /// Stop accepting work and drain; unfinished requests terminate
    /// with `Failed(Shutdown)` once the deadline (the config's
    /// `drain_deadline` when `None`) passes.
    Shutdown(Option<Duration>),
}

/// Handle to one submitted request: a live [`Event`] stream plus a
/// cancellation edge. The stream always ends with exactly one terminal
/// event (unless the server died mid-request, in which case it just
/// closes).
pub struct RequestHandle {
    id: u64,
    ctl: mpsc::SyncSender<Ctl>,
    events: EventRx,
}

impl RequestHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Next event, blocking. `None` once the stream is closed.
    pub fn recv(&self) -> Option<Event> {
        self.events.recv()
    }

    /// Next event if one is ready (non-blocking).
    pub fn try_recv(&self) -> Option<Event> {
        self.events.try_recv()
    }

    /// Blocking iterator over the remaining events; ends after the
    /// terminal event.
    pub fn events(&self) -> impl Iterator<Item = Event> + '_ {
        std::iter::from_fn(move || self.events.recv())
    }

    /// Ask the engine to cancel this request, queued or mid-flight.
    /// The stream still terminates with [`Event::Finished`] (reason
    /// `Cancelled`, tokens streamed so far included) — unless the
    /// request already finished, in which case the cancel is a no-op.
    /// May block briefly if the bounded control channel is full.
    pub fn cancel(&self) {
        let _ = self.ctl.send(Ctl::Cancel(self.id));
    }

    /// Drain the stream to its terminal event.
    pub fn wait(self) -> Result<Response, SubmitError> {
        loop {
            match self.events.recv() {
                Some(Event::Finished(r)) => return Ok(r),
                Some(Event::Rejected { error, .. }) => return Err(error),
                Some(_) => {}
                None => return Err(SubmitError::Closed),
            }
        }
    }
}

/// The streaming session server: owns the engine thread.
pub struct Server {
    ctl: mpsc::SyncSender<Ctl>,
    /// Per-handle event-channel capacity, copied out of the config at
    /// spawn (the config itself moves into the engine).
    event_buffer: usize,
    worker: Option<thread::JoinHandle<Metrics>>,
}

impl Server {
    /// Move `backend` into an [`Engine`] on a dedicated worker thread
    /// and start serving.
    pub fn spawn<B>(backend: B, cfg: EngineConfig) -> Server
    where
        B: Backend + Send + 'static,
        B::Kv: Send,
    {
        // Bounded control channel: producers block past the bound (see
        // the module docs). Sized to the admission queue so control
        // backpressure engages only once the queue itself is saturated.
        let (ctl, ctl_rx) = mpsc::sync_channel(cfg.max_queue.max(16));
        let event_buffer = cfg.event_buffer;
        let worker = thread::Builder::new()
            .name("gptqt-engine".into())
            .spawn(move || serve_loop(Engine::new(backend, cfg), ctl_rx))
            // lint:allow(no-panic-serve) startup: no engine thread means
            // no server — construction failure, not a serving fault.
            .expect("spawn engine thread");
        Server { ctl, event_buffer, worker: Some(worker) }
    }

    /// Submit a request; its lifecycle streams through the returned
    /// handle. Validation happens on the engine thread — a request the
    /// engine cannot serve yields [`Event::Rejected`] as the stream's
    /// only event. Blocks while the bounded control channel is full
    /// (the documented overflow behavior).
    pub fn submit(&self, req: Request) -> RequestHandle {
        let (tx, rx) = event_channel(self.event_buffer);
        let id = req.id;
        if self.ctl.send(Ctl::Submit(Box::new(req), tx.clone())).is_err() {
            // engine thread is gone: reject locally so the handle still
            // sees a terminal event
            let _ = tx.send(
                Event::Rejected { id, error: SubmitError::Closed, retry_after: None },
                BackpressurePolicy::Block,
            );
        }
        RequestHandle { id, ctl: self.ctl.clone(), events: rx }
    }

    /// Stop accepting new requests, drain everything in flight (bounded
    /// by the config's `drain_deadline`), join the engine thread, and
    /// return its final metrics.
    pub fn shutdown(mut self) -> Metrics {
        self.shutdown_impl(None)
    }

    /// [`Server::shutdown`] with an explicit drain deadline: requests
    /// still unfinished past it terminate with `Failed(Shutdown)` so no
    /// handle hangs and no block leaks.
    pub fn shutdown_within(mut self, deadline: Duration) -> Metrics {
        self.shutdown_impl(Some(deadline))
    }

    fn shutdown_impl(&mut self, deadline: Option<Duration>) -> Metrics {
        let _ = self.ctl.send(Ctl::Shutdown(deadline));
        let worker = match self.worker.take() {
            // unreachable: both shutdown entry points consume `self`
            None => return Metrics::new(),
            Some(w) => w,
        };
        match worker.join() {
            Ok(metrics) => metrics,
            // the engine thread itself panicked (nothing contained it):
            // surface that on the caller instead of fabricating metrics
            Err(panic) => std::panic::resume_unwind(panic),
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(worker) = self.worker.take() {
            let _ = self.ctl.send(Ctl::Shutdown(None));
            let _ = worker.join();
        }
    }
}

/// Route one tick's events to their per-request channels, applying the
/// backpressure policy and its consequences (auto-cancel on dropped
/// handles, cancel-on-overflow, drop accounting).
fn route_events<B: Backend>(
    engine: &mut Engine<B>,
    sinks: &mut HashMap<u64, EventTx>,
    events: Vec<Event>,
) {
    let policy = engine.cfg.backpressure;
    for ev in events {
        let id = ev.id();
        if ev.is_terminal() {
            // drop the sink *before* sending: the entry is gone even if
            // the receiver already hung up, so the map can never grow
            // with server lifetime
            if let Some(tx) = sinks.remove(&id) {
                let (_, dropped) = tx.send(ev, policy);
                engine.metrics.events_dropped += dropped;
            }
        } else {
            let sent = sinks.get(&id).map(|tx| tx.send(ev, policy));
            if let Some((outcome, dropped)) = sent {
                engine.metrics.events_dropped += dropped;
                match outcome {
                    SendOutcome::Sent => {}
                    SendOutcome::Disconnected => {
                        // handle dropped: free the KV blocks and stop
                        // spending ticks on a stream nobody reads
                        sinks.remove(&id);
                        engine.cancel(id);
                    }
                    SendOutcome::Overflow => {
                        // slow consumer under the Cancel policy: the
                        // request terminates, but its sink stays — the
                        // terminal Finished(Cancelled) always lands
                        engine.cancel(id);
                    }
                }
            }
        }
    }
}

/// The engine thread: multiplex control messages with scheduling ticks
/// and route every event to its request's channel.
fn serve_loop<B: Backend>(mut engine: Engine<B>, ctl: mpsc::Receiver<Ctl>) -> Metrics {
    let mut sinks: HashMap<u64, EventTx> = HashMap::new();
    // sink-lifecycle gauges: `sinks_peak` is the high-water mark,
    // `sinks_open_final` must drain to zero — every sink is dropped the
    // moment its terminal event routes, so the map cannot grow with
    // server lifetime (pinned by `sink_map_drains_to_zero`)
    let mut sinks_peak = 0usize;
    let mut draining = false;
    let mut drain_deadline: Option<Instant> = None;
    'serve: loop {
        // ---- control: non-blocking while busy, parked when idle --------
        loop {
            let msg = if engine.has_work() {
                match ctl.try_recv() {
                    Ok(m) => Some(m),
                    Err(mpsc::TryRecvError::Empty) => None,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        draining = true;
                        None
                    }
                }
            } else if draining {
                break 'serve;
            } else {
                match ctl.recv() {
                    Ok(m) => Some(m),
                    // every Server clone and handle is gone, nothing runs
                    Err(_) => break 'serve,
                }
            };
            let Some(msg) = msg else { break };
            match msg {
                Ctl::Submit(req, tx) => {
                    let id = req.id;
                    if draining {
                        let (_, d) = tx.send(
                            Event::Rejected {
                                id,
                                error: SubmitError::Closed,
                                retry_after: None,
                            },
                            engine.cfg.backpressure,
                        );
                        engine.metrics.events_dropped += d;
                    } else {
                        let shed_before = engine.metrics.shed_total;
                        match engine.submit(*req) {
                            Ok(()) => {
                                sinks.insert(id, tx);
                                sinks_peak = sinks_peak.max(sinks.len());
                            }
                            Err(error) => {
                                // a queue-depth shed (vs an unservable
                                // request) carries a drain-time hint so
                                // clients back off instead of hammering
                                let retry_after = (engine.metrics.shed_total > shed_before)
                                    .then(|| engine.retry_after_hint());
                                let (_, d) = tx.send(
                                    Event::Rejected { id, error, retry_after },
                                    engine.cfg.backpressure,
                                );
                                engine.metrics.events_dropped += d;
                            }
                        }
                    }
                }
                Ctl::Cancel(id) => {
                    engine.cancel(id);
                }
                Ctl::Shutdown(deadline) => {
                    draining = true;
                    if drain_deadline.is_none() {
                        drain_deadline =
                            Some(now() + deadline.unwrap_or(engine.cfg.drain_deadline));
                    }
                }
            }
        }
        if !engine.has_work() {
            continue;
        }

        // ---- drain deadline: no handle hangs past it -------------------
        if draining {
            let deadline =
                *drain_deadline.get_or_insert_with(|| now() + engine.cfg.drain_deadline);
            if now() >= deadline {
                let events = engine.abort_all(FailReason::Shutdown);
                route_events(&mut engine, &mut sinks, events);
                continue; // no work left: the control loop exits
            }
        }

        // ---- one scheduling tick ---------------------------------------
        match engine.step() {
            Ok(events) => route_events(&mut engine, &mut sinks, events),
            Err(e) => {
                // recoverable faults already terminated per-request
                // inside step(); an Err is EngineError::PoolCorrupted —
                // the one state serving cannot continue from. Closing
                // the sinks ends every stream without a terminal event.
                eprintln!("gptqt-engine: fatal: {e}");
                break 'serve;
            }
        }
    }
    // teardown: unpin cached prefixes so the pool-drain gauges report
    // true leaks, not intentional cache pins
    engine.clear_prefix_cache();
    let free = engine.kv().free_blocks() as u64;
    let total = free + engine.kv().used_blocks() as u64;
    let mut metrics = engine.into_metrics();
    metrics.kv_blocks_free_final = free;
    metrics.kv_blocks_total = total;
    metrics.sinks_peak = sinks_peak as u64;
    metrics.sinks_open_final = sinks.len() as u64;
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;
    use crate::coordinator::CpuBackend;
    use crate::model::init::random_weights;
    use crate::model::{presets, BackendModel, Model};

    fn backend(seed: u64) -> CpuBackend {
        let mut cfg = presets::by_name("opt-nano").unwrap();
        cfg.vocab = 64;
        cfg.max_seq = 48;
        let model = Model::new(cfg.clone(), random_weights(&cfg, seed));
        CpuBackend(BackendModel::dense(&model))
    }

    fn cfg(max_batch: usize) -> EngineConfig {
        EngineConfig {
            max_batch,
            total_blocks: 64,
            block_size: 8,
            // random-weight models can argmax the EOS id; disable EOS so
            // generation lengths are deterministic in these tests
            eos_token: u32::MAX,
            ..Default::default()
        }
    }

    #[test]
    fn serves_and_streams_tokens() {
        let server = Server::spawn(backend(1), cfg(2));
        let h = server.submit(Request::new(1, vec![5, 9, 13], 6));
        let mut streamed = Vec::new();
        let mut saw_started = false;
        let resp = loop {
            match h.recv().expect("stream must end with a terminal event") {
                Event::Started { id, .. } => {
                    assert_eq!(id, 1);
                    saw_started = true;
                }
                Event::Token { id, token, .. } => {
                    assert_eq!(id, 1);
                    streamed.push(token);
                }
                Event::Finished(r) => break r,
                Event::Rejected { error, .. } => panic!("rejected: {error:?}"),
            }
        };
        assert!(saw_started, "admission must be visible");
        assert_eq!(resp.tokens, streamed, "stream and response must agree");
        assert!(h.recv().is_none(), "stream closed after terminal event");
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.events_dropped, 0, "Block policy loses nothing");
    }

    #[test]
    fn rejects_unservable_requests_via_event() {
        let server = Server::spawn(backend(2), cfg(2));
        // capacity is 48; this wants 100
        let h = server.submit(Request::new(1, vec![3; 50], 50));
        match h.recv() {
            Some(Event::Rejected { error: SubmitError::Full, retry_after, .. }) => {
                assert!(retry_after.is_none(), "unservable ≠ shed: retrying cannot help");
            }
            other => panic!("expected Rejected(Full), got {other:?}"),
        }
        // empty prompt is unservable too
        let h = server.submit(Request::new(2, vec![], 4));
        assert!(h.wait().is_err());
        let m = server.shutdown();
        assert_eq!(m.rejected, 2);
        assert_eq!(m.shed_total, 0, "semantic rejections are not shed load");
    }

    #[test]
    fn queue_full_shed_carries_retry_after() {
        // queue of 1 and a single busy slot: the third submit must shed
        let mut c = cfg(1);
        c.max_queue = 1;
        let server = Server::spawn(backend(10), c);
        let busy = server.submit(Request::new(0, vec![4; 6], 40));
        // wait until 0 is admitted so it occupies the engine, not the queue
        while !matches!(busy.recv().expect("stream alive"), Event::Started { .. }) {}
        let queued = server.submit(Request::new(1, vec![4; 6], 4));
        // 0 running + 1 queued: this one must be shed with a hint
        let shed = server.submit(Request::new(2, vec![4; 6], 4));
        match shed.wait() {
            Err(SubmitError::Full) => {}
            other => panic!("expected shed Full rejection, got {other:?}"),
        }
        let _ = busy.wait();
        let _ = queued.wait();
        let m = server.shutdown();
        assert!(m.shed_total >= 1, "queue-depth shed must be counted");
        // the shed stream carried a retry hint — verify via a fresh shed
        // is racy here; the counter + the Rejected shape are pinned by
        // `rejects_unservable_requests_via_event` and engine unit tests
    }

    #[test]
    fn submit_after_shutdown_is_rejected() {
        let server = Server::spawn(backend(3), cfg(2));
        let ctl = server.ctl.clone();
        let m = server.shutdown();
        assert_eq!(m.completed, 0);
        // a handle built against the dead thread still terminates
        let (tx, rx) = event_channel(4);
        if ctl.send(Ctl::Submit(Box::new(Request::new(9, vec![3], 2)), tx.clone())).is_err() {
            let _ = tx.send(
                Event::Rejected { id: 9, error: SubmitError::Closed, retry_after: None },
                BackpressurePolicy::Block,
            );
        }
        drop(tx);
        match rx.recv() {
            Some(Event::Rejected { error: SubmitError::Closed, .. }) | None => {}
            other => panic!("expected closed-channel rejection, got {other:?}"),
        }
    }

    #[test]
    fn cancel_queued_request_streams_cancelled() {
        // max_batch 1: request 0 occupies the engine long enough that
        // the FIFO control channel guarantees request 1 is still queued
        // when its cancel lands
        let server = Server::spawn(backend(4), cfg(1));
        let long = server.submit(Request::new(0, vec![4; 6], 40));
        let doomed = server.submit(Request::new(1, vec![4; 6], 4));
        doomed.cancel();
        let r = doomed.wait().expect("cancelled stream still terminates");
        assert_eq!(r.finish, FinishReason::Cancelled);
        assert!(r.tokens.is_empty());
        let r = long.wait().unwrap();
        assert_eq!(r.finish, FinishReason::Length);
        assert_eq!(r.tokens.len(), 40);
        let m = server.shutdown();
        assert_eq!(m.cancelled_total, 1);
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn sink_map_drains_to_zero() {
        // N requests through every terminal path — natural finish,
        // cancel, and reject — must leave no sink behind: the map is
        // keyed per request and an entry that outlives its terminal
        // event is a leak that grows with server lifetime
        let server = Server::spawn(backend(6), cfg(4));
        let mut handles = Vec::new();
        for id in 0..8u64 {
            handles.push(server.submit(Request::new(id, vec![4; 6], 8)));
        }
        // a couple of mid-flight / queued cancels
        handles[2].cancel();
        handles[5].cancel();
        // one structurally rejected request (never gets a sink)
        let rejected = server.submit(Request::new(100, vec![], 4));
        assert!(rejected.wait().is_err());
        for h in handles {
            let _ = h.wait();
        }
        let m = server.shutdown();
        assert!(m.sinks_peak >= 1, "submissions must register sinks");
        assert_eq!(
            m.sinks_open_final, 0,
            "every terminal event must drop its sink (peak was {})",
            m.sinks_peak
        );
        assert_eq!(m.completed + m.cancelled_total, 8);
        assert_eq!(m.kv_blocks_free_final, m.kv_blocks_total, "no block leaks");
    }

    #[test]
    fn dropped_handle_auto_cancels() {
        let server = Server::spawn(backend(5), cfg(2));
        let h = server.submit(Request::new(0, vec![4; 6], 40));
        // read one token so the request is known to be mid-flight
        while !matches!(h.recv().expect("stream alive"), Event::Token { .. }) {}
        drop(h);
        let m = server.shutdown();
        assert_eq!(
            m.cancelled_total + m.completed,
            1,
            "dropped handle must cancel (or the request raced to completion)"
        );
    }

    #[test]
    fn dropped_handle_mid_prefill_returns_all_blocks() {
        // one-token prefill chunks stretch a 24-token prompt across 24
        // ticks: the handle is long gone before prefill can finish, so
        // the auto-cancel provably lands mid-prefill — and every
        // admission-committed KV block must come back
        let mut c = cfg(2);
        c.prefill_chunk = 1;
        let server = Server::spawn(backend(7), c);
        let doomed = server.submit(Request::new(0, vec![4; 24], 8));
        drop(doomed);
        // a live request sharing the pool proves serving continues
        let live = server.submit(Request::new(1, vec![4; 6], 4));
        let r = live.wait().expect("live request must be unaffected");
        assert_eq!(r.finish, FinishReason::Length);
        assert_eq!(r.tokens.len(), 4);
        let m = server.shutdown();
        assert_eq!(m.cancelled_total, 1, "dropped handle must auto-cancel");
        assert_eq!(m.sinks_open_final, 0);
        assert_eq!(
            m.kv_blocks_free_final, m.kv_blocks_total,
            "mid-prefill cancel must return every KV block to free"
        );
    }

    #[test]
    fn shutdown_deadline_terminates_inflight_with_failed_shutdown() {
        let server = Server::spawn(backend(8), cfg(2));
        let h = server.submit(Request::new(0, vec![4; 6], 40));
        // mid-flight: at least one token has streamed
        while !matches!(h.recv().expect("stream alive"), Event::Token { .. }) {}
        let m = server.shutdown_within(Duration::ZERO);
        // the handle terminates (no hang) with the shutdown failure
        let r = h.wait().expect("handle must not hang across a deadline shutdown");
        assert_eq!(r.finish, FinishReason::Failed(FailReason::Shutdown));
        assert!(!r.tokens.is_empty(), "tokens streamed before shutdown are kept");
        assert_eq!(m.requests_failed, 1);
        assert_eq!(m.sinks_open_final, 0, "terminal events drained every sink");
        assert_eq!(m.kv_blocks_free_final, m.kv_blocks_total, "no block leaks");
    }

    #[test]
    fn drop_oldest_policy_bounds_slow_consumer_losslessly_in_response() {
        let mut c = cfg(2);
        c.event_buffer = 4;
        c.backpressure = BackpressurePolicy::DropOldest;
        let server = Server::spawn(backend(9), c);
        let h = server.submit(Request::new(0, vec![4; 6], 30));
        // read nothing until the server has fully drained: ~32 events
        // into a 4-slot buffer must drop, not block, not grow
        let m = server.shutdown();
        assert_eq!(m.completed, 1, "DropOldest never stalls the engine");
        assert!(m.events_dropped > 0, "a 4-slot buffer cannot hold 30 tokens");
        let r = h.wait().expect("terminal event always delivered");
        assert_eq!(r.finish, FinishReason::Length);
        assert_eq!(r.tokens.len(), 30, "the response carries every token even when events drop");
    }

    #[test]
    fn cancel_policy_terminates_slow_consumer() {
        let mut c = cfg(2);
        c.event_buffer = 2;
        c.backpressure = BackpressurePolicy::Cancel;
        let server = Server::spawn(backend(11), c);
        let h = server.submit(Request::new(0, vec![4; 6], 40));
        // never read: the third event overflows and cancels the request
        let m = server.shutdown();
        assert_eq!(m.cancelled_total, 1, "slow consumer must be cancelled");
        assert!(m.events_dropped >= 1, "the overflowing event is dropped");
        let r = h.wait().expect("terminal event still delivered");
        assert_eq!(r.finish, FinishReason::Cancelled);
        assert!(r.tokens.len() < 40, "cancel must cut generation short");
        assert_eq!(m.kv_blocks_free_final, m.kv_blocks_total, "no block leaks");
    }
}
