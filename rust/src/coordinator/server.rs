//! Streaming session front-end — the public serving API.
//!
//! [`Server::spawn`] moves an [`Engine`] onto a dedicated worker
//! thread; any number of producer threads then [`Server::submit`]
//! requests and consume per-request [`Event`] streams through the
//! returned [`RequestHandle`]s. Tokens surface the moment the engine
//! samples them ([`Event::Token`]), so callers observe true
//! inter-token latency instead of a fully-buffered response — the
//! quantity the paper's §III-E speed claims are about — and can
//! [`RequestHandle::cancel`] mid-flight (paged-KV blocks return to the
//! pool immediately) or bound a request with a deadline
//! ([`super::Request::with_deadline`]).
//!
//! The engine thread multiplexes control messages (submit / cancel /
//! shutdown) with scheduling ticks: it drains the control channel
//! without blocking while work is running and parks on it when idle,
//! so an idle server burns no CPU. Dropping a [`RequestHandle`]
//! auto-cancels its request on the next token, and dropping the
//! [`Server`] (or calling [`Server::shutdown`]) drains in-flight work
//! and returns the final [`Metrics`].

use super::engine::{Backend, Engine};
use super::metrics::Metrics;
use super::queue::SubmitError;
use super::request::{Request, Response};
use super::EngineConfig;
use std::collections::HashMap;
use std::sync::mpsc;
use std::thread;
use std::time::Instant;

/// What a [`RequestHandle`] yields. `Finished` and `Rejected` are
/// terminal: the stream closes after them.
#[derive(Debug, Clone)]
pub enum Event {
    /// The request left the queue and began prefill (queue-wait
    /// visibility; also recorded in `Metrics::queue_time`).
    Started { id: u64, queue_secs: f64 },
    /// One generated token, emitted as soon as it was sampled.
    Token { id: u64, token: u32, t_emit: Instant },
    /// Terminal: the full response — any [`super::request::FinishReason`],
    /// including `Cancelled` and `DeadlineExpired`. Its `tokens` are
    /// exactly the concatenated `Token` events of this stream.
    Finished(Response),
    /// Terminal: the request never entered the queue.
    Rejected { id: u64, error: SubmitError },
}

impl Event {
    /// The request this event belongs to.
    pub fn id(&self) -> u64 {
        match self {
            Event::Started { id, .. } => *id,
            Event::Token { id, .. } => *id,
            Event::Rejected { id, .. } => *id,
            Event::Finished(r) => r.id,
        }
    }

    /// True for `Finished` / `Rejected` — the stream ends here.
    pub fn is_terminal(&self) -> bool {
        matches!(self, Event::Finished(_) | Event::Rejected { .. })
    }
}

enum Ctl {
    Submit(Box<Request>, mpsc::Sender<Event>),
    Cancel(u64),
    Shutdown,
}

/// Handle to one submitted request: a live [`Event`] stream plus a
/// cancellation edge. The stream always ends with exactly one terminal
/// event (unless the server died mid-request, in which case it just
/// closes).
pub struct RequestHandle {
    id: u64,
    ctl: mpsc::Sender<Ctl>,
    events: mpsc::Receiver<Event>,
}

impl RequestHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Next event, blocking. `None` once the stream is closed.
    pub fn recv(&self) -> Option<Event> {
        self.events.recv().ok()
    }

    /// Next event if one is ready (non-blocking).
    pub fn try_recv(&self) -> Option<Event> {
        self.events.try_recv().ok()
    }

    /// Blocking iterator over the remaining events; ends after the
    /// terminal event.
    pub fn events(&self) -> impl Iterator<Item = Event> + '_ {
        self.events.iter()
    }

    /// Ask the engine to cancel this request, queued or mid-flight.
    /// The stream still terminates with [`Event::Finished`] (reason
    /// `Cancelled`, tokens streamed so far included) — unless the
    /// request already finished, in which case the cancel is a no-op.
    pub fn cancel(&self) {
        let _ = self.ctl.send(Ctl::Cancel(self.id));
    }

    /// Drain the stream to its terminal event.
    pub fn wait(self) -> Result<Response, SubmitError> {
        loop {
            match self.events.recv() {
                Ok(Event::Finished(r)) => return Ok(r),
                Ok(Event::Rejected { error, .. }) => return Err(error),
                Ok(_) => {}
                Err(_) => return Err(SubmitError::Closed),
            }
        }
    }
}

/// The streaming session server: owns the engine thread.
pub struct Server {
    ctl: mpsc::Sender<Ctl>,
    worker: Option<thread::JoinHandle<Metrics>>,
}

impl Server {
    /// Move `backend` into an [`Engine`] on a dedicated worker thread
    /// and start serving.
    pub fn spawn<B>(backend: B, cfg: EngineConfig) -> Server
    where
        B: Backend + Send + 'static,
        B::Kv: Send,
    {
        let (ctl, ctl_rx) = mpsc::channel();
        let worker = thread::Builder::new()
            .name("gptqt-engine".into())
            .spawn(move || serve_loop(Engine::new(backend, cfg), ctl_rx))
            .expect("spawn engine thread");
        Server { ctl, worker: Some(worker) }
    }

    /// Submit a request; its lifecycle streams through the returned
    /// handle. Validation happens on the engine thread — a request the
    /// engine cannot serve yields [`Event::Rejected`] as the stream's
    /// only event.
    pub fn submit(&self, req: Request) -> RequestHandle {
        let (tx, rx) = mpsc::channel();
        let id = req.id;
        if self.ctl.send(Ctl::Submit(Box::new(req), tx.clone())).is_err() {
            // engine thread is gone: reject locally so the handle still
            // sees a terminal event
            let _ = tx.send(Event::Rejected { id, error: SubmitError::Closed });
        }
        RequestHandle { id, ctl: self.ctl.clone(), events: rx }
    }

    /// Stop accepting new requests, drain everything in flight, join
    /// the engine thread, and return its final metrics.
    pub fn shutdown(mut self) -> Metrics {
        let _ = self.ctl.send(Ctl::Shutdown);
        self.worker
            .take()
            .expect("server already shut down")
            .join()
            .expect("engine thread panicked")
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(worker) = self.worker.take() {
            let _ = self.ctl.send(Ctl::Shutdown);
            let _ = worker.join();
        }
    }
}

/// The engine thread: multiplex control messages with scheduling ticks
/// and route every event to its request's channel.
fn serve_loop<B: Backend>(mut engine: Engine<B>, ctl: mpsc::Receiver<Ctl>) -> Metrics {
    let mut sinks: HashMap<u64, mpsc::Sender<Event>> = HashMap::new();
    // sink-lifecycle gauges: `sinks_peak` is the high-water mark,
    // `sinks_open_final` must drain to zero — every sink is dropped the
    // moment its terminal event routes, so the map cannot grow with
    // server lifetime (pinned by `sink_map_drains_to_zero`)
    let mut sinks_peak = 0usize;
    let mut draining = false;
    'serve: loop {
        // ---- control: non-blocking while busy, parked when idle --------
        loop {
            let msg = if engine.has_work() {
                match ctl.try_recv() {
                    Ok(m) => Some(m),
                    Err(mpsc::TryRecvError::Empty) => None,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        draining = true;
                        None
                    }
                }
            } else if draining {
                break 'serve;
            } else {
                match ctl.recv() {
                    Ok(m) => Some(m),
                    // every Server clone and handle is gone, nothing runs
                    Err(_) => break 'serve,
                }
            };
            let Some(msg) = msg else { break };
            match msg {
                Ctl::Submit(req, tx) => {
                    let id = req.id;
                    if draining {
                        let _ = tx.send(Event::Rejected { id, error: SubmitError::Closed });
                    } else {
                        match engine.submit(*req) {
                            Ok(()) => {
                                sinks.insert(id, tx);
                                sinks_peak = sinks_peak.max(sinks.len());
                            }
                            Err(error) => {
                                let _ = tx.send(Event::Rejected { id, error });
                            }
                        }
                    }
                }
                Ctl::Cancel(id) => {
                    engine.cancel(id);
                }
                Ctl::Shutdown => draining = true,
            }
        }
        if !engine.has_work() {
            continue;
        }

        // ---- one scheduling tick ---------------------------------------
        match engine.step() {
            Ok(events) => {
                for ev in events {
                    let id = ev.id();
                    if ev.is_terminal() {
                        // drop the sink *before* sending: the entry is
                        // gone even if the receiver already hung up,
                        // so the map can never grow with server lifetime
                        if let Some(tx) = sinks.remove(&id) {
                            let _ = tx.send(ev);
                        }
                    } else if sinks.get(&id).is_some_and(|tx| tx.send(ev).is_err()) {
                        // handle dropped: free the KV blocks and stop
                        // spending ticks on a stream nobody reads
                        sinks.remove(&id);
                        engine.cancel(id);
                    }
                }
            }
            Err(e) => {
                // backend failure is fatal for the whole engine; closing
                // the sinks ends every stream without a terminal event
                eprintln!("gptqt-engine: fatal backend error: {e:#}");
                break 'serve;
            }
        }
    }
    let mut metrics = engine.into_metrics();
    metrics.sinks_peak = sinks_peak as u64;
    metrics.sinks_open_final = sinks.len() as u64;
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;
    use crate::coordinator::CpuBackend;
    use crate::model::init::random_weights;
    use crate::model::{presets, BackendModel, Model};

    fn backend(seed: u64) -> CpuBackend {
        let mut cfg = presets::by_name("opt-nano").unwrap();
        cfg.vocab = 64;
        cfg.max_seq = 48;
        let model = Model::new(cfg.clone(), random_weights(&cfg, seed));
        CpuBackend(BackendModel::dense(&model))
    }

    fn cfg(max_batch: usize) -> EngineConfig {
        EngineConfig {
            max_batch,
            total_blocks: 64,
            block_size: 8,
            // random-weight models can argmax the EOS id; disable EOS so
            // generation lengths are deterministic in these tests
            eos_token: u32::MAX,
            ..Default::default()
        }
    }

    #[test]
    fn serves_and_streams_tokens() {
        let server = Server::spawn(backend(1), cfg(2));
        let h = server.submit(Request::new(1, vec![5, 9, 13], 6));
        let mut streamed = Vec::new();
        let mut saw_started = false;
        let resp = loop {
            match h.recv().expect("stream must end with a terminal event") {
                Event::Started { id, .. } => {
                    assert_eq!(id, 1);
                    saw_started = true;
                }
                Event::Token { id, token, .. } => {
                    assert_eq!(id, 1);
                    streamed.push(token);
                }
                Event::Finished(r) => break r,
                Event::Rejected { error, .. } => panic!("rejected: {error:?}"),
            }
        };
        assert!(saw_started, "admission must be visible");
        assert_eq!(resp.tokens, streamed, "stream and response must agree");
        assert!(h.recv().is_none(), "stream closed after terminal event");
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn rejects_unservable_requests_via_event() {
        let server = Server::spawn(backend(2), cfg(2));
        // capacity is 48; this wants 100
        let h = server.submit(Request::new(1, vec![3; 50], 50));
        match h.wait() {
            Err(SubmitError::Full) => {}
            other => panic!("expected Rejected(Full), got {other:?}"),
        }
        // empty prompt is unservable too
        let h = server.submit(Request::new(2, vec![], 4));
        assert!(h.wait().is_err());
        let m = server.shutdown();
        assert_eq!(m.rejected, 2);
    }

    #[test]
    fn submit_after_shutdown_is_rejected() {
        let server = Server::spawn(backend(3), cfg(2));
        let ctl = server.ctl.clone();
        let m = server.shutdown();
        assert_eq!(m.completed, 0);
        // a handle built against the dead thread still terminates
        let (tx, rx) = mpsc::channel();
        if ctl.send(Ctl::Submit(Box::new(Request::new(9, vec![3], 2)), tx.clone())).is_err() {
            let _ = tx.send(Event::Rejected { id: 9, error: SubmitError::Closed });
        }
        drop(tx);
        match rx.recv() {
            Ok(Event::Rejected { error: SubmitError::Closed, .. }) | Err(_) => {}
            other => panic!("expected closed-channel rejection, got {other:?}"),
        }
    }

    #[test]
    fn cancel_queued_request_streams_cancelled() {
        // max_batch 1: request 0 occupies the engine long enough that
        // the FIFO control channel guarantees request 1 is still queued
        // when its cancel lands
        let server = Server::spawn(backend(4), cfg(1));
        let long = server.submit(Request::new(0, vec![4; 6], 40));
        let doomed = server.submit(Request::new(1, vec![4; 6], 4));
        doomed.cancel();
        let r = doomed.wait().expect("cancelled stream still terminates");
        assert_eq!(r.finish, FinishReason::Cancelled);
        assert!(r.tokens.is_empty());
        let r = long.wait().unwrap();
        assert_eq!(r.finish, FinishReason::Length);
        assert_eq!(r.tokens.len(), 40);
        let m = server.shutdown();
        assert_eq!(m.cancelled_total, 1);
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn sink_map_drains_to_zero() {
        // N requests through every terminal path — natural finish,
        // cancel, and reject — must leave no sink behind: the map is
        // keyed per request and an entry that outlives its terminal
        // event is a leak that grows with server lifetime
        let server = Server::spawn(backend(6), cfg(4));
        let mut handles = Vec::new();
        for id in 0..8u64 {
            handles.push(server.submit(Request::new(id, vec![4; 6], 8)));
        }
        // a couple of mid-flight / queued cancels
        handles[2].cancel();
        handles[5].cancel();
        // one structurally rejected request (never gets a sink)
        let rejected = server.submit(Request::new(100, vec![], 4));
        assert!(rejected.wait().is_err());
        for h in handles {
            let _ = h.wait();
        }
        let m = server.shutdown();
        assert!(m.sinks_peak >= 1, "submissions must register sinks");
        assert_eq!(
            m.sinks_open_final, 0,
            "every terminal event must drop its sink (peak was {})",
            m.sinks_peak
        );
        assert_eq!(m.completed + m.cancelled_total, 8);
    }

    #[test]
    fn dropped_handle_auto_cancels() {
        let server = Server::spawn(backend(5), cfg(2));
        let h = server.submit(Request::new(0, vec![4; 6], 40));
        // read one token so the request is known to be mid-flight
        while !matches!(h.recv().expect("stream alive"), Event::Token { .. }) {}
        drop(h);
        let m = server.shutdown();
        assert_eq!(
            m.cancelled_total + m.completed,
            1,
            "dropped handle must cancel (or the request raced to completion)"
        );
    }
}
