//! Prompt-prefix cache over the paged KV pool.
//!
//! Serving traffic is dominated by shared preambles (system prompts,
//! few-shot scaffolding). Re-prefilling the same leading tokens through
//! the quantized forward path on every request wastes exactly the compute
//! GPTQT's cheap weights are supposed to save, so completed prefills are
//! published here: an entry pins the donor sequence's blocks covering its
//! prompt ([`PagedKvManager::pin_prefix`]) and keeps a trimmed snapshot of
//! the physical KV (an `Arc` the engine imports into a fresh cache on a
//! hit). Matching is content-based — a chained FNV-1a hash per full block
//! for cheap rejection, then direct token comparison which also extends
//! the match token-by-token into a partially-filled tail block. A hit
//! admits through [`PagedKvManager::admit_shared`], adopting the matched
//! blocks copy-on-write instead of re-prefilling them.
//!
//! Eviction is LRU by last hit. Under pool pressure the cache either
//! evicts to make room for an incoming request
//! ([`PrefixCacheConfig::evict_on_pressure`]) or lets admission refuse —
//! the entry a request is about to share from is always protected from
//! that pressure eviction, since unpinning it mid-admission could free
//! blocks the new table is adopting.
//!
//! The matched length is capped at `prompt.len() - 1`: at least one
//! prompt token must still flow through the forward pass so the engine
//! has logits to sample the first new token from.

use std::sync::Arc;

use super::kv_pool::{PagedKvManager, SeqId};
use super::metrics::Metrics;
use super::request::Request;

/// Prefix-cache policy, surfaced through `EngineConfig`.
#[derive(Clone, Debug)]
pub struct PrefixCacheConfig {
    /// Master switch; disabled by default so small-pool tests keep exact
    /// block accounting. The serve CLI and benches enable it.
    pub enabled: bool,
    /// Maximum cached prefixes; LRU-evicted beyond this.
    pub max_entries: usize,
    /// Maximum blocks the cache may pin (summed per entry; blocks shared
    /// between overlapping entries count once per entry).
    pub max_blocks: usize,
    /// Prompts shorter than this are not cached and not matched.
    pub min_tokens: usize,
    /// Under pool pressure, evict LRU entries to admit a request (true)
    /// or leave the cache intact and let admission refuse (false).
    pub evict_on_pressure: bool,
}

impl Default for PrefixCacheConfig {
    fn default() -> PrefixCacheConfig {
        PrefixCacheConfig {
            enabled: false,
            max_entries: 32,
            max_blocks: 128,
            min_tokens: 1,
            evict_on_pressure: true,
        }
    }
}

/// Outcome of a cache-aware admission attempt.
pub enum AdmitOutcome<K> {
    /// The pool cannot host the request's worst case right now.
    Rejected,
    /// Admitted with no cached prefix; full prefill required.
    Cold,
    /// Admitted sharing `matched` prompt tokens; the engine imports the
    /// snapshot and prefills only tokens `matched..`.
    Hit { matched: usize, kv: Arc<K> },
}

struct Entry<K> {
    id: u64,
    tokens: Vec<u32>,
    /// chained FNV-1a hash per full block of `tokens`
    block_hashes: Vec<u64>,
    /// pool blocks covering `tokens`, pinned for this entry's lifetime
    blocks: Vec<u32>,
    /// trimmed physical KV snapshot (first `tokens.len()` positions)
    kv: Arc<K>,
    last_hit: u64,
    hits: u64,
}

/// LRU prefix cache, generic over the backend's physical KV type.
pub struct PrefixCache<K> {
    cfg: PrefixCacheConfig,
    entries: Vec<Entry<K>>,
    clock: u64,
    next_id: u64,
}

/// Chained FNV-1a hash of each full `block_size` chunk of `tokens`;
/// hash `i` covers tokens `0..(i+1)*block_size`, so equal chains mean
/// equal leading blocks.
pub fn block_hash_chain(tokens: &[u32], block_size: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(tokens.len() / block_size);
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for chunk in tokens.chunks_exact(block_size) {
        for &t in chunk {
            for byte in t.to_le_bytes() {
                h = (h ^ byte as u64).wrapping_mul(0x0100_0000_01b3);
            }
        }
        out.push(h);
    }
    out
}

struct Candidate {
    entry_idx: usize,
    matched: usize,
}

impl<K> PrefixCache<K> {
    pub fn new(cfg: PrefixCacheConfig) -> PrefixCache<K> {
        PrefixCache { cfg, entries: Vec::new(), clock: 0, next_id: 0 }
    }

    pub fn config(&self) -> &PrefixCacheConfig {
        &self.cfg
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Blocks pinned across entries (an overlap-shared block counts once
    /// per entry that pins it, matching the pool's pin counts).
    pub fn pinned_blocks(&self) -> usize {
        self.entries.iter().map(|e| e.blocks.len()).sum()
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Longest cached match for `prompt`, capped at `prompt.len() - 1`.
    fn best_match(&self, prompt: &[u32], block_size: usize) -> Option<Candidate> {
        if prompt.len() < self.cfg.min_tokens.max(2) {
            // a 1-token prompt can never share (cap leaves nothing)
            return None;
        }
        let chain = block_hash_chain(prompt, block_size);
        let mut best: Option<Candidate> = None;
        for (idx, entry) in self.entries.iter().enumerate() {
            // cheap reject: count leading full-block hash agreements
            let full = entry
                .block_hashes
                .iter()
                .zip(&chain)
                .take_while(|(a, b)| a == b)
                .count();
            // verify against hash collisions, then extend token-by-token
            // into the next (partial) block
            let verified = entry
                .tokens
                .iter()
                .zip(prompt)
                .take(full * block_size)
                .take_while(|(a, b)| a == b)
                .count();
            let mut matched = verified;
            if verified == full * block_size {
                matched += entry.tokens[verified..]
                    .iter()
                    .zip(&prompt[verified..])
                    .take_while(|(a, b)| a == b)
                    .count();
            }
            matched = matched.min(prompt.len() - 1);
            if matched < self.cfg.min_tokens.max(1) {
                continue;
            }
            let better = match &best {
                None => true,
                Some(b) => {
                    matched > b.matched
                        || (matched == b.matched
                            && entry.last_hit > self.entries[b.entry_idx].last_hit)
                }
            };
            if better {
                best = Some(Candidate { entry_idx: idx, matched });
            }
        }
        best
    }

    /// Whether a completed prefill for `prompt` would be worth
    /// snapshotting — a cheap pre-check so the engine can skip the KV
    /// clone when the cache is off or an entry already covers the
    /// prompt.
    pub fn wants(&self, prompt: &[u32]) -> bool {
        self.cfg.enabled
            && !prompt.is_empty()
            && prompt.len() >= self.cfg.min_tokens
            && !self
                .entries
                .iter()
                .any(|e| e.tokens.len() >= prompt.len() && e.tokens[..prompt.len()] == *prompt)
    }

    /// Cache-aware admission: look up `req.prompt`, evict under pressure
    /// if the policy allows, and admit either sharing the matched blocks
    /// or cold. The caller is responsible for importing the returned KV
    /// snapshot before prefilling the remainder.
    pub fn try_admit(
        &mut self,
        req: &Request,
        kv: &mut PagedKvManager,
        metrics: &mut Metrics,
    ) -> AdmitOutcome<K> {
        if !self.cfg.enabled {
            return if kv.admit(req.id, req.prompt.len(), req.max_tokens()) {
                AdmitOutcome::Cold
            } else {
                AdmitOutcome::Rejected
            };
        }
        match self.best_match(&req.prompt, kv.block_size()) {
            None => {
                metrics.prefix_misses += 1;
                if self.cfg.evict_on_pressure {
                    while !kv.can_admit(req.max_tokens()) && self.evict_lru(kv, metrics, None) {}
                }
                if kv.admit(req.id, req.prompt.len(), req.max_tokens()) {
                    AdmitOutcome::Cold
                } else {
                    AdmitOutcome::Rejected
                }
            }
            Some(c) => {
                let entry_id = self.entries[c.entry_idx].id;
                if self.cfg.evict_on_pressure {
                    // never evict the entry we are about to share from:
                    // unpinning it could free the very blocks the new
                    // table is adopting
                    while !kv.can_admit_shared(req.max_tokens(), c.matched)
                        && self.evict_lru(kv, metrics, Some(entry_id))
                    {}
                }
                // the eviction loop cannot remove the protected entry, so
                // the index is still valid
                let entry = self
                    .entries
                    .iter_mut()
                    .find(|e| e.id == entry_id)
                    .expect("protected entry evicted");
                let covering = c.matched.div_ceil(kv.block_size());
                let shared = entry.blocks[..covering].to_vec();
                let snapshot = Arc::clone(&entry.kv);
                if kv.admit_shared(req.id, req.prompt.len(), req.max_tokens(), &shared, c.matched)
                {
                    metrics.prefix_hits += 1;
                    metrics.prefix_tokens_reused += c.matched as u64;
                    let now = self.tick();
                    let entry = self
                        .entries
                        .iter_mut()
                        .find(|e| e.id == entry_id)
                        .expect("protected entry evicted");
                    entry.last_hit = now;
                    entry.hits += 1;
                    AdmitOutcome::Hit { matched: c.matched, kv: snapshot }
                } else {
                    // a shared admit demands no more than a cold one, so
                    // there is no fallback to try — refuse (head-of-line)
                    AdmitOutcome::Rejected
                }
            }
        }
    }

    /// Publish a freshly completed prefill: pin the donor's blocks
    /// covering `prompt` and keep the trimmed KV snapshot. No-ops when
    /// disabled, when the prompt is too short, when an existing entry
    /// already covers it, or when pinning would outrun the pool.
    pub fn insert(
        &mut self,
        prompt: &[u32],
        donor: SeqId,
        kv: &mut PagedKvManager,
        snapshot: Arc<K>,
        metrics: &mut Metrics,
    ) {
        if !self.cfg.enabled || prompt.is_empty() || prompt.len() < self.cfg.min_tokens {
            return;
        }
        if let Some(existing) = self
            .entries
            .iter_mut()
            .find(|e| e.tokens.len() >= prompt.len() && e.tokens[..prompt.len()] == *prompt)
        {
            // already covered; refresh recency instead of duplicating pins
            existing.last_hit = self.clock + 1;
            self.clock += 1;
            return;
        }
        let covering = kv.blocks_covering(prompt.len());
        if covering > self.cfg.max_blocks {
            return;
        }
        while self.entries.len() >= self.cfg.max_entries
            || self.pinned_blocks() + covering > self.cfg.max_blocks
        {
            if !self.evict_lru(kv, metrics, None) {
                return;
            }
        }
        let Some(table) = kv.table(donor) else { return };
        if table.len() < covering {
            return;
        }
        let blocks = table[..covering].to_vec();
        let Some(donor_len) = kv.seq_tokens(donor) else { return };
        // the donor keeps decoding: if its next write lands inside the
        // pinned span it will copy-on-write, which needs one extra
        // allocation granted at pin time
        let grant = (donor_len / kv.block_size() < covering).then_some(donor);
        if !kv.pin_prefix(&blocks, grant) {
            return;
        }
        let now = self.tick();
        let id = self.next_id;
        self.next_id += 1;
        self.entries.push(Entry {
            id,
            tokens: prompt.to_vec(),
            block_hashes: block_hash_chain(prompt, kv.block_size()),
            blocks,
            kv: snapshot,
            last_hit: now,
            hits: 0,
        });
        metrics.prefix_insertions += 1;
        metrics.prefix_blocks_pinned = self.pinned_blocks() as u64;
    }

    /// Evict the least-recently-hit entry (skipping `protect`), unpinning
    /// its blocks. Returns false when nothing is evictable.
    pub fn evict_lru(
        &mut self,
        kv: &mut PagedKvManager,
        metrics: &mut Metrics,
        protect: Option<u64>,
    ) -> bool {
        let victim = self
            .entries
            .iter()
            .enumerate()
            .filter(|(_, e)| Some(e.id) != protect)
            .min_by_key(|(_, e)| e.last_hit)
            .map(|(i, _)| i);
        let Some(idx) = victim else { return false };
        let entry = self.entries.swap_remove(idx);
        kv.unpin_prefix(&entry.blocks);
        metrics.prefix_evictions += 1;
        metrics.prefix_blocks_pinned = self.pinned_blocks() as u64;
        true
    }

    /// Drop every entry, unpinning all blocks (tests and shutdown).
    pub fn clear(&mut self, kv: &mut PagedKvManager) {
        for entry in self.entries.drain(..) {
            kv.unpin_prefix(&entry.blocks);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;

    fn req(id: u64, prompt: Vec<u32>, max_new: usize) -> Request {
        Request::new(id, prompt, max_new)
    }

    #[test]
    fn hash_chain_is_per_full_block_and_prefix_stable() {
        let a: Vec<u32> = (0..10).collect();
        let b: Vec<u32> = (0..12).map(|t| if t < 10 { t } else { 99 }).collect();
        let ca = block_hash_chain(&a, 4);
        let cb = block_hash_chain(&b, 4);
        assert_eq!(ca.len(), 2); // 10 tokens → 2 full blocks of 4
        assert_eq!(cb.len(), 3);
        assert_eq!(ca, cb[..2]); // shared full blocks hash identically
        let c = block_hash_chain(&[0, 1, 2, 7, 4, 5, 6, 7], 4);
        assert_ne!(c[0], ca[0]); // a differing token changes the block hash
    }

    #[test]
    fn disabled_cache_admits_cold_and_never_matches() {
        let mut cache: PrefixCache<u8> = PrefixCache::new(PrefixCacheConfig::default());
        let mut kv = PagedKvManager::new(16, 4);
        let mut metrics = Metrics::new();
        let r = req(1, (0..8).collect(), 4);
        assert!(matches!(
            cache.try_admit(&r, &mut kv, &mut metrics),
            AdmitOutcome::Cold
        ));
        cache.insert(&r.prompt, 1, &mut kv, Arc::new(0u8), &mut metrics);
        assert!(cache.is_empty());
        assert_eq!(metrics.prefix_misses, 0);
        kv.release(1);
        assert_eq!(kv.free_blocks(), 16);
    }

    #[test]
    fn insert_then_hit_shares_blocks_and_counts_metrics() {
        let cfg = PrefixCacheConfig { enabled: true, ..PrefixCacheConfig::default() };
        let mut cache: PrefixCache<u8> = PrefixCache::new(cfg);
        let mut kv = PagedKvManager::new(32, 4);
        let mut metrics = Metrics::new();

        let prompt: Vec<u32> = (100..112).collect(); // 12 tokens → 3 blocks
        let r1 = req(1, prompt.clone(), 8);
        assert!(matches!(
            cache.try_admit(&r1, &mut kv, &mut metrics),
            AdmitOutcome::Cold
        ));
        assert_eq!(metrics.prefix_misses, 1);
        cache.insert(&prompt, 1, &mut kv, Arc::new(7u8), &mut metrics);
        assert_eq!(cache.len(), 1);
        assert_eq!(metrics.prefix_insertions, 1);
        assert_eq!(kv.pinned_blocks(), 3);
        kv.check_invariants().unwrap();

        // identical prompt: matches all but the last token
        let r2 = req(2, prompt.clone(), 8);
        match cache.try_admit(&r2, &mut kv, &mut metrics) {
            AdmitOutcome::Hit { matched, kv: snap } => {
                assert_eq!(matched, 11);
                assert_eq!(*snap, 7u8);
            }
            _ => panic!("expected hit"),
        }
        assert_eq!(metrics.prefix_hits, 1);
        assert_eq!(metrics.prefix_tokens_reused, 11);
        // the two full shared blocks are adopted by reference
        let t1 = kv.table(1).unwrap().to_vec();
        let t2 = kv.table(2).unwrap().to_vec();
        assert_eq!(&t1[..2], &t2[..2]);
        assert_ne!(t1[2], t2[2]); // partial tail copied at admission
        kv.check_invariants().unwrap();

        // a prompt diverging inside the second block matches 5 tokens
        let mut div = prompt.clone();
        div[5] = 999;
        let r3 = req(3, div, 8);
        match cache.try_admit(&r3, &mut kv, &mut metrics) {
            AdmitOutcome::Hit { matched, .. } => assert_eq!(matched, 5),
            _ => panic!("expected partial hit"),
        }
        kv.check_invariants().unwrap();

        kv.release(1);
        kv.release(2);
        kv.release(3);
        cache.clear(&mut kv);
        assert_eq!(kv.free_blocks(), 32);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn lru_eviction_under_entry_cap_and_pressure() {
        let cfg = PrefixCacheConfig {
            enabled: true,
            max_entries: 2,
            max_blocks: 64,
            ..PrefixCacheConfig::default()
        };
        let mut cache: PrefixCache<u8> = PrefixCache::new(cfg);
        let mut kv = PagedKvManager::new(64, 4);
        let mut metrics = Metrics::new();

        for (seq, base) in [(1u64, 0u32), (2, 1000), (3, 2000)] {
            let prompt: Vec<u32> = (base..base + 8).collect();
            let r = req(seq, prompt.clone(), 4);
            assert!(matches!(
                cache.try_admit(&r, &mut kv, &mut metrics),
                AdmitOutcome::Cold
            ));
            cache.insert(&prompt, seq, &mut kv, Arc::new(seq as u8), &mut metrics);
            kv.release(seq);
        }
        // third insert evicted the oldest entry
        assert_eq!(cache.len(), 2);
        assert_eq!(metrics.prefix_evictions, 1);
        // the first prefix no longer matches; the later ones do
        let miss = req(10, (0..8).collect(), 4);
        assert!(matches!(
            cache.try_admit(&miss, &mut kv, &mut metrics),
            AdmitOutcome::Cold
        ));
        kv.release(10);
        let hit = req(11, (2000..2008).collect(), 4);
        assert!(matches!(
            cache.try_admit(&hit, &mut kv, &mut metrics),
            AdmitOutcome::Hit { matched: 7, .. }
        ));
        kv.release(11);
        cache.clear(&mut kv);
        assert_eq!(kv.free_blocks(), 64);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn pressure_eviction_frees_pinned_blocks_for_admission() {
        // pool of 8 blocks; a cached 16-token prefix pins 4 of them
        let cfg = PrefixCacheConfig { enabled: true, ..PrefixCacheConfig::default() };
        let mut cache: PrefixCache<u8> = PrefixCache::new(cfg);
        let mut kv = PagedKvManager::new(8, 4);
        let mut metrics = Metrics::new();
        let prompt: Vec<u32> = (0..16).collect();
        let r1 = req(1, prompt.clone(), 0);
        assert!(matches!(
            cache.try_admit(&r1, &mut kv, &mut metrics),
            AdmitOutcome::Cold
        ));
        cache.insert(&prompt, 1, &mut kv, Arc::new(0u8), &mut metrics);
        kv.release(1);
        assert_eq!(kv.free_blocks(), 4);

        // an unrelated 24-token request needs 6 blocks → pressure-evict
        let r2 = req(2, (500..524).collect(), 0);
        assert!(matches!(
            cache.try_admit(&r2, &mut kv, &mut metrics),
            AdmitOutcome::Cold
        ));
        assert_eq!(metrics.prefix_evictions, 1);
        assert!(cache.is_empty());
        kv.check_invariants().unwrap();
        kv.release(2);
        assert_eq!(kv.free_blocks(), 8);
    }

    #[test]
    fn refuse_policy_keeps_cache_and_rejects() {
        let cfg = PrefixCacheConfig {
            enabled: true,
            evict_on_pressure: false,
            ..PrefixCacheConfig::default()
        };
        let mut cache: PrefixCache<u8> = PrefixCache::new(cfg);
        let mut kv = PagedKvManager::new(8, 4);
        let mut metrics = Metrics::new();
        let prompt: Vec<u32> = (0..16).collect();
        let r1 = req(1, prompt.clone(), 0);
        assert!(matches!(
            cache.try_admit(&r1, &mut kv, &mut metrics),
            AdmitOutcome::Cold
        ));
        cache.insert(&prompt, 1, &mut kv, Arc::new(0u8), &mut metrics);
        kv.release(1);
        let r2 = req(2, (500..524).collect(), 0);
        assert!(matches!(
            cache.try_admit(&r2, &mut kv, &mut metrics),
            AdmitOutcome::Rejected
        ));
        assert_eq!(cache.len(), 1);
        assert_eq!(metrics.prefix_evictions, 0);
        cache.clear(&mut kv);
        assert_eq!(kv.free_blocks(), 8);
    }
}
