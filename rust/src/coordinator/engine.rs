//! The serving engine: scheduling loop over admitted sequences, driving
//! either the CPU decode backends (quantized or dense) or the PJRT
//! executables, with paged-KV admission and full metrics.

use super::batcher::{Batcher, BatcherConfig};
use super::kv_pool::PagedKvManager;
use super::metrics::Metrics;
use super::queue::{RequestQueue, SubmitError};
use super::request::{FinishReason, Request, Response};
use super::sampler::Sampler;
use super::EngineConfig;
use crate::model::{BackendModel, KvCache};
use crate::runtime::{CompiledModel, DeviceKv};
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

/// What executes the model math.
pub enum EngineBackend {
    /// Pure-rust decode path (dense / gptq-dequant / gptqt-lut kernels).
    Cpu(BackendModel),
    /// AOT-compiled XLA executables on the PJRT CPU device.
    Pjrt(CompiledModel),
}

enum SeqCache {
    Cpu(KvCache),
    Pjrt(DeviceKv),
}

impl EngineBackend {
    fn capacity(&self) -> usize {
        match self {
            EngineBackend::Cpu(m) => m.cfg.max_seq,
            EngineBackend::Pjrt(m) => m.meta.kv_len,
        }
    }

    fn new_cache(&self) -> Result<SeqCache> {
        Ok(match self {
            EngineBackend::Cpu(m) => SeqCache::Cpu(KvCache::new(&m.cfg)),
            EngineBackend::Pjrt(m) => SeqCache::Pjrt(m.new_kv()?),
        })
    }

    /// Human label (which Table-IV row this engine realizes).
    pub fn label(&self) -> &'static str {
        match self {
            EngineBackend::Cpu(m) => m.backend_label(),
            EngineBackend::Pjrt(_) => "pjrt",
        }
    }
}

struct Running {
    req: Request,
    sampler: Sampler,
    cache: SeqCache,
    /// next prompt index to feed (== prompt.len() once prefilled)
    prompt_idx: usize,
    generated: Vec<u32>,
    prefill_started: Option<Instant>,
}

impl Running {
    fn prefilling(&self) -> bool {
        self.prompt_idx < self.req.prompt.len()
    }
}

/// The engine. Single-threaded scheduling loop (`step`) over a
/// thread-safe submission queue — a worker thread can own the engine
/// while any number of producers submit.
pub struct Engine {
    backend: EngineBackend,
    pub cfg: EngineConfig,
    batcher: Batcher,
    pub queue: Arc<RequestQueue>,
    running: Vec<Running>,
    kv: PagedKvManager,
    pub metrics: Metrics,
}

impl Engine {
    pub fn new(backend: EngineBackend, cfg: EngineConfig) -> Engine {
        let queue = Arc::new(RequestQueue::new(cfg.max_queue));
        let kv = PagedKvManager::new(cfg.total_blocks, cfg.block_size);
        // prefill pacing lives in the batcher config — the scheduling
        // policy's single runtime source of truth
        let batcher = Batcher::new(BatcherConfig {
            max_batch: cfg.max_batch,
            prefill_token_budget: cfg.block_size * cfg.max_batch * 4,
            prefill_chunk: cfg.prefill_chunk,
        });
        Engine {
            backend,
            cfg,
            batcher,
            queue,
            running: Vec::new(),
            kv,
            metrics: Metrics::new(),
        }
    }

    /// Validate + enqueue a request.
    pub fn submit(&mut self, req: Request) -> Result<(), SubmitError> {
        if req.prompt.is_empty() || req.max_tokens() > self.backend.capacity() {
            self.metrics.rejected += 1;
            return Err(SubmitError::Full); // semantic: cannot ever be served
        }
        self.queue.push(req)
    }

    pub fn has_work(&self) -> bool {
        !self.running.is_empty() || !self.queue.is_empty()
    }

    /// One scheduling tick: admit, then advance **every** running
    /// sequence through a single chunk-major forward — prefilling
    /// sequences contribute their next prompt chunk, decoding sequences
    /// their last sampled token, and all of it shares one weight stream
    /// per linear per tick (CPU backend). Finished sequences retire.
    /// Per-sequence sampling and finish logic are untouched, and the
    /// core is per-token bit-identical to the sequential loop, so
    /// generations are token-identical to per-sequence serving.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        // ---- admission -------------------------------------------------
        for req in self.batcher.admit(&self.queue, self.running.len(), &mut self.kv) {
            self.metrics.record_queue(req.arrived.elapsed());
            let cache = self.backend.new_cache()?;
            self.running.push(Running {
                sampler: Sampler::new(req.sampling),
                cache,
                prompt_idx: 0,
                generated: Vec::new(),
                prefill_started: Some(Instant::now()),
                req,
            });
        }

        // ---- one unified chunked forward over the running set ----------
        let chunk_len = self.batcher.cfg.prefill_chunk.max(1);
        match &self.backend {
            // the batched hot path: prefill chunks and decode tokens
            // flatten into one gemm per linear — the weights stream once
            // for the whole tick
            EngineBackend::Cpu(m) => {
                if !self.running.is_empty() {
                    let t0 = Instant::now();
                    let chunks: Vec<Vec<u32>> = self
                        .running
                        .iter()
                        .map(|run| {
                            if run.prefilling() {
                                let end = (run.prompt_idx + chunk_len)
                                    .min(run.req.prompt.len());
                                run.req.prompt[run.prompt_idx..end].to_vec()
                            } else {
                                vec![*run
                                    .generated
                                    .last()
                                    .expect("decoding sequence has a sampled token")]
                            }
                        })
                        .collect();
                    // logits are needed only where something will sample:
                    // decoding sequences and prompts completing this tick
                    let need: Vec<bool> = self
                        .running
                        .iter()
                        .zip(&chunks)
                        .map(|(run, chunk)| {
                            run.prompt_idx + chunk.len() >= run.req.prompt.len()
                        })
                        .collect();
                    let chunk_refs: Vec<&[u32]> =
                        chunks.iter().map(|c| c.as_slice()).collect();
                    let mut caches: Vec<&mut KvCache> = self
                        .running
                        .iter_mut()
                        .map(|r| match &mut r.cache {
                            SeqCache::Cpu(k) => k,
                            SeqCache::Pjrt(_) => unreachable!("cache/backend mismatch"),
                        })
                        .collect();
                    let all_logits =
                        m.forward_chunks_masked(&chunk_refs, &mut caches, &need);
                    // sample: sequences that just completed their prompt
                    // emit their first token, decoding ones their next —
                    // mid-prompt sequences only advanced their KV cache
                    let seqs = chunks.len();
                    let mut emitted = 0usize;
                    for ((run, chunk), logits) in
                        self.running.iter_mut().zip(&chunks).zip(&all_logits)
                    {
                        if run.prefilling() {
                            run.prompt_idx += chunk.len();
                            if !run.prefilling() {
                                let logits =
                                    logits.as_ref().expect("completing chunk has logits");
                                let tok = run.sampler.sample(logits);
                                run.generated.push(tok);
                                self.kv.append_token(run.req.id);
                                self.metrics.record_ttft(run.req.arrived.elapsed());
                                emitted += 1;
                            }
                        } else {
                            let logits =
                                logits.as_ref().expect("decoding chunk has logits");
                            let tok = run.sampler.sample(logits);
                            run.generated.push(tok);
                            self.kv.append_token(run.req.id);
                            emitted += 1;
                        }
                    }
                    self.metrics.record_batch_step(t0.elapsed(), seqs, emitted);
                }
            }
            // PJRT has no batched (or multi-token) executable ABI yet
            // (ROADMAP): per-sequence single-token stepping, with
            // sample/push immediately after each step so a mid-batch
            // error leaves completed sequences consistent
            EngineBackend::Pjrt(m) => {
                for run in self.running.iter_mut() {
                    let t0 = Instant::now();
                    if run.prefilling() {
                        let end = (run.prompt_idx + chunk_len).min(run.req.prompt.len());
                        let mut logits = Vec::new();
                        for i in run.prompt_idx..end {
                            let tok = run.req.prompt[i];
                            logits = match &mut run.cache {
                                SeqCache::Pjrt(k) => m.decode(k, tok)?,
                                SeqCache::Cpu(_) => unreachable!("cache/backend mismatch"),
                            };
                        }
                        run.prompt_idx = end;
                        if !run.prefilling() {
                            let tok = run.sampler.sample(&logits);
                            run.generated.push(tok);
                            self.kv.append_token(run.req.id);
                            self.metrics.record_ttft(run.req.arrived.elapsed());
                            // occupancy 1: no weight-streaming amortization
                            self.metrics.record_batch_step(t0.elapsed(), 1, 1);
                        }
                    } else {
                        let last =
                            *run.generated.last().expect("at least one generated token");
                        let logits = match &mut run.cache {
                            SeqCache::Pjrt(k) => m.decode(k, last)?,
                            SeqCache::Cpu(_) => unreachable!("cache/backend mismatch"),
                        };
                        let tok = run.sampler.sample(&logits);
                        run.generated.push(tok);
                        self.kv.append_token(run.req.id);
                        self.metrics.record_batch_step(t0.elapsed(), 1, 1);
                    }
                }
            }
        }

        // ---- finish checks ---------------------------------------------
        let mut finished: Vec<usize> = Vec::new();
        for (idx, run) in self.running.iter().enumerate() {
            if run.prompt_idx == run.req.prompt.len() {
                let hit_eos = run.generated.last() == Some(&self.cfg.eos_token);
                let hit_len = run.generated.len() >= run.req.max_new_tokens;
                if hit_eos || hit_len {
                    finished.push(idx);
                }
            }
        }

        // ---- retire ----------------------------------------------------
        let mut responses = Vec::new();
        for idx in finished.into_iter().rev() {
            let run = self.running.swap_remove(idx);
            self.kv.release(run.req.id);
            let e2e = run.req.arrived.elapsed();
            self.metrics.record_done(e2e, run.req.prompt.len());
            let finish = if run.generated.last() == Some(&self.cfg.eos_token) {
                FinishReason::Eos
            } else {
                FinishReason::Length
            };
            responses.push(Response {
                id: run.req.id,
                tokens: run.generated,
                finish,
                queue_secs: run
                    .prefill_started
                    .map(|t| t.duration_since(run.req.arrived).as_secs_f64())
                    .unwrap_or(0.0),
                ttft_secs: 0.0, // per-request ttft folded into metrics
                e2e_secs: e2e.as_secs_f64(),
            });
        }
        Ok(responses)
    }

    /// Drain everything currently queued/running (offline batch mode).
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.has_work() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    /// KV-pool consistency (exposed for tests and debug assertions).
    pub fn check_invariants(&self) -> Result<(), String> {
        self.kv.check_invariants()
    }

    pub fn backend(&self) -> &EngineBackend {
        &self.backend
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplingParams;
    use crate::model::init::random_weights;
    use crate::model::{presets, Model};

    fn cpu_engine(max_batch: usize) -> Engine {
        let mut cfg = presets::by_name("opt-nano").unwrap();
        cfg.vocab = 64;
        cfg.max_seq = 48;
        let model = Model::new(cfg.clone(), random_weights(&cfg, 42));
        let backend = EngineBackend::Cpu(BackendModel::dense(&model));
        Engine::new(
            backend,
            EngineConfig { max_batch, total_blocks: 64, block_size: 8, ..Default::default() },
        )
    }

    fn req(id: u64, prompt_len: usize, gen: usize) -> Request {
        Request::new(id, (0..prompt_len as u32).map(|i| 3 + i % 60).collect(), gen)
    }

    #[test]
    fn serves_single_request() {
        let mut e = cpu_engine(4);
        e.submit(req(1, 5, 6)).unwrap();
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 1);
        assert!(out[0].tokens.len() <= 6 && !out[0].tokens.is_empty());
        assert!(e.check_invariants().is_ok());
        assert_eq!(e.metrics.completed, 1);
    }

    #[test]
    fn serves_many_requests_batched() {
        let mut e = cpu_engine(3);
        for id in 0..9 {
            e.submit(req(id, 4 + (id as usize % 5), 5)).unwrap();
        }
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 9);
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..9).collect::<Vec<_>>());
        assert!(e.check_invariants().is_ok());
        assert_eq!(e.metrics.completed, 9);
        assert!(e.metrics.generated_tokens > 0);
        // with 9 requests and max_batch 3, decode ticks run >1 sequence
        assert!(
            e.metrics.max_batch_occupancy >= 2,
            "batched decode never ran: max occupancy {}",
            e.metrics.max_batch_occupancy
        );
        assert!(e.metrics.mean_batch_occupancy() > 1.0);
    }

    #[test]
    fn greedy_generation_is_deterministic() {
        let run = || {
            let mut e = cpu_engine(2);
            e.submit(req(1, 6, 8)).unwrap();
            e.run_to_completion().unwrap().remove(0).tokens
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn sampled_generation_is_seed_deterministic() {
        let run = |seed| {
            let mut e = cpu_engine(2);
            e.submit(req(1, 6, 8).with_sampling(SamplingParams::TopK {
                k: 8,
                temperature: 1.0,
                seed,
            }))
            .unwrap();
            e.run_to_completion().unwrap().remove(0).tokens
        };
        assert_eq!(run(7), run(7));
    }

    #[test]
    fn rejects_oversized_requests() {
        let mut e = cpu_engine(2);
        // capacity is 48 tokens; this wants 100
        assert!(e.submit(req(1, 50, 50)).is_err());
        assert_eq!(e.metrics.rejected, 1);
        assert!(e.submit(Request::new(2, vec![], 5)).is_err());
    }

    #[test]
    fn kv_pressure_defers_but_completes_all() {
        let mut e = cpu_engine(8);
        // tiny pool: only ~2 requests' worst case fit at once
        e.kv = PagedKvManager::new(6, 8);
        for id in 0..6 {
            e.submit(req(id, 8, 8)).unwrap();
        }
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 6);
        assert!(e.check_invariants().is_ok());
    }

    #[test]
    fn long_prompts_prefill_in_chunks() {
        let mut e = cpu_engine(2);
        e.batcher.cfg.prefill_chunk = 4;
        e.submit(req(1, 20, 3)).unwrap();
        let mut steps = 0;
        let mut responses = Vec::new();
        while e.has_work() {
            responses.extend(e.step().unwrap());
            steps += 1;
            assert!(steps < 100, "engine stuck");
        }
        // 20 prompt tokens / 4 per tick = 5 prefill ticks + ≥2 decode
        assert!(steps >= 7, "only {steps} steps");
        assert_eq!(responses.len(), 1);
    }
}
