//! The serving engine: the per-tick scheduling loop over admitted
//! sequences, generic over a pluggable [`Backend`], emitting per-token
//! [`Event`]s with paged-KV admission, cancellation, deadlines, and
//! full metrics.
//!
//! The engine is single-threaded by design — [`Engine::step`] is one
//! scheduling tick — and [`super::server::Server`] owns it on a
//! dedicated thread behind the streaming session API. Offline callers
//! can still drive it directly ([`Engine::run_to_completion`]).

use super::batcher::{Batcher, BatcherConfig};
use super::error::{EngineError, FailReason};
use super::kv_pool::PagedKvManager;
use super::metrics::Metrics;
use super::policy::{SchedulePolicy, TickState};
use super::prefix_cache::{AdmitOutcome, PrefixCache};
use super::queue::{RequestQueue, SubmitError};
use super::request::{FinishReason, Request, Response, SamplingParams};
use super::sampler::Sampler;
use super::server::Event;
use super::speculative::{SpecConfig, SpecOutcome};
use super::EngineConfig;
use crate::kernels::NumericsMode;
use crate::model::{BackendModel, ForwardScratch, KvCache};
use crate::runtime::{CompiledModel, DeviceKv};
use crate::util::fault;
use anyhow::Result;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Instant;

use crate::util::time::now;

/// What executes the model math. The engine body never matches on a
/// concrete implementation: new backends (NEON tier builds, sharded
/// CPU, a real batched PJRT ABI) plug in by implementing this trait —
/// `engine.rs` does not change.
pub trait Backend {
    /// Per-sequence attention-cache type this backend owns (`'static`
    /// so the engine can recycle its borrow buffers across ticks and
    /// the prefix cache can hold snapshots for arbitrary lifetimes).
    type Kv: 'static;

    /// Reusable forward workspace, owned by the engine and threaded
    /// through every [`Backend::forward_tick`] — the CPU path persists
    /// its activation buffers here so steady-state ticks allocate
    /// nothing ([`crate::model::ForwardScratch`]). Backends without
    /// buffer reuse use `()`. Contents never carry information between
    /// ticks: reuse is an allocation optimization, not state.
    type Scratch: Default + Send;

    /// Max tokens (prompt + generated) one sequence may occupy.
    fn capacity(&self) -> usize;

    /// Fresh per-sequence cache for a newly admitted request.
    fn new_cache(&self) -> Result<Self::Kv>;

    /// Advance every running sequence by its token chunk in one tick:
    /// `chunks[b]` is consumed against `caches[b]`, and the next-token
    /// logits are returned for exactly the sequences with
    /// `need[b] == true` (mid-prompt chunks pass `false` — nothing
    /// samples them). Per token the math must be identical to feeding
    /// the same tokens one at a time, so chunking and batching can
    /// never change a served token.
    fn forward_tick(
        &self,
        chunks: &[&[u32]],
        caches: &mut [&mut Self::Kv],
        need: &[bool],
        scratch: &mut Self::Scratch,
    ) -> Result<Vec<Option<Vec<f32>>>>;

    /// Whether `forward_tick` amortizes one weight stream across the
    /// whole batch. Per-sequence fallbacks return `false` so the
    /// batch-occupancy metrics never claim amortization that did not
    /// happen.
    fn batch_amortized(&self) -> bool {
        true
    }

    /// Trimmed, standalone copy of the first `tokens` positions of a
    /// cache — what the prefix cache retains after a prefill completes.
    /// Backends that cannot export their KV (no readback path) keep the
    /// default `None`, which disables prefix caching for them without
    /// touching the engine.
    fn snapshot_kv_prefix(&self, _cache: &Self::Kv, _tokens: usize) -> Option<Self::Kv> {
        None
    }

    /// Import `tokens` positions from a snapshot into a freshly created
    /// cache (prefix-cache hit). Must be bitwise — a hit stream has to
    /// match a cold stream exactly. Returning `false` (the default)
    /// makes the engine fall back to prefilling the whole prompt.
    fn import_kv_prefix(&self, _dst: &mut Self::Kv, _src: &Self::Kv, _tokens: usize) -> bool {
        false
    }

    /// Apply the engine's configured numerics tier
    /// ([`EngineConfig::numerics`]) before serving starts — the engine
    /// calls this once at construction, making the config the single
    /// source of truth. Backends without a `Fast` tier ignore it.
    fn set_numerics(&mut self, _mode: NumericsMode) {}

    /// Whether this backend implements the speculative draft/verify
    /// protocol ([`Backend::spec_tick`]). When `true`, the engine
    /// routes greedy decoding sequences through `spec_tick` instead of
    /// the one-token-per-tick [`Backend::forward_tick`] path.
    fn speculates(&self) -> bool {
        false
    }

    /// Apply the engine's speculative config ([`EngineConfig::spec`])
    /// before serving starts — called once at construction, exactly
    /// like [`Backend::set_numerics`]. Non-speculating backends ignore
    /// it.
    fn set_spec(&mut self, _cfg: &SpecConfig) {}

    /// One speculative round for a batch of greedy decoding sequences:
    /// draft candidate tokens with the cheap model, verify them all in
    /// one chunk-major target forward, truncate both caches past the
    /// accept point, and return each sequence's emitted tokens.
    /// `last[b]` is sequence `b`'s newest sampled (not yet fed) token,
    /// `budgets[b]` its remaining generation budget (≥ 1); every
    /// outcome must emit between 1 and `budgets[b]` tokens and leave
    /// the cache exactly as if those tokens had been served one normal
    /// tick at a time. Backends that don't speculate keep the default
    /// `None`.
    fn spec_tick(
        &self,
        _last: &[u32],
        _caches: &mut [&mut Self::Kv],
        _budgets: &[usize],
        _scratch: &mut Self::Scratch,
    ) -> Option<Result<Vec<SpecOutcome>>> {
        None
    }

    /// Human label (which Table-IV row this backend realizes).
    fn label(&self) -> &'static str;
}

/// Pure-rust decode path (dense / gptq-dequant / gptqt-lut kernels).
/// One [`BackendModel::forward_chunks_masked`] call advances the whole
/// tick — every linear streams its weights once per tick.
pub struct CpuBackend(pub BackendModel);

impl Backend for CpuBackend {
    type Kv = KvCache;
    type Scratch = ForwardScratch;

    fn capacity(&self) -> usize {
        self.0.cfg.max_seq
    }

    fn new_cache(&self) -> Result<KvCache> {
        Ok(KvCache::new(&self.0.cfg))
    }

    fn forward_tick(
        &self,
        chunks: &[&[u32]],
        caches: &mut [&mut KvCache],
        need: &[bool],
        scratch: &mut ForwardScratch,
    ) -> Result<Vec<Option<Vec<f32>>>> {
        Ok(self.0.forward_chunks_masked_with(chunks, caches, need, scratch))
    }

    fn snapshot_kv_prefix(&self, cache: &KvCache, tokens: usize) -> Option<KvCache> {
        Some(cache.prefix_clone(tokens))
    }

    fn import_kv_prefix(&self, dst: &mut KvCache, src: &KvCache, tokens: usize) -> bool {
        if dst.len != 0 || tokens > src.len || tokens > dst.remaining() {
            return false;
        }
        dst.copy_prefix_from(src, tokens);
        true
    }

    fn set_numerics(&mut self, mode: NumericsMode) {
        self.0.set_numerics(mode);
    }

    fn label(&self) -> &'static str {
        self.0.backend_label()
    }
}

/// AOT-compiled XLA executables on the PJRT CPU device. There is no
/// batched (or multi-token) executable ABI yet (ROADMAP), so a tick
/// feeds each sequence's chunk token-by-token — correct, just without
/// the weight-stream amortization the CPU path gets.
pub struct PjrtBackend(pub CompiledModel);

impl Backend for PjrtBackend {
    type Kv = DeviceKv;
    /// The per-token fallback keeps no host-side activation buffers.
    type Scratch = ();

    fn capacity(&self) -> usize {
        self.0.kv_capacity()
    }

    fn new_cache(&self) -> Result<DeviceKv> {
        self.0.new_kv()
    }

    fn forward_tick(
        &self,
        chunks: &[&[u32]],
        caches: &mut [&mut DeviceKv],
        need: &[bool],
        _scratch: &mut (),
    ) -> Result<Vec<Option<Vec<f32>>>> {
        // lint:allow(hot-path-no-alloc) reference per-token backend — the
        // production chunk-major backend reuses ForwardScratch instead.
        let mut out = Vec::with_capacity(chunks.len());
        for ((chunk, cache), &wanted) in chunks.iter().zip(caches.iter_mut()).zip(need) {
            // lint:allow(hot-path-no-alloc) reference backend, see above.
            let mut logits = Vec::new();
            for &tok in chunk.iter() {
                logits = self.0.decode(&mut **cache, tok)?;
            }
            out.push(if wanted { Some(logits) } else { None });
        }
        Ok(out)
    }

    fn batch_amortized(&self) -> bool {
        false // per-sequence per-token loop: nothing is shared
    }

    fn label(&self) -> &'static str {
        "pjrt"
    }
}

struct Running<K> {
    req: Request,
    sampler: Sampler,
    cache: K,
    /// next prompt index to feed (== prompt.len() once prefilled); a
    /// prefix-cache hit starts at its matched length instead of 0
    prompt_idx: usize,
    generated: Vec<u32>,
    admitted_at: Instant,
    first_token_at: Option<Instant>,
    /// admitted via a prefix-cache hit (splits the TTFT histograms)
    prefix_hit: bool,
}

impl<K> Running<K> {
    fn prefilling(&self) -> bool {
        self.prompt_idx < self.req.prompt.len()
    }
}

/// The engine. Single-threaded scheduling loop (`step`) over a
/// thread-safe submission queue — a worker thread can own the engine
/// while any number of producers submit.
pub struct Engine<B: Backend> {
    backend: B,
    pub cfg: EngineConfig,
    batcher: Batcher,
    policy: Box<dyn SchedulePolicy>,
    pub queue: Arc<RequestQueue>,
    running: Vec<Running<B::Kv>>,
    kv: PagedKvManager,
    /// Content-addressed prompt-prefix cache; admission consults it so a
    /// hit adopts cached blocks instead of re-prefilling.
    prefix: PrefixCache<B::Kv>,
    pub metrics: Metrics,
    /// Events produced outside `step` (cancellations), drained by the
    /// next `step` so every event still flows through one stream.
    pending: Vec<Event>,
    /// Persistent forward workspace threaded through every
    /// [`Backend::forward_tick`] — steady-state ticks reuse its buffers
    /// instead of reallocating activations per layer per row.
    scratch: B::Scratch,
    /// Per-tick buffers, persisted so steady-state ticks allocate
    /// nothing: token chunks, the needs-logits mask, and the borrow
    /// vectors handed to [`Backend::forward_tick`]. The borrow vectors
    /// are stored with a `'static` element type while empty and
    /// re-borrowed per tick (see `take_slice_buf` / `take_mut_buf`).
    tick_chunks: Vec<Vec<u32>>,
    tick_need: Vec<bool>,
    tick_chunk_refs: Vec<&'static [u32]>,
    tick_caches: Vec<&'static mut B::Kv>,
    /// Per-tick partition of `running` (indices, ascending): greedy
    /// decoding sequences routed through [`Backend::spec_tick`] vs
    /// everything else (prefilling, non-greedy, or a non-speculating
    /// backend — then `tick_spec_idx` stays empty).
    tick_spec_idx: Vec<usize>,
    tick_normal_idx: Vec<usize>,
    /// Speculative-round inputs, persisted like the chunk buffers.
    tick_last: Vec<u32>,
    tick_budgets: Vec<usize>,
    /// Requests marked for per-request failure during the current tick
    /// (id, reason), retired after the forward/spec loops release their
    /// borrows. Persistent so the steady-state tick allocates nothing.
    tick_failed: Vec<(u64, FailReason)>,
    /// Latched when a panic unwound out of the backend and was
    /// contained: the engine keeps serving, but degraded (no
    /// speculation, no prefix insertion).
    panicked: bool,
}

impl<B: Backend> Engine<B> {
    pub fn new(backend: B, cfg: EngineConfig) -> Engine<B> {
        let policy = cfg.policy.build(cfg.prefill_chunk);
        Engine::with_policy(backend, cfg, policy)
    }

    /// Construct with a custom [`SchedulePolicy`] (anything beyond the
    /// [`super::SchedulePolicyKind`] presets).
    pub fn with_policy(
        mut backend: B,
        cfg: EngineConfig,
        policy: Box<dyn SchedulePolicy>,
    ) -> Engine<B> {
        backend.set_numerics(cfg.numerics);
        backend.set_spec(&cfg.spec);
        let queue = Arc::new(RequestQueue::new(cfg.max_queue));
        let kv = PagedKvManager::new(cfg.total_blocks, cfg.block_size);
        let batcher = Batcher::new(BatcherConfig {
            max_batch: cfg.max_batch,
            prefill_token_budget: cfg.block_size * cfg.max_batch * 4,
        });
        let prefix = PrefixCache::new(cfg.prefix.clone());
        let mut metrics = Metrics::new();
        metrics.numerics_label = cfg.numerics.label();
        Engine {
            backend,
            cfg,
            batcher,
            policy,
            queue,
            running: Vec::new(),
            kv,
            prefix,
            metrics,
            pending: Vec::new(),
            scratch: B::Scratch::default(),
            tick_chunks: Vec::new(),
            tick_need: Vec::new(),
            tick_chunk_refs: Vec::new(),
            tick_caches: Vec::new(),
            tick_spec_idx: Vec::new(),
            tick_normal_idx: Vec::new(),
            tick_last: Vec::new(),
            tick_budgets: Vec::new(),
            tick_failed: Vec::new(),
            panicked: false,
        }
    }

    /// Validate + enqueue a request.
    pub fn submit(&mut self, req: Request) -> Result<(), SubmitError> {
        if req.prompt.is_empty() || req.max_tokens() > self.backend.capacity() {
            self.metrics.rejected += 1;
            return Err(SubmitError::Full); // semantic: cannot ever be served
        }
        // an id is reusable only once its terminal event has drained —
        // a pending Finished (cancel/expiry) still owns the id, else a
        // cancel-then-resubmit race would cross-route the two streams
        if self.running.iter().any(|r| r.req.id == req.id)
            || self.pending.iter().any(|ev| ev.id() == req.id)
        {
            self.metrics.rejected += 1;
            return Err(SubmitError::DuplicateId);
        }
        let r = self.queue.push(req);
        if let Err(e) = &r {
            self.metrics.rejected += 1;
            if matches!(e, SubmitError::Full) {
                // queue-depth admission control shed this submission
                self.metrics.shed_total += 1;
            }
        }
        r
    }

    /// Suggested client back-off after a queue-full rejection, in
    /// seconds: the time to drain the current backlog one admission
    /// wave (`max_batch` requests, one mean end-to-end latency each) at
    /// a time. Falls back to a small constant before any request has
    /// completed.
    pub fn retry_after_hint(&self) -> f64 {
        let waves = (self.queue.len() / self.cfg.max_batch.max(1)) as f64 + 1.0;
        let wave_secs = if self.metrics.e2e.count() > 0 {
            self.metrics.e2e.mean().as_secs_f64()
        } else {
            0.05
        };
        waves * wave_secs
    }

    /// Whether the engine is currently serving degraded: a contained
    /// backend panic latched it, or pool pressure crossed
    /// [`EngineConfig::pressure_threshold`]. Degraded ticks disable
    /// speculation and prefix-cache insertion — neither changes any
    /// request's tokens — and count into [`Metrics::degraded_ticks`].
    pub fn is_degraded(&self) -> bool {
        self.panicked || self.under_pressure()
    }

    fn under_pressure(&self) -> bool {
        let thr = self.cfg.pressure_threshold;
        if thr <= 0.0 {
            return false;
        }
        let free = self.kv.free_blocks();
        let total = free + self.kv.used_blocks();
        (free as f64) < thr * total as f64
    }

    /// Terminate one request with a contained failure: release its KV
    /// blocks, emit the terminal `Failed(reason)` response, count it.
    /// No-op for ids the engine no longer runs (already retired).
    fn fail_by_id(&mut self, id: u64, reason: FailReason, events: &mut Vec<Event>) {
        if let Some(idx) = self.running.iter().position(|r| r.req.id == id) {
            self.metrics.requests_failed += 1;
            let resp = self.retire(idx, FinishReason::Failed(reason));
            events.push(Event::Finished(resp));
        }
    }

    /// Fail every request the engine currently knows (queued and
    /// running) with `Failed(reason)`, returning their terminal events
    /// plus anything already pending. The server's drain-deadline path
    /// uses this with [`FailReason::Shutdown`] so no handle ever hangs.
    pub fn abort_all(&mut self, reason: FailReason) -> Vec<Event> {
        let mut events = std::mem::take(&mut self.pending);
        while let Some(req) = self.queue.try_pop() {
            self.metrics.requests_failed += 1;
            let waited = req.arrived.elapsed().as_secs_f64();
            events.push(Event::Finished(Response {
                id: req.id,
                tokens: Vec::new(),
                finish: FinishReason::Failed(reason),
                queue_secs: waited,
                ttft_secs: 0.0,
                e2e_secs: waited,
            }));
        }
        while !self.running.is_empty() {
            self.metrics.requests_failed += 1;
            let resp = self.retire(0, FinishReason::Failed(reason));
            events.push(Event::Finished(resp));
        }
        events
    }

    pub fn has_work(&self) -> bool {
        !self.running.is_empty() || !self.queue.is_empty() || !self.pending.is_empty()
    }

    /// Cancel a request by id, queued or mid-flight. A running
    /// sequence's paged-KV blocks are returned to the pool immediately;
    /// the terminal [`Event::Finished`] (reason
    /// [`FinishReason::Cancelled`], tokens streamed so far included)
    /// surfaces on the next [`Engine::step`]. Returns `false` for ids
    /// the engine does not know.
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(req) = self.queue.remove(id) {
            self.metrics.record_cancelled();
            let e2e = req.arrived.elapsed().as_secs_f64();
            self.pending.push(Event::Finished(Response {
                id,
                tokens: Vec::new(),
                finish: FinishReason::Cancelled,
                queue_secs: e2e,
                ttft_secs: 0.0,
                e2e_secs: e2e,
            }));
            return true;
        }
        if let Some(idx) = self.running.iter().position(|r| r.req.id == id) {
            self.metrics.record_cancelled();
            let resp = self.retire(idx, FinishReason::Cancelled);
            self.pending.push(Event::Finished(resp));
            return true;
        }
        false
    }

    /// Remove `running[idx]`, release its KV blocks, and build the
    /// terminal response. Completion metrics are only recorded for
    /// natural finishes (EOS / length).
    fn retire(&mut self, idx: usize, finish: FinishReason) -> Response {
        let run = self.running.swap_remove(idx);
        self.kv.release(run.req.id);
        let e2e = run.req.arrived.elapsed();
        if matches!(finish, FinishReason::Eos | FinishReason::Length) {
            self.metrics.record_done(e2e, run.req.prompt.len());
        }
        Response {
            id: run.req.id,
            tokens: run.generated,
            finish,
            queue_secs: run.admitted_at.duration_since(run.req.arrived).as_secs_f64(),
            ttft_secs: run
                .first_token_at
                .map(|t| t.duration_since(run.req.arrived).as_secs_f64())
                .unwrap_or(0.0),
            e2e_secs: e2e.as_secs_f64(),
        }
    }

    /// One scheduling tick: expire deadlines, admit from the queue,
    /// then advance **every** running sequence through a single
    /// [`Backend::forward_tick`] — prefilling sequences contribute
    /// their next prompt chunk (length chosen by the
    /// [`SchedulePolicy`]), decoding sequences their last sampled
    /// token. Tokens are emitted as [`Event::Token`] the moment they
    /// are sampled; finished sequences retire with
    /// [`Event::Finished`]. Per-sequence sampling and finish logic are
    /// chunking-independent and the forward core is per-token
    /// bit-identical to the sequential loop, so generations are
    /// token-identical to per-sequence serving under any policy.
    ///
    /// Failure containment: recoverable faults (backend errors,
    /// contained panics, pool exhaustion beyond admission, cache-import
    /// mismatch, spec-rollback violations) terminate only the affected
    /// request(s) with `Failed(reason)` — see [`super::error`]. `Err`
    /// here means [`EngineError::PoolCorrupted`]: containment left the
    /// pool inconsistent and serving must stop.
    pub fn step(&mut self) -> Result<Vec<Event>, EngineError> {
        let mut events = std::mem::take(&mut self.pending);
        debug_assert!(self.tick_failed.is_empty());
        let mut failed = std::mem::take(&mut self.tick_failed);
        let mut contained_fault = false;

        // ---- deadline expiry (queued + running) ------------------------
        let t_tick = now();
        self.expire_queued(t_tick, &mut events);
        let mut idx = 0;
        while idx < self.running.len() {
            let deadline = self.running[idx].req.deadline;
            let arrived = self.running[idx].req.arrived;
            if deadline.is_some_and(|d| t_tick.duration_since(arrived) >= d) {
                self.metrics.record_expired();
                let resp = self.retire(idx, FinishReason::DeadlineExpired);
                events.push(Event::Finished(resp));
            } else {
                idx += 1;
            }
        }

        // ---- admission -------------------------------------------------
        // Cache-aware: the closure consults the prefix cache, which
        // either admits sharing cached blocks (a hit — recorded as an
        // import plan applied when the Running entry is built) or falls
        // back to a cold admit, evicting LRU entries under pool
        // pressure if the policy allows.
        // lint:allow(hot-path-no-alloc) admission-only: the empty Vec
        // allocates nothing until a prefix hit actually admits.
        let mut plans: Vec<(u64, usize, Arc<B::Kv>)> = Vec::new();
        let admitted = {
            let Engine { batcher, queue, kv, prefix, metrics, running, .. } = &mut *self;
            batcher.admit_with(&**queue, running.len(), kv, &mut |req, kv| {
                match prefix.try_admit(req, kv, metrics) {
                    AdmitOutcome::Rejected => false,
                    AdmitOutcome::Cold => true,
                    AdmitOutcome::Hit { matched, kv: snap } => {
                        plans.push((req.id, matched, snap));
                        true
                    }
                }
            })
        };
        for req in admitted {
            let waited = req.arrived.elapsed();
            if req.deadline.is_some_and(|d| waited >= d) {
                // expired while queued; admission committed KV blocks —
                // hand them straight back (shared refs included)
                self.kv.release(req.id);
                self.metrics.record_expired();
                events.push(Event::Finished(Response {
                    id: req.id,
                    // lint:allow(hot-path-no-alloc) empty Vec — rare
                    // deadline-expiry control path, no allocation.
                    tokens: Vec::new(),
                    finish: FinishReason::DeadlineExpired,
                    queue_secs: waited.as_secs_f64(),
                    ttft_secs: 0.0,
                    e2e_secs: waited.as_secs_f64(),
                }));
                continue;
            }
            self.metrics.record_queue(waited);
            events.push(Event::Started { id: req.id, queue_secs: waited.as_secs_f64() });
            let mut cache = match self.backend.new_cache() {
                Ok(c) => c,
                Err(_) => {
                    // backend cannot build a cache for this request:
                    // hand its admission commitment straight back and
                    // fail only this request — the engine keeps serving
                    self.kv.release(req.id);
                    self.metrics.requests_failed += 1;
                    contained_fault = true;
                    events.push(Event::Finished(Response {
                        id: req.id,
                        // lint:allow(hot-path-no-alloc) empty Vec — rare
                        // containment control path, no allocation.
                        tokens: Vec::new(),
                        finish: FinishReason::Failed(FailReason::Backend),
                        queue_secs: waited.as_secs_f64(),
                        ttft_secs: 0.0,
                        e2e_secs: req.arrived.elapsed().as_secs_f64(),
                    }));
                    continue;
                }
            };
            let mut prompt_idx = 0;
            let mut prefix_hit = false;
            let mut import_fault = false;
            if let Some(pos) = plans.iter().position(|(id, _, _)| *id == req.id) {
                let (_, matched, snap) = plans.swap_remove(pos);
                if self.backend.import_kv_prefix(&mut cache, &snap, matched) {
                    if fault::point("prefix_cache.import") {
                        // injected import mismatch: the snapshot landed
                        // in the cache but post-import validation
                        // (simulated) rejected it — serving on would
                        // risk non-identical streams, so the request
                        // terminates instead
                        self.metrics.faults_injected += 1;
                        import_fault = true;
                    } else {
                        // the matched prefix's KV is already in place:
                        // prefill resumes at `matched`
                        prompt_idx = matched;
                        prefix_hit = true;
                    }
                }
                // else: backend cannot import — prefill everything; the
                // shared block accounting still holds (physical KV is
                // per-sequence, blocks are capacity bookkeeping)
            }
            if import_fault {
                self.kv.release(req.id);
                self.metrics.requests_failed += 1;
                contained_fault = true;
                events.push(Event::Finished(Response {
                    id: req.id,
                    // lint:allow(hot-path-no-alloc) empty Vec — chaos-only
                    // containment path, no allocation.
                    tokens: Vec::new(),
                    finish: FinishReason::Failed(FailReason::CacheImport),
                    queue_secs: waited.as_secs_f64(),
                    ttft_secs: 0.0,
                    e2e_secs: req.arrived.elapsed().as_secs_f64(),
                }));
                continue;
            }
            self.running.push(Running {
                sampler: Sampler::new(req.sampling),
                cache,
                prompt_idx,
                // lint:allow(hot-path-no-alloc) admission-only; grows with
                // the generation, not per tick.
                generated: Vec::new(),
                admitted_at: now(),
                first_token_at: None,
                prefix_hit,
                req,
            });
        }

        // ---- graceful degradation under pressure -----------------------
        // Pool pressure past the configured threshold (or the contained-
        // panic latch) turns off speculation and prefix insertion for
        // the tick: both are throughput optimizations whose absence
        // never changes a request's tokens, and both consume extra pool
        // headroom (draft overshoot, pinned prefixes) exactly when the
        // pool has none. Re-evaluated every tick, so recovery is
        // automatic once pressure recedes.
        let degraded = self.is_degraded();
        if degraded && !self.running.is_empty() {
            self.metrics.degraded_ticks += 1;
        }

        // ---- partition the running set ---------------------------------
        // Greedy decoding sequences take the speculative draft/verify
        // path when the backend offers one; prefilling and non-greedy
        // sequences (the acceptance rule is argmax-based) take the
        // normal chunked tick. Non-speculating backends put everything
        // in the normal set, so this partition is behavior-free for
        // them.
        self.tick_spec_idx.clear();
        self.tick_normal_idx.clear();
        let speculates = self.backend.speculates() && !degraded;
        for (i, run) in self.running.iter().enumerate() {
            if speculates
                && !run.prefilling()
                && matches!(run.req.sampling, SamplingParams::Greedy)
            {
                self.tick_spec_idx.push(i);
            } else {
                self.tick_normal_idx.push(i);
            }
        }

        // ---- one unified chunked forward over the normal subset --------
        if !self.tick_normal_idx.is_empty() {
            let n_pre =
                self.tick_normal_idx.iter().filter(|&&i| self.running[i].prefilling()).count();
            let tick = TickState {
                prefilling: n_pre,
                decoding: self.tick_normal_idx.len() - n_pre,
                queued: self.queue.len(),
            };
            let bound = self.cfg.prefill_chunk.max(1);
            let chunk_len = self.policy.chunk_for_tick(tick).clamp(1, bound);
            self.metrics.record_tick_chunk(chunk_len);

            let t0 = now();
            // per-tick buffers persist across ticks: cleared and refilled
            // in place, so a steady-state tick performs no heap
            // allocation outside the kernels (pinned by
            // eval::speed::measure_decode_batch's allocation probe)
            let nb = self.tick_normal_idx.len();
            for c in &mut self.tick_chunks {
                c.clear();
            }
            while self.tick_chunks.len() < nb {
                // lint:allow(hot-path-no-alloc) grows the persistent tick
                // buffers to peak batch size once; flat thereafter
                // (tests/alloc_steady.rs pins it).
                self.tick_chunks.push(Vec::new());
            }
            self.tick_need.clear();
            for (j, &i) in self.tick_normal_idx.iter().enumerate() {
                let run = &self.running[i];
                let chunk = &mut self.tick_chunks[j];
                if run.prefilling() {
                    let end = (run.prompt_idx + chunk_len).min(run.req.prompt.len());
                    chunk.extend_from_slice(&run.req.prompt[run.prompt_idx..end]);
                } else {
                    // lint:allow(no-panic-serve) load-bearing: a decoding
                    // sequence always holds ≥1 generated token (it left
                    // prefill by sampling one); empty here is an engine
                    // bug, not a workload condition.
                    chunk.push(*run.generated.last().expect("decoding sequence has a token"));
                }
                // logits are needed only where something will sample:
                // decoding sequences and prompts completing this tick
                self.tick_need.push(run.prompt_idx + chunk.len() >= run.req.prompt.len());
            }
            // prompt tokens actually entering the forward pass this tick
            // (prefix-cache hits start past their matched prefix, so the
            // skipped fraction is visible as reused vs computed tokens)
            let prefill_toks: u64 = self
                .tick_normal_idx
                .iter()
                .zip(&self.tick_chunks)
                .filter(|(&i, _)| self.running[i].prefilling())
                .map(|(_, c)| c.len() as u64)
                .sum();
            self.metrics.prefill_tokens_computed += prefill_toks;

            let mut chunk_refs = take_slice_buf(&mut self.tick_chunk_refs);
            chunk_refs.extend(self.tick_chunks[..nb].iter().map(|c| c.as_slice()));
            let mut caches = take_mut_buf(&mut self.tick_caches);
            {
                let mut want = self.tick_normal_idx.iter().peekable();
                for (i, run) in self.running.iter_mut().enumerate() {
                    if want.peek() == Some(&&i) {
                        want.next();
                        caches.push(&mut run.cache);
                    }
                }
            }
            let mut panicked_now = false;
            let result = if fault::point("engine.forward_tick") {
                self.metrics.faults_injected += 1;
                // lint:allow(hot-path-no-alloc) chaos-only containment path
                Err(anyhow::anyhow!("injected: backend forward fault"))
            } else {
                let backend = &self.backend;
                let need = &self.tick_need;
                let scratch = &mut self.scratch;
                // Unwind safety: the closure borrows disjoint engine
                // fields; on a panic every participating request retires
                // (its cache is discarded with it) and Scratch carries no
                // cross-tick state by contract, so nothing broken
                // survives the unwind.
                match catch_unwind(AssertUnwindSafe(|| {
                    if fault::point("engine.forward_panic") {
                        self.metrics.faults_injected += 1;
                        // lint:allow(no-panic-serve) chaos-only injected
                        // panic exercising the catch_unwind backstop.
                        panic!("injected: forward panic");
                    }
                    backend.forward_tick(&chunk_refs, &mut caches, need, scratch)
                })) {
                    Ok(r) => r,
                    Err(_) => {
                        panicked_now = true;
                        // lint:allow(hot-path-no-alloc) containment path
                        Err(anyhow::anyhow!("contained panic in forward_tick"))
                    }
                }
            };
            stash_mut_buf(&mut self.tick_caches, caches);
            stash_slice_buf(&mut self.tick_chunk_refs, chunk_refs);
            let all_logits = match result {
                Ok(l) => l,
                Err(_) => {
                    // The fused forward failed: the failure domain is the
                    // whole tick's normal batch — once a shared forward
                    // dies there is no per-sequence attribution. Queued
                    // and speculative-path sequences are untouched. The
                    // empty logits vector makes the sampling loop below a
                    // no-op (zip against empty).
                    if panicked_now {
                        self.panicked = true;
                    }
                    contained_fault = true;
                    let reason =
                        if panicked_now { FailReason::Panic } else { FailReason::Backend };
                    // deferred into `failed`: retiring here would shift
                    // `running` and invalidate `tick_spec_idx` before the
                    // speculative section below consumes it
                    for &i in &self.tick_normal_idx {
                        failed.push((self.running[i].req.id, reason));
                    }
                    Vec::new()
                }
            };

            // sample: sequences that just completed their prompt emit
            // their first token, decoding ones their next — mid-prompt
            // sequences only advanced their KV cache
            let seqs = nb;
            let mut emitted = 0usize;
            for ((&i, chunk), logits) in
                self.tick_normal_idx.iter().zip(&self.tick_chunks).zip(&all_logits)
            {
                let run = &mut self.running[i];
                let sample_from = if run.prefilling() {
                    run.prompt_idx += chunk.len();
                    if run.prefilling() {
                        None
                    } else {
                        // the prompt's KV is fully written and the first
                        // decode token's is not yet — the exact state the
                        // prefix cache snapshots. Skipped while degraded:
                        // pinning prefixes costs pool headroom exactly
                        // when there is none (hits still serve).
                        if !degraded && self.prefix.wants(&run.req.prompt) {
                            if let Some(snap) =
                                self.backend.snapshot_kv_prefix(&run.cache, run.req.prompt.len())
                            {
                                self.prefix.insert(
                                    &run.req.prompt,
                                    run.req.id,
                                    &mut self.kv,
                                    Arc::new(snap),
                                    &mut self.metrics,
                                );
                            }
                        }
                        // lint:allow(no-panic-serve) load-bearing: need[b]
                        // was true for this chunk, so the backend contract
                        // guarantees logits — absence is an engine bug.
                        Some(logits.as_ref().expect("completing chunk has logits"))
                    }
                } else {
                    // lint:allow(no-panic-serve) load-bearing, as above.
                    Some(logits.as_ref().expect("decoding chunk has logits"))
                };
                if let Some(logits) = sample_from {
                    let tok = run.sampler.sample(logits);
                    let appended = if fault::point("kv_pool.append") {
                        self.metrics.faults_injected += 1;
                        false
                    } else {
                        self.kv.append_token(run.req.id)
                    };
                    if !appended {
                        // beyond the admission-time commitment: the pool
                        // refused the position, so this request (alone)
                        // terminates once the loop releases its borrows
                        failed.push((run.req.id, FailReason::PoolExhausted));
                        continue;
                    }
                    run.generated.push(tok);
                    let t_emit = now();
                    if run.first_token_at.is_none() {
                        run.first_token_at = Some(t_emit);
                        let ttft = t_emit.duration_since(run.req.arrived);
                        self.metrics.record_ttft(ttft);
                        self.metrics.record_ttft_admission(ttft, run.prefix_hit);
                    }
                    events.push(Event::Token { id: run.req.id, token: tok, t_emit });
                    emitted += 1;
                }
            }
            if self.backend.batch_amortized() {
                self.metrics.record_batch_step(t0.elapsed(), seqs, emitted);
            } else {
                // per-sequence backend: every token still saw the whole
                // tick as its client-observed latency, but no weight
                // stream was shared — occupancy must stay 1
                for _ in 0..emitted {
                    self.metrics.record_batch_step(t0.elapsed(), 1, 1);
                }
            }
        }

        // ---- one speculative draft/verify round over the spec subset ---
        if !self.tick_spec_idx.is_empty() {
            let t0 = now();
            self.tick_last.clear();
            self.tick_budgets.clear();
            for &i in &self.tick_spec_idx {
                let run = &self.running[i];
                // lint:allow(no-panic-serve) load-bearing: spec routing
                // only picks decoding sequences, which hold ≥1 token.
                self.tick_last.push(*run.generated.last().expect("decoding sequence has a token"));
                // remaining budget is ≥ 1: exhausted sequences retired
                // at the end of the tick that exhausted them
                self.tick_budgets.push(run.req.max_new_tokens - run.generated.len());
            }
            let mut caches = take_mut_buf(&mut self.tick_caches);
            {
                let mut want = self.tick_spec_idx.iter().peekable();
                for (i, run) in self.running.iter_mut().enumerate() {
                    if want.peek() == Some(&&i) {
                        want.next();
                        caches.push(&mut run.cache);
                    }
                }
            }
            let mut panicked_now = false;
            let result = if fault::point("engine.spec_tick") {
                self.metrics.faults_injected += 1;
                // lint:allow(hot-path-no-alloc) chaos-only containment path
                Some(Err(anyhow::anyhow!("injected: spec_tick fault")))
            } else {
                let backend = &self.backend;
                let last = &self.tick_last;
                let budgets = &self.tick_budgets;
                let scratch = &mut self.scratch;
                // Unwind safety: same argument as the normal forward —
                // every spec participant retires on a panic and Scratch
                // is stateless across ticks by contract.
                match catch_unwind(AssertUnwindSafe(|| {
                    backend.spec_tick(last, &mut caches, budgets, scratch)
                })) {
                    Ok(r) => r,
                    Err(_) => {
                        panicked_now = true;
                        // lint:allow(hot-path-no-alloc) containment path
                        Some(Err(anyhow::anyhow!("contained panic in spec_tick")))
                    }
                }
            };
            stash_mut_buf(&mut self.tick_caches, caches);
            let outcomes = match result {
                Some(Ok(o)) => o,
                // A failed or panicked round — or a speculating backend
                // without spec_tick, a trait-contract violation contained
                // the same way — fails the whole spec batch: the fused
                // draft/verify forward offers no per-sequence attribution.
                Some(Err(_)) | None => {
                    if panicked_now {
                        self.panicked = true;
                    }
                    contained_fault = true;
                    let reason =
                        if panicked_now { FailReason::Panic } else { FailReason::Backend };
                    // deferred like the normal-batch failure above: all
                    // contained failures retire together once the tick's
                    // index buffers are dead
                    for &i in &self.tick_spec_idx {
                        failed.push((self.running[i].req.id, reason));
                    }
                    Vec::new()
                }
            };

            let mut emitted = 0usize;
            for (&i, outcome) in self.tick_spec_idx.iter().zip(&outcomes) {
                let run = &mut self.running[i];
                // Rollback-protocol validation: a round must emit between
                // 1 and budget tokens with consistent accept accounting.
                // A violating outcome would corrupt the KV ledger below,
                // so it is contained as a per-request failure instead.
                let budget = run.req.max_new_tokens - run.generated.len();
                let valid = !outcome.tokens.is_empty()
                    && outcome.tokens.len() <= budget
                    && outcome.accepted <= outcome.drafted
                    && outcome.tokens.len() <= outcome.accepted + 1;
                let injected = fault::point("engine.spec_rollback");
                if injected {
                    self.metrics.faults_injected += 1;
                }
                if !valid || injected {
                    failed.push((run.req.id, FailReason::SpecRollback));
                    continue;
                }
                // Pool bookkeeping mirrors the physical overshoot: the
                // round transiently occupied `drafted + 1` positions
                // past the pre-round length, then the backend rolled the
                // caches back. Appending them all and truncating to the
                // emitted history exercises the same accept-with-
                // rollback path on the paged pool, re-crediting the
                // blocks the rejected tail had claimed.
                let written = outcome.drafted + 1;
                let mut append_ok = true;
                for _ in 0..written {
                    // within the admission-time commitment (the draft
                    // allotment is clamped to budget − 1) unless the pool
                    // refuses — then this request alone terminates and
                    // retiring releases the partially appended positions
                    let ok = if fault::point("kv_pool.append.spec") {
                        self.metrics.faults_injected += 1;
                        false
                    } else {
                        self.kv.append_token(run.req.id)
                    };
                    if !ok {
                        append_ok = false;
                        break;
                    }
                }
                if !append_ok {
                    failed.push((run.req.id, FailReason::PoolExhausted));
                    continue;
                }
                // emission stops at EOS — tokens past it were verified
                // but must never surface (the sequence retires below)
                let mut emit_n = outcome.tokens.len();
                if let Some(pos) = outcome.tokens.iter().position(|&t| t == self.cfg.eos_token) {
                    emit_n = pos + 1;
                }
                let t_emit = now();
                for &tok in &outcome.tokens[..emit_n] {
                    run.generated.push(tok);
                    events.push(Event::Token { id: run.req.id, token: tok, t_emit });
                }
                self.kv.truncate_to(run.req.id, run.req.prompt.len() + run.generated.len());
                self.metrics
                    .record_spec(outcome.drafted, outcome.accepted, written - emit_n, emit_n);
                emitted += emit_n;
            }
            self.metrics.record_batch_step(t0.elapsed(), self.tick_spec_idx.len(), emitted);
        }

        // ---- contained per-request failures ----------------------------
        // Marked during the forward/spec loops (which hold borrows into
        // `running`); retiring here returns every KV block in the same
        // tick the fault happened.
        if !failed.is_empty() {
            contained_fault = true;
            for (id, reason) in failed.drain(..) {
                self.fail_by_id(id, reason, &mut events);
            }
        }
        self.tick_failed = failed;

        // ---- finish checks + retire ------------------------------------
        let mut idx = 0;
        while idx < self.running.len() {
            let run = &self.running[idx];
            let hit_eos = run.generated.last() == Some(&self.cfg.eos_token);
            let done = !run.prefilling()
                && (hit_eos || run.generated.len() >= run.req.max_new_tokens);
            if done {
                let finish = if hit_eos { FinishReason::Eos } else { FinishReason::Length };
                let resp = self.retire(idx, finish);
                events.push(Event::Finished(resp));
            } else {
                idx += 1;
            }
        }

        // ---- post-containment pool audit -------------------------------
        // The only fatal outcome: a contained fault left the pool's
        // accounting inconsistent. Everything else already terminated
        // per-request above and serving continues.
        if contained_fault {
            if let Err(detail) = self.kv.check_invariants() {
                return Err(EngineError::PoolCorrupted(detail));
            }
        }
        Ok(events)
    }

    /// Retire every *queued* request whose deadline has already passed
    /// (they never reach admission, so the sweep is what bounds their
    /// wait under saturation).
    fn expire_queued(&mut self, now: Instant, events: &mut Vec<Event>) {
        for req in self.queue.remove_expired(now) {
            self.metrics.record_expired();
            let waited = now.duration_since(req.arrived).as_secs_f64();
            events.push(Event::Finished(Response {
                id: req.id,
                tokens: Vec::new(),
                finish: FinishReason::DeadlineExpired,
                queue_secs: waited,
                ttft_secs: 0.0,
                e2e_secs: waited,
            }));
        }
    }

    /// Drain everything currently queued/running (offline batch mode),
    /// returning only the terminal responses. The streamed
    /// [`Event::Token`] sequence of a request concatenates to exactly
    /// the `tokens` of its response here — same forward core, same
    /// sampler state, bit-identical logits.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.has_work() {
            for ev in self.step()? {
                if let Event::Finished(r) = ev {
                    out.push(r);
                }
            }
        }
        Ok(out)
    }

    /// KV-pool consistency (exposed for tests and debug assertions).
    pub fn check_invariants(&self) -> Result<(), String> {
        self.kv.check_invariants()
    }

    /// The prompt-prefix cache (tests inspect entry counts).
    pub fn prefix_cache(&self) -> &PrefixCache<B::Kv> {
        &self.prefix
    }

    /// Drop every cached prefix, unpinning its blocks (tests assert the
    /// pool drains back to full after churn).
    pub fn clear_prefix_cache(&mut self) {
        let Engine { prefix, kv, .. } = self;
        prefix.clear(kv);
    }

    /// Paged-KV pool accounting (tests assert cancelled sequences
    /// return every block).
    pub fn kv(&self) -> &PagedKvManager {
        &self.kv
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Tear down, keeping the final metrics (the server thread returns
    /// these on shutdown).
    pub fn into_metrics(self) -> Metrics {
        self.metrics
    }
}

// ---- per-tick borrow-buffer recycling ---------------------------------
//
// `forward_tick` takes `&[&[u32]]` and `&mut [&mut Kv]` — vectors of
// borrows whose lifetimes are local to one `step`. To avoid allocating
// them every tick, the engine keeps the *allocations* alive in fields
// typed with `'static` elements and re-borrows them per tick. The
// transmutes only ever see **empty** vectors (asserted), so no reference
// with the wrong lifetime ever exists — only a raw capacity is recycled
// between two layout-identical types that differ in lifetime alone.

fn take_slice_buf<'a>(buf: &mut Vec<&'static [u32]>) -> Vec<&'a [u32]> {
    let v = std::mem::take(buf);
    debug_assert!(v.is_empty());
    // SAFETY: `v` is empty; `&'static [u32]` and `&'a [u32]` are
    // layout-identical, so only the allocation is reinterpreted.
    unsafe { std::mem::transmute::<Vec<&'static [u32]>, Vec<&'a [u32]>>(v) }
}

fn stash_slice_buf<'a>(buf: &mut Vec<&'static [u32]>, mut v: Vec<&'a [u32]>) {
    v.clear();
    // SAFETY: cleared above — no `'a` reference survives the transmute.
    *buf = unsafe { std::mem::transmute::<Vec<&'a [u32]>, Vec<&'static [u32]>>(v) };
}

fn take_mut_buf<'a, K: 'static>(buf: &mut Vec<&'static mut K>) -> Vec<&'a mut K> {
    let v = std::mem::take(buf);
    debug_assert!(v.is_empty());
    // SAFETY: `v` is empty; the element types differ only in lifetime.
    unsafe { std::mem::transmute::<Vec<&'static mut K>, Vec<&'a mut K>>(v) }
}

fn stash_mut_buf<'a, K: 'static>(buf: &mut Vec<&'static mut K>, mut v: Vec<&'a mut K>) {
    v.clear();
    // SAFETY: cleared above — no `'a` reference survives the transmute.
    *buf = unsafe { std::mem::transmute::<Vec<&'a mut K>, Vec<&'static mut K>>(v) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplingParams;
    use crate::coordinator::SchedulePolicyKind;
    use crate::model::init::random_weights;
    use crate::model::{presets, Model};
    use std::time::Duration;

    fn cpu_engine(max_batch: usize) -> Engine<CpuBackend> {
        cpu_engine_cfg(EngineConfig {
            max_batch,
            total_blocks: 64,
            block_size: 8,
            ..Default::default()
        })
    }

    fn cpu_engine_cfg(cfg: EngineConfig) -> Engine<CpuBackend> {
        let mut mcfg = presets::by_name("opt-nano").unwrap();
        mcfg.vocab = 64;
        mcfg.max_seq = 48;
        let model = Model::new(mcfg.clone(), random_weights(&mcfg, 42));
        Engine::new(CpuBackend(BackendModel::dense(&model)), cfg)
    }

    fn req(id: u64, prompt_len: usize, gen: usize) -> Request {
        Request::new(id, (0..prompt_len as u32).map(|i| 3 + i % 60).collect(), gen)
    }

    #[test]
    fn serves_single_request() {
        let mut e = cpu_engine(4);
        e.submit(req(1, 5, 6)).unwrap();
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 1);
        assert!(out[0].tokens.len() <= 6 && !out[0].tokens.is_empty());
        assert!(out[0].ttft_secs > 0.0, "per-request TTFT must be populated");
        assert!(e.check_invariants().is_ok());
        assert_eq!(e.metrics.completed, 1);
    }

    #[test]
    fn serves_many_requests_batched() {
        let mut e = cpu_engine(3);
        for id in 0..9 {
            e.submit(req(id, 4 + (id as usize % 5), 5)).unwrap();
        }
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 9);
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..9).collect::<Vec<_>>());
        assert!(e.check_invariants().is_ok());
        assert_eq!(e.metrics.completed, 9);
        assert!(e.metrics.generated_tokens > 0);
        // with 9 requests and max_batch 3, decode ticks run >1 sequence
        assert!(
            e.metrics.max_batch_occupancy >= 2,
            "batched decode never ran: max occupancy {}",
            e.metrics.max_batch_occupancy
        );
        assert!(e.metrics.mean_batch_occupancy() > 1.0);
    }

    #[test]
    fn step_streams_token_events_matching_responses() {
        let mut e = cpu_engine(4);
        for id in 0..3 {
            e.submit(req(id, 5, 6)).unwrap();
        }
        let mut streamed: std::collections::HashMap<u64, Vec<u32>> = Default::default();
        let mut finished: std::collections::HashMap<u64, Response> = Default::default();
        while e.has_work() {
            for ev in e.step().unwrap() {
                match ev {
                    Event::Token { id, token, .. } => streamed.entry(id).or_default().push(token),
                    Event::Finished(r) => {
                        finished.insert(r.id, r);
                    }
                    Event::Started { queue_secs, .. } => assert!(queue_secs >= 0.0),
                    Event::Rejected { .. } => panic!("nothing was rejected"),
                }
            }
        }
        assert_eq!(finished.len(), 3);
        for (id, r) in &finished {
            assert_eq!(
                &streamed[id], &r.tokens,
                "request {id}: streamed tokens diverged from the terminal response"
            );
        }
    }

    #[test]
    fn greedy_generation_is_deterministic() {
        let run = || {
            let mut e = cpu_engine(2);
            e.submit(req(1, 6, 8)).unwrap();
            e.run_to_completion().unwrap().remove(0).tokens
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn numerics_config_reaches_backend_and_keeps_greedy_tokens() {
        let run = |mode| {
            let mut e = cpu_engine_cfg(EngineConfig {
                max_batch: 2,
                total_blocks: 64,
                block_size: 8,
                numerics: mode,
                ..Default::default()
            });
            assert_eq!(e.backend().0.numerics(), mode, "engine must apply cfg.numerics");
            assert_eq!(e.metrics.numerics_label, mode.label());
            e.submit(req(1, 6, 8)).unwrap();
            e.run_to_completion().unwrap().remove(0).tokens
        };
        // the Fast tier must not change a single greedy-served token
        assert_eq!(run(NumericsMode::Exact), run(NumericsMode::Fast));
    }

    #[test]
    fn sampled_generation_is_seed_deterministic() {
        let run = |seed| {
            let mut e = cpu_engine(2);
            e.submit(req(1, 6, 8).with_sampling(SamplingParams::TopK {
                k: 8,
                temperature: 1.0,
                seed,
            }))
            .unwrap();
            e.run_to_completion().unwrap().remove(0).tokens
        };
        assert_eq!(run(7), run(7));
    }

    #[test]
    fn rejects_oversized_requests() {
        let mut e = cpu_engine(2);
        // capacity is 48 tokens; this wants 100
        assert!(e.submit(req(1, 50, 50)).is_err());
        assert_eq!(e.metrics.rejected, 1);
        assert!(e.submit(Request::new(2, vec![], 5)).is_err());
    }

    #[test]
    fn rejects_id_already_running() {
        let mut e = cpu_engine(2);
        e.submit(req(7, 4, 10)).unwrap();
        e.step().unwrap(); // admits 7
        assert_eq!(e.submit(req(7, 4, 4)), Err(SubmitError::DuplicateId));
    }

    #[test]
    fn kv_pressure_defers_but_completes_all() {
        let mut e = cpu_engine(8);
        // tiny pool: only ~2 requests' worst case fit at once
        e.kv = PagedKvManager::new(6, 8);
        for id in 0..6 {
            e.submit(req(id, 8, 8)).unwrap();
        }
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 6);
        assert!(e.check_invariants().is_ok());
    }

    #[test]
    fn long_prompts_prefill_in_chunks() {
        let mut e = cpu_engine_cfg(EngineConfig {
            max_batch: 2,
            total_blocks: 64,
            block_size: 8,
            prefill_chunk: 4,
            ..Default::default()
        });
        e.submit(req(1, 20, 3)).unwrap();
        let mut steps = 0;
        let mut responses = Vec::new();
        while e.has_work() {
            for ev in e.step().unwrap() {
                if let Event::Finished(r) = ev {
                    responses.push(r);
                }
            }
            steps += 1;
            assert!(steps < 100, "engine stuck");
        }
        // 20 prompt tokens / 4 per tick = 5 prefill ticks + ≥2 decode
        assert!(steps >= 7, "only {steps} steps");
        assert_eq!(responses.len(), 1);
        assert!(e.metrics.max_tick_chunk <= 4);
    }

    /// Engine config with EOS disabled — random-weight models can
    /// argmax the EOS id, which would make generation lengths (and the
    /// cancel/deadline timing these tests rely on) nondeterministic.
    fn no_eos(max_batch: usize) -> EngineConfig {
        EngineConfig {
            max_batch,
            total_blocks: 64,
            block_size: 8,
            eos_token: u32::MAX,
            ..Default::default()
        }
    }

    #[test]
    fn cancel_running_frees_kv_and_reports_partial_tokens() {
        let mut e = cpu_engine_cfg(no_eos(4));
        let total_free = e.kv().free_blocks();
        for id in 0..3 {
            e.submit(req(id, 6, 30)).unwrap();
        }
        // into decode: prompt prefills in one tick, a few tokens stream
        for _ in 0..4 {
            e.step().unwrap();
        }
        let used_before = e.kv().used_blocks();
        assert!(used_before > 0);
        assert!(e.cancel(1), "id 1 is running");
        assert!(e.kv().used_blocks() < used_before, "cancel must free blocks now");
        e.check_invariants().unwrap();
        // the terminal event surfaces on the next step
        let evs = e.step().unwrap();
        let resp = evs
            .iter()
            .find_map(|ev| match ev {
                Event::Finished(r) if r.id == 1 => Some(r.clone()),
                _ => None,
            })
            .expect("cancelled response");
        assert_eq!(resp.finish, FinishReason::Cancelled);
        assert!(!resp.tokens.is_empty(), "mid-decode cancel keeps streamed tokens");
        let rest = e.run_to_completion().unwrap();
        assert_eq!(rest.len(), 2);
        assert_eq!(e.metrics.cancelled_total, 1);
        assert_eq!(e.metrics.completed, 2);
        assert_eq!(e.kv().free_blocks(), total_free, "every block back in the pool");
        e.check_invariants().unwrap();
        assert!(!e.cancel(1), "already gone");
    }

    #[test]
    fn cancel_queued_request_never_runs() {
        let mut e = cpu_engine_cfg(no_eos(1));
        e.submit(req(0, 4, 30)).unwrap();
        e.step().unwrap(); // 0 occupies the only slot
        e.submit(req(1, 4, 4)).unwrap();
        assert!(e.cancel(1), "id 1 is queued");
        let out = e.run_to_completion().unwrap();
        let cancelled = out.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(cancelled.finish, FinishReason::Cancelled);
        assert!(cancelled.tokens.is_empty());
        assert_eq!(out.iter().find(|r| r.id == 0).unwrap().finish, FinishReason::Length);
        assert_eq!(e.metrics.cancelled_total, 1);
        e.check_invariants().unwrap();
    }

    #[test]
    fn resubmit_of_cancelled_id_waits_for_terminal_drain() {
        let mut e = cpu_engine_cfg(no_eos(2));
        e.submit(req(1, 4, 20)).unwrap();
        e.step().unwrap();
        assert!(e.cancel(1));
        // the terminal event is still pending: the id is not reusable
        // yet, else the old and new streams would cross-route
        assert_eq!(e.submit(req(1, 4, 4)), Err(SubmitError::DuplicateId));
        e.step().unwrap(); // drains the pending Finished(Cancelled)
        e.submit(req(1, 4, 4)).unwrap();
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].finish, FinishReason::Length);
        assert_eq!(e.metrics.cancelled_total, 1);
        e.check_invariants().unwrap();
    }

    #[test]
    fn queued_deadline_expires_without_admission() {
        // the only slot is busy for 30 ticks; the queued request's
        // deadline must fire on the next tick, not at admission
        let mut e = cpu_engine_cfg(no_eos(1));
        e.submit(req(0, 4, 30)).unwrap();
        e.step().unwrap();
        e.submit(req(1, 4, 4).with_deadline(Duration::ZERO)).unwrap();
        let evs = e.step().unwrap();
        let resp = evs
            .iter()
            .find_map(|ev| match ev {
                Event::Finished(r) if r.id == 1 => Some(r.clone()),
                _ => None,
            })
            .expect("queued request must expire on the very next tick");
        assert_eq!(resp.finish, FinishReason::DeadlineExpired);
        assert!(resp.tokens.is_empty());
        assert_eq!(e.metrics.expired_total, 1);
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 1); // only request 0 remains
        e.check_invariants().unwrap();
    }

    #[test]
    fn deadline_zero_expires_before_serving() {
        let mut e = cpu_engine(2);
        e.submit(req(1, 5, 8).with_deadline(Duration::ZERO)).unwrap();
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].finish, FinishReason::DeadlineExpired);
        assert!(out[0].tokens.is_empty());
        assert_eq!(e.metrics.expired_total, 1);
        e.check_invariants().unwrap();
    }

    #[test]
    fn deadline_expires_mid_flight() {
        let mut e = cpu_engine_cfg(no_eos(2));
        e.submit(req(1, 4, 40).with_deadline(Duration::from_millis(30))).unwrap();
        e.step().unwrap(); // admit + prefill + first token
        std::thread::sleep(Duration::from_millis(40));
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].finish, FinishReason::DeadlineExpired);
        assert!(out[0].tokens.len() < 40, "deadline must cut generation short");
        assert_eq!(e.metrics.expired_total, 1);
        e.check_invariants().unwrap();
    }

    #[test]
    fn adaptive_policy_matches_fixed_tokens() {
        // chunking is an efficiency decision, never a correctness one
        let run = |policy| {
            let mut e = cpu_engine_cfg(EngineConfig {
                max_batch: 4,
                total_blocks: 64,
                block_size: 8,
                prefill_chunk: 8,
                policy,
                ..Default::default()
            });
            for id in 0..5 {
                e.submit(req(id, 14, 6)).unwrap();
            }
            let mut out = e.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            assert!(e.metrics.max_tick_chunk <= 8, "chunk bound violated");
            e.check_invariants().unwrap();
            out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(SchedulePolicyKind::Fixed), run(SchedulePolicyKind::Adaptive));
    }

    // ---- fault containment ---------------------------------------------

    #[derive(Clone, Copy, PartialEq, Eq)]
    enum Sabotage {
        Error,
        Panic,
    }

    /// CpuBackend wrapper whose next `forward_tick` can be armed to
    /// fail or panic exactly once — the containment paths' test double.
    struct SabotageBackend {
        inner: CpuBackend,
        mode: std::cell::Cell<Option<Sabotage>>,
    }

    impl Backend for SabotageBackend {
        type Kv = KvCache;
        type Scratch = ForwardScratch;

        fn capacity(&self) -> usize {
            self.inner.capacity()
        }

        fn new_cache(&self) -> Result<KvCache> {
            self.inner.new_cache()
        }

        fn forward_tick(
            &self,
            chunks: &[&[u32]],
            caches: &mut [&mut KvCache],
            need: &[bool],
            scratch: &mut ForwardScratch,
        ) -> Result<Vec<Option<Vec<f32>>>> {
            match self.mode.take() {
                Some(Sabotage::Error) => anyhow::bail!("sabotage: injected forward error"),
                Some(Sabotage::Panic) => panic!("sabotage: injected forward panic"),
                None => self.inner.forward_tick(chunks, caches, need, scratch),
            }
        }

        fn label(&self) -> &'static str {
            "sabotage"
        }
    }

    fn sabotage_engine(cfg: EngineConfig) -> Engine<SabotageBackend> {
        let mut mcfg = presets::by_name("opt-nano").unwrap();
        mcfg.vocab = 64;
        mcfg.max_seq = 48;
        let model = Model::new(mcfg.clone(), random_weights(&mcfg, 42));
        let backend = SabotageBackend {
            inner: CpuBackend(BackendModel::dense(&model)),
            mode: std::cell::Cell::new(None),
        };
        Engine::new(backend, cfg)
    }

    #[test]
    fn forward_error_fails_tick_batch_but_engine_survives() {
        let mut e = sabotage_engine(no_eos(4));
        e.submit(req(0, 4, 10)).unwrap();
        e.submit(req(1, 4, 10)).unwrap();
        e.step().unwrap(); // both admitted, prefilled, first token out
        e.backend().mode.set(Some(Sabotage::Error));
        let evs = e.step().unwrap();
        let failed: Vec<u64> = evs
            .iter()
            .filter_map(|ev| match ev {
                Event::Finished(r) if r.finish == FinishReason::Failed(FailReason::Backend) => {
                    Some(r.id)
                }
                _ => None,
            })
            .collect();
        assert_eq!(failed.len(), 2, "a batched forward shares one failure domain");
        assert_eq!(e.metrics.requests_failed, 2);
        assert_eq!(e.kv().used_blocks(), 0, "failed requests must return their blocks");
        e.check_invariants().unwrap();
        assert!(!e.is_degraded(), "a plain backend error must not latch degradation");
        // the engine keeps serving
        e.submit(req(2, 4, 4)).unwrap();
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].finish, FinishReason::Length);
        assert_eq!(e.metrics.completed, 1);
    }

    #[test]
    fn contained_panic_latches_degraded_but_keeps_serving() {
        let mut e = sabotage_engine(no_eos(2));
        e.submit(req(0, 4, 10)).unwrap();
        e.step().unwrap();
        e.backend().mode.set(Some(Sabotage::Panic));
        let evs = e.step().unwrap(); // panic contained at the tick boundary
        let finishes: Vec<FinishReason> = evs
            .iter()
            .filter_map(|ev| match ev {
                Event::Finished(r) => Some(r.finish),
                _ => None,
            })
            .collect();
        assert_eq!(finishes, vec![FinishReason::Failed(FailReason::Panic)]);
        assert!(e.is_degraded(), "a contained panic must latch degraded mode");
        assert_eq!(e.kv().used_blocks(), 0);
        e.check_invariants().unwrap();
        // degraded, not dead: new work still completes (spec and
        // prefix-insert are off, neither changes tokens)
        e.submit(req(1, 4, 6)).unwrap();
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].finish, FinishReason::Length);
        assert!(e.metrics.degraded_ticks > 0, "degraded serving must be counted");
    }

    #[test]
    fn abort_all_terminates_queued_and_running_and_frees_blocks() {
        let mut e = cpu_engine_cfg(no_eos(1));
        e.submit(req(0, 4, 30)).unwrap();
        e.step().unwrap(); // admits 0 into the only slot
        e.submit(req(1, 4, 4)).unwrap(); // stays queued
        let evs = e.abort_all(FailReason::Shutdown);
        let mut finished: Vec<(u64, FinishReason)> = evs
            .iter()
            .filter_map(|ev| match ev {
                Event::Finished(r) => Some((r.id, r.finish)),
                _ => None,
            })
            .collect();
        finished.sort_by_key(|(id, _)| *id);
        assert_eq!(
            finished,
            vec![
                (0, FinishReason::Failed(FailReason::Shutdown)),
                (1, FinishReason::Failed(FailReason::Shutdown)),
            ]
        );
        assert!(!e.has_work(), "abort_all must leave no queued or running work");
        assert_eq!(e.metrics.requests_failed, 2);
        assert_eq!(e.kv().used_blocks(), 0);
        e.check_invariants().unwrap();
    }

    #[test]
    fn pressure_degradation_counts_and_recovers() {
        let mut cfg = no_eos(4);
        // 64-block pool: any real occupancy pushes free/total under 0.9
        cfg.pressure_threshold = 0.9;
        let mut e = cpu_engine_cfg(cfg);
        for id in 0..4 {
            e.submit(req(id, 8, 8)).unwrap();
        }
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|r| r.finish == FinishReason::Length));
        assert!(
            e.metrics.degraded_ticks > 0,
            "a 0.9 free-fraction threshold must trip under load"
        );
        assert!(!e.is_degraded(), "pressure degradation must clear once the pool drains");
        e.check_invariants().unwrap();
    }

    #[test]
    fn shed_accounting_distinguishes_queue_full_from_unservable() {
        let mut cfg = no_eos(1);
        cfg.max_queue = 1;
        let mut e = cpu_engine_cfg(cfg);
        // unservable (empty prompt): rejected but not shed — retrying is useless
        assert_eq!(e.submit(Request::new(0, vec![], 4)), Err(SubmitError::Full));
        assert_eq!(e.metrics.shed_total, 0);
        // fill the queue, then overflow it: that is load shedding
        e.submit(req(1, 4, 4)).unwrap();
        assert_eq!(e.submit(req(2, 4, 4)), Err(SubmitError::Full));
        assert_eq!(e.metrics.rejected, 2);
        assert_eq!(e.metrics.shed_total, 1);
        let hint = e.retry_after_hint();
        assert!(hint > 0.0, "shed rejections must carry a positive back-off hint");
        // a deeper backlog means a longer hint
        let empty_hint = cpu_engine_cfg(no_eos(1)).retry_after_hint();
        assert!(hint >= empty_hint);
    }
}
