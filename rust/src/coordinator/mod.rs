//! Serving coordinator — the L3 runtime system around the quantized
//! model: request queue, continuous batcher, paged KV-cache manager,
//! sampler, metrics, and the engine loop driving either the CPU decode
//! backends (`full` / `gptq-dequant` / `gptqt-lut`) or the PJRT
//! executables.
//!
//! Shape: a miniature vLLM-style router/engine. The paper measures
//! per-token generation latency under low-concurrency serving (§III-E);
//! this module is the system that measurement runs in, plus the
//! admission/batching machinery a deployment needs around it.

pub mod batcher;
pub mod engine;
pub mod kv_pool;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod sampler;

pub use engine::{Engine, EngineBackend};
pub use kv_pool::PagedKvManager;
pub use metrics::Metrics;
pub use queue::RequestQueue;
pub use request::{Request, Response, SamplingParams};

/// Engine configuration knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Max sequences decoded concurrently.
    pub max_batch: usize,
    /// KV block size in tokens (paged allocator granularity).
    pub block_size: usize,
    /// Total KV blocks in the pool (bounds admitted tokens).
    pub total_blocks: usize,
    /// Max queued requests before `submit` rejects.
    pub max_queue: usize,
    /// Stop token (EOS).
    pub eos_token: u32,
    /// Prompt tokens each prefilling sequence feeds into the shared
    /// chunked forward per tick. Copied into `batcher::BatcherConfig`
    /// at engine construction — the batcher's copy is the runtime
    /// source of truth.
    pub prefill_chunk: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 8,
            block_size: 16,
            total_blocks: 256,
            max_queue: 1024,
            eos_token: crate::data::vocab::EOS,
            prefill_chunk: 16,
        }
    }
}
