//! Serving coordinator — the L3 runtime system around the quantized
//! model, organized around four public abstractions:
//!
//! * [`Server`] — the streaming session front-end. It owns the engine
//!   on a dedicated thread; [`Server::submit`] returns a
//!   [`RequestHandle`] whose [`Event`] stream yields every generated
//!   token as it is sampled, plus admission ([`Event::Started`]) and a
//!   terminal [`Event::Finished`] / [`Event::Rejected`]. Handles
//!   support mid-flight cancellation (paged-KV blocks return to the
//!   pool immediately) and per-request deadlines.
//! * [`Backend`] — what executes the model math. [`CpuBackend`] wraps
//!   the pure-rust decode path (dense / gptq-dequant / gptqt-lut
//!   kernels, one weight stream per tick); [`PjrtBackend`] wraps the
//!   AOT-compiled XLA executables. The engine never matches on a
//!   concrete backend, so new ones plug in without touching
//!   `engine.rs`.
//! * [`SchedulePolicy`] — the per-tick chunk decision.
//!   [`policy::FixedChunk`] is the constant-chunk baseline;
//!   [`policy::AdaptiveChunk`] shrinks prefill chunks as decode
//!   occupancy rises. Selected via [`EngineConfig::policy`].
//! * [`PrefixCache`] — content-addressed reuse of completed prefills,
//!   configured by [`EngineConfig::prefix`] and disabled by default.
//!
//! # Prefix cache + copy-on-write block lifecycle
//!
//! Most serving traffic shares a leading prompt (system preamble,
//! few-shot scaffold); re-prefilling it through the quantized forward
//! path on every request wastes exactly the compute the cheap 2/3-bit
//! weights buy. The coordinator therefore refcounts KV blocks and
//! shares them across sequences:
//!
//! 1. **Publish.** The tick a sequence finishes its prompt (its KV
//!    holds exactly the prompt positions, the first sampled token not
//!    yet written), the engine snapshots that prefix
//!    ([`Backend::snapshot_kv_prefix`]) and the cache pins the blocks
//!    covering it ([`PagedKvManager::pin_prefix`]). Pins keep blocks
//!    alive after the donor retires. If the donor's prompt ends
//!    mid-block it will later write into a pinned block, so the pin
//!    grants it one extra copy-on-write allocation — refused (no cache
//!    entry) when the pool cannot promise it.
//! 2. **Hit.** Admission hashes the incoming prompt per full block
//!    (chained FNV-1a), verifies tokens against the best entry, and
//!    extends the match token-by-token into a partial tail block,
//!    capped at `prompt.len() - 1` so one token still produces logits.
//!    [`PagedKvManager::admit_shared`] then adopts the matched blocks
//!    by reference: fully-covered blocks are read-only forever; a
//!    shared partial tail is copied-on-write immediately (the new
//!    sequence prefills its remaining prompt into the copy). The engine
//!    imports the snapshot ([`Backend::import_kv_prefix`]) and resumes
//!    prefill at the matched offset — bitwise-identical streams, with
//!    the skipped work visible as `prefix_tokens_reused` vs
//!    `prefill_tokens_computed` in [`Metrics`].
//! 3. **Diverge.** Any sequence appending into a block whose refcount
//!    exceeds one copies it first ([`PagedKvManager::append_token`]),
//!    so writers never alias. Admission's no-deadlock guarantee is kept
//!    in terms of *future allocations*: every sequence carries a
//!    `pending` budget with the pool-wide invariant `Σ pending ≤ free`.
//! 4. **Evict.** LRU by last hit, triggered by capacity
//!    ([`PrefixCacheConfig::max_entries`] / `max_blocks`) or pool
//!    pressure (`evict_on_pressure`; the alternative is refusing
//!    admission). Evicting unpins; blocks free once their last
//!    reference drops. The entry being shared from is never
//!    pressure-evicted mid-admission.
//!
//! Underneath sit the same building blocks as before: a bounded
//! priority+FIFO [`RequestQueue`], the continuous [`batcher`], the
//! paged [`PagedKvManager`], per-sequence [`sampler`]s, and
//! [`Metrics`] (now including prefix hit/miss/evict counters and
//! hit-vs-cold TTFT). The [`Engine`] itself is still a single-threaded
//! scheduling loop — offline callers may drive [`Engine::step`] /
//! [`Engine::run_to_completion`] directly, and the streamed token
//! sequence of a request is bit-identical to its offline response
//! (same forward core, same sampler state).
//!
//! # Self-speculative decoding (draft → verify → accept/rollback)
//!
//! GPTQT quantizes twice, so every served model has a cheap sibling
//! for free: the 2-bit binary-coding backend drafts, the 3-bit (or
//! dense) target verifies. [`SpeculativeBackend`] packages the pair as
//! one [`Backend`]; per tick the engine routes greedy decoding
//! sequences through [`Backend::spec_tick`]:
//!
//! 1. **Draft.** The cheap model decodes up to `k` tokens
//!    autoregressively (batched across sequences, greedy argmax).
//! 2. **Verify.** The target consumes `[last, d₁..d_k]` in **one**
//!    chunk-major forward — k+1 positions of logits per weight stream,
//!    which is exactly the batched forward core's amortization.
//! 3. **Accept.** Drafted tokens agreeing with the target argmax are
//!    accepted left to right; the first disagreement emits the
//!    target's correction token instead; a full agreement earns the
//!    position-k argmax as a bonus. Every round emits `accepted + 1`
//!    tokens — precisely the tokens target-only greedy decoding would
//!    emit, so speculation changes latency, never output.
//! 4. **Rollback.** Both KV caches truncate past the accept point
//!    ([`crate::model::KvCache::truncate_to`]) and the paged pool
//!    re-credits the rejected tail's blocks
//!    ([`PagedKvManager::truncate_to`]) — accept-with-rollback on the
//!    same refcounted pool the prefix cache shares.
//!
//! Prefilling and non-greedy sequences (the acceptance rule is
//! argmax-based) ride the normal tick, with both caches advanced in
//! lockstep and the target's logits served. Configured by
//! [`EngineConfig::spec`] / `gptqt serve --speculative`; acceptance
//! counters surface in [`Metrics`] and the `serve spec` bench records.
//!
//! # Failure taxonomy and fault containment
//!
//! The serving path never lets one bad request (or one bad tick) take
//! the engine down. Failures are classed in three tiers (see
//! [`error`]):
//!
//! 1. **Per-request, recoverable** — [`FailReason`]. Backend forward
//!    errors, `append_token` beyond the admission commitment, prefix
//!    cache import mismatches, and speculative-rollback protocol
//!    violations terminate *only the offending request* with
//!    [`Event::Finished`]`(`[`FinishReason::Failed`]`)`. Its paged-KV
//!    blocks return to the free list in the same tick
//!    ([`PagedKvManager::release`]), so `Σ pending ≤ free` and every
//!    other pool invariant hold *through* the failure. A batched
//!    forward failure fails the whole tick's participants (the fused
//!    forward offers no per-sequence attribution) but never queued or
//!    co-resident speculative sequences.
//! 2. **Contained panics** — a panic unwinding out of
//!    [`Backend::forward_tick`] / [`Backend::spec_tick`] is caught at
//!    the tick boundary (`catch_unwind`), the participants fail with
//!    `FailReason::Panic`, and the engine latches *degraded*:
//!    speculation and prefix-cache insertion stay disabled
//!    ([`Metrics::degraded_ticks`] counts every affected tick), but
//!    serving continues.
//! 3. **Fatal** — [`EngineError::PoolCorrupted`]: after containment
//!    the pool's `check_invariants` failed, so [`Engine::step`] returns
//!    `Err` and the server closes all streams. This is the only way a
//!    step errors.
//!
//! Backpressure is bounded end to end: the server's control channel
//! and every per-handle event channel have fixed capacities
//! ([`EngineConfig::event_buffer`]), with the slow-consumer policy
//! chosen by [`BackpressurePolicy`] — block the engine (lossless,
//! default), drop the oldest undelivered token events (lossy, counted
//! in [`Metrics::events_dropped`]; terminal events always delivered),
//! or cancel the lagging request. A full admission queue sheds load
//! with [`Event::Rejected`]`{ retry_after }` instead of growing, and
//! pool pressure beyond [`EngineConfig::pressure_threshold`]
//! temporarily disables speculation + prefix insertion (both re-enable
//! when pressure recedes; the stream contract means neither switch ever
//! changes a request's tokens).
//!
//! Deterministic fault injection ([`crate::util::fault`], `chaos`
//! feature) drives the `rust/tests/chaos.rs` property suite that holds
//! all of the above under a seeded mixed-workload churn.
//!
//! Shape: a miniature vLLM-style router/engine. The paper measures
//! per-token generation latency under low-concurrency serving (§III-E);
//! this module is the system that measurement runs in, plus the
//! admission/batching/streaming machinery a deployment needs around it.

pub mod batcher;
pub mod engine;
pub mod error;
pub mod kv_pool;
pub mod metrics;
pub mod policy;
pub mod prefix_cache;
pub mod queue;
pub mod request;
pub mod sampler;
pub mod server;
pub mod speculative;

pub use engine::{Backend, CpuBackend, Engine, PjrtBackend};
pub use error::{EngineError, FailReason};
pub use kv_pool::PagedKvManager;
pub use metrics::Metrics;
pub use policy::{AdaptiveChunk, FixedChunk, SchedulePolicy, SchedulePolicyKind, TickState};
pub use prefix_cache::{PrefixCache, PrefixCacheConfig};
pub use queue::{RequestQueue, SubmitError};
pub use request::{FinishReason, Request, Response, SamplingParams};
pub use server::{BackpressurePolicy, Event, RequestHandle, Server};
pub use speculative::{DraftFormat, SpecCapable, SpecConfig, SpecOutcome, SpeculativeBackend};

/// Engine configuration knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Max sequences decoded concurrently.
    pub max_batch: usize,
    /// KV block size in tokens (paged allocator granularity).
    pub block_size: usize,
    /// Total KV blocks in the pool (bounds admitted tokens).
    pub total_blocks: usize,
    /// Max queued requests before `submit` rejects.
    pub max_queue: usize,
    /// Stop token (EOS).
    pub eos_token: u32,
    /// Upper bound on the prompt tokens a prefilling sequence feeds
    /// into the shared forward per tick. The [`SchedulePolicy`] decides
    /// the actual per-tick chunk within `1..=prefill_chunk`.
    pub prefill_chunk: usize,
    /// Which [`SchedulePolicy`] the engine instantiates (with
    /// `prefill_chunk` as its bound). Custom policy objects go through
    /// [`Engine::with_policy`] instead.
    pub policy: SchedulePolicyKind,
    /// Prompt-prefix cache policy (admission sharing, LRU eviction).
    /// Off by default; the serve CLI and benches switch it on.
    pub prefix: PrefixCacheConfig,
    /// Numerics tier the backend serves under
    /// ([`crate::kernels::NumericsMode`]): `Exact` (default) keeps the
    /// bitwise kernel contract; `Fast` enables the FMA +
    /// online-softmax kernels. Applied to the backend at engine
    /// construction ([`Backend::set_numerics`]) — the single source of
    /// truth for a serving session's numerics.
    pub numerics: crate::kernels::NumericsMode,
    /// Self-speculative decoding knobs ([`SpecConfig`]): draft depth
    /// `k` and draft weight format. Disabled by default; applied to
    /// the backend at engine construction ([`Backend::set_spec`]).
    /// Only meaningful for speculating backends
    /// ([`SpeculativeBackend`]) — others ignore it.
    pub spec: SpecConfig,
    /// What the engine does when a per-handle event channel is full
    /// (the consumer is slower than generation). See
    /// [`BackpressurePolicy`]; `Block` (lossless) by default.
    pub backpressure: BackpressurePolicy,
    /// Capacity of each per-handle event channel, in events. Bounded so
    /// a slow consumer costs at most `event_buffer * size_of::<Event>`
    /// instead of growing without limit.
    pub event_buffer: usize,
    /// Pool-pressure degradation threshold as a free-block fraction in
    /// `[0, 1]`: when `free / total` drops below it the engine
    /// temporarily disables speculation and prefix-cache insertion
    /// (re-enabled as soon as pressure recedes; neither switch changes
    /// any request's tokens). `0.0` disables degradation.
    pub pressure_threshold: f64,
    /// Default graceful-drain budget for [`Server::shutdown`]: past it,
    /// still-unfinished requests terminate with
    /// `FinishReason::Failed(FailReason::Shutdown)` instead of hanging
    /// their handles. [`Server::shutdown_within`] overrides per call.
    pub drain_deadline: std::time::Duration,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 8,
            block_size: 16,
            total_blocks: 256,
            max_queue: 1024,
            eos_token: crate::data::vocab::EOS,
            prefill_chunk: 16,
            policy: SchedulePolicyKind::Fixed,
            prefix: PrefixCacheConfig::default(),
            numerics: crate::kernels::NumericsMode::Exact,
            spec: SpecConfig::default(),
            backpressure: BackpressurePolicy::Block,
            event_buffer: 256,
            pressure_threshold: 0.0,
            drain_deadline: std::time::Duration::from_secs(30),
        }
    }
}
