//! Serving coordinator — the L3 runtime system around the quantized
//! model, organized around three public abstractions:
//!
//! * [`Server`] — the streaming session front-end. It owns the engine
//!   on a dedicated thread; [`Server::submit`] returns a
//!   [`RequestHandle`] whose [`Event`] stream yields every generated
//!   token as it is sampled, plus admission ([`Event::Started`]) and a
//!   terminal [`Event::Finished`] / [`Event::Rejected`]. Handles
//!   support mid-flight cancellation (paged-KV blocks return to the
//!   pool immediately) and per-request deadlines.
//! * [`Backend`] — what executes the model math. [`CpuBackend`] wraps
//!   the pure-rust decode path (dense / gptq-dequant / gptqt-lut
//!   kernels, one weight stream per tick); [`PjrtBackend`] wraps the
//!   AOT-compiled XLA executables. The engine never matches on a
//!   concrete backend, so new ones plug in without touching
//!   `engine.rs`.
//! * [`SchedulePolicy`] — the per-tick chunk decision.
//!   [`policy::FixedChunk`] is the constant-chunk baseline;
//!   [`policy::AdaptiveChunk`] shrinks prefill chunks as decode
//!   occupancy rises to bound inter-token latency and grows them back
//!   when a tick is prefill-only. Selected via
//!   [`EngineConfig::policy`].
//!
//! Underneath sit the same building blocks as before: a bounded
//! priority+FIFO [`RequestQueue`], the continuous [`batcher`], the
//! paged [`PagedKvManager`], per-sequence [`sampler`]s, and
//! [`Metrics`] (now including per-request TTFT, queue wait,
//! cancellation and deadline-expiry counts). The [`Engine`] itself is
//! still a single-threaded scheduling loop — offline callers may
//! drive [`Engine::step`] / [`Engine::run_to_completion`] directly,
//! and the streamed token sequence of a request is bit-identical to
//! its offline response (same forward core, same sampler state).
//!
//! Shape: a miniature vLLM-style router/engine. The paper measures
//! per-token generation latency under low-concurrency serving (§III-E);
//! this module is the system that measurement runs in, plus the
//! admission/batching/streaming machinery a deployment needs around it.

pub mod batcher;
pub mod engine;
pub mod kv_pool;
pub mod metrics;
pub mod policy;
pub mod queue;
pub mod request;
pub mod sampler;
pub mod server;

pub use engine::{Backend, CpuBackend, Engine, PjrtBackend};
pub use kv_pool::PagedKvManager;
pub use metrics::Metrics;
pub use policy::{AdaptiveChunk, FixedChunk, SchedulePolicy, SchedulePolicyKind, TickState};
pub use queue::{RequestQueue, SubmitError};
pub use request::{FinishReason, Request, Response, SamplingParams};
pub use server::{Event, RequestHandle, Server};

/// Engine configuration knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Max sequences decoded concurrently.
    pub max_batch: usize,
    /// KV block size in tokens (paged allocator granularity).
    pub block_size: usize,
    /// Total KV blocks in the pool (bounds admitted tokens).
    pub total_blocks: usize,
    /// Max queued requests before `submit` rejects.
    pub max_queue: usize,
    /// Stop token (EOS).
    pub eos_token: u32,
    /// Upper bound on the prompt tokens a prefilling sequence feeds
    /// into the shared forward per tick. The [`SchedulePolicy`] decides
    /// the actual per-tick chunk within `1..=prefill_chunk`.
    pub prefill_chunk: usize,
    /// Which [`SchedulePolicy`] the engine instantiates (with
    /// `prefill_chunk` as its bound). Custom policy objects go through
    /// [`Engine::with_policy`] instead.
    pub policy: SchedulePolicyKind,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 8,
            block_size: 16,
            total_blocks: 256,
            max_queue: 1024,
            eos_token: crate::data::vocab::EOS,
            prefill_chunk: 16,
            policy: SchedulePolicyKind::Fixed,
        }
    }
}
