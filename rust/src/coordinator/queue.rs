//! Thread-safe admission queue: priority classes, FIFO within a class,
//! bounded, close-able.

use super::request::Request;
use std::collections::{BinaryHeap, HashSet};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

struct Entry {
    priority: u8,
    seq: u64,
    req: Request,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap: smaller (priority, seq) must compare
        // greater so it pops first.
        (other.priority, other.seq).cmp(&(self.priority, self.seq))
    }
}

struct Inner {
    heap: BinaryHeap<Entry>,
    ids: HashSet<u64>,
    next_seq: u64,
    closed: bool,
}

/// Bounded priority+FIFO request queue.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    available: Condvar,
    capacity: usize,
}

/// Submission failure modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    Full,
    Closed,
    DuplicateId,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(Inner {
                heap: BinaryHeap::new(),
                ids: HashSet::new(),
                next_seq: 0,
                closed: false,
            }),
            available: Condvar::new(),
            capacity,
        }
    }

    /// Lock the queue state, recovering from poisoning: the state is a
    /// plain heap + id set — structurally valid even if a peer thread
    /// panicked while holding the lock — and the serving path must
    /// contain panics, not cascade them.
    fn locked(&self) -> std::sync::MutexGuard<'_, Inner> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    pub fn len(&self) -> usize {
        self.locked().heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn push(&self, req: Request) -> Result<(), SubmitError> {
        let mut g = self.locked();
        if g.closed {
            return Err(SubmitError::Closed);
        }
        if g.heap.len() >= self.capacity {
            return Err(SubmitError::Full);
        }
        if !g.ids.insert(req.id) {
            return Err(SubmitError::DuplicateId);
        }
        let seq = g.next_seq;
        g.next_seq += 1;
        g.heap.push(Entry { priority: req.priority, seq, req });
        drop(g);
        self.available.notify_one();
        Ok(())
    }

    /// Non-blocking pop of the highest-priority, oldest request.
    pub fn try_pop(&self) -> Option<Request> {
        let mut g = self.locked();
        let e = g.heap.pop()?;
        g.ids.remove(&e.req.id);
        Some(e.req)
    }

    /// Blocking pop; `None` once closed and drained.
    pub fn pop_wait(&self) -> Option<Request> {
        let mut g = self.locked();
        loop {
            if let Some(e) = g.heap.pop() {
                g.ids.remove(&e.req.id);
                return Some(e.req);
            }
            if g.closed {
                return None;
            }
            g = match self.available.wait(g) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }

    /// Remove a queued request by id (the cancellation path). O(n)
    /// heap rebuild — cancellations are rare next to pops. Returns
    /// `None` when the id is not queued (already admitted or unknown).
    pub fn remove(&self, id: u64) -> Option<Request> {
        let mut g = self.locked();
        if !g.ids.remove(&id) {
            return None;
        }
        let mut removed = None;
        let entries = std::mem::take(&mut g.heap).into_vec();
        g.heap = entries
            .into_iter()
            .filter_map(|e| {
                if e.req.id == id {
                    removed = Some(e.req);
                    None
                } else {
                    Some(e)
                }
            })
            .collect();
        removed
    }

    /// Remove every queued request whose deadline has passed as of
    /// `now` (the engine's per-tick expiry sweep — without it a
    /// saturated queue would hold expired requests until admission).
    /// Cheap O(n) scan when nothing expired; heap rebuild otherwise.
    pub fn remove_expired(&self, now: Instant) -> Vec<Request> {
        let is_expired = |req: &Request| {
            req.deadline.is_some_and(|d| now.duration_since(req.arrived) >= d)
        };
        let mut g = self.locked();
        if !g.heap.iter().any(|e| is_expired(&e.req)) {
            return Vec::new();
        }
        let entries = std::mem::take(&mut g.heap).into_vec();
        let (expired, keep): (Vec<Entry>, Vec<Entry>) =
            entries.into_iter().partition(|e| is_expired(&e.req));
        g.heap = keep.into_iter().collect();
        let mut out = Vec::with_capacity(expired.len());
        for e in expired {
            g.ids.remove(&e.req.id);
            out.push(e.req);
        }
        out
    }

    /// Close the queue: pending items still drain, new pushes fail.
    pub fn close(&self) {
        self.locked().closed = true;
        self.available.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.locked().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::time::now;

    fn req(id: u64, prio: u8) -> Request {
        Request::new(id, vec![1], 4).with_priority(prio)
    }

    #[test]
    fn fifo_within_priority() {
        let q = RequestQueue::new(16);
        for id in 0..5 {
            q.push(req(id, 0)).unwrap();
        }
        for id in 0..5 {
            assert_eq!(q.try_pop().unwrap().id, id);
        }
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn priority_classes_pop_first() {
        let q = RequestQueue::new(16);
        q.push(req(1, 2)).unwrap();
        q.push(req(2, 0)).unwrap();
        q.push(req(3, 1)).unwrap();
        q.push(req(4, 0)).unwrap();
        let order: Vec<u64> = std::iter::from_fn(|| q.try_pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![2, 4, 3, 1]);
    }

    #[test]
    fn bounded_and_duplicate_rejection() {
        let q = RequestQueue::new(2);
        q.push(req(1, 0)).unwrap();
        assert_eq!(q.push(req(1, 0)), Err(SubmitError::DuplicateId));
        q.push(req(2, 0)).unwrap();
        assert_eq!(q.push(req(3, 0)), Err(SubmitError::Full));
        q.try_pop().unwrap();
        q.push(req(3, 0)).unwrap(); // id freed after pop? no — id 1 popped, 3 is new
    }

    #[test]
    fn remove_cancels_queued_requests_only() {
        let q = RequestQueue::new(16);
        for id in 0..5 {
            q.push(req(id, (id % 2) as u8)).unwrap();
        }
        let r = q.remove(3).expect("id 3 is queued");
        assert_eq!(r.id, 3);
        assert!(q.remove(3).is_none(), "already removed");
        assert!(q.remove(99).is_none(), "never queued");
        // remaining order is unchanged: priority class, then FIFO
        let order: Vec<u64> = std::iter::from_fn(|| q.try_pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![0, 2, 4, 1]);
        // removed id is free for resubmission
        q.push(req(3, 0)).unwrap();
        assert_eq!(q.try_pop().unwrap().id, 3);
    }

    #[test]
    fn remove_expired_sweeps_only_past_deadline() {
        let q = RequestQueue::new(16);
        q.push(req(1, 0)).unwrap();
        q.push(req(2, 0).with_deadline(std::time::Duration::ZERO)).unwrap();
        q.push(req(3, 1).with_deadline(std::time::Duration::from_secs(3600))).unwrap();
        let expired = q.remove_expired(now());
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].id, 2);
        assert_eq!(q.len(), 2);
        // no-deadline and far-future requests survive, order preserved
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert_eq!(q.try_pop().unwrap().id, 3);
        // swept id is free for reuse
        q.push(req(2, 0)).unwrap();
        assert!(q.remove_expired(now()).is_empty());
    }

    #[test]
    fn close_semantics() {
        let q = RequestQueue::new(4);
        q.push(req(1, 0)).unwrap();
        q.close();
        assert_eq!(q.push(req(2, 0)), Err(SubmitError::Closed));
        assert_eq!(q.pop_wait().unwrap().id, 1);
        assert!(q.pop_wait().is_none());
    }

    #[test]
    fn pop_wait_wakes_on_push() {
        let q = std::sync::Arc::new(RequestQueue::new(4));
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_wait().map(|r| r.id));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.push(req(9, 0)).unwrap();
        assert_eq!(h.join().unwrap(), Some(9));
    }
}
