//! Hand-rolled CLI (no `clap` offline). Subcommands:
//!
//! ```text
//! gptqt quantize  --model <name> --method <rtn|gptq|bcq|gptqt> --bits <2|3|4> ...
//! gptqt serve     --model <name> [--quant gptqt3] [--requests N] ...
//! gptqt ppl       --model <name> --dataset <wiki-syn|ptb-syn> ...
//! gptqt exp       <table1|table2|table3|table4|table5|table6|fig4|all>
//! gptqt help
//! ```

use std::collections::HashMap;

/// Parsed arguments: positional values plus `--key value` / `--flag` pairs.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse a raw argv tail. `--key value` pairs become options unless the
    /// next token also starts with `--`, in which case `--key` is a flag.
    pub fn parse(argv: &[String]) -> Args {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(key) = tok.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    args.options.insert(key.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        args
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

const HELP: &str = "\
gptqt — GPTQT: Quantize Large Language Models Twice (reproduction)

USAGE:
    gptqt <COMMAND> [OPTIONS]

COMMANDS:
    quantize   Quantize a model's weights with a chosen method
               --model <name>           model preset (see `gptqt models`)
               --method <m>             rtn|gptq|gptq-minmse|bcq|gptq-bcq|gptqt
               --bits <n>               final bit-width (default 3)
               --step1-bits <n>         GPTQT intermediate bits (default 5)
               --explore-range <n>      GPTQT scale re-exploration range (default 1)
               --seed <n>               rng seed (default 0)
    ppl        Evaluate perplexity of a (quantized) model. Quantized
               methods run through the serving kernels (LUT/dequant)
               end-to-end; --dequant evaluates the dequantized dense
               weights instead (legacy path)
               --model <name> --dataset <wiki-syn|ptb-syn> --method <m> --bits <n>
               --numerics <exact|fast>  kernel numerics tier (default exact)
    serve      Serve requests through the streaming session server
               --model <name> --quant <fp32|gptq2|gptqt3> --requests <n>
               --max-batch <n> --prompt-len <n> --gen-len <n>
               --backend <cpu|pjrt> --policy <fixed|adaptive>
               --numerics <exact|fast>  kernel numerics tier (default exact)
               --speculative            self-speculative decoding: a cheap
                                        draft model proposes, the served
                                        target verifies (cpu backend only;
                                        greedy output is token-identical)
               --spec-k <n>             draft tokens per round (default 4)
               --draft <lut2|lut3|dense> draft weight format (default lut2)
               --greedy                 greedy sampling (speculation engages
                                        on greedy sequences)
    exp        Reproduce a paper experiment:
               table1|table2|table3|table4|table5|table6|fig4|all
    gen-corpus Write synthetic training corpora to artifacts/ (build step
               consumed by python/compile/train.py)
               --out-dir <dir> --tokens <n> --seed <n>
    models     List model presets
    help       Show this message

Artifacts are expected under ./artifacts (run `make artifacts` first).
";

/// Run the CLI; returns the process exit code.
pub fn run(argv: &[String]) -> i32 {
    if argv.is_empty() {
        print!("{HELP}");
        return 2;
    }
    let cmd = argv[0].as_str();
    let args = Args::parse(&argv[1..]);
    let result = match cmd {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "models" => {
            for preset in crate::model::presets::all() {
                println!(
                    "{:<14} layers={:<2} d={:<4} heads={:<2} params≈{}",
                    preset.name,
                    preset.layers,
                    preset.d_model,
                    preset.heads,
                    crate::model::fmt_params(preset.param_count())
                );
            }
            Ok(())
        }
        "quantize" => crate::eval::cmd::quantize(&args),
        "ppl" => crate::eval::cmd::ppl(&args),
        "serve" => crate::eval::cmd::serve(&args),
        "exp" => crate::eval::cmd::experiment(&args),
        "gen-corpus" => crate::eval::cmd::gen_corpus(&args),
        other => {
            eprintln!("unknown command `{other}`; see `gptqt help`");
            return 2;
        }
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_options_and_flags() {
        let a = Args::parse(&sv(&["table1", "--bits", "3", "--fast", "--model=opt-sm"]));
        assert_eq!(a.positional, vec!["table1"]);
        assert_eq!(a.get("bits"), Some("3"));
        assert_eq!(a.get("model"), Some("opt-sm"));
        assert!(a.has_flag("fast"));
        assert_eq!(a.get_usize("bits", 0), 3);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn flag_before_option() {
        let a = Args::parse(&sv(&["--verbose", "--seed", "42"]));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get_u64("seed", 0), 42);
    }

    #[test]
    fn help_exits_ok() {
        assert_eq!(run(&sv(&["help"])), 0);
    }

    #[test]
    fn unknown_command_exits_2() {
        assert_eq!(run(&sv(&["frobnicate"])), 2);
    }
}
