//! Artifact metadata shared by the real PJRT runtime and the stub.

use anyhow::{Context, Result};
use std::path::Path;

/// Metadata written by `python -m compile.aot` next to the HLO files.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub model: String,
    pub seq: usize,
    pub kv_len: usize,
    pub pallas: bool,
    pub weights: usize,
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> Result<ArtifactMeta> {
        let mut map = std::collections::HashMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                map.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        let get = |k: &str| {
            map.get(k)
                .with_context(|| format!("meta missing key `{k}`"))
                .cloned()
        };
        Ok(ArtifactMeta {
            model: get("model")?,
            seq: get("seq")?.parse()?,
            kv_len: get("kv_len")?.parse()?,
            pallas: get("pallas")? == "1",
            weights: get("weights")?.parse()?,
        })
    }

    pub fn load(path: &Path) -> Result<ArtifactMeta> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses() {
        let m = ArtifactMeta::parse(
            "model=opt-nano\nseq=128\nkv_len=64\npallas=1\nweights=24\n",
        )
        .unwrap();
        assert_eq!(m.model, "opt-nano");
        assert_eq!(m.seq, 128);
        assert_eq!(m.kv_len, 64);
        assert!(m.pallas);
        assert_eq!(m.weights, 24);
    }

    #[test]
    fn meta_rejects_missing_keys() {
        assert!(ArtifactMeta::parse("model=x\n").is_err());
    }
}
