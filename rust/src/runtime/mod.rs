//! PJRT runtime — loads the AOT-compiled HLO artifacts (L2 output) and
//! executes them from the rust request path. Python never runs here.
//!
//! Flow: `HloModuleProto::from_text_file` (HLO *text* — the interchange
//! format xla_extension 0.5.1 accepts, see DESIGN.md §3) →
//! `XlaComputation::from_proto` → `PjRtClient::cpu().compile` →
//! `execute_b` with device-resident buffers. Weights are uploaded once
//! per model; KV caches live on the device and round-trip as buffers
//! between decode steps.
//!
//! The XLA bindings are only present when the crate is built with the
//! `xla-runtime` feature (they need the `xla` crate + libxla_extension,
//! which the hermetic offline build does not carry). Without it, [`stub`]
//! provides the same types with a runtime error on construction, so the
//! engine, CLI, and tests compile either way — including under
//! `--features pjrt` alone, which selects the PJRT API surface with the
//! stub backing it (the CI feature-matrix builds exactly that).

pub mod meta;

// The gated implementation below references the `xla` bindings crate,
// which is not vendored in the offline build and therefore not declared
// in Cargo.toml. Fail with instructions instead of a wall of E0433s.
#[cfg(feature = "xla-runtime")]
compile_error!(
    "the `xla-runtime` feature additionally requires the `xla` bindings \
     crate (xla_extension 0.5.1 ABI) plus a libxla_extension install: \
     add `xla = ...` to [dependencies] in rust/Cargo.toml and remove \
     this guard in rust/src/runtime/mod.rs"
);

#[cfg(feature = "xla-runtime")]
pub mod compiled;
#[cfg(not(feature = "xla-runtime"))]
pub mod stub;

pub use meta::ArtifactMeta;

#[cfg(feature = "xla-runtime")]
pub use compiled::{CompiledModel, DeviceKv};
#[cfg(not(feature = "xla-runtime"))]
pub use stub::{CompiledModel, DeviceKv, Runtime};

#[cfg(feature = "xla-runtime")]
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Shared PJRT client (CPU platform).
#[cfg(feature = "xla-runtime")]
pub struct Runtime {
    pub client: xla::PjRtClient,
}

#[cfg(feature = "xla-runtime")]
impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact file.
    pub fn compile_artifact(&self, path: impl AsRef<Path>) -> Result<xla::PjRtLoadedExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))
    }

    /// Load a model's full artifact set (logits + decode executables,
    /// metadata, weights uploaded to the device).
    pub fn load_model(
        &self,
        artifacts_dir: impl AsRef<Path>,
        model: &crate::model::Model,
    ) -> Result<CompiledModel> {
        CompiledModel::load(self, artifacts_dir.as_ref(), model)
    }
}

/// Path of an artifact kind for a model name.
pub fn artifact_path(dir: &Path, name: &str, kind: &str) -> PathBuf {
    dir.join(format!("{name}.{kind}.hlo.txt"))
}

/// True if the full artifact set for `name` exists under `dir` — used by
/// tests and examples to skip gracefully before `make artifacts` has run.
pub fn artifacts_present(dir: impl AsRef<Path>, name: &str) -> bool {
    let dir = dir.as_ref();
    artifact_path(dir, name, "logits").exists()
        && artifact_path(dir, name, "decode").exists()
        && dir.join(format!("{name}.meta.txt")).exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_paths() {
        let p = artifact_path(Path::new("artifacts"), "opt-nano", "logits");
        assert_eq!(p.to_str().unwrap(), "artifacts/opt-nano.logits.hlo.txt");
    }

    #[test]
    fn artifacts_present_false_for_missing() {
        assert!(!artifacts_present("/definitely/not/here", "opt-nano"));
    }
}
