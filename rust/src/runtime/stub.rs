//! API-compatible stand-ins for the PJRT runtime, compiled when the
//! `xla-runtime` feature is off (the default in the hermetic offline
//! build — including under `--features pjrt` alone, which the CI
//! feature-matrix job builds and tests).
//!
//! The real implementation in `compiled.rs` needs the `xla` bindings
//! crate and a libxla_extension install. This stub keeps every caller —
//! the coordinator's `PjrtBackend`, the CLI `serve --backend pjrt`
//! path, and the `hlo_parity` integration tests — type-checking
//! without them. [`Runtime::cpu`] fails with an explanatory error, and
//! since that is the only way to obtain a [`CompiledModel`], the other
//! methods are unreachable at runtime.

use super::ArtifactMeta;
use crate::model::Model;
use crate::tensor::Tensor;
use anyhow::Result;
use std::path::Path;

fn unavailable() -> anyhow::Error {
    anyhow::anyhow!(
        "PJRT runtime unavailable: gptqt was built without the \
         `xla-runtime` feature that backs the pjrt path (requires the \
         `xla` bindings crate + libxla_extension)"
    )
}

/// Stub PJRT client — construction always fails.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    /// Always errors in the stub build.
    pub fn cpu() -> Result<Runtime> {
        Err(unavailable())
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn load_model(
        &self,
        _artifacts_dir: impl AsRef<Path>,
        _model: &Model,
    ) -> Result<CompiledModel> {
        Err(unavailable())
    }
}

/// Stub device KV cache (never instantiated).
pub struct DeviceKv {
    pub len: usize,
    pub capacity: usize,
}

/// Stub compiled model (never instantiated — `Runtime::cpu` fails first).
pub struct CompiledModel {
    pub meta: ArtifactMeta,
    _private: (),
}

impl CompiledModel {
    /// Max tokens one sequence may occupy on the device (the
    /// coordinator's `Backend::capacity`).
    pub fn kv_capacity(&self) -> usize {
        self.meta.kv_len
    }

    pub fn new_kv(&self) -> Result<DeviceKv> {
        Err(unavailable())
    }

    pub fn logits(&self, _tokens: &[u32]) -> Result<Tensor> {
        Err(unavailable())
    }

    pub fn decode(&self, _kv: &mut DeviceKv, _token: u32) -> Result<Vec<f32>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_cpu_reports_missing_feature() {
        let err = Runtime::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
