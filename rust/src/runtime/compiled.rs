//! A compiled model: executables + device-resident weights + KV buffers.
//! Only built with the `pjrt` feature (needs the `xla` bindings crate).

use super::{ArtifactMeta, Runtime};
use crate::model::Model;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Device-resident KV caches for one sequence (round-trip between decode
/// steps as buffers — never copied to host).
pub struct DeviceKv {
    pub k: xla::PjRtBuffer,
    pub v: xla::PjRtBuffer,
    pub len: usize,
    pub capacity: usize,
}

/// A model compiled onto the PJRT device with weights uploaded once.
pub struct CompiledModel {
    pub meta: ArtifactMeta,
    logits_exec: xla::PjRtLoadedExecutable,
    decode_exec: xla::PjRtLoadedExecutable,
    weight_bufs: Vec<xla::PjRtBuffer>,
    client: xla::PjRtClient,
    layers: usize,
    d_model: usize,
    vocab: usize,
}

impl CompiledModel {
    pub fn load(rt: &Runtime, dir: &Path, model: &Model) -> Result<CompiledModel> {
        let name = model.cfg.name;
        let meta = ArtifactMeta::load(&dir.join(format!("{name}.meta.txt")))?;
        if meta.weights != model.cfg.weight_order().len() {
            bail!(
                "artifact ABI mismatch: meta says {} weights, config has {}",
                meta.weights,
                model.cfg.weight_order().len()
            );
        }
        let logits_exec = rt.compile_artifact(super::artifact_path(dir, name, "logits"))?;
        let decode_exec = rt.compile_artifact(super::artifact_path(dir, name, "decode"))?;

        // upload weights once, in ABI order
        let mut weight_bufs = Vec::new();
        for wname in model.cfg.weight_order() {
            let t = model.weights.expect(&wname);
            let buf = rt
                .client
                .buffer_from_host_buffer(t.data(), &[t.rows(), t.cols()], None)
                .with_context(|| format!("upload {wname}"))?;
            weight_bufs.push(buf);
        }
        Ok(CompiledModel {
            meta,
            logits_exec,
            decode_exec,
            weight_bufs,
            client: rt.client.clone(),
            layers: model.cfg.layers,
            d_model: model.cfg.d_model,
            vocab: model.cfg.vocab,
        })
    }

    /// Max tokens one sequence may occupy on the device (the
    /// coordinator's `Backend::capacity`).
    pub fn kv_capacity(&self) -> usize {
        self.meta.kv_len
    }

    /// Replace the device weights (e.g. after quantization) — same ABI.
    pub fn upload_weights(&mut self, model: &Model) -> Result<()> {
        let mut bufs = Vec::new();
        for wname in model.cfg.weight_order() {
            let t = model.weights.expect(&wname);
            bufs.push(
                self.client
                    .buffer_from_host_buffer(t.data(), &[t.rows(), t.cols()], None)?,
            );
        }
        self.weight_bufs = bufs;
        Ok(())
    }

    /// Fresh device KV cache.
    pub fn new_kv(&self) -> Result<DeviceKv> {
        let zeros = vec![0.0f32; self.layers * self.meta.kv_len * self.d_model];
        let dims = [self.layers, self.meta.kv_len, self.d_model];
        Ok(DeviceKv {
            k: self.client.buffer_from_host_buffer(&zeros, &dims, None)?,
            v: self.client.buffer_from_host_buffer(&zeros, &dims, None)?,
            len: 0,
            capacity: self.meta.kv_len,
        })
    }

    /// Unwrap an execute result that may come back as one tuple buffer or
    /// as N separate buffers, into N literals.
    fn untuple(outputs: Vec<Vec<xla::PjRtBuffer>>, n: usize) -> Result<Vec<xla::Literal>> {
        let mut outs = outputs.into_iter().next().context("no output device")?;
        if outs.len() == 1 {
            // may be a 1-tuple (return_tuple=True lowering) — peel it
            let lit = outs.remove(0).to_literal_sync()?;
            let parts = if lit.shape()?.is_tuple() { lit.to_tuple()? } else { vec![lit] };
            if parts.len() == n {
                return Ok(parts);
            }
            bail!("expected {n} outputs, got {}", parts.len());
        }
        if outs.len() == n {
            return outs.iter().map(|b| Ok(b.to_literal_sync()?)).collect();
        }
        bail!("expected {n} outputs, got {}", outs.len());
    }

    /// Full-window logits: `tokens.len()` must equal `meta.seq`.
    pub fn logits(&self, tokens: &[u32]) -> Result<Tensor> {
        if tokens.len() != self.meta.seq {
            bail!("logits artifact takes exactly {} tokens, got {}", self.meta.seq, tokens.len());
        }
        let toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let tok_buf = self
            .client
            .buffer_from_host_buffer(&toks, &[toks.len()], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&tok_buf);
        let outputs = self.logits_exec.execute_b(&args)?;
        let lit = Self::untuple(outputs, 1)?.remove(0);
        let data = lit.to_vec::<f32>()?;
        Ok(Tensor::from_vec(self.meta.seq, self.vocab, data))
    }

    /// One decode step: consumes `token` at `kv.len`, returns next-token
    /// logits; KV buffers stay on device.
    pub fn decode(&self, kv: &mut DeviceKv, token: u32) -> Result<Vec<f32>> {
        if kv.len >= kv.capacity {
            bail!("device KV cache full ({} tokens)", kv.capacity);
        }
        let tok = self
            .client
            .buffer_from_host_buffer(&[token as i32], &[], None)?;
        let pos = self
            .client
            .buffer_from_host_buffer(&[kv.len as i32], &[], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&kv.k);
        args.push(&kv.v);
        args.push(&tok);
        args.push(&pos);
        let mut outs = self
            .decode_exec
            .execute_b(&args)?
            .into_iter()
            .next()
            .context("no output device")?;
        if outs.len() == 3 {
            // buffers stay on device: swap KV in place
            let logits = outs[0].to_literal_sync()?.to_vec::<f32>()?;
            kv.v = outs.remove(2);
            kv.k = outs.remove(1);
            kv.len += 1;
            Ok(logits)
        } else if outs.len() == 1 {
            // tuple output: must round-trip via literal
            let lit = outs.remove(0).to_literal_sync()?;
            let parts = lit.to_tuple()?;
            anyhow::ensure!(parts.len() == 3, "decode expected 3 outputs");
            let mut it = parts.into_iter();
            let logits = it.next().unwrap().to_vec::<f32>()?;
            let k = it.next().unwrap();
            let v = it.next().unwrap();
            let dims = [self.layers, self.meta.kv_len, self.d_model];
            kv.k = self
                .client
                .buffer_from_host_buffer(&k.to_vec::<f32>()?, &dims, None)?;
            kv.v = self
                .client
                .buffer_from_host_buffer(&v.to_vec::<f32>()?, &dims, None)?;
            kv.len += 1;
            Ok(logits)
        } else {
            bail!("decode returned {} buffers", outs.len());
        }
    }
}
