//! Reference f32 forward pass for all three model families.
//!
//! This path exists for three jobs:
//! 1. **Calibration** — `block_forward` exposes per-linear input hooks so
//!    the quantization driver can accumulate GPTQ Hessians block by block
//!    (activations flow through the *already quantized* earlier blocks,
//!    exactly like the GPTQ reference implementation).
//! 2. **Perplexity evaluation** — the Tables I/II/III ladders run through
//!    `nll_window`.
//! 3. **Numerics oracle** — integration tests check the AOT-compiled XLA
//!    executables (Layer 2) against this implementation.
//!
//! Since the chunk-major refactor, `Model::forward` (and with it
//! `nll_window`) is the degenerate full-sequence case of the KV-cache
//! forward core in [`super::decode`] — one code path serves decode,
//! prefill, and evaluation. Only the hooked block-by-block form below
//! remains a separate implementation, because calibration needs
//! whole-window activation matrices fed to each linear.
//!
//! Every op matches the JAX model in `python/compile/model.py` exactly
//! (same GELU tanh approximation, same RoPE pairing, same ALiBi slopes,
//! same ε) so HLO-vs-rust diffs stay at f32 round-off level.

use super::config::{Family, ModelConfig};
use super::weights::WeightStore;
use crate::tensor::Tensor;

pub const LN_EPS: f32 = 1e-5;

// The scalar activation functions are canonical in the kernel layer
// (the forward core's elementwise loops dispatch through
// `kernels::simd`); re-exported here so calibration and model code keep
// their historical paths.
pub use crate::kernels::simd::{gelu, silu};

/// Row-wise LayerNorm with weight+bias.
pub fn layernorm(x: &Tensor, w: &[f32], b: &[f32]) -> Tensor {
    let d = x.cols();
    assert_eq!(w.len(), d);
    assert_eq!(b.len(), d);
    let mut out = x.clone();
    for r in 0..x.rows() {
        let row = out.row_mut(r);
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * w[i] + b[i];
        }
    }
    out
}

/// Row-wise RMSNorm with weight.
pub fn rmsnorm(x: &Tensor, w: &[f32]) -> Tensor {
    let d = x.cols();
    assert_eq!(w.len(), d);
    let mut out = x.clone();
    for r in 0..x.rows() {
        let row = out.row_mut(r);
        let ms = row.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + LN_EPS).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = *v * inv * w[i];
        }
    }
    out
}

/// In-place numerically stable softmax over a slice.
///
/// This is the `Exact`-tier reference: libm `exp`, sequential
/// accumulation — bitwise reproducible. The `Fast` numerics tier
/// replaces it with `kernels::fast_math::softmax_fast` (vectorized
/// polynomial exp, pinned 8-lane sum) under the relaxed tolerance
/// contract.
pub fn softmax(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// Apply rotary position embedding in place to a (T × d_model) tensor
/// laid out head-major, starting at absolute position `start_pos`.
/// Pairing convention: `(x[2i], x[2i+1])` within each head.
pub fn rope(x: &mut Tensor, heads: usize, start_pos: usize) {
    let d = x.cols();
    let dh = d / heads;
    let half = dh / 2;
    for t in 0..x.rows() {
        let pos = (start_pos + t) as f32;
        let row = x.row_mut(t);
        for h in 0..heads {
            let base = h * dh;
            for i in 0..half {
                let theta = pos * 10000f32.powf(-2.0 * i as f32 / dh as f32);
                let (sin, cos) = theta.sin_cos();
                let a = row[base + 2 * i];
                let b = row[base + 2 * i + 1];
                row[base + 2 * i] = a * cos - b * sin;
                row[base + 2 * i + 1] = a * sin + b * cos;
            }
        }
    }
}

/// ALiBi head slopes `m_h = 2^(−8(h+1)/H)` (Bloom).
pub fn alibi_slopes(heads: usize) -> Vec<f32> {
    (0..heads)
        .map(|h| 2f32.powf(-8.0 * (h as f32 + 1.0) / heads as f32))
        .collect()
}

/// Hook invoked with the input matrix of each quantizable linear layer.
pub type LinearHook<'a> = &'a mut dyn FnMut(&str, &Tensor);

/// A model = config + weights, with the reference forward pass.
pub struct Model {
    pub cfg: ModelConfig,
    pub weights: WeightStore,
}

impl Model {
    pub fn new(cfg: ModelConfig, weights: WeightStore) -> Model {
        Model { cfg, weights }
    }

    fn linear(&self, name: &str, x: &Tensor, hook: &mut Option<LinearHook>) -> Tensor {
        if let Some(h) = hook.as_mut() {
            h(name, x);
        }
        x.matmul_nt(self.weights.expect(name))
    }

    /// Token + position embedding for a window starting at `start_pos`.
    pub fn embed(&self, tokens: &[u32], start_pos: usize) -> Tensor {
        let d = self.cfg.d_model;
        let tok = self.weights.expect("tok_emb");
        let mut x = Tensor::zeros(tokens.len(), d);
        for (t, &id) in tokens.iter().enumerate() {
            x.row_mut(t).copy_from_slice(tok.row(id as usize % self.cfg.vocab));
        }
        if self.cfg.family == Family::Opt {
            let pos = self.weights.expect("pos_emb");
            for t in 0..tokens.len() {
                let p = (start_pos + t) % self.cfg.max_seq;
                for (v, &pv) in x.row_mut(t).iter_mut().zip(pos.row(p)) {
                    *v += pv;
                }
            }
        }
        x
    }

    fn norm1(&self, i: usize, x: &Tensor) -> Tensor {
        match self.cfg.family {
            Family::Llama => rmsnorm(x, self.weights.expect(&format!("L{i}.ln1.w")).data()),
            _ => layernorm(
                x,
                self.weights.expect(&format!("L{i}.ln1.w")).data(),
                self.weights.expect(&format!("L{i}.ln1.b")).data(),
            ),
        }
    }

    fn norm2(&self, i: usize, x: &Tensor) -> Tensor {
        match self.cfg.family {
            Family::Llama => rmsnorm(x, self.weights.expect(&format!("L{i}.ln2.w")).data()),
            _ => layernorm(
                x,
                self.weights.expect(&format!("L{i}.ln2.w")).data(),
                self.weights.expect(&format!("L{i}.ln2.b")).data(),
            ),
        }
    }

    /// Multi-head causal self-attention over a full window (training-style
    /// square attention, batch 1).
    fn attention(
        &self,
        i: usize,
        h: &Tensor,
        start_pos: usize,
        hook: &mut Option<LinearHook>,
    ) -> Tensor {
        let cfg = &self.cfg;
        let (tlen, d) = h.shape();
        let heads = cfg.heads;
        let dh = cfg.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();

        let mut hk = |name: String, x: &Tensor| {
            if let Some(cb) = hook.as_mut() {
                cb(&name, x);
            }
        };
        hk(format!("L{i}.attn.q"), h);
        hk(format!("L{i}.attn.k"), h);
        hk(format!("L{i}.attn.v"), h);
        let mut q = h.matmul_nt(self.weights.expect(&format!("L{i}.attn.q")));
        let mut k = h.matmul_nt(self.weights.expect(&format!("L{i}.attn.k")));
        let v = h.matmul_nt(self.weights.expect(&format!("L{i}.attn.v")));

        if cfg.family == Family::Llama {
            rope(&mut q, heads, start_pos);
            rope(&mut k, heads, start_pos);
        }
        let slopes = if cfg.family == Family::Bloom {
            alibi_slopes(heads)
        } else {
            vec![0.0; heads]
        };

        let mut ctx = Tensor::zeros(tlen, d);
        let mut scores = vec![0.0f32; tlen];
        for head in 0..heads {
            let base = head * dh;
            let slope = slopes[head];
            for t in 0..tlen {
                let qrow = &q.row(t)[base..base + dh];
                for (j, s) in scores[..=t].iter_mut().enumerate() {
                    let krow = &k.row(j)[base..base + dh];
                    let mut dot = 0.0f32;
                    for (a, b) in qrow.iter().zip(krow) {
                        dot += a * b;
                    }
                    // ALiBi bias: slope·(j − i) ≤ 0 for the past
                    *s = dot * scale + slope * (j as f32 - t as f32);
                }
                softmax(&mut scores[..=t]);
                let out = &mut ctx.row_mut(t)[base..base + dh];
                for (j, &p) in scores[..=t].iter().enumerate() {
                    let vrow = &v.row(j)[base..base + dh];
                    for (o, &vv) in out.iter_mut().zip(vrow) {
                        *o += p * vv;
                    }
                }
            }
        }
        hk(format!("L{i}.attn.o"), &ctx);
        ctx.matmul_nt(self.weights.expect(&format!("L{i}.attn.o")))
    }

    /// One transformer block: `x + attn(norm1(x))`, then `+ ffn(norm2(·))`.
    pub fn block_forward(
        &self,
        i: usize,
        x: &Tensor,
        start_pos: usize,
        mut hook: Option<LinearHook>,
    ) -> Tensor {
        let h = self.norm1(i, x);
        let attn = self.attention(i, &h, start_pos, &mut hook);
        let x1 = x.add(&attn);

        let h2 = self.norm2(i, &x1);
        let ff = match self.cfg.family {
            Family::Llama => {
                let gate = self.linear(&format!("L{i}.ff.gate"), &h2, &mut hook);
                let up = self.linear(&format!("L{i}.ff.up"), &h2, &mut hook);
                let mut act = gate;
                for (g, &u) in act.data_mut().iter_mut().zip(up.data()) {
                    *g = silu(*g) * u;
                }
                self.linear(&format!("L{i}.ff.down"), &act, &mut hook)
            }
            _ => {
                let up = self.linear(&format!("L{i}.ff.up"), &h2, &mut hook);
                let act = up.map(gelu);
                self.linear(&format!("L{i}.ff.down"), &act, &mut hook)
            }
        };
        x1.add(&ff)
    }

    /// Final norm + tied-embedding logits.
    pub fn logits(&self, x: &Tensor) -> Tensor {
        let xf = match self.cfg.family {
            Family::Llama => rmsnorm(x, self.weights.expect("final_ln.w").data()),
            _ => layernorm(
                x,
                self.weights.expect("final_ln.w").data(),
                self.weights.expect("final_ln.b").data(),
            ),
        };
        xf.matmul_nt(self.weights.expect("tok_emb"))
    }

    /// Full forward over a token window → (T × vocab) logits.
    ///
    /// Since the chunk-major refactor this is the degenerate
    /// single-chunk case of the KV-cache forward core: the whole window
    /// as one chunk of a dense [`super::BackendModel`] against an empty
    /// cache. Bit-identical to the old block-by-block implementation
    /// (same per-row ops, and the kernels pin `gemm == per-item gemv`),
    /// which survives as [`Model::forward_hooked`] for calibration.
    /// Windows are capped at `cfg.max_seq` (the KV-cache capacity).
    ///
    /// Builds a dense backend (one weight clone) per call — convenient
    /// for tests and one-shot forwards; anything calling in a loop
    /// should hold a [`super::BackendModel`] and use `forward_chunk` /
    /// `nll_window` directly (as `eval_ppl` does).
    pub fn forward(&self, tokens: &[u32]) -> Tensor {
        let bm = super::BackendModel::dense(self);
        let mut cache = super::KvCache::new(&self.cfg);
        bm.forward_chunk(tokens, &mut cache)
    }

    /// Forward with per-linear input hooks (calibration). Keeps the
    /// explicit block-by-block square-attention form: the quantization
    /// driver needs whole-window activation matrices per linear.
    pub fn forward_hooked(&self, tokens: &[u32], mut hook: Option<LinearHook>) -> Tensor {
        let mut x = self.embed(tokens, 0);
        for i in 0..self.cfg.layers {
            // reborrow the hook for each block
            let reborrowed: Option<LinearHook> = match hook {
                Some(ref mut h) => Some(&mut **h),
                None => None,
            };
            x = self.block_forward(i, &x, 0, reborrowed);
        }
        self.logits(&x)
    }

    /// Sum of next-token negative log-likelihoods over a window plus the
    /// number of predictions (for perplexity: `exp(Σnll / Σcount)`).
    /// Runs through [`Model::forward`], i.e. the same chunked core the
    /// quantized backends use — see `BackendModel::nll_window` for the
    /// quantized-kernel variant.
    pub fn nll_window(&self, tokens: &[u32]) -> (f64, usize) {
        if tokens.len() < 2 {
            return (0.0, 0);
        }
        let logits = self.forward(tokens);
        nll_from_logits(&logits, tokens)
    }
}

/// Compute `(Σ nll, count)` of teacher-forced next-token predictions from
/// a (T × vocab) logits matrix.
pub fn nll_from_logits(logits: &Tensor, tokens: &[u32]) -> (f64, usize) {
    let vocab = logits.cols();
    let mut total = 0.0f64;
    let mut count = 0usize;
    for t in 0..tokens.len() - 1 {
        let target = tokens[t + 1] as usize;
        debug_assert!(target < vocab);
        let row = logits.row(t);
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let sum_exp: f64 = row.iter().map(|&v| ((v as f64) - max).exp()).sum();
        let log_p = (row[target] as f64 - max) - sum_exp.ln();
        total -= log_p;
        count += 1;
    }
    (total, count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::random_weights;
    use crate::model::presets;
    use crate::util::Rng;

    fn tiny(family: Family) -> Model {
        let mut cfg = presets::by_name("opt-nano").unwrap();
        cfg.family = family;
        cfg.vocab = 64;
        cfg.max_seq = 32;
        let w = random_weights(&cfg, 11);
        Model::new(cfg, w)
    }

    #[test]
    fn gelu_matches_known_values() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
        assert!(gelu(10.0) > 9.99);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut v = vec![1.0, 2.0, 3.0, -100.0];
        softmax(&mut v);
        assert!((v.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(v[2] > v[1] && v[1] > v[0]);
    }

    #[test]
    fn layernorm_normalizes() {
        let x = Tensor::from_slice(1, 4, &[1.0, 2.0, 3.0, 4.0]);
        let out = layernorm(&x, &[1.0; 4], &[0.0; 4]);
        let mean: f32 = out.row(0).iter().sum::<f32>() / 4.0;
        let var: f32 = out.row(0).iter().map(|&v| v * v).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn rope_preserves_norm_and_pos0_identity() {
        let mut rng = Rng::new(500);
        let mut x = Tensor::randn(3, 16, 1.0, &mut rng);
        let orig = x.clone();
        rope(&mut x, 2, 0);
        // position 0 rotates by angle 0 → identity
        for (a, b) in x.row(0).iter().zip(orig.row(0)) {
            assert!((a - b).abs() < 1e-6);
        }
        // rotations preserve pairwise norms
        for t in 0..3 {
            let n1: f32 = x.row(t).iter().map(|v| v * v).sum();
            let n0: f32 = orig.row(t).iter().map(|v| v * v).sum();
            assert!((n1 - n0).abs() < 1e-3);
        }
    }

    #[test]
    fn alibi_slopes_decay() {
        let s = alibi_slopes(4);
        assert_eq!(s.len(), 4);
        for w in s.windows(2) {
            assert!(w[1] < w[0]);
        }
        assert!((s[3] - 2f32.powf(-8.0)).abs() < 1e-7);
    }

    #[test]
    fn forward_shapes_all_families() {
        for fam in [Family::Opt, Family::Llama, Family::Bloom] {
            let m = tiny(fam);
            let tokens: Vec<u32> = (0..10).map(|i| i % 64).collect();
            let logits = m.forward(&tokens);
            assert_eq!(logits.shape(), (10, 64), "{fam:?}");
            assert!(logits.data().iter().all(|v| v.is_finite()), "{fam:?}");
        }
    }

    #[test]
    fn causality_future_tokens_do_not_affect_past() {
        for fam in [Family::Opt, Family::Llama, Family::Bloom] {
            let m = tiny(fam);
            let a: Vec<u32> = vec![5, 6, 7, 8, 9, 10];
            let mut b = a.clone();
            b[5] = 63; // change the last token only
            let la = m.forward(&a);
            let lb = m.forward(&b);
            for t in 0..5 {
                for c in 0..64 {
                    assert!(
                        (la.get(t, c) - lb.get(t, c)).abs() < 1e-5,
                        "{fam:?} leaked future info at t={t}"
                    );
                }
            }
        }
    }

    #[test]
    fn hooks_fire_for_every_linear() {
        let m = tiny(Family::Llama);
        let mut seen = std::collections::HashSet::new();
        let tokens: Vec<u32> = (0..8).collect();
        let mut hook = |name: &str, x: &Tensor| {
            assert_eq!(x.rows(), 8);
            seen.insert(name.to_string());
        };
        m.forward_hooked(&tokens, Some(&mut hook));
        for (name, _, _) in m.cfg.all_linears() {
            assert!(seen.contains(&name), "hook missed {name}");
        }
    }

    #[test]
    fn nll_is_positive_and_finite() {
        let m = tiny(Family::Opt);
        let tokens: Vec<u32> = (0..16).map(|i| (i * 7) % 64).collect();
        let (nll, count) = m.nll_window(&tokens);
        assert_eq!(count, 15);
        assert!(nll > 0.0 && nll.is_finite());
        // random-init model ≈ uniform: nll/count ≈ ln(64)
        let per_tok = nll / count as f64;
        assert!(per_tok < 64f64.ln() * 3.0, "per-token nll absurd: {per_tok}");
    }

    #[test]
    fn block_forward_composes_to_forward() {
        let m = tiny(Family::Opt);
        let tokens: Vec<u32> = (0..12).collect();
        let mut x = m.embed(&tokens, 0);
        for i in 0..m.cfg.layers {
            x = m.block_forward(i, &x, 0, None);
        }
        let manual = m.logits(&x);
        let auto = m.forward(&tokens);
        assert!(manual.max_abs_diff(&auto) < 1e-6);
    }
}
