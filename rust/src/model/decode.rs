//! The chunk-major KV-cache forward core — the serving hot loop.
//!
//! Every linear layer is a [`Gemv`] backend, so the same code executes
//! the dense f32 model (`full`), the GPTQ int+dequant model, or the GPTQT
//! fused binary-coded model — Table IV's three contenders — with
//! identical math and different memory traffic.
//!
//! One private core, [`BackendModel::forward_core`], advances any mix of
//! per-sequence token chunks against their KV caches in a single pass
//! per layer: every linear runs one batched [`Gemv::gemm`] over **all**
//! chunk tokens of **all** sequences, so the weights stream once per
//! (linear, tick) instead of once per token per sequence. Everything
//! else is a thin view of that core:
//!
//! * single-token decode = B chunks of length 1 ([`BackendModel::decode_step`],
//!   [`BackendModel::decode_batch`]),
//! * chunked prefill = chunks of T prompt tokens ([`BackendModel::prefill`],
//!   [`BackendModel::prefill_batch`]),
//! * full-sequence evaluation = one chunk spanning the whole window
//!   against an empty cache ([`BackendModel::forward_chunk`],
//!   [`BackendModel::nll_window`] — and [`Model::forward`] delegates
//!   here too).
//!
//! Causality inside a chunk falls out of the iteration bound: the whole
//! chunk's K/V rows are appended first, then token at position `p`
//! attends over cache rows `0..=p` only. Per token the fp operation
//! order is identical to the sequential single-token loop (the kernels
//! pin `gemm == per-item gemv` bitwise), so chunked, batched, and
//! sequential execution all produce bit-identical logits.

use super::config::{Family, ModelConfig};
use super::forward::{alibi_slopes, gelu, silu, softmax, LN_EPS};
use super::weights::WeightStore;
use super::Model;
use crate::kernels::{DenseGemv, Gemv};
use crate::quant::QuantizedLayer;
use crate::tensor::Tensor;
use std::collections::HashMap;

/// Per-sequence attention cache: one (max_seq × d_model) K and V buffer
/// per layer, head-major like the forward pass.
pub struct KvCache {
    pub k: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub len: usize,
    max_seq: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache {
            k: (0..cfg.layers).map(|_| Tensor::zeros(cfg.max_seq, cfg.d_model)).collect(),
            v: (0..cfg.layers).map(|_| Tensor::zeros(cfg.max_seq, cfg.d_model)).collect(),
            len: 0,
            max_seq: cfg.max_seq,
        }
    }

    pub fn remaining(&self) -> usize {
        self.max_seq - self.len
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Bytes held by this cache (capacity, not fill level).
    pub fn bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(|t| t.len() * 4).sum()
    }
}

/// A model whose linears are pluggable compute backends.
pub struct BackendModel {
    pub cfg: ModelConfig,
    /// Norm + embedding parameters (never quantized).
    pub weights: WeightStore,
    linears: HashMap<String, Box<dyn Gemv>>,
}

impl BackendModel {
    /// Dense f32 backends straight from a [`Model`] (the `full` row).
    pub fn dense(model: &Model) -> BackendModel {
        let mut linears: HashMap<String, Box<dyn Gemv>> = HashMap::new();
        for (name, _, _) in model.cfg.all_linears() {
            linears.insert(
                name.clone(),
                Box::new(DenseGemv::new(model.weights.expect(&name).clone())),
            );
        }
        BackendModel { cfg: model.cfg.clone(), weights: model.weights.clone(), linears }
    }

    /// Backends from quantized layers: packed binary coding if present
    /// (GPTQT/BCQ → LUT-GEMM), else int weights (GPTQ → dequant), else
    /// the dense dequantized tensor.
    pub fn quantized(model: &Model, mut layers: HashMap<String, QuantizedLayer>) -> BackendModel {
        let mut linears: HashMap<String, Box<dyn Gemv>> = HashMap::new();
        for (name, _, _) in model.cfg.all_linears() {
            let backend: Box<dyn Gemv> = match layers.remove(&name) {
                Some(q) => {
                    if let Some(packed) = q.packed {
                        Box::new(packed)
                    } else if let Some(int) = q.int_weights {
                        Box::new(int)
                    } else {
                        Box::new(DenseGemv::new(q.dequant))
                    }
                }
                None => Box::new(DenseGemv::new(model.weights.expect(&name).clone())),
            };
            linears.insert(name, backend);
        }
        BackendModel { cfg: model.cfg.clone(), weights: model.weights.clone(), linears }
    }

    /// Batched linear: one weight stream serves every sequence in the
    /// batch (see [`crate::kernels::Gemv::gemm`]). Batch 1 (the
    /// [`BackendModel::decode_step`] path) hits each backend's `gemm`,
    /// which is bitwise-identical to its `gemv`.
    fn gemm(&self, name: &str, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        let b = self
            .linears
            .get(name)
            .unwrap_or_else(|| panic!("no backend for {name}"));
        let mut ys: Vec<Vec<f32>> = (0..xs.len()).map(|_| vec![0.0f32; b.rows()]).collect();
        b.gemm(xs, &mut ys);
        ys
    }

    /// Total weight bytes streamed per decoded token — the bandwidth
    /// model behind Table IV (embeddings excluded: shared by all rows).
    pub fn streamed_bytes_per_token(&self) -> usize {
        self.linears.values().map(|b| b.streamed_bytes()).sum()
    }

    /// Label of the dominant backend (for reports).
    pub fn backend_label(&self) -> &'static str {
        self.linears
            .values()
            .next()
            .map(|b| b.label())
            .unwrap_or("empty")
    }

    fn norm(&self, prefix: &str, x: &[f32]) -> Vec<f32> {
        let d = x.len();
        let w = self.weights.expect(&format!("{prefix}.w"));
        match self.cfg.family {
            Family::Llama => {
                let ms = x.iter().map(|&v| v * v).sum::<f32>() / d as f32;
                let inv = 1.0 / (ms + LN_EPS).sqrt();
                x.iter().zip(w.data()).map(|(&v, &wi)| v * inv * wi).collect()
            }
            _ => {
                let b = self.weights.expect(&format!("{prefix}.b"));
                let mean = x.iter().sum::<f32>() / d as f32;
                let var = x.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
                let inv = 1.0 / (var + LN_EPS).sqrt();
                x.iter()
                    .zip(w.data().iter().zip(b.data()))
                    .map(|(&v, (&wi, &bi))| (v - mean) * inv * wi + bi)
                    .collect()
            }
        }
    }

    /// Embed a single token at absolute position `pos`.
    pub fn embed_one(&self, token: u32, pos: usize) -> Vec<f32> {
        let tok = self.weights.expect("tok_emb");
        let mut x = tok.row(token as usize % self.cfg.vocab).to_vec();
        if self.cfg.family == Family::Opt {
            let pemb = self.weights.expect("pos_emb");
            for (v, &p) in x.iter_mut().zip(pemb.row(pos % self.cfg.max_seq)) {
                *v += p;
            }
        }
        x
    }

    /// Run one decode step: consume `token` at position `cache.len`,
    /// append K/V, return the next-token logits.
    ///
    /// Implemented as [`BackendModel::decode_batch_refs`] at batch 1 —
    /// one shared transformer step means batched and sequential decode
    /// cannot drift apart (the engine's token-parity guarantee holds by
    /// construction), and `gemm(B=1)` is pinned bitwise-identical to
    /// `gemv` in the kernel layer.
    pub fn decode_step(&self, token: u32, cache: &mut KvCache) -> Vec<f32> {
        let mut caches = [cache];
        self.decode_batch_refs(&[token], &mut caches)
            .pop()
            .expect("decode_batch_refs returns one logits vector per sequence")
    }

    /// One decode step for a batch of independent sequences:
    /// `tokens[b]` is consumed at position `caches[b].len`, each cache
    /// gets its K/V appended, and the per-sequence next-token logits are
    /// returned in batch order.
    ///
    /// Every linear layer runs through the batched [`Gemv::gemm`]
    /// kernels, so the weights are streamed once per *batch* instead of
    /// once per sequence — the amortization a multi-tenant server needs.
    /// Sequences may sit at different positions. Per sequence the fp
    /// arithmetic is identical to [`BackendModel::decode_step`], so
    /// greedy generation is token-identical to a sequential loop.
    pub fn decode_batch(&self, tokens: &[u32], caches: &mut [KvCache]) -> Vec<Vec<f32>> {
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        self.decode_batch_refs(tokens, &mut refs)
    }

    /// [`BackendModel::decode_batch`] over borrowed caches — the form
    /// the engine uses when the caches live inside its running set.
    /// The degenerate all-chunks-of-length-1 case of the forward core.
    pub fn decode_batch_refs(
        &self,
        tokens: &[u32],
        caches: &mut [&mut KvCache],
    ) -> Vec<Vec<f32>> {
        let chunks: Vec<&[u32]> = tokens.iter().map(std::slice::from_ref).collect();
        self.forward_chunks_refs(&chunks, caches)
    }

    /// Advance each sequence by its token chunk and return the logits
    /// after each chunk's **last** token (the serving form: that is the
    /// only position a sampler needs). Chunks may have different lengths;
    /// length-1 chunks are exactly single-token decode, so one call can
    /// mix prefilling and decoding sequences — the engine's unified tick.
    pub fn forward_chunks_refs(
        &self,
        chunks: &[&[u32]],
        caches: &mut [&mut KvCache],
    ) -> Vec<Vec<f32>> {
        self.forward_core(chunks, caches, LogitsWanted::Last)
            .into_iter()
            .map(|t| t.into_vec())
            .collect()
    }

    /// [`BackendModel::forward_chunks_refs`] with a per-sequence logits
    /// mask: chunks with `need[b] == false` advance their KV cache but
    /// skip the final-norm + vocab projection entirely (`None` in the
    /// result). The engine uses this for mid-prompt prefill chunks,
    /// whose logits nothing samples.
    pub fn forward_chunks_masked(
        &self,
        chunks: &[&[u32]],
        caches: &mut [&mut KvCache],
        need: &[bool],
    ) -> Vec<Option<Vec<f32>>> {
        assert_eq!(chunks.len(), need.len(), "forward_chunks_masked need-mask length");
        self.forward_core(chunks, caches, LogitsWanted::LastIf(need))
            .into_iter()
            .zip(need)
            .map(|(t, &k)| if k { Some(t.into_vec()) } else { None })
            .collect()
    }

    /// Process `tokens` as one chunk against `cache`, returning the full
    /// (T × vocab) logits matrix — one row per position. With an empty
    /// cache this is the whole-window forward pass ([`Model::forward`]
    /// delegates here); with a warm cache it is multi-token continuation.
    pub fn forward_chunk(&self, tokens: &[u32], cache: &mut KvCache) -> Tensor {
        let mut caches = [cache];
        self.forward_core(&[tokens], &mut caches, LogitsWanted::All)
            .pop()
            .expect("forward_core returns one logits tensor per chunk")
    }

    /// Teacher-forced `(Σ nll, count)` over a window — [`Model::nll_window`]
    /// semantics through the serving kernels, so quantized backends
    /// (int-dequant, LUT) are perplexity-evaluated end-to-end on the
    /// exact code path deployment runs.
    pub fn nll_window(&self, tokens: &[u32]) -> (f64, usize) {
        if tokens.len() < 2 {
            return (0.0, 0);
        }
        let mut cache = KvCache::new(&self.cfg);
        let logits = self.forward_chunk(tokens, &mut cache);
        super::forward::nll_from_logits(&logits, tokens)
    }

    /// Prefill a prompt through the chunked core (one weight stream per
    /// linear per [`PREFILL_CHUNK`] tokens instead of per token),
    /// returning the logits after the last prompt token. Bit-identical
    /// to a sequential [`BackendModel::decode_step`] loop.
    pub fn prefill(&self, tokens: &[u32], cache: &mut KvCache) -> Vec<f32> {
        self.prefill_chunked(tokens, cache, PREFILL_CHUNK)
    }

    /// [`BackendModel::prefill`] with an explicit chunk size (tests and
    /// sweeps; `chunk >= tokens.len()` is a single pass).
    pub fn prefill_chunked(&self, tokens: &[u32], cache: &mut KvCache, chunk: usize) -> Vec<f32> {
        assert!(!tokens.is_empty());
        assert!(chunk >= 1, "prefill chunk must be >= 1");
        let mut logits = Vec::new();
        let last_start = tokens.len() - 1 - (tokens.len() - 1) % chunk;
        for (ci, piece) in tokens.chunks(chunk).enumerate() {
            // only the final chunk's logits are observable
            let need = [ci * chunk == last_start];
            let mut caches = [&mut *cache];
            if let Some(l) = self
                .forward_chunks_masked(&[piece], &mut caches, &need)
                .pop()
                .expect("forward_chunks_masked returns one entry per chunk")
            {
                logits = l;
            }
        }
        logits
    }

    /// Prefill B prompts concurrently: each round takes the next `chunk`
    /// tokens of every unfinished prompt and advances them through one
    /// core call, so the weights stream once per `B × chunk` prompt
    /// tokens. Prompts may have different lengths (finished ones simply
    /// drop out of later rounds). Returns each sequence's last-token
    /// logits, bit-identical to per-sequence sequential prefill.
    pub fn prefill_batch(
        &self,
        prompts: &[&[u32]],
        caches: &mut [KvCache],
        chunk: usize,
    ) -> Vec<Vec<f32>> {
        assert_eq!(prompts.len(), caches.len(), "prefill_batch prompt/cache mismatch");
        assert!(chunk >= 1, "prefill chunk must be >= 1");
        let nb = prompts.len();
        let mut out: Vec<Vec<f32>> = vec![Vec::new(); nb];
        let mut idx = vec![0usize; nb];
        loop {
            let pending: Vec<bool> = (0..nb).map(|bi| idx[bi] < prompts[bi].len()).collect();
            let mut sel: Vec<usize> = Vec::new();
            let mut chunks: Vec<&[u32]> = Vec::new();
            let mut need: Vec<bool> = Vec::new();
            for (bi, prompt) in prompts.iter().enumerate() {
                if pending[bi] {
                    let end = (idx[bi] + chunk).min(prompt.len());
                    chunks.push(&prompt[idx[bi]..end]);
                    // only a prompt-completing chunk's logits are observable
                    need.push(end == prompt.len());
                    sel.push(bi);
                }
            }
            if sel.is_empty() {
                return out;
            }
            let mut cache_refs: Vec<&mut KvCache> = caches
                .iter_mut()
                .enumerate()
                .filter_map(|(bi, c)| if pending[bi] { Some(c) } else { None })
                .collect();
            let logits = self.forward_chunks_masked(&chunks, &mut cache_refs, &need);
            for ((&bi, chunk_fed), l) in sel.iter().zip(&chunks).zip(logits) {
                idx[bi] += chunk_fed.len();
                if let Some(l) = l {
                    out[bi] = l;
                }
            }
        }
    }

    /// The chunk-major forward core every public entry point reduces to.
    ///
    /// `chunks[b]` is consumed at positions `caches[b].len ..`, all K/V
    /// rows are appended, and each linear layer runs **one** batched
    /// [`Gemv::gemm`] over the flattened token rows of every chunk — the
    /// single place weights are streamed. Attention is per token over
    /// cache rows `0..=pos` (causal by construction; intra-chunk tokens
    /// see exactly the prefix a sequential loop would have written).
    ///
    /// Returns one logits tensor per chunk, per `wanted`: all T
    /// positions (evaluation), the last position only (serving — skips
    /// `T−1` of the vocab-sized projections per chunk), or the last
    /// position of masked chunks only (mid-prompt chunks skip the
    /// final-norm + vocab projection entirely and get an empty tensor).
    fn forward_core(
        &self,
        chunks: &[&[u32]],
        caches: &mut [&mut KvCache],
        wanted: LogitsWanted,
    ) -> Vec<Tensor> {
        let cfg = &self.cfg;
        let nb = chunks.len();
        assert_eq!(caches.len(), nb, "forward_core chunk/cache count mismatch");
        if nb == 0 {
            return Vec::new();
        }
        let heads = cfg.heads;
        let dh = cfg.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let slopes = if cfg.family == Family::Bloom {
            alibi_slopes(heads)
        } else {
            vec![0.0; heads]
        };

        // flat row layout: chunk 0's tokens, then chunk 1's, …
        let starts: Vec<usize> = caches.iter().map(|c| c.len).collect();
        let mut row_seq: Vec<usize> = Vec::new(); // row → chunk index
        let mut row_pos: Vec<usize> = Vec::new(); // row → absolute position
        for (bi, chunk) in chunks.iter().enumerate() {
            assert!(!chunk.is_empty(), "forward_core: empty chunk (seq {bi})");
            assert!(
                starts[bi] + chunk.len() <= cfg.max_seq,
                "KV cache overflow (seq {bi}: {} + {} > {})",
                starts[bi],
                chunk.len(),
                cfg.max_seq
            );
            for t in 0..chunk.len() {
                row_seq.push(bi);
                row_pos.push(starts[bi] + t);
            }
        }
        let nrows = row_seq.len();

        let mut xs: Vec<Vec<f32>> = Vec::with_capacity(nrows);
        for (bi, chunk) in chunks.iter().enumerate() {
            for (t, &tok) in chunk.iter().enumerate() {
                xs.push(self.embed_one(tok, starts[bi] + t));
            }
        }

        for i in 0..cfg.layers {
            let hs: Vec<Vec<f32>> =
                xs.iter().map(|x| self.norm(&format!("L{i}.ln1"), x)).collect();
            let hrefs: Vec<&[f32]> = hs.iter().map(|v| v.as_slice()).collect();
            let mut qs = self.gemm(&format!("L{i}.attn.q"), &hrefs);
            let mut ks = self.gemm(&format!("L{i}.attn.k"), &hrefs);
            let vs = self.gemm(&format!("L{i}.attn.v"), &hrefs);
            // rope + append the whole chunk's K/V before any attention
            for r in 0..nrows {
                let (bi, p) = (row_seq[r], row_pos[r]);
                if cfg.family == Family::Llama {
                    rope_vec(&mut qs[r], heads, p);
                    rope_vec(&mut ks[r], heads, p);
                }
                caches[bi].k[i].row_mut(p).copy_from_slice(&ks[r]);
                caches[bi].v[i].row_mut(p).copy_from_slice(&vs[r]);
            }

            // attention stays per token: row at position p attends over
            // cache rows 0..=p — prefix plus the intra-chunk past
            let mut ctxs: Vec<Vec<f32>> = Vec::with_capacity(nrows);
            for r in 0..nrows {
                let (bi, p) = (row_seq[r], row_pos[r]);
                let cache = &caches[bi];
                let q = &qs[r];
                let mut ctx = vec![0.0f32; cfg.d_model];
                let mut scores = vec![0.0f32; p + 1];
                for head in 0..heads {
                    let base = head * dh;
                    let qh = &q[base..base + dh];
                    for (j, s) in scores.iter_mut().enumerate() {
                        let krow = &cache.k[i].row(j)[base..base + dh];
                        let mut dot = 0.0f32;
                        for (a, b) in qh.iter().zip(krow) {
                            dot += a * b;
                        }
                        *s = dot * scale + slopes[head] * (j as f32 - p as f32);
                    }
                    softmax(&mut scores);
                    let out = &mut ctx[base..base + dh];
                    for (j, &pw) in scores.iter().enumerate() {
                        let vrow = &cache.v[i].row(j)[base..base + dh];
                        for (o, &vv) in out.iter_mut().zip(vrow) {
                            *o += pw * vv;
                        }
                    }
                }
                ctxs.push(ctx);
            }
            let crefs: Vec<&[f32]> = ctxs.iter().map(|v| v.as_slice()).collect();
            let attns = self.gemm(&format!("L{i}.attn.o"), &crefs);
            for (x, a) in xs.iter_mut().zip(&attns) {
                for (xv, &av) in x.iter_mut().zip(a) {
                    *xv += av;
                }
            }

            let h2s: Vec<Vec<f32>> =
                xs.iter().map(|x| self.norm(&format!("L{i}.ln2"), x)).collect();
            let h2refs: Vec<&[f32]> = h2s.iter().map(|v| v.as_slice()).collect();
            let ffs = match cfg.family {
                Family::Llama => {
                    let gates = self.gemm(&format!("L{i}.ff.gate"), &h2refs);
                    let ups = self.gemm(&format!("L{i}.ff.up"), &h2refs);
                    let acts: Vec<Vec<f32>> = gates
                        .iter()
                        .zip(&ups)
                        .map(|(gate, up)| {
                            gate.iter().zip(up).map(|(&g, &u)| silu(g) * u).collect()
                        })
                        .collect();
                    let arefs: Vec<&[f32]> = acts.iter().map(|v| v.as_slice()).collect();
                    self.gemm(&format!("L{i}.ff.down"), &arefs)
                }
                _ => {
                    let ups = self.gemm(&format!("L{i}.ff.up"), &h2refs);
                    let acts: Vec<Vec<f32>> = ups
                        .iter()
                        .map(|up| up.iter().map(|&u| gelu(u)).collect())
                        .collect();
                    let arefs: Vec<&[f32]> = acts.iter().map(|v| v.as_slice()).collect();
                    self.gemm(&format!("L{i}.ff.down"), &arefs)
                }
            };
            for (x, f) in xs.iter_mut().zip(&ffs) {
                for (xv, &fv) in x.iter_mut().zip(f) {
                    *xv += fv;
                }
            }
        }
        for (cache, chunk) in caches.iter_mut().zip(chunks) {
            cache.len += chunk.len();
        }

        // tied-embedding logits through the batched dense kernel: the
        // (vocab × d_model) embedding streams once for the whole call
        let tok = self.weights.expect("tok_emb");
        if let LogitsWanted::All = wanted {
            let xfs: Vec<Vec<f32>> = xs.iter().map(|x| self.norm("final_ln", x)).collect();
            let xrefs: Vec<&[f32]> = xfs.iter().map(|v| v.as_slice()).collect();
            let mut ys: Vec<Vec<f32>> =
                (0..nrows).map(|_| vec![0.0f32; cfg.vocab]).collect();
            crate::kernels::gemm_f32(tok, &xrefs, &mut ys);
            let mut out = Vec::with_capacity(nb);
            let mut row = 0usize;
            for chunk in chunks {
                let t = chunk.len();
                let mut data = Vec::with_capacity(t * cfg.vocab);
                for y in &ys[row..row + t] {
                    data.extend_from_slice(y);
                }
                out.push(Tensor::from_vec(t, cfg.vocab, data));
                row += t;
            }
            return out;
        }
        // serving only samples after a chunk's last token — and only for
        // chunks the mask wants; everything else skips the final norm
        // and the vocab-sized projection altogether
        let keep: Vec<bool> = match wanted {
            LogitsWanted::All => unreachable!("handled above"),
            LogitsWanted::Last => vec![true; nb],
            LogitsWanted::LastIf(mask) => {
                assert_eq!(mask.len(), nb, "forward_core logits-mask length");
                mask.to_vec()
            }
        };
        let mut last_rows = Vec::new();
        let mut row = 0usize;
        for (chunk, &k) in chunks.iter().zip(&keep) {
            row += chunk.len();
            if k {
                last_rows.push(row - 1);
            }
        }
        let xfs: Vec<Vec<f32>> =
            last_rows.iter().map(|&r| self.norm("final_ln", &xs[r])).collect();
        let xrefs: Vec<&[f32]> = xfs.iter().map(|v| v.as_slice()).collect();
        let mut ys: Vec<Vec<f32>> =
            (0..last_rows.len()).map(|_| vec![0.0f32; cfg.vocab]).collect();
        crate::kernels::gemm_f32(tok, &xrefs, &mut ys);
        let mut ys_iter = ys.into_iter();
        keep.iter()
            .map(|&k| {
                if k {
                    Tensor::from_vec(1, cfg.vocab, ys_iter.next().expect("one per kept chunk"))
                } else {
                    Tensor::zeros(0, 0)
                }
            })
            .collect()
    }
}

/// Which logits a [`BackendModel::forward_core`] call materializes.
#[derive(Clone, Copy)]
enum LogitsWanted<'a> {
    /// Every position of every chunk (evaluation).
    All,
    /// Each chunk's last position (serving).
    Last,
    /// Last position of masked chunks only; others return empty tensors
    /// (mid-prompt prefill chunks — nothing will sample them).
    LastIf(&'a [bool]),
}

/// Default prompt tokens per core call in [`BackendModel::prefill`]:
/// weight streams per prompt drop `PREFILL_CHUNK`× vs the per-token
/// loop, while the per-call activation working set stays small.
pub const PREFILL_CHUNK: usize = 32;

/// RoPE on a single d_model vector at absolute position `pos`.
pub fn rope_vec(x: &mut [f32], heads: usize, pos: usize) {
    let d = x.len();
    let dh = d / heads;
    let half = dh / 2;
    let posf = pos as f32;
    for h in 0..heads {
        let base = h * dh;
        for i in 0..half {
            let theta = posf * 10000f32.powf(-2.0 * i as f32 / dh as f32);
            let (sin, cos) = theta.sin_cos();
            let a = x[base + 2 * i];
            let b = x[base + 2 * i + 1];
            x[base + 2 * i] = a * cos - b * sin;
            x[base + 2 * i + 1] = a * sin + b * cos;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::random_weights;
    use crate::model::presets;

    fn tiny(family: Family) -> Model {
        let mut cfg = presets::by_name("opt-nano").unwrap();
        cfg.family = family;
        cfg.vocab = 64;
        cfg.max_seq = 32;
        Model::new(cfg.clone(), random_weights(&cfg, 21))
    }

    #[test]
    fn decode_matches_full_forward_all_families() {
        for fam in [Family::Opt, Family::Llama, Family::Bloom] {
            let m = tiny(fam);
            let bm = BackendModel::dense(&m);
            let tokens: Vec<u32> = vec![3, 9, 27, 44, 5, 13, 60, 2];
            // full-sequence reference
            let full = m.forward(&tokens);
            // incremental decode
            let mut cache = KvCache::new(&m.cfg);
            let mut last = Vec::new();
            for &t in &tokens {
                last = bm.decode_step(t, &mut cache);
            }
            let t_last = tokens.len() - 1;
            for c in 0..m.cfg.vocab {
                assert!(
                    (full.get(t_last, c) - last[c]).abs() < 1e-3,
                    "{fam:?} logit {c}: {} vs {}",
                    full.get(t_last, c),
                    last[c]
                );
            }
        }
    }

    #[test]
    fn prefill_equals_stepwise() {
        let m = tiny(Family::Opt);
        let bm = BackendModel::dense(&m);
        let tokens: Vec<u32> = vec![1, 2, 3, 4, 5];
        let mut c1 = KvCache::new(&m.cfg);
        let l1 = bm.prefill(&tokens, &mut c1);
        let mut c2 = KvCache::new(&m.cfg);
        let mut l2 = Vec::new();
        for &t in &tokens {
            l2 = bm.decode_step(t, &mut c2);
        }
        assert_eq!(c1.len, c2.len);
        for (a, b) in l1.iter().zip(&l2) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn decode_batch_matches_decode_step_mixed_lengths() {
        for fam in [Family::Opt, Family::Llama, Family::Bloom] {
            let m = tiny(fam);
            let bm = BackendModel::dense(&m);
            // three sequences with different histories/positions
            let prompts: [&[u32]; 3] = [&[3, 9, 27], &[44, 5], &[13, 60, 2, 7, 1]];
            let mut batch_caches: Vec<KvCache> =
                (0..3).map(|_| KvCache::new(&m.cfg)).collect();
            let mut seq_caches: Vec<KvCache> =
                (0..3).map(|_| KvCache::new(&m.cfg)).collect();
            for (bi, prompt) in prompts.iter().enumerate() {
                for &t in prompt.iter() {
                    bm.decode_step(t, &mut batch_caches[bi]);
                    bm.decode_step(t, &mut seq_caches[bi]);
                }
            }
            // two batched steps vs two sequential steps, greedy feedback
            let mut batch_tokens: Vec<u32> = vec![11, 22, 33];
            let mut seq_tokens = batch_tokens.clone();
            for _ in 0..2 {
                let batch_logits = bm.decode_batch(&batch_tokens, &mut batch_caches);
                for (bi, logits) in batch_logits.iter().enumerate() {
                    let seq_logits = bm.decode_step(seq_tokens[bi], &mut seq_caches[bi]);
                    assert_eq!(
                        logits, &seq_logits,
                        "{fam:?} batched logits diverged from sequential (seq {bi})"
                    );
                    batch_tokens[bi] = crate::coordinator::sampler::argmax(logits);
                    seq_tokens[bi] = crate::coordinator::sampler::argmax(&seq_logits);
                }
                assert_eq!(batch_tokens, seq_tokens);
            }
            for (a, b) in batch_caches.iter().zip(&seq_caches) {
                assert_eq!(a.len, b.len);
            }
        }
    }

    #[test]
    fn decode_batch_of_one_equals_decode_step() {
        let m = tiny(Family::Opt);
        let bm = BackendModel::dense(&m);
        let mut c1 = KvCache::new(&m.cfg);
        let mut c2 = vec![KvCache::new(&m.cfg)];
        for &t in &[5u32, 9, 13] {
            let a = bm.decode_step(t, &mut c1);
            let b = bm.decode_batch(&[t], &mut c2).remove(0);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn quantized_backend_runs_and_stays_close() {
        use crate::quant::{quantize_layer, Method, QuantConfig};
        let m = tiny(Family::Opt);
        // quantize every linear against a synthetic Hessian
        let mut rng = crate::util::Rng::new(77);
        let mut layers = HashMap::new();
        for (name, _rows, cols) in m.cfg.all_linears() {
            let acts = Tensor::randn(4 * cols, cols, 1.0, &mut rng);
            let h = crate::quant::gptq::accumulate_hessian(&acts);
            let cfg = QuantConfig { explore_grid: 2, ..QuantConfig::with_bits(4) };
            let q = quantize_layer(m.weights.expect(&name), &h, Method::Gptqt, &cfg).unwrap();
            layers.insert(name, q);
        }
        let bm_q = BackendModel::quantized(&m, layers);
        let bm_f = BackendModel::dense(&m);
        assert!(bm_q.streamed_bytes_per_token() * 4 < bm_f.streamed_bytes_per_token());

        let mut cq = KvCache::new(&m.cfg);
        let mut cf = KvCache::new(&m.cfg);
        let tokens = [7u32, 13, 2, 41];
        let (mut lq, mut lf) = (Vec::new(), Vec::new());
        for &t in &tokens {
            lq = bm_q.decode_step(t, &mut cq);
            lf = bm_f.decode_step(t, &mut cf);
        }
        assert!(lq.iter().all(|v| v.is_finite()));
        // 4-bit quantization on a tiny model: logits close but not equal
        let max_diff = lq
            .iter()
            .zip(&lf)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff > 0.0, "quantization must change something");
        assert!(max_diff < 1.0, "logits diverged: {max_diff}");
    }

    #[test]
    fn cache_overflow_panics() {
        let m = tiny(Family::Opt);
        let bm = BackendModel::dense(&m);
        let mut cache = KvCache::new(&m.cfg);
        for i in 0..m.cfg.max_seq {
            bm.decode_step((i % 64) as u32, &mut cache);
        }
        assert_eq!(cache.remaining(), 0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            bm.decode_step(0, &mut cache);
        }));
        assert!(result.is_err());
    }

    #[test]
    fn rope_vec_matches_matrix_rope() {
        let mut rng = crate::util::Rng::new(501);
        let mut mat = Tensor::randn(4, 16, 1.0, &mut rng);
        let orig = mat.clone();
        super::super::forward::rope(&mut mat, 2, 5);
        for t in 0..4 {
            let mut v = orig.row(t).to_vec();
            rope_vec(&mut v, 2, 5 + t);
            for (a, b) in v.iter().zip(mat.row(t)) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }
}
