//! Single-token decode path with KV cache — the serving hot loop.
//!
//! Every linear layer is a [`Gemv`] backend, so the same loop executes
//! the dense f32 model (`full`), the GPTQ int+dequant model, or the GPTQT
//! fused binary-coded model — Table IV's three contenders — with
//! identical math and different memory traffic.

use super::config::{Family, ModelConfig};
use super::forward::{alibi_slopes, gelu, silu, softmax, LN_EPS};
use super::weights::WeightStore;
use super::Model;
use crate::kernels::{DenseGemv, Gemv};
use crate::quant::QuantizedLayer;
use crate::tensor::Tensor;
use std::collections::HashMap;

/// Per-sequence attention cache: one (max_seq × d_model) K and V buffer
/// per layer, head-major like the forward pass.
pub struct KvCache {
    pub k: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub len: usize,
    max_seq: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache {
            k: (0..cfg.layers).map(|_| Tensor::zeros(cfg.max_seq, cfg.d_model)).collect(),
            v: (0..cfg.layers).map(|_| Tensor::zeros(cfg.max_seq, cfg.d_model)).collect(),
            len: 0,
            max_seq: cfg.max_seq,
        }
    }

    pub fn remaining(&self) -> usize {
        self.max_seq - self.len
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Bytes held by this cache (capacity, not fill level).
    pub fn bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(|t| t.len() * 4).sum()
    }
}

/// A model whose linears are pluggable compute backends.
pub struct BackendModel {
    pub cfg: ModelConfig,
    /// Norm + embedding parameters (never quantized).
    pub weights: WeightStore,
    linears: HashMap<String, Box<dyn Gemv>>,
}

impl BackendModel {
    /// Dense f32 backends straight from a [`Model`] (the `full` row).
    pub fn dense(model: &Model) -> BackendModel {
        let mut linears: HashMap<String, Box<dyn Gemv>> = HashMap::new();
        for (name, _, _) in model.cfg.all_linears() {
            linears.insert(
                name.clone(),
                Box::new(DenseGemv::new(model.weights.expect(&name).clone())),
            );
        }
        BackendModel { cfg: model.cfg.clone(), weights: model.weights.clone(), linears }
    }

    /// Backends from quantized layers: packed binary coding if present
    /// (GPTQT/BCQ → LUT-GEMM), else int weights (GPTQ → dequant), else
    /// the dense dequantized tensor.
    pub fn quantized(model: &Model, mut layers: HashMap<String, QuantizedLayer>) -> BackendModel {
        let mut linears: HashMap<String, Box<dyn Gemv>> = HashMap::new();
        for (name, _, _) in model.cfg.all_linears() {
            let backend: Box<dyn Gemv> = match layers.remove(&name) {
                Some(q) => {
                    if let Some(packed) = q.packed {
                        Box::new(packed)
                    } else if let Some(int) = q.int_weights {
                        Box::new(int)
                    } else {
                        Box::new(DenseGemv::new(q.dequant))
                    }
                }
                None => Box::new(DenseGemv::new(model.weights.expect(&name).clone())),
            };
            linears.insert(name, backend);
        }
        BackendModel { cfg: model.cfg.clone(), weights: model.weights.clone(), linears }
    }

    /// Batched linear: one weight stream serves every sequence in the
    /// batch (see [`crate::kernels::Gemv::gemm`]). Batch 1 (the
    /// [`BackendModel::decode_step`] path) hits each backend's `gemm`,
    /// which is bitwise-identical to its `gemv`.
    fn gemm(&self, name: &str, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        let b = self
            .linears
            .get(name)
            .unwrap_or_else(|| panic!("no backend for {name}"));
        let mut ys: Vec<Vec<f32>> = (0..xs.len()).map(|_| vec![0.0f32; b.rows()]).collect();
        b.gemm(xs, &mut ys);
        ys
    }

    /// Total weight bytes streamed per decoded token — the bandwidth
    /// model behind Table IV (embeddings excluded: shared by all rows).
    pub fn streamed_bytes_per_token(&self) -> usize {
        self.linears.values().map(|b| b.streamed_bytes()).sum()
    }

    /// Label of the dominant backend (for reports).
    pub fn backend_label(&self) -> &'static str {
        self.linears
            .values()
            .next()
            .map(|b| b.label())
            .unwrap_or("empty")
    }

    fn norm(&self, prefix: &str, x: &[f32]) -> Vec<f32> {
        let d = x.len();
        let w = self.weights.expect(&format!("{prefix}.w"));
        match self.cfg.family {
            Family::Llama => {
                let ms = x.iter().map(|&v| v * v).sum::<f32>() / d as f32;
                let inv = 1.0 / (ms + LN_EPS).sqrt();
                x.iter().zip(w.data()).map(|(&v, &wi)| v * inv * wi).collect()
            }
            _ => {
                let b = self.weights.expect(&format!("{prefix}.b"));
                let mean = x.iter().sum::<f32>() / d as f32;
                let var = x.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
                let inv = 1.0 / (var + LN_EPS).sqrt();
                x.iter()
                    .zip(w.data().iter().zip(b.data()))
                    .map(|(&v, (&wi, &bi))| (v - mean) * inv * wi + bi)
                    .collect()
            }
        }
    }

    /// Embed a single token at absolute position `pos`.
    pub fn embed_one(&self, token: u32, pos: usize) -> Vec<f32> {
        let tok = self.weights.expect("tok_emb");
        let mut x = tok.row(token as usize % self.cfg.vocab).to_vec();
        if self.cfg.family == Family::Opt {
            let pemb = self.weights.expect("pos_emb");
            for (v, &p) in x.iter_mut().zip(pemb.row(pos % self.cfg.max_seq)) {
                *v += p;
            }
        }
        x
    }

    /// Run one decode step: consume `token` at position `cache.len`,
    /// append K/V, return the next-token logits.
    ///
    /// Implemented as [`BackendModel::decode_batch_refs`] at batch 1 —
    /// one shared transformer step means batched and sequential decode
    /// cannot drift apart (the engine's token-parity guarantee holds by
    /// construction), and `gemm(B=1)` is pinned bitwise-identical to
    /// `gemv` in the kernel layer.
    pub fn decode_step(&self, token: u32, cache: &mut KvCache) -> Vec<f32> {
        let mut caches = [cache];
        self.decode_batch_refs(&[token], &mut caches)
            .pop()
            .expect("decode_batch_refs returns one logits vector per sequence")
    }

    /// One decode step for a batch of independent sequences:
    /// `tokens[b]` is consumed at position `caches[b].len`, each cache
    /// gets its K/V appended, and the per-sequence next-token logits are
    /// returned in batch order.
    ///
    /// Every linear layer runs through the batched [`Gemv::gemm`]
    /// kernels, so the weights are streamed once per *batch* instead of
    /// once per sequence — the amortization a multi-tenant server needs.
    /// Sequences may sit at different positions. Per sequence the fp
    /// arithmetic is identical to [`BackendModel::decode_step`], so
    /// greedy generation is token-identical to a sequential loop.
    pub fn decode_batch(&self, tokens: &[u32], caches: &mut [KvCache]) -> Vec<Vec<f32>> {
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        self.decode_batch_refs(tokens, &mut refs)
    }

    /// [`BackendModel::decode_batch`] over borrowed caches — the form
    /// the engine uses when the caches live inside its running set.
    pub fn decode_batch_refs(
        &self,
        tokens: &[u32],
        caches: &mut [&mut KvCache],
    ) -> Vec<Vec<f32>> {
        let cfg = &self.cfg;
        let nb = tokens.len();
        assert_eq!(caches.len(), nb, "decode_batch token/cache count mismatch");
        if nb == 0 {
            return Vec::new();
        }
        let heads = cfg.heads;
        let dh = cfg.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let slopes = if cfg.family == Family::Bloom {
            alibi_slopes(heads)
        } else {
            vec![0.0; heads]
        };
        let pos: Vec<usize> = caches.iter().map(|c| c.len).collect();
        for (bi, &p) in pos.iter().enumerate() {
            assert!(p < cfg.max_seq, "KV cache full (batch seq {bi})");
        }

        let mut xs: Vec<Vec<f32>> = tokens
            .iter()
            .zip(&pos)
            .map(|(&t, &p)| self.embed_one(t, p))
            .collect();
        for i in 0..cfg.layers {
            let hs: Vec<Vec<f32>> =
                xs.iter().map(|x| self.norm(&format!("L{i}.ln1"), x)).collect();
            let hrefs: Vec<&[f32]> = hs.iter().map(|v| v.as_slice()).collect();
            let mut qs = self.gemm(&format!("L{i}.attn.q"), &hrefs);
            let mut ks = self.gemm(&format!("L{i}.attn.k"), &hrefs);
            let vs = self.gemm(&format!("L{i}.attn.v"), &hrefs);
            for (bi, cache) in caches.iter_mut().enumerate() {
                if cfg.family == Family::Llama {
                    rope_vec(&mut qs[bi], heads, pos[bi]);
                    rope_vec(&mut ks[bi], heads, pos[bi]);
                }
                cache.k[i].row_mut(pos[bi]).copy_from_slice(&ks[bi]);
                cache.v[i].row_mut(pos[bi]).copy_from_slice(&vs[bi]);
            }

            // attention stays per-sequence: each cache has its own length
            let mut ctxs: Vec<Vec<f32>> = Vec::with_capacity(nb);
            for (bi, cache) in caches.iter().enumerate() {
                let p = pos[bi];
                let q = &qs[bi];
                let mut ctx = vec![0.0f32; cfg.d_model];
                let mut scores = vec![0.0f32; p + 1];
                for head in 0..heads {
                    let base = head * dh;
                    let qh = &q[base..base + dh];
                    for (j, s) in scores.iter_mut().enumerate() {
                        let krow = &cache.k[i].row(j)[base..base + dh];
                        let mut dot = 0.0f32;
                        for (a, b) in qh.iter().zip(krow) {
                            dot += a * b;
                        }
                        *s = dot * scale + slopes[head] * (j as f32 - p as f32);
                    }
                    softmax(&mut scores);
                    let out = &mut ctx[base..base + dh];
                    for (j, &pw) in scores.iter().enumerate() {
                        let vrow = &cache.v[i].row(j)[base..base + dh];
                        for (o, &vv) in out.iter_mut().zip(vrow) {
                            *o += pw * vv;
                        }
                    }
                }
                ctxs.push(ctx);
            }
            let crefs: Vec<&[f32]> = ctxs.iter().map(|v| v.as_slice()).collect();
            let attns = self.gemm(&format!("L{i}.attn.o"), &crefs);
            for (x, a) in xs.iter_mut().zip(&attns) {
                for (xv, &av) in x.iter_mut().zip(a) {
                    *xv += av;
                }
            }

            let h2s: Vec<Vec<f32>> =
                xs.iter().map(|x| self.norm(&format!("L{i}.ln2"), x)).collect();
            let h2refs: Vec<&[f32]> = h2s.iter().map(|v| v.as_slice()).collect();
            let ffs = match cfg.family {
                Family::Llama => {
                    let gates = self.gemm(&format!("L{i}.ff.gate"), &h2refs);
                    let ups = self.gemm(&format!("L{i}.ff.up"), &h2refs);
                    let acts: Vec<Vec<f32>> = gates
                        .iter()
                        .zip(&ups)
                        .map(|(gate, up)| {
                            gate.iter().zip(up).map(|(&g, &u)| silu(g) * u).collect()
                        })
                        .collect();
                    let arefs: Vec<&[f32]> = acts.iter().map(|v| v.as_slice()).collect();
                    self.gemm(&format!("L{i}.ff.down"), &arefs)
                }
                _ => {
                    let ups = self.gemm(&format!("L{i}.ff.up"), &h2refs);
                    let acts: Vec<Vec<f32>> = ups
                        .iter()
                        .map(|up| up.iter().map(|&u| gelu(u)).collect())
                        .collect();
                    let arefs: Vec<&[f32]> = acts.iter().map(|v| v.as_slice()).collect();
                    self.gemm(&format!("L{i}.ff.down"), &arefs)
                }
            };
            for (x, f) in xs.iter_mut().zip(&ffs) {
                for (xv, &fv) in x.iter_mut().zip(f) {
                    *xv += fv;
                }
            }
        }
        for (cache, &p) in caches.iter_mut().zip(&pos) {
            cache.len = p + 1;
        }

        // tied-embedding logits through the batched dense kernel: the
        // (vocab × d_model) embedding streams once for the whole batch
        let xfs: Vec<Vec<f32>> = xs.iter().map(|x| self.norm("final_ln", x)).collect();
        let xrefs: Vec<&[f32]> = xfs.iter().map(|v| v.as_slice()).collect();
        let tok = self.weights.expect("tok_emb");
        let mut logits: Vec<Vec<f32>> = (0..nb).map(|_| vec![0.0f32; cfg.vocab]).collect();
        crate::kernels::gemm_f32(tok, &xrefs, &mut logits);
        logits
    }

    /// Prefill a prompt (sequential decode steps), returning the logits
    /// after the last prompt token.
    pub fn prefill(&self, tokens: &[u32], cache: &mut KvCache) -> Vec<f32> {
        assert!(!tokens.is_empty());
        let mut logits = Vec::new();
        for &t in tokens {
            logits = self.decode_step(t, cache);
        }
        logits
    }
}

/// RoPE on a single d_model vector at absolute position `pos`.
pub fn rope_vec(x: &mut [f32], heads: usize, pos: usize) {
    let d = x.len();
    let dh = d / heads;
    let half = dh / 2;
    let posf = pos as f32;
    for h in 0..heads {
        let base = h * dh;
        for i in 0..half {
            let theta = posf * 10000f32.powf(-2.0 * i as f32 / dh as f32);
            let (sin, cos) = theta.sin_cos();
            let a = x[base + 2 * i];
            let b = x[base + 2 * i + 1];
            x[base + 2 * i] = a * cos - b * sin;
            x[base + 2 * i + 1] = a * sin + b * cos;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::random_weights;
    use crate::model::presets;

    fn tiny(family: Family) -> Model {
        let mut cfg = presets::by_name("opt-nano").unwrap();
        cfg.family = family;
        cfg.vocab = 64;
        cfg.max_seq = 32;
        Model::new(cfg.clone(), random_weights(&cfg, 21))
    }

    #[test]
    fn decode_matches_full_forward_all_families() {
        for fam in [Family::Opt, Family::Llama, Family::Bloom] {
            let m = tiny(fam);
            let bm = BackendModel::dense(&m);
            let tokens: Vec<u32> = vec![3, 9, 27, 44, 5, 13, 60, 2];
            // full-sequence reference
            let full = m.forward(&tokens);
            // incremental decode
            let mut cache = KvCache::new(&m.cfg);
            let mut last = Vec::new();
            for &t in &tokens {
                last = bm.decode_step(t, &mut cache);
            }
            let t_last = tokens.len() - 1;
            for c in 0..m.cfg.vocab {
                assert!(
                    (full.get(t_last, c) - last[c]).abs() < 1e-3,
                    "{fam:?} logit {c}: {} vs {}",
                    full.get(t_last, c),
                    last[c]
                );
            }
        }
    }

    #[test]
    fn prefill_equals_stepwise() {
        let m = tiny(Family::Opt);
        let bm = BackendModel::dense(&m);
        let tokens: Vec<u32> = vec![1, 2, 3, 4, 5];
        let mut c1 = KvCache::new(&m.cfg);
        let l1 = bm.prefill(&tokens, &mut c1);
        let mut c2 = KvCache::new(&m.cfg);
        let mut l2 = Vec::new();
        for &t in &tokens {
            l2 = bm.decode_step(t, &mut c2);
        }
        assert_eq!(c1.len, c2.len);
        for (a, b) in l1.iter().zip(&l2) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn decode_batch_matches_decode_step_mixed_lengths() {
        for fam in [Family::Opt, Family::Llama, Family::Bloom] {
            let m = tiny(fam);
            let bm = BackendModel::dense(&m);
            // three sequences with different histories/positions
            let prompts: [&[u32]; 3] = [&[3, 9, 27], &[44, 5], &[13, 60, 2, 7, 1]];
            let mut batch_caches: Vec<KvCache> =
                (0..3).map(|_| KvCache::new(&m.cfg)).collect();
            let mut seq_caches: Vec<KvCache> =
                (0..3).map(|_| KvCache::new(&m.cfg)).collect();
            for (bi, prompt) in prompts.iter().enumerate() {
                for &t in prompt.iter() {
                    bm.decode_step(t, &mut batch_caches[bi]);
                    bm.decode_step(t, &mut seq_caches[bi]);
                }
            }
            // two batched steps vs two sequential steps, greedy feedback
            let mut batch_tokens: Vec<u32> = vec![11, 22, 33];
            let mut seq_tokens = batch_tokens.clone();
            for _ in 0..2 {
                let batch_logits = bm.decode_batch(&batch_tokens, &mut batch_caches);
                for (bi, logits) in batch_logits.iter().enumerate() {
                    let seq_logits = bm.decode_step(seq_tokens[bi], &mut seq_caches[bi]);
                    assert_eq!(
                        logits, &seq_logits,
                        "{fam:?} batched logits diverged from sequential (seq {bi})"
                    );
                    batch_tokens[bi] = crate::coordinator::sampler::argmax(logits);
                    seq_tokens[bi] = crate::coordinator::sampler::argmax(&seq_logits);
                }
                assert_eq!(batch_tokens, seq_tokens);
            }
            for (a, b) in batch_caches.iter().zip(&seq_caches) {
                assert_eq!(a.len, b.len);
            }
        }
    }

    #[test]
    fn decode_batch_of_one_equals_decode_step() {
        let m = tiny(Family::Opt);
        let bm = BackendModel::dense(&m);
        let mut c1 = KvCache::new(&m.cfg);
        let mut c2 = vec![KvCache::new(&m.cfg)];
        for &t in &[5u32, 9, 13] {
            let a = bm.decode_step(t, &mut c1);
            let b = bm.decode_batch(&[t], &mut c2).remove(0);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn quantized_backend_runs_and_stays_close() {
        use crate::quant::{quantize_layer, Method, QuantConfig};
        let m = tiny(Family::Opt);
        // quantize every linear against a synthetic Hessian
        let mut rng = crate::util::Rng::new(77);
        let mut layers = HashMap::new();
        for (name, _rows, cols) in m.cfg.all_linears() {
            let acts = Tensor::randn(4 * cols, cols, 1.0, &mut rng);
            let h = crate::quant::gptq::accumulate_hessian(&acts);
            let cfg = QuantConfig { explore_grid: 2, ..QuantConfig::with_bits(4) };
            let q = quantize_layer(m.weights.expect(&name), &h, Method::Gptqt, &cfg).unwrap();
            layers.insert(name, q);
        }
        let bm_q = BackendModel::quantized(&m, layers);
        let bm_f = BackendModel::dense(&m);
        assert!(bm_q.streamed_bytes_per_token() * 4 < bm_f.streamed_bytes_per_token());

        let mut cq = KvCache::new(&m.cfg);
        let mut cf = KvCache::new(&m.cfg);
        let tokens = [7u32, 13, 2, 41];
        let (mut lq, mut lf) = (Vec::new(), Vec::new());
        for &t in &tokens {
            lq = bm_q.decode_step(t, &mut cq);
            lf = bm_f.decode_step(t, &mut cf);
        }
        assert!(lq.iter().all(|v| v.is_finite()));
        // 4-bit quantization on a tiny model: logits close but not equal
        let max_diff = lq
            .iter()
            .zip(&lf)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff > 0.0, "quantization must change something");
        assert!(max_diff < 1.0, "logits diverged: {max_diff}");
    }

    #[test]
    fn cache_overflow_panics() {
        let m = tiny(Family::Opt);
        let bm = BackendModel::dense(&m);
        let mut cache = KvCache::new(&m.cfg);
        for i in 0..m.cfg.max_seq {
            bm.decode_step((i % 64) as u32, &mut cache);
        }
        assert_eq!(cache.remaining(), 0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            bm.decode_step(0, &mut cache);
        }));
        assert!(result.is_err());
    }

    #[test]
    fn rope_vec_matches_matrix_rope() {
        let mut rng = crate::util::Rng::new(501);
        let mut mat = Tensor::randn(4, 16, 1.0, &mut rng);
        let orig = mat.clone();
        super::super::forward::rope(&mut mat, 2, 5);
        for t in 0..4 {
            let mut v = orig.row(t).to_vec();
            rope_vec(&mut v, 2, 5 + t);
            for (a, b) in v.iter().zip(mat.row(t)) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }
}
