//! The chunk-major KV-cache forward core — the serving hot loop.
//!
//! Every linear layer is a [`Gemv`] backend, so the same code executes
//! the dense f32 model (`full`), the GPTQ int+dequant model, or the GPTQT
//! fused binary-coded model — Table IV's three contenders — with
//! identical math and different memory traffic.
//!
//! One private core, `BackendModel::forward_core`, advances any mix of
//! per-sequence token chunks against their KV caches in a single pass
//! per layer: every linear runs one batched [`Gemv::gemm`] over **all**
//! chunk tokens of **all** sequences, so the weights stream once per
//! (linear, tick) instead of once per token per sequence. Everything
//! else is a thin view of that core — single-token decode
//! ([`BackendModel::decode_step`], [`BackendModel::decode_batch`]),
//! chunked prefill ([`BackendModel::prefill`],
//! [`BackendModel::prefill_batch`]), and full-window evaluation
//! ([`BackendModel::forward_chunk`], [`BackendModel::nll_window`],
//! [`Model::forward`]).
//!
//! ## The attention subsystem
//!
//! Between the QKV and output GEMMs the core runs the vectorized
//! attention kernels of [`crate::kernels::attn`] over the **head-major**
//! [`KvCache`] (`layers × heads × max_seq × head_dim`): each (row, head)
//! work item scores one query head against that head's contiguous K
//! strip ([`crate::kernels::attn::qk_dots`]), softmaxes, and accumulates
//! the matching V strip ([`crate::kernels::attn::av_accumulate`]) —
//! streaming contiguous cache memory where the old `max_seq × d_model`
//! layout strided `d_model` floats per position. When a tick carries
//! enough total attention work the items fan out across
//! [`crate::util::pool`]; items are independent and internally
//! sequential, so threaded attention is bitwise identical to the
//! sequential loop. The kernels carry the same pinned scalar↔AVX2
//! bitwise contract as the GEMMs.
//!
//! Under the opt-in `Fast` numerics mode
//! ([`BackendModel::with_numerics`]) the same (row, head) work items
//! run the fused flash-style kernel
//! [`crate::kernels::fast_math::attn_row_fast`] instead — scores are
//! never materialized — the GEMMs take their FMA epilogues, and the
//! FFN activations switch to the polynomial-exp forms. See
//! [`crate::kernels::fast_math`] for the per-tier contract.
//!
//! ## The zero-alloc workspace
//!
//! The core's activation buffers (residual stream, norm outputs, QKV,
//! attention context, FFN tiles, scores) live in a caller-owned
//! [`ForwardScratch`] that persists across calls: the serving engine
//! threads one workspace through every tick
//! (`coordinator::Backend::forward_tick`), so steady-state decode does
//! no per-row-per-layer heap allocation. Linear and norm handles are
//! likewise resolved once at [`BackendModel`] construction into indexed
//! slots — the layer loop never formats a name or hashes a string.
//!
//! Causality inside a chunk falls out of the iteration bound: the whole
//! chunk's K/V rows are appended first, then token at position `p`
//! attends over cache rows `0..=p` only. Per token the fp operation
//! order is identical to the sequential single-token loop (the kernels
//! pin `gemm == per-item gemv` bitwise), so chunked, batched, threaded,
//! and sequential execution all produce bit-identical logits.

use super::config::{Family, ModelConfig};
use super::forward::{alibi_slopes, softmax, LN_EPS};
use super::weights::WeightStore;
use super::Model;
use crate::kernels::{attn, fast_math, simd, DenseGemv, Gemv, NumericsMode};
use crate::quant::QuantizedLayer;
use crate::tensor::Tensor;
use crate::util::pool;
use std::collections::HashMap;

/// Per-sequence attention cache in **head-major** layout: one
/// `(heads·max_seq) × head_dim` K and one V tensor per layer, head `h`'s
/// rows for positions `0..max_seq` stored contiguously starting at row
/// `h·max_seq`. A head's cache prefix is therefore one contiguous strip
/// ([`KvCache::k_strip`]) — what the [`crate::kernels::attn`] inner
/// loops stream — where the previous `max_seq × d_model` layout strided
/// `d_model` floats between positions of the same head.
pub struct KvCache {
    k: Vec<Tensor>,
    v: Vec<Tensor>,
    pub len: usize,
    max_seq: usize,
    heads: usize,
    head_dim: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> KvCache {
        let heads = cfg.heads;
        let dh = cfg.head_dim();
        KvCache {
            k: (0..cfg.layers)
                .map(|_| Tensor::zeros(heads * cfg.max_seq, dh))
                .collect(),
            v: (0..cfg.layers)
                .map(|_| Tensor::zeros(heads * cfg.max_seq, dh))
                .collect(),
            len: 0,
            max_seq: cfg.max_seq,
            heads,
            head_dim: dh,
        }
    }

    pub fn remaining(&self) -> usize {
        self.max_seq - self.len
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Bytes held by this cache (capacity, not fill level).
    pub fn bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(|t| t.len() * 4).sum()
    }

    /// Append position `pos`'s K and V (`d_model` vectors, head-major
    /// within the row), scattering each head's `head_dim` slice into
    /// that head's contiguous strip.
    #[inline]
    pub fn write_kv(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        let dh = self.head_dim;
        let ms = self.max_seq;
        let kt = &mut self.k[layer];
        for h in 0..self.heads {
            kt.row_mut(h * ms + pos)
                .copy_from_slice(&k_row[h * dh..(h + 1) * dh]);
        }
        let vt = &mut self.v[layer];
        for h in 0..self.heads {
            vt.row_mut(h * ms + pos)
                .copy_from_slice(&v_row[h * dh..(h + 1) * dh]);
        }
    }

    /// Head `head`'s K rows for positions `0..len` — one contiguous
    /// `len·head_dim` strip (the point of the head-major layout).
    #[inline]
    pub fn k_strip(&self, layer: usize, head: usize, len: usize) -> &[f32] {
        let dh = self.head_dim;
        let base = head * self.max_seq * dh;
        &self.k[layer].data()[base..base + len * dh]
    }

    /// Head `head`'s V rows for positions `0..len`, contiguous.
    #[inline]
    pub fn v_strip(&self, layer: usize, head: usize, len: usize) -> &[f32] {
        let dh = self.head_dim;
        let base = head * self.max_seq * dh;
        &self.v[layer].data()[base..base + len * dh]
    }

    /// Gather position `pos`'s K back into `d_model` (head-major row)
    /// order — tests and debugging; the hot path never materializes
    /// this view.
    pub fn k_row(&self, layer: usize, pos: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.heads * self.head_dim);
        for h in 0..self.heads {
            out.extend_from_slice(self.k[layer].row(h * self.max_seq + pos));
        }
        out
    }

    /// Gather position `pos`'s V into `d_model` order (see
    /// [`KvCache::k_row`]).
    pub fn v_row(&self, layer: usize, pos: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.heads * self.head_dim);
        for h in 0..self.heads {
            out.extend_from_slice(self.v[layer].row(h * self.max_seq + pos));
        }
        out
    }

    /// Standalone snapshot of the first `tokens` positions, sized to
    /// exactly `tokens` (`max_seq == len == tokens`). The prefix cache
    /// holds these; a hit imports one back with
    /// [`KvCache::copy_prefix_from`]. Bitwise copies — no recompute.
    pub fn prefix_clone(&self, tokens: usize) -> KvCache {
        assert!(tokens <= self.len, "snapshot {tokens} of {} stored", self.len);
        let dh = self.head_dim;
        let mut k = Vec::with_capacity(self.k.len());
        let mut v = Vec::with_capacity(self.v.len());
        for layer in 0..self.k.len() {
            let mut kt = Tensor::zeros(self.heads * tokens, dh);
            let mut vt = Tensor::zeros(self.heads * tokens, dh);
            for h in 0..self.heads {
                let src = h * self.max_seq * dh;
                let dst = h * tokens * dh;
                kt.data_mut()[dst..dst + tokens * dh]
                    .copy_from_slice(&self.k[layer].data()[src..src + tokens * dh]);
                vt.data_mut()[dst..dst + tokens * dh]
                    .copy_from_slice(&self.v[layer].data()[src..src + tokens * dh]);
            }
            k.push(kt);
            v.push(vt);
        }
        KvCache { k, v, len: tokens, max_seq: tokens, heads: self.heads, head_dim: dh }
    }

    /// Roll the cache back to its first `len` positions — the
    /// speculative-decode reject path: drafted-but-refused positions are
    /// simply forgotten. Rows past `len` are never read before being
    /// overwritten (every consumer bounds its strips by `len`), so
    /// lowering the length *is* the rollback; a later re-append at the
    /// same position overwrites bitwise.
    pub fn truncate_to(&mut self, len: usize) {
        assert!(len <= self.len, "truncate_to({len}) beyond stored {}", self.len);
        self.len = len;
    }

    /// Import the first `tokens` positions of a snapshot into this empty
    /// cache — the prefix-cache hit path; the engine then prefills only
    /// positions `tokens..`. Bitwise per-head strip copies, so a hit
    /// stream matches a cold stream exactly.
    pub fn copy_prefix_from(&mut self, src: &KvCache, tokens: usize) {
        assert_eq!(self.len, 0, "import into a non-empty cache");
        assert!(tokens <= src.len && tokens <= self.max_seq);
        assert_eq!(self.heads, src.heads);
        assert_eq!(self.head_dim, src.head_dim);
        assert_eq!(self.k.len(), src.k.len());
        let dh = self.head_dim;
        for layer in 0..self.k.len() {
            for h in 0..self.heads {
                let s = h * src.max_seq * dh;
                let d = h * self.max_seq * dh;
                self.k[layer].data_mut()[d..d + tokens * dh]
                    .copy_from_slice(&src.k[layer].data()[s..s + tokens * dh]);
                self.v[layer].data_mut()[d..d + tokens * dh]
                    .copy_from_slice(&src.v[layer].data()[s..s + tokens * dh]);
            }
        }
        self.len = tokens;
    }
}

/// Reusable row-major buffer pool: `prepare(n, width)` hands back `n`
/// rows of exactly `width` f32 each, growing (never shrinking) the
/// backing allocations, so steady-state serving reuses the same heap
/// blocks tick after tick. Rows are *not* cleared — every consumer
/// fully overwrites its rows (the GEMMs write each output element).
#[derive(Default)]
struct RowBuf(Vec<Vec<f32>>);

impl RowBuf {
    fn prepare(&mut self, n: usize, width: usize) -> &mut [Vec<f32>] {
        if self.0.len() < n {
            self.0.resize_with(n, Vec::new);
        }
        let rows = &mut self.0[..n];
        for row in rows.iter_mut() {
            row.resize(width, 0.0);
        }
        rows
    }
}

/// Persistent forward-pass workspace owned by `BackendModel`'s callers.
///
/// The serving engine keeps one per backend and threads it through
/// every tick (`coordinator::Backend::forward_tick` →
/// [`BackendModel::forward_chunks_masked_with`]), so the per-tick layer
/// loop performs no heap allocation once the buffers have grown to the
/// tick's working set. One-shot entry points construct a throwaway one.
/// Buffer contents do not carry information between calls — reuse is
/// purely an allocation optimization and cannot change any result.
#[derive(Default)]
pub struct ForwardScratch {
    /// Residual stream, one `d_model` row per chunk token.
    xs: RowBuf,
    /// Norm outputs (ln1/ln2/final reuse the same rows).
    hs: RowBuf,
    qs: RowBuf,
    ks: RowBuf,
    vs: RowBuf,
    /// Attention-output / FFN-down projection rows.
    proj: RowBuf,
    /// FFN gate tile (Llama) / up tile.
    ffa: RowBuf,
    ffb: RowBuf,
    /// Vocab-sized projection rows.
    logits: RowBuf,
    /// Flat `nrows × d_model` attention context (flat so the threaded
    /// (row, head) fan-out can write disjoint raw slices).
    ctx: Vec<f32>,
    /// Score buffer for the sequential attention path.
    scores: Vec<f32>,
    /// Flat row → chunk index / absolute position maps.
    row_seq: Vec<usize>,
    row_pos: Vec<usize>,
}

impl ForwardScratch {
    pub fn new() -> ForwardScratch {
        ForwardScratch::default()
    }
}

/// Norm parameters resolved at construction (weight + optional bias —
/// bias absent ⇒ RMSNorm, the Llama family).
struct NormParams {
    w: Tensor,
    b: Option<Tensor>,
}

impl NormParams {
    fn resolve(cfg: &ModelConfig, weights: &WeightStore, prefix: &str) -> NormParams {
        NormParams {
            w: weights.expect(&format!("{prefix}.w")).clone(),
            b: (cfg.family != Family::Llama)
                .then(|| weights.expect(&format!("{prefix}.b")).clone()),
        }
    }
}

/// Per-layer handles resolved once at [`BackendModel`] construction:
/// norm parameters (cloned — `d_model`-sized) and slot indices into the
/// linear backend table, so the per-tick layer loop never formats an
/// `L{i}.…` name or hashes a string.
struct LayerSlots {
    ln1: NormParams,
    ln2: NormParams,
    q: usize,
    k: usize,
    v: usize,
    o: usize,
    gate: Option<usize>,
    up: usize,
    down: usize,
}

/// A model whose linears are pluggable compute backends.
pub struct BackendModel {
    pub cfg: ModelConfig,
    /// Norm + embedding parameters (never quantized).
    pub weights: WeightStore,
    /// Linear backends in [`ModelConfig::all_linears`] order.
    linears: Vec<Box<dyn Gemv>>,
    layers: Vec<LayerSlots>,
    final_norm: NormParams,
    /// Numerics tier every forward pass runs under: `Exact` (default)
    /// keeps the bitwise scalar↔AVX2 contract end to end; `Fast` swaps
    /// the GEMM epilogues, activations, and the whole attention row for
    /// the FMA + online-softmax kernels of
    /// [`crate::kernels::fast_math`]. Set with
    /// [`BackendModel::with_numerics`].
    numerics: NumericsMode,
}

impl BackendModel {
    /// Dense f32 backends straight from a [`Model`] (the `full` row).
    pub fn dense(model: &Model) -> BackendModel {
        let src = &model.weights;
        Self::build(model.cfg.clone(), model.weights.clone(), |name| {
            let backend: Box<dyn Gemv> = Box::new(DenseGemv::new(src.expect(name).clone()));
            backend
        })
    }

    /// Backends from quantized layers: packed binary coding if present
    /// (GPTQT/BCQ → LUT-GEMM), else int weights (GPTQ → dequant), else
    /// the dense dequantized tensor.
    pub fn quantized(model: &Model, mut layers: HashMap<String, QuantizedLayer>) -> BackendModel {
        let src = &model.weights;
        Self::build(model.cfg.clone(), model.weights.clone(), move |name| {
            let backend: Box<dyn Gemv> = match layers.remove(name) {
                Some(q) => {
                    if let Some(packed) = q.packed {
                        Box::new(packed)
                    } else if let Some(int) = q.int_weights {
                        Box::new(int)
                    } else {
                        Box::new(DenseGemv::new(q.dequant))
                    }
                }
                None => Box::new(DenseGemv::new(src.expect(name).clone())),
            };
            backend
        })
    }

    /// Shared constructor: materialize one backend per linear (in
    /// [`ModelConfig::all_linears`] order) and resolve every per-layer
    /// handle — linear slots and norm parameters — exactly once.
    fn build(
        cfg: ModelConfig,
        weights: WeightStore,
        mut backend_for: impl FnMut(&str) -> Box<dyn Gemv>,
    ) -> BackendModel {
        let mut linears: Vec<Box<dyn Gemv>> = Vec::new();
        let mut slot_of: HashMap<String, usize> = HashMap::new();
        for (name, _, _) in cfg.all_linears() {
            slot_of.insert(name.clone(), linears.len());
            linears.push(backend_for(&name));
        }
        let slot = |name: String| -> usize {
            *slot_of
                .get(&name)
                .unwrap_or_else(|| panic!("no backend for {name}"))
        };
        let mut layers = Vec::with_capacity(cfg.layers);
        for i in 0..cfg.layers {
            layers.push(LayerSlots {
                ln1: NormParams::resolve(&cfg, &weights, &format!("L{i}.ln1")),
                ln2: NormParams::resolve(&cfg, &weights, &format!("L{i}.ln2")),
                q: slot(format!("L{i}.attn.q")),
                k: slot(format!("L{i}.attn.k")),
                v: slot(format!("L{i}.attn.v")),
                o: slot(format!("L{i}.attn.o")),
                gate: (cfg.family == Family::Llama).then(|| slot(format!("L{i}.ff.gate"))),
                up: slot(format!("L{i}.ff.up")),
                down: slot(format!("L{i}.ff.down")),
            });
        }
        let final_norm = NormParams::resolve(&cfg, &weights, "final_ln");
        BackendModel {
            cfg,
            weights,
            linears,
            layers,
            final_norm,
            numerics: NumericsMode::Exact,
        }
    }

    /// Select the numerics tier for every subsequent forward pass
    /// (builder style; the constructors default to
    /// [`NumericsMode::Exact`]). Switching modes never touches weights
    /// or caches — only which kernels run.
    pub fn with_numerics(mut self, mode: NumericsMode) -> BackendModel {
        self.numerics = mode;
        self
    }

    /// In-place form of [`BackendModel::with_numerics`] — the serving
    /// engine applies [`crate::coordinator::EngineConfig`]'s mode to an
    /// already-constructed backend through this.
    pub fn set_numerics(&mut self, mode: NumericsMode) {
        self.numerics = mode;
    }

    /// The numerics tier this model's forward passes run under.
    pub fn numerics(&self) -> NumericsMode {
        self.numerics
    }

    /// Batched linear through slot `slot`: one weight stream serves
    /// every row (see [`Gemv::gemm`]); output rows come from the
    /// scratch buffer (resized, never reallocated at steady state).
    fn gemm_slot<'b>(&self, slot: usize, xs: &[&[f32]], buf: &'b mut RowBuf) -> &'b mut [Vec<f32>] {
        let lin = &self.linears[slot];
        let ys = buf.prepare(xs.len(), lin.rows());
        lin.gemm_mode(xs, ys, self.numerics);
        ys
    }

    /// Total weight bytes streamed per decoded token — the bandwidth
    /// model behind Table IV (embeddings excluded: shared by all rows).
    pub fn streamed_bytes_per_token(&self) -> usize {
        self.linears.iter().map(|b| b.streamed_bytes()).sum()
    }

    /// Label of the dominant backend (for reports).
    pub fn backend_label(&self) -> &'static str {
        self.linears.first().map(|b| b.label()).unwrap_or("empty")
    }

    /// Normalize `x` into `out` with resolved parameters: RMSNorm when
    /// the bias is absent (Llama), LayerNorm otherwise. Same per-element
    /// fp order as the historical string-keyed `norm`.
    fn norm_into(&self, np: &NormParams, x: &[f32], out: &mut [f32]) {
        let d = x.len();
        debug_assert_eq!(out.len(), d);
        match &np.b {
            None => {
                let ms = x.iter().map(|&v| v * v).sum::<f32>() / d as f32;
                let inv = 1.0 / (ms + LN_EPS).sqrt();
                for ((o, &v), &wi) in out.iter_mut().zip(x).zip(np.w.data()) {
                    *o = v * inv * wi;
                }
            }
            Some(b) => {
                let mean = x.iter().sum::<f32>() / d as f32;
                let var = x.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
                let inv = 1.0 / (var + LN_EPS).sqrt();
                for ((o, &v), (&wi, &bi)) in
                    out.iter_mut().zip(x).zip(np.w.data().iter().zip(b.data()))
                {
                    *o = (v - mean) * inv * wi + bi;
                }
            }
        }
    }

    /// Run one decode step: consume `token` at position `cache.len`,
    /// append K/V, return the next-token logits.
    ///
    /// Implemented as [`BackendModel::decode_batch_refs`] at batch 1 —
    /// one shared transformer step means batched and sequential decode
    /// cannot drift apart (the engine's token-parity guarantee holds by
    /// construction), and `gemm(B=1)` is pinned bitwise-identical to
    /// `gemv` in the kernel layer.
    pub fn decode_step(&self, token: u32, cache: &mut KvCache) -> Vec<f32> {
        let mut caches = [cache];
        self.decode_batch_refs(&[token], &mut caches)
            .pop()
            .expect("decode_batch_refs returns one logits vector per sequence")
    }

    /// One decode step for a batch of independent sequences:
    /// `tokens[b]` is consumed at position `caches[b].len`, each cache
    /// gets its K/V appended, and the per-sequence next-token logits are
    /// returned in batch order.
    ///
    /// Every linear layer runs through the batched [`Gemv::gemm`]
    /// kernels, so the weights are streamed once per *batch* instead of
    /// once per sequence — the amortization a multi-tenant server needs.
    /// Sequences may sit at different positions. Per sequence the fp
    /// arithmetic is identical to [`BackendModel::decode_step`], so
    /// greedy generation is token-identical to a sequential loop.
    pub fn decode_batch(&self, tokens: &[u32], caches: &mut [KvCache]) -> Vec<Vec<f32>> {
        self.decode_batch_with(tokens, caches, &mut ForwardScratch::new())
    }

    /// [`BackendModel::decode_batch`] against a caller-owned
    /// [`ForwardScratch`] — loops that decode many steps reuse the
    /// workspace instead of reallocating it per step.
    pub fn decode_batch_with(
        &self,
        tokens: &[u32],
        caches: &mut [KvCache],
        scratch: &mut ForwardScratch,
    ) -> Vec<Vec<f32>> {
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        let chunks: Vec<&[u32]> = tokens.iter().map(std::slice::from_ref).collect();
        self.forward_chunks_refs_with(&chunks, &mut refs, scratch)
    }

    /// [`BackendModel::decode_batch`] over borrowed caches — the form
    /// the engine uses when the caches live inside its running set.
    /// The degenerate all-chunks-of-length-1 case of the forward core.
    pub fn decode_batch_refs(
        &self,
        tokens: &[u32],
        caches: &mut [&mut KvCache],
    ) -> Vec<Vec<f32>> {
        let chunks: Vec<&[u32]> = tokens.iter().map(std::slice::from_ref).collect();
        self.forward_chunks_refs(&chunks, caches)
    }

    /// Advance each sequence by its token chunk and return the logits
    /// after each chunk's **last** token (the serving form: that is the
    /// only position a sampler needs). Chunks may have different lengths;
    /// length-1 chunks are exactly single-token decode, so one call can
    /// mix prefilling and decoding sequences — the engine's unified tick.
    pub fn forward_chunks_refs(
        &self,
        chunks: &[&[u32]],
        caches: &mut [&mut KvCache],
    ) -> Vec<Vec<f32>> {
        self.forward_chunks_refs_with(chunks, caches, &mut ForwardScratch::new())
    }

    /// [`BackendModel::forward_chunks_refs`] with a caller-owned
    /// workspace.
    pub fn forward_chunks_refs_with(
        &self,
        chunks: &[&[u32]],
        caches: &mut [&mut KvCache],
        scratch: &mut ForwardScratch,
    ) -> Vec<Vec<f32>> {
        self.forward_core(chunks, caches, LogitsWanted::Last, scratch)
            .into_iter()
            .map(|t| t.into_vec())
            .collect()
    }

    /// [`BackendModel::forward_chunks_refs`] with a per-sequence logits
    /// mask: chunks with `need[b] == false` advance their KV cache but
    /// skip the final-norm + vocab projection entirely (`None` in the
    /// result). The engine uses this for mid-prompt prefill chunks,
    /// whose logits nothing samples.
    pub fn forward_chunks_masked(
        &self,
        chunks: &[&[u32]],
        caches: &mut [&mut KvCache],
        need: &[bool],
    ) -> Vec<Option<Vec<f32>>> {
        self.forward_chunks_masked_with(chunks, caches, need, &mut ForwardScratch::new())
    }

    /// [`BackendModel::forward_chunks_masked`] with a caller-owned
    /// [`ForwardScratch`] — the serving tick entry point
    /// (`coordinator::Backend::forward_tick` threads the engine's
    /// persistent workspace through here).
    pub fn forward_chunks_masked_with(
        &self,
        chunks: &[&[u32]],
        caches: &mut [&mut KvCache],
        need: &[bool],
        scratch: &mut ForwardScratch,
    ) -> Vec<Option<Vec<f32>>> {
        assert_eq!(chunks.len(), need.len(), "forward_chunks_masked need-mask length");
        self.forward_core(chunks, caches, LogitsWanted::LastIf(need), scratch)
            .into_iter()
            .zip(need)
            .map(|(t, &k)| if k { Some(t.into_vec()) } else { None })
            .collect()
    }

    /// Process `tokens` as one chunk against `cache`, returning the full
    /// (T × vocab) logits matrix — one row per position. With an empty
    /// cache this is the whole-window forward pass ([`Model::forward`]
    /// delegates here); with a warm cache it is multi-token continuation.
    pub fn forward_chunk(&self, tokens: &[u32], cache: &mut KvCache) -> Tensor {
        let mut caches = [cache];
        self.forward_core(&[tokens], &mut caches, LogitsWanted::All, &mut ForwardScratch::new())
            .pop()
            .expect("forward_core returns one logits tensor per chunk")
    }

    /// Batched multi-chunk forward returning **every** position's logits
    /// per chunk (one `Tᵦ × vocab` tensor each) — the speculative-decode
    /// verify kernel: the target model scores a drafted k-token chunk in
    /// one chunk-major pass and the acceptance rule reads the argmax at
    /// every position. Per position the logits are bitwise identical to
    /// feeding the same tokens one at a time (the forward-core parity
    /// contract), which is what makes accept-by-argmax equivalent to
    /// target-only greedy decoding.
    pub fn forward_chunks_all_with(
        &self,
        chunks: &[&[u32]],
        caches: &mut [&mut KvCache],
        scratch: &mut ForwardScratch,
    ) -> Vec<Tensor> {
        self.forward_core(chunks, caches, LogitsWanted::All, scratch)
    }

    /// Teacher-forced `(Σ nll, count)` over a window — [`Model::nll_window`]
    /// semantics through the serving kernels, so quantized backends
    /// (int-dequant, LUT) are perplexity-evaluated end-to-end on the
    /// exact code path deployment runs.
    pub fn nll_window(&self, tokens: &[u32]) -> (f64, usize) {
        if tokens.len() < 2 {
            return (0.0, 0);
        }
        let mut cache = KvCache::new(&self.cfg);
        let logits = self.forward_chunk(tokens, &mut cache);
        super::forward::nll_from_logits(&logits, tokens)
    }

    /// Prefill a prompt through the chunked core (one weight stream per
    /// linear per [`PREFILL_CHUNK`] tokens instead of per token),
    /// returning the logits after the last prompt token. Bit-identical
    /// to a sequential [`BackendModel::decode_step`] loop.
    pub fn prefill(&self, tokens: &[u32], cache: &mut KvCache) -> Vec<f32> {
        self.prefill_chunked(tokens, cache, PREFILL_CHUNK)
    }

    /// [`BackendModel::prefill`] with an explicit chunk size (tests and
    /// sweeps; `chunk >= tokens.len()` is a single pass). One workspace
    /// is reused across all chunk passes.
    pub fn prefill_chunked(&self, tokens: &[u32], cache: &mut KvCache, chunk: usize) -> Vec<f32> {
        assert!(!tokens.is_empty());
        assert!(chunk >= 1, "prefill chunk must be >= 1");
        let mut scratch = ForwardScratch::new();
        let mut logits = Vec::new();
        let last_start = tokens.len() - 1 - (tokens.len() - 1) % chunk;
        for (ci, piece) in tokens.chunks(chunk).enumerate() {
            // only the final chunk's logits are observable
            let need = [ci * chunk == last_start];
            let mut caches = [&mut *cache];
            if let Some(l) = self
                .forward_chunks_masked_with(&[piece], &mut caches, &need, &mut scratch)
                .pop()
                .expect("forward_chunks_masked returns one entry per chunk")
            {
                logits = l;
            }
        }
        logits
    }

    /// Prefill B prompts concurrently: each round takes the next `chunk`
    /// tokens of every unfinished prompt and advances them through one
    /// core call, so the weights stream once per `B × chunk` prompt
    /// tokens. Prompts may have different lengths (finished ones simply
    /// drop out of later rounds). Returns each sequence's last-token
    /// logits, bit-identical to per-sequence sequential prefill.
    pub fn prefill_batch(
        &self,
        prompts: &[&[u32]],
        caches: &mut [KvCache],
        chunk: usize,
    ) -> Vec<Vec<f32>> {
        assert_eq!(prompts.len(), caches.len(), "prefill_batch prompt/cache mismatch");
        assert!(chunk >= 1, "prefill chunk must be >= 1");
        let mut scratch = ForwardScratch::new();
        let nb = prompts.len();
        let mut out: Vec<Vec<f32>> = vec![Vec::new(); nb];
        let mut idx = vec![0usize; nb];
        loop {
            let pending: Vec<bool> = (0..nb).map(|bi| idx[bi] < prompts[bi].len()).collect();
            let mut sel: Vec<usize> = Vec::new();
            let mut chunks: Vec<&[u32]> = Vec::new();
            let mut need: Vec<bool> = Vec::new();
            for (bi, prompt) in prompts.iter().enumerate() {
                if pending[bi] {
                    let end = (idx[bi] + chunk).min(prompt.len());
                    chunks.push(&prompt[idx[bi]..end]);
                    // only a prompt-completing chunk's logits are observable
                    need.push(end == prompt.len());
                    sel.push(bi);
                }
            }
            if sel.is_empty() {
                return out;
            }
            let mut cache_refs: Vec<&mut KvCache> = caches
                .iter_mut()
                .enumerate()
                .filter_map(|(bi, c)| if pending[bi] { Some(c) } else { None })
                .collect();
            let logits =
                self.forward_chunks_masked_with(&chunks, &mut cache_refs, &need, &mut scratch);
            for ((&bi, chunk_fed), l) in sel.iter().zip(&chunks).zip(logits) {
                idx[bi] += chunk_fed.len();
                if let Some(l) = l {
                    out[bi] = l;
                }
            }
        }
    }

    /// The chunk-major forward core every public entry point reduces to.
    ///
    /// `chunks[b]` is consumed at positions `caches[b].len ..`, all K/V
    /// rows are appended head-major, and each linear layer runs **one**
    /// batched [`Gemv::gemm`] over the flattened token rows of every
    /// chunk — the single place weights are streamed. Attention runs the
    /// [`crate::kernels::attn`] kernels per (row, head) over contiguous
    /// cache strips, rows `0..=pos` (causal by construction; intra-chunk
    /// tokens see exactly the prefix a sequential loop would have
    /// written), fanning items across the pool when the tick carries
    /// enough work. All activations live in `scratch`.
    ///
    /// Returns one logits tensor per chunk, per `wanted`: all T
    /// positions (evaluation), the last position only (serving — skips
    /// `T−1` of the vocab-sized projections per chunk), or the last
    /// position of masked chunks only (mid-prompt chunks skip the
    /// final-norm + vocab projection entirely and get an empty tensor).
    fn forward_core(
        &self,
        chunks: &[&[u32]],
        caches: &mut [&mut KvCache],
        wanted: LogitsWanted,
        scratch: &mut ForwardScratch,
    ) -> Vec<Tensor> {
        let cfg = &self.cfg;
        let nb = chunks.len();
        assert_eq!(caches.len(), nb, "forward_core chunk/cache count mismatch");
        if nb == 0 {
            // lint:allow(hot-path-no-alloc) empty Vec — allocation-free.
            return Vec::new();
        }
        let d = cfg.d_model;
        let heads = cfg.heads;
        let dh = cfg.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let tier = simd::tier();
        let fast = self.numerics == NumericsMode::Fast;
        let slopes = if cfg.family == Family::Bloom {
            alibi_slopes(heads)
        } else {
            // lint:allow(hot-path-no-alloc) O(heads), once per forward.
            vec![0.0; heads]
        };

        let ForwardScratch {
            xs: xs_buf,
            hs: hs_buf,
            qs: qs_buf,
            ks: ks_buf,
            vs: vs_buf,
            proj: proj_buf,
            ffa: ffa_buf,
            ffb: ffb_buf,
            logits: logits_buf,
            ctx,
            scores,
            row_seq,
            row_pos,
        } = scratch;

        // flat row layout: chunk 0's tokens, then chunk 1's, …
        // lint:allow(hot-path-no-alloc) O(batch) table, once per forward.
        let starts: Vec<usize> = caches.iter().map(|c| c.len).collect();
        row_seq.clear();
        row_pos.clear();
        for (bi, chunk) in chunks.iter().enumerate() {
            assert!(!chunk.is_empty(), "forward_core: empty chunk (seq {bi})");
            assert!(
                starts[bi] + chunk.len() <= cfg.max_seq,
                "KV cache overflow (seq {bi}: {} + {} > {})",
                starts[bi],
                chunk.len(),
                cfg.max_seq
            );
            for t in 0..chunk.len() {
                row_seq.push(bi);
                row_pos.push(starts[bi] + t);
            }
        }
        let nrows = row_seq.len();
        let row_seq: &[usize] = row_seq.as_slice();
        let row_pos: &[usize] = row_pos.as_slice();
        let max_ctx = row_pos.iter().map(|&p| p + 1).max().unwrap_or(0);
        // the attention fan-out decision is the same for every layer
        let total_ctx: usize = row_pos.iter().map(|&p| p + 1).sum();
        let attn_work = total_ctx * dh * heads * 2; // qk + av mul-adds
        let par = attn_work >= crate::kernels::PAR_MIN_WORK && pool::global().threads() > 1;

        // embeddings straight into the persistent residual buffer
        let tok = self.weights.expect("tok_emb");
        let pos_emb = (cfg.family == Family::Opt).then(|| self.weights.expect("pos_emb"));
        let xs = xs_buf.prepare(nrows, d);
        {
            let mut r = 0usize;
            for (bi, chunk) in chunks.iter().enumerate() {
                for (t, &tokid) in chunk.iter().enumerate() {
                    let x = &mut xs[r];
                    x.copy_from_slice(tok.row(tokid as usize % cfg.vocab));
                    if let Some(pe) = pos_emb {
                        simd::add_assign_t(x, pe.row((starts[bi] + t) % cfg.max_seq), tier);
                    }
                    r += 1;
                }
            }
        }

        for (li, layer) in self.layers.iter().enumerate() {
            // pre-attention norm
            let hs = hs_buf.prepare(nrows, d);
            for (h, x) in hs.iter_mut().zip(xs.iter()) {
                self.norm_into(&layer.ln1, x, h);
            }
            // lint:allow(hot-path-no-alloc) O(batch) slice-ref table per gemm
            // call; steady-state flatness is pinned by tests/alloc_steady.rs.
            let hrefs: Vec<&[f32]> = hs.iter().map(|v| v.as_slice()).collect();
            let qs = self.gemm_slot(layer.q, &hrefs, qs_buf);
            let ks = self.gemm_slot(layer.k, &hrefs, ks_buf);
            let vs = self.gemm_slot(layer.v, &hrefs, vs_buf);
            // rope + append the whole chunk's K/V before any attention
            for r in 0..nrows {
                let (bi, p) = (row_seq[r], row_pos[r]);
                if cfg.family == Family::Llama {
                    rope_vec(&mut qs[r], heads, p);
                    rope_vec(&mut ks[r], heads, p);
                }
                caches[bi].write_kv(li, p, &ks[r], &vs[r]);
            }

            // attention: row at position p attends over cache rows 0..=p
            // (prefix plus the intra-chunk past), one (row, head) work
            // item per head-major strip pair. Items are independent and
            // internally sequential, so the pool fan-out below is
            // bitwise-identical to the sequential loop.
            ctx.clear();
            ctx.resize(nrows * d, 0.0);
            if par {
                let caches_ro: &[&mut KvCache] = &*caches;
                let qs_ro: &[Vec<f32>] = qs;
                let slopes_ro: &[f32] = &slopes;
                let ctx_ptr = CtxWriter(ctx.as_mut_ptr());
                pool::global().scope_chunks(nrows * heads, |range| {
                    // the Fast kernel never materializes scores
                    let score_len = if fast { 0 } else { max_ctx };
                    // lint:allow(hot-path-no-alloc) per-worker score strip,
                    // sized once per fan-out (zero-length on the Fast tier).
                    let mut local_scores = vec![0.0f32; score_len];
                    for it in range {
                        let r = it / heads;
                        let head = it % heads;
                        let (bi, p) = (row_seq[r], row_pos[r]);
                        let cache: &KvCache = &*caches_ro[bi];
                        let base = head * dh;
                        let qh = &qs_ro[r][base..base + dh];
                        // SAFETY: each (row, head) slice is written by
                        // exactly one worker (disjoint item ranges), and
                        // scope_chunks joins before `ctx` is used again.
                        let out = unsafe {
                            std::slice::from_raw_parts_mut(ctx_ptr.0.add(r * d + base), dh)
                        };
                        if fast {
                            fast_math::attn_row_fast(
                                qh,
                                cache.k_strip(li, head, p + 1),
                                cache.v_strip(li, head, p + 1),
                                scale,
                                slopes_ro[head],
                                p,
                                out,
                            );
                        } else {
                            let s = &mut local_scores[..p + 1];
                            attn::qk_dots_t(
                                qh,
                                cache.k_strip(li, head, p + 1),
                                scale,
                                slopes_ro[head],
                                p,
                                s,
                                tier,
                            );
                            softmax(s);
                            attn::av_accumulate_t(s, cache.v_strip(li, head, p + 1), out, tier);
                        }
                    }
                });
            } else {
                scores.clear();
                scores.resize(max_ctx, 0.0);
                for r in 0..nrows {
                    let (bi, p) = (row_seq[r], row_pos[r]);
                    let cache: &KvCache = &*caches[bi];
                    for head in 0..heads {
                        let base = head * dh;
                        let qh = &qs[r][base..base + dh];
                        let out = &mut ctx[r * d + base..r * d + base + dh];
                        if fast {
                            fast_math::attn_row_fast(
                                qh,
                                cache.k_strip(li, head, p + 1),
                                cache.v_strip(li, head, p + 1),
                                scale,
                                slopes[head],
                                p,
                                out,
                            );
                        } else {
                            let s = &mut scores[..p + 1];
                            attn::qk_dots_t(
                                qh,
                                cache.k_strip(li, head, p + 1),
                                scale,
                                slopes[head],
                                p,
                                s,
                                tier,
                            );
                            softmax(s);
                            attn::av_accumulate_t(s, cache.v_strip(li, head, p + 1), out, tier);
                        }
                    }
                }
            }

            // lint:allow(hot-path-no-alloc) O(batch) slice-ref table per gemm
            // call; steady-state flatness is pinned by tests/alloc_steady.rs.
            let crefs: Vec<&[f32]> = ctx.chunks_exact(d).collect();
            let attns = self.gemm_slot(layer.o, &crefs, proj_buf);
            for (x, a) in xs.iter_mut().zip(attns.iter()) {
                simd::add_assign_t(x, a, tier);
            }

            // FFN
            let hs = hs_buf.prepare(nrows, d);
            for (h, x) in hs.iter_mut().zip(xs.iter()) {
                self.norm_into(&layer.ln2, x, h);
            }
            // lint:allow(hot-path-no-alloc) O(batch) slice-ref table per gemm
            // call; steady-state flatness is pinned by tests/alloc_steady.rs.
            let h2refs: Vec<&[f32]> = hs.iter().map(|v| v.as_slice()).collect();
            let ffs = if let Some(gate_slot) = layer.gate {
                let gates = self.gemm_slot(gate_slot, &h2refs, ffa_buf);
                let ups = self.gemm_slot(layer.up, &h2refs, ffb_buf);
                for (g, u) in gates.iter_mut().zip(ups.iter()) {
                    if fast {
                        fast_math::silu_mul_fast(g, u);
                    } else {
                        simd::silu_mul_t(g, u, tier);
                    }
                }
                // lint:allow(hot-path-no-alloc) O(batch) slice-ref table per gemm
                // call; steady-state flatness is pinned by tests/alloc_steady.rs.
                let arefs: Vec<&[f32]> = gates.iter().map(|v| v.as_slice()).collect();
                self.gemm_slot(layer.down, &arefs, proj_buf)
            } else {
                let ups = self.gemm_slot(layer.up, &h2refs, ffb_buf);
                for u in ups.iter_mut() {
                    if fast {
                        fast_math::gelu_map_fast(u);
                    } else {
                        simd::gelu_map_t(u, tier);
                    }
                }
                // lint:allow(hot-path-no-alloc) O(batch) slice-ref table per gemm
                // call; steady-state flatness is pinned by tests/alloc_steady.rs.
                let arefs: Vec<&[f32]> = ups.iter().map(|v| v.as_slice()).collect();
                self.gemm_slot(layer.down, &arefs, proj_buf)
            };
            for (x, f) in xs.iter_mut().zip(ffs.iter()) {
                simd::add_assign_t(x, f, tier);
            }
        }
        for (cache, chunk) in caches.iter_mut().zip(chunks) {
            cache.len += chunk.len();
        }

        // tied-embedding logits through the batched dense kernel: the
        // (vocab × d_model) embedding streams once for the whole call
        if let LogitsWanted::All = wanted {
            let hs = hs_buf.prepare(nrows, d);
            for (h, x) in hs.iter_mut().zip(xs.iter()) {
                self.norm_into(&self.final_norm, x, h);
            }
            // lint:allow(hot-path-no-alloc) O(batch) slice-ref table per gemm
            // call; steady-state flatness is pinned by tests/alloc_steady.rs.
            let xrefs: Vec<&[f32]> = hs.iter().map(|v| v.as_slice()).collect();
            let ys = logits_buf.prepare(nrows, cfg.vocab);
            crate::kernels::gemm_f32(tok, &xrefs, ys);
            // lint:allow(hot-path-no-alloc) all-logits materialization —
            // the perplexity/eval path, not the serving tick.
            let mut out = Vec::with_capacity(nb);
            let mut row = 0usize;
            for chunk in chunks {
                let t = chunk.len();
                // lint:allow(hot-path-no-alloc) eval-path logits tensor.
                let mut data = Vec::with_capacity(t * cfg.vocab);
                for y in &ys[row..row + t] {
                    data.extend_from_slice(y);
                }
                out.push(Tensor::from_vec(t, cfg.vocab, data));
                row += t;
            }
            return out;
        }
        // serving only samples after a chunk's last token — and only for
        // chunks the mask wants; everything else skips the final norm
        // and the vocab-sized projection altogether
        // lint:allow(hot-path-no-alloc) O(batch) mask + row table, once
        // per forward; steady-state pinned by tests/alloc_steady.rs.
        let keep: Vec<bool> = match wanted {
            LogitsWanted::All => unreachable!("handled above"),
            // lint:allow(hot-path-no-alloc) O(batch) mask.
            LogitsWanted::Last => vec![true; nb],
            LogitsWanted::LastIf(mask) => {
                assert_eq!(mask.len(), nb, "forward_core logits-mask length");
                // lint:allow(hot-path-no-alloc) O(batch) mask copy.
                mask.to_vec()
            }
        };
        // lint:allow(hot-path-no-alloc) O(batch) row table.
        let mut last_rows = Vec::new();
        let mut row = 0usize;
        for (chunk, &k) in chunks.iter().zip(&keep) {
            row += chunk.len();
            if k {
                last_rows.push(row - 1);
            }
        }
        let hs = hs_buf.prepare(last_rows.len(), d);
        for (h, &r) in hs.iter_mut().zip(&last_rows) {
            self.norm_into(&self.final_norm, &xs[r], h);
        }
        // lint:allow(hot-path-no-alloc) O(batch) slice-ref table per gemm
        // call; steady-state flatness is pinned by tests/alloc_steady.rs.
        let xrefs: Vec<&[f32]> = hs.iter().map(|v| v.as_slice()).collect();
        let ys = logits_buf.prepare(last_rows.len(), cfg.vocab);
        crate::kernels::gemm_f32(tok, &xrefs, ys);
        let mut ys_iter = ys.iter();
        keep.iter()
            .map(|&k| {
                if k {
                    let y = ys_iter.next().expect("one per kept chunk");
                    Tensor::from_vec(1, cfg.vocab, y.clone())
                } else {
                    Tensor::zeros(0, 0)
                }
            })
            // lint:allow(hot-path-no-alloc) one logits tensor per kept
            // chunk — the call's return value.
            .collect()
    }
}

/// Raw write handle for the threaded attention fan-out: workers own
/// disjoint `(row, head)` slices of the flat context buffer.
struct CtxWriter(*mut f32);
// SAFETY: each attention worker writes only its own disjoint (row, head)
// slice of the context buffer, and the fan-out joins before the buffer is
// read — no aliased writes can ever be observed.
unsafe impl Send for CtxWriter {}
// SAFETY: shared only for disjoint-slice writes — see `Send`.
unsafe impl Sync for CtxWriter {}

/// Which logits a `BackendModel::forward_core` call materializes.
#[derive(Clone, Copy)]
enum LogitsWanted<'a> {
    /// Every position of every chunk (evaluation).
    All,
    /// Each chunk's last position (serving).
    Last,
    /// Last position of masked chunks only; others return empty tensors
    /// (mid-prompt prefill chunks — nothing will sample them).
    LastIf(&'a [bool]),
}

/// Default prompt tokens per core call in [`BackendModel::prefill`]:
/// weight streams per prompt drop `PREFILL_CHUNK`× vs the per-token
/// loop, while the per-call activation working set stays small.
pub const PREFILL_CHUNK: usize = 32;

/// RoPE on a single d_model vector at absolute position `pos`.
pub fn rope_vec(x: &mut [f32], heads: usize, pos: usize) {
    let d = x.len();
    let dh = d / heads;
    let half = dh / 2;
    let posf = pos as f32;
    for h in 0..heads {
        let base = h * dh;
        for i in 0..half {
            let theta = posf * 10000f32.powf(-2.0 * i as f32 / dh as f32);
            let (sin, cos) = theta.sin_cos();
            let a = x[base + 2 * i];
            let b = x[base + 2 * i + 1];
            x[base + 2 * i] = a * cos - b * sin;
            x[base + 2 * i + 1] = a * sin + b * cos;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::random_weights;
    use crate::model::presets;

    fn tiny(family: Family) -> Model {
        let mut cfg = presets::by_name("opt-nano").unwrap();
        cfg.family = family;
        cfg.vocab = 64;
        cfg.max_seq = 32;
        Model::new(cfg.clone(), random_weights(&cfg, 21))
    }

    #[test]
    fn decode_matches_full_forward_all_families() {
        for fam in [Family::Opt, Family::Llama, Family::Bloom] {
            let m = tiny(fam);
            let bm = BackendModel::dense(&m);
            let tokens: Vec<u32> = vec![3, 9, 27, 44, 5, 13, 60, 2];
            // full-sequence reference
            let full = m.forward(&tokens);
            // incremental decode
            let mut cache = KvCache::new(&m.cfg);
            let mut last = Vec::new();
            for &t in &tokens {
                last = bm.decode_step(t, &mut cache);
            }
            let t_last = tokens.len() - 1;
            for c in 0..m.cfg.vocab {
                assert!(
                    (full.get(t_last, c) - last[c]).abs() < 1e-3,
                    "{fam:?} logit {c}: {} vs {}",
                    full.get(t_last, c),
                    last[c]
                );
            }
        }
    }

    #[test]
    fn prefix_snapshot_roundtrips_bitwise() {
        let m = tiny(Family::Opt);
        let bm = BackendModel::dense(&m);
        let tokens: Vec<u32> = vec![3, 9, 27, 44, 5, 13, 60, 2];
        let mut cold = KvCache::new(&m.cfg);
        for &t in &tokens {
            bm.decode_step(t, &mut cold);
        }
        // snapshot the first 5 positions, import into a fresh cache,
        // decode the remaining tokens — logits must match bitwise
        let snap = cold.prefix_clone(5);
        assert_eq!(snap.len, 5);
        assert_eq!(snap.remaining(), 0);
        for layer in 0..m.cfg.layers {
            for pos in 0..5 {
                assert_eq!(snap.k_row(layer, pos), cold.k_row(layer, pos));
                assert_eq!(snap.v_row(layer, pos), cold.v_row(layer, pos));
            }
        }
        let mut warm = KvCache::new(&m.cfg);
        warm.copy_prefix_from(&snap, 5);
        assert_eq!(warm.len, 5);
        let mut cold2 = KvCache::new(&m.cfg);
        let mut want = Vec::new();
        for &t in &tokens {
            want = bm.decode_step(t, &mut cold2);
        }
        let mut got = Vec::new();
        for &t in &tokens[5..] {
            got = bm.decode_step(t, &mut warm);
        }
        assert_eq!(want, got, "imported-prefix logits must match bitwise");
    }

    #[test]
    fn truncate_to_restores_pre_draft_state_bitwise() {
        let m = tiny(Family::Opt);
        let bm = BackendModel::dense(&m);
        let prompt: Vec<u32> = vec![3, 9, 27, 44, 5];
        let mut cache = KvCache::new(&m.cfg);
        for &t in &prompt {
            bm.decode_step(t, &mut cache);
        }
        let pre_len = cache.len;
        let pre_k: Vec<Vec<f32>> = (0..m.cfg.layers)
            .map(|l| (0..pre_len).flat_map(|p| cache.k_row(l, p)).collect())
            .collect();
        let pre_v: Vec<Vec<f32>> = (0..m.cfg.layers)
            .map(|l| (0..pre_len).flat_map(|p| cache.v_row(l, p)).collect())
            .collect();
        // speculate: feed 3 draft tokens, then reject them all
        for &t in &[13u32, 60, 2] {
            bm.decode_step(t, &mut cache);
        }
        cache.truncate_to(pre_len);
        assert_eq!(cache.len, pre_len);
        for l in 0..m.cfg.layers {
            let k_now: Vec<f32> = (0..pre_len).flat_map(|p| cache.k_row(l, p)).collect();
            let v_now: Vec<f32> = (0..pre_len).flat_map(|p| cache.v_row(l, p)).collect();
            assert_eq!(k_now, pre_k[l], "layer {l}: K rows changed under rollback");
            assert_eq!(v_now, pre_v[l], "layer {l}: V rows changed under rollback");
        }
        // continuing after rollback is bitwise identical to a cache that
        // never saw the rejected tokens
        let mut fresh = KvCache::new(&m.cfg);
        for &t in &prompt {
            bm.decode_step(t, &mut fresh);
        }
        let got = bm.decode_step(99, &mut cache);
        let want = bm.decode_step(99, &mut fresh);
        assert_eq!(got, want, "post-rollback logits must match a clean history");
    }

    #[test]
    #[should_panic(expected = "truncate_to")]
    fn truncate_beyond_len_panics() {
        let m = tiny(Family::Opt);
        let mut cache = KvCache::new(&m.cfg);
        cache.truncate_to(1);
    }

    #[test]
    fn forward_chunks_all_matches_sequential_decode_per_position() {
        let m = tiny(Family::Opt);
        let bm = BackendModel::dense(&m);
        // two sequences with warm caches at different positions — the
        // verify call shape: [last_accepted, d1, d2, ...] per sequence
        let histories: [&[u32]; 2] = [&[3, 9, 27], &[44, 5]];
        let verify: [&[u32]; 2] = [&[7, 11, 21], &[8, 2, 33, 4]];
        let mut caches: Vec<KvCache> = (0..2).map(|_| KvCache::new(&m.cfg)).collect();
        let mut seq_caches: Vec<KvCache> = (0..2).map(|_| KvCache::new(&m.cfg)).collect();
        for bi in 0..2 {
            for &t in histories[bi] {
                bm.decode_step(t, &mut caches[bi]);
                bm.decode_step(t, &mut seq_caches[bi]);
            }
        }
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        let all = bm.forward_chunks_all_with(&verify, &mut refs, &mut ForwardScratch::new());
        for bi in 0..2 {
            assert_eq!(all[bi].shape(), (verify[bi].len(), m.cfg.vocab));
            for (t, &tok) in verify[bi].iter().enumerate() {
                let want = bm.decode_step(tok, &mut seq_caches[bi]);
                assert_eq!(
                    all[bi].row(t),
                    want.as_slice(),
                    "seq {bi} position {t}: batched verify logits diverged"
                );
            }
        }
    }

    #[test]
    fn prefill_equals_stepwise() {
        let m = tiny(Family::Opt);
        let bm = BackendModel::dense(&m);
        let tokens: Vec<u32> = vec![1, 2, 3, 4, 5];
        let mut c1 = KvCache::new(&m.cfg);
        let l1 = bm.prefill(&tokens, &mut c1);
        let mut c2 = KvCache::new(&m.cfg);
        let mut l2 = Vec::new();
        for &t in &tokens {
            l2 = bm.decode_step(t, &mut c2);
        }
        assert_eq!(c1.len, c2.len);
        for (a, b) in l1.iter().zip(&l2) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn decode_batch_matches_decode_step_mixed_lengths() {
        for fam in [Family::Opt, Family::Llama, Family::Bloom] {
            let m = tiny(fam);
            let bm = BackendModel::dense(&m);
            // three sequences with different histories/positions
            let prompts: [&[u32]; 3] = [&[3, 9, 27], &[44, 5], &[13, 60, 2, 7, 1]];
            let mut batch_caches: Vec<KvCache> =
                (0..3).map(|_| KvCache::new(&m.cfg)).collect();
            let mut seq_caches: Vec<KvCache> =
                (0..3).map(|_| KvCache::new(&m.cfg)).collect();
            for (bi, prompt) in prompts.iter().enumerate() {
                for &t in prompt.iter() {
                    bm.decode_step(t, &mut batch_caches[bi]);
                    bm.decode_step(t, &mut seq_caches[bi]);
                }
            }
            // two batched steps vs two sequential steps, greedy feedback —
            // the batched side reuses one workspace across steps, which
            // must be invisible in the tokens
            let mut scratch = ForwardScratch::new();
            let mut batch_tokens: Vec<u32> = vec![11, 22, 33];
            let mut seq_tokens = batch_tokens.clone();
            for _ in 0..2 {
                let batch_logits =
                    bm.decode_batch_with(&batch_tokens, &mut batch_caches, &mut scratch);
                for (bi, logits) in batch_logits.iter().enumerate() {
                    let seq_logits = bm.decode_step(seq_tokens[bi], &mut seq_caches[bi]);
                    assert_eq!(
                        logits, &seq_logits,
                        "{fam:?} batched logits diverged from sequential (seq {bi})"
                    );
                    batch_tokens[bi] = crate::coordinator::sampler::argmax(logits);
                    seq_tokens[bi] = crate::coordinator::sampler::argmax(&seq_logits);
                }
                assert_eq!(batch_tokens, seq_tokens);
            }
            for (a, b) in batch_caches.iter().zip(&seq_caches) {
                assert_eq!(a.len, b.len);
            }
        }
    }

    #[test]
    fn decode_batch_of_one_equals_decode_step() {
        let m = tiny(Family::Opt);
        let bm = BackendModel::dense(&m);
        let mut c1 = KvCache::new(&m.cfg);
        let mut c2 = vec![KvCache::new(&m.cfg)];
        for &t in &[5u32, 9, 13] {
            let a = bm.decode_step(t, &mut c1);
            let b = bm.decode_batch(&[t], &mut c2).remove(0);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn kv_cache_head_major_roundtrip() {
        let m = tiny(Family::Opt);
        let cfg = &m.cfg;
        let (heads, dh) = (cfg.heads, cfg.head_dim());
        let mut cache = KvCache::new(cfg);
        let mut rng = crate::util::Rng::new(91);
        let k: Vec<f32> = (0..cfg.d_model).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..cfg.d_model).map(|_| rng.normal_f32()).collect();
        cache.write_kv(1, 3, &k, &v);
        assert_eq!(cache.k_row(1, 3), k, "k scatter/gather roundtrip");
        assert_eq!(cache.v_row(1, 3), v, "v scatter/gather roundtrip");
        // the strip view of head h at position 3 is the head's row slice
        for h in 0..heads {
            let strip = cache.k_strip(1, h, 4);
            assert_eq!(strip.len(), 4 * dh);
            assert_eq!(&strip[3 * dh..4 * dh], &k[h * dh..(h + 1) * dh]);
        }
    }

    #[test]
    fn quantized_backend_runs_and_stays_close() {
        use crate::quant::{quantize_layer, Method, QuantConfig};
        let m = tiny(Family::Opt);
        // quantize every linear against a synthetic Hessian
        let mut rng = crate::util::Rng::new(77);
        let mut layers = HashMap::new();
        for (name, _rows, cols) in m.cfg.all_linears() {
            let acts = Tensor::randn(4 * cols, cols, 1.0, &mut rng);
            let h = crate::quant::gptq::accumulate_hessian(&acts);
            let cfg = QuantConfig { explore_grid: 2, ..QuantConfig::with_bits(4) };
            let q = quantize_layer(m.weights.expect(&name), &h, Method::Gptqt, &cfg).unwrap();
            layers.insert(name, q);
        }
        let bm_q = BackendModel::quantized(&m, layers);
        let bm_f = BackendModel::dense(&m);
        assert!(bm_q.streamed_bytes_per_token() * 4 < bm_f.streamed_bytes_per_token());

        let mut cq = KvCache::new(&m.cfg);
        let mut cf = KvCache::new(&m.cfg);
        let tokens = [7u32, 13, 2, 41];
        let (mut lq, mut lf) = (Vec::new(), Vec::new());
        for &t in &tokens {
            lq = bm_q.decode_step(t, &mut cq);
            lf = bm_f.decode_step(t, &mut cf);
        }
        assert!(lq.iter().all(|v| v.is_finite()));
        // 4-bit quantization on a tiny model: logits close but not equal
        let max_diff = lq
            .iter()
            .zip(&lf)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff > 0.0, "quantization must change something");
        assert!(max_diff < 1.0, "logits diverged: {max_diff}");
    }

    #[test]
    fn fast_numerics_decode_tracks_exact_logits() {
        for fam in [Family::Opt, Family::Llama, Family::Bloom] {
            let m = tiny(fam);
            let exact = BackendModel::dense(&m);
            let fast = BackendModel::dense(&m).with_numerics(NumericsMode::Fast);
            assert_eq!(exact.numerics(), NumericsMode::Exact);
            assert_eq!(fast.numerics(), NumericsMode::Fast);
            let tokens: Vec<u32> = vec![3, 9, 27, 44, 5, 13, 60, 2];
            let mut ce = KvCache::new(&m.cfg);
            let mut cf = KvCache::new(&m.cfg);
            let (mut le, mut lf) = (Vec::new(), Vec::new());
            for &t in &tokens {
                le = exact.decode_step(t, &mut ce);
                lf = fast.decode_step(t, &mut cf);
            }
            let max_diff = le
                .iter()
                .zip(&lf)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_diff < 1e-2,
                "{fam:?} fast-mode logits drifted from exact: {max_diff}"
            );
            assert_eq!(
                crate::coordinator::sampler::argmax(&le),
                crate::coordinator::sampler::argmax(&lf),
                "{fam:?} greedy token diverged between numerics modes"
            );
        }
    }

    #[test]
    fn cache_overflow_panics() {
        let m = tiny(Family::Opt);
        let bm = BackendModel::dense(&m);
        let mut cache = KvCache::new(&m.cfg);
        for i in 0..m.cfg.max_seq {
            bm.decode_step((i % 64) as u32, &mut cache);
        }
        assert_eq!(cache.remaining(), 0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            bm.decode_step(0, &mut cache);
        }));
        assert!(result.is_err());
    }

    #[test]
    fn rope_vec_matches_matrix_rope() {
        let mut rng = crate::util::Rng::new(501);
        let mut mat = Tensor::randn(4, 16, 1.0, &mut rng);
        let orig = mat.clone();
        super::super::forward::rope(&mut mat, 2, 5);
        for t in 0..4 {
            let mut v = orig.row(t).to_vec();
            rope_vec(&mut v, 2, 5 + t);
            for (a, b) in v.iter().zip(mat.row(t)) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }
}
