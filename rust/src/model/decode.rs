//! Single-token decode path with KV cache — the serving hot loop.
//!
//! Every linear layer is a [`Gemv`] backend, so the same loop executes
//! the dense f32 model (`full`), the GPTQ int+dequant model, or the GPTQT
//! fused binary-coded model — Table IV's three contenders — with
//! identical math and different memory traffic.

use super::config::{Family, ModelConfig};
use super::forward::{alibi_slopes, gelu, silu, softmax, LN_EPS};
use super::weights::WeightStore;
use super::Model;
use crate::kernels::{DenseGemv, Gemv};
use crate::quant::QuantizedLayer;
use crate::tensor::Tensor;
use std::collections::HashMap;

/// Per-sequence attention cache: one (max_seq × d_model) K and V buffer
/// per layer, head-major like the forward pass.
pub struct KvCache {
    pub k: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub len: usize,
    max_seq: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache {
            k: (0..cfg.layers).map(|_| Tensor::zeros(cfg.max_seq, cfg.d_model)).collect(),
            v: (0..cfg.layers).map(|_| Tensor::zeros(cfg.max_seq, cfg.d_model)).collect(),
            len: 0,
            max_seq: cfg.max_seq,
        }
    }

    pub fn remaining(&self) -> usize {
        self.max_seq - self.len
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Bytes held by this cache (capacity, not fill level).
    pub fn bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(|t| t.len() * 4).sum()
    }
}

/// A model whose linears are pluggable compute backends.
pub struct BackendModel {
    pub cfg: ModelConfig,
    /// Norm + embedding parameters (never quantized).
    pub weights: WeightStore,
    linears: HashMap<String, Box<dyn Gemv>>,
}

impl BackendModel {
    /// Dense f32 backends straight from a [`Model`] (the `full` row).
    pub fn dense(model: &Model) -> BackendModel {
        let mut linears: HashMap<String, Box<dyn Gemv>> = HashMap::new();
        for (name, _, _) in model.cfg.all_linears() {
            linears.insert(
                name.clone(),
                Box::new(DenseGemv::new(model.weights.expect(&name).clone())),
            );
        }
        BackendModel { cfg: model.cfg.clone(), weights: model.weights.clone(), linears }
    }

    /// Backends from quantized layers: packed binary coding if present
    /// (GPTQT/BCQ → LUT-GEMM), else int weights (GPTQ → dequant), else
    /// the dense dequantized tensor.
    pub fn quantized(model: &Model, mut layers: HashMap<String, QuantizedLayer>) -> BackendModel {
        let mut linears: HashMap<String, Box<dyn Gemv>> = HashMap::new();
        for (name, _, _) in model.cfg.all_linears() {
            let backend: Box<dyn Gemv> = match layers.remove(&name) {
                Some(q) => {
                    if let Some(packed) = q.packed {
                        Box::new(packed)
                    } else if let Some(int) = q.int_weights {
                        Box::new(int)
                    } else {
                        Box::new(DenseGemv::new(q.dequant))
                    }
                }
                None => Box::new(DenseGemv::new(model.weights.expect(&name).clone())),
            };
            linears.insert(name, backend);
        }
        BackendModel { cfg: model.cfg.clone(), weights: model.weights.clone(), linears }
    }

    fn gemv(&self, name: &str, x: &[f32]) -> Vec<f32> {
        let b = self
            .linears
            .get(name)
            .unwrap_or_else(|| panic!("no backend for {name}"));
        let mut y = vec![0.0f32; b.rows()];
        b.gemv(x, &mut y);
        y
    }

    /// Total weight bytes streamed per decoded token — the bandwidth
    /// model behind Table IV (embeddings excluded: shared by all rows).
    pub fn streamed_bytes_per_token(&self) -> usize {
        self.linears.values().map(|b| b.streamed_bytes()).sum()
    }

    /// Label of the dominant backend (for reports).
    pub fn backend_label(&self) -> &'static str {
        self.linears
            .values()
            .next()
            .map(|b| b.label())
            .unwrap_or("empty")
    }

    fn norm(&self, prefix: &str, x: &[f32]) -> Vec<f32> {
        let d = x.len();
        let w = self.weights.expect(&format!("{prefix}.w"));
        match self.cfg.family {
            Family::Llama => {
                let ms = x.iter().map(|&v| v * v).sum::<f32>() / d as f32;
                let inv = 1.0 / (ms + LN_EPS).sqrt();
                x.iter().zip(w.data()).map(|(&v, &wi)| v * inv * wi).collect()
            }
            _ => {
                let b = self.weights.expect(&format!("{prefix}.b"));
                let mean = x.iter().sum::<f32>() / d as f32;
                let var = x.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
                let inv = 1.0 / (var + LN_EPS).sqrt();
                x.iter()
                    .zip(w.data().iter().zip(b.data()))
                    .map(|(&v, (&wi, &bi))| (v - mean) * inv * wi + bi)
                    .collect()
            }
        }
    }

    /// Embed a single token at absolute position `pos`.
    pub fn embed_one(&self, token: u32, pos: usize) -> Vec<f32> {
        let tok = self.weights.expect("tok_emb");
        let mut x = tok.row(token as usize % self.cfg.vocab).to_vec();
        if self.cfg.family == Family::Opt {
            let pemb = self.weights.expect("pos_emb");
            for (v, &p) in x.iter_mut().zip(pemb.row(pos % self.cfg.max_seq)) {
                *v += p;
            }
        }
        x
    }

    /// Run one decode step: consume `token` at position `cache.len`,
    /// append K/V, return the next-token logits.
    pub fn decode_step(&self, token: u32, cache: &mut KvCache) -> Vec<f32> {
        let cfg = &self.cfg;
        let pos = cache.len;
        assert!(pos < cfg.max_seq, "KV cache full");
        let heads = cfg.heads;
        let dh = cfg.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let slopes = if cfg.family == Family::Bloom {
            alibi_slopes(heads)
        } else {
            vec![0.0; heads]
        };

        let mut x = self.embed_one(token, pos);
        for i in 0..cfg.layers {
            let h = self.norm(&format!("L{i}.ln1"), &x);
            let mut q = self.gemv(&format!("L{i}.attn.q"), &h);
            let mut k = self.gemv(&format!("L{i}.attn.k"), &h);
            let v = self.gemv(&format!("L{i}.attn.v"), &h);
            if cfg.family == Family::Llama {
                rope_vec(&mut q, heads, pos);
                rope_vec(&mut k, heads, pos);
            }
            cache.k[i].row_mut(pos).copy_from_slice(&k);
            cache.v[i].row_mut(pos).copy_from_slice(&v);

            let mut ctx = vec![0.0f32; cfg.d_model];
            let mut scores = vec![0.0f32; pos + 1];
            for head in 0..heads {
                let base = head * dh;
                let qh = &q[base..base + dh];
                for (j, s) in scores.iter_mut().enumerate() {
                    let krow = &cache.k[i].row(j)[base..base + dh];
                    let mut dot = 0.0f32;
                    for (a, b) in qh.iter().zip(krow) {
                        dot += a * b;
                    }
                    *s = dot * scale + slopes[head] * (j as f32 - pos as f32);
                }
                softmax(&mut scores);
                let out = &mut ctx[base..base + dh];
                for (j, &p) in scores.iter().enumerate() {
                    let vrow = &cache.v[i].row(j)[base..base + dh];
                    for (o, &vv) in out.iter_mut().zip(vrow) {
                        *o += p * vv;
                    }
                }
            }
            let attn = self.gemv(&format!("L{i}.attn.o"), &ctx);
            for (xv, &a) in x.iter_mut().zip(&attn) {
                *xv += a;
            }

            let h2 = self.norm(&format!("L{i}.ln2"), &x);
            let ff = match cfg.family {
                Family::Llama => {
                    let gate = self.gemv(&format!("L{i}.ff.gate"), &h2);
                    let up = self.gemv(&format!("L{i}.ff.up"), &h2);
                    let act: Vec<f32> =
                        gate.iter().zip(&up).map(|(&g, &u)| silu(g) * u).collect();
                    self.gemv(&format!("L{i}.ff.down"), &act)
                }
                _ => {
                    let up = self.gemv(&format!("L{i}.ff.up"), &h2);
                    let act: Vec<f32> = up.iter().map(|&u| gelu(u)).collect();
                    self.gemv(&format!("L{i}.ff.down"), &act)
                }
            };
            for (xv, &f) in x.iter_mut().zip(&ff) {
                *xv += f;
            }
        }
        cache.len = pos + 1;

        let xf = self.norm("final_ln", &x);
        // tied-embedding logits (fp32 — the paper keeps the head in fp16)
        let tok = self.weights.expect("tok_emb");
        let mut logits = vec![0.0f32; cfg.vocab];
        crate::kernels::gemv_f32(tok, &xf, &mut logits);
        logits
    }

    /// Prefill a prompt (sequential decode steps), returning the logits
    /// after the last prompt token.
    pub fn prefill(&self, tokens: &[u32], cache: &mut KvCache) -> Vec<f32> {
        assert!(!tokens.is_empty());
        let mut logits = Vec::new();
        for &t in tokens {
            logits = self.decode_step(t, cache);
        }
        logits
    }
}

/// RoPE on a single d_model vector at absolute position `pos`.
pub fn rope_vec(x: &mut [f32], heads: usize, pos: usize) {
    let d = x.len();
    let dh = d / heads;
    let half = dh / 2;
    let posf = pos as f32;
    for h in 0..heads {
        let base = h * dh;
        for i in 0..half {
            let theta = posf * 10000f32.powf(-2.0 * i as f32 / dh as f32);
            let (sin, cos) = theta.sin_cos();
            let a = x[base + 2 * i];
            let b = x[base + 2 * i + 1];
            x[base + 2 * i] = a * cos - b * sin;
            x[base + 2 * i + 1] = a * sin + b * cos;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::random_weights;
    use crate::model::presets;

    fn tiny(family: Family) -> Model {
        let mut cfg = presets::by_name("opt-nano").unwrap();
        cfg.family = family;
        cfg.vocab = 64;
        cfg.max_seq = 32;
        Model::new(cfg.clone(), random_weights(&cfg, 21))
    }

    #[test]
    fn decode_matches_full_forward_all_families() {
        for fam in [Family::Opt, Family::Llama, Family::Bloom] {
            let m = tiny(fam);
            let bm = BackendModel::dense(&m);
            let tokens: Vec<u32> = vec![3, 9, 27, 44, 5, 13, 60, 2];
            // full-sequence reference
            let full = m.forward(&tokens);
            // incremental decode
            let mut cache = KvCache::new(&m.cfg);
            let mut last = Vec::new();
            for &t in &tokens {
                last = bm.decode_step(t, &mut cache);
            }
            let t_last = tokens.len() - 1;
            for c in 0..m.cfg.vocab {
                assert!(
                    (full.get(t_last, c) - last[c]).abs() < 1e-3,
                    "{fam:?} logit {c}: {} vs {}",
                    full.get(t_last, c),
                    last[c]
                );
            }
        }
    }

    #[test]
    fn prefill_equals_stepwise() {
        let m = tiny(Family::Opt);
        let bm = BackendModel::dense(&m);
        let tokens: Vec<u32> = vec![1, 2, 3, 4, 5];
        let mut c1 = KvCache::new(&m.cfg);
        let l1 = bm.prefill(&tokens, &mut c1);
        let mut c2 = KvCache::new(&m.cfg);
        let mut l2 = Vec::new();
        for &t in &tokens {
            l2 = bm.decode_step(t, &mut c2);
        }
        assert_eq!(c1.len, c2.len);
        for (a, b) in l1.iter().zip(&l2) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn quantized_backend_runs_and_stays_close() {
        use crate::quant::{quantize_layer, Method, QuantConfig};
        let m = tiny(Family::Opt);
        // quantize every linear against a synthetic Hessian
        let mut rng = crate::util::Rng::new(77);
        let mut layers = HashMap::new();
        for (name, _rows, cols) in m.cfg.all_linears() {
            let acts = Tensor::randn(4 * cols, cols, 1.0, &mut rng);
            let h = crate::quant::gptq::accumulate_hessian(&acts);
            let cfg = QuantConfig { explore_grid: 2, ..QuantConfig::with_bits(4) };
            let q = quantize_layer(m.weights.expect(&name), &h, Method::Gptqt, &cfg).unwrap();
            layers.insert(name, q);
        }
        let bm_q = BackendModel::quantized(&m, layers);
        let bm_f = BackendModel::dense(&m);
        assert!(bm_q.streamed_bytes_per_token() * 4 < bm_f.streamed_bytes_per_token());

        let mut cq = KvCache::new(&m.cfg);
        let mut cf = KvCache::new(&m.cfg);
        let tokens = [7u32, 13, 2, 41];
        let (mut lq, mut lf) = (Vec::new(), Vec::new());
        for &t in &tokens {
            lq = bm_q.decode_step(t, &mut cq);
            lf = bm_f.decode_step(t, &mut cf);
        }
        assert!(lq.iter().all(|v| v.is_finite()));
        // 4-bit quantization on a tiny model: logits close but not equal
        let max_diff = lq
            .iter()
            .zip(&lf)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff > 0.0, "quantization must change something");
        assert!(max_diff < 1.0, "logits diverged: {max_diff}");
    }

    #[test]
    fn cache_overflow_panics() {
        let m = tiny(Family::Opt);
        let bm = BackendModel::dense(&m);
        let mut cache = KvCache::new(&m.cfg);
        for i in 0..m.cfg.max_seq {
            bm.decode_step((i % 64) as u32, &mut cache);
        }
        assert_eq!(cache.remaining(), 0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            bm.decode_step(0, &mut cache);
        }));
        assert!(result.is_err());
    }

    #[test]
    fn rope_vec_matches_matrix_rope() {
        let mut rng = crate::util::Rng::new(501);
        let mut mat = Tensor::randn(4, 16, 1.0, &mut rng);
        let orig = mat.clone();
        super::super::forward::rope(&mut mat, 2, 5);
        for t in 0..4 {
            let mut v = orig.row(t).to_vec();
            rope_vec(&mut v, 2, 5 + t);
            for (a, b) in v.iter().zip(mat.row(t)) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }
}
