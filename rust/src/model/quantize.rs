//! Block-sequential model quantization driver.
//!
//! Mirrors the GPTQ reference flow: blocks are quantized in order, and
//! each block's calibration activations flow through the *already
//! quantized* earlier blocks (two passes per block — one to accumulate
//! Hessians, one to propagate activations with the new weights).
//!
//! Linears sharing an input (q/k/v; gate/up) share one Hessian
//! accumulation — a 2–3× calibration saving with identical results.

use super::forward::Model;
use super::ModelConfig;
use crate::data::TokenSlice;
use crate::quant::{quantize_layer, LayerStats, Method, QuantConfig, QuantizedLayer};
use crate::tensor::linalg::MatF64;
use crate::tensor::Tensor;
use crate::util::{pool, Stopwatch};
use anyhow::Result;
use std::collections::{HashMap, HashSet};

/// Map a linear layer name to its Hessian-sharing key.
fn hessian_key(name: &str) -> String {
    if let Some(stripped) = name.strip_suffix(".attn.k").or_else(|| name.strip_suffix(".attn.v")) {
        return format!("{stripped}.attn.q");
    }
    if let Some(stripped) = name.strip_suffix(".ff.up") {
        // llama: gate/up share input; opt/bloom: up is its own key
        return format!("{stripped}.ff.up"); // canonical — gate aliases here
    }
    if let Some(stripped) = name.strip_suffix(".ff.gate") {
        return format!("{stripped}.ff.up");
    }
    name.to_string()
}

/// Streamed Hessian accumulation `H += 2·XᵀX`, rows parallel.
fn accumulate_into(h: &mut MatF64, acts: &Tensor) {
    let d = acts.cols();
    assert_eq!(h.n, d);
    let h_ptr = HPtr(h.data.as_mut_ptr());
    pool::global().scope_chunks(d, |range| {
        let h_ptr = &h_ptr;
        for i in range {
            // SAFETY: disjoint H rows per chunk.
            let hrow = unsafe { std::slice::from_raw_parts_mut(h_ptr.0.add(i * d), d) };
            for t in 0..acts.rows() {
                let x = acts.row(t);
                let xi = 2.0 * x[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                for (j, &xj) in x.iter().enumerate() {
                    hrow[j] += xi * xj as f64;
                }
            }
        }
    });
}

struct HPtr(*mut f64);
// SAFETY: pool chunks write disjoint H rows and are joined before the
// Hessian buffer is read back.
unsafe impl Sync for HPtr {}
// SAFETY: the pointer outlives the scope — the pool joins before return.
unsafe impl Send for HPtr {}

/// Result of quantizing a whole model.
pub struct QuantizedModel {
    /// Model with every linear replaced by its dequantized weights.
    pub model: Model,
    /// Per-linear packed/int forms for the hot-path backends.
    pub layers: HashMap<String, QuantizedLayer>,
    /// Per-linear diagnostics in processing order.
    pub stats: Vec<(String, LayerStats)>,
    /// Wall-clock seconds for the full pipeline.
    pub seconds: f64,
}

/// Quantize `model` with `method` against calibration token slices.
pub fn quantize_model(
    model: &Model,
    calib: &[TokenSlice],
    method: Method,
    qcfg: &QuantConfig,
    verbose: bool,
) -> Result<QuantizedModel> {
    let sw = Stopwatch::start();
    let cfg: ModelConfig = model.cfg.clone();
    let mut work = Model::new(cfg.clone(), model.weights.clone());

    // per-slice activations entering the current block
    let mut xs: Vec<Tensor> = calib.iter().map(|s| work.embed(&s.tokens, 0)).collect();

    let mut all_layers = HashMap::new();
    let mut all_stats = Vec::new();

    for block in 0..cfg.layers {
        // -- pass 1: Hessians for this block's linears ------------------
        let mut hessians: HashMap<String, MatF64> = HashMap::new();
        for x in &xs {
            let mut seen: HashSet<String> = HashSet::new();
            let mut hook = |name: &str, acts: &Tensor| {
                let key = hessian_key(name);
                if !seen.insert(key.clone()) {
                    return; // q/k/v (or gate/up) already accumulated
                }
                let h = hessians
                    .entry(key)
                    .or_insert_with(|| MatF64::zeros(acts.cols()));
                accumulate_into(h, acts);
            };
            // outputs discarded: weights are still unquantized here
            let _ = work.block_forward(block, x, 0, Some(&mut hook));
        }

        // -- quantize each linear in the block --------------------------
        for (name, _rows, _cols) in cfg.block_linears(block) {
            let key = hessian_key(&name);
            let hessian = hessians
                .get(&key)
                .unwrap_or_else(|| panic!("no hessian for {name} (key {key})"));
            let w = work.weights.expect(&name).clone();
            let q = quantize_layer(&w, hessian, method, qcfg)?;
            if verbose {
                eprintln!(
                    "  [{}] {name}: mse={:.3e} out_err={:.3e} ({:.2}s)",
                    method.name(),
                    q.stats.weight_mse,
                    q.stats.output_err,
                    q.stats.seconds
                );
            }
            work.weights.insert(name.clone(), q.dequant.clone());
            all_stats.push((name.clone(), q.stats.clone()));
            all_layers.insert(name, q);
        }

        // -- pass 2: propagate activations through quantized block ------
        for x in xs.iter_mut() {
            *x = work.block_forward(block, x, 0, None);
        }
    }

    Ok(QuantizedModel {
        model: work,
        layers: all_layers,
        stats: all_stats,
        seconds: sw.elapsed_secs(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{calibration_slices, CorpusGenerator, Dataset};
    use crate::model::init::random_weights;
    use crate::model::presets;

    fn tiny_setup() -> (Model, Vec<TokenSlice>) {
        let mut cfg = presets::by_name("opt-nano").unwrap();
        cfg.vocab = 128;
        cfg.max_seq = 32;
        let model = Model::new(cfg.clone(), random_weights(&cfg, 33));
        let gen = CorpusGenerator::new(Dataset::WikiSyn, 128, 3);
        let stream = gen.generate(2000, 0);
        let calib = calibration_slices(&stream, 4, 24, 5);
        (model, calib)
    }

    #[test]
    fn hessian_key_sharing() {
        assert_eq!(hessian_key("L3.attn.k"), "L3.attn.q");
        assert_eq!(hessian_key("L3.attn.v"), "L3.attn.q");
        assert_eq!(hessian_key("L3.attn.q"), "L3.attn.q");
        assert_eq!(hessian_key("L0.ff.gate"), "L0.ff.up");
        assert_eq!(hessian_key("L0.ff.up"), "L0.ff.up");
        assert_eq!(hessian_key("L0.ff.down"), "L0.ff.down");
        assert_eq!(hessian_key("L1.attn.o"), "L1.attn.o");
    }

    #[test]
    fn accumulate_into_matches_fresh() {
        let mut rng = crate::util::Rng::new(600);
        let acts = Tensor::randn(20, 12, 1.0, &mut rng);
        let fresh = crate::quant::gptq::accumulate_hessian(&acts);
        let mut inc = MatF64::zeros(12);
        accumulate_into(&mut inc, &acts);
        assert!(fresh.max_abs_diff(&inc) < 1e-9);
    }

    #[test]
    fn quantize_model_end_to_end_gptqt() {
        let (model, calib) = tiny_setup();
        let qcfg = QuantConfig { explore_grid: 2, ..QuantConfig::with_bits(3) };
        let qm = quantize_model(&model, &calib, Method::Gptqt, &qcfg, false).unwrap();
        // every linear replaced, packed form present
        for (name, _, _) in model.cfg.all_linears() {
            assert!(qm.layers.contains_key(&name), "missing {name}");
            assert!(qm.layers[&name].packed.is_some(), "{name} not packed");
            assert_ne!(
                qm.model.weights.expect(&name),
                model.weights.expect(&name),
                "{name} unchanged"
            );
        }
        // quantized model still produces finite logits
        let tokens: Vec<u32> = (0..16).map(|i| i % 128).collect();
        let logits = qm.model.forward(&tokens);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantized_ppl_ordering_gptqt_vs_rtn_2bit() {
        // the paper's core claim, miniaturized: at very low bits GPTQT
        // degrades perplexity less than RTN on the same model+data
        let (model, calib) = tiny_setup();
        let gen = CorpusGenerator::new(Dataset::WikiSyn, 128, 3);
        let eval_stream = gen.generate(600, 99);
        let windows = crate::data::eval_windows(&eval_stream, 24, 4);

        let ppl = |m: &Model| {
            let (mut nll, mut n) = (0.0, 0usize);
            for w in &windows {
                let (s, c) = m.nll_window(&w.tokens);
                nll += s;
                n += c;
            }
            (nll / n as f64).exp()
        };

        let qcfg = QuantConfig { explore_grid: 4, ..QuantConfig::with_bits(2) };
        let qm_t = quantize_model(&model, &calib, Method::Gptqt, &qcfg, false).unwrap();
        let qm_r = quantize_model(&model, &calib, Method::Rtn, &qcfg, false).unwrap();
        let (p_full, p_t, p_r) = (ppl(&model), ppl(&qm_t.model), ppl(&qm_r.model));
        assert!(p_t.is_finite() && p_r.is_finite());
        // quantization shouldn't *meaningfully* improve the model it was
        // calibrated on (tiny eval windows leave room for noise-level
        // improvement, hence the 5 % tolerance)
        assert!(p_full <= p_t * 1.05, "full {p_full} ≫ quantized {p_t}?");
        assert!(
            p_t < p_r,
            "GPTQT ppl {p_t} should beat RTN ppl {p_r} (full {p_full})"
        );
    }
}
