//! Model configurations: three decoder-only transformer families
//! mirroring the paper's evaluation models (OPT, Llama2, Bloom), scaled
//! to run on this testbed (see DESIGN.md §2 for the substitution).

/// Architectural family — each reproduces the distinguishing features the
/// paper's results react to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    /// OPT-like: LayerNorm, learned absolute positions, GELU FFN.
    Opt,
    /// Llama-like: RMSNorm, RoPE, SwiGLU gated FFN (the paper notes GPTQ
    /// and BCQ struggle specifically on this family).
    Llama,
    /// Bloom-like: LayerNorm, ALiBi attention bias, GELU FFN.
    Bloom,
}

impl Family {
    pub fn name(&self) -> &'static str {
        match self {
            Family::Opt => "opt",
            Family::Llama => "llama",
            Family::Bloom => "bloom",
        }
    }
}

/// A concrete model configuration.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: &'static str,
    pub family: Family,
    pub vocab: usize,
    pub d_model: usize,
    pub layers: usize,
    pub heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.heads
    }

    /// Approximate parameter count (embeddings + blocks).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let ff = self.d_ff;
        let emb = self.vocab * d
            + if self.family == Family::Opt { self.max_seq * d } else { 0 };
        let attn = 4 * d * d;
        let ffn = match self.family {
            Family::Llama => 3 * d * ff,
            _ => 2 * d * ff,
        };
        let norms = match self.family {
            Family::Llama => 2 * d,
            _ => 4 * d,
        } * self.layers
            + 2 * d;
        emb + self.layers * (attn + ffn) + norms
    }

    /// Names of the quantizable linear layers in block `i`, with their
    /// (rows, cols) shapes. Order matters: it is the GPTQ processing
    /// order within a block.
    pub fn block_linears(&self, i: usize) -> Vec<(String, usize, usize)> {
        let d = self.d_model;
        let ff = self.d_ff;
        let mut v = vec![
            (format!("L{i}.attn.q"), d, d),
            (format!("L{i}.attn.k"), d, d),
            (format!("L{i}.attn.v"), d, d),
            (format!("L{i}.attn.o"), d, d),
        ];
        match self.family {
            Family::Llama => {
                v.push((format!("L{i}.ff.gate"), ff, d));
                v.push((format!("L{i}.ff.up"), ff, d));
                v.push((format!("L{i}.ff.down"), d, ff));
            }
            _ => {
                v.push((format!("L{i}.ff.up"), ff, d));
                v.push((format!("L{i}.ff.down"), d, ff));
            }
        }
        v
    }

    /// All quantizable linears across the model.
    pub fn all_linears(&self) -> Vec<(String, usize, usize)> {
        (0..self.layers).flat_map(|i| self.block_linears(i)).collect()
    }

    /// Canonical weight argument order for the AOT artifacts. MUST match
    /// `weight_order()` in `python/compile/model.py`: the HLO executables
    /// take weights positionally in exactly this order.
    pub fn weight_order(&self) -> Vec<String> {
        let mut v = vec!["tok_emb".to_string()];
        if self.family == Family::Opt {
            v.push("pos_emb".into());
        }
        for i in 0..self.layers {
            v.push(format!("L{i}.ln1.w"));
            if self.family != Family::Llama {
                v.push(format!("L{i}.ln1.b"));
            }
            for (name, _, _) in self.block_linears(i).into_iter().take(4) {
                v.push(name);
            }
            v.push(format!("L{i}.ln2.w"));
            if self.family != Family::Llama {
                v.push(format!("L{i}.ln2.b"));
            }
            for (name, _, _) in self.block_linears(i).into_iter().skip(4) {
                v.push(name);
            }
        }
        v.push("final_ln.w".into());
        if self.family != Family::Llama {
            v.push("final_ln.b".into());
        }
        v
    }
}

/// Model presets.
pub mod presets {
    use super::*;

    /// Shared synthetic vocabulary size (matches the data generators).
    pub const VOCAB: usize = 2048;
    /// Maximum sequence length supported by the artifacts.
    pub const MAX_SEQ: usize = 256;

    macro_rules! preset {
        ($name:literal, $family:expr, $d:expr, $layers:expr, $heads:expr, $ff:expr) => {
            ModelConfig {
                name: $name,
                family: $family,
                vocab: VOCAB,
                d_model: $d,
                layers: $layers,
                heads: $heads,
                d_ff: $ff,
                max_seq: MAX_SEQ,
            }
        };
    }

    /// The OPT-like ladder — the analogue of the paper's 125M→66B sweep
    /// (Table I/III/IV). Sizes are chosen so the biggest still quantizes
    /// and evaluates in seconds on CPU while spanning ~100× in params.
    pub fn opt_ladder() -> Vec<ModelConfig> {
        vec![
            preset!("opt-nano", Family::Opt, 64, 2, 2, 256),
            preset!("opt-micro", Family::Opt, 96, 3, 3, 384),
            preset!("opt-mini", Family::Opt, 128, 4, 4, 512),
            preset!("opt-sm", Family::Opt, 192, 6, 6, 768),
            preset!("opt-md", Family::Opt, 256, 8, 8, 1024),
            preset!("opt-lg", Family::Opt, 384, 10, 8, 1536),
            preset!("opt-xl", Family::Opt, 512, 12, 8, 2048),
        ]
    }

    /// Llama-like pair (Table II left).
    pub fn llama_ladder() -> Vec<ModelConfig> {
        vec![
            preset!("llama-sm", Family::Llama, 192, 6, 6, 512),
            preset!("llama-md", Family::Llama, 256, 8, 8, 688),
        ]
    }

    /// Bloom-like ladder (Table II right).
    pub fn bloom_ladder() -> Vec<ModelConfig> {
        vec![
            preset!("bloom-nano", Family::Bloom, 64, 2, 2, 256),
            preset!("bloom-mini", Family::Bloom, 128, 4, 4, 512),
            preset!("bloom-sm", Family::Bloom, 192, 6, 6, 768),
            preset!("bloom-md", Family::Bloom, 256, 8, 8, 1024),
        ]
    }

    /// Every preset.
    pub fn all() -> Vec<ModelConfig> {
        let mut v = opt_ladder();
        v.extend(llama_ladder());
        v.extend(bloom_ladder());
        v
    }

    /// Look up a preset by name.
    pub fn by_name(name: &str) -> Option<ModelConfig> {
        all().into_iter().find(|c| c.name == name)
    }
}

/// Human-format a parameter count (`1.2M`, `340K`, …).
pub fn fmt_params(n: usize) -> String {
    if n >= 1_000_000_000 {
        format!("{:.1}B", n as f64 / 1e9)
    } else if n >= 1_000_000 {
        format!("{:.1}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.0}K", n as f64 / 1e3)
    } else {
        n.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_is_monotone_in_params() {
        let ladder = presets::opt_ladder();
        for pair in ladder.windows(2) {
            assert!(
                pair[0].param_count() < pair[1].param_count(),
                "{} !< {}",
                pair[0].name,
                pair[1].name
            );
        }
        // ~100× span
        let first = ladder.first().unwrap().param_count();
        let last = ladder.last().unwrap().param_count();
        assert!(last > first * 50, "span too small: {first}..{last}");
    }

    #[test]
    fn by_name_roundtrip() {
        for c in presets::all() {
            assert_eq!(presets::by_name(c.name).unwrap().name, c.name);
        }
        assert!(presets::by_name("gpt-5").is_none());
    }

    #[test]
    fn head_dims_divide() {
        for c in presets::all() {
            assert_eq!(c.d_model % c.heads, 0, "{}", c.name);
            assert!(c.head_dim() % 2 == 0, "{} head_dim must be even for RoPE", c.name);
        }
    }

    #[test]
    fn llama_has_gate() {
        let c = presets::by_name("llama-sm").unwrap();
        let names: Vec<String> = c.block_linears(0).into_iter().map(|(n, _, _)| n).collect();
        assert!(names.iter().any(|n| n.contains("gate")));
        let o = presets::by_name("opt-mini").unwrap();
        let names: Vec<String> = o.block_linears(0).into_iter().map(|(n, _, _)| n).collect();
        assert!(!names.iter().any(|n| n.contains("gate")));
    }

    #[test]
    fn fmt_params_units() {
        assert_eq!(fmt_params(950), "950");
        assert_eq!(fmt_params(1_500), "2K");
        assert_eq!(fmt_params(2_300_000), "2.3M");
        assert_eq!(fmt_params(1_200_000_000), "1.2B");
    }
}
