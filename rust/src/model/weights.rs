//! GQTW — the repo's weight container format (no serde/safetensors
//! offline, so we carry our own tiny, versioned binary format, written by
//! `python/compile/gqtw.py` at train time and read here at run time).
//!
//! Layout (little-endian):
//!
//! ```text
//! magic   [8]  b"GQTW0001"
//! count   u32
//! repeat count times:
//!   name_len u32, name [name_len] utf-8
//!   rows u32, cols u32
//!   data rows*cols f32
//! ```

use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"GQTW0001";

/// A named collection of tensors.
#[derive(Clone, Default)]
pub struct WeightStore {
    tensors: HashMap<String, Tensor>,
    /// insertion order, for deterministic serialization
    order: Vec<String>,
}

impl WeightStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        let name = name.into();
        if !self.tensors.contains_key(&name) {
            self.order.push(name.clone());
        }
        self.tensors.insert(name, t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }

    /// Get or panic with a helpful message — model code paths use this
    /// because a missing tensor is a build error, not a runtime condition.
    pub fn expect(&self, name: &str) -> &Tensor {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("weight `{name}` missing from store (have {})", self.len()))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tensors.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.order.iter().map(|s| s.as_str())
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.tensors.values().map(|t| t.len()).sum()
    }

    /// Serialize to GQTW bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.order.len() as u32).to_le_bytes());
        for name in &self.order {
            let t = &self.tensors[name];
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&(t.rows() as u32).to_le_bytes());
            out.extend_from_slice(&(t.cols() as u32).to_le_bytes());
            for &v in t.data() {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Parse GQTW bytes.
    pub fn from_bytes(bytes: &[u8]) -> Result<WeightStore> {
        let mut cur = bytes;
        let mut read_exact = |n: usize| -> Result<&[u8]> {
            if cur.len() < n {
                bail!("truncated GQTW file");
            }
            let (head, tail) = cur.split_at(n);
            cur = tail;
            Ok(head)
        };
        let magic = read_exact(8)?;
        if magic != MAGIC {
            bail!("bad GQTW magic: {magic:?}");
        }
        let count = u32::from_le_bytes(read_exact(4)?.try_into().unwrap()) as usize;
        let mut store = WeightStore::new();
        for _ in 0..count {
            let name_len = u32::from_le_bytes(read_exact(4)?.try_into().unwrap()) as usize;
            if name_len > 4096 {
                bail!("implausible name length {name_len}");
            }
            let name = std::str::from_utf8(read_exact(name_len)?)
                .context("weight name not utf-8")?
                .to_string();
            let rows = u32::from_le_bytes(read_exact(4)?.try_into().unwrap()) as usize;
            let cols = u32::from_le_bytes(read_exact(4)?.try_into().unwrap()) as usize;
            let n = rows
                .checked_mul(cols)
                .context("tensor size overflow")?;
            let raw = read_exact(n * 4)?;
            let mut data = Vec::with_capacity(n);
            for chunk in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes(chunk.try_into().unwrap()));
            }
            store.insert(name, Tensor::from_vec(rows, cols, data));
        }
        Ok(store)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<WeightStore> {
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {}", path.as_ref().display()))?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        Self::from_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_bytes() {
        let mut rng = Rng::new(401);
        let mut s = WeightStore::new();
        s.insert("a", Tensor::randn(3, 5, 1.0, &mut rng));
        s.insert("b.c/d", Tensor::randn(7, 2, 0.5, &mut rng));
        s.insert("empty", Tensor::zeros(0, 4));
        let bytes = s.to_bytes();
        let back = WeightStore::from_bytes(&bytes).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.get("a").unwrap(), s.get("a").unwrap());
        assert_eq!(back.get("b.c/d").unwrap(), s.get("b.c/d").unwrap());
        assert_eq!(back.get("empty").unwrap().shape(), (0, 4));
    }

    #[test]
    fn roundtrip_file() {
        let mut rng = Rng::new(402);
        let mut s = WeightStore::new();
        s.insert("w", Tensor::randn(16, 16, 1.0, &mut rng));
        let path = std::env::temp_dir().join("gqtw_test.bin");
        s.save(&path).unwrap();
        let back = WeightStore::load(&path).unwrap();
        assert_eq!(back.get("w").unwrap(), s.get("w").unwrap());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_garbage() {
        assert!(WeightStore::from_bytes(b"not a weight file").is_err());
        assert!(WeightStore::from_bytes(b"GQTW0001").is_err()); // truncated count
        let mut bad = MAGIC.to_vec();
        bad.extend_from_slice(&1u32.to_le_bytes());
        bad.extend_from_slice(&u32::MAX.to_le_bytes()); // absurd name len
        assert!(WeightStore::from_bytes(&bad).is_err());
    }

    #[test]
    fn insertion_order_is_preserved() {
        let mut s = WeightStore::new();
        s.insert("z", Tensor::zeros(1, 1));
        s.insert("a", Tensor::zeros(1, 1));
        s.insert("m", Tensor::zeros(1, 1));
        let names: Vec<&str> = s.names().collect();
        assert_eq!(names, vec!["z", "a", "m"]);
        let back = WeightStore::from_bytes(&s.to_bytes()).unwrap();
        let names2: Vec<&str> = back.names().collect();
        assert_eq!(names2, vec!["z", "a", "m"]);
    }

    #[test]
    fn overwrite_keeps_single_entry() {
        let mut s = WeightStore::new();
        s.insert("w", Tensor::zeros(1, 1));
        s.insert("w", Tensor::zeros(2, 2));
        assert_eq!(s.len(), 1);
        assert_eq!(s.get("w").unwrap().shape(), (2, 2));
    }
}
