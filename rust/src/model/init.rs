//! Weight initialization — the fallback when no trained GQTW artifact is
//! present (unit tests, quick experiments). Scaled-normal init in the
//! GPT-2 style: `σ = 0.02`, residual projections scaled by `1/√(2L)`.

use super::config::{Family, ModelConfig};
use super::weights::WeightStore;
use crate::tensor::Tensor;
use crate::util::Rng;

/// Build a randomly initialized weight store for a config.
pub fn random_weights(cfg: &ModelConfig, seed: u64) -> WeightStore {
    let mut rng = Rng::new(seed ^ 0x11A7_57A7);
    let d = cfg.d_model;
    let sigma = 0.02f32;
    let resid_sigma = sigma / ((2 * cfg.layers) as f32).sqrt();
    let mut w = WeightStore::new();

    w.insert("tok_emb", Tensor::randn(cfg.vocab, d, sigma, &mut rng));
    if cfg.family == Family::Opt {
        w.insert("pos_emb", Tensor::randn(cfg.max_seq, d, sigma, &mut rng));
    }
    for i in 0..cfg.layers {
        w.insert(format!("L{i}.ln1.w"), ones(1, d));
        if cfg.family != Family::Llama {
            w.insert(format!("L{i}.ln1.b"), Tensor::zeros(1, d));
        }
        w.insert(format!("L{i}.ln2.w"), ones(1, d));
        if cfg.family != Family::Llama {
            w.insert(format!("L{i}.ln2.b"), Tensor::zeros(1, d));
        }
        for (name, rows, cols) in cfg.block_linears(i) {
            // residual-writing projections (attn.o, ff.down) get the
            // depth-scaled init
            let s = if name.ends_with(".o") || name.ends_with(".down") {
                resid_sigma
            } else {
                sigma
            };
            w.insert(name, Tensor::randn(rows, cols, s, &mut rng));
        }
    }
    w.insert("final_ln.w", ones(1, d));
    if cfg.family != Family::Llama {
        w.insert("final_ln.b", Tensor::zeros(1, d));
    }
    w
}

fn ones(rows: usize, cols: usize) -> Tensor {
    Tensor::from_vec(rows, cols, vec![1.0; rows * cols])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;

    #[test]
    fn covers_all_linears_and_norms() {
        for name in ["opt-nano", "llama-sm", "bloom-nano"] {
            let cfg = presets::by_name(name).unwrap();
            let w = random_weights(&cfg, 1);
            for (lname, rows, cols) in cfg.all_linears() {
                let t = w.get(&lname).unwrap_or_else(|| panic!("{name}: missing {lname}"));
                assert_eq!(t.shape(), (rows, cols), "{name}:{lname}");
            }
            assert!(w.contains("tok_emb"));
            assert_eq!(w.contains("pos_emb"), cfg.family == Family::Opt);
        }
    }

    #[test]
    fn weight_order_covers_exactly_the_store() {
        for name in ["opt-nano", "llama-sm", "bloom-nano"] {
            let cfg = presets::by_name(name).unwrap();
            let w = random_weights(&cfg, 2);
            let order = cfg.weight_order();
            assert_eq!(order.len(), w.len(), "{name}: order/store size mismatch");
            for o in &order {
                assert!(w.contains(o), "{name}: order names missing tensor {o}");
            }
        }
    }

    #[test]
    fn deterministic() {
        let cfg = presets::by_name("opt-nano").unwrap();
        let a = random_weights(&cfg, 7);
        let b = random_weights(&cfg, 7);
        assert_eq!(a.get("L0.attn.q"), b.get("L0.attn.q"));
    }

    #[test]
    fn param_count_close_to_config_estimate() {
        let cfg = presets::by_name("opt-mini").unwrap();
        let w = random_weights(&cfg, 3);
        let actual = w.param_count();
        let estimate = cfg.param_count();
        let ratio = actual as f64 / estimate as f64;
        assert!((0.9..1.1).contains(&ratio), "{actual} vs {estimate}");
    }
}
