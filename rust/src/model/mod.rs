//! Model substrate: transformer configs (OPT/Llama/Bloom-like families),
//! the GQTW weight container, random init, the reference f32 forward pass
//! and the backend-pluggable decode path.
//!
//! The paper's HuggingFace checkpoints are unavailable offline; models
//! here are trained in-repo by `python/compile/train.py` on the synthetic
//! corpora and saved as `artifacts/<name>.gqtw` (DESIGN.md §2).

pub mod config;
pub mod decode;
pub mod forward;
pub mod init;
pub mod quantize;
pub mod weights;

pub use config::{fmt_params, presets, Family, ModelConfig};
pub use decode::{BackendModel, ForwardScratch, KvCache};
pub use forward::Model;
pub use weights::WeightStore;

use anyhow::{Context, Result};
use std::path::Path;

/// Load a preset model's trained weights from `artifacts/`, falling back
/// to deterministic random init when the artifact is absent (tests,
/// smoke runs). Returns the model and whether trained weights were found.
pub fn load_or_init(name: &str, artifacts_dir: impl AsRef<Path>, seed: u64) -> Result<(Model, bool)> {
    let cfg = presets::by_name(name).with_context(|| format!("unknown model preset `{name}`"))?;
    let path = artifacts_dir.as_ref().join(format!("{name}.gqtw"));
    if path.exists() {
        let weights = WeightStore::load(&path)?;
        // sanity: every expected tensor present
        for (lname, rows, cols) in cfg.all_linears() {
            let t = weights
                .get(&lname)
                .with_context(|| format!("{}: missing {lname}", path.display()))?;
            anyhow::ensure!(
                t.shape() == (rows, cols),
                "{lname}: artifact shape {:?} != config {:?}",
                t.shape(),
                (rows, cols)
            );
        }
        Ok((Model::new(cfg, weights), true))
    } else {
        let weights = init::random_weights(&cfg, seed);
        Ok((Model::new(cfg, weights), false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_or_init_falls_back() {
        let (m, trained) = load_or_init("opt-nano", "/nonexistent-dir", 1).unwrap();
        assert!(!trained);
        assert_eq!(m.cfg.name, "opt-nano");
    }

    #[test]
    fn unknown_preset_errors() {
        assert!(load_or_init("opt-1t", "/tmp", 1).is_err());
    }

    #[test]
    fn roundtrip_through_artifact() {
        let dir = std::env::temp_dir().join("gptqt_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = presets::by_name("opt-nano").unwrap();
        let w = init::random_weights(&cfg, 5);
        w.save(dir.join("opt-nano.gqtw")).unwrap();
        let (m, trained) = load_or_init("opt-nano", &dir, 0).unwrap();
        assert!(trained);
        assert_eq!(m.weights.get("tok_emb"), w.get("tok_emb"));
        let _ = std::fs::remove_dir_all(dir);
    }
}
