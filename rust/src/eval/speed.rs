//! Token-generation speed measurement (Table IV), single-sequence and
//! batched.
//!
//! Protocol mirrors §III-E: generate a fixed number of tokens at batch 1
//! and report mean seconds/token. The three contenders are the three
//! weight formats on the same architecture:
//!
//! * `full`   — dense f32 ([`DenseGemv`]),
//! * `GPTQ 2` — int codes + on-the-fly dequant ([`IntLayer`]),
//! * `GPTQT 3`— fused binary coding via LUT-GEMM ([`PackedBcLayer`]).
//!
//! [`measure_decode_batch`] extends the protocol to B concurrent
//! sequences through [`BackendModel::decode_batch`]: one batched step
//! decodes B tokens while streaming the weights once, so the reported
//! amortized weight traffic is `streamed_bytes_per_token / B` — the
//! serving-side win the batched kernels exist for.
//!
//! [`measure_prefill`] covers the prompt phase: chunked multi-token
//! prefill ([`BackendModel::prefill_batch`]) against the legacy
//! per-token loop, reporting prompt tokens/sec and time-to-first-token.
//!
//! Weight *values* are irrelevant for timing, so quantized forms are
//! synthesized directly (RTN codes / random sign patterns) — this keeps
//! the big timing-only ladder entries (opt-lg/xl) cheap to set up.

use crate::coordinator::{
    CpuBackend, Engine, EngineConfig, Event, PrefixCacheConfig, Request, SchedulePolicyKind,
    Server, SpeculativeBackend,
};
use crate::kernels::NumericsMode;
use crate::model::{BackendModel, KvCache, Model, ModelConfig};
use crate::quant::fuse::FusedRow;
use crate::quant::linear::{rtn_quantize, IntLayer};
use crate::quant::pack::PackedBcLayer;
use crate::quant::QuantizedLayer;
use crate::util::time::now;
use crate::util::{Rng, Stopwatch};
use std::collections::HashMap;
use std::time::Instant;

/// Which weight format to time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpeedVariant {
    Full,
    GptqInt { bits: u32 },
    GptqtLut { bits: u32 },
}

impl SpeedVariant {
    pub fn label(&self) -> String {
        match self {
            SpeedVariant::Full => "full (fp32)".into(),
            SpeedVariant::GptqInt { bits } => format!("GPTQ {bits}-bit dequant"),
            SpeedVariant::GptqtLut { bits } => format!("GPTQT {bits}-bit LUT"),
        }
    }
}

/// Build a backend model of the requested variant with synthesized
/// quantized layers (values arbitrary, formats faithful).
pub fn build_variant(model: &Model, variant: SpeedVariant, seed: u64) -> BackendModel {
    match variant {
        SpeedVariant::Full => BackendModel::dense(model),
        SpeedVariant::GptqInt { bits } => {
            let mut layers = HashMap::new();
            for (name, _, _) in model.cfg.all_linears() {
                let w = model.weights.expect(&name);
                let (q, grids) = rtn_quantize(w, bits);
                let il = IntLayer::encode(&q, &grids, bits);
                layers.insert(
                    name,
                    QuantizedLayer {
                        dequant: q,
                        packed: None,
                        int_weights: Some(il),
                        stats: Default::default(),
                    },
                );
            }
            BackendModel::quantized(model, layers)
        }
        SpeedVariant::GptqtLut { bits } => {
            let mut rng = Rng::new(seed);
            let mut layers = HashMap::new();
            for (name, rows, cols) in model.cfg.all_linears() {
                let planes = bits as usize;
                let fused: Vec<FusedRow> = (0..rows)
                    .map(|_| FusedRow {
                        alphas: (0..planes).map(|p| 0.02 / (1 << p) as f32).collect(),
                        bias: 0.0,
                    })
                    .collect();
                let patterns: Vec<Vec<u32>> = (0..rows)
                    .map(|_| (0..cols).map(|_| rng.below(1 << planes) as u32).collect())
                    .collect();
                let packed = PackedBcLayer::pack(rows, cols, &fused, &patterns);
                layers.insert(
                    name,
                    QuantizedLayer {
                        dequant: packed.dequant(),
                        packed: Some(packed),
                        int_weights: None,
                        stats: Default::default(),
                    },
                );
            }
            BackendModel::quantized(model, layers)
        }
    }
}

/// Timing result for one (model, variant) pair.
#[derive(Debug, Clone)]
pub struct SpeedResult {
    pub model: String,
    pub variant: SpeedVariant,
    pub ms_per_token: f64,
    pub tokens: usize,
    pub streamed_mb_per_token: f64,
}

/// Measure mean per-token decode latency: prompt of `prompt_len`, then
/// `gen_tokens` timed decode steps (prompt excluded from timing).
pub fn measure_decode(
    cfg: &ModelConfig,
    bm: &BackendModel,
    variant: SpeedVariant,
    prompt_len: usize,
    gen_tokens: usize,
    seed: u64,
) -> SpeedResult {
    let mut rng = Rng::new(seed);
    let mut cache = KvCache::new(cfg);
    let mut last = 3u32;
    for _ in 0..prompt_len {
        let tok = 3 + rng.below((cfg.vocab - 3) as u64) as u32;
        bm.decode_step(tok, &mut cache);
        last = tok;
    }
    let sw = Stopwatch::start();
    for _ in 0..gen_tokens {
        let logits = bm.decode_step(last, &mut cache);
        last = crate::coordinator::sampler::argmax(&logits);
    }
    let secs = sw.elapsed_secs();
    SpeedResult {
        model: cfg.name.to_string(),
        variant,
        ms_per_token: secs * 1e3 / gen_tokens as f64,
        tokens: gen_tokens,
        streamed_mb_per_token: bm.streamed_bytes_per_token() as f64 / 1e6,
    }
}

/// Timing result for one (model, variant, batch) cell.
#[derive(Debug, Clone)]
pub struct BatchSpeedResult {
    pub model: String,
    pub variant: SpeedVariant,
    pub batch: usize,
    /// Wall-clock ms per batched decode step (each step emits `batch`
    /// tokens).
    pub ms_per_step: f64,
    /// Generated tokens per second summed over the batch — the serving
    /// throughput this configuration sustains.
    pub tokens_per_sec: f64,
    /// Total tokens generated during the timed window.
    pub tokens: usize,
    /// Weight MB streamed per *generated token*, amortized over the
    /// batch (`streamed_bytes_per_token / batch`).
    pub amortized_mb_per_token: f64,
    /// Heap allocation events per timed step. Always 0 unless the
    /// calling binary installs [`crate::util::alloc::CountingAllocator`]
    /// as its global allocator (the steady-state regression test does);
    /// under that allocator the figure is exact and must stay flat
    /// across windows.
    pub allocs_per_step: f64,
}

/// Measure batched decode throughput: prefill `batch` independent
/// sequences with `prompt_len` random tokens each (untimed), then run
/// `gen_steps` timed [`BackendModel::decode_batch`] steps. Like
/// [`measure_decode`], the first timed step re-feeds each sequence's
/// last prompt token (token values are irrelevant for timing);
/// subsequent steps use greedy feedback. `batch == 1` matches the
/// sequential protocol exactly.
pub fn measure_decode_batch(
    cfg: &ModelConfig,
    bm: &BackendModel,
    variant: SpeedVariant,
    batch: usize,
    prompt_len: usize,
    gen_steps: usize,
    seed: u64,
) -> BatchSpeedResult {
    assert!(batch >= 1 && gen_steps >= 1);
    assert!(prompt_len + gen_steps <= cfg.max_seq, "exceeds KV capacity");
    let mut rng = Rng::new(seed);
    let mut caches: Vec<KvCache> = (0..batch).map(|_| KvCache::new(cfg)).collect();
    let mut lasts: Vec<u32> = vec![3; batch];
    for (cache, last) in caches.iter_mut().zip(lasts.iter_mut()) {
        for _ in 0..prompt_len {
            let tok = 3 + rng.below((cfg.vocab - 3) as u64) as u32;
            bm.decode_step(tok, cache);
            *last = tok;
        }
    }
    // one workspace across the timed steps — the zero-alloc steady state
    let mut scratch = crate::model::ForwardScratch::new();
    let a0 = crate::util::alloc::snapshot();
    let sw = Stopwatch::start();
    for _ in 0..gen_steps {
        let logits = bm.decode_batch_with(&lasts, &mut caches, &mut scratch);
        for (last, l) in lasts.iter_mut().zip(&logits) {
            *last = crate::coordinator::sampler::argmax(l);
        }
    }
    let secs = sw.elapsed_secs();
    let a1 = crate::util::alloc::snapshot();
    let tokens = gen_steps * batch;
    BatchSpeedResult {
        model: cfg.name.to_string(),
        variant,
        batch,
        ms_per_step: secs * 1e3 / gen_steps as f64,
        tokens_per_sec: tokens as f64 / secs.max(1e-12),
        tokens,
        amortized_mb_per_token: bm.streamed_bytes_per_token() as f64 / batch as f64 / 1e6,
        allocs_per_step: a1.allocs_since(&a0) as f64 / gen_steps as f64,
    }
}

/// Timing result for one (model, variant, batch, prompt, chunk) prefill
/// cell.
#[derive(Debug, Clone)]
pub struct PrefillSpeedResult {
    pub model: String,
    pub variant: SpeedVariant,
    pub batch: usize,
    pub prompt_len: usize,
    /// Prompt tokens per core call; 0 marks the per-token baseline.
    pub chunk: usize,
    /// Prompt tokens processed per second, summed over the batch.
    pub tokens_per_sec: f64,
    /// Mean time-to-first-token across the batch, ms (time until each
    /// sequence's last prompt-token logits were available).
    pub ttft_ms: f64,
}

/// Measure prefill throughput for `batch` sequences of `prompt_len`
/// random tokens each.
///
/// `chunk == 0` runs the pre-chunking baseline — a sequential
/// [`BackendModel::decode_step`] loop per sequence, streaming every
/// weight once **per prompt token per sequence**. `chunk >= 1` runs
/// [`BackendModel::prefill_batch`]: each round advances every sequence
/// by `chunk` tokens through one shared forward, so each linear streams
/// its weights once per `batch × chunk` prompt tokens — the
/// O(prompt_len) → O(prompt_len / chunk) weight-stream reduction the
/// chunk-major core exists for. Logits are bit-identical either way.
pub fn measure_prefill(
    cfg: &ModelConfig,
    bm: &BackendModel,
    variant: SpeedVariant,
    batch: usize,
    prompt_len: usize,
    chunk: usize,
    seed: u64,
) -> PrefillSpeedResult {
    assert!(batch >= 1 && prompt_len >= 1);
    assert!(prompt_len <= cfg.max_seq, "prompt exceeds KV capacity");
    let mut rng = Rng::new(seed);
    let prompts: Vec<Vec<u32>> = (0..batch)
        .map(|_| {
            (0..prompt_len)
                .map(|_| 3 + rng.below((cfg.vocab - 3) as u64) as u32)
                .collect()
        })
        .collect();
    let mut caches: Vec<KvCache> = (0..batch).map(|_| KvCache::new(cfg)).collect();
    let sw = Stopwatch::start();
    let mut ttft_sum = 0.0f64;
    if chunk == 0 {
        for (prompt, cache) in prompts.iter().zip(caches.iter_mut()) {
            for &t in prompt {
                bm.decode_step(t, cache);
            }
            ttft_sum += sw.elapsed_secs();
        }
    } else {
        let prefs: Vec<&[u32]> = prompts.iter().map(|p| p.as_slice()).collect();
        bm.prefill_batch(&prefs, &mut caches, chunk);
        // all sequences finish together in the shared-forward mode
        ttft_sum = sw.elapsed_secs() * batch as f64;
    }
    let secs = sw.elapsed_secs();
    for cache in &caches {
        assert_eq!(cache.len, prompt_len, "prefill left a cache short");
    }
    PrefillSpeedResult {
        model: cfg.name.to_string(),
        variant,
        batch,
        prompt_len,
        chunk,
        tokens_per_sec: (batch * prompt_len) as f64 / secs.max(1e-12),
        ttft_ms: ttft_sum / batch as f64 * 1e3,
    }
}

/// Timing result for the streaming-server protocol: client-observed
/// latency through the full session stack (queue → engine thread →
/// per-request event channels), not just raw kernel time.
#[derive(Debug, Clone)]
pub struct StreamSpeedResult {
    pub model: String,
    pub variant: SpeedVariant,
    pub requests: usize,
    /// Total tokens streamed across all requests.
    pub tokens: usize,
    /// Streamed tokens per wall-clock second (submit → last terminal).
    pub tokens_per_sec: f64,
    /// Mean time-to-first-token across requests, ms (from submit).
    pub ttft_ms: f64,
    /// Mean gap between consecutive streamed tokens of a request, ms —
    /// the §III-E quantity as a client actually observes it.
    pub inter_token_ms: f64,
    /// Cancellations recorded by the engine (should be 0 here; surfaced
    /// from the metrics summary as a sanity check).
    pub cancelled: u64,
    /// Fault-containment counters of the run — all zero in a healthy
    /// bench; tagged into the `serve stream` records so a perf number
    /// produced by a degraded run is visible in the trajectory.
    pub robustness: crate::bench::RobustnessTags,
}

/// Measure end-to-end streaming latency: spawn a [`Server`] over `bm`,
/// submit `requests` greedy requests of `prompt_len` random prompt
/// tokens each, and consume every [`Event::Token`] as it arrives.
/// TTFT and inter-token gaps are computed from the tokens' `t_emit`
/// stamps, so buffering in the consumer loop does not distort them.
/// EOS is disabled so each request streams exactly `gen_tokens`.
/// `numerics` selects the kernel tier the engine serves under
/// ([`EngineConfig::numerics`]) — the speed benches race `fast` vs
/// `exact` through this.
#[allow(clippy::too_many_arguments)]
pub fn measure_streaming(
    cfg: &ModelConfig,
    bm: BackendModel,
    variant: SpeedVariant,
    requests: usize,
    prompt_len: usize,
    gen_tokens: usize,
    policy: SchedulePolicyKind,
    numerics: NumericsMode,
    seed: u64,
) -> StreamSpeedResult {
    assert!(requests >= 1 && prompt_len >= 1 && gen_tokens >= 1);
    assert!(prompt_len + gen_tokens <= cfg.max_seq, "exceeds KV capacity");
    let mut rng = Rng::new(seed);
    let server = Server::spawn(
        CpuBackend(bm),
        EngineConfig {
            max_batch: requests,
            policy,
            eos_token: u32::MAX, // deterministic token counts
            numerics,
            ..Default::default()
        },
    );
    let t_submit = now();
    let handles: Vec<_> = (0..requests as u64)
        .map(|id| {
            let prompt: Vec<u32> = (0..prompt_len)
                .map(|_| 3 + rng.below((cfg.vocab - 3) as u64) as u32)
                .collect();
            server.submit(Request::new(id, prompt, gen_tokens))
        })
        .collect();
    let mut tokens = 0usize;
    let mut ttft_sum = 0.0f64;
    let mut gap_sum = 0.0f64;
    let mut gaps = 0usize;
    let mut t_done = t_submit;
    for h in handles {
        let mut last: Option<Instant> = None;
        for ev in h.events() {
            match ev {
                Event::Token { t_emit, .. } => {
                    tokens += 1;
                    match last {
                        None => ttft_sum += t_emit.duration_since(t_submit).as_secs_f64(),
                        Some(prev) => {
                            gap_sum += t_emit.duration_since(prev).as_secs_f64();
                            gaps += 1;
                        }
                    }
                    last = Some(t_emit);
                    t_done = t_done.max(t_emit);
                }
                Event::Finished(_) | Event::Rejected { .. } | Event::Started { .. } => {}
            }
        }
    }
    let secs = t_done.duration_since(t_submit).as_secs_f64();
    let metrics = server.shutdown();
    StreamSpeedResult {
        model: cfg.name.to_string(),
        variant,
        requests,
        tokens,
        tokens_per_sec: tokens as f64 / secs.max(1e-12),
        ttft_ms: ttft_sum / requests as f64 * 1e3,
        inter_token_ms: if gaps == 0 { 0.0 } else { gap_sum / gaps as f64 * 1e3 },
        cancelled: metrics.cancelled_total,
        robustness: crate::bench::RobustnessTags::from_metrics(&metrics),
    }
}

/// Timing result for the speculative-serving protocol: effective
/// throughput plus the acceptance counters that explain it.
#[derive(Debug, Clone)]
pub struct SpecStreamResult {
    pub model: String,
    /// Draft/target pair label (e.g. `"lut2->lut3"`).
    pub pair: String,
    pub requests: usize,
    /// Total tokens streamed across all requests.
    pub tokens: usize,
    /// Streamed tokens per wall-clock second — the *effective* rate
    /// speculation is judged by (each verify pass emits 1..=k+1
    /// tokens for one target weight stream).
    pub tokens_per_sec: f64,
    /// Fraction of drafted tokens the target accepted.
    pub acceptance_rate: f64,
    pub drafted: u64,
    pub accepted: u64,
    pub rolled_back: u64,
    /// Mean emitted tokens per draft/verify round (≥ 1; the weight-
    /// stream amortization factor speculation achieved).
    pub tokens_per_round: f64,
    /// Fault-containment counters of the run (see
    /// [`StreamSpeedResult::robustness`]).
    pub robustness: crate::bench::RobustnessTags,
}

/// Measure end-to-end speculative streaming: spawn a [`Server`] over a
/// [`SpeculativeBackend`] draft/target pair and stream greedy requests
/// (speculation only engages for greedy sampling — the acceptance rule
/// is argmax-based). Reports effective tokens/sec plus the acceptance
/// counters; compare against [`measure_streaming`] over the same
/// target model to see what the draft bought. Greedy output is
/// token-identical to the target-only run by construction.
#[allow(clippy::too_many_arguments)]
pub fn measure_spec_streaming(
    cfg: &ModelConfig,
    draft: BackendModel,
    target: BackendModel,
    pair: &str,
    requests: usize,
    prompt_len: usize,
    gen_tokens: usize,
    k: usize,
    numerics: NumericsMode,
    seed: u64,
) -> SpecStreamResult {
    assert!(requests >= 1 && prompt_len >= 1 && gen_tokens >= 1 && k >= 1);
    assert!(prompt_len + gen_tokens <= cfg.max_seq, "exceeds KV capacity");
    let mut rng = Rng::new(seed);
    let server = Server::spawn(
        SpeculativeBackend::new(CpuBackend(draft), CpuBackend(target), k),
        EngineConfig {
            max_batch: requests,
            eos_token: u32::MAX, // deterministic token counts
            numerics,
            ..Default::default()
        },
    );
    let t_submit = now();
    let handles: Vec<_> = (0..requests as u64)
        .map(|id| {
            let prompt: Vec<u32> = (0..prompt_len)
                .map(|_| 3 + rng.below((cfg.vocab - 3) as u64) as u32)
                .collect();
            server.submit(Request::new(id, prompt, gen_tokens))
        })
        .collect();
    let mut tokens = 0usize;
    let mut t_done = t_submit;
    for h in handles {
        for ev in h.events() {
            if let Event::Token { t_emit, .. } = ev {
                tokens += 1;
                t_done = t_done.max(t_emit);
            }
        }
    }
    let secs = t_done.duration_since(t_submit).as_secs_f64();
    let m = server.shutdown();
    SpecStreamResult {
        model: cfg.name.to_string(),
        pair: pair.to_string(),
        requests,
        tokens,
        tokens_per_sec: tokens as f64 / secs.max(1e-12),
        acceptance_rate: m.spec_acceptance_rate(),
        drafted: m.spec_drafted_total,
        accepted: m.spec_accepted_total,
        rolled_back: m.spec_rolled_back_total,
        tokens_per_round: if m.spec_ticks == 0 {
            0.0
        } else {
            m.spec_emitted_total as f64 / m.spec_ticks as f64
        },
        robustness: crate::bench::RobustnessTags::from_metrics(&m),
    }
}

/// TTFT comparison for the prompt-prefix cache: the same prompt served
/// twice through one [`Engine`], first cold (filling the cache), then as
/// a prefix hit that adopts the cached KV blocks and computes only the
/// unmatched tail.
#[derive(Debug, Clone)]
pub struct PrefixSpeedResult {
    pub model: String,
    pub variant: SpeedVariant,
    pub prompt_len: usize,
    /// TTFT of the cold, cache-filling request, ms.
    pub cold_ttft_ms: f64,
    /// TTFT of the identical follow-up request served from the cache, ms.
    pub hit_ttft_ms: f64,
    /// Prompt tokens the cold request pushed through the forward path.
    pub prefill_tokens_cold: u64,
    /// Prompt tokens the hit request still computed (its unmatched tail —
    /// 1 for an exact repeat, since one token must produce logits).
    pub prefill_tokens_hit: u64,
    /// Prefix-cache hits recorded (1 when the cache worked).
    pub hits: u64,
    /// Fault-containment counters of the run (see
    /// [`StreamSpeedResult::robustness`]).
    pub robustness: crate::bench::RobustnessTags,
}

/// Measure cold-vs-hit TTFT: drive an [`Engine`] directly (prefix cache
/// enabled, EOS disabled), serve a random prompt to completion, then
/// serve the identical prompt again. The skipped work is visible in the
/// prefill-token accounting, the latency win in the two TTFTs.
pub fn measure_prefix_ttft(
    cfg: &ModelConfig,
    bm: BackendModel,
    variant: SpeedVariant,
    prompt_len: usize,
    gen_tokens: usize,
    seed: u64,
) -> PrefixSpeedResult {
    assert!(prompt_len >= 2 && gen_tokens >= 1);
    assert!(prompt_len + gen_tokens <= cfg.max_seq, "exceeds KV capacity");
    let mut rng = Rng::new(seed);
    let prompt: Vec<u32> = (0..prompt_len)
        .map(|_| 3 + rng.below((cfg.vocab - 3) as u64) as u32)
        .collect();
    let mut engine = Engine::new(
        CpuBackend(bm),
        EngineConfig {
            eos_token: u32::MAX, // deterministic token counts
            prefix: PrefixCacheConfig { enabled: true, ..Default::default() },
            ..Default::default()
        },
    );
    engine.submit(Request::new(0, prompt.clone(), gen_tokens)).expect("queue accepts");
    let cold = engine.run_to_completion().expect("cold request completes");
    let prefill_cold = engine.metrics.prefill_tokens_computed;
    engine.submit(Request::new(1, prompt, gen_tokens)).expect("queue accepts");
    let hit = engine.run_to_completion().expect("hit request completes");
    let m = engine.into_metrics();
    PrefixSpeedResult {
        model: cfg.name.to_string(),
        variant,
        prompt_len,
        cold_ttft_ms: cold[0].ttft_secs * 1e3,
        hit_ttft_ms: hit[0].ttft_secs * 1e3,
        prefill_tokens_cold: prefill_cold,
        prefill_tokens_hit: m.prefill_tokens_computed - prefill_cold,
        hits: m.prefix_hits,
        robustness: crate::bench::RobustnessTags::from_metrics(&m),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::random_weights;
    use crate::model::presets;

    fn tiny_model() -> Model {
        let mut cfg = presets::by_name("opt-nano").unwrap();
        cfg.vocab = 64;
        cfg.max_seq = 32;
        Model::new(cfg.clone(), random_weights(&cfg, 9))
    }

    #[test]
    fn variants_build_and_run() {
        let m = tiny_model();
        for v in [
            SpeedVariant::Full,
            SpeedVariant::GptqInt { bits: 2 },
            SpeedVariant::GptqtLut { bits: 3 },
        ] {
            let bm = build_variant(&m, v, 1);
            let r = measure_decode(&m.cfg, &bm, v, 4, 4, 2);
            assert!(r.ms_per_token > 0.0, "{v:?}");
            assert_eq!(r.tokens, 4);
        }
    }

    #[test]
    fn batched_variants_run_at_all_batch_sizes() {
        let m = tiny_model();
        for v in [
            SpeedVariant::Full,
            SpeedVariant::GptqInt { bits: 2 },
            SpeedVariant::GptqtLut { bits: 3 },
        ] {
            let bm = build_variant(&m, v, 1);
            for batch in [1usize, 4] {
                let r = measure_decode_batch(&m.cfg, &bm, v, batch, 4, 3, 2);
                assert_eq!(r.batch, batch, "{v:?}");
                assert_eq!(r.tokens, 3 * batch);
                assert!(r.tokens_per_sec > 0.0 && r.ms_per_step > 0.0);
            }
            // amortization accounting: B=4 streams 4x less per token
            let r1 = measure_decode_batch(&m.cfg, &bm, v, 1, 4, 2, 2);
            let r4 = measure_decode_batch(&m.cfg, &bm, v, 4, 4, 2, 2);
            assert!(
                (r1.amortized_mb_per_token / r4.amortized_mb_per_token - 4.0).abs() < 1e-6
            );
        }
    }

    #[test]
    fn prefill_measurement_runs_baseline_and_chunked() {
        let m = tiny_model();
        let bm = build_variant(&m, SpeedVariant::Full, 1);
        for chunk in [0usize, 1, 8] {
            let r = measure_prefill(&m.cfg, &bm, SpeedVariant::Full, 2, 12, chunk, 5);
            assert_eq!(r.batch, 2);
            assert_eq!(r.prompt_len, 12);
            assert_eq!(r.chunk, chunk);
            assert!(r.tokens_per_sec > 0.0 && r.ttft_ms >= 0.0, "chunk {chunk}");
        }
    }

    #[test]
    fn streaming_measurement_counts_every_token() {
        let m = tiny_model();
        for policy in [SchedulePolicyKind::Fixed, SchedulePolicyKind::Adaptive] {
            for numerics in [NumericsMode::Exact, NumericsMode::Fast] {
                let bm = build_variant(&m, SpeedVariant::Full, 1);
                let r =
                    measure_streaming(&m.cfg, bm, SpeedVariant::Full, 3, 4, 5, policy, numerics, 2);
                assert_eq!(r.requests, 3);
                assert_eq!(r.tokens, 3 * 5, "{policy:?}: EOS disabled, counts are exact");
                assert!(r.tokens_per_sec > 0.0 && r.ttft_ms > 0.0);
                assert!(r.inter_token_ms >= 0.0);
                assert_eq!(r.cancelled, 0);
                // a healthy bench run carries all-zero containment tags
                assert_eq!(r.robustness, crate::bench::RobustnessTags::default());
            }
        }
    }

    #[test]
    fn spec_streaming_counts_tokens_and_acceptance() {
        let m = tiny_model();
        let draft = build_variant(&m, SpeedVariant::GptqtLut { bits: 2 }, 1);
        let target = build_variant(&m, SpeedVariant::Full, 1);
        let r = measure_spec_streaming(
            &m.cfg,
            draft,
            target,
            "lut2->dense",
            3,
            4,
            6,
            4,
            NumericsMode::Exact,
            2,
        );
        assert_eq!(r.requests, 3);
        assert_eq!(r.tokens, 3 * 6, "EOS disabled, counts are exact");
        assert!(r.tokens_per_sec > 0.0);
        assert!((0.0..=1.0).contains(&r.acceptance_rate));
        assert!(r.accepted + r.rolled_back >= r.drafted);
        assert!(r.tokens_per_round >= 1.0, "every round emits at least one token");
        assert_eq!(r.pair, "lut2->dense");
    }

    #[test]
    fn prefix_ttft_hit_skips_prefill_work() {
        let m = tiny_model();
        let bm = build_variant(&m, SpeedVariant::Full, 1);
        let r = measure_prefix_ttft(&m.cfg, bm, SpeedVariant::Full, 12, 4, 7);
        assert_eq!(r.hits, 1);
        assert_eq!(r.prefill_tokens_cold, 12);
        // exact repeat: only the final prompt token (capped out of the
        // match so it can produce first-token logits) is recomputed
        assert_eq!(r.prefill_tokens_hit, 1);
        assert!(r.cold_ttft_ms > 0.0 && r.hit_ttft_ms > 0.0);
    }

    #[test]
    fn quantized_variants_stream_less() {
        let m = tiny_model();
        let full = build_variant(&m, SpeedVariant::Full, 1);
        let int2 = build_variant(&m, SpeedVariant::GptqInt { bits: 2 }, 1);
        let lut3 = build_variant(&m, SpeedVariant::GptqtLut { bits: 3 }, 1);
        assert!(int2.streamed_bytes_per_token() < full.streamed_bytes_per_token());
        assert!(lut3.streamed_bytes_per_token() < full.streamed_bytes_per_token() / 4);
    }
}
