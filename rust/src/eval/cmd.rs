//! CLI command implementations (`gptqt quantize|ppl|serve|exp|gen-corpus`).

use super::ppl::{calib_for, eval_for, eval_ppl, eval_ppl_backend, EvalConfig};
use super::tables::{self, ExpConfig};
use crate::cli::Args;
use crate::coordinator::{
    Backend, CpuBackend, DraftFormat, EngineConfig, PjrtBackend, PrefixCacheConfig, Request,
    SamplingParams, SchedulePolicyKind, Server, SpecConfig, SpeculativeBackend,
};
use crate::data::{CorpusGenerator, Dataset};
use crate::kernels::NumericsMode;
use crate::model::quantize::quantize_model;
use crate::model::{load_or_init, presets, BackendModel};
use crate::quant::{Method, QuantConfig};
use anyhow::{bail, Context, Result};

fn qcfg_from(a: &Args) -> QuantConfig {
    QuantConfig {
        bits: a.get_usize("bits", 3) as u32,
        step1_bits: a.get_usize("step1-bits", 5) as u32,
        explore_range: a.get_usize("explore-range", 1) as u32,
        explore_grid: a.get_usize("explore-grid", 6),
        ..Default::default()
    }
}

/// `--numerics exact|fast` (default `exact`) — which kernel numerics
/// tier the forward passes run under ([`NumericsMode`]).
fn numerics_from(a: &Args) -> Result<NumericsMode> {
    let s = a.get_or("numerics", "exact");
    NumericsMode::parse(s).with_context(|| format!("bad --numerics {s:?} (exact|fast)"))
}

/// `--quant gptq2|gptq3|gptqt2|gptqt3` → (method, bits).
fn parse_quant(q: &str) -> Result<(Method, u32)> {
    Ok(match q {
        "gptq2" => (Method::Gptq, 2),
        "gptq3" => (Method::Gptq, 3),
        "gptqt2" => (Method::Gptqt, 2),
        "gptqt3" => (Method::Gptqt, 3),
        other => bail!("bad --quant {other} (fp32|gptq2|gptq3|gptqt2|gptqt3)"),
    })
}

/// Speculative-decoding knobs (`--speculative --spec-k <n>
/// --draft <lut2|lut3|dense>`), single source for [`EngineConfig::spec`]
/// and the draft-model build.
fn spec_from(a: &Args) -> Result<SpecConfig> {
    Ok(SpecConfig {
        enabled: a.has_flag("speculative"),
        k: a.get_usize("spec-k", 4).max(1),
        draft_format: DraftFormat::parse(a.get_or("draft", "lut2"))
            .map_err(|e| anyhow::anyhow!(e))?,
    })
}

fn eval_cfg_from(a: &Args) -> EvalConfig {
    let mut e = if a.has_flag("fast") { EvalConfig::fast() } else { EvalConfig::default() };
    e.calib_slices = a.get_usize("calib-slices", e.calib_slices);
    e.calib_len = a.get_usize("calib-len", e.calib_len);
    e.eval_windows = a.get_usize("eval-windows", e.eval_windows);
    e.eval_len = a.get_usize("eval-len", e.eval_len);
    e.seed = a.get_u64("seed", 0);
    e
}

/// `gptqt quantize --model <name> --method <m> --bits <n>`
pub fn quantize(a: &Args) -> Result<()> {
    let name = a.get_or("model", "opt-mini");
    let method = Method::parse(a.get_or("method", "gptqt"))
        .context("bad --method (rtn|gptq|gptq-minmse|bcq|gptq-bcq|gptqt)")?;
    let qcfg = qcfg_from(a);
    let ecfg = eval_cfg_from(a);
    let (model, trained) = load_or_init(name, a.get_or("artifacts", "artifacts"), ecfg.seed)?;
    eprintln!(
        "quantizing {name} ({} params, trained={trained}) with {} at {} bits",
        crate::model::fmt_params(model.cfg.param_count()),
        method.name(),
        qcfg.bits
    );
    let calib = calib_for(&ecfg, Dataset::WikiSyn);
    let qm = quantize_model(&model, &calib, method, &qcfg, true)?;
    let total_mse: f64 = qm.stats.iter().map(|(_, s)| s.weight_mse).sum::<f64>()
        / qm.stats.len().max(1) as f64;
    let total_err: f64 = qm.stats.iter().map(|(_, s)| s.output_err).sum();
    println!(
        "quantized {} layers in {:.2}s  mean weight MSE {:.3e}  Σ output err {:.3e}",
        qm.stats.len(),
        qm.seconds,
        total_mse,
        total_err
    );
    if let Some(out) = a.get("out") {
        qm.model.weights.save(out)?;
        println!("wrote dequantized weights to {out}");
    }
    Ok(())
}

/// `gptqt ppl --model <name> --dataset <wiki-syn|ptb-syn> --method <m>
///            [--dequant]`
///
/// Quantized methods evaluate through the serving kernels
/// ([`eval_ppl_backend`]) by default — the deployment path; `--dequant`
/// restores the legacy dequantized-dense evaluation for comparison.
pub fn ppl(a: &Args) -> Result<()> {
    let name = a.get_or("model", "opt-mini");
    let dataset = Dataset::parse(a.get_or("dataset", "wiki-syn")).context("bad --dataset")?;
    let method = Method::parse(a.get_or("method", "full")).context("bad --method")?;
    let qcfg = qcfg_from(a);
    let ecfg = eval_cfg_from(a);
    let (model, trained) = load_or_init(name, a.get_or("artifacts", "artifacts"), ecfg.seed)?;
    if !trained {
        eprintln!("WARNING: no trained artifact for {name}; using random init");
    }
    let numerics = numerics_from(a)?;
    let windows = eval_for(&ecfg, dataset);
    let (ppl, via) = if method == Method::Full {
        if numerics == NumericsMode::Fast {
            // the Fast tier lives in the serving kernels — route the
            // dense model through BackendModel to reach it
            let bm = BackendModel::dense(&model).with_numerics(numerics);
            (eval_ppl_backend(&bm, &windows), "full kernels, fast numerics".to_string())
        } else {
            (eval_ppl(&model, &windows), "full".to_string())
        }
    } else {
        let calib = calib_for(&ecfg, dataset);
        let qm = quantize_model(&model, &calib, method, &qcfg, false)?;
        if a.has_flag("dequant") {
            // legacy path: perplexity of the dequantized dense weights
            (eval_ppl(&qm.model, &windows), "dequant-dense".to_string())
        } else {
            // deployment path: the quantized serving kernels end-to-end
            let bm = BackendModel::quantized(&model, qm.layers).with_numerics(numerics);
            let label = bm.backend_label().to_string();
            (
                eval_ppl_backend(&bm, &windows),
                format!("{label} kernels, {} numerics", numerics.label()),
            )
        }
    };
    println!(
        "{name} {} {}bit on {} [{via}]: ppl {}",
        method.name(),
        if method == Method::Full { 16 } else { qcfg.bits },
        dataset.name(),
        super::fmt_ppl(ppl)
    );
    Ok(())
}

/// `gptqt serve --model <name> --quant <fp32|gptq2|gptqt3|gptqt2>
///              [--backend cpu|pjrt] [--policy fixed|adaptive]
///              [--prefix-cache on|off] [--speculative --spec-k <n>
///              --draft <lut2|lut3|dense>] --requests <n> ...`
///
/// Serves through the streaming [`Server`] session API: requests are
/// submitted up front, every token is consumed from the per-request
/// event streams as it is produced, and the engine-thread metrics are
/// reported at shutdown. `--speculative` builds a second, cheaper model
/// in the `--draft` format and serves through a [`SpeculativeBackend`]
/// draft/verify pair — greedy output stays token-identical to serving
/// the target alone, and the metrics report gains the acceptance
/// counters.
pub fn serve(a: &Args) -> Result<()> {
    let name = a.get_or("model", "opt-mini");
    let quant = a.get_or("quant", "gptqt3");
    let n_requests = a.get_usize("requests", 16);
    let prompt_len = a.get_usize("prompt-len", 12);
    let gen_len = a.get_usize("gen-len", 24);
    let max_batch = a.get_usize("max-batch", 4);
    let backend_kind = a.get_or("backend", "cpu");
    let artifacts = a.get_or("artifacts", "artifacts");
    let ecfg = eval_cfg_from(a);

    let (model, trained) = load_or_init(name, artifacts, ecfg.seed)?;
    if !trained {
        eprintln!("WARNING: serving a random-init {name} (run `make artifacts`)");
    }

    // --- speculative serving: draft/target pair as one backend --------
    let spec = spec_from(a)?;
    if spec.enabled {
        if backend_kind != "cpu" {
            bail!("--speculative requires --backend cpu (no batched PJRT verify ABI yet)");
        }
        let calib = calib_for(&ecfg, Dataset::WikiSyn);
        let target_bm = match quant {
            "fp32" | "full" => BackendModel::dense(&model),
            q => {
                let (method, bits) = parse_quant(q)?;
                eprintln!("quantizing {name} with {} {bits}-bit (target) …", method.name());
                let qm =
                    quantize_model(&model, &calib, method, &QuantConfig::with_bits(bits), false)?;
                BackendModel::quantized(&model, qm.layers)
            }
        };
        let target_label = target_bm.backend_label().to_string();
        // the draft comes from the same weights — GPTQT's second
        // quantization step is the cheap sibling speculation drafts with
        let draft_bm = match spec.draft_format {
            DraftFormat::Dense => BackendModel::dense(&model),
            DraftFormat::Lut2 | DraftFormat::Lut3 => {
                let bits = if spec.draft_format == DraftFormat::Lut2 { 2 } else { 3 };
                eprintln!("quantizing {name} with gptqt {bits}-bit (draft) …");
                let qm = quantize_model(
                    &model,
                    &calib,
                    Method::Gptqt,
                    &QuantConfig::with_bits(bits),
                    false,
                )?;
                BackendModel::quantized(&model, qm.layers)
            }
        };
        if !a.has_flag("greedy") {
            eprintln!(
                "note: speculation engages for greedy sequences only — pass --greedy to see it"
            );
        }
        let label =
            format!("spec {}->{target_label} k={} (cpu)", spec.draft_format.label(), spec.k);
        return serve_with_backend(
            a,
            SpeculativeBackend::new(CpuBackend(draft_bm), CpuBackend(target_bm), spec.k),
            &model.cfg,
            n_requests,
            prompt_len,
            gen_len,
            max_batch,
            &label,
        );
    }

    // --- build the quantized (or full) model --------------------------
    let (served, label): (crate::model::Model, String) = match quant {
        "fp32" | "full" => (
            crate::model::Model::new(model.cfg.clone(), model.weights.clone()),
            "full fp32".into(),
        ),
        q => {
            let (method, bits) = parse_quant(q)?;
            let qcfg = QuantConfig::with_bits(bits);
            let calib = calib_for(&ecfg, Dataset::WikiSyn);
            eprintln!("quantizing {name} with {} {bits}-bit for serving …", method.name());
            let qm = quantize_model(&model, &calib, method, &qcfg, false)?;
            // CPU backend consumes packed/int layers for the real hot path
            if backend_kind == "cpu" {
                let bm = BackendModel::quantized(&model, qm.layers);
                return serve_with_backend(
                    a,
                    CpuBackend(bm),
                    &model.cfg,
                    n_requests,
                    prompt_len,
                    gen_len,
                    max_batch,
                    &format!("{} {bits}-bit (cpu)", method.name()),
                );
            }
            (qm.model, format!("{} {bits}-bit", method.name()))
        }
    };

    match backend_kind {
        "cpu" => {
            let bm = BackendModel::dense(&served);
            serve_with_backend(
                a,
                CpuBackend(bm),
                &served.cfg,
                n_requests,
                prompt_len,
                gen_len,
                max_batch,
                &format!("{label} (cpu)"),
            )
        }
        "pjrt" => {
            if !crate::runtime::artifacts_present(artifacts, name) {
                bail!("no HLO artifacts for {name} under {artifacts}; run `make artifacts`");
            }
            let rt = crate::runtime::Runtime::cpu()?;
            eprintln!("PJRT platform: {}", rt.platform());
            let compiled = rt.load_model(artifacts, &served)?;
            serve_with_backend(
                a,
                PjrtBackend(compiled),
                &served.cfg,
                n_requests,
                prompt_len,
                gen_len,
                max_batch,
                &format!("{label} (pjrt)"),
            )
        }
        other => bail!("bad --backend {other} (cpu|pjrt)"),
    }
}

#[allow(clippy::too_many_arguments)]
fn serve_with_backend<B>(
    a: &Args,
    backend: B,
    cfg: &crate::model::ModelConfig,
    n_requests: usize,
    prompt_len: usize,
    gen_len: usize,
    max_batch: usize,
    label: &str,
) -> Result<()>
where
    B: Backend + Send + 'static,
    B::Kv: Send,
{
    let seed = a.get_u64("seed", 0);
    let policy = SchedulePolicyKind::parse(a.get_or("policy", "fixed"))
        .context("bad --policy (fixed|adaptive)")?;
    // prompt-prefix reuse is on for the CLI (the library default is off);
    // backends without KV snapshot support simply never populate it
    let prefix_on = match a.get_or("prefix-cache", "on") {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => anyhow::bail!("bad --prefix-cache {other:?} (on|off)"),
    };
    let numerics = numerics_from(a)?;
    let spec = spec_from(a)?;
    let (gen, vocab) = CorpusGenerator::with_vocab(Dataset::WikiSyn, cfg.vocab, seed);
    let stream = gen.generate(n_requests * prompt_len * 4 + 64, 9);
    let server = Server::spawn(
        backend,
        EngineConfig {
            max_batch,
            policy,
            prefix: PrefixCacheConfig { enabled: prefix_on, ..Default::default() },
            numerics,
            spec,
            ..Default::default()
        },
    );
    eprintln!(
        "serving {n_requests} requests on {} [{label}, {policy:?} scheduling, {} numerics]",
        cfg.name,
        numerics.label()
    );
    let mut rng = crate::util::Rng::new(seed);
    let mut handles = Vec::new();
    for id in 0..n_requests as u64 {
        let start = rng.range(0, stream.len() - prompt_len);
        let prompt = stream[start..start + prompt_len].to_vec();
        let sampling = if a.has_flag("greedy") {
            SamplingParams::Greedy
        } else {
            SamplingParams::TopK { k: 16, temperature: 0.9, seed: seed ^ id }
        };
        handles.push(server.submit(Request::new(id, prompt, gen_len).with_sampling(sampling)));
    }
    let mut responses = Vec::new();
    for h in handles {
        let id = h.id();
        responses.push(h.wait().map_err(|e| anyhow::anyhow!("request {id}: {e:?}"))?);
    }
    let metrics = server.shutdown();
    println!("--- engine metrics [{label}] ---");
    println!("{}", metrics.report());
    if let Some(r) = responses.first() {
        println!(
            "sample continuation (req {}, ttft {:.1} ms): {}",
            r.id,
            r.ttft_secs * 1e3,
            vocab.detokenize(&r.tokens)
        );
    }
    anyhow::ensure!(responses.len() == n_requests, "lost responses");
    Ok(())
}

/// `gptqt exp <table1|table2|table3|table4|table5|table6|fig4|all>`
pub fn experiment(a: &Args) -> Result<()> {
    let which = a
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let cfg = ExpConfig {
        eval: eval_cfg_from(a),
        artifacts_dir: a.get_or("artifacts", "artifacts").to_string(),
        fast: a.has_flag("fast"),
        seed: a.get_u64("seed", 0),
    };
    let run = |name: &str| -> Result<()> {
        eprintln!("=== {name} ===");
        match name {
            "table1" => tables::table1(&cfg).map(|_| ()),
            "table2" => tables::table2(&cfg).map(|_| ()),
            "table3" => tables::table3(&cfg).map(|_| ()),
            "table4" => tables::table4(&cfg).map(|_| ()),
            "table5" => tables::table5(&cfg).map(|_| ()),
            "table6" => tables::table6(&cfg).map(|_| ()),
            "fig4" => tables::fig4(&cfg).map(|_| ()),
            other => bail!("unknown experiment `{other}`"),
        }
    };
    if which == "all" {
        for name in ["table1", "table2", "table3", "table4", "table5", "table6", "fig4"] {
            run(name)?;
        }
        Ok(())
    } else {
        run(which)
    }
}

/// `gptqt gen-corpus --out-dir artifacts --tokens N --seed S`
pub fn gen_corpus(a: &Args) -> Result<()> {
    let out_dir = a.get_or("out-dir", "artifacts");
    let tokens = a.get_usize("tokens", 1_500_000);
    let seed = a.get_u64("seed", 0);
    std::fs::create_dir_all(out_dir)?;
    for ds in [Dataset::WikiSyn, Dataset::PtbSyn] {
        let gen = CorpusGenerator::new(ds, presets::VOCAB, seed);
        let train = gen.generate(tokens, 0);
        let path = format!("{out_dir}/corpus-{}-train.bin", ds.name());
        let mut bytes = Vec::with_capacity(train.len() * 4);
        for t in &train {
            bytes.extend_from_slice(&t.to_le_bytes());
        }
        std::fs::write(&path, bytes)?;
        eprintln!("wrote {} tokens to {path}", train.len());
    }
    Ok(())
}
