//! Perplexity protocol (paper §III): non-overlapping windows over a
//! held-out synthetic stream, teacher-forced next-token NLL, `exp(mean)`.
//!
//! Two entry points share one implementation: [`eval_ppl`] evaluates a
//! dense [`Model`] and [`eval_ppl_backend`] evaluates any
//! [`BackendModel`] — including the quantized int-dequant and LUT-GEMM
//! backends, so the formats the paper serves are perplexity-measured on
//! the exact kernel path deployment runs (not on dequantized dense
//! stand-ins). Both run each window as one chunked KV-cache forward.

use crate::data::{calibration_slices, eval_windows, CorpusGenerator, Dataset, TokenSlice};
use crate::model::{presets, BackendModel, Model};

/// Evaluation-scale knobs (the paper's "128 slices × 2048 tokens"
/// calibration and full-dataset ppl, scaled to this testbed).
#[derive(Debug, Clone)]
pub struct EvalConfig {
    pub calib_slices: usize,
    pub calib_len: usize,
    pub eval_windows: usize,
    pub eval_len: usize,
    /// corpus seed (must match `gen-corpus --seed` for trained models)
    pub seed: u64,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig { calib_slices: 12, calib_len: 96, eval_windows: 8, eval_len: 96, seed: 0 }
    }
}

impl EvalConfig {
    /// Reduced-cost preset for smoke runs (`--fast`).
    pub fn fast() -> Self {
        EvalConfig { calib_slices: 4, calib_len: 48, eval_windows: 3, eval_len: 48, seed: 0 }
    }
}

/// Calibration slices for a dataset (stream 1 — train used stream 0).
pub fn calib_for(cfg: &EvalConfig, dataset: Dataset) -> Vec<TokenSlice> {
    let gen = CorpusGenerator::new(dataset, presets::VOCAB, cfg.seed);
    let stream = gen.generate(cfg.calib_slices * cfg.calib_len * 8, 1);
    calibration_slices(&stream, cfg.calib_slices, cfg.calib_len, cfg.seed ^ 0xCAFE)
}

/// Held-out evaluation windows (stream 2).
pub fn eval_for(cfg: &EvalConfig, dataset: Dataset) -> Vec<TokenSlice> {
    let gen = CorpusGenerator::new(dataset, presets::VOCAB, cfg.seed);
    let stream = gen.generate(cfg.eval_windows * cfg.eval_len + 1, 2);
    eval_windows(&stream, cfg.eval_len, cfg.eval_windows)
}

/// Perplexity of a dense model over prepared windows — the degenerate
/// dense-backend case of [`eval_ppl_backend`].
pub fn eval_ppl(model: &Model, windows: &[TokenSlice]) -> f64 {
    eval_ppl_backend(&BackendModel::dense(model), windows)
}

/// Perplexity through a serving backend: each window runs as one
/// chunked KV-cache forward over the backend's kernels (dense f32,
/// int-dequant, or LUT-GEMM), so quantized formats are evaluated
/// end-to-end on the deployment path.
pub fn eval_ppl_backend(bm: &BackendModel, windows: &[TokenSlice]) -> f64 {
    let (mut nll, mut count) = (0.0f64, 0usize);
    for w in windows {
        let (s, c) = bm.nll_window(&w.tokens);
        nll += s;
        count += c;
    }
    if count == 0 {
        return f64::NAN;
    }
    (nll / count as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::model::init::random_weights;

    #[test]
    fn random_model_ppl_near_uniform() {
        let mut cfg = presets::by_name("opt-nano").unwrap();
        cfg.vocab = 128;
        let model = Model::new(cfg.clone(), random_weights(&cfg, 1));
        let ecfg = EvalConfig { eval_windows: 2, eval_len: 32, ..EvalConfig::fast() };
        let windows = eval_for(&ecfg, Dataset::WikiSyn);
        // windows tokens < 128 vocab? corpus vocab is presets::VOCAB —
        // clamp: model.embed mods by vocab, nll target < vocab needed.
        // Use tokens under 128:
        let windows: Vec<_> = windows
            .into_iter()
            .map(|mut w| {
                for t in w.tokens.iter_mut() {
                    *t %= 128;
                }
                w
            })
            .collect();
        let ppl = eval_ppl(&model, &windows);
        assert!(ppl.is_finite());
        // random init ≈ uniform over 128 tokens (generous band)
        assert!(ppl > 40.0 && ppl < 400.0, "ppl {ppl}");
    }

    #[test]
    fn calib_and_eval_are_disjoint_streams() {
        let ecfg = EvalConfig::fast();
        let calib = calib_for(&ecfg, Dataset::WikiSyn);
        let eval = eval_for(&ecfg, Dataset::WikiSyn);
        assert!(!calib.is_empty() && !eval.is_empty());
        // trivially different content (different generator streams)
        assert_ne!(calib[0].tokens, eval[0].tokens);
    }
}
