//! Experiment drivers — one function per paper table/figure.
//!
//! Every driver prints the same row/column structure the paper reports
//! (methods × model ladder), writes `results/<exp>.txt`, and returns the
//! numbers for tests/benches. Absolute perplexities differ from the
//! paper (scaled models + synthetic corpora — DESIGN.md §2); the
//! reproduction target is the *shape*: who wins, where 2-bit collapses,
//! which ablations hurt.

use super::ppl::{calib_for, eval_for, eval_ppl, EvalConfig};
use super::speed::{build_variant, measure_decode, SpeedVariant};
use super::{emit_result, fmt_ppl, render_table};
use crate::data::{Dataset, TokenSlice};
use crate::model::quantize::quantize_model;
use crate::model::{load_or_init, Model};
use crate::quant::{Method, QuantConfig};
use anyhow::Result;

/// Shared experiment knobs.
#[derive(Debug, Clone)]
pub struct ExpConfig {
    pub eval: EvalConfig,
    pub artifacts_dir: String,
    /// shrink ladders + calibration for smoke runs
    pub fast: bool,
    pub seed: u64,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            eval: EvalConfig::default(),
            artifacts_dir: "artifacts".into(),
            fast: false,
            seed: 0,
        }
    }
}

impl ExpConfig {
    pub fn fast() -> Self {
        ExpConfig { eval: EvalConfig::fast(), fast: true, ..Default::default() }
    }

    fn opt_ladder(&self) -> Vec<&'static str> {
        if self.fast {
            vec!["opt-nano", "opt-micro"]
        } else {
            vec!["opt-nano", "opt-micro", "opt-mini", "opt-sm", "opt-md"]
        }
    }

    fn qcfg(&self, bits: u32) -> QuantConfig {
        QuantConfig {
            bits,
            explore_grid: if self.fast { 3 } else { 6 },
            ..QuantConfig::with_bits(bits)
        }
    }

    fn load(&self, name: &str) -> Result<(Model, bool)> {
        load_or_init(name, &self.artifacts_dir, self.seed)
    }
}

/// Quantize a model and evaluate perplexity in one go.
pub fn quantized_ppl(
    model: &Model,
    calib: &[TokenSlice],
    windows: &[TokenSlice],
    method: Method,
    qcfg: &QuantConfig,
) -> Result<f64> {
    if method == Method::Full {
        return Ok(eval_ppl(model, windows));
    }
    let qm = quantize_model(model, calib, method, qcfg, false)?;
    Ok(eval_ppl(&qm.model, windows))
}

/// Generic ppl ladder: methods × bit-widths over a model ladder.
fn ppl_ladder(
    cfg: &ExpConfig,
    title: &str,
    out_name: &str,
    models: &[&str],
    dataset: Dataset,
    methods_bits: &[(Method, u32)],
) -> Result<Vec<Vec<String>>> {
    let calib = calib_for(&cfg.eval, dataset);
    let windows = eval_for(&cfg.eval, dataset);
    let mut header = vec!["method".to_string(), "bits".to_string()];
    let mut trained_note = String::new();
    let mut columns: Vec<(String, Model)> = Vec::new();
    for name in models {
        let (model, trained) = cfg.load(name)?;
        header.push(format!(
            "{}({})",
            name.trim_start_matches("opt-")
                .trim_start_matches("llama-")
                .trim_start_matches("bloom-"),
            crate::model::fmt_params(model.cfg.param_count())
        ));
        if !trained {
            trained_note.push_str(&format!("NOTE: {name} has no trained artifact (random init)\n"));
        }
        columns.push((name.to_string(), model));
    }
    let mut rows = Vec::new();
    for &(method, bits) in methods_bits {
        let qcfg = cfg.qcfg(bits);
        let mut row = vec![
            method.name().to_string(),
            if method == Method::Full { "16".into() } else { bits.to_string() },
        ];
        for (name, model) in &columns {
            let ppl = quantized_ppl(model, &calib, &windows, method, &qcfg)?;
            eprintln!("  [{title}] {name} {} {bits}b → ppl {}", method.name(), fmt_ppl(ppl));
            row.push(fmt_ppl(ppl));
        }
        rows.push(row);
    }
    let mut body = render_table(title, &header, &rows);
    if !trained_note.is_empty() {
        body.push_str(&trained_note);
    }
    emit_result(out_name, &body)?;
    Ok(rows)
}

/// Table I — OPT ladder, wiki-syn, {full, RTN, BCQ, GPTQ, GPTQT} × {3,2}.
pub fn table1(cfg: &ExpConfig) -> Result<Vec<Vec<String>>> {
    let mb: Vec<(Method, u32)> = vec![
        (Method::Full, 16),
        (Method::Rtn, 3),
        (Method::Bcq, 3),
        (Method::Gptq, 3),
        (Method::Gptqt, 3),
        (Method::Rtn, 2),
        (Method::Bcq, 2),
        (Method::Gptq, 2),
        (Method::Gptqt, 2),
    ];
    ppl_ladder(
        cfg,
        "Table I — OPT perplexity on wiki-syn (WikiText2 analogue)",
        "table1",
        &cfg.opt_ladder(),
        Dataset::WikiSyn,
        &mb,
    )
}

/// Table II — Llama-like + Bloom-like ladders, wiki-syn, 3-bit.
pub fn table2(cfg: &ExpConfig) -> Result<Vec<Vec<String>>> {
    let models: Vec<&str> = if cfg.fast {
        vec!["llama-sm", "bloom-nano"]
    } else {
        vec!["llama-sm", "llama-md", "bloom-nano", "bloom-mini", "bloom-sm", "bloom-md"]
    };
    let mb = vec![
        (Method::Full, 16),
        (Method::Bcq, 3),
        (Method::Gptq, 3),
        (Method::Gptqt, 3),
    ];
    ppl_ladder(
        cfg,
        "Table II — Llama-like and Bloom-like perplexity on wiki-syn, 3-bit",
        "table2",
        &models,
        Dataset::WikiSyn,
        &mb,
    )
}

/// Table III — OPT ladder on ptb-syn (PTB analogue), 3-bit.
pub fn table3(cfg: &ExpConfig) -> Result<Vec<Vec<String>>> {
    let mb = vec![
        (Method::Full, 16),
        (Method::Bcq, 3),
        (Method::Gptq, 3),
        (Method::Gptqt, 3),
    ];
    ppl_ladder(
        cfg,
        "Table III — OPT perplexity on ptb-syn (PTB analogue), 3-bit",
        "table3",
        &cfg.opt_ladder(),
        Dataset::PtbSyn,
        &mb,
    )
}

/// Table IV — per-token decode latency across the full ladder (timing
/// only; values don't need trained weights).
pub fn table4(cfg: &ExpConfig) -> Result<Vec<Vec<String>>> {
    let models: Vec<&str> = if cfg.fast {
        vec!["opt-nano", "opt-mini"]
    } else {
        vec!["opt-nano", "opt-mini", "opt-sm", "opt-md", "opt-lg", "opt-xl"]
    };
    let gen_tokens = if cfg.fast { 8 } else { 24 };
    let variants = [
        SpeedVariant::Full,
        SpeedVariant::GptqInt { bits: 2 },
        SpeedVariant::GptqtLut { bits: 3 },
    ];
    let mut header = vec!["variant".to_string()];
    let mut grid: Vec<Vec<String>> =
        variants.iter().map(|v| vec![v.label()]).collect();
    let mut mb_row = vec!["streamed MB/tok (GPTQT)".to_string()];
    for name in &models {
        let (model, _) = cfg.load(name)?;
        header.push(format!(
            "{}({})",
            name.trim_start_matches("opt-"),
            crate::model::fmt_params(model.cfg.param_count())
        ));
        for (vi, &variant) in variants.iter().enumerate() {
            let bm = build_variant(&model, variant, cfg.seed);
            let r = measure_decode(&model.cfg, &bm, variant, 8, gen_tokens, cfg.seed);
            eprintln!(
                "  [table4] {name} {}: {:.2} ms/tok ({:.2} MB/tok)",
                variant.label(),
                r.ms_per_token,
                r.streamed_mb_per_token
            );
            grid[vi].push(format!("{:.2}", r.ms_per_token));
            if vi == 2 {
                mb_row.push(format!("{:.2}", r.streamed_mb_per_token));
            }
        }
    }
    let mut rows = grid;
    rows.push(mb_row);
    let body = render_table(
        "Table IV — ms per generated token (batch 1, greedy), CPU decode",
        &header,
        &rows,
    );
    emit_result("table4", &body)?;
    Ok(rows)
}

/// Table V — the overfitting ablation: GPTQ vs GPTQ(minMSE) vs GPTQ+BCQ
/// vs GPTQT, 3-bit, wiki-syn.
pub fn table5(cfg: &ExpConfig) -> Result<Vec<Vec<String>>> {
    let mb = vec![
        (Method::Gptq, 3),
        (Method::GptqMinMse, 3),
        (Method::GptqBcq, 3),
        (Method::Gptqt, 3),
    ];
    ppl_ladder(
        cfg,
        "Table V — overfitting ablation (weight-MSE-optimal codebooks vs GPTQT), 3-bit",
        "table5",
        &cfg.opt_ladder(),
        Dataset::WikiSyn,
        &mb,
    )
}

/// Fig. 4 — intermediate (step-1) bit sweep, final 3-bit.
pub fn fig4(cfg: &ExpConfig) -> Result<Vec<Vec<String>>> {
    let models: Vec<&str> = if cfg.fast {
        vec!["opt-nano"]
    } else {
        vec!["opt-nano", "opt-micro", "opt-mini"]
    };
    let calib = calib_for(&cfg.eval, Dataset::WikiSyn);
    let windows = eval_for(&cfg.eval, Dataset::WikiSyn);
    let mut header = vec!["step1 bits".to_string()];
    for m in &models {
        header.push(m.to_string());
    }
    let mut rows = Vec::new();
    for step1 in 3u32..=6 {
        let mut row = vec![step1.to_string()];
        for name in &models {
            let (model, _) = cfg.load(name)?;
            let ppl = if step1 == 3 {
                // step1 == final bits: step 2 is the identity — GPTQT
                // degenerates to plain GPTQ linear quantization
                let q = cfg.qcfg(3);
                quantized_ppl(&model, &calib, &windows, Method::Gptq, &q)?
            } else {
                let q = QuantConfig { step1_bits: step1, ..cfg.qcfg(3) };
                quantized_ppl(&model, &calib, &windows, Method::Gptqt, &q)?
            };
            eprintln!("  [fig4] {name} step1={step1} → ppl {}", fmt_ppl(ppl));
            row.push(fmt_ppl(ppl));
        }
        rows.push(row);
    }
    let body = render_table(
        "Fig. 4 — impact of the intermediate bit (final 3-bit, wiki-syn ppl)",
        &header,
        &rows,
    );
    emit_result("fig4", &body)?;
    Ok(rows)
}

/// Table VI — scale re-exploration range 0/1/2 (step1 5-bit, final 3-bit).
pub fn table6(cfg: &ExpConfig) -> Result<Vec<Vec<String>>> {
    let models: Vec<&str> = if cfg.fast {
        vec!["opt-nano"]
    } else {
        vec!["opt-nano", "opt-micro", "opt-mini", "opt-sm"]
    };
    let calib = calib_for(&cfg.eval, Dataset::WikiSyn);
    let windows = eval_for(&cfg.eval, Dataset::WikiSyn);
    let mut header = vec!["range".to_string()];
    for m in &models {
        header.push(m.to_string());
    }
    let mut rows = Vec::new();
    for range in 0u32..=2 {
        let mut row = vec![range.to_string()];
        for name in &models {
            let (model, _) = cfg.load(name)?;
            let q = QuantConfig { explore_range: range, step1_bits: 5, ..cfg.qcfg(3) };
            let ppl = quantized_ppl(&model, &calib, &windows, Method::Gptqt, &q)?;
            eprintln!("  [table6] {name} range={range} → ppl {}", fmt_ppl(ppl));
            row.push(fmt_ppl(ppl));
        }
        rows.push(row);
    }
    let body = render_table(
        "Table VI — re-exploration range of Ŝ (step1 5-bit, final 3-bit, wiki-syn ppl)",
        &header,
        &rows,
    );
    emit_result("table6", &body)?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch_results() {
        // keep smoke outputs away from the real results/ directory
        std::env::set_var(
            "GPTQT_RESULTS_DIR",
            std::env::temp_dir().join("gptqt-test-results"),
        );
    }

    /// Smoke: the fast config runs every driver end to end (tiny ladder,
    /// random-init fallback — exercises code paths, not paper shapes).
    #[test]
    fn fast_drivers_run() {
        scratch_results();
        let cfg = ExpConfig {
            artifacts_dir: "/nonexistent".into(), // force random init
            ..ExpConfig::fast()
        };
        // keep it cheap: fig4 on the nano model only
        let rows = fig4(&cfg).unwrap();
        assert_eq!(rows.len(), 4); // step1 ∈ 3..=6
        let rows = table6(&cfg).unwrap();
        assert_eq!(rows.len(), 3); // range 0..=2
    }

    #[test]
    fn table4_fast_runs_and_orders_memory() {
        scratch_results();
        let cfg = ExpConfig {
            artifacts_dir: "/nonexistent".into(),
            ..ExpConfig::fast()
        };
        let rows = table4(&cfg).unwrap();
        assert_eq!(rows.len(), 4); // 3 variants + MB row
    }
}
