//! Evaluation + experiment drivers: perplexity protocol, the paper's
//! Tables I–VI and Fig. 4, and the CLI command implementations.

pub mod cmd;
pub mod ppl;
pub mod speed;
pub mod tables;

pub use ppl::{eval_ppl, eval_ppl_backend, EvalConfig};

/// Where experiment outputs are written (one text file per experiment,
/// same rows that are printed).
pub const RESULTS_DIR: &str = "results";

/// Append a result blob to `results/<name>.txt` (creating the dir), and
/// echo it to stdout. `GPTQT_RESULTS_DIR` overrides the directory (tests
/// point it at a scratch dir so smoke runs don't clobber real results).
pub fn emit_result(name: &str, body: &str) -> anyhow::Result<()> {
    println!("{body}");
    let dir = std::env::var("GPTQT_RESULTS_DIR").unwrap_or_else(|_| RESULTS_DIR.to_string());
    std::fs::create_dir_all(&dir)?;
    let path = format!("{dir}/{name}.txt");
    std::fs::write(&path, body)?;
    eprintln!("[results] wrote {path}");
    Ok(())
}

/// Render an aligned text table: header row + data rows.
pub fn render_table(title: &str, header: &[String], rows: &[Vec<String>]) -> String {
    let ncols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let mut out = format!("## {title}\n{}\n", fmt_row(header));
    let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
    out.push_str(&"-".repeat(total));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out
}

/// Format a perplexity like the paper (large collapses as `1.3e3`).
pub fn fmt_ppl(p: f64) -> String {
    if !p.is_finite() {
        "inf".into()
    } else if p >= 1000.0 {
        format!("{:.1e}", p)
    } else if p >= 100.0 {
        format!("{:.1}", p)
    } else {
        format!("{:.2}", p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            "demo",
            &["method".into(), "3bit".into()],
            &[vec!["GPTQT".into(), "10.15".into()], vec!["RTN".into(), "6.1e3".into()]],
        );
        assert!(t.contains("## demo"));
        assert!(t.contains("GPTQT"));
        let lines: Vec<&str> = t.lines().collect();
        assert!(lines.len() >= 4);
    }

    #[test]
    fn ppl_formatting_matches_paper_style() {
        assert_eq!(fmt_ppl(9.34), "9.34");
        assert_eq!(fmt_ppl(139.9), "139.9");
        assert_eq!(fmt_ppl(6100.0), "6.1e3");
        assert_eq!(fmt_ppl(f64::INFINITY), "inf");
    }
}
