//! Synthetic corpora + calibration sampling.
//!
//! The paper evaluates on WikiText2 and PTB — unavailable offline, so we
//! substitute two synthetic corpora with deliberately *different* token
//! statistics (see DESIGN.md §2):
//!
//! * [`Dataset::WikiSyn`] — order-2 Markov chain over a 2048-word
//!   Zipf-weighted vocabulary, long "sentences" (mirrors WikiText2's
//!   heavier-tailed, higher-entropy prose).
//! * [`Dataset::PtbSyn`] — order-1 chain over a smaller effective
//!   vocabulary with short sentences (mirrors PTB's clipped newswire).
//!
//! Both are deterministic functions of a seed, so every experiment
//! (python training, rust calibration, rust evaluation) sees the same
//! data without shipping datasets.

pub mod corpus;
pub mod vocab;

pub use corpus::{CorpusGenerator, Dataset};

use crate::util::Rng;

/// A contiguous slice of tokens used for calibration or evaluation.
#[derive(Debug, Clone)]
pub struct TokenSlice {
    pub tokens: Vec<u32>,
}

/// Calibration sampler: `n_slices` random windows of `slice_len` tokens,
/// the shape of the paper's "128 random slices of 2048 tokens" (§III-A),
/// scaled by config.
pub fn calibration_slices(
    stream: &[u32],
    n_slices: usize,
    slice_len: usize,
    seed: u64,
) -> Vec<TokenSlice> {
    assert!(
        stream.len() > slice_len,
        "stream too short: {} <= {}",
        stream.len(),
        slice_len
    );
    let mut rng = Rng::new(seed ^ 0xCA11_B0B5);
    (0..n_slices)
        .map(|_| {
            let start = rng.range(0, stream.len() - slice_len);
            TokenSlice { tokens: stream[start..start + slice_len].to_vec() }
        })
        .collect()
}

/// Non-overlapping evaluation windows covering the stream prefix —
/// the perplexity protocol walks these in order.
pub fn eval_windows(stream: &[u32], window: usize, max_windows: usize) -> Vec<TokenSlice> {
    stream
        .chunks_exact(window)
        .take(max_windows)
        .map(|c| TokenSlice { tokens: c.to_vec() })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_slices_shapes() {
        let stream: Vec<u32> = (0..10_000).map(|i| i % 97).collect();
        let slices = calibration_slices(&stream, 16, 128, 7);
        assert_eq!(slices.len(), 16);
        assert!(slices.iter().all(|s| s.tokens.len() == 128));
    }

    #[test]
    fn calibration_is_deterministic() {
        let stream: Vec<u32> = (0..5_000).map(|i| (i * 31) % 211).collect();
        let a = calibration_slices(&stream, 4, 64, 42);
        let b = calibration_slices(&stream, 4, 64, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn eval_windows_non_overlapping() {
        let stream: Vec<u32> = (0..1000).collect();
        let ws = eval_windows(&stream, 100, 5);
        assert_eq!(ws.len(), 5);
        assert_eq!(ws[0].tokens[0], 0);
        assert_eq!(ws[1].tokens[0], 100);
        assert_eq!(ws[4].tokens[99], 499);
    }
}
