//! Synthetic corpus generators with WikiText2-like and PTB-like token
//! statistics (the paper's two evaluation datasets, §III).
//!
//! Both are Markov chains over a Zipf-weighted vocabulary whose sparse
//! transition structure is itself drawn deterministically from the seed.
//! `WikiSyn` uses order-2 transitions, a larger vocabulary slice and long
//! sentences; `PtbSyn` order-1, a smaller effective vocabulary and short
//! sentences — two genuinely different generative processes, so a model
//! trained on one has measurably different perplexity on the other
//! (mirroring the Table I vs Table III contrast).

use super::vocab::{Vocab, BOS, EOS, FIRST_WORD};
use crate::util::Rng;

/// Which synthetic dataset to generate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    WikiSyn,
    PtbSyn,
}

impl Dataset {
    pub fn parse(s: &str) -> Option<Dataset> {
        match s {
            "wiki-syn" | "wikitext2" | "wiki" => Some(Dataset::WikiSyn),
            "ptb-syn" | "ptb" => Some(Dataset::PtbSyn),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dataset::WikiSyn => "wiki-syn",
            Dataset::PtbSyn => "ptb-syn",
        }
    }
}

/// Number of successor candidates per Markov state. Kept small and the
/// transition weights peaked so a small transformer can actually harvest
/// the conditional structure within a short training budget — quantized
/// linears then matter measurably (the tables need a model whose blocks
/// carry signal, not just unigram statistics in the embeddings).
const BRANCH: usize = 6;
/// Peakedness of transition weights (higher ⇒ lower conditional entropy).
const PEAK: f64 = 3.0;

/// A deterministic Markov text generator over a [`Vocab`].
pub struct CorpusGenerator {
    vocab_size: u32,
    dataset: Dataset,
    /// effective vocabulary (words actually used) — PTB-syn uses fewer
    effective: u32,
    /// per-first-token successor tables: BRANCH candidate ids + weights
    successors: Vec<[u32; BRANCH]>,
    weights: Vec<[f64; BRANCH]>,
    /// sentence termination probability per step
    end_prob: f64,
    seed: u64,
}

impl CorpusGenerator {
    /// Build the generator for a dataset over a `vocab_size`-token space.
    pub fn new(dataset: Dataset, vocab_size: usize, seed: u64) -> CorpusGenerator {
        let vocab_size = vocab_size as u32;
        let (effective, end_prob, table_seed) = match dataset {
            Dataset::WikiSyn => (vocab_size - FIRST_WORD, 1.0 / 24.0, seed ^ 0x1117),
            Dataset::PtbSyn => ((vocab_size - FIRST_WORD) / 4, 1.0 / 9.0, seed ^ 0x9272),
        };
        let mut rng = Rng::new(table_seed);
        // Zipf weights over the effective vocabulary.
        let zipf: Vec<f64> = (0..effective)
            .map(|i| 1.0 / (i as f64 + 2.7).powf(1.07))
            .collect();
        // Sparse successor tables: every state gets BRANCH candidates
        // drawn Zipf-biased, with random positive weights.
        let states = effective as usize;
        let mut successors = Vec::with_capacity(states);
        let mut weights = Vec::with_capacity(states);
        for _ in 0..states {
            let mut succ = [0u32; BRANCH];
            let mut w = [0f64; BRANCH];
            for k in 0..BRANCH {
                succ[k] = FIRST_WORD + rng.weighted(&zipf) as u32;
                // geometric peaking: first candidates dominate, so the
                // conditional entropy sits far below the unigram entropy
                w[k] = PEAK.powi(-(k as i32)) * (0.6 + 0.8 * rng.next_f64());
            }
            successors.push(succ);
            weights.push(w);
        }
        CorpusGenerator { vocab_size, dataset, effective, successors, weights, end_prob, seed }
    }

    pub fn dataset(&self) -> Dataset {
        self.dataset
    }

    pub fn vocab_size(&self) -> u32 {
        self.vocab_size
    }

    fn state_of(&self, dataset: Dataset, prev: u32, prev2: u32) -> usize {
        let p = (prev.saturating_sub(FIRST_WORD)) as u64;
        match dataset {
            Dataset::PtbSyn => (p % self.effective as u64) as usize,
            Dataset::WikiSyn => {
                // mostly order-1 (learnable as a bigram table) with a
                // mild order-2 perturbation on a quarter of the states —
                // keeps the two corpora statistically distinct while
                // staying harvestable by small models
                let q = (prev2.saturating_sub(FIRST_WORD)) as u64;
                let mix = if p % 4 == 0 { q % 4 } else { 0 };
                ((p + mix * (self.effective as u64 / 4)) % self.effective as u64) as usize
            }
        }
    }

    /// Generate `len` tokens (BOS/EOS-delimited sentences), deterministic
    /// for (generator seed, stream id).
    pub fn generate(&self, len: usize, stream: u64) -> Vec<u32> {
        let mut rng = Rng::new(self.seed ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut out = Vec::with_capacity(len);
        let mut prev = BOS;
        let mut prev2 = BOS;
        out.push(BOS);
        while out.len() < len {
            if prev != BOS && rng.next_f64() < self.end_prob {
                out.push(EOS);
                out.push(BOS);
                prev2 = BOS;
                prev = BOS;
                continue;
            }
            let state = self.state_of(self.dataset, prev, prev2);
            let k = rng.weighted(&self.weights[state]);
            let tok = self.successors[state][k];
            out.push(tok);
            prev2 = prev;
            prev = tok;
        }
        out.truncate(len);
        out
    }

    /// Convenience: generator + matching vocabulary.
    pub fn with_vocab(dataset: Dataset, vocab_size: usize, seed: u64) -> (CorpusGenerator, Vocab) {
        (
            CorpusGenerator::new(dataset, vocab_size, seed),
            Vocab::new(vocab_size, seed),
        )
    }

    /// Unigram entropy (bits) of a generated stream — used by tests and
    /// the dataset-statistics report in EXPERIMENTS.md.
    pub fn unigram_entropy(stream: &[u32], vocab_size: usize) -> f64 {
        let mut counts = vec![0u64; vocab_size];
        for &t in stream {
            counts[t as usize] += 1;
        }
        let n = stream.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.log2()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const V: usize = 512;

    #[test]
    fn deterministic_streams() {
        let g = CorpusGenerator::new(Dataset::WikiSyn, V, 5);
        assert_eq!(g.generate(1000, 0), g.generate(1000, 0));
        assert_ne!(g.generate(1000, 0), g.generate(1000, 1));
    }

    #[test]
    fn tokens_in_range() {
        for ds in [Dataset::WikiSyn, Dataset::PtbSyn] {
            let g = CorpusGenerator::new(ds, V, 6);
            let s = g.generate(5000, 0);
            assert!(s.iter().all(|&t| (t as usize) < V));
        }
    }

    #[test]
    fn ptb_has_smaller_effective_vocab_and_shorter_sentences() {
        let gw = CorpusGenerator::new(Dataset::WikiSyn, V, 7);
        let gp = CorpusGenerator::new(Dataset::PtbSyn, V, 7);
        let sw = gw.generate(40_000, 0);
        let sp = gp.generate(40_000, 0);
        let distinct = |s: &[u32]| s.iter().collect::<std::collections::HashSet<_>>().len();
        assert!(
            distinct(&sp) < distinct(&sw),
            "ptb distinct {} !< wiki {}",
            distinct(&sp),
            distinct(&sw)
        );
        let eos_count = |s: &[u32]| s.iter().filter(|&&t| t == EOS).count();
        assert!(eos_count(&sp) > eos_count(&sw) * 2, "ptb sentences should be shorter");
    }

    #[test]
    fn corpora_are_statistically_different() {
        let gw = CorpusGenerator::new(Dataset::WikiSyn, V, 8);
        let gp = CorpusGenerator::new(Dataset::PtbSyn, V, 8);
        let ew = CorpusGenerator::unigram_entropy(&gw.generate(50_000, 0), V);
        let ep = CorpusGenerator::unigram_entropy(&gp.generate(50_000, 0), V);
        assert!(ew > ep, "wiki entropy {ew} !> ptb {ep}");
        assert!(ew > 3.0, "wiki-syn should be nontrivial: {ew}");
    }

    #[test]
    fn markov_structure_is_learnable() {
        // the bigram-conditional entropy must be far below unigram
        // entropy — otherwise there is nothing for a model to learn
        let g = CorpusGenerator::new(Dataset::WikiSyn, V, 9);
        let s = g.generate(100_000, 0);
        let uni = CorpusGenerator::unigram_entropy(&s, V);
        // conditional entropy H(next | prev) via bigram counts
        let mut pair = std::collections::HashMap::<(u32, u32), u64>::new();
        let mut ctx = std::collections::HashMap::<u32, u64>::new();
        for w in s.windows(2) {
            *pair.entry((w[0], w[1])).or_default() += 1;
            *ctx.entry(w[0]).or_default() += 1;
        }
        let n = (s.len() - 1) as f64;
        let mut cond = 0.0;
        for (&(a, _), &c) in &pair {
            let p_pair = c as f64 / n;
            let p_cond = c as f64 / ctx[&a] as f64;
            cond -= p_pair * p_cond.log2();
        }
        assert!(
            cond < uni - 0.5,
            "conditional {cond} not much below unigram {uni}"
        );
    }

    #[test]
    fn dataset_parse() {
        assert_eq!(Dataset::parse("wiki-syn"), Some(Dataset::WikiSyn));
        assert_eq!(Dataset::parse("ptb"), Some(Dataset::PtbSyn));
        assert_eq!(Dataset::parse("imagenet"), None);
    }
}
