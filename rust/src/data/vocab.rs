//! Synthetic vocabulary: deterministic syllable-built words with a
//! reserved control-token block, plus a whitespace tokenizer over it.
//!
//! Serving examples want human-readable prompts/continuations; the
//! vocabulary maps token ids to pronounceable words (`"toka"`, `"rimo"`,
//! …) generated from the seed, so `detokenize(tokenize(s)) == s` for any
//! in-vocabulary string.

use crate::util::Rng;
use std::collections::HashMap;

/// Reserved ids.
pub const BOS: u32 = 0;
pub const EOS: u32 = 1;
pub const UNK: u32 = 2;
/// First ordinary word id.
pub const FIRST_WORD: u32 = 3;

const ONSETS: &[&str] = &[
    "b", "d", "f", "g", "h", "j", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z", "br", "dr",
    "gr", "kr", "pl", "st", "tr", "sk",
];
const NUCLEI: &[&str] = &["a", "e", "i", "o", "u", "ai", "ei", "ou"];

/// A fixed-size synthetic vocabulary.
#[derive(Clone)]
pub struct Vocab {
    words: Vec<String>,
    index: HashMap<String, u32>,
}

impl Vocab {
    /// Build a vocabulary of `size` tokens (including the 3 reserved).
    /// Words are unique, deterministic for a seed.
    pub fn new(size: usize, seed: u64) -> Vocab {
        assert!(size > FIRST_WORD as usize + 1, "vocab too small");
        let mut rng = Rng::new(seed ^ 0x0CAB_1E57);
        let mut words: Vec<String> = vec!["<bos>".into(), "<eos>".into(), "<unk>".into()];
        let mut index = HashMap::new();
        for (i, w) in words.iter().enumerate() {
            index.insert(w.clone(), i as u32);
        }
        while words.len() < size {
            let syllables = 1 + rng.below(3) as usize;
            let mut w = String::new();
            for _ in 0..=syllables {
                w.push_str(ONSETS[rng.range(0, ONSETS.len())]);
                w.push_str(NUCLEI[rng.range(0, NUCLEI.len())]);
            }
            if !index.contains_key(&w) {
                index.insert(w.clone(), words.len() as u32);
                words.push(w);
            }
        }
        Vocab { words, index }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Word for a token id (`<unk>` if out of range).
    pub fn word(&self, id: u32) -> &str {
        self.words
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("<unk>")
    }

    /// Token id for a word (UNK when unknown).
    pub fn id(&self, word: &str) -> u32 {
        self.index.get(word).copied().unwrap_or(UNK)
    }

    /// Whitespace tokenize.
    pub fn tokenize(&self, text: &str) -> Vec<u32> {
        text.split_whitespace().map(|w| self.id(w)).collect()
    }

    /// Join token ids back into text.
    pub fn detokenize(&self, tokens: &[u32]) -> String {
        tokens
            .iter()
            .map(|&t| self.word(t))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_unique() {
        let a = Vocab::new(512, 1);
        let b = Vocab::new(512, 1);
        assert_eq!(a.len(), 512);
        for i in 0..512u32 {
            assert_eq!(a.word(i), b.word(i));
        }
        let mut seen = std::collections::HashSet::new();
        for i in 0..512u32 {
            assert!(seen.insert(a.word(i).to_string()), "dup word {}", a.word(i));
        }
    }

    #[test]
    fn roundtrip() {
        let v = Vocab::new(256, 3);
        let text = format!("{} {} {}", v.word(10), v.word(77), v.word(200));
        let toks = v.tokenize(&text);
        assert_eq!(toks, vec![10, 77, 200]);
        assert_eq!(v.detokenize(&toks), text);
    }

    #[test]
    fn unknown_maps_to_unk() {
        let v = Vocab::new(128, 9);
        assert_eq!(v.id("zzzzzzzzzzz"), UNK);
    }

    #[test]
    fn different_seeds_differ() {
        let a = Vocab::new(128, 1);
        let b = Vocab::new(128, 2);
        let same = (FIRST_WORD..128).filter(|&i| a.word(i) == b.word(i)).count();
        assert!(same < 30, "vocabularies suspiciously similar: {same}");
    }
}
