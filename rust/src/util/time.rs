//! Timing helpers shared by the bench harness and the serving metrics.

use std::time::{Duration, Instant};

/// The blessed monotonic-clock read. `clippy.toml` disallows raw
/// `Instant::now()` so every timestamp in the crate flows through this
/// one choke point (keeps timing auditable and leaves room for a
/// virtual clock in tests).
#[allow(clippy::disallowed_methods)]
pub fn now() -> Instant {
    Instant::now()
}

/// A simple stopwatch.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = now();
        e
    }
}

/// Format a duration in engineer-friendly units.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Format a f64 seconds value.
pub fn fmt_secs(s: f64) -> String {
    fmt_duration(Duration::from_secs_f64(s.max(0.0)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed_ms() >= 1.0);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000 s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.000 ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7.000 µs");
        assert!(fmt_duration(Duration::from_nanos(42)).ends_with("ns"));
    }
}
