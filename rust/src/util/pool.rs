//! A small scoped thread pool.
//!
//! The vendored crate set has neither `rayon` nor `tokio`, so the library
//! carries its own work-stealing-free but contention-light pool:
//! a fixed set of workers pulling closures from a shared injector queue.
//! [`ThreadPool::scope`] provides rayon-like scoped parallelism (borrowed
//! data, joined before return), which is all the quantization and serving
//! hot paths need.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::collections::VecDeque;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: Mutex<bool>,
    in_flight: AtomicUsize,
    done: Condvar,
    done_lock: Mutex<()>,
}

/// Fixed-size thread pool with scoped execution.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Spawn a pool with `threads` workers (min 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: Mutex::new(false),
            in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
            done_lock: Mutex::new(()),
        });
        let workers = (0..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("gptqt-pool-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { shared, workers, threads }
    }

    /// Pool sized to the machine (`available_parallelism`, capped at 16).
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16);
        Self::new(n)
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Submit a detached job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Box::new(f));
        drop(q);
        self.shared.available.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn join(&self) {
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }

    /// Run `f` for each index in `0..n`, partitioned into contiguous chunks
    /// across workers, blocking until all complete. `f` may borrow from the
    /// caller's stack (scoped via `std::thread::scope` semantics emulated
    /// by transmuting lifetimes safely through join-before-return).
    pub fn scope_chunks<F>(&self, n: usize, f: F)
    where
        F: Fn(std::ops::Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        let parts = self.threads.min(n);
        self.scope_chunks_with(n, n.div_ceil(parts), f);
    }

    /// Like [`ThreadPool::scope_chunks`], but rounds the per-worker chunk
    /// size up to a multiple of `align`, so every chunk except possibly
    /// the last starts on an `align` boundary and spans a whole number of
    /// `align` blocks. The SIMD kernels partition output rows with this
    /// so each worker's accumulator range is a whole number of vector
    /// blocks (scalar tails only in the final chunk); the per-index work
    /// and ordering are identical to `scope_chunks`, only the chunk
    /// boundaries move.
    pub fn scope_chunks_aligned<F>(&self, n: usize, align: usize, f: F)
    where
        F: Fn(std::ops::Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        let align = align.max(1);
        let parts = self.threads.min(n);
        let chunk = n.div_ceil(parts).div_ceil(align) * align;
        self.scope_chunks_with(n, chunk, f);
    }

    /// Shared body of the scoped partitioners: `0..n` split into chunks
    /// of `chunk` (last one ragged), one pool job per non-empty chunk.
    fn scope_chunks_with<F>(&self, n: usize, chunk: usize, f: F)
    where
        F: Fn(std::ops::Range<usize>) + Sync,
    {
        debug_assert!(chunk > 0);
        let parts = n.div_ceil(chunk);
        // SAFETY: every job is joined before `scope_chunks_with` returns,
        // so the borrowed closure outlives all uses. We enforce the join
        // with an explicit counter rather than relying on pool drop order.
        let f_ref: &(dyn Fn(std::ops::Range<usize>) + Sync) = &f;
        let f_static: &'static (dyn Fn(std::ops::Range<usize>) + Sync) =
            // SAFETY: lifetime erasure only — the counter below blocks this
            // frame until every job ran, so the borrow outlives all uses.
            unsafe { std::mem::transmute(f_ref) };
        let pending = Arc::new((Mutex::new(parts), Condvar::new()));
        for p in 0..parts {
            let lo = p * chunk;
            let hi = ((p + 1) * chunk).min(n);
            let pending = Arc::clone(&pending);
            self.execute(move || {
                f_static(lo..hi);
                let (lock, cv) = &*pending;
                let mut left = lock.lock().unwrap();
                *left -= 1;
                if *left == 0 {
                    cv.notify_all();
                }
            });
        }
        let (lock, cv) = &*pending;
        let mut left = lock.lock().unwrap();
        while *left != 0 {
            left = cv.wait(left).unwrap();
        }
    }

    /// Map `0..n` in parallel collecting results in index order.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        {
            let out_ptr = SendPtr(out.as_mut_ptr());
            self.scope_chunks(n, |range| {
                let out_ptr = &out_ptr;
                for i in range {
                    // SAFETY: disjoint indices per chunk; joined before return.
                    unsafe { *out_ptr.0.add(i) = Some(f(i)) };
                }
            });
        }
        out.into_iter().map(|o| o.expect("map slot filled")).collect()
    }
}

struct SendPtr<T>(*mut T);
// SAFETY: `map` hands each worker a disjoint output slot and joins before
// reading — the pointer is never aliased for writes.
unsafe impl<T> Sync for SendPtr<T> {}
// SAFETY: the pointer outlives the scope — `map` joins before return.
unsafe impl<T> Send for SendPtr<T> {}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if *sh.shutdown.lock().unwrap() {
                    break None;
                }
                q = sh.available.wait(q).unwrap();
            }
        };
        match job {
            Some(job) => {
                job();
                if sh.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = sh.done_lock.lock().unwrap();
                    sh.done.notify_all();
                }
            }
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.join();
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Process-wide shared pool for hot-path kernels.
pub fn global() -> &'static ThreadPool {
    use once_cell::sync::Lazy;
    static POOL: Lazy<ThreadPool> = Lazy::new(ThreadPool::default_size);
    &POOL
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_chunks_covers_all_indices() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        pool.scope_chunks(97, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map(1000, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn scope_zero_is_noop() {
        let pool = ThreadPool::new(2);
        pool.scope_chunks(0, |_| panic!("should not run"));
        pool.scope_chunks_aligned(0, 8, |_| panic!("should not run"));
    }

    #[test]
    fn aligned_chunks_cover_all_indices_on_block_boundaries() {
        let pool = ThreadPool::new(3);
        for (n, align) in [(97usize, 8usize), (64, 8), (5, 8), (100, 16), (33, 1)] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            let starts = Mutex::new(Vec::new());
            pool.scope_chunks_aligned(n, align, |range| {
                starts.lock().unwrap().push((range.start, range.end));
                for i in range {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                "n={n} align={align}: every index exactly once"
            );
            for &(lo, hi) in starts.lock().unwrap().iter() {
                assert_eq!(lo % align, 0, "n={n} align={align}: chunk start {lo}");
                assert!(hi % align == 0 || hi == n, "n={n} align={align}: chunk end {hi}");
            }
        }
    }

    #[test]
    fn nested_sequential_scopes() {
        let pool = ThreadPool::new(2);
        let total = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let t = Arc::clone(&total);
            pool.scope_chunks(10, move |r| {
                t.fetch_add(r.len() as u64, Ordering::SeqCst);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 100);
    }
}
