//! Foundation utilities: deterministic RNG, thread pool, timing, histograms.
//!
//! Everything here exists because the offline vendored crate set has no
//! `rand`, `rayon`, `criterion`, or `hdrhistogram`; the implementations are
//! deliberately small, tested, and tailored to what the quantization and
//! serving paths need.

pub mod alloc;
pub mod fault;
pub mod hist;
pub mod pool;
pub mod rng;
pub mod time;

pub use hist::Histogram;
pub use pool::ThreadPool;
pub use rng::Rng;
pub use time::Stopwatch;
