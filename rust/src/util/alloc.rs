//! Opt-in global-allocator instrumentation for steady-state
//! zero-allocation checks.
//!
//! [`CountingAllocator`] wraps [`std::alloc::System`] and bumps atomic
//! counters on every heap event. The library never installs it — a test
//! binary opts in with
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: gptqt::util::alloc::CountingAllocator = CountingAllocator;
//! ```
//!
//! and then compares [`snapshot`]s around the code under test. When no
//! binary installs it the counters simply stay at zero, so library code
//! (e.g. `eval::speed::measure_decode_batch`) can record
//! allocations-per-step unconditionally: the figure is real under the
//! instrumented test and inert zero everywhere else ([`enabled`] tells
//! the two apart).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static FREES: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// `System` with relaxed-atomic event counting. Zero overhead beyond
/// two relaxed `fetch_add`s per event.
pub struct CountingAllocator;

// SAFETY: pure pass-through to `System` — every layout/pointer contract is
// forwarded unchanged; the counters are relaxed atomics with no effect on
// allocation behavior.
unsafe impl GlobalAlloc for CountingAllocator {
    // SAFETY: defers to `System.alloc` under the same contract.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: defers to `System.dealloc` under the same contract.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        FREES.fetch_add(1, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }

    // SAFETY: defers to `System.alloc_zeroed` under the same contract.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    // SAFETY: defers to `System.realloc` under the same contract.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // a growth counts as one allocation event — exactly what a
        // steady-state check wants to catch
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Cumulative heap-event counts at one instant.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocSnapshot {
    pub allocs: u64,
    pub frees: u64,
    pub bytes: u64,
}

impl AllocSnapshot {
    /// Allocation events between `earlier` and `self`.
    pub fn allocs_since(&self, earlier: &AllocSnapshot) -> u64 {
        self.allocs.saturating_sub(earlier.allocs)
    }
}

/// Current counter values (all zero unless a binary installed
/// [`CountingAllocator`] as its global allocator).
pub fn snapshot() -> AllocSnapshot {
    AllocSnapshot {
        allocs: ALLOCS.load(Ordering::Relaxed),
        frees: FREES.load(Ordering::Relaxed),
        bytes: BYTES.load(Ordering::Relaxed),
    }
}

/// Whether the counting allocator is actually installed in this binary
/// (heuristic: any recorded event — reaching any caller of this
/// function has long since allocated something).
pub fn enabled() -> bool {
    ALLOCS.load(Ordering::Relaxed) > 0
}
