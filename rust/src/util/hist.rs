//! Latency histogram with percentile queries.
//!
//! Log-bucketed (HdrHistogram-flavoured) over nanoseconds: constant-size,
//! lock-free-friendly recording, good-enough percentile resolution for
//! serving metrics (≤ ~4% relative error per bucket).

use std::time::Duration;

const SUB_BUCKETS: usize = 32; // per power-of-two magnitude
const MAGNITUDES: usize = 40; // covers 1ns .. ~18 minutes

/// Log-bucketed histogram of durations.
#[derive(Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum_ns: u128,
    min_ns: u64,
    max_ns: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; SUB_BUCKETS * MAGNITUDES],
            total: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    fn bucket_of(ns: u64) -> usize {
        if ns < SUB_BUCKETS as u64 {
            return ns as usize;
        }
        let mag = 63 - ns.leading_zeros() as usize; // >= 5
        let shift = mag - 5; // keep 5 significant bits
        let sub = ((ns >> shift) as usize) & (SUB_BUCKETS - 1);
        let idx = (mag - 4) * SUB_BUCKETS + sub;
        idx.min(SUB_BUCKETS * MAGNITUDES - 1)
    }

    /// Lower edge (ns) of a bucket index — used to report percentiles.
    fn bucket_low(idx: usize) -> u64 {
        if idx < SUB_BUCKETS {
            return idx as u64;
        }
        let mag = idx / SUB_BUCKETS + 4;
        let sub = idx % SUB_BUCKETS;
        let shift = mag - 5;
        ((1u64 << 5) | sub as u64) << shift
    }

    pub fn record(&mut self, d: Duration) {
        self.record_ns(d.as_nanos().min(u128::from(u64::MAX)) as u64);
    }

    pub fn record_ns(&mut self, ns: u64) {
        self.counts[Self::bucket_of(ns)] += 1;
        self.total += 1;
        self.sum_ns += ns as u128;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> Duration {
        if self.total == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos((self.sum_ns / self.total as u128) as u64)
    }

    pub fn min(&self) -> Duration {
        if self.total == 0 {
            Duration::ZERO
        } else {
            Duration::from_nanos(self.min_ns)
        }
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns)
    }

    /// p in [0, 100]. Returns the lower edge of the bucket containing the
    /// p-th percentile sample.
    pub fn percentile(&self, p: f64) -> Duration {
        if self.total == 0 {
            return Duration::ZERO;
        }
        let rank = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let ns = Self::bucket_low(i).clamp(self.min_ns, self.max_ns);
                return Duration::from_nanos(ns);
            }
        }
        Duration::from_nanos(self.max_ns)
    }

    /// One-line summary: `n=..  mean=..  p50=..  p95=..  p99=..  max=..`.
    pub fn summary(&self) -> String {
        use super::time::fmt_duration as f;
        format!(
            "n={} mean={} p50={} p95={} p99={} max={}",
            self.total,
            f(self.mean()),
            f(self.percentile(50.0)),
            f(self.percentile(95.0)),
            f(self.percentile(99.0)),
            f(self.max()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.percentile(99.0), Duration::ZERO);
    }

    #[test]
    fn single_value() {
        let mut h = Histogram::new();
        h.record(Duration::from_micros(100));
        assert_eq!(h.count(), 1);
        let p50 = h.percentile(50.0).as_nanos() as f64;
        assert!((p50 - 1e5).abs() / 1e5 < 0.05, "p50={p50}");
    }

    #[test]
    fn percentiles_ordered() {
        let mut h = Histogram::new();
        for i in 1..=1000u64 {
            h.record_ns(i * 1000);
        }
        let p50 = h.percentile(50.0);
        let p95 = h.percentile(95.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p95 && p95 <= p99 && p99 <= h.max());
        // ~relative accuracy
        let p50n = p50.as_nanos() as f64;
        assert!((p50n - 500_000.0).abs() / 500_000.0 < 0.07, "p50={p50n}");
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for i in 0..100 {
            a.record_ns(1000 + i);
            b.record_ns(2000 + i);
        }
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert!(a.max() >= Duration::from_nanos(2099));
    }

    #[test]
    fn bucket_low_monotone() {
        let mut prev = 0;
        for i in 0..SUB_BUCKETS * MAGNITUDES {
            let lo = Histogram::bucket_low(i);
            assert!(lo >= prev, "bucket {i}: {lo} < {prev}");
            prev = lo;
        }
    }

    #[test]
    fn bucket_of_roundtrip() {
        for ns in [0u64, 1, 31, 32, 33, 100, 1_000, 123_456, 10_000_000, u32::MAX as u64] {
            let idx = Histogram::bucket_of(ns);
            let lo = Histogram::bucket_low(idx);
            let hi = Histogram::bucket_low((idx + 1).min(SUB_BUCKETS * MAGNITUDES - 1));
            assert!(lo <= ns, "ns={ns} lo={lo}");
            if idx + 1 < SUB_BUCKETS * MAGNITUDES {
                assert!(ns <= hi.max(lo), "ns={ns} hi={hi}");
            }
        }
    }
}
