//! Deterministic fault injection for the chaos harness.
//!
//! Serving code marks recoverable failure sites with
//! `fault::point("kv_pool.append")`. In a normal build the call is a
//! `const`-foldable no-op returning `false`; with the `chaos` feature
//! it consults a seeded schedule installed by the test harness and
//! returns `true` when the site should fail this time.
//!
//! Determinism: whether a point fires depends only on the installed
//! seed, the point's name, and that point's own call counter — never on
//! wall-clock time or cross-point interleaving. Replaying the same
//! workload with the same seed fires the same faults at the same calls,
//! which is what lets `rust/tests/chaos.rs` compare a chaos run against
//! a fault-free run bitwise.
//!
//! Adding a new injection point (see CONTRIBUTING.md):
//!   1. call `crate::util::fault::point("area.site")` at the decision,
//!   2. contain the `true` branch like any real failure (terminate only
//!      the offending request, return its blocks, bump
//!      `metrics.faults_injected`),
//!   3. add the name to `EXPECTED_POINTS` in `rust/tests/chaos.rs` so
//!      the churn test proves the site both fires and is survived.

#[cfg(feature = "chaos")]
mod imp {
    use std::collections::BTreeMap;
    use std::sync::{Mutex, MutexGuard};

    #[derive(Default)]
    struct State {
        /// `None` = disarmed: every point reports no-fault.
        plan: Option<Plan>,
        /// Per-point call counters (advance even while disarmed so a
        /// late `install` still sees deterministic indices relative to
        /// installation).
        calls: BTreeMap<&'static str, u64>,
        /// Per-point fired counters.
        fired: BTreeMap<&'static str, u64>,
        /// Point names forced to fire exactly once on their next call.
        armed: Vec<&'static str>,
    }

    struct Plan {
        seed: u64,
        /// Fire when `hash % den < num`.
        num: u64,
        den: u64,
    }

    static STATE: Mutex<State> = Mutex::new(State {
        plan: None,
        calls: BTreeMap::new(),
        fired: BTreeMap::new(),
        armed: Vec::new(),
    });

    fn lock() -> MutexGuard<'static, State> {
        // A poisoned injector mutex means a test thread panicked while
        // holding it; chaos state is test-only, so recover the guard.
        match STATE.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// splitmix64 finisher — cheap, well-mixed, and stable across
    /// platforms (the schedule is part of the chaos tests' contract).
    fn mix(mut x: u64) -> u64 {
        x ^= x >> 30;
        x = x.wrapping_mul(0xbf58476d1ce4e5b9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94d049bb133111eb);
        x ^ (x >> 31)
    }

    fn name_hash(name: &str) -> u64 {
        // FNV-1a; dependency-free and stable.
        let mut h = 0xcbf29ce484222325u64;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    /// Install a seeded schedule: each point call fires independently
    /// with probability `num / den`. Resets all counters.
    pub fn install(seed: u64, num: u64, den: u64) {
        assert!(den > 0, "fault rate denominator must be positive");
        let mut s = lock();
        s.plan = Some(Plan { seed, num, den });
        s.calls.clear();
        s.fired.clear();
        s.armed.clear();
    }

    /// Disarm the schedule (counters keep their values for inspection).
    pub fn uninstall() {
        lock().plan = None;
    }

    /// Force `name` to fire on its next call, exactly once, regardless
    /// of any installed schedule. Used by targeted containment tests.
    pub fn arm(name: &'static str) {
        lock().armed.push(name);
    }

    /// Total faults fired since the last `install`.
    pub fn fired_total() -> u64 {
        lock().fired.values().sum()
    }

    /// Faults fired at one point since the last `install`.
    pub fn fired_at(name: &str) -> u64 {
        lock().fired.get(name).copied().unwrap_or(0)
    }

    /// Every point name that has been *called* (fired or not) since the
    /// last `install` — the registry the chaos suite checks for
    /// coverage.
    pub fn points_seen() -> Vec<&'static str> {
        lock().calls.keys().copied().collect()
    }

    /// Should this site fail right now?
    pub fn point(name: &'static str) -> bool {
        let mut s = lock();
        let count = {
            let c = s.calls.entry(name).or_insert(0);
            *c += 1;
            *c
        };
        if let Some(pos) = s.armed.iter().position(|&n| n == name) {
            s.armed.remove(pos);
            *s.fired.entry(name).or_insert(0) += 1;
            return true;
        }
        let fire = match &s.plan {
            Some(plan) => mix(plan.seed ^ name_hash(name).wrapping_add(count)) % plan.den < plan.num,
            None => false,
        };
        if fire {
            *s.fired.entry(name).or_insert(0) += 1;
        }
        fire
    }
}

#[cfg(feature = "chaos")]
pub use imp::{arm, fired_at, fired_total, install, point, points_seen, uninstall};

/// No-op stub: without the `chaos` feature every injection point
/// compiles to a constant `false` and the optimizer deletes the branch.
#[cfg(not(feature = "chaos"))]
#[inline(always)]
pub fn point(_name: &'static str) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The injector state is process-global; serialize the tests that
    /// touch it so they cannot see each other's plans.
    #[cfg(feature = "chaos")]
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[cfg(feature = "chaos")]
    fn locked() -> std::sync::MutexGuard<'static, ()> {
        match LOCK.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    #[test]
    fn disarmed_points_never_fire() {
        // Holds in every build: with `chaos` off this is the stub; with
        // `chaos` on the guard below disarms any schedule first.
        #[cfg(feature = "chaos")]
        let _g = {
            let g = locked();
            uninstall();
            g
        };
        assert!(!point("unit.never-armed"));
    }

    #[cfg(feature = "chaos")]
    #[test]
    fn schedule_is_deterministic_and_rate_bounded() {
        let _g = locked();
        install(0xC0FFEE, 1, 8);
        let a: Vec<bool> = (0..256).map(|_| point("unit.det")).collect();
        install(0xC0FFEE, 1, 8);
        let b: Vec<bool> = (0..256).map(|_| point("unit.det")).collect();
        assert_eq!(a, b, "same seed must replay the same schedule");
        let fires = a.iter().filter(|&&f| f).count();
        assert!(fires > 0, "a 1/8 rate over 256 calls should fire");
        assert!(fires < 128, "rate wildly above 1/8: {fires}/256");
        install(0xBEEF, 1, 8);
        let c: Vec<bool> = (0..256).map(|_| point("unit.det")).collect();
        assert_ne!(a, c, "different seed should differ somewhere");
        uninstall();
    }

    #[cfg(feature = "chaos")]
    #[test]
    fn arm_fires_exactly_once() {
        let _g = locked();
        install(1, 0, 1); // rate 0: only armed faults fire
        arm("unit.armed");
        assert!(point("unit.armed"));
        assert!(!point("unit.armed"));
        assert_eq!(fired_at("unit.armed"), 1);
        assert!(points_seen().contains(&"unit.armed"));
        uninstall();
    }
}
