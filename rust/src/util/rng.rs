//! Deterministic pseudo-random number generation.
//!
//! The vendored crate set has no `rand`, so we implement xoshiro256**
//! (Blackman & Vigna) seeded through SplitMix64. All stochastic behaviour
//! in the library (synthetic corpora, weight init fallback, calibration
//! sampling, property-test generators) flows through [`Rng`] so every run
//! is reproducible from a single `u64` seed.

/// SplitMix64 step — used to expand a single seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator.
///
/// Fast, high-quality, and trivially reproducible. Not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-thread / per-layer
    /// streams) without correlating sequences.
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Unbiased via rejection on the low product half.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller (cached second value discarded for
    /// simplicity; quantization workloads are not rng-bound).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, sigma²) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from an (unnormalized) non-negative weight vector.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "weighted: zero total");
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut root = Rng::new(7);
        let mut c1 = root.fork(0);
        let mut c2 = root.fork(1);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy_bucket() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
