//! Linear (uniform) quantization: RTN, per-row asymmetric grids, and the
//! min-MSE clip-range search used by the `GPTQ(min MSE)` baseline
//! (paper Table V).
//!
//! Convention (matching the paper's Eq. 5): a weight is stored as
//! `W_int = round(W/S) − qz` clamped to `[0, 2ᵇ−1]` and dequantized as
//! `Ŵ = S·(W_int + qz)` — i.e. an asymmetric grid with real-valued zero
//! offset `Z = S·qz` aligned to the row minimum.

use super::RowCodebook;
use crate::tensor::Tensor;

/// Per-row uniform quantization grid.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UniformGrid {
    /// Scaling factor `S` (grid pitch).
    pub scale: f32,
    /// Zero offset in *integer* units: `Ŵ = S·(q + qz)`.
    pub qz: f32,
    /// Number of representable levels (`2ᵇ`).
    pub levels: u32,
}

impl UniformGrid {
    /// Min/max grid over a row of weights (the RTN / vanilla-GPTQ choice:
    /// `S = (Wmax − Wmin)/(2ᵇ − 1)`, zero at `Wmin`).
    pub fn from_minmax(row: &[f32], bits: u32) -> UniformGrid {
        let (mut mn, mut mx) = (f32::INFINITY, f32::NEG_INFINITY);
        for &w in row {
            mn = mn.min(w);
            mx = mx.max(w);
        }
        if !mn.is_finite() || !mx.is_finite() {
            (mn, mx) = (0.0, 0.0);
        }
        Self::from_range(mn, mx, bits)
    }

    /// Grid spanning `[lo, hi]` with `2ᵇ` levels.
    pub fn from_range(lo: f32, hi: f32, bits: u32) -> UniformGrid {
        let levels = 1u32 << bits;
        let span = (hi - lo).max(1e-12);
        let scale = span / (levels - 1) as f32;
        UniformGrid { scale, qz: lo / scale, levels }
    }

    /// Integer code for a weight (clamped).
    #[inline]
    pub fn encode(&self, w: f32) -> u32 {
        let q = (w / self.scale - self.qz).round();
        q.clamp(0.0, (self.levels - 1) as f32) as u32
    }

    /// Dequantize an integer code.
    #[inline]
    pub fn decode(&self, q: u32) -> f32 {
        self.scale * (q as f32 + self.qz)
    }

    /// Continuous (pre-round) grid coordinate of a weight. Used by the
    /// GPTQT candidate scoring (residual within a grid cell).
    #[inline]
    pub fn coord(&self, w: f32) -> f32 {
        w / self.scale - self.qz
    }
}

impl RowCodebook for UniformGrid {
    #[inline]
    fn snap(&self, w: f32) -> f32 {
        self.decode(self.encode(w))
    }

    fn levels(&self) -> Vec<f32> {
        (0..self.levels).map(|q| self.decode(q)).collect()
    }
}

/// Grid-search the clip range to minimize the *weight* MSE — the
/// `GPTQ(min MSE)` baseline the paper shows **overfits** (Table V).
///
/// Shrinks the max-abs range symmetrically through `grid` steps and keeps
/// the best; mirrors the common "clipped linear quantization" recipe.
pub fn min_mse_grid(row: &[f32], bits: u32, grid: usize) -> UniformGrid {
    let base = UniformGrid::from_minmax(row, bits);
    let (mut mn, mut mx) = (f32::INFINITY, f32::NEG_INFINITY);
    for &w in row {
        mn = mn.min(w);
        mx = mx.max(w);
    }
    if !mn.is_finite() || mx - mn < 1e-12 {
        return base;
    }
    // Shrink the low and high clip points independently (outliers are
    // usually one-sided), each over `grid` steps down to 60 % of the span.
    let steps = (grid as f32).sqrt().ceil() as usize;
    let mut best = base;
    let mut best_err = row_mse(row, &base);
    let span = mx - mn;
    for lo_step in 0..=steps {
        let lo = mn + span * 0.4 * lo_step as f32 / steps.max(1) as f32;
        for hi_step in 0..=steps {
            if lo_step == 0 && hi_step == 0 {
                continue; // base already scored
            }
            let hi = mx - span * 0.4 * hi_step as f32 / steps.max(1) as f32;
            if hi - lo < span * 0.1 {
                continue;
            }
            let g = UniformGrid::from_range(lo, hi, bits);
            let err = row_mse(row, &g);
            if err < best_err {
                best_err = err;
                best = g;
            }
        }
    }
    best
}

fn row_mse(row: &[f32], g: &UniformGrid) -> f64 {
    row.iter()
        .map(|&w| {
            let d = (w - g.snap(w)) as f64;
            d * d
        })
        .sum()
}

/// Round-to-nearest quantization of a full matrix (no compensation):
/// the `RTN` rows of Tables I–III.
pub fn rtn_quantize(w: &Tensor, bits: u32) -> (Tensor, Vec<UniformGrid>) {
    let mut out = w.clone();
    let mut grids = Vec::with_capacity(w.rows());
    for r in 0..w.rows() {
        let grid = UniformGrid::from_minmax(w.row(r), bits);
        for v in out.row_mut(r) {
            *v = grid.snap(*v);
        }
        grids.push(grid);
    }
    (out, grids)
}

/// Integer-form storage of a linearly quantized layer — what the
/// `gemv_dequant` hot path streams (per-row scale/zero + codes).
#[derive(Clone)]
pub struct IntLayer {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    /// Per-row `(scale, qz)`.
    pub row_params: Vec<(f32, f32)>,
    /// Row-major integer codes (one byte each; ≤ 4 bits used).
    pub codes: Vec<u8>,
}

impl IntLayer {
    /// Encode a dequantized matrix given its per-row grids. Every entry of
    /// `w` must already be a representable grid level (i.e. the output of
    /// the quantizer); encoding is exact in that case.
    pub fn encode(w: &Tensor, grids: &[UniformGrid], bits: u32) -> IntLayer {
        assert_eq!(w.rows(), grids.len());
        let mut codes = Vec::with_capacity(w.len());
        let mut row_params = Vec::with_capacity(w.rows());
        for r in 0..w.rows() {
            let g = &grids[r];
            row_params.push((g.scale, g.qz));
            for &v in w.row(r) {
                codes.push(g.encode(v) as u8);
            }
        }
        IntLayer { rows: w.rows(), cols: w.cols(), bits, row_params, codes }
    }

    /// Dense dequantized view (for testing / fallback).
    pub fn dequant(&self) -> Tensor {
        let mut t = Tensor::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (s, qz) = self.row_params[r];
            let row = t.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                *v = s * (self.codes[r * self.cols + c] as f32 + qz);
            }
        }
        t
    }

    /// Storage bytes of the packed form this layer models
    /// (codes at `bits` bits + per-row params) — used for the memory
    /// accounting in the speed experiments.
    pub fn packed_bytes(&self) -> usize {
        (self.rows * self.cols * self.bits as usize).div_ceil(8) + self.rows * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn grid_encode_decode_roundtrip() {
        let g = UniformGrid::from_range(-1.0, 1.0, 3);
        for q in 0..8u32 {
            assert_eq!(g.encode(g.decode(q)), q);
        }
        // endpoints are representable
        assert!((g.snap(-1.0) + 1.0).abs() < 1e-6);
        assert!((g.snap(1.0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn snap_is_idempotent_and_nearest() {
        let mut rng = Rng::new(31);
        let g = UniformGrid::from_range(-2.0, 3.0, 4);
        let levels = RowCodebook::levels(&g);
        for _ in 0..500 {
            let w = rng.next_f32() * 6.0 - 3.0;
            let s = g.snap(w);
            assert_eq!(g.snap(s), s, "idempotent");
            let nearest = levels
                .iter()
                .cloned()
                .min_by(|a, b| (a - w).abs().partial_cmp(&(b - w).abs()).unwrap())
                .unwrap();
            assert!((s - nearest).abs() < 1e-5, "w={w} snap={s} nearest={nearest}");
        }
    }

    #[test]
    fn constant_row_does_not_blow_up() {
        let g = UniformGrid::from_minmax(&[0.5; 16], 3);
        assert!(g.scale > 0.0);
        assert!((g.snap(0.5) - 0.5).abs() < 1e-5);
    }

    #[test]
    fn rtn_reduces_to_levels() {
        let mut rng = Rng::new(32);
        let w = Tensor::randn(4, 64, 1.0, &mut rng);
        let (q, grids) = rtn_quantize(&w, 3);
        for r in 0..4 {
            let levels = RowCodebook::levels(&grids[r]);
            for &v in q.row(r) {
                assert!(levels.iter().any(|&l| (l - v).abs() < 1e-5));
            }
        }
        // 3-bit error is bounded by half a grid pitch
        for r in 0..4 {
            let g = &grids[r];
            for (a, b) in w.row(r).iter().zip(q.row(r)) {
                assert!((a - b).abs() <= g.scale * 0.5 + 1e-5);
            }
        }
    }

    #[test]
    fn min_mse_never_worse_than_minmax() {
        let mut rng = Rng::new(33);
        for _ in 0..10 {
            let row: Vec<f32> = (0..256).map(|_| rng.normal_f32()).collect();
            let base = UniformGrid::from_minmax(&row, 3);
            let tuned = min_mse_grid(&row, 3, 16);
            assert!(row_mse(&row, &tuned) <= row_mse(&row, &base) + 1e-9);
        }
    }

    #[test]
    fn min_mse_clips_outliers() {
        // a moderate one-sided outlier over many weights: clipping it
        // costs one large error but sharpens the grid for everyone else
        let mut row = vec![0.0f32; 1024];
        let mut rng = Rng::new(34);
        for v in row.iter_mut() {
            *v = rng.normal_f32() * 0.1;
        }
        row[0] = 5.0;
        let base = UniformGrid::from_minmax(&row, 3);
        let tuned = min_mse_grid(&row, 3, 64);
        assert!(tuned.scale < base.scale);
        assert!(row_mse(&row, &tuned) < row_mse(&row, &base));
    }

    #[test]
    fn int_layer_roundtrip() {
        let mut rng = Rng::new(35);
        let w = Tensor::randn(6, 40, 1.0, &mut rng);
        let (q, grids) = rtn_quantize(&w, 3);
        let il = IntLayer::encode(&q, &grids, 3);
        let back = il.dequant();
        assert!(q.max_abs_diff(&back) < 1e-5);
        assert!(il.packed_bytes() < 6 * 40 * 4);
    }
}
