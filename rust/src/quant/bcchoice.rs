//! BCchoice enumeration — every m-bit binary-coding codebook embeddable
//! in the n-bit linear-quantization integer grid (paper §II-B Eq. 6 and
//! the tree construction of Fig. 3).
//!
//! The n-bit integer grid `{0, …, 2ⁿ−1}` *is* a binary coding
//! (paper Eq. 9): `v = c₀ + Σᵢ ±hᵢ` with `c₀ = (2ⁿ−1)/2` and bit weights
//! `hᵢ = 2^{i-1}` (`0.5, 1, 2, …`). An m-bit sub-coding is obtained by
//! assigning each of the n original bits to one of:
//!
//! * one of the m new groups — the group's α̂ is the *sum* of its bit
//!   weights (Fig. 3: merging tree levels, e.g. `α̂₂ = 2⁰ + 2¹`),
//! * "fixed +" or "fixed −" — the bit is frozen, shifting the center
//!   (Fig. 3: selecting a subtree).
//!
//! Every resulting level `ĉ ± α̂₁ ± … ± α̂ₘ` lands on the original grid by
//! construction, which is exactly the paper's `BCchoice` (e.g. n=3, m=2,
//! fixing nothing ⇒ impossible; fixing bit 1 ⇒ `{0,1,6,7}`-style sets).
//! Enumerating all assignments with non-empty groups and deduplicating by
//! level set yields the complete search space — small enough for the
//! paper's "sequential trial of each possibility" when m ≤ 4.

use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// One candidate binary-coding codebook in integer-grid units.
#[derive(Debug, Clone)]
pub struct BcCodebook {
    /// Intermediate (step-1) bit count n.
    pub n_bits: u32,
    /// Final bit count m (< n).
    pub m_bits: u32,
    /// Group weights α̂ⱼ in grid units (e.g. `[0.5, 3.0]`), one per bit.
    pub group_alphas: Vec<f32>,
    /// Center ĉ in grid units (e.g. `3.5`).
    pub center: f32,
    /// The 2^m levels, ascending. Each is an integer grid value (stored
    /// as f32; exact — magnitudes ≤ 2ⁿ).
    pub levels: Vec<f32>,
    /// `patterns[k]` = sign pattern (bit j set ⇒ +α̂ⱼ) producing
    /// `levels[k]`.
    pub patterns: Vec<u32>,
}

impl BcCodebook {
    /// Level value for a sign pattern.
    pub fn decode(&self, pattern: u32) -> f32 {
        let mut v = self.center;
        for (j, &a) in self.group_alphas.iter().enumerate() {
            v += if pattern >> j & 1 == 1 { a } else { -a };
        }
        v
    }

    /// Nearest-level index for an integer-grid coordinate.
    pub fn snap_index(&self, x: f32) -> usize {
        let ls = &self.levels;
        let mut lo = 0usize;
        let mut hi = ls.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if ls[mid] < x {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        if lo == 0 {
            0
        } else if lo == ls.len() {
            ls.len() - 1
        } else if (x - ls[lo - 1]) <= (ls[lo] - x) {
            lo - 1
        } else {
            lo
        }
    }
}

/// Enumerate all distinct m-bit binary-coding codebooks within an n-bit
/// grid. Cached per `(n, m)` — the set is shared by every row of every
/// layer.
pub fn enumerate(n_bits: u32, m_bits: u32) -> Arc<Vec<BcCodebook>> {
    static CACHE: Lazy<Mutex<HashMap<(u32, u32), Arc<Vec<BcCodebook>>>>> =
        Lazy::new(|| Mutex::new(HashMap::new()));
    if let Some(hit) = CACHE.lock().unwrap().get(&(n_bits, m_bits)) {
        return Arc::clone(hit);
    }
    let result = Arc::new(enumerate_uncached(n_bits, m_bits));
    CACHE
        .lock()
        .unwrap()
        .insert((n_bits, m_bits), Arc::clone(&result));
    result
}

fn enumerate_uncached(n_bits: u32, m_bits: u32) -> Vec<BcCodebook> {
    assert!(m_bits >= 1 && m_bits < n_bits, "need 1 ≤ m < n (got m={m_bits}, n={n_bits})");
    assert!(n_bits <= 8, "n > 8 bits explodes the search; paper uses ≤ 6");
    let n = n_bits as usize;
    let m = m_bits as usize;
    let targets = m + 2; // m groups, fix+, fix−
    let total = (targets as u64).pow(n as u32);

    // Doubled-integer arithmetic keeps everything exact: doubled bit
    // weight of original bit i is 2^i; doubled base center is 2ⁿ−1.
    let mut seen: HashMap<Vec<i32>, ()> = HashMap::new();
    let mut out = Vec::new();

    for code in 0..total {
        // decode base-(m+2) assignment
        let mut assign = [0usize; 8];
        let mut c = code;
        for a in assign.iter_mut().take(n) {
            *a = (c % targets as u64) as usize;
            c /= targets as u64;
        }
        // group weights (doubled) and center shift (doubled)
        let mut ga = vec![0i64; m];
        let mut center2: i64 = (1i64 << n) - 1;
        let mut groups_ok = true;
        for (i, &a) in assign.iter().take(n).enumerate() {
            let w2 = 1i64 << i;
            if a < m {
                ga[a] += w2;
            } else if a == m {
                center2 += w2;
            } else {
                center2 -= w2;
            }
        }
        for &g in &ga {
            if g == 0 {
                groups_ok = false;
                break;
            }
        }
        if !groups_ok {
            continue;
        }

        // levels (doubled) for all 2^m sign patterns
        let mut lv: Vec<(i64, u32)> = (0..(1u32 << m))
            .map(|pat| {
                let mut v = center2;
                for (j, &g) in ga.iter().enumerate() {
                    v += if pat >> j & 1 == 1 { g } else { -g };
                }
                (v, pat)
            })
            .collect();
        lv.sort_unstable();
        let key: Vec<i32> = lv.iter().map(|&(v, _)| v as i32).collect();
        if seen.contains_key(&key) {
            continue;
        }
        seen.insert(key, ());

        // doubled levels are even and inside the doubled grid [0, 2(2ⁿ−1)]
        debug_assert!(lv
            .iter()
            .all(|&(v, _)| v % 2 == 0 && v >= 0 && v <= 2 * ((1i64 << n) - 1)));
        out.push(BcCodebook {
            n_bits,
            m_bits,
            group_alphas: ga.iter().map(|&g| g as f32 / 2.0).collect(),
            center: center2 as f32 / 2.0,
            levels: lv.iter().map(|&(v, _)| v as f32 / 2.0).collect(),
            patterns: lv.iter().map(|&(_, p)| p).collect(),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_is_enumerated() {
        // n=3, m=2: the paper's example BCchoice {0, 1, 6, 7}
        // (α̂₁ = 0.5, α̂₂ = 3, center 3.5 — Eq. 10).
        let cbs = enumerate(3, 2);
        let found = cbs.iter().any(|cb| cb.levels == vec![0.0, 1.0, 6.0, 7.0]);
        assert!(found, "missing the paper's {{0,1,6,7}} codebook");
    }

    #[test]
    fn all_levels_on_grid_and_sorted() {
        for (n, m) in [(3u32, 2u32), (4, 2), (4, 3), (5, 2), (5, 3), (6, 3)] {
            let cbs = enumerate(n, m);
            assert!(!cbs.is_empty(), "(n={n}, m={m}) empty");
            let max = (1u32 << n) as f32 - 1.0;
            for cb in cbs.iter() {
                assert_eq!(cb.levels.len(), 1 << m);
                for win in cb.levels.windows(2) {
                    assert!(win[0] < win[1], "levels not strictly ascending");
                }
                for &l in &cb.levels {
                    assert!(l >= 0.0 && l <= max, "level {l} outside grid (n={n})");
                    assert_eq!(l.fract(), 0.0, "level {l} not an integer");
                }
            }
        }
    }

    #[test]
    fn patterns_decode_to_levels() {
        let cbs = enumerate(5, 3);
        for cb in cbs.iter().take(50) {
            for (k, &pat) in cb.patterns.iter().enumerate() {
                assert!(
                    (cb.decode(pat) - cb.levels[k]).abs() < 1e-6,
                    "pattern {pat} decodes wrong"
                );
            }
        }
    }

    #[test]
    fn full_grid_is_a_codebook_when_m_covers() {
        // n=3, m=2 cannot cover all 8 values; but the coarsest uniform
        // sub-grids (e.g. {0,2,4,6} via α̂ = {1, 2} center 3) must exist.
        let cbs = enumerate(3, 2);
        assert!(cbs.iter().any(|cb| cb.levels == vec![0.0, 2.0, 4.0, 6.0]));
        // and the "linear-quantization-like" uniform 4-level spread
        assert!(cbs.iter().any(|cb| cb.levels == vec![0.0, 2.0, 5.0, 7.0])
            || cbs.iter().any(|cb| cb.levels == vec![1.0, 3.0, 4.0, 6.0]));
    }

    #[test]
    fn snap_index_nearest() {
        let cbs = enumerate(3, 2);
        let cb = cbs
            .iter()
            .find(|cb| cb.levels == vec![0.0, 1.0, 6.0, 7.0])
            .unwrap();
        assert_eq!(cb.snap_index(2.0), 1); // paper Eq. 6: 2 → 1
        assert_eq!(cb.snap_index(3.0), 1); // 3 → 1
        assert_eq!(cb.snap_index(5.0), 2); // 5 → 6
        assert_eq!(cb.snap_index(6.4), 2);
        assert_eq!(cb.snap_index(-3.0), 0);
        assert_eq!(cb.snap_index(9.0), 3);
    }

    #[test]
    fn counts_are_reasonable() {
        // sanity: enumeration should be in the hundreds–thousands, not
        // millions (the paper's "limited options ⇒ sequential trial").
        let c52 = enumerate(5, 2).len();
        let c53 = enumerate(5, 3).len();
        assert!(c52 > 20 && c52 < 20_000, "5→2: {c52}");
        assert!(c53 > 50 && c53 < 50_000, "5→3: {c53}");
    }

    #[test]
    fn cache_returns_same_arc() {
        let a = enumerate(4, 2);
        let b = enumerate(4, 2);
        assert!(Arc::ptr_eq(&a, &b));
    }
}
