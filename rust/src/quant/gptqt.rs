//! GPTQT — the paper's method (§II-B/C): quantize twice, progressively.
//!
//! Per weight row:
//!
//! 1. **Step 1** — linear quantization to `step1_bits` (n) with scale `S`
//!    and offset anchored to the row's range (Eq. 5 step 1).
//! 2. **Step 2** — re-encode the n-bit integer grid into an m-bit binary
//!    coding: pick the `BCchoice` codebook ([`super::bcchoice`]) that
//!    minimizes the *output* error of the layer (Eq. 5 step 2) — scored
//!    with the diagonal-Hessian-weighted proxy `Σ_c H_cc e_c²`, the
//!    second-order objective GPTQ itself optimizes columnwise.
//! 3. **Re-exploration** (Eq. 7) — the scale is re-searched over
//!    `Ŝ ∈ (span/(2^{n+r}−1), span/(2^{n−r}−1))` because step 2 punches
//!    non-uniform gaps into the integer axis; the step-1-optimal S is no
//!    longer optimal ("stretching the spring", Fig. 2).
//!
//! The winning `(Ŝ, BCchoice)` pair per row becomes (a) the row codebook
//! driving the GPTQ compensation loop and (b), through [`super::fuse`],
//! a single pure binary coding `Σ α̂ᵢb̂ᵢ + ĉ` for the LUT-GEMM hot path
//! (Eq. 8–11).
//!
//! ### Scoring trick
//!
//! For a fixed `Ŝ`, every weight has a continuous grid coordinate
//! `x = (w − Z)/Ŝ`; step 1 rounds it to `v = round(x)` and step 2 snaps
//! `v` to the codebook. Grouping weights by `v` and pre-accumulating
//! `(H₀, H₁, H₂) = Σ h, Σ h·r, Σ h·r²` with `r = x − v` per grid cell
//! turns the error of *any* codebook into a `O(2ⁿ)` scan:
//!
//! ```text
//! err(cb) = Ŝ² Σ_v [ H₂(v) + 2δ(v)H₁(v) + δ(v)²H₀(v) ],  δ(v) = v − snap_cb(v)
//! ```
//!
//! which makes the exhaustive BCchoice × Ŝ grid search (the paper's
//! "sequential trial of each possibility") cheap.

use super::bcchoice::{self, BcCodebook};
use super::{RowCodebook, SortedLevels};
use std::sync::Arc;

/// The per-row result of the GPTQT parameter search.
#[derive(Debug, Clone)]
pub struct GptqtRow {
    /// Re-explored scaling factor Ŝ (Eq. 7).
    pub scale: f32,
    /// Real-valued grid origin: `w ≈ Z + Ŝ·(grid coordinate)`.
    pub zero: f32,
    /// Winning BCchoice codebook (integer-grid units).
    pub codebook: Arc<BcCodebook>,
    /// Diagonal-weighted output error of the winner.
    pub err: f64,
    /// Number of (Ŝ, codebook) candidates evaluated.
    pub candidates: usize,
}

impl GptqtRow {
    /// The row's dequantized level set — the codebook the GPTQ loop snaps
    /// against.
    pub fn level_set(&self) -> SortedLevels {
        SortedLevels::new(
            self.codebook
                .levels
                .iter()
                .map(|&v| self.zero + self.scale * v)
                .collect(),
        )
    }

    /// Integer-grid coordinate after step 1 (round, then clamp — Eq. 5).
    #[inline]
    fn step1(&self, w: f32) -> f32 {
        let max = ((1u64 << self.codebook.n_bits) - 1) as f32;
        ((w - self.zero) / self.scale).round().clamp(0.0, max)
    }

    /// Sign pattern of the level `w` quantizes to (for packing). Follows
    /// the paper's two-step semantics: round to the intermediate grid
    /// (step 1), then map to the BCchoice level (step 2).
    pub fn encode(&self, w: f32) -> u32 {
        self.codebook.patterns[self.codebook.snap_index(self.step1(w))]
    }

    /// Dequantized value of a sign pattern.
    pub fn decode(&self, pattern: u32) -> f32 {
        self.zero + self.scale * self.codebook.decode(pattern)
    }
}

/// Search configuration distilled from [`super::QuantConfig`].
#[derive(Debug, Clone, Copy)]
pub struct SearchParams {
    /// Step-1 intermediate bits n.
    pub step1_bits: u32,
    /// Final bits m.
    pub final_bits: u32,
    /// Re-exploration range r in bits (0 disables, Table VI).
    pub explore_range: u32,
    /// Grid points across the Ŝ interval (≥ 1).
    pub explore_grid: usize,
}

impl SearchParams {
    pub fn from_config(cfg: &super::QuantConfig) -> SearchParams {
        SearchParams {
            step1_bits: cfg.step1_bits,
            final_bits: cfg.bits,
            explore_range: cfg.explore_range,
            explore_grid: cfg.explore_grid.max(1),
        }
    }

    /// Candidate scale factors per Eq. 7: the base scale
    /// `S = span/(2ⁿ−1)` plus `explore_grid` points spanning
    /// `(span/(2^{n+r}−1), span/(2^{n−r}−1))`.
    pub fn scale_candidates(&self, span: f32) -> Vec<f32> {
        let n = self.step1_bits;
        let base = span / ((1u64 << n) - 1) as f32;
        if self.explore_range == 0 {
            return vec![base];
        }
        let r = self.explore_range.min(n - self.final_bits.min(n - 1)).max(1);
        // guard: n − r must stay ≥ 1 bit
        let r = r.min(n - 1);
        let s_lo = span / ((1u64 << (n + r)) - 1) as f32; // compressed axis
        let s_hi = span / ((1u64 << (n - r)) - 1) as f32; // stretched axis
        let mut out = Vec::with_capacity(self.explore_grid + 1);
        out.push(base);
        // geometric spacing matches the bit-exponent structure of Eq. 7
        let ratio = (s_hi / s_lo).max(1.0 + 1e-6);
        for k in 0..self.explore_grid {
            let t = (k as f32 + 0.5) / self.explore_grid as f32;
            out.push(s_lo * ratio.powf(t));
        }
        out
    }
}

/// Per-grid-cell accumulators for the scoring trick.
struct CellStats {
    h0: Vec<f64>,
    h1: Vec<f64>,
    h2: Vec<f64>,
}

impl CellStats {
    fn accumulate(row: &[f32], hdiag: &[f64], scale: f32, zero: f32, cells: usize) -> CellStats {
        let mut h0 = vec![0.0f64; cells];
        let mut h1 = vec![0.0f64; cells];
        let mut h2 = vec![0.0f64; cells];
        let max = (cells - 1) as f32;
        for (&w, &h) in row.iter().zip(hdiag) {
            // residual is measured from the *unclamped* coordinate:
            // clamping before differencing would hide the error of
            // compressed scales whose grid no longer covers the row.
            let x = (w - zero) / scale;
            let v = x.round().clamp(0.0, max);
            let r = (x - v) as f64;
            let vi = v as usize;
            h0[vi] += h;
            h1[vi] += h * r;
            h2[vi] += h * r * r;
        }
        CellStats { h0, h1, h2 }
    }

    /// Diagonal-weighted error of a codebook over these cells (in units
    /// of `Ŝ²` — multiply by `scale²` for the absolute value).
    #[inline]
    fn score(&self, cb: &BcCodebook) -> f64 {
        let mut err = 0.0f64;
        let mut next_level = 0usize;
        let levels = &cb.levels;
        for v in 0..self.h0.len() {
            if self.h0[v] == 0.0 && self.h2[v] == 0.0 {
                continue;
            }
            let vf = v as f32;
            // advance the two-pointer to the nearest level for cell v;
            // strict `<` matches `BcCodebook::snap_index` (ties go low) —
            // the cross term `2δH₁` is sign-sensitive, so the tie rule
            // must be identical to the actual snapping path.
            while next_level + 1 < levels.len()
                && (levels[next_level + 1] - vf).abs() < (vf - levels[next_level]).abs()
            {
                next_level += 1;
            }
            let delta = (vf - levels[next_level]) as f64;
            err += self.h2[v] + 2.0 * delta * self.h1[v] + delta * delta * self.h0[v];
        }
        err
    }
}

/// Run the full GPTQT per-row parameter search (Eq. 5–7): over scale
/// candidates × all BCchoice codebooks, minimizing the diagonal-Hessian-
/// weighted output error. `hdiag` is the diagonal of the (dampened)
/// GPTQ Hessian for this layer.
pub fn search_row(row: &[f32], hdiag: &[f64], params: &SearchParams) -> GptqtRow {
    assert_eq!(row.len(), hdiag.len());
    let codebooks = bcchoice::enumerate(params.step1_bits, params.final_bits);
    let cells = 1usize << params.step1_bits;

    let (mut mn, mut mx) = (f32::INFINITY, f32::NEG_INFINITY);
    for &w in row {
        mn = mn.min(w);
        mx = mx.max(w);
    }
    if !mn.is_finite() || mx - mn < 1e-12 {
        // degenerate row: constant weights — any codebook works
        let cb = Arc::new(codebooks[0].clone());
        let zero = if mn.is_finite() { mn - cb.levels[0] } else { 0.0 };
        return GptqtRow { scale: 1e-6, zero, codebook: cb, err: 0.0, candidates: 0 };
    }
    let span = mx - mn;
    let mid = 0.5 * (mn + mx);

    let mut best: Option<(f64, f32, f32, usize)> = None; // (err, scale, zero, cb index)
    let mut evaluated = 0usize;
    for scale in params.scale_candidates(span) {
        // Anchor the stretched/compressed axis at the row midpoint
        // (Fig. 2: the spring stretches symmetrically).
        let zero = mid - scale * (cells - 1) as f32 * 0.5;
        let stats = CellStats::accumulate(row, hdiag, scale, zero, cells);
        let s2 = (scale as f64) * (scale as f64);
        for (ci, cb) in codebooks.iter().enumerate() {
            let err = stats.score(cb) * s2;
            evaluated += 1;
            if best.is_none() || err < best.unwrap().0 {
                best = Some((err, scale, zero, ci));
            }
        }
    }
    let (err, scale, zero, ci) = best.unwrap();
    GptqtRow {
        scale,
        zero,
        codebook: Arc::new(codebooks[ci].clone()),
        err,
        candidates: evaluated,
    }
}

/// The GPTQ+BCQ ablation row (Table V): fit BCQ on the raw row and use its
/// level set as the GPTQ codebook — the overfitting construction.
pub fn bcq_row_codebook(row: &[f32], bits: u32, iters: usize) -> SortedLevels {
    super::bcq::bcq_fit(row, bits, iters).level_set()
}

impl RowCodebook for GptqtRow {
    /// Two-step snap exactly as scored: round to the intermediate n-bit
    /// grid (step 1), then map to the nearest BCchoice level (step 2).
    fn snap(&self, w: f32) -> f32 {
        let v = self.step1(w);
        self.zero + self.scale * self.codebook.levels[self.codebook.snap_index(v)]
    }

    fn levels(&self) -> Vec<f32> {
        self.codebook
            .levels
            .iter()
            .map(|&v| self.zero + self.scale * v)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn params(n: u32, m: u32, range: u32) -> SearchParams {
        SearchParams { step1_bits: n, final_bits: m, explore_range: range, explore_grid: 8 }
    }

    fn random_row(d: usize, seed: u64) -> (Vec<f32>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let row: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let hdiag: Vec<f64> = (0..d).map(|_| 0.5 + rng.next_f64()).collect();
        (row, hdiag)
    }

    #[test]
    fn scale_candidates_respect_eq7() {
        let p = params(5, 3, 1);
        let span = 2.0f32;
        let cands = p.scale_candidates(span);
        let lo = span / (2f32.powi(6) - 1.0);
        let hi = span / (2f32.powi(4) - 1.0);
        assert!(cands.len() > 1);
        for &s in &cands[1..] {
            assert!(s >= lo * 0.999 && s <= hi * 1.001, "scale {s} outside Eq.7 range");
        }
        // base scale present
        let base = span / 31.0;
        assert!(cands.iter().any(|&s| (s - base).abs() < 1e-7));
    }

    #[test]
    fn range_zero_means_single_scale() {
        let p = params(5, 3, 0);
        assert_eq!(p.scale_candidates(1.0).len(), 1);
    }

    #[test]
    fn snap_lands_on_levels() {
        let (row, hdiag) = random_row(256, 61);
        let r = search_row(&row, &hdiag, &params(5, 3, 1));
        let levels = r.levels();
        for &w in row.iter().take(64) {
            let s = r.snap(w);
            assert!(levels.iter().any(|&l| (l - s).abs() < 1e-5));
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let (row, hdiag) = random_row(128, 62);
        let r = search_row(&row, &hdiag, &params(5, 2, 1));
        for &w in row.iter().take(32) {
            let pat = r.encode(w);
            let v = r.decode(pat);
            assert!((v - r.snap(w)).abs() < 1e-5, "decode(encode) != snap");
        }
    }

    #[test]
    fn reexploration_never_hurts() {
        // with re-exploration the search space is a superset ⇒ err ≤
        for seed in [63u64, 64, 65, 66] {
            let (row, hdiag) = random_row(256, seed);
            let e0 = search_row(&row, &hdiag, &params(5, 3, 0)).err;
            let e1 = search_row(&row, &hdiag, &params(5, 3, 1)).err;
            assert!(e1 <= e0 + 1e-12, "seed {seed}: e1={e1} > e0={e0}");
        }
    }

    #[test]
    fn gptqt_beats_plain_grid_snap_on_weighted_error() {
        // GPTQT's searched codebook should beat the naive m-bit min/max
        // linear grid on the weighted objective it optimizes.
        use crate::quant::linear::UniformGrid;
        for seed in [70u64, 71, 72] {
            let (row, hdiag) = random_row(512, seed);
            let r = search_row(&row, &hdiag, &params(5, 3, 1));
            let grid = UniformGrid::from_minmax(&row, 3);
            let mut grid_err = 0.0f64;
            for (&w, &h) in row.iter().zip(&hdiag) {
                let e = (w - grid.snap(w)) as f64;
                grid_err += h * e * e;
            }
            // measure GPTQT error directly (not the proxy) for fairness
            let mut gt_err = 0.0f64;
            for (&w, &h) in row.iter().zip(&hdiag) {
                let e = (w - r.snap(w)) as f64;
                gt_err += h * e * e;
            }
            assert!(
                gt_err <= grid_err * 1.05,
                "seed {seed}: gptqt {gt_err} vs grid {grid_err}"
            );
        }
    }

    #[test]
    fn proxy_error_matches_direct_error() {
        // the bucketed (H0,H1,H2) score must equal the directly computed
        // diagonal-weighted error of the winning quantizer
        let (row, hdiag) = random_row(128, 80);
        let r = search_row(&row, &hdiag, &params(4, 2, 1));
        let mut direct = 0.0f64;
        for (&w, &h) in row.iter().zip(&hdiag) {
            // two-step snap exactly as scored: round-to-grid then codebook
            let x = (w - r.zero) / r.scale;
            let v = x.round().clamp(0.0, 15.0);
            let snapped = r.codebook.levels[r.codebook.snap_index(v)];
            let e = ((x - snapped) * r.scale) as f64;
            direct += h * e * e;
        }
        assert!(
            (direct - r.err).abs() <= 1e-6 * direct.max(1.0),
            "direct {direct} vs proxy {}",
            r.err
        );
    }

    #[test]
    fn constant_row_degenerates_gracefully() {
        let row = vec![0.7f32; 64];
        let hdiag = vec![1.0f64; 64];
        let r = search_row(&row, &hdiag, &params(5, 3, 1));
        assert!(r.snap(0.7).is_finite());
    }

    #[test]
    fn heavy_hessian_columns_dominate_choice() {
        // put huge Hessian weight on a few outlier coordinates: the
        // chosen codebook must represent them well.
        let mut rng = Rng::new(90);
        let mut row: Vec<f32> = (0..256).map(|_| rng.normal_f32() * 0.1).collect();
        let mut hdiag = vec![1.0f64; 256];
        row[0] = 3.0;
        row[1] = -3.0;
        hdiag[0] = 1e4;
        hdiag[1] = 1e4;
        let r = search_row(&row, &hdiag, &params(5, 3, 1));
        assert!((r.snap(3.0) - 3.0).abs() < 0.25, "outlier badly quantized: {}", r.snap(3.0));
        assert!((r.snap(-3.0) + 3.0).abs() < 0.25);
    }
}
