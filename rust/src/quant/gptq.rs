//! GPTQ — column-by-column quantization with second-order error
//! compensation (paper §II-A, Eq. 1–2; Frantar et al., OPTQ).
//!
//! Given a layer weight matrix `W (rows × d)` and the calibration Hessian
//! `H = 2XXᵀ (d × d)`, GPTQ fixes per-row quantization parameters up
//! front, then walks columns `q = 0..d`: each element is snapped to its
//! row codebook, and the remaining (unquantized) columns of the same row
//! absorb the scaled error through the upper Cholesky factor of `H⁻¹`:
//!
//! ```text
//! e       = (W[r,q] − snap(W[r,q])) / U[q,q]
//! W[r,j] -= e · U[q,j]          for j > q        (Eq. 2)
//! ```
//!
//! The codebook is *pluggable* ([`RowCodebook`]): a uniform grid gives
//! vanilla GPTQ, a min-MSE-clipped grid gives the Table-V overfitting
//! baseline, BCQ level sets give GPTQ+BCQ, and GPTQT's searched
//! binary-coding codebooks give the paper's method. This mechanism is
//! exactly why weight-MSE-optimal codebooks *overfit*: the weights the
//! codebook was fitted to are not the weights the loop eventually snaps
//! (they keep moving through compensation).

use super::{QuantConfig, RowCodebook};
use crate::tensor::linalg::{cholesky, dampen, spd_inverse, LinalgError, MatF64};
use crate::tensor::Tensor;
use crate::util::pool;

/// Result diagnostics of a GPTQ run.
#[derive(Debug, Clone, Default)]
pub struct GptqStats {
    /// Final dampening λ actually used (escalated if H was near-singular).
    pub damp_used: f64,
    /// Σ over elements of squared snap error at quantization time.
    pub snap_err: f64,
}

/// Accumulate the GPTQ Hessian `H = 2 Σ xxᵀ` from calibration activations
/// `x` (rows of `acts`, shape tokens × d). f64 accumulation.
pub fn accumulate_hessian(acts: &Tensor) -> MatF64 {
    let d = acts.cols();
    let mut h = MatF64::zeros(d);
    for t in 0..acts.rows() {
        let x = acts.row(t);
        for i in 0..d {
            let xi = 2.0 * x[i] as f64;
            if xi == 0.0 {
                continue;
            }
            let hrow = &mut h.data[i * d..(i + 1) * d];
            for (j, &xj) in x.iter().enumerate() {
                hrow[j] += xi * xj as f64;
            }
        }
    }
    h
}

/// Compute the upper Cholesky factor `U = chol(H⁻¹)ᵀ` with escalating
/// dampening until the factorization succeeds.
pub fn inverse_cholesky(h: &MatF64, damp: f64) -> Result<(MatF64, f64), LinalgError> {
    let mut lambda = damp.max(1e-8);
    for _ in 0..12 {
        let mut hd = h.clone();
        dampen(&mut hd, lambda);
        match spd_inverse(&hd).and_then(|inv| cholesky(&inv)) {
            Ok(l) => return Ok((l.transpose(), lambda)),
            Err(_) => lambda *= 10.0,
        }
    }
    Err(LinalgError::NotPositiveDefinite(0, lambda))
}

/// Run the GPTQ loop in place: `w` becomes the dequantized quantized
/// weights (every entry a codebook level). One codebook per row.
///
/// Rows are processed in parallel (the compensation never crosses rows).
pub fn gptq_quantize(
    w: &mut Tensor,
    hessian: &MatF64,
    codebooks: &[Box<dyn RowCodebook>],
    cfg: &QuantConfig,
) -> Result<GptqStats, LinalgError> {
    let d = w.cols();
    assert_eq!(hessian.n, d, "Hessian dim != layer input dim");
    assert_eq!(codebooks.len(), w.rows(), "one codebook per row");
    let (u, damp_used) = inverse_cholesky(hessian, cfg.damp)?;

    // Precompute f32 copies of the U rows (hot loop is f32).
    let u32f: Vec<Vec<f32>> = (0..d)
        .map(|q| (q..d).map(|j| (u.get(q, j) / u.get(q, q)) as f32).collect())
        .collect();

    let rows = w.rows();
    let snap_err = std::sync::atomic::AtomicU64::new(0);
    {
        let w_cell = WPtr(w.data_mut().as_mut_ptr());
        let snap_err = &snap_err;
        let u32f = &u32f;
        pool::global().scope_chunks(rows, |range| {
            let w_cell = &w_cell;
            let mut local_err = 0.0f64;
            for r in range {
                // SAFETY: rows are disjoint across chunks.
                let row = unsafe { std::slice::from_raw_parts_mut(w_cell.0.add(r * d), d) };
                let cb = &codebooks[r];
                for q in 0..d {
                    let wq = row[q];
                    let z = cb.snap(wq);
                    let err = wq - z;
                    local_err += (err as f64) * (err as f64);
                    row[q] = z;
                    if err != 0.0 {
                        let urow = &u32f[q];
                        // urow[0] == 1 (j = q), compensation starts at j = q+1
                        for (off, &uqj) in urow.iter().enumerate().skip(1) {
                            row[q + off] -= err * uqj;
                        }
                    }
                }
            }
            let bits = local_err.to_bits();
            // accumulate f64 via CAS loop
            let mut cur = snap_err.load(std::sync::atomic::Ordering::Relaxed);
            loop {
                let new = (f64::from_bits(cur) + f64::from_bits(bits)).to_bits();
                match snap_err.compare_exchange_weak(
                    cur,
                    new,
                    std::sync::atomic::Ordering::SeqCst,
                    std::sync::atomic::Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(c) => cur = c,
                }
            }
        });
    }

    Ok(GptqStats { damp_used, snap_err: f64::from_bits(snap_err.load(std::sync::atomic::Ordering::SeqCst)) })
}

struct WPtr(*mut f32);
// SAFETY: pool chunks write disjoint weight rows and are joined before
// the matrix is read back.
unsafe impl Sync for WPtr {}
// SAFETY: the pointer outlives the scope — the pool joins before return.
unsafe impl Send for WPtr {}

/// True second-order output error `Σ_rows eᵀ(H/2)e = Σ_rows ‖e·X‖²` —
/// the layer-level quality metric reported in stats. (The *diagonal*
/// proxy would mis-rank GPTQ results: compensation deliberately trades
/// larger per-element errors for a smaller quadratic form.)
pub fn weighted_output_err(orig: &Tensor, quant: &Tensor, hessian: &MatF64) -> f64 {
    assert_eq!(orig.shape(), quant.shape());
    let d = orig.cols();
    let totals = pool::global().map(orig.rows(), |r| {
        let (o, q) = (orig.row(r), quant.row(r));
        let e: Vec<f64> = (0..d).map(|c| (o[c] - q[c]) as f64).collect();
        let mut acc = 0.0;
        for i in 0..d {
            if e[i] == 0.0 {
                continue;
            }
            let hrow = &hessian.data[i * d..(i + 1) * d];
            let mut he = 0.0;
            for (j, &ej) in e.iter().enumerate() {
                he += hrow[j] * ej;
            }
            acc += e[i] * he;
        }
        acc * 0.5
    });
    totals.into_iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::linear::UniformGrid;
    use crate::util::Rng;

    fn make_acts(tokens: usize, d: usize, rng: &mut Rng) -> Tensor {
        Tensor::randn(tokens, d, 1.0, rng)
    }

    fn minmax_codebooks(w: &Tensor, bits: u32) -> Vec<Box<dyn RowCodebook>> {
        (0..w.rows())
            .map(|r| Box::new(UniformGrid::from_minmax(w.row(r), bits)) as Box<dyn RowCodebook>)
            .collect()
    }

    #[test]
    fn hessian_is_symmetric_psd_diag() {
        let mut rng = Rng::new(51);
        let acts = make_acts(40, 8, &mut rng);
        let h = accumulate_hessian(&acts);
        for i in 0..8 {
            assert!(h.get(i, i) >= 0.0);
            for j in 0..8 {
                assert!((h.get(i, j) - h.get(j, i)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn hessian_matches_definition() {
        // d=2, single token x=(1,2): H = 2xxᵀ = [[2,4],[4,8]]
        let acts = Tensor::from_slice(1, 2, &[1.0, 2.0]);
        let h = accumulate_hessian(&acts);
        assert!((h.get(0, 0) - 2.0).abs() < 1e-9);
        assert!((h.get(0, 1) - 4.0).abs() < 1e-9);
        assert!((h.get(1, 1) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn gptq_output_is_on_codebook_levels() {
        let mut rng = Rng::new(52);
        let d = 32;
        let mut w = Tensor::randn(8, d, 1.0, &mut rng);
        let orig = w.clone();
        let h = accumulate_hessian(&make_acts(64, d, &mut rng));
        let cbs = minmax_codebooks(&w, 3);
        gptq_quantize(&mut w, &h, &cbs, &QuantConfig::default()).unwrap();
        for r in 0..8 {
            let levels = cbs[r].levels();
            for &v in w.row(r) {
                assert!(
                    levels.iter().any(|&l| (l - v).abs() < 1e-4),
                    "row {r}: {v} not on grid"
                );
            }
        }
        assert!(w.max_abs_diff(&orig) > 0.0, "something must change");
    }

    #[test]
    fn gptq_beats_rtn_on_output_error() {
        // The whole point of compensation: ‖(W−Ŵ)X‖ is smaller than RTN's,
        // even though RTN minimizes per-element weight error.
        let mut rng = Rng::new(53);
        let d = 48;
        let orig = Tensor::randn(16, d, 1.0, &mut rng);
        // correlated activations make compensation matter
        let base = make_acts(96, d, &mut rng);
        let mixer = Tensor::randn(d, d, 0.4, &mut rng).add(&Tensor::eye(d));
        let acts = base.matmul(&mixer);
        let h = accumulate_hessian(&acts);

        let cbs = minmax_codebooks(&orig, 3);
        let mut gptq_w = orig.clone();
        gptq_quantize(&mut gptq_w, &h, &cbs, &QuantConfig::default()).unwrap();
        let rtn_w = crate::quant::snap_tensor(&orig, &cbs);

        // true output error on the calibration set
        let err_gptq = acts.matmul(&orig.sub(&gptq_w).transpose()).norm();
        let err_rtn = acts.matmul(&orig.sub(&rtn_w).transpose()).norm();
        assert!(
            err_gptq < err_rtn,
            "gptq {err_gptq} should beat rtn {err_rtn}"
        );
    }

    #[test]
    fn gptq_with_identity_hessian_is_rtn() {
        // With H = I the compensation coefficients vanish (U = I), so
        // GPTQ degenerates to per-element snapping.
        let mut rng = Rng::new(54);
        let d = 16;
        let orig = Tensor::randn(4, d, 1.0, &mut rng);
        let h = MatF64::eye(d);
        let cbs = minmax_codebooks(&orig, 3);
        let mut w = orig.clone();
        gptq_quantize(&mut w, &h, &cbs, &QuantConfig { damp: 1e-8, ..Default::default() })
            .unwrap();
        let rtn = crate::quant::snap_tensor(&orig, &cbs);
        assert!(w.max_abs_diff(&rtn) < 1e-5);
    }

    #[test]
    fn singular_hessian_is_rescued_by_damping() {
        let mut rng = Rng::new(55);
        let d = 12;
        // rank-1 activations → singular H
        let mut acts = Tensor::zeros(20, d);
        let dir: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        for t in 0..20 {
            let s = rng.normal_f32();
            for (c, v) in acts.row_mut(t).iter_mut().enumerate() {
                *v = s * dir[c];
            }
        }
        let h = accumulate_hessian(&acts);
        let mut w = Tensor::randn(4, d, 1.0, &mut rng);
        let cbs = minmax_codebooks(&w, 3);
        let stats = gptq_quantize(&mut w, &h, &cbs, &QuantConfig::default()).unwrap();
        assert!(stats.damp_used >= 0.01);
        assert!(w.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn weighted_output_err_zero_for_identical() {
        let mut rng = Rng::new(56);
        let w = Tensor::randn(3, 8, 1.0, &mut rng);
        let h = MatF64::eye(8);
        assert_eq!(weighted_output_err(&w, &w, &h), 0.0);
    }
}
