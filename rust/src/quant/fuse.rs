//! Fusion of the two quantization steps into a single pure binary coding
//! (paper §II-D, Eq. 8–11).
//!
//! Linear quantization is a special binary coding (Eq. 8–9): the n-bit
//! integer grid is `Σᵢ 2^{i-1}bᵢ + (2ⁿ−1)/2`. GPTQT's step 2 picks an
//! m-bit sub-coding of that grid (α̂ in integer units, center ĉ), so the
//! composition *with the dequantization* `w = Ŝ·v + Z` collapses into
//!
//! ```text
//! W_q = Σ_j (Ŝ·α̂_j) b̂_j + (Ŝ·ĉ + Z)            (Eq. 11)
//! ```
//!
//! — no intermediate integer state survives at inference, which is what
//! lets the LUT-GEMM kernels run directly on sign bits.

use super::bcchoice::BcCodebook;
use super::gptqt::GptqtRow;

/// A fused per-row binary coding: `w(pattern) = Σ_j alphas[j]·(±1) + bias`.
#[derive(Debug, Clone, PartialEq)]
pub struct FusedRow {
    /// Real-valued α̂ per bit (ascending bit index = codebook group order).
    pub alphas: Vec<f32>,
    /// Real-valued offset (absorbs `Ŝ·ĉ + Z`).
    pub bias: f32,
}

impl FusedRow {
    /// Fuse a GPTQT row result (Eq. 11).
    pub fn from_gptqt(row: &GptqtRow) -> FusedRow {
        FusedRow {
            alphas: row.codebook.group_alphas.iter().map(|&a| a * row.scale).collect(),
            bias: row.zero + row.scale * row.codebook.center,
        }
    }

    /// Fuse an arbitrary (scale, zero, codebook) triple.
    pub fn from_parts(scale: f32, zero: f32, cb: &BcCodebook) -> FusedRow {
        FusedRow {
            alphas: cb.group_alphas.iter().map(|&a| a * scale).collect(),
            bias: zero + scale * cb.center,
        }
    }

    /// Express a plain n-bit *linear* grid as a binary coding (Eq. 8–9):
    /// `α_i = 2^{i-1}·S`, bias = `S·(2ⁿ−1)/2 + Z`.
    pub fn from_linear(scale: f32, zero: f32, bits: u32) -> FusedRow {
        let alphas = (0..bits).map(|i| scale * 2f32.powi(i as i32 - 1)).collect();
        let bias = zero + scale * ((1u64 << bits) - 1) as f32 / 2.0;
        FusedRow { alphas, bias }
    }

    /// Dequantized value of a sign pattern (bit j set ⇒ +α̂_j).
    #[inline]
    pub fn decode(&self, pattern: u32) -> f32 {
        let mut v = self.bias;
        for (j, &a) in self.alphas.iter().enumerate() {
            v += if pattern >> j & 1 == 1 { a } else { -a };
        }
        v
    }

    /// All representable values, ascending.
    pub fn levels(&self) -> Vec<f32> {
        let mut out: Vec<f32> = (0..(1u32 << self.alphas.len()))
            .map(|p| self.decode(p))
            .collect();
        out.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out
    }

    /// Number of bits (planes).
    pub fn planes(&self) -> usize {
        self.alphas.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bcchoice;
    use crate::quant::gptqt::{search_row, SearchParams};
    use crate::quant::linear::UniformGrid;
    use crate::quant::RowCodebook;
    use crate::util::Rng;

    #[test]
    fn linear_grid_as_binary_coding_matches_eq9() {
        // 3-bit grid {0..7}, S=1, Z=0 ⇒ α = (0.5, 1, 2), bias 3.5 (Eq. 9)
        let f = FusedRow::from_linear(1.0, 0.0, 3);
        assert_eq!(f.alphas, vec![0.5, 1.0, 2.0]);
        assert_eq!(f.bias, 3.5);
        let mut lv = f.levels();
        lv.iter_mut().for_each(|v| *v = v.round());
        assert_eq!(lv, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn linear_fusion_equals_grid_levels_for_any_scale() {
        let g = UniformGrid::from_range(-1.3, 0.9, 3);
        let f = FusedRow::from_linear(g.scale, g.scale * g.qz, 3);
        let grid_levels = RowCodebook::levels(&g);
        let fused_levels = f.levels();
        for (a, b) in grid_levels.iter().zip(&fused_levels) {
            assert!((a - b).abs() < 1e-5, "grid {a} vs fused {b}");
        }
    }

    #[test]
    fn paper_worked_example_eq10_eq11() {
        // n=3 grid, BCchoice {0,1,6,7}: α̂₁=0.5, α̂₂=3, center 3.5 (Eq. 10).
        // With S and qbias folded in (Eq. 11): α̂₁=0.5S, α̂₂=3S, bias 3.5S+Z.
        let cbs = bcchoice::enumerate(3, 2);
        let cb = cbs.iter().find(|cb| cb.levels == vec![0.0, 1.0, 6.0, 7.0]).unwrap();
        let (s, z) = (0.25f32, -0.8f32);
        let f = FusedRow::from_parts(s, z, cb);
        let mut expect: Vec<f32> = [0.0f32, 1.0, 6.0, 7.0].iter().map(|&v| z + s * v).collect();
        expect.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let got = f.levels();
        for (a, b) in expect.iter().zip(&got) {
            assert!((a - b).abs() < 1e-6);
        }
        assert!((f.bias - (3.5 * s + z)).abs() < 1e-6);
    }

    #[test]
    fn fused_gptqt_row_is_exact() {
        // Property (DESIGN §6): for every searched row, the fused binary
        // coding represents *identical* values to the two-step composition.
        let mut rng = Rng::new(100);
        for seed in 0..5u64 {
            let row: Vec<f32> = (0..128).map(|_| rng.normal_f32()).collect();
            let hdiag: Vec<f64> = (0..128).map(|_| 0.5 + rng.next_f64()).collect();
            let p = SearchParams {
                step1_bits: 5,
                final_bits: 3,
                explore_range: 1,
                explore_grid: 4,
            };
            let r = search_row(&row, &hdiag, &p);
            let f = FusedRow::from_gptqt(&r);
            // per-pattern equality
            for pat in 0..8u32 {
                let two_step = r.decode(pat);
                let fused = f.decode(pat);
                assert!(
                    (two_step - fused).abs() <= 1e-5 * two_step.abs().max(1.0),
                    "seed {seed} pattern {pat}: {two_step} vs {fused}"
                );
            }
            let _ = seed;
        }
    }

    #[test]
    fn decode_pattern_count() {
        let f = FusedRow { alphas: vec![1.0, 2.0], bias: 0.0 };
        assert_eq!(f.levels(), vec![-3.0, -1.0, 1.0, 3.0]);
        assert_eq!(f.planes(), 2);
    }
}
