//! Quantization library: the paper's contribution (GPTQT) and every
//! baseline it compares against.
//!
//! | method        | module      | paper section |
//! |---------------|-------------|---------------|
//! | RTN           | [`linear`]  | Table I       |
//! | GPTQ (linear) | [`gptq`]    | §II-A, Eq 1–2 |
//! | GPTQ min-MSE  | [`linear`]  | Table V       |
//! | BCQ           | [`bcq`]     | §II-A, Eq 3–4 |
//! | GPTQ+BCQ      | [`gptq`]+[`bcq`] | Table V  |
//! | **GPTQT**     | [`gptqt`]   | §II-B/C/D, Eq 5–11 |
//!
//! The pipeline quantizes one linear layer at a time: per-row parameters
//! are fixed up front (scale / codebook), then the GPTQ column loop snaps
//! each column and compensates the not-yet-quantized columns through
//! `H⁻¹` (Eq. 2). GPTQT's per-row parameter search (intermediate-bit
//! linear scale, re-explored `Ŝ`, and the binary-coding codebook choice)
//! happens in [`gptqt`], and [`fuse`] collapses the two steps into the
//! pure binary coding that [`crate::kernels::gemv_lut`] executes.

pub mod bcchoice;
pub mod bcq;
pub mod fuse;
pub mod gptq;
pub mod gptqt;
pub mod linear;
pub mod pack;
pub mod pipeline;

pub use pipeline::quantize_layer;

use crate::tensor::Tensor;

/// Which quantization method to run (CLI / experiment-driver facing).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// fp32/fp16 passthrough (the "full" rows of the tables).
    Full,
    /// Round-to-nearest linear quantization, no compensation.
    Rtn,
    /// GPTQ with plain linear (min/max) per-row params.
    Gptq,
    /// GPTQ whose clip range is grid-searched to minimize weight MSE
    /// (the overfitting baseline of Table V).
    GptqMinMse,
    /// Binary-coding quantization, greedy + alternating LSQ, no GPTQ loop.
    Bcq,
    /// BCQ codebooks plugged into the GPTQ loop (Table V's GPTQ+BCQ).
    GptqBcq,
    /// The paper's method: quantize twice + re-explored scale + fusion.
    Gptqt,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s {
            "full" | "fp16" | "fp32" => Method::Full,
            "rtn" => Method::Rtn,
            "gptq" => Method::Gptq,
            "gptq-minmse" | "minmse" => Method::GptqMinMse,
            "bcq" => Method::Bcq,
            "gptq-bcq" | "gptq+bcq" => Method::GptqBcq,
            "gptqt" => Method::Gptqt,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Full => "full",
            Method::Rtn => "RTN",
            Method::Gptq => "GPTQ",
            Method::GptqMinMse => "GPTQ(minMSE)",
            Method::Bcq => "BCQ",
            Method::GptqBcq => "GPTQ+BCQ",
            Method::Gptqt => "GPTQT",
        }
    }
}

/// Knobs shared by the per-layer quantizers.
#[derive(Debug, Clone)]
pub struct QuantConfig {
    /// Final bit-width of the stored weights (2, 3 or 4).
    pub bits: u32,
    /// GPTQT step-1 intermediate bit-width (paper: 4–5 optimal, Fig. 4).
    pub step1_bits: u32,
    /// GPTQT scale re-exploration range in bits around `step1_bits`
    /// (paper Table VI: 0 = off, 1 = n−1..n+1, 2 = n−2..n+2).
    pub explore_range: u32,
    /// Grid points per explored bit interval for `Ŝ` (Eq. 7).
    pub explore_grid: usize,
    /// GPTQ Hessian dampening fraction λ (of mean diagonal).
    pub damp: f64,
    /// BCQ alternating-optimization iterations (Eq. 4).
    pub bcq_iters: usize,
    /// Quantize this many columns per GPTQ block before a bulk update.
    pub block_size: usize,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            bits: 3,
            step1_bits: 5,
            explore_range: 1,
            explore_grid: 8,
            damp: 0.01,
            bcq_iters: 10,
            block_size: 64,
        }
    }
}

impl QuantConfig {
    pub fn with_bits(bits: u32) -> Self {
        QuantConfig { bits, ..Default::default() }
    }
}

/// Everything a quantized linear layer needs at inference time.
///
/// `dequant` is the dense fp32 view (fed to the XLA executables — exactly
/// equal to what the fused binary coding represents); `packed` is the
/// fused binary-coded form consumed by the LUT-GEMM hot path (present for
/// binary-coding methods), `int_weights` the linear-quantized form used by
/// the dequant hot path (present for linear methods).
pub struct QuantizedLayer {
    pub dequant: Tensor,
    pub packed: Option<pack::PackedBcLayer>,
    pub int_weights: Option<linear::IntLayer>,
    pub stats: LayerStats,
}

/// Per-layer quantization diagnostics.
#[derive(Debug, Clone, Default)]
pub struct LayerStats {
    /// Mean squared weight error after quantization.
    pub weight_mse: f64,
    /// Diagonal-Hessian-weighted output-error proxy `Σ hᵢ eᵢ²`.
    pub output_err: f64,
    /// Seconds spent quantizing the layer.
    pub seconds: f64,
    /// Codebook/scale search candidates evaluated (GPTQT).
    pub candidates: usize,
}

/// A per-row quantization codebook: maps a real weight to the nearest
/// representable dequantized value. Implementations: uniform grids
/// (linear/RTN) and sorted non-uniform level sets (BCQ/GPTQT).
pub trait RowCodebook: Send + Sync {
    /// Nearest representable value.
    fn snap(&self, w: f32) -> f32;
    /// All representable levels (ascending) — used by packing & tests.
    fn levels(&self) -> Vec<f32>;
}

/// A sorted, non-uniform level set (BCQ / GPTQT codebooks realized as
/// dequantized values). `snap` is a branchless-ish binary search — this
/// sits inside the GPTQ column loop.
#[derive(Debug, Clone, PartialEq)]
pub struct SortedLevels {
    levels: Vec<f32>,
}

impl SortedLevels {
    /// Build from arbitrary level values (sorted + deduped internally).
    pub fn new(mut levels: Vec<f32>) -> SortedLevels {
        assert!(!levels.is_empty(), "empty codebook");
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        levels.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
        SortedLevels { levels }
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.levels
    }

    /// Index of the nearest level.
    #[inline]
    pub fn snap_index(&self, w: f32) -> usize {
        let ls = &self.levels;
        match ls.binary_search_by(|l| l.partial_cmp(&w).unwrap()) {
            Ok(i) => i,
            Err(i) => {
                if i == 0 {
                    0
                } else if i == ls.len() {
                    ls.len() - 1
                } else if (w - ls[i - 1]) <= (ls[i] - w) {
                    i - 1
                } else {
                    i
                }
            }
        }
    }
}

impl RowCodebook for SortedLevels {
    #[inline]
    fn snap(&self, w: f32) -> f32 {
        self.levels[self.snap_index(w)]
    }

    fn levels(&self) -> Vec<f32> {
        self.levels.clone()
    }
}

/// Quantize `w` (rows × cols, modified in place to the *dequantized*
/// result) with a per-row codebook under the GPTQ compensation loop.
/// Re-exported convenience over [`gptq::gptq_quantize`].
pub fn snap_tensor(w: &Tensor, codebooks: &[Box<dyn RowCodebook>]) -> Tensor {
    assert_eq!(w.rows(), codebooks.len());
    let mut out = w.clone();
    for r in 0..w.rows() {
        let cb = &codebooks[r];
        for v in out.row_mut(r) {
            *v = cb.snap(*v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for (s, m) in [
            ("rtn", Method::Rtn),
            ("gptq", Method::Gptq),
            ("gptq-minmse", Method::GptqMinMse),
            ("bcq", Method::Bcq),
            ("gptq+bcq", Method::GptqBcq),
            ("gptqt", Method::Gptqt),
            ("full", Method::Full),
        ] {
            assert_eq!(Method::parse(s), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn default_config_sane() {
        let c = QuantConfig::default();
        assert_eq!(c.bits, 3);
        assert!(c.step1_bits > c.bits);
        assert!(c.damp > 0.0);
    }

    #[test]
    fn sorted_levels_snap_nearest() {
        let cb = SortedLevels::new(vec![3.0, -1.0, 0.0, 7.5]);
        assert_eq!(cb.snap(-5.0), -1.0);
        assert_eq!(cb.snap(-0.4), 0.0);
        assert_eq!(cb.snap(1.4), 0.0);
        assert_eq!(cb.snap(1.6), 3.0);
        assert_eq!(cb.snap(100.0), 7.5);
        assert_eq!(cb.snap(3.0), 3.0);
    }

    #[test]
    fn sorted_levels_midpoint_ties_go_down() {
        let cb = SortedLevels::new(vec![0.0, 2.0]);
        assert_eq!(cb.snap(1.0), 0.0);
    }

    #[test]
    fn sorted_levels_dedup() {
        let cb = SortedLevels::new(vec![1.0, 1.0, 2.0]);
        assert_eq!(cb.as_slice(), &[1.0, 2.0]);
    }
}
