//! Per-layer quantization dispatch: one entry point covering the paper's
//! method and every baseline, returning dense + packed/int forms plus
//! diagnostics.

use super::fuse::FusedRow;
use super::gptq::{gptq_quantize, weighted_output_err};
use super::gptqt::{search_row, SearchParams};
use super::linear::{min_mse_grid, rtn_quantize, IntLayer, UniformGrid};
use super::pack::PackedBcLayer;
use super::{bcq, LayerStats, Method, QuantConfig, QuantizedLayer, RowCodebook};
use crate::tensor::linalg::MatF64;
use crate::tensor::Tensor;
use crate::util::{pool, Stopwatch};
use anyhow::Result;

/// Quantize one linear layer (`w`: rows × d) against its calibration
/// Hessian (`H = 2XXᵀ`, d × d). Returns the dequantized weights, the
/// packed form for the matching hot path, and stats.
pub fn quantize_layer(
    w: &Tensor,
    hessian: &MatF64,
    method: Method,
    cfg: &QuantConfig,
) -> Result<QuantizedLayer> {
    let sw = Stopwatch::start();
    let orig = w;
    let mut stats = LayerStats::default();

    let out = match method {
        Method::Full => QuantizedLayer {
            dequant: w.clone(),
            packed: None,
            int_weights: None,
            stats: LayerStats::default(),
        },
        Method::Rtn => {
            let (dq, grids) = rtn_quantize(w, cfg.bits);
            let int_weights = IntLayer::encode(&dq, &grids, cfg.bits);
            QuantizedLayer { dequant: dq, packed: None, int_weights: Some(int_weights), stats: stats.clone() }
        }
        Method::Gptq | Method::GptqMinMse => {
            let grids: Vec<UniformGrid> = pool::global().map(w.rows(), |r| {
                if method == Method::Gptq {
                    UniformGrid::from_minmax(w.row(r), cfg.bits)
                } else {
                    min_mse_grid(w.row(r), cfg.bits, 32)
                }
            });
            let codebooks: Vec<Box<dyn RowCodebook>> = grids
                .iter()
                .map(|g| Box::new(*g) as Box<dyn RowCodebook>)
                .collect();
            let mut dq = w.clone();
            gptq_quantize(&mut dq, hessian, &codebooks, cfg)?;
            let int_weights = IntLayer::encode(&dq, &grids, cfg.bits);
            QuantizedLayer { dequant: dq, packed: None, int_weights: Some(int_weights), stats: stats.clone() }
        }
        Method::Bcq => {
            // BCQ fits and snaps directly — no compensation loop (the
            // original BCQ recipe; paper Eq. 3–4).
            let fits: Vec<bcq::BcqRow> =
                pool::global().map(w.rows(), |r| bcq::bcq_fit(w.row(r), cfg.bits, cfg.bcq_iters));
            let mut dq = w.clone();
            let mut patterns = vec![Vec::with_capacity(w.cols()); w.rows()];
            for r in 0..w.rows() {
                let fit = &fits[r];
                let cb = fit.level_set();
                let pats = &mut patterns[r];
                for v in dq.row_mut(r) {
                    *v = cb.snap(*v);
                    pats.push(fit.encode(*v));
                }
            }
            let fused: Vec<FusedRow> = fits
                .iter()
                .map(|f| FusedRow { alphas: f.alphas.clone(), bias: 0.0 })
                .collect();
            let packed = PackedBcLayer::pack(w.rows(), w.cols(), &fused, &patterns);
            QuantizedLayer { dequant: dq, packed: Some(packed), int_weights: None, stats: stats.clone() }
        }
        Method::GptqBcq => {
            // Table V's overfitting construction: weight-MSE-optimal BCQ
            // codebooks frozen from the *original* weights, then used
            // inside the GPTQ loop (where the weights they were fitted to
            // keep moving).
            let fits: Vec<bcq::BcqRow> =
                pool::global().map(w.rows(), |r| bcq::bcq_fit(w.row(r), cfg.bits, cfg.bcq_iters));
            let codebooks: Vec<Box<dyn RowCodebook>> = fits
                .iter()
                .map(|f| Box::new(f.level_set()) as Box<dyn RowCodebook>)
                .collect();
            let mut dq = w.clone();
            gptq_quantize(&mut dq, hessian, &codebooks, cfg)?;
            let mut patterns = vec![Vec::with_capacity(w.cols()); w.rows()];
            for r in 0..w.rows() {
                let pats = &mut patterns[r];
                for &v in dq.row(r) {
                    pats.push(fits[r].encode(v));
                }
            }
            let fused: Vec<FusedRow> = fits
                .iter()
                .map(|f| FusedRow { alphas: f.alphas.clone(), bias: 0.0 })
                .collect();
            let packed = PackedBcLayer::pack(w.rows(), w.cols(), &fused, &patterns);
            QuantizedLayer { dequant: dq, packed: Some(packed), int_weights: None, stats: stats.clone() }
        }
        Method::Gptqt => {
            // The paper's method: per-row (Ŝ, BCchoice) search on the
            // original weights + Hessian diagonal, then the GPTQ loop,
            // then fusion into pure binary coding.
            let sp = SearchParams::from_config(cfg);
            let hdiag: Vec<f64> = (0..hessian.n).map(|i| hessian.get(i, i)).collect();
            let rows: Vec<super::gptqt::GptqtRow> =
                pool::global().map(w.rows(), |r| search_row(w.row(r), &hdiag, &sp));
            stats.candidates = rows.iter().map(|r| r.candidates).sum();
            let codebooks: Vec<Box<dyn RowCodebook>> = rows
                .iter()
                .map(|r| Box::new(r.clone()) as Box<dyn RowCodebook>)
                .collect();
            let mut dq = w.clone();
            gptq_quantize(&mut dq, hessian, &codebooks, cfg)?;
            let mut patterns = vec![Vec::with_capacity(w.cols()); w.rows()];
            for r in 0..w.rows() {
                let pats = &mut patterns[r];
                for &v in dq.row(r) {
                    pats.push(rows[r].encode(v));
                }
            }
            let fused: Vec<FusedRow> = rows.iter().map(FusedRow::from_gptqt).collect();
            let packed = PackedBcLayer::pack(w.rows(), w.cols(), &fused, &patterns);
            QuantizedLayer { dequant: dq, packed: Some(packed), int_weights: None, stats: stats.clone() }
        }
    };

    let mut out = out;
    out.stats.weight_mse = orig.mse(&out.dequant);
    out.stats.output_err = weighted_output_err(orig, &out.dequant, hessian);
    out.stats.seconds = sw.elapsed_secs();
    out.stats.candidates = stats.candidates;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gptq::accumulate_hessian;
    use crate::util::Rng;

    fn setup(d: usize, rows: usize, seed: u64) -> (Tensor, MatF64) {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(rows, d, 1.0, &mut rng);
        let base = Tensor::randn(3 * d, d, 1.0, &mut rng);
        let mixer = Tensor::randn(d, d, 0.3, &mut rng).add(&Tensor::eye(d));
        let acts = base.matmul(&mixer);
        (w, accumulate_hessian(&acts))
    }

    #[test]
    fn every_method_runs_and_is_finite() {
        let (w, h) = setup(32, 8, 201);
        let cfg = QuantConfig { bits: 3, step1_bits: 5, explore_grid: 4, ..Default::default() };
        for m in [
            Method::Full,
            Method::Rtn,
            Method::Gptq,
            Method::GptqMinMse,
            Method::Bcq,
            Method::GptqBcq,
            Method::Gptqt,
        ] {
            let q = quantize_layer(&w, &h, m, &cfg).unwrap();
            assert!(q.dequant.data().iter().all(|v| v.is_finite()), "{m:?} produced NaN");
            assert_eq!(q.dequant.shape(), w.shape());
            if m == Method::Full {
                assert_eq!(q.stats.weight_mse, 0.0);
            } else {
                assert!(q.stats.weight_mse > 0.0, "{m:?} should not be lossless");
            }
        }
    }

    #[test]
    fn gptqt_packed_matches_dequant_exactly() {
        let (w, h) = setup(48, 6, 202);
        let cfg = QuantConfig { explore_grid: 4, ..QuantConfig::with_bits(3) };
        let q = quantize_layer(&w, &h, Method::Gptqt, &cfg).unwrap();
        let packed = q.packed.expect("gptqt must pack");
        let dq2 = packed.dequant();
        assert!(
            q.dequant.max_abs_diff(&dq2) < 1e-4,
            "fusion property violated: {}",
            q.dequant.max_abs_diff(&dq2)
        );
    }

    #[test]
    fn gptq_int_weights_match_dequant() {
        let (w, h) = setup(32, 5, 203);
        let q = quantize_layer(&w, &h, Method::Gptq, &QuantConfig::with_bits(3)).unwrap();
        let il = q.int_weights.expect("gptq stores int weights");
        assert!(q.dequant.max_abs_diff(&il.dequant()) < 1e-5);
    }

    #[test]
    fn gptqt_beats_rtn_on_output_error() {
        let (w, h) = setup(64, 16, 204);
        let cfg = QuantConfig { explore_grid: 6, ..QuantConfig::with_bits(3) };
        let rtn = quantize_layer(&w, &h, Method::Rtn, &cfg).unwrap();
        let gptqt = quantize_layer(&w, &h, Method::Gptqt, &cfg).unwrap();
        assert!(
            gptqt.stats.output_err < rtn.stats.output_err,
            "gptqt {} !< rtn {}",
            gptqt.stats.output_err,
            rtn.stats.output_err
        );
    }

    #[test]
    fn two_bit_gptqt_survives_where_bcq_collapses() {
        // The paper's 2-bit story (Table I bottom): BCQ collapses, GPTQT
        // stays bounded. Proxy: output error ratio.
        let (w, h) = setup(64, 16, 205);
        let cfg = QuantConfig { explore_grid: 6, ..QuantConfig::with_bits(2) };
        let bcq = quantize_layer(&w, &h, Method::Bcq, &cfg).unwrap();
        let gptqt = quantize_layer(&w, &h, Method::Gptqt, &cfg).unwrap();
        assert!(
            gptqt.stats.output_err < bcq.stats.output_err,
            "gptqt {} !< bcq {}",
            gptqt.stats.output_err,
            bcq.stats.output_err
        );
    }
}
