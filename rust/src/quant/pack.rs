//! Bit-plane packing of fused binary-coded layers — the storage format the
//! LUT-GEMM hot path ([`crate::kernels::gemv_lut`]) streams.
//!
//! Columns are grouped in runs of [`GROUP`] (= 8); for every
//! (group, row, plane) one byte holds the 8 sign bits (bit k ⇒ column
//! `group·8 + k`, set ⇒ `+1`). This group-major layout means the kernel
//! builds one 256-entry LUT of activation partial sums per group and then
//! streams bytes contiguously over rows × planes — the CPU analogue of
//! LUT-GEMM's warp-shared-memory table.
//!
//! Storage: `cols/8 · rows · planes` bytes + `rows·(planes+1)` floats,
//! i.e. ~`planes` bits per weight — a 10.7× traffic reduction vs f32 at
//! 3 bits.

use super::fuse::FusedRow;
use crate::tensor::Tensor;

/// Columns per LUT group (one packed byte).
pub const GROUP: usize = 8;

/// Bit value of every padded tail column (positions `>= cols % GROUP`
/// of the last group's bytes, when `cols` is ragged).
///
/// The kernels build each group's 256-entry LUT from zero-padded
/// activations, so *any* sign pattern in the padding contributes ±0 —
/// but SIMD gathers consume the **entire** byte as a table index, so
/// the format pins the padding to one documented encoding instead of
/// whatever the packer happened to leave behind: all bits clear
/// (sign −1). [`PackedBcLayer::pack`] masks the tail explicitly and
/// [`PackedBcLayer::tail_is_neutral`] checks the invariant.
pub const TAIL_NEUTRAL: u8 = 0;

/// A packed binary-coded layer (rows × cols, `planes` sign bits/weight).
#[derive(Clone)]
pub struct PackedBcLayer {
    pub rows: usize,
    pub cols: usize,
    /// Number of binary-coding bits m.
    pub planes: usize,
    /// Column groups = ceil(cols / 8).
    pub groups: usize,
    /// Per-row α̂ values, row-major `[row][plane]`.
    pub alphas: Vec<f32>,
    /// Per-row bias (the fused `Ŝ·ĉ + Z` term).
    pub bias: Vec<f32>,
    /// Sign bytes, index `(g·rows + r)·planes + p`.
    pub codes: Vec<u8>,
}

impl PackedBcLayer {
    /// Pack from per-row fused codings + per-element sign patterns.
    ///
    /// `patterns[r][c]` is the sign pattern (bit j ⇒ +α̂_j) of element
    /// `(r, c)` — produced by `GptqtRow::encode` after the GPTQ loop.
    pub fn pack(rows: usize, cols: usize, fused: &[FusedRow], patterns: &[Vec<u32>]) -> Self {
        assert_eq!(fused.len(), rows);
        assert_eq!(patterns.len(), rows);
        let planes = fused.iter().map(|f| f.planes()).max().unwrap_or(0);
        let groups = cols.div_ceil(GROUP);
        let mut alphas = vec![0.0f32; rows * planes];
        let mut bias = vec![0.0f32; rows];
        for (r, f) in fused.iter().enumerate() {
            bias[r] = f.bias;
            for (p, &a) in f.alphas.iter().enumerate() {
                alphas[r * planes + p] = a;
            }
            // rows with fewer planes than the max pad with α = 0 (bits
            // contribute ±0 — harmless).
        }
        let mut codes = vec![0u8; groups * rows * planes];
        for r in 0..rows {
            assert_eq!(patterns[r].len(), cols, "row {r} pattern length");
            for c in 0..cols {
                let pat = patterns[r][c];
                let g = c / GROUP;
                let k = c % GROUP;
                for p in 0..planes {
                    if pat >> p & 1 == 1 {
                        codes[(g * rows + r) * planes + p] |= 1 << k;
                    }
                }
            }
        }
        // Pin the padded tail columns of the last group to TAIL_NEUTRAL:
        // the LUTs are built from zero-padded activations so the value
        // is moot, but SIMD gathers read the full byte — the format
        // guarantees one deterministic encoding there.
        let tail_cols = cols % GROUP;
        if tail_cols != 0 {
            let keep = (1u8 << tail_cols) - 1;
            let g = groups - 1;
            for slot in codes[g * rows * planes..].iter_mut() {
                *slot = (*slot & keep) | (TAIL_NEUTRAL & !keep);
            }
        }
        let packed = PackedBcLayer { rows, cols, planes, groups, alphas, bias, codes };
        debug_assert!(packed.tail_is_neutral());
        packed
    }

    /// Deterministic randomly-signed layer (positive α̂s, small bias) —
    /// shared scaffolding for the kernel parity tests and micro-benches,
    /// where only the *format* matters, not the values.
    pub fn random(rows: usize, cols: usize, planes: usize, seed: u64) -> Self {
        let mut rng = crate::util::Rng::new(seed);
        let fused: Vec<FusedRow> = (0..rows)
            .map(|_| FusedRow {
                alphas: (0..planes).map(|_| rng.next_f32() + 0.1).collect(),
                bias: rng.normal_f32() * 0.1,
            })
            .collect();
        let patterns: Vec<Vec<u32>> = (0..rows)
            .map(|_| (0..cols).map(|_| rng.below(1 << planes) as u32).collect())
            .collect();
        Self::pack(rows, cols, &fused, &patterns)
    }

    /// Sign of element `(r, c)` on plane `p`: `+1.0` or `-1.0`.
    #[inline]
    pub fn sign(&self, r: usize, c: usize, p: usize) -> f32 {
        let g = c / GROUP;
        let k = c % GROUP;
        let byte = self.codes[(g * self.rows + r) * self.planes + p];
        if byte >> k & 1 == 1 {
            1.0
        } else {
            -1.0
        }
    }

    /// Dense dequantized view: `W[r,c] = Σ_p α[r,p]·sign + bias[r]`.
    /// Exactly the tensor the XLA path is fed — fusion property tested.
    pub fn dequant(&self) -> Tensor {
        let mut t = Tensor::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let mut v = self.bias[r];
                for p in 0..self.planes {
                    v += self.alphas[r * self.planes + p] * self.sign(r, c, p);
                }
                t.set(r, c, v);
            }
        }
        t
    }

    /// True when every padded tail bit of the last group carries the
    /// [`TAIL_NEUTRAL`] encoding — the invariant that makes full-byte
    /// SIMD gathers over the tail group deterministic.
    pub fn tail_is_neutral(&self) -> bool {
        let tail_cols = self.cols % GROUP;
        if tail_cols == 0 {
            return true;
        }
        let pad = !((1u8 << tail_cols) - 1);
        let g = self.groups - 1;
        self.codes[g * self.rows * self.planes..]
            .iter()
            .all(|&b| b & pad == TAIL_NEUTRAL & pad)
    }

    /// Packed storage bytes (codes + per-row parameters).
    pub fn packed_bytes(&self) -> usize {
        self.codes.len() + self.rows * (self.planes + 1) * 4
    }

    /// Effective bits per weight.
    pub fn bits_per_weight(&self) -> f64 {
        self.packed_bytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gptqt::{search_row, SearchParams};
    use crate::util::Rng;

    fn toy_packed() -> (PackedBcLayer, Vec<FusedRow>, Vec<Vec<u32>>) {
        // 2 rows × 10 cols, 2 planes
        let fused = vec![
            FusedRow { alphas: vec![0.5, 2.0], bias: 0.1 },
            FusedRow { alphas: vec![1.0, 4.0], bias: -0.3 },
        ];
        let mut rng = Rng::new(7);
        let patterns: Vec<Vec<u32>> = (0..2)
            .map(|_| (0..10).map(|_| rng.below(4) as u32).collect())
            .collect();
        let p = PackedBcLayer::pack(2, 10, &fused, &patterns);
        (p, fused, patterns)
    }

    #[test]
    fn pack_dequant_matches_patterns() {
        let (p, fused, patterns) = toy_packed();
        let dq = p.dequant();
        for r in 0..2 {
            for c in 0..10 {
                let expect = fused[r].decode(patterns[r][c]);
                assert!(
                    (dq.get(r, c) - expect).abs() < 1e-6,
                    "({r},{c}): {} vs {}",
                    dq.get(r, c),
                    expect
                );
            }
        }
    }

    #[test]
    fn sign_extraction() {
        let (p, _, patterns) = toy_packed();
        for r in 0..2 {
            for c in 0..10 {
                for plane in 0..2 {
                    let want = if patterns[r][c] >> plane & 1 == 1 { 1.0 } else { -1.0 };
                    assert_eq!(p.sign(r, c, plane), want);
                }
            }
        }
    }

    #[test]
    fn packing_is_compact() {
        let (p, _, _) = toy_packed();
        // 10 cols → 2 groups, 2 rows, 2 planes = 8 bytes of codes
        assert_eq!(p.codes.len(), 8);
        assert!(p.packed_bytes() < 2 * 10 * 4);
    }

    #[test]
    fn gptqt_rows_pack_exactly() {
        // end-to-end: search → encode → pack → dequant equals snap
        let mut rng = Rng::new(8);
        let cols = 64;
        let rows_n = 4;
        let mut fused = Vec::new();
        let mut patterns = Vec::new();
        let mut expect = Tensor::zeros(rows_n, cols);
        let sp = SearchParams { step1_bits: 5, final_bits: 3, explore_range: 1, explore_grid: 4 };
        for r in 0..rows_n {
            let row: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
            let hdiag = vec![1.0f64; cols];
            let gr = search_row(&row, &hdiag, &sp);
            let pats: Vec<u32> = row.iter().map(|&w| gr.encode(w)).collect();
            for (c, &w) in row.iter().enumerate() {
                expect.set(r, c, crate::quant::RowCodebook::snap(&gr, w));
            }
            fused.push(FusedRow::from_gptqt(&gr));
            patterns.push(pats);
        }
        let packed = PackedBcLayer::pack(rows_n, cols, &fused, &patterns);
        let dq = packed.dequant();
        assert!(
            dq.max_abs_diff(&expect) < 1e-4,
            "fused/packed dequant deviates: {}",
            dq.max_abs_diff(&expect)
        );
        assert_eq!(packed.planes, 3);
        assert!(packed.bits_per_weight() < 32.0);
    }

    #[test]
    fn ragged_tail_is_pinned_to_neutral_encoding() {
        // 10 cols → 2 ragged tail columns in the last group; the packer
        // must leave their bits at TAIL_NEUTRAL even when the pattern
        // source would have set them.
        let (p, _, _) = toy_packed();
        assert!(p.tail_is_neutral());
        let pad = !((1u8 << (10 % GROUP)) - 1);
        let g = p.groups - 1;
        for &b in &p.codes[g * p.rows * p.planes..] {
            assert_eq!(b & pad, TAIL_NEUTRAL & pad, "tail bits of byte {b:#010b}");
        }
        // aligned layers are trivially neutral
        let fused = vec![FusedRow { alphas: vec![1.0], bias: 0.0 }];
        let patterns = vec![vec![1u32; 16]];
        assert!(PackedBcLayer::pack(1, 16, &fused, &patterns).tail_is_neutral());
        // the deterministic random scaffolding goes through pack() too
        assert!(PackedBcLayer::random(7, 13, 3, 5).tail_is_neutral());
    }

    #[test]
    fn corrupted_tail_bits_cannot_change_kernel_output() {
        // The neutrality argument: LUTs are built from zero-padded
        // activations, so even adversarial tail patterns contribute ±0.
        // This pins the *reason* the TAIL_NEUTRAL contract is safe to
        // rely on from full-byte gathers.
        let layer = PackedBcLayer::random(6, 13, 2, 123);
        let mut rng = Rng::new(124);
        let x: Vec<f32> = (0..13).map(|_| rng.normal_f32()).collect();
        let mut y_ref = vec![0.0f32; 6];
        crate::kernels::gemv_lut::gemv_lut(&layer, &x, &mut y_ref);
        let mut corrupted = layer.clone();
        let pad = !((1u8 << (13 % GROUP)) - 1);
        let g = corrupted.groups - 1;
        for slot in corrupted.codes[g * corrupted.rows * corrupted.planes..].iter_mut() {
            *slot |= pad;
        }
        assert!(!corrupted.tail_is_neutral());
        let mut y = vec![0.0f32; 6];
        crate::kernels::gemv_lut::gemv_lut(&corrupted, &x, &mut y);
        assert_eq!(y, y_ref, "tail sign bits must be value-neutral");
    }

    #[test]
    fn bits_per_weight_approaches_planes_for_wide_layers() {
        let cols = 4096;
        let fused = vec![FusedRow { alphas: vec![1.0, 2.0, 4.0], bias: 0.0 }];
        let patterns = vec![vec![0u32; cols]];
        let p = PackedBcLayer::pack(1, cols, &fused, &patterns);
        let bpw = p.bits_per_weight();
        assert!(bpw > 2.9 && bpw < 3.2, "bpw={bpw}");
    }
}
