//! BCQ — binary-coding quantization (paper §II-A, Eq. 3–4; Kwon et al.
//! 2021, reproduced here as a baseline).
//!
//! A row of weights is approximated as `w ≈ Σᵢ αᵢ bᵢ` with `bᵢ ∈ {±1}ᵈ`
//! and per-row floats `αᵢ`. Fitting is the classic two-phase recipe:
//!
//! 1. **Greedy** (Eq. 3): `bᵢ = sign(rᵢ₋₁)`, `αᵢ = rᵢ₋₁ᵀbᵢ / d`, residual
//!    peeling.
//! 2. **Alternating least squares** (Eq. 4): given the sign matrix `B`,
//!    solve `α = (BᵀB)⁻¹Bᵀw`; given `α`, re-assign each weight to the
//!    nearest representable level; iterate.
//!
//! BCQ minimizes *weight* MSE — exactly the objective the paper shows
//! overfits under GPTQ's compensation loop (Table V's GPTQ+BCQ row).

use super::SortedLevels;
use crate::tensor::linalg::{spd_inverse, MatF64};

/// A fitted per-row binary coding `w ≈ Σ αᵢ bᵢ` (no offset term — BCQ is
/// symmetric around zero, one of its weaknesses on shifted weight rows).
#[derive(Debug, Clone)]
pub struct BcqRow {
    /// One α per bit, `α₁` fitted first (largest magnitude residual).
    pub alphas: Vec<f32>,
}

impl BcqRow {
    /// All `2^m` representable levels `Σ ±αᵢ`, ascending.
    pub fn level_set(&self) -> SortedLevels {
        SortedLevels::new(enumerate_levels(&self.alphas, 0.0))
    }

    /// Sign pattern (bit per α, 1 ⇒ +1) of the level nearest to `w`.
    pub fn encode(&self, w: f32) -> u32 {
        let mut best = 0u32;
        let mut best_d = f32::INFINITY;
        for pattern in 0..(1u32 << self.alphas.len()) {
            let v = self.decode(pattern);
            let d = (v - w).abs();
            if d < best_d {
                best_d = d;
                best = pattern;
            }
        }
        best
    }

    /// Level value of a sign pattern.
    #[inline]
    pub fn decode(&self, pattern: u32) -> f32 {
        let mut v = 0.0f32;
        for (i, &a) in self.alphas.iter().enumerate() {
            v += if pattern >> i & 1 == 1 { a } else { -a };
        }
        v
    }
}

/// All `Σ ±αᵢ + c` values.
pub fn enumerate_levels(alphas: &[f32], c: f32) -> Vec<f32> {
    let m = alphas.len();
    (0..(1u32 << m))
        .map(|pattern| {
            let mut v = c;
            for (i, &a) in alphas.iter().enumerate() {
                v += if pattern >> i & 1 == 1 { a } else { -a };
            }
            v
        })
        .collect()
}

/// Greedy residual fit (Eq. 3).
pub fn greedy_fit(row: &[f32], bits: u32) -> BcqRow {
    let d = row.len().max(1);
    let mut residual: Vec<f32> = row.to_vec();
    let mut alphas = Vec::with_capacity(bits as usize);
    for _ in 0..bits {
        // b = sign(r); alpha = rᵀb/d = mean(|r|)
        let alpha = residual.iter().map(|r| r.abs()).sum::<f32>() / d as f32;
        for r in residual.iter_mut() {
            *r -= alpha * r.signum();
        }
        alphas.push(alpha);
    }
    BcqRow { alphas }
}

/// Greedy + alternating LSQ refinement (Eq. 4). `iters` alternations;
/// stops early when the assignment stabilizes.
pub fn bcq_fit(row: &[f32], bits: u32, iters: usize) -> BcqRow {
    let mut fit = greedy_fit(row, bits);
    if row.is_empty() {
        return fit;
    }
    let m = bits as usize;
    let mut assignment: Vec<u32> = row.iter().map(|&w| fit.encode(w)).collect();
    let mut best = fit.clone();
    let mut best_mse = fit_mse(row, &fit);
    for _ in 0..iters {
        // --- α step: solve (BᵀB) α = Bᵀ w  (m×m, SPD after damping) ---
        let mut btb = MatF64::zeros(m);
        let mut btw = vec![0.0f64; m];
        for (&w, &pat) in row.iter().zip(&assignment) {
            let signs: Vec<f64> = (0..m)
                .map(|i| if pat >> i & 1 == 1 { 1.0 } else { -1.0 })
                .collect();
            for i in 0..m {
                btw[i] += signs[i] * w as f64;
                for j in 0..m {
                    btb.data[i * m + j] += signs[i] * signs[j];
                }
            }
        }
        for i in 0..m {
            btb.data[i * m + i] += 1e-9 * row.len() as f64; // damp ties
        }
        let Ok(inv) = spd_inverse(&btb) else { break };
        let mut new_alphas = vec![0.0f32; m];
        for i in 0..m {
            let mut s = 0.0;
            for j in 0..m {
                s += inv.data[i * m + j] * btw[j];
            }
            new_alphas[i] = s.abs() as f32; // sign folds into b
        }
        fit.alphas = new_alphas;
        let mse = fit_mse(row, &fit);
        if mse < best_mse {
            best_mse = mse;
            best = fit.clone();
        }
        // --- b step: re-assign to nearest level ---
        let new_assignment: Vec<u32> = row.iter().map(|&w| fit.encode(w)).collect();
        if new_assignment == assignment {
            break;
        }
        assignment = new_assignment;
    }
    best
}

/// Weight-MSE of a fit against its row (the objective BCQ minimizes).
pub fn fit_mse(row: &[f32], fit: &BcqRow) -> f64 {
    let cb = fit.level_set();
    row.iter()
        .map(|&w| {
            let d = (w - crate::quant::RowCodebook::snap(&cb, w)) as f64;
            d * d
        })
        .sum::<f64>()
        / row.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::RowCodebook;
    use crate::util::Rng;

    #[test]
    fn greedy_one_bit_is_mean_abs() {
        let row = [1.0f32, -2.0, 3.0, -4.0];
        let fit = greedy_fit(&row, 1);
        assert!((fit.alphas[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn decode_encode_consistent() {
        let fit = BcqRow { alphas: vec![0.5, 2.0] };
        for pat in 0..4u32 {
            let v = fit.decode(pat);
            assert_eq!(fit.encode(v), pat, "pattern {pat} value {v}");
        }
    }

    #[test]
    fn level_set_size() {
        let fit = BcqRow { alphas: vec![1.0, 2.0, 4.0] };
        assert_eq!(fit.level_set().as_slice().len(), 8);
    }

    #[test]
    fn alternating_improves_or_matches_greedy() {
        let mut rng = Rng::new(41);
        for _ in 0..20 {
            let row: Vec<f32> = (0..128).map(|_| rng.normal_f32()).collect();
            let g = greedy_fit(&row, 3);
            let a = bcq_fit(&row, 3, 10);
            assert!(
                fit_mse(&row, &a) <= fit_mse(&row, &g) + 1e-6,
                "alt {} > greedy {}",
                fit_mse(&row, &a),
                fit_mse(&row, &g)
            );
        }
    }

    #[test]
    fn exact_two_level_row_is_recovered() {
        // row drawn exactly from {±1.5}: 1-bit BCQ should be lossless
        let row = [1.5f32, -1.5, 1.5, 1.5, -1.5, -1.5, 1.5, -1.5];
        let fit = bcq_fit(&row, 1, 10);
        assert!(fit_mse(&row, &fit) < 1e-10);
    }

    #[test]
    fn exact_four_level_row_is_recovered() {
        // levels {±a2 ±a1} with a1=0.5, a2=2.0
        let levels = [-2.5f32, -1.5, 1.5, 2.5];
        let mut rng = Rng::new(42);
        let row: Vec<f32> = (0..256).map(|_| levels[rng.range(0, 4)]).collect();
        let fit = bcq_fit(&row, 2, 20);
        assert!(fit_mse(&row, &fit) < 1e-6, "mse={}", fit_mse(&row, &fit));
    }

    #[test]
    fn snap_produces_representable_values() {
        let mut rng = Rng::new(43);
        let row: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
        let fit = bcq_fit(&row, 3, 5);
        let cb = fit.level_set();
        let levels = cb.levels();
        for &w in &row {
            let s = cb.snap(w);
            assert!(levels.iter().any(|&l| (l - s).abs() < 1e-6));
        }
    }

    #[test]
    fn shifted_rows_hurt_bcq() {
        // BCQ is symmetric around 0: a strongly shifted row must quantize
        // worse than the same row centered. (This asymmetry weakness is
        // part of why BCQ collapses in the paper's tables.)
        let mut rng = Rng::new(44);
        let centered: Vec<f32> = (0..256).map(|_| rng.normal_f32() * 0.1).collect();
        let shifted: Vec<f32> = centered.iter().map(|&w| w + 10.0).collect();
        let fc = bcq_fit(&centered, 2, 10);
        let fs = bcq_fit(&shifted, 2, 10);
        assert!(fit_mse(&shifted, &fs) > fit_mse(&centered, &fc));
    }
}
