//! Dense tensor substrate.
//!
//! The quantization pipeline (Hessians, error compensation, perplexity
//! forward pass) needs a small, predictable dense linear-algebra layer.
//! No BLAS is available offline, so [`Tensor`] carries cache-blocked
//! matmul/gemv implementations tuned well enough that calibration and
//! evaluation run in seconds at the repo's model scales, plus the Cholesky
//! routines GPTQ requires.

pub mod linalg;
pub mod ops;

use crate::util::Rng;

/// A dense row-major f32 matrix (2-D tensor). 1-D vectors are `1×n` or
/// `n×1` as convenient; almost everything in the pipeline is 2-D.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl std::fmt::Debug for Tensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Tensor[{}x{}]", self.rows, self.cols)?;
        if self.len() <= 16 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Tensor {
    /// Zero-filled `rows × cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Tensor { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Construct from a row-major vec. Panics if sizes mismatch.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "Tensor::from_vec size mismatch");
        Tensor { rows, cols, data }
    }

    /// Construct from a slice.
    pub fn from_slice(rows: usize, cols: usize, data: &[f32]) -> Self {
        Self::from_vec(rows, cols, data.to_vec())
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut t = Self::zeros(n, n);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// i.i.d. N(0, sigma²) entries.
    pub fn randn(rows: usize, cols: usize, sigma: f32, rng: &mut Rng) -> Self {
        let mut t = Self::zeros(rows, cols);
        rng.fill_normal(&mut t.data, sigma);
        t
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Extract a column as a new vec.
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    /// Reshape in place (same number of elements).
    pub fn reshape(mut self, rows: usize, cols: usize) -> Self {
        assert_eq!(rows * cols, self.data.len(), "reshape size mismatch");
        self.rows = rows;
        self.cols = cols;
        self
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Tensor {
        let mut t = Tensor::zeros(self.cols, self.rows);
        // blocked transpose for cache behaviour on large matrices
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        t.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Mean squared difference to another tensor of the same shape.
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape(), other.shape());
        if self.is_empty() {
            return 0.0;
        }
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            / self.len() as f64
    }

    /// Max absolute difference.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// min/max over all entries. Returns (0,0) for empty tensors.
    pub fn min_max(&self) -> (f32, f32) {
        if self.data.is_empty() {
            return (0.0, 0.0);
        }
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &x in &self.data {
            mn = mn.min(x);
            mx = mx.max(x);
        }
        (mn, mx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::from_slice(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.shape(), (2, 3));
        assert_eq!(t.get(0, 2), 3.0);
        assert_eq!(t.get(1, 0), 4.0);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.col(1), vec![2., 5.]);
    }

    #[test]
    fn eye_matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(5, 5, 1.0, &mut rng);
        let i = Tensor::eye(5);
        let prod = a.matmul(&i);
        assert!(a.max_abs_diff(&prod) < 1e-6);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(7, 13, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().get(3, 5), a.get(5, 3));
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_slice(2, 3, &[1., 2., 3., 4., 5., 6.]).reshape(3, 2);
        assert_eq!(t.get(2, 1), 6.0);
    }

    #[test]
    #[should_panic]
    fn reshape_wrong_size_panics() {
        let _ = Tensor::zeros(2, 3).reshape(4, 2);
    }

    #[test]
    fn mse_and_norm() {
        let a = Tensor::from_slice(1, 3, &[0., 3., 4.]);
        let b = Tensor::zeros(1, 3);
        assert!((a.norm() - 5.0).abs() < 1e-9);
        assert!((a.mse(&b) - 25.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn min_max() {
        let a = Tensor::from_slice(1, 4, &[-3., 0.5, 9., -0.1]);
        assert_eq!(a.min_max(), (-3.0, 9.0));
    }
}
