//! Dense linear algebra needed by GPTQ: symmetric positive-definite
//! Cholesky factorization, triangular solves, and SPD inversion.
//!
//! GPTQ needs `H⁻¹` of the (dampened) Hessian `H = 2XXᵀ + λI` and, in the
//! standard formulation, the *upper Cholesky factor of the inverse*
//! (`chol(H⁻¹)ᵀ`) whose rows drive the column-by-column compensation.
//! Everything is computed in f64 for stability and returned as f64 — the
//! Hessian dimension is the layer input width (≤ a few thousand here).

use thiserror::Error;

#[derive(Debug, Error)]
pub enum LinalgError {
    #[error("matrix not positive definite at pivot {0} (value {1})")]
    NotPositiveDefinite(usize, f64),
    #[error("dimension mismatch: {0}")]
    Dimension(String),
}

/// Row-major square f64 matrix helper for the linalg layer.
#[derive(Clone, Debug)]
pub struct MatF64 {
    pub n: usize,
    pub data: Vec<f64>,
}

impl MatF64 {
    pub fn zeros(n: usize) -> Self {
        MatF64 { n, data: vec![0.0; n * n] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_rows(n: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), n * n);
        MatF64 { n, data }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.n + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.n + c] = v;
    }

    /// `self @ other`.
    pub fn matmul(&self, other: &MatF64) -> MatF64 {
        assert_eq!(self.n, other.n);
        let n = self.n;
        let mut out = MatF64::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let a = self.get(i, k);
                if a == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out.data[i * n + j] += a * other.data[k * n + j];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> MatF64 {
        let n = self.n;
        let mut out = MatF64::zeros(n);
        for i in 0..n {
            for j in 0..n {
                out.data[j * n + i] = self.data[i * n + j];
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &MatF64) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

/// Lower-triangular Cholesky factor `L` with `L Lᵀ = A`.
///
/// `A` must be symmetric positive definite; returns
/// [`LinalgError::NotPositiveDefinite`] otherwise (callers damp and retry).
pub fn cholesky(a: &MatF64) -> Result<MatF64, LinalgError> {
    let n = a.n;
    let mut l = MatF64::zeros(n);
    for j in 0..n {
        // diagonal
        let mut d = a.get(j, j);
        for k in 0..j {
            let ljk = l.get(j, k);
            d -= ljk * ljk;
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(LinalgError::NotPositiveDefinite(j, d));
        }
        let dj = d.sqrt();
        l.set(j, j, dj);
        // column below the diagonal
        for i in j + 1..n {
            let mut s = a.get(i, j);
            let (ri, rj) = (i * n, j * n);
            for k in 0..j {
                s -= l.data[ri + k] * l.data[rj + k];
            }
            l.set(i, j, s / dj);
        }
    }
    Ok(l)
}

/// Solve `L y = b` (lower triangular, forward substitution).
pub fn solve_lower(l: &MatF64, b: &[f64]) -> Vec<f64> {
    let n = l.n;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        let row = &l.data[i * n..i * n + i];
        for (k, &lik) in row.iter().enumerate() {
            s -= lik * y[k];
        }
        y[i] = s / l.data[i * n + i];
    }
    y
}

/// Solve `Lᵀ x = y` (backward substitution on the transpose of lower `L`).
pub fn solve_lower_t(l: &MatF64, y: &[f64]) -> Vec<f64> {
    let n = l.n;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l.data[k * n + i] * x[k];
        }
        x[i] = s / l.data[i * n + i];
    }
    x
}

/// Inverse of an SPD matrix via Cholesky: `A⁻¹ = L⁻ᵀ L⁻¹`.
pub fn spd_inverse(a: &MatF64) -> Result<MatF64, LinalgError> {
    let n = a.n;
    let l = cholesky(a)?;
    let mut inv = MatF64::zeros(n);
    // Solve A x_j = e_j column by column.
    let mut e = vec![0.0; n];
    for j in 0..n {
        e.iter_mut().for_each(|v| *v = 0.0);
        e[j] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        for i in 0..n {
            inv.data[i * n + j] = x[i];
        }
    }
    Ok(inv)
}

/// Upper Cholesky factor `U` with `Uᵀ U = A` (i.e. `U = chol(A)ᵀ`).
///
/// GPTQ uses `U = chol(H⁻¹)ᵀ`: row `q` of `U` scaled by `1/U[q,q]` gives
/// the compensation coefficients for the remaining columns.
pub fn cholesky_upper(a: &MatF64) -> Result<MatF64, LinalgError> {
    Ok(cholesky(a)?.transpose())
}

/// Dampen a symmetric matrix in place: `A += lambda * mean(diag(A)) * I`.
/// Returns the additive term used.
pub fn dampen(a: &mut MatF64, lambda: f64) -> f64 {
    let n = a.n;
    let mean_diag = (0..n).map(|i| a.get(i, i)).sum::<f64>() / n.max(1) as f64;
    let add = lambda * mean_diag;
    for i in 0..n {
        a.data[i * n + i] += add;
    }
    add
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Random SPD matrix `M Mᵀ + n·I`.
    fn random_spd(n: usize, rng: &mut Rng) -> MatF64 {
        let mut m = MatF64::zeros(n);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        let mt = m.transpose();
        let mut a = m.matmul(&mt);
        for i in 0..n {
            a.data[i * n + i] += n as f64;
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(21);
        for n in [1, 2, 5, 17, 40] {
            let a = random_spd(n, &mut rng);
            let l = cholesky(&a).unwrap();
            let llt = l.matmul(&l.transpose());
            assert!(llt.max_abs_diff(&a) < 1e-8 * n as f64, "n={n}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = MatF64::from_rows(2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Rng::new(22);
        let a = random_spd(12, &mut rng);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..12).map(|i| (i as f64) - 3.0).collect();
        let y = solve_lower(&l, &b);
        let x = solve_lower_t(&l, &y);
        // check A x = b
        let n = 12;
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..n {
                s += a.get(i, j) * x[j];
            }
            assert!((s - b[i]).abs() < 1e-8, "row {i}: {s} vs {}", b[i]);
        }
    }

    #[test]
    fn spd_inverse_correct() {
        let mut rng = Rng::new(23);
        for n in [1, 3, 8, 25] {
            let a = random_spd(n, &mut rng);
            let inv = spd_inverse(&a).unwrap();
            let prod = a.matmul(&inv);
            let eye = MatF64::eye(n);
            assert!(prod.max_abs_diff(&eye) < 1e-8, "n={n}");
        }
    }

    #[test]
    fn upper_factor_matches() {
        let mut rng = Rng::new(24);
        let a = random_spd(9, &mut rng);
        let u = cholesky_upper(&a).unwrap();
        let utu = u.transpose().matmul(&u);
        assert!(utu.max_abs_diff(&a) < 1e-8);
        // upper triangular: zeros below diagonal
        for i in 0..9 {
            for j in 0..i {
                assert_eq!(u.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn dampen_shifts_diagonal() {
        let mut a = MatF64::eye(4);
        let add = dampen(&mut a, 0.01);
        assert!((add - 0.01).abs() < 1e-12);
        for i in 0..4 {
            assert!((a.get(i, i) - 1.01).abs() < 1e-12);
        }
    }

    #[test]
    fn dampening_rescues_near_singular() {
        // rank-deficient Hessian (duplicate rows in X) becomes factorizable
        // (4s are exactly representable: the inner subtraction hits 0.0)
        let mut a = MatF64::from_rows(2, vec![4.0, 4.0, 4.0, 4.0]);
        assert!(cholesky(&a).is_err());
        dampen(&mut a, 0.01);
        assert!(cholesky(&a).is_ok());
    }
}
