//! Matmul / gemv and elementwise kernels for [`Tensor`].
//!
//! Cache-blocked, k-inner-loop matmul with optional threading via the
//! global pool. These are the *calibration-time* kernels; the serving hot
//! path uses the specialized quantized kernels in `crate::kernels`.

use super::Tensor;
use crate::util::pool;

/// Tile sizes for the blocked matmul. Chosen for ~32 KiB L1 data cache:
/// an MC×KC panel of A (64×256×4 B = 64 KiB, L2-resident) and a KC-row
/// slab of B streamed through L1.
const MC: usize = 64;
const KC: usize = 256;

impl Tensor {
    /// `self (m×k) @ other (k×n)` single-threaded.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(
            self.cols(),
            other.rows(),
            "matmul shape mismatch: {:?} @ {:?}",
            self.shape(),
            other.shape()
        );
        let mut out = Tensor::zeros(self.rows(), other.cols());
        matmul_into(self, other, &mut out, false);
        out
    }

    /// `self @ other` using the global thread pool (row-partitioned).
    pub fn matmul_par(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.cols(), other.rows(), "matmul_par shape mismatch");
        let mut out = Tensor::zeros(self.rows(), other.cols());
        matmul_into(self, other, &mut out, true);
        out
    }

    /// `self (m×k) @ other (n×k)ᵀ` — the natural layout for linear layers
    /// stored (out × in): `y = x · Wᵀ` runs row-dot-row with no transpose
    /// materialization.
    pub fn matmul_nt(&self, other: &Tensor) -> Tensor {
        assert_eq!(
            self.cols(),
            other.cols(),
            "matmul_nt shape mismatch: {:?} @ {:?}ᵀ",
            self.shape(),
            other.shape()
        );
        let mut out = Tensor::zeros(self.rows(), other.rows());
        let n = other.rows();
        if self.rows() >= 4 && n >= 16 {
            // parallel over output rows
            let out_ptr = SendPtrF(out.data_mut().as_mut_ptr());
            let m = self.rows();
            pool::global().scope_chunks(m, |range| {
                let out_ptr = &out_ptr;
                for i in range {
                    let xrow = self.row(i);
                    // SAFETY: disjoint rows per chunk, joined before return.
                    let orow = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
                    for (j, o) in orow.iter_mut().enumerate() {
                        *o = dot(xrow, other.row(j));
                    }
                }
            });
        } else {
            for i in 0..self.rows() {
                for j in 0..n {
                    let v = dot(self.row(i), other.row(j));
                    out.set(i, j, v);
                }
            }
        }
        out
    }

    /// Matrix–vector product `self (m×k) @ x (k)`.
    pub fn gemv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols(), x.len(), "gemv shape mismatch");
        let mut y = vec![0.0f32; self.rows()];
        for (r, yr) in y.iter_mut().enumerate() {
            *yr = dot(self.row(r), x);
        }
        y
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape(), other.shape());
        for (a, &b) in self.data_mut().iter_mut().zip(other.data()) {
            *a += alpha * b;
        }
    }

    /// `self - other` as a new tensor.
    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape(), other.shape());
        let data = self
            .data()
            .iter()
            .zip(other.data())
            .map(|(&a, &b)| a - b)
            .collect();
        Tensor::from_vec(self.rows(), self.cols(), data)
    }

    /// `self + other` as a new tensor.
    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape(), other.shape());
        let data = self
            .data()
            .iter()
            .zip(other.data())
            .map(|(&a, &b)| a + b)
            .collect();
        Tensor::from_vec(self.rows(), self.cols(), data)
    }

    /// Scale by a scalar, returning a new tensor.
    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }
}

struct SendPtrF(*mut f32);
// SAFETY: pool chunks write disjoint output rows and are joined before
// the buffer is read back.
unsafe impl Sync for SendPtrF {}
// SAFETY: the pointer outlives the scope — the pool joins before return.
unsafe impl Send for SendPtrF {}

/// Dot product, dispatched to the best SIMD tier of the running CPU
/// ([`crate::kernels::simd`]). The AVX2 tier is bitwise-identical to
/// the pinned 8-accumulator scalar loop, so routing the calibration
/// kernels through it changes no result anywhere.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    crate::kernels::simd::dot(a, b)
}

/// Blocked matmul kernel. `C += A @ B` with C zero-initialized by caller.
fn matmul_into(a: &Tensor, b: &Tensor, c: &mut Tensor, threaded: bool) {
    let (m, k) = a.shape();
    let n = b.cols();
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let a_data = a.data();
    let b_data = b.data();
    let c_data = c.data_mut();

    let row_block = |rows: std::ops::Range<usize>, c_rows: &mut [f32]| {
        // i-k-j loop order: innermost j streams B rows and C rows
        // contiguously; k blocked so the B panel stays cache-resident.
        for kb in (0..k).step_by(KC) {
            let kend = (kb + KC).min(k);
            for ib in (rows.start..rows.end).step_by(MC) {
                let iend = (ib + MC).min(rows.end);
                for i in ib..iend {
                    let arow = &a_data[i * k..(i + 1) * k];
                    let crow = &mut c_rows[(i - rows.start) * n..(i - rows.start + 1) * n];
                    for kk in kb..kend {
                        let aik = arow[kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b_data[kk * n..(kk + 1) * n];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
    };

    if !threaded || m < 8 {
        row_block(0..m, c_data);
        return;
    }

    // Partition output rows into disjoint mutable slabs for the pool.
    let pool = pool::global();
    let parts = pool.threads().min(m);
    let chunk = m.div_ceil(parts);
    let mut slabs: Vec<(usize, &mut [f32])> = Vec::with_capacity(parts);
    {
        let mut rest = c_data;
        let mut start = 0usize;
        while start < m {
            let rows = chunk.min(m - start);
            let (head, tail) = rest.split_at_mut(rows * n);
            slabs.push((start, head));
            rest = tail;
            start += rows;
        }
    }
    let slabs_cell: Vec<std::sync::Mutex<(usize, &mut [f32])>> =
        slabs.into_iter().map(std::sync::Mutex::new).collect();
    pool.scope_chunks(slabs_cell.len(), |range| {
        for idx in range {
            let mut guard = slabs_cell[idx].lock().unwrap();
            let (start, ref mut slab) = *guard;
            let rows = slab.len() / n;
            row_block(start..start + rows, slab);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let mut c = Tensor::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0f64;
                for kk in 0..a.cols() {
                    s += a.get(i, kk) as f64 * b.get(kk, j) as f64;
                }
                c.set(i, j, s as f32);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(10);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (64, 128, 32), (70, 300, 65)] {
            let a = Tensor::randn(m, k, 1.0, &mut rng);
            let b = Tensor::randn(k, n, 1.0, &mut rng);
            let c = a.matmul(&b);
            let c_ref = naive_matmul(&a, &b);
            let scale = (k as f32).sqrt();
            assert!(
                c.max_abs_diff(&c_ref) < 1e-4 * scale,
                "mismatch at ({m},{k},{n}): {}",
                c.max_abs_diff(&c_ref)
            );
        }
    }

    #[test]
    fn matmul_par_matches_serial() {
        let mut rng = Rng::new(11);
        let a = Tensor::randn(123, 77, 1.0, &mut rng);
        let b = Tensor::randn(77, 55, 1.0, &mut rng);
        let c1 = a.matmul(&b);
        let c2 = a.matmul_par(&b);
        assert!(c1.max_abs_diff(&c2) < 1e-5);
    }

    #[test]
    fn matmul_nt_matches_transpose() {
        let mut rng = Rng::new(13);
        for &(m, k, n) in &[(3, 7, 5), (40, 64, 33), (2, 8, 100)] {
            let a = Tensor::randn(m, k, 1.0, &mut rng);
            let b = Tensor::randn(n, k, 1.0, &mut rng);
            let c1 = a.matmul_nt(&b);
            let c2 = a.matmul(&b.transpose());
            assert!(c1.max_abs_diff(&c2) < 1e-4, "({m},{k},{n})");
        }
    }

    #[test]
    fn gemv_matches_matmul() {
        let mut rng = Rng::new(12);
        let a = Tensor::randn(31, 47, 1.0, &mut rng);
        let x = Tensor::randn(47, 1, 1.0, &mut rng);
        let y1 = a.gemv(x.data());
        let y2 = a.matmul(&x);
        for (i, v) in y1.iter().enumerate() {
            assert!((v - y2.get(i, 0)).abs() < 1e-4);
        }
    }

    #[test]
    fn dot_basic() {
        let a: Vec<f32> = (0..19).map(|i| i as f32).collect();
        let b = vec![2.0f32; 19];
        let expect: f32 = (0..19).map(|i| i as f32 * 2.0).sum();
        assert_eq!(dot(&a, &b), expect);
    }

    #[test]
    fn axpy_add_sub_scale() {
        let a = Tensor::from_slice(1, 3, &[1., 2., 3.]);
        let b = Tensor::from_slice(1, 3, &[10., 20., 30.]);
        assert_eq!(a.add(&b).data(), &[11., 22., 33.]);
        assert_eq!(b.sub(&a).data(), &[9., 18., 27.]);
        assert_eq!(a.scale(2.0).data(), &[2., 4., 6.]);
        let mut c = a.clone();
        c.axpy(0.5, &b);
        assert_eq!(c.data(), &[6., 12., 18.]);
    }

    #[test]
    fn empty_matmul() {
        let a = Tensor::zeros(0, 5);
        let b = Tensor::zeros(5, 3);
        let c = a.matmul(&b);
        assert_eq!(c.shape(), (0, 3));
    }
}
