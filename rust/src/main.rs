//! `gptqt` binary — CLI entrypoint for the quantization pipeline, the
//! serving coordinator, and the experiment drivers. See `gptqt help`.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(gptqt::cli::run(&args));
}
